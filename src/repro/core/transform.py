"""Pass 3 — partition transformation (paper Alg. 1).

Restream the edges and turn the vertex→partition mapping (join of passes
1 and 2) into an edge→partition assignment, strictly enforcing the balance
cap L_max = τ·|E|/k:

  - both endpoints' partitions full   → any underflow partition (least load)
  - same partition                    → keep
  - an endpoint was divided (has mirrors) → reuse the mirror side (free cut)
  - otherwise                         → cut the higher-degree endpoint
                                        (HDRF-style, lines 20-22)

Space O(k) (the load array), time O(|E|) — matching §III-C.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..dist import collectives as coll


def transform_np(src: np.ndarray, dst: np.ndarray,
                 vertex_part: np.ndarray, deg: np.ndarray,
                 divided: np.ndarray, k: int, tau: float = 1.0, *,
                 loads: np.ndarray | None = None,
                 lmax: float | None = None) -> np.ndarray:
    """``loads``/``lmax`` seed the greedy pass with pre-existing
    per-partition edge counts and an external balance cap — the
    incremental window-assign path (``stages.incremental_assign``)
    streams NEW edges against the loads the resident partition already
    carries.  Defaults reproduce the batch Alg. 1 exactly."""
    E = src.shape[0]
    if lmax is None:
        lmax = tau * E / float(k)
    loads = (np.zeros(k, dtype=np.int64) if loads is None
             else np.asarray(loads, dtype=np.int64).copy())
    assign = np.zeros(E, dtype=np.int32)
    vp = vertex_part
    for i in range(E):
        u = int(src[i]); v = int(dst[i])
        pu = int(vp[u]); pv = int(vp[v])
        if loads[pu] >= lmax or loads[pv] >= lmax:      # lines 6-14
            if loads[pu] < lmax:
                p = pu
            elif loads[pv] < lmax:
                p = pv
            else:
                p = int(np.argmin(loads))
        elif pu == pv:                                   # lines 15-16
            p = pu
        elif divided[u]:                                 # lines 17-19
            p = pv
        elif divided[v]:
            p = pu
        elif deg[v] > deg[u]:                            # lines 20-22
            p = pu
        else:
            p = pv
        assign[i] = p
        loads[p] += 1
    return assign


def _transform_step(loads, edge, *, lmax, k: int, k_real=None):
    u, v, pu, pv, du, dv, divu, divv, live = edge
    full_u = loads[pu] >= lmax
    full_v = loads[pv] >= lmax
    # lanes past the traced live count (the k_max-padded sweep) must not
    # win the least-loaded fallback — they stay empty forever
    cand = (loads if k_real is None
            else jnp.where(jnp.arange(k) < k_real, loads,
                           jnp.iinfo(loads.dtype).max))
    least = jnp.argmin(cand).astype(jnp.int32)
    overflow_choice = jnp.where(~full_u, pu, jnp.where(~full_v, pv, least))
    same = pu == pv
    mirror_choice = jnp.where(divu.astype(bool), pv, pu)
    has_mirror = (divu > 0) | (divv > 0)
    degree_choice = jnp.where(dv > du, pu, pv)
    normal = jnp.where(same, pu,
                       jnp.where(has_mirror, mirror_choice, degree_choice))
    p = jnp.where(full_u | full_v, overflow_choice, normal).astype(jnp.int32)
    p = jnp.where(live.astype(bool), p, 0)
    # arithmetic one-hot instead of a scatter: XLA:CPU pays a buffer copy
    # + kernel call per computed-index scatter inside a loop body, and a
    # (k,)-wide fused select is far cheaper; padded edges carry no load
    loads = loads + jnp.where(jnp.arange(k) == p, live, 0)
    return loads, p


def transform_jax(src, dst, vertex_part, deg, divided, k: int,
                  tau: float = 1.0, mask=None, lmax=None, k_real=None):
    """lax.scan form of Alg. 1 (used inside the jitted pipeline).

    ``mask`` marks live edges (the sharded backend pads each device's
    stream slice to a static length; padded rows get partition 0 and add
    no load).  ``lmax`` overrides the balance cap — per-device slices use
    τ·|E_local|/k with the *real* (masked) edge count, which is a traced
    scalar.  ``k_real`` (traced) restricts the balance cap and the
    least-loaded fallback to the live lanes of a k_max-padded sweep
    step."""
    E = src.shape[0]
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    live = (jnp.ones((E,), jnp.int32) if mask is None
            else jnp.asarray(mask, jnp.int32))
    if lmax is None:
        lmax = (tau * E / float(k) if k_real is None
                else tau * E / k_real.astype(jnp.float32))
    vp = jnp.asarray(vertex_part, jnp.int32)
    edges = jnp.stack([
        src, dst,
        vp[src], vp[dst],
        jnp.asarray(deg, jnp.int32)[src], jnp.asarray(deg, jnp.int32)[dst],
        jnp.asarray(divided, jnp.int32)[src],
        jnp.asarray(divided, jnp.int32)[dst],
        live,
    ], axis=1)
    loads0 = jnp.zeros((k,), dtype=jnp.int32)
    step = lambda s, e: _transform_step(s, e, lmax=lmax, k=k,
                                        k_real=k_real)
    _, assign = jax.lax.scan(step, loads0, edges)
    return assign


# ---------------------------------------------------------------------------
# Restreaming (beyond the paper; Awadelkarim & Ugander's prioritized
# restreaming): re-consume the stream with the *realized* vertex→partition
# majority of the previous pass as the prior.  The transform pass then
# reuses free cuts (divided flags) and reassigns load-aware against fresh
# load counters — each extra pass measurably cuts RF (EXPERIMENTS.md
# §Perf-partitioner).
# ---------------------------------------------------------------------------

def majority_vertex_map_np(src, dst, assign, num_vertices: int,
                           k: int) -> np.ndarray:
    """Per vertex, the partition holding most of its edges in the previous
    pass (ties → lowest partition id, matching jnp.argmax)."""
    key = (np.concatenate([src, dst]).astype(np.int64) * k
           + np.tile(assign, 2))
    cnt = np.bincount(key, minlength=num_vertices * k)
    return cnt.reshape(num_vertices, k).argmax(axis=1).astype(np.int32)


def majority_vertex_map_jax(src, dst, assign, num_vertices: int, k: int,
                            mask=None, axis: str | None = None):
    """jit/shard_map form of ``majority_vertex_map_np``.  Under ``axis``
    each device counts its local slice and the (V, k) tables are psum'd —
    the restream prior is global even though streams stay device-local."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    if mask is not None:
        drop = jnp.int32(num_vertices)
        src = jnp.where(mask, src, drop)
        dst = jnp.where(mask, dst, drop)
    cnt = (jnp.zeros((num_vertices, k), jnp.int32)
           .at[src, assign].add(1, mode="drop")
           .at[dst, assign].add(1, mode="drop"))
    cnt = coll.psum(cnt, axis)
    return jnp.argmax(cnt, axis=1).astype(jnp.int32)
