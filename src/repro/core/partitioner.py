"""Backend-parametric CLUGP partitioner — thin strategies over ONE body.

The paper's §III-C scalability claim is about the *partitioner's own
runtime*: the three passes parallelize across nodes and restreaming
recovers the quality one-pass streaming leaves behind.  The pass sequence
itself lives in ``repro.core.stages.run_clugp_body``; this module holds
the public API and the per-backend strategy wrappers:

    partition(src, dst, num_vertices, cfg, backend=..., nodes=..., mesh=...)

Three backends share one ``CLUGPConfig`` and one ``CLUGPResult``:

- ``"np"``      — the interpreted host path (``HOST_STAGES`` adapters),
                  kept as the equivalence oracle.  With ``nodes > 1`` it
                  is the host reference of the sharded combine: the
                  stream splits into contiguous slices, each slice runs
                  the body in a private cluster-id space, and the
                  per-slice edge assignments concatenate (paper §III-C
                  "combine partial partitioning results").
- ``"jit"``     — single-device fused pipeline: the body under ONE jit
                  with ``JAX_STAGES`` (blocked clustering scan →
                  in-graph contraction → game → transform scan), so the
                  host never touches per-edge state.
- ``"sharded"`` — true §III-C: the SAME body with the SAME ``JAX_STAGES``
                  runs per device inside shard_map over a ``stream`` mesh
                  axis (specs resolved through ``repro.dist.sharding``
                  rule tables); the only difference is the ctx — mask,
                  ``axis="stream"``, traced per-slice vmax, per-slice
                  balance cap.

``cfg.restream`` adds that many prioritized-restream passes on every
backend (Awadelkarim & Ugander).  Measured effect in EXPERIMENTS.md
§Perf-partitioner.  The one-object façade over partition → layout → GAS
is ``repro.session.GraphSession``.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from .clustering import ClusteringResult, default_vmax
from .game import contract
from .pipeline import CLUGPConfig, CLUGPResult
from .stages import (HOST_STAGES, JAX_STAGES, StageCtx, resolve_game_mode,
                     restream_loop, run_clugp_body)
from . import metrics

BACKENDS = ("np", "jit", "sharded")
_BLOCK = 256          # game-kernel block: m_cap pads to a multiple of this


def _check_stream(src: np.ndarray) -> None:
    if src.shape[0] == 0:
        raise ValueError(
            "partition: the edge stream is empty (0 edges); there is "
            "nothing to partition")


def _pad_to(n: int, mult: int) -> int:
    return -(-max(n, 1) // mult) * mult


def partition(src: np.ndarray, dst: np.ndarray, num_vertices: int,
              cfg: CLUGPConfig, *, backend: str = "np", nodes: int = 1,
              mesh=None) -> CLUGPResult:
    """Run the CLUGP pipeline on the chosen backend.

    ``nodes`` is the §III-C stream-split width (np reference combine /
    sharded mesh size).  ``mesh`` overrides the sharded backend's mesh
    (must carry a ``stream`` axis); otherwise one is built over
    ``nodes`` devices."""
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    _check_stream(src)
    if backend == "np":
        if nodes <= 1:
            return _run_np(src, dst, num_vertices, cfg)
        return _run_np_nodes(src, dst, num_vertices, cfg, nodes)
    if backend == "jit":
        return _run_jit(src, dst, num_vertices, cfg)
    return _run_sharded(src, dst, num_vertices, cfg, nodes, mesh)


# ------------------------------------------------------------- np strategy

def _resolve_vmax(cfg: CLUGPConfig, num_edges: int) -> float:
    """The §VI-A default cap over the edges the strategy actually
    streams — the slice count for host-combine nodes, |E| otherwise (the
    sharded node_fn derives the same rule from its traced mask count)."""
    return cfg.vmax if cfg.vmax is not None else default_vmax(num_edges,
                                                              cfg.k)


def _host_ctx(num_vertices: int, num_edges: int, cfg: CLUGPConfig
              ) -> StageCtx:
    return StageCtx(num_vertices=num_vertices,
                    vmax=_resolve_vmax(cfg, num_edges))


def _run_np(src: np.ndarray, dst: np.ndarray, num_vertices: int,
            cfg: CLUGPConfig) -> CLUGPResult:
    ctx = _host_ctx(num_vertices, src.shape[0], cfg)
    out = run_clugp_body(src, dst, ctx, cfg, HOST_STAGES)
    res = CLUGPResult(out.assign, out.cluster, out.graph.cg,
                      out.cluster_assign, out.rounds)
    res.stats = metrics.summarize(src, dst, out.assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = out.cluster.num_clusters
    res.stats["game_rounds"] = out.rounds
    res.stats["backend"] = "np"
    if cfg.restream:
        trace = list(out.trace) + [res.stats["rf"]]
        res.stats["restream_rf_trace"] = [round(r, 4) for r in trace]
    return res


def _run_np_nodes(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                  cfg: CLUGPConfig, nodes: int) -> CLUGPResult:
    """Host reference of the sharded combine: contiguous ceil(E/n) slices
    (the same chunking shard_map uses), private id spaces per node,
    concatenated edge assignments, then *global* restream passes whose
    majority prior spans all slices (the psum'd table's host twin).

    The merged result is explicit about what it is: per-node clustering /
    cluster-graph objects are not stitched into one fake global object —
    ``clustering``/``cluster_graph``/``cluster_assign`` are None and
    ``stats["per_node"]`` carries each node's private-space summary."""
    E = src.shape[0]
    e_per = -(-E // nodes)
    sub_cfg = dataclasses.replace(cfg, restream=0)
    parts, per_node, pieces = [], [], []
    rounds = 0
    clusters = 0
    for i in range(nodes):
        lo, hi = i * e_per, min(E, (i + 1) * e_per)
        if hi <= lo:
            continue
        ctx = _host_ctx(num_vertices, hi - lo, sub_cfg)
        out = run_clugp_body(src[lo:hi], dst[lo:hi], ctx, sub_cfg,
                             HOST_STAGES)
        pieces.append(out.assign)
        rounds = max(rounds, out.rounds)
        clusters += out.cluster.num_clusters
        per_node.append({"node": i, "edges": int(hi - lo),
                         "clusters": out.cluster.num_clusters,
                         "game_rounds": out.rounds})
        parts.append((slice(lo, hi), out.cluster, ctx))
    assign = np.concatenate(pieces)
    gctx = StageCtx(num_vertices=num_vertices, vmax=None)
    assign, trace = restream_loop(src, dst, assign, parts, gctx, cfg,
                                  HOST_STAGES)
    res = CLUGPResult(assign, None, None, None, rounds)
    res.stats = metrics.summarize(src, dst, assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = clusters   # sum over private id spaces
    res.stats["game_rounds"] = rounds
    res.stats["backend"] = "np"
    res.stats["nodes"] = nodes
    res.stats["per_node"] = per_node
    if cfg.restream:
        res.stats["restream_rf_trace"] = [
            round(r, 4) for r in list(trace) + [res.stats["rf"]]]
    return res


# ----------------------------------------------------------- adaptive caps

class Caps(NamedTuple):
    id_cap: int
    m_cap: int
    nnz_cap: int


def _id_cap_guess(num_vertices: int, num_edges: int) -> int:
    """Initial cluster-id-space guess: ids = allocations (≤ V) + splits
    (usually a fraction of V).  The pipeline re-runs with a doubled cap
    iff the returned next_id hits it — the table is copied per scan block,
    so a tight cap is worth the rare retry."""
    return _pad_to(min(2 * num_vertices + 2048,
                       num_vertices + 2 * num_edges + 2), 1024)


def _m_cap_guess(num_vertices: int) -> int:
    """Initial compacted-cluster-count guess: real streams end with
    m ≪ V (clusters ≈ V_max-sized communities), and the game's per-round
    cost is O(m_cap·k), so guess small and retry on overflow."""
    return _pad_to(min(num_vertices, max(_BLOCK, num_vertices // 4)),
                   _BLOCK)


def _init_caps(num_vertices: int, e_per: int) -> Caps:
    m_cap = _m_cap_guess(num_vertices)
    return Caps(_id_cap_guess(num_vertices, e_per), m_cap, 8 * m_cap)


def _grow_caps(caps: Caps, *, next_id: int, m: int, overflow: bool,
               num_vertices: int, e_per: int) -> tuple:
    """One retry step of the adaptive caps shared by the device
    strategies: double whichever cap the run overflowed (bounded by its
    worst case) and report whether the run was already clean."""
    id_cap, m_cap, nnz_cap = caps
    ok = True
    if next_id > id_cap - 2:
        id_cap = min(2 * id_cap, num_vertices + 2 * e_per + 2)
        ok = False
    if m > m_cap:
        m_cap = min(2 * m_cap, _pad_to(num_vertices, _BLOCK))
        ok = False
    if overflow:
        nnz_cap = min(2 * nnz_cap, m_cap * m_cap)
        ok = False
    return Caps(id_cap, m_cap, nnz_cap), ok


# ------------------------------------------------------------ jit strategy

@partial(jax.jit, static_argnames=("num_vertices", "cfg", "vmax",
                                   "game_mode", "id_cap", "m_cap",
                                   "nnz_cap"))
def _jit_body(src, dst, *, num_vertices: int, cfg: CLUGPConfig, vmax: float,
              game_mode: str, id_cap: int, m_cap: int, nnz_cap: int):
    """The whole stage body (+ restreams) under one jit — the host sees
    only the final arrays, never per-edge state."""
    ctx = StageCtx(num_vertices=num_vertices, vmax=vmax,
                   game_mode=game_mode, id_cap=id_cap, m_cap=m_cap,
                   nnz_cap=nnz_cap)
    out = run_clugp_body(src, dst, ctx, cfg, JAX_STAGES)
    return (out.assign, out.cluster.compact, out.cluster.deg,
            out.cluster.divided, out.cluster.replicas, out.cluster.m,
            out.rounds, out.cluster_assign, out.overflow,
            out.cluster.next_id)


def _run_jit(src: np.ndarray, dst: np.ndarray, num_vertices: int,
             cfg: CLUGPConfig) -> CLUGPResult:
    E = src.shape[0]
    vmax = _resolve_vmax(cfg, E)
    caps = _init_caps(num_vertices, E)
    while True:
        out = _jit_body(
            jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
            num_vertices=num_vertices, cfg=cfg, vmax=float(vmax),
            game_mode=resolve_game_mode(cfg.kernel, caps.m_cap),
            id_cap=caps.id_cap, m_cap=caps.m_cap, nnz_cap=caps.nnz_cap)
        caps, ok = _grow_caps(caps, next_id=int(out[-1]), m=int(out[5]),
                              overflow=bool(out[-2]),
                              num_vertices=num_vertices, e_per=E)
        if ok:
            break
    assign, compact, deg, divided, replicas, m, rounds, cluster_assign = (
        np.asarray(x) for x in out[:-2])
    m = int(m)
    rounds = int(rounds)
    clus = ClusteringResult(compact, deg, divided, replicas, m)
    cg = contract(src, dst, compact)
    res = CLUGPResult(assign, clus, cg, cluster_assign[:m], rounds)
    res.stats = metrics.summarize(src, dst, assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = m
    res.stats["game_rounds"] = rounds
    res.stats["backend"] = "jit"
    return res


# ------------------------------------------------------- compile-once sweep

_SWEEP_TRACES = {"count": 0}


def sweep_trace_count() -> int:
    """How many times the stacked sweep body has been traced (== jit
    compiles) in this process — the bench/CI compile-once assertion."""
    return _SWEEP_TRACES["count"]


@partial(jax.jit, static_argnames=("num_vertices", "cfg", "game_mode",
                                   "id_cap", "m_cap", "nnz_cap"))
def _jit_sweep_body(src, dst, ks, vmaxs, *, num_vertices: int,
                    cfg: CLUGPConfig, game_mode: str, id_cap: int,
                    m_cap: int, nnz_cap: int):
    """A whole k-sweep under ONE jit: ``lax.scan`` stacks N homogeneous
    stage bodies, every lane-carrying table padded to ``cfg.k == k_max``
    while the traced per-step ``k_real`` masks the live partitions
    (argmin/cost lanes past it cost 3e38, λ and the balance cap use the
    real count).  Sweeping k therefore compiles once instead of once per
    k — the static args no longer include k itself."""
    _SWEEP_TRACES["count"] += 1

    def body(carry, per_k):
        k_real, vmax = per_k
        ctx = StageCtx(num_vertices=num_vertices, vmax=vmax,
                       game_mode=game_mode, id_cap=id_cap, m_cap=m_cap,
                       nnz_cap=nnz_cap, k_real=k_real)
        out = run_clugp_body(src, dst, ctx, cfg, JAX_STAGES)
        return carry, (out.assign, out.cluster.m, out.rounds,
                       out.overflow, out.cluster.next_id)

    _, outs = jax.lax.scan(body, 0, (ks, vmaxs))
    return outs


def partition_sweep(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                    cfg: CLUGPConfig, ks) -> list:
    """Run the jit pipeline at every ``k`` in ``ks`` under one compiled
    body (``_jit_sweep_body``) and return one ``CLUGPResult`` per k, in
    input order.  Repeat sweeps over same-shaped streams reuse the cached
    executable whatever the k values are — ``sweep_trace_count()`` exposes
    the compile count.  The adaptive caps retry the WHOLE sweep (caps are
    k-independent, so one clean set serves every step)."""
    _check_stream(src)
    ks = tuple(int(k) for k in ks)
    if not ks or min(ks) < 1:
        raise ValueError(f"partition_sweep: need at least one k >= 1, "
                         f"got {ks!r}")
    k_max = max(ks)
    sweep_cfg = dataclasses.replace(cfg, k=k_max)
    E = src.shape[0]
    vmaxs = np.array([_resolve_vmax(dataclasses.replace(cfg, k=k), E)
                      for k in ks], np.float32)
    ks_arr = np.array(ks, np.int32)
    caps = _init_caps(num_vertices, E)
    while True:
        assigns, ms, rounds, overflows, next_ids = _jit_sweep_body(
            jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
            jnp.asarray(ks_arr), jnp.asarray(vmaxs),
            num_vertices=num_vertices, cfg=sweep_cfg,
            game_mode=resolve_game_mode(cfg.kernel, caps.m_cap),
            id_cap=caps.id_cap, m_cap=caps.m_cap, nnz_cap=caps.nnz_cap)
        caps, ok = _grow_caps(caps, next_id=int(np.asarray(next_ids).max()),
                              m=int(np.asarray(ms).max()),
                              overflow=int(np.asarray(overflows).max()) > 0,
                              num_vertices=num_vertices, e_per=E)
        if ok:
            break
    results = []
    for i, k in enumerate(ks):
        assign = np.asarray(assigns[i])
        res = CLUGPResult(assign, None, None, None, int(rounds[i]))
        res.stats = metrics.summarize(src, dst, assign, num_vertices, k)
        res.stats["num_clusters"] = int(ms[i])
        res.stats["game_rounds"] = int(rounds[i])
        res.stats["backend"] = "jit"
        res.stats["sweep"] = True
        res.stats["k_max"] = k_max
        results.append(res)
    return results


# ----------------------------------------------------------- sharded backend

def _stream_spec(mesh, shape: tuple):
    """Resolve the edge-stream PartitionSpec through the dist.sharding
    rule table (the partitioner never names mesh axes directly)."""
    from ..dist.sharding import PARTITIONER_RULES, resolve_spec
    return resolve_spec(shape, ("stream",), PARTITIONER_RULES,
                        dict(mesh.shape))


@lru_cache(maxsize=32)
def _make_sharded_fn(mesh, e_per: int, num_vertices: int,
                     cfg: CLUGPConfig, game_mode: str, id_cap: int,
                     m_cap: int, nnz_cap: int):
    """Build (and cache, keyed by mesh + the frozen cfg + caps) the jitted
    shard_map pipeline: one stream slice per device along the ``stream``
    axis, each running the SAME stage body as the jit strategy — only the
    ctx differs."""
    from ..dist._compat import shard_map

    n = mesh.shape["stream"]
    spec = _stream_spec(mesh, (n * e_per,))

    def node_fn(src_b, dst_b, mask_b):
        # padded lanes become self-loops: the clustering scan freezes on
        # them and the transform scan skips them via the mask
        s = jnp.where(mask_b, src_b, 0).astype(jnp.int32)
        d = jnp.where(mask_b, dst_b, 0).astype(jnp.int32)
        e_real = mask_b.sum().astype(jnp.float32)
        # V_max from the slice's REAL edge count — each node derives its
        # own cap from its sub-stream, exactly like the np combine (a
        # global-|E| cap grows node-local clusters 4× too fat at n=4 and
        # costs ~40% RF)
        vmax = (jnp.maximum(2.0, e_real / cfg.k) if cfg.vmax is None
                else jnp.float32(cfg.vmax))
        ctx = StageCtx(num_vertices=num_vertices, vmax=vmax, mask=mask_b,
                       axis="stream",
                       # per-slice balance cap (§III-C)
                       lmax=cfg.tau * e_real / cfg.k,
                       game_mode=game_mode, id_cap=id_cap, m_cap=m_cap,
                       nnz_cap=nnz_cap)
        out = run_clugp_body(s, d, ctx, cfg, JAX_STAGES)
        return (out.assign, out.cluster.m[None], out.rounds[None],
                out.cluster.next_id[None],
                out.overflow.astype(jnp.int32)[None])

    # check_vma=False: the game's while_loop has no replication rule on
    # the container's jax (0.4.x shard_map check_rep)
    mapped = shard_map(node_fn, mesh=mesh, in_specs=(spec, spec, spec),
                       out_specs=(spec, spec, spec, spec, spec),
                       check_vma=False)
    return jax.jit(mapped)


def _run_sharded(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                 cfg: CLUGPConfig, nodes: int, mesh) -> CLUGPResult:
    E = src.shape[0]
    if mesh is None:
        if jax.device_count() < nodes:
            raise RuntimeError(
                f"sharded backend needs {nodes} devices but only "
                f"{jax.device_count()} are visible; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={nodes} before "
                f"the first jax import (launch.partition does this for "
                f"--backend sharded)")
        mesh = jax.make_mesh((nodes,), ("stream",))
    n = int(mesh.shape["stream"])
    e_per = -(-E // n)
    e_pad = e_per * n
    src_p = np.zeros(e_pad, dtype=np.int32)
    dst_p = np.zeros(e_pad, dtype=np.int32)
    mask = np.zeros(e_pad, dtype=bool)
    src_p[:E], dst_p[:E], mask[:E] = src, dst, True
    caps = _init_caps(num_vertices, e_per)
    while True:
        run = _make_sharded_fn(
            mesh, e_per, num_vertices, cfg,
            resolve_game_mode(cfg.kernel, caps.m_cap),
            caps.id_cap, caps.m_cap, caps.nnz_cap)
        with mesh:
            assign_p, m_locals, rounds_arr, next_ids, overflows = run(
                jnp.asarray(src_p), jnp.asarray(dst_p), jnp.asarray(mask))
        caps, ok = _grow_caps(
            caps, next_id=int(np.asarray(next_ids).max()),
            m=int(np.asarray(m_locals).max()),
            overflow=int(np.asarray(overflows).max()) > 0,
            num_vertices=num_vertices, e_per=e_per)
        if ok:
            break
    assign = np.asarray(assign_p)[:E]
    m_locals = np.asarray(m_locals)
    rounds = int(np.asarray(rounds_arr).max())
    res = CLUGPResult(assign, None, None, None, rounds)
    res.stats = metrics.summarize(src, dst, assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = int(m_locals.sum())
    res.stats["game_rounds"] = rounds
    res.stats["backend"] = "sharded"
    res.stats["nodes"] = n
    res.stats["per_node"] = [
        {"node": i, "clusters": int(c)} for i, c in enumerate(m_locals)]
    return res
