"""Deterministic, seek-addressable synthetic data pipeline.

batch(step) is a pure function of (seed, step, host) — a restarted host
replays its shard exactly (the FT contract), and no host ever needs
another host's stream.  Tokens follow a Zipf distribution so the loss
curve is non-trivial; a markov-ish structure makes it learnable.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Returns {'tokens','labels'}: host-local slice of the global batch."""
    local = cfg.global_batch // cfg.n_hosts
    rng = _rng_for(cfg, step)
    # zipf body + learnable bigram: tok[t+1] ≡ (a·tok[t] + b) mod V with
    # noise — a model that learns the map beats the unigram entropy.
    base = rng.zipf(1.5, size=(local, cfg.seq_len)).astype(np.int64)
    toks = base % cfg.vocab
    a, b = 31, 17
    follow = (a * toks[:, :-1] + b) % cfg.vocab
    mask = rng.random((local, cfg.seq_len - 1)) < 0.7
    toks[:, 1:] = np.where(mask, follow, toks[:, 1:])
    labels = np.concatenate(
        [toks[:, 1:], np.full((local, 1), -1, np.int64)], axis=1)
    return {"tokens": toks.astype(np.int32),
            "labels": labels.astype(np.int32)}


def graph_edge_shards(src: np.ndarray, dst: np.ndarray, n_hosts: int):
    """Contiguous edge-stream shards per host (the CLUGP distributed mode's
    reader) — seek-addressable by (host, offset)."""
    E = src.shape[0]
    bounds = np.linspace(0, E, n_hosts + 1).astype(np.int64)
    return [(src[bounds[i]:bounds[i + 1]], dst[bounds[i]:bounds[i + 1]])
            for i in range(n_hosts)]
