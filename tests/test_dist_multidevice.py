"""Multi-device (8 virtual CPU devices) integration tests, run in
subprocesses: shard_map graph engine (single and fused multi-program),
SP decode, pipeline parallelism, compressed psum, sharded train step."""
import pytest

pytestmark = pytest.mark.multidevice


def test_shard_map_pagerank_matches_reference(multidevice):
    multidevice("""
    import numpy as np
    from repro.core import web_graph, partition, CLUGPConfig
    from repro.graph import (build_layout, shard_map_pagerank,
                             reference_pagerank)
    from repro.launch.mesh import make_graph_mesh

    g = web_graph(scale=10, edge_factor=6, seed=3)
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(8))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, 8)
    mesh = make_graph_mesh(8)
    pr = shard_map_pagerank(lay, mesh, iters=30)
    ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
    err = np.abs(pr - ref).max()
    assert err < 1e-6, err
    print('pagerank ok', err)
    """)


def test_shard_map_pagerank_halo_matches_dense(multidevice):
    """The mirror-routed halo backend matches the dense all_gather backend
    and the oracle on 8 real devices, and actually lowers to all-to-all
    (no all-gather) in the compiled step."""
    multidevice("""
    import numpy as np
    from repro.core import web_graph, partition, CLUGPConfig
    from repro.graph import (build_layout, shard_map_pagerank,
                             pagerank_step_for_dryrun, reference_pagerank)
    from repro.launch.mesh import make_graph_mesh

    g = web_graph(scale=10, edge_factor=6, seed=3)
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(8))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, 8)
    mesh = make_graph_mesh(8)
    ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
    pr_d = shard_map_pagerank(lay, mesh, iters=30, exchange='dense')
    pr_h = shard_map_pagerank(lay, mesh, iters=30, exchange='halo')
    assert np.abs(pr_d - ref).max() < 1e-6
    assert np.abs(pr_h - ref).max() < 1e-6

    jitted, args = pagerank_step_for_dryrun(lay, mesh, exchange='halo')
    hlo = jitted.lower(*args).compile().as_text()
    lhs = [l.split(' = ')[0] for l in hlo.splitlines() if ' = ' in l]
    assert any('all-to-all' in h for h in lhs), 'halo must use all_to_all'
    assert not any('all-gather' in h for h in lhs), 'halo must not gather'
    print('halo shard_map ok')
    """)


def test_shard_map_cc_and_quantized_match_reference(multidevice):
    """shard_map_cc ≡ simulate_cc ≡ reference_cc on every backend, and the
    quantized pagerank driver matches its stacked simulation bit-for-bit
    (same program spec, same exchange math) and the oracle within the
    error-feedback tolerance; its compiled step ships int8 lanes."""
    multidevice("""
    import numpy as np
    from repro.core import web_graph, partition, CLUGPConfig
    from repro.graph import (build_layout, shard_map_cc, shard_map_pagerank,
                             simulate_cc, simulate_pagerank,
                             pagerank_step_for_dryrun, reference_cc,
                             reference_pagerank)
    from repro.launch.mesh import make_graph_mesh

    g = web_graph(scale=10, edge_factor=6, seed=3)
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(8))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, 8)
    mesh = make_graph_mesh(8)

    ref_cc = reference_cc(g.src, g.dst, g.num_vertices)
    for exchange in ('dense', 'halo', 'quantized'):
        cc_sm = shard_map_cc(lay, mesh, iters=30, exchange=exchange)
        cc_sim = simulate_cc(lay, iters=30, exchange=exchange)
        np.testing.assert_array_equal(cc_sm, cc_sim, err_msg=exchange)
        np.testing.assert_array_equal(cc_sm, ref_cc, err_msg=exchange)

    ref_pr = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
    pr_sm = shard_map_pagerank(lay, mesh, iters=30, exchange='quantized')
    pr_sim = simulate_pagerank(lay, iters=30, exchange='quantized')
    np.testing.assert_array_equal(pr_sm, pr_sim)
    assert np.abs(pr_sm - ref_pr).max() < 1e-5

    jitted, args = pagerank_step_for_dryrun(lay, mesh, exchange='quantized')
    hlo = jitted.lower(*args).compile().as_text()
    coll = [line for line in hlo.splitlines()
            if line.strip().lstrip('%').startswith(
                ('all-to-all', 'all-gather'))]
    assert any('s8[' in line for line in coll), 'int8 lanes must ship'
    assert not any(line.strip().lstrip('%').startswith('all-gather')
                   for line in coll), 'quantized must not all-gather'
    print('cc + quantized shard_map ok')
    """)


def test_shard_map_ragged_ring_matches_and_ships_fewer_bytes(multidevice):
    """The ragged ppermute ring on 8 real devices: pagerank matches the
    oracle on both ragged wires, exact int payloads (CC) ride the ring
    bit-for-bit with the stacked simulation, the compiled step lowers to
    collective-permutes ONLY (no all-to-all, no all-gather — the whole
    point of the per-distance lanes), and the byte models the dry-run
    gate validates against HLO order ragged < halo and ragged_quantized
    < quantized on this skewed-RF layout."""
    multidevice("""
    import numpy as np
    from repro.core import web_graph, partition, CLUGPConfig
    from repro.graph import (build_layout, shard_map_cc, shard_map_pagerank,
                             simulate_cc, simulate_pagerank,
                             pagerank_step_for_dryrun, reference_cc,
                             reference_pagerank)
    from repro.launch.mesh import make_graph_mesh

    g = web_graph(scale=10, edge_factor=6, seed=3)
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(8))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, 8)
    mesh = make_graph_mesh(8)

    ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
    pr = shard_map_pagerank(lay, mesh, iters=30, exchange='ragged')
    assert np.abs(pr - ref).max() < 1e-6
    # top-delta sparsification lags the padded EF wire (only ~25% of
    # each hop's lanes ship per iteration), so the 30-iter tolerance is
    # the fused-quantized one, not the dense one
    pr_q = shard_map_pagerank(lay, mesh, iters=30,
                              exchange='ragged_quantized')
    assert np.abs(pr_q - ref).max() < 5e-4

    ref_cc = reference_cc(g.src, g.dst, g.num_vertices)
    for exchange in ('ragged', 'ragged_quantized'):
        cc = shard_map_cc(lay, mesh, iters=30, exchange=exchange)
        np.testing.assert_array_equal(
            cc, simulate_cc(lay, iters=30, exchange=exchange),
            err_msg=exchange)
        np.testing.assert_array_equal(cc, ref_cc, err_msg=exchange)

    jitted, args = pagerank_step_for_dryrun(lay, mesh, exchange='ragged')
    hlo = jitted.lower(*args).compile().as_text()
    lhs = [l.split(' = ')[0] for l in hlo.splitlines() if ' = ' in l]
    assert any('collective-permute' in h for h in lhs), \\
        'ragged must ppermute'
    assert not any('all-to-all' in h for h in lhs)
    assert not any('all-gather' in h for h in lhs)

    assert lay.comm_bytes('ragged') < lay.comm_bytes('halo')
    assert lay.comm_bytes('ragged_quantized', lossy=True) < \\
        lay.comm_bytes('quantized', lossy=True)
    print('ragged shard_map ok')
    """)


def test_shard_map_fused_many_matches_simulation(multidevice):
    """shard_map_gas_many ≡ simulate_gas_many on 8 real devices for a
    fused f32 bundle (within float reduction-order noise: the global-aux
    psum on the mesh associates differently than the stacked vmap+sum),
    the fused quantized step lowers to one all-to-all pair per phase
    (not one per program), and iters=0 returns init values unchanged."""
    multidevice("""
    import numpy as np
    from repro.core import web_graph, partition, CLUGPConfig
    from repro.graph import (build_layout, gas_step_for_dryrun, get_program,
                             reference_centrality, reference_pagerank,
                             reference_ppr, shard_map_gas_many,
                             simulate_gas_many)
    from repro.launch.mesh import make_graph_mesh

    g = web_graph(scale=10, edge_factor=6, seed=3)
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(8))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, 8)
    mesh = make_graph_mesh(8)
    names = ('pagerank', 'ppr', 'centrality')
    progs = [get_program(p, g.num_vertices) for p in names]
    refs = {
        'pagerank': reference_pagerank(g.src, g.dst, g.num_vertices, 30),
        'ppr': reference_ppr(g.src, g.dst, g.num_vertices, iters=30),
        'centrality': reference_centrality(g.src, g.dst, g.num_vertices,
                                           iters=30),
    }
    for exchange in ('dense', 'halo', 'quantized'):
        sim = simulate_gas_many(progs, lay, iters=30, exchange=exchange)
        sm = shard_map_gas_many(progs, lay, mesh, iters=30,
                                exchange=exchange)
        # the EF quantizer amplifies reduction-order noise (a 1-ulp aux
        # difference can flip an int4 code), so sim↔shard_map is only as
        # tight as the wire itself under 'quantized'
        tol = 5e-4 if exchange == 'quantized' else 1e-5
        for name, a, b in zip(names, sim, sm):
            assert np.abs(a - b).max() < tol, (exchange, name)
            assert np.abs(a - refs[name]).max() < tol, (exchange, name)
            assert np.abs(b - refs[name]).max() < tol, (exchange, name)

    # one collective per phase for the whole bundle: the fused quantized
    # step ships exactly 2 all-to-alls per phase (packed int4 codes +
    # fp16 scales) x 2 phases (reduce + broadcast) = 4 all-to-all ops
    # total, regardless of bundle width, and never all-gathers
    jitted, args = gas_step_for_dryrun(progs, lay, mesh,
                                       exchange='quantized')
    hlo = jitted.lower(*args).compile().as_text()
    lhs = [line.split(' = ')[0] for line in hlo.splitlines()
           if ' = ' in line]
    n_a2a = sum('all-to-all' in h for h in lhs)
    assert n_a2a == 4, n_a2a
    assert not any('all-gather' in h for h in lhs)

    z = shard_map_gas_many(progs, lay, mesh, iters=0, exchange='halo')
    V = g.num_vertices
    np.testing.assert_array_equal(
        z[0], np.full(V, np.float32(1.0 / V), np.float32))
    print('fused shard_map ok')
    """)


def test_sp_decode_matches_full_attention(multidevice):
    multidevice("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.dist.decode import sp_decode_attention, sp_cache_update
    from repro.dist.sharding import use_rules, SINGLE_POD_RULES
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(2, 4)     # model axis = 4 shards the KV sequence
    B, S, Hq, Hkv, D = 4, 64, 8, 2, 32
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (B, 1, Hq, D), jnp.float32)
    kc = jax.random.normal(k2, (B, S, Hkv, D), jnp.float32)
    vc = jax.random.normal(k3, (B, S, Hkv, D), jnp.float32)
    idx = jnp.int32(37)

    # single-shard reference (no mesh)
    ref = sp_decode_attention(q, kc, vc, idx)
    with use_rules(SINGLE_POD_RULES, mesh):
        got = sp_decode_attention(q, kc, vc, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    # cache update writes only on the owning shard
    new = jax.random.normal(k1, (B, 1, Hkv, D), jnp.float32)
    ref_c = sp_cache_update(kc, new, idx)
    with use_rules(SINGLE_POD_RULES, mesh):
        got_c = sp_cache_update(kc, new, idx)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(ref_c),
                               rtol=1e-6)
    print('sp decode ok')
    """)


def test_pipeline_parallel_matches_reference(multidevice):
    multidevice("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.dist.pipeline_parallel import pipeline_apply, reference_apply

    mesh = jax.make_mesh((8,), ('stage',))
    S, M, mb, d = 8, 6, 4, 16
    key = jax.random.key(0)
    w = jax.random.normal(key, (S, d, d), jnp.float32) / np.sqrt(d)
    xs = jax.random.normal(jax.random.key(1), (M, mb, d), jnp.float32)

    def block(x, wi):
        return jnp.tanh(x @ wi)

    got = pipeline_apply(mesh, 'stage', {'w': w}, xs,
                         lambda x, p: block(x, p['w']))
    ref = reference_apply(w, xs, block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print('pipeline ok')
    """)


def test_compressed_psum_close_to_exact(multidevice):
    multidevice("""
    import numpy as np, jax, jax.numpy as jnp
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.dist.compress import compressed_psum

    mesh = jax.make_mesh((8,), ('d',))
    x = jax.random.normal(jax.random.key(0), (8, 256), jnp.float32)

    @partial(jax.shard_map, mesh=mesh, in_specs=P('d'), out_specs=P('d'),
             check_vma=False)
    def f(xl):
        return compressed_psum(xl[0], 'd')[None]

    got = np.asarray(f(x))[0]
    exact = np.asarray(x).sum(0)
    rel = np.abs(got - exact).max() / (np.abs(exact).max() + 1e-9)
    assert rel < 0.05, rel     # int8 quantization error bound
    print('compressed psum ok', rel)
    """)


def test_sharded_train_step_runs_and_improves(multidevice):
    multidevice("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.configs import get_config
    from repro.models import init_params
    from repro.train import (get_optimizer, make_train_step, param_specs,
                             batch_specs)
    from repro.dist.sharding import use_rules, SINGLE_POD_RULES
    from repro.launch.mesh import make_test_mesh
    from repro.data.pipeline import DataConfig, batch_at

    mesh = make_test_mesh(2, 4)
    cfg = get_config('qwen2_7b').reduced()
    with use_rules(SINGLE_POD_RULES, mesh):
        params = init_params(cfg, jax.random.key(0), mp=4)
        ps = param_specs(params, zero=True, multi_pod=False)
        psh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), ps)
        params = jax.tree_util.tree_map(jax.device_put, params, psh)
        opt = get_optimizer('adamw', lr=1e-2)
        opt_state = opt.init(params)
        step = jax.jit(make_train_step(cfg, opt, mp=4, dtype=jnp.float32,
                                       block_kv=32, loss_chunk=32))
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
        losses = []
        for i in range(8):
            b = {k: jnp.asarray(v) for k, v in batch_at(dcfg, i).items()}
            params, opt_state, loss = step(params, opt_state, b,
                                           jnp.int32(i))
            losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses
    print('sharded train ok', losses[0], '->', losses[-1])
    """, n_devices=8, timeout=900)
