"""Serving launcher: batched prefill + decode loop at smoke scale.

``python -m repro.launch.serve --arch qwen2-7b --reduced --tokens 32``
loads a reduced model, prefills a batch of prompts and decodes N tokens,
reporting per-token latency. The production path is the same decode_step
the dry-run lowers at (16,16)/(2,16,16).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_cache, init_params
from repro.train import make_decode_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.key(args.seed))
    B = args.batch
    max_len = args.prompt_len + args.tokens
    cache = init_cache(cfg, B, max_len, dtype=jnp.float32)
    memory = (jnp.zeros((B, 8, cfg.d_model), jnp.float32)
              if cfg.family == "encdec" else None)

    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt_len)),
                         jnp.int32)
    fn = jax.jit(make_decode_fn(cfg, dtype=jnp.float32),
                 static_argnames=())

    # prefill via repeated decode (exact; batched-prefill path is the
    # dry-run's prefill cell)
    tok = prompt[:, :1]
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, cache = fn(params, cache, prompt[:, t:t + 1],
                           jnp.int32(t), memory)
    out = []
    for t in range(args.tokens):
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab], -1)[:, None] \
            .astype(jnp.int32)
        out.append(np.asarray(nxt))
        logits, cache = fn(params, cache, nxt,
                           jnp.int32(args.prompt_len + t), memory)
    dt = time.time() - t0
    total = args.prompt_len + args.tokens
    print(f"arch={cfg.name} batch={B} {total} steps in {dt:.2f}s "
          f"({1000*dt/total:.1f} ms/token-step)")
    gen = np.concatenate(out, axis=1)
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
