"""Compare all partitioners across k — a minified Fig. 3/7, plus the
per-iteration GAS wire cost each partition would pay on the engine's
exchange backends, via the session façade: CLUGP algos run
``GraphSession.partition``, baselines adopt their assignment with
``with_partition``, and the comm table is ``session.comm_bytes()`` either
way.

    PYTHONPATH=src:. python examples/partition_compare.py
"""
from benchmarks.common import quality_row, run_partitioner, stream_for
from repro.core import CLUGPConfig, web_graph
from repro.session import GraphSession, SessionConfig

g = web_graph(scale=12, edge_factor=8, seed=0)
print(f"web graph: |V|={g.num_vertices} |E|={g.num_edges}")
print(f"{'algo':12s} {'k':>4s} {'RF':>8s} {'balance':>8s} {'µs/edge':>9s} "
      f"{'dense kB/it':>12s} {'halo kB/it':>11s} {'ideal kB/it':>12s}")
for k in (4, 16, 64):
    for algo in ("clugp", "clugp-opt", "hashing", "dbh", "greedy", "hdrf",
                 "mint"):
        out = run_partitioner(algo, g, k, 0)
        r = quality_row(algo, g, k, out=out)
        src, dst = stream_for(algo, g, out)
        sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=k)))
        sess.with_partition(src, dst, g.num_vertices, out[0])
        cb = sess.comm_bytes()
        print(f"{r['algo']:12s} {r['k']:>4d} {r['rf']:>8.3f} "
              f"{r['balance']:>8.3f} {r['us_per_edge']:>9.2f} "
              f"{cb['dense_gather']/1e3:>12.1f} "
              f"{cb['halo']/1e3:>11.1f} "
              f"{cb['ideal']/1e3:>12.1f}")
