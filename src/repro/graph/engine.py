"""Distributed vertex-cut GAS engine (PowerGraph semantics) on shard_map.

Per iteration (paper §II-B): local scatter/gather over the partition's edges
(segment_sum — the ``csr_spmv`` Pallas kernel's op), mirror partials reduced
to masters, masters apply, new values broadcast back to mirrors.  The two
mirror-sync phases go through the pluggable exchange layer
(``repro.dist.halo``):

- ``exchange="dense"``: two all_gathers of (k, L_max) values — simple, but
  bytes scale with k²·L_max regardless of partition quality (the seed wire
  format).
- ``exchange="halo"``: two all_to_alls over the layout's static mirror
  routing tables — bytes scale with the mirror count (RF−1)·|V|, the
  quantity the partitioner optimizes, so Fig. 8's mechanism shows up on
  the wire.

Two drivers around the same per-device halves:

- ``simulate_*``   : stacked (k, …) arrays on one device — used by tests
                     and host-side benchmarks (bit-identical math).
- ``shard_map_*``  : one partition per mesh device over axis ``parts`` —
                     the production path (multi-pod dry-run lowers this).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .partition import PartitionLayout
from ..dist._compat import shard_map
from ..dist.halo import get_exchange

DAMPING = 0.85


# ----------------------------------------------------------- per-device math

def _local_rank_partial(rank, dev):
    """Scatter phase: Σ_{(u,w)∈E_p, w=v} rank[u]/outdeg[u] per local slot."""
    l_max = dev["vert_gid"].shape[0]
    safe_deg = jnp.maximum(dev["out_deg"], 1)
    contrib = jnp.where(dev["vert_mask"] & (dev["out_deg"] > 0),
                        rank / safe_deg, 0.0)
    contrib = jnp.concatenate([contrib, jnp.zeros((1,), contrib.dtype)])
    per_edge = jnp.where(dev["edge_mask"], contrib[dev["edge_src"]], 0.0)
    return jax.ops.segment_sum(per_edge, dev["edge_dst"],
                               num_segments=l_max + 1)[:l_max]


def _local_dangle(rank, dev):
    """Rank mass sitting on dangling masters (out_deg == 0)."""
    m = dev["vert_mask"] & dev["is_master"] & (dev["out_deg"] == 0)
    return jnp.sum(jnp.where(m, rank, 0.0))


def _pagerank_apply(total_in, dangle, dev, num_vertices):
    base = (1.0 - DAMPING) / num_vertices
    new = base + DAMPING * (total_in + dangle / num_vertices)
    return jnp.where(dev["vert_mask"] & dev["is_master"], new, 0.0)


def _cc_local_min(label, dev):
    """Edge-wise min exchange in both directions (undirected semantics)."""
    l_max = dev["vert_gid"].shape[0]
    big = jnp.asarray(np.float32(np.inf))
    lab = jnp.concatenate([jnp.where(dev["vert_mask"], label, big),
                           jnp.full((1,), big, label.dtype)])
    s, d, m = dev["edge_src"], dev["edge_dst"], dev["edge_mask"]
    vs = jnp.where(m, lab[s], big)
    vd = jnp.where(m, lab[d], big)
    out = jax.ops.segment_min(vs, d, num_segments=l_max + 1)[:l_max]
    out2 = jax.ops.segment_min(vd, s, num_segments=l_max + 1)[:l_max]
    cur = jnp.where(dev["vert_mask"], label, big)
    return jnp.minimum(cur, jnp.minimum(out, out2))


# ----------------------------------------------------------- simulated driver

def _stack_dev(layout: PartitionLayout, exchange: str | None = None):
    return jax.tree_util.tree_map(jnp.asarray,
                                  layout.device_arrays(exchange))


@partial(jax.jit, static_argnames=("iters", "num_vertices", "exchange"))
def _sim_pagerank(dev, iters: int, num_vertices: int, exchange: str):
    ex = get_exchange(exchange)
    rank = jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)

    def body(_, rank):
        partial_ = jax.vmap(_local_rank_partial)(rank, dev)
        total = ex.reduce_stacked(partial_, dev)
        dangle = jnp.sum(jax.vmap(_local_dangle)(rank, dev))
        new_master = jax.vmap(
            lambda t, d: _pagerank_apply(t, dangle, d, num_vertices)
        )(total, dev)
        return ex.broadcast_stacked(new_master, dev)

    return jax.lax.fori_loop(0, iters, body, rank)


@partial(jax.jit, static_argnames=("iters", "exchange"))
def _sim_cc(dev, iters: int, exchange: str):
    ex = get_exchange(exchange)
    label = jnp.where(dev["vert_mask"], dev["vert_gid"].astype(jnp.float32),
                      jnp.float32(np.inf))

    def body(_, label):
        part = jax.vmap(_cc_local_min)(label, dev)
        part = jnp.where(jnp.isfinite(part), part, jnp.float32(3e38))
        total = ex.reduce_stacked(part, dev, "min")
        new_master = jnp.where(dev["vert_mask"] & dev["is_master"], total,
                               jnp.float32(3e38))
        return ex.broadcast_stacked(new_master, dev)

    return jax.lax.fori_loop(0, iters, body, label)


def _collect_master_values(layout: PartitionLayout, stacked) -> np.ndarray:
    """(k, L_max) per-device values → dense (V,) using master slots."""
    vals = np.asarray(stacked)
    out = np.zeros(layout.num_vertices, dtype=vals.dtype)
    gid = layout.vert_gid
    sel = layout.is_master & layout.vert_mask
    out[gid[sel]] = vals[sel]
    return out


def simulate_pagerank(layout: PartitionLayout, iters: int = 30,
                      exchange: str = "dense") -> np.ndarray:
    dev = _stack_dev(layout, exchange)
    ranks = _sim_pagerank(dev, iters, layout.num_vertices, exchange)
    return _collect_master_values(layout, ranks)


def simulate_cc(layout: PartitionLayout, iters: int = 30,
                exchange: str = "dense") -> np.ndarray:
    dev = _stack_dev(layout, exchange)
    labels = _sim_cc(dev, iters, exchange)
    return _collect_master_values(layout, labels).astype(np.int64)


# ----------------------------------------------------------- shard_map driver

def _pagerank_body(ex, dev, num_vertices, axis):
    """One GAS iteration as run on each device (inside shard_map)."""
    def body(_, rank):
        partial_ = _local_rank_partial(rank, dev)
        total = ex.reduce_to_masters(partial_, dev)
        dangle = jax.lax.psum(_local_dangle(rank, dev), axis)
        new_master = _pagerank_apply(total, dangle, dev, num_vertices)
        return ex.broadcast_from_masters(new_master, dev)
    return body


def shard_map_pagerank(layout: PartitionLayout, mesh: Mesh,
                       iters: int = 30, axis: str = "parts",
                       exchange: str = "dense"):
    """Production path: one partition per device along ``axis``.
    Requires mesh axis size == layout.k.  ``exchange`` picks the mirror
    wire format (see module docstring).  Returns (V,) master ranks."""
    dev = _stack_dev(layout, exchange)
    num_vertices = layout.num_vertices
    ex = get_exchange(exchange, axis)
    spec = P(axis)

    @partial(shard_map, mesh=mesh,
             in_specs=(spec, jax.tree_util.tree_map(lambda _: spec, dev)),
             out_specs=spec)
    def run(rank, dev):
        rank = rank[0]
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        body = _pagerank_body(ex, dev, num_vertices, axis)
        out = jax.lax.fori_loop(0, iters, body, rank)
        return out[None]

    rank0 = jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)
    with mesh:
        ranks = run(rank0, dev)
    return _collect_master_values(layout, ranks)


def pagerank_step_for_dryrun(layout: PartitionLayout, mesh: Mesh,
                             axis: str = "parts", iters: int = 1,
                             exchange: str = "dense"):
    """Returns (jitted_fn, example_args) whose .lower() the dry-run compiles
    — the graph dry-run parses each backend's collective bytes out of the
    post-SPMD HLO (``launch/dryrun.py --graph``)."""
    dev = _stack_dev(layout, exchange)
    num_vertices = layout.num_vertices
    ex = get_exchange(exchange, axis)
    spec = P(axis)

    @partial(shard_map, mesh=mesh,
             in_specs=(spec, jax.tree_util.tree_map(lambda _: spec, dev)),
             out_specs=spec)
    def step(rank, dev):
        rank = rank[0]
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        body = _pagerank_body(ex, dev, num_vertices, axis)
        return jax.lax.fori_loop(0, iters, body, rank)[None]

    rank0 = jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)
    return jax.jit(step), (rank0, dev)


# ----------------------------------------------------------- oracles

def reference_pagerank(src, dst, num_vertices, iters: int = 30) -> np.ndarray:
    """Dense single-machine oracle with identical dangling handling."""
    outdeg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(outdeg, src, 1)
    rank = np.full(num_vertices, 1.0 / num_vertices)
    base = (1.0 - DAMPING) / num_vertices
    for _ in range(iters):
        contrib = np.where(outdeg > 0, rank / np.maximum(outdeg, 1), 0.0)
        s = np.zeros(num_vertices)
        np.add.at(s, dst, contrib[src])
        dangle = rank[outdeg == 0].sum()
        rank = base + DAMPING * (s + dangle / num_vertices)
    return rank


def reference_cc(src, dst, num_vertices) -> np.ndarray:
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components
    A = sp.coo_matrix((np.ones(len(src)), (src, dst)),
                      shape=(num_vertices, num_vertices))
    _, comp = connected_components(A, directed=False)
    # canonical label: min vertex id of the component (what min-label finds)
    mins = np.full(comp.max() + 1, num_vertices, dtype=np.int64)
    np.minimum.at(mins, comp, np.arange(num_vertices))
    return mins[comp]
