"""The paper's end-to-end scenario: CLUGP-partition a web graph, deploy it
on the k-device GAS engine, run PageRank + connected components, and show
the comm-volume dependence on partition quality (Fig. 8's mechanism).

    PYTHONPATH=src python examples/distributed_pagerank.py
"""
import numpy as np

from repro.core import CLUGPConfig, baselines, random_stream, web_graph
from repro.graph import reference_cc, reference_pagerank
from repro.session import GraphSession

K = 8
g = web_graph(scale=11, edge_factor=8, seed=2)
print(f"web graph: |V|={g.num_vertices} |E|={g.num_edges}, k={K}")

sess = GraphSession(CLUGPConfig.optimized(K))
sess.partition(g.src, g.dst, g.num_vertices)
lay_clugp = sess.partition_layout

gr = random_stream(g, seed=1)
h = baselines.hashing(gr.src, gr.dst, g.num_vertices, K)
lay_hash = GraphSession(CLUGPConfig(k=K)).with_partition(
    gr.src, gr.dst, g.num_vertices, h).partition_layout

print(f"{'partitioner':10s} {'mirrors':>9s} {'ideal MB/it':>12s} "
      f"{'quant MB/it':>12s} {'halo MB/it':>11s} {'dense MB/it':>12s}")
for name, lay in (("clugp", lay_clugp), ("hashing", lay_hash)):
    print(f"{name:10s} {lay.mirrors_total:>9d} "
          f"{lay.comm_bytes('ideal')/1e6:>12.3f} "
          f"{lay.comm_bytes('quantized')/1e6:>12.3f} "
          f"{lay.comm_bytes('halo')/1e6:>11.3f} "
          f"{lay.comm_bytes('dense')/1e6:>12.3f}")

ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
for exchange in ("halo", "quantized"):
    pr = sess.run("pagerank", iters=30, exchange=exchange)
    print(f"pagerank[{exchange}]: max|err|={np.abs(pr-ref).max():.2e} "
          f"(30 iters)")

# pagerank to convergence rather than a fixed sweep count: tol makes 60
# a cap and the early-exit loop reports the executed count
pr, it = sess.run("pagerank", iters=60, exchange="ragged",
                  tol=1e-6, return_iters=True)
print(f"pagerank[ragged, tol=1e-6]: max|err|={np.abs(pr-ref).max():.2e} "
      f"({it} of 60 capped iters)")

cc, it = sess.run("cc", iters=30, tol=0, return_iters=True)
rcc = reference_cc(g.src, g.dst, g.num_vertices)
print(f"connected components: label match={np.mean(cc == rcc)*100:.1f}% "
      f"({len(np.unique(rcc))} components, {it} sweeps to fixed point)")
