"""Kernel micro-benchmarks (interpret-mode correctness + host-side μs;
TPU wall-time comes from the roofline terms, not this container)."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)                          # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def kernels_microbench():
    rows = []
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(k2, (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(k3, (1, 2, 256, 64), jnp.float32)
    t_kern = _time(lambda a, b, c: ops.flash_attention(a, b, c,
                                                       interpret=True),
                   q, k, v)
    t_ref = _time(lambda a, b, c: ref.flash_attention_ref(a, b, c), q, k, v)
    rows.append({"bench": "kernel_flash_attn", "us_kernel_interp":
                 round(1e6 * t_kern, 1), "us_ref": round(1e6 * t_ref, 1)})

    rng = np.random.default_rng(0)
    aff = jnp.asarray(rng.random((1024, 256)), jnp.float32)
    sizes = jnp.asarray(rng.integers(1, 50, 1024), jnp.float32)
    rt = jnp.asarray(np.asarray(aff).sum(1), jnp.float32)
    cur = jnp.asarray(rng.integers(0, 256, 1024), jnp.int32)
    loads = jnp.asarray(rng.random(256) * 100, jnp.float32)
    t_kern = _time(lambda *a: ops.game_best_response(*a, lam=2.0,
                                                     interpret=True),
                   aff, sizes, rt, cur, loads)
    t_ref = _time(lambda *a: ref.game_bestresponse_ref(*a, lam=2.0),
                  aff, sizes, rt, cur, loads)
    rows.append({"bench": "kernel_game_br", "us_kernel_interp":
                 round(1e6 * t_kern, 1), "us_ref": round(1e6 * t_ref, 1)})

    vals = jnp.asarray(rng.random((2048, 16)), jnp.float32)
    cols = jnp.asarray(rng.integers(0, 4096, (2048, 16)), jnp.int32)
    x = jnp.asarray(rng.random(4096), jnp.float32)
    t_kern = _time(lambda *a: ops.ell_spmv(*a, interpret=True),
                   vals, cols, x)
    t_ref = _time(lambda *a: ref.ell_spmv_ref(*a), vals, cols, x)
    rows.append({"bench": "kernel_ell_spmv", "us_kernel_interp":
                 round(1e6 * t_kern, 1), "us_ref": round(1e6 * t_ref, 1)})

    # cluster-scatter: the clustering inner loop on the Pallas fused
    # table-update kernel (interpret mode off-TPU) vs the XLA
    # fused-scatter scan — bit-identical outputs by construction (both
    # compose edge_decisions), so the cells differ only in µs/edge.
    # "kernel" is the trend identity field keying the two cells.
    from functools import partial

    from repro.core.clustering import streaming_clustering_jax

    E, V = 4096, 1024
    src = jnp.asarray(rng.integers(0, V, E), jnp.int32)
    dst = jnp.asarray(rng.integers(0, V, E), jnp.int32)
    outs = {}
    for kernel in ("xla", "pallas"):
        fn = jax.jit(partial(streaming_clustering_jax, num_vertices=V,
                             vmax=64.0, id_cap=2 * V, kernel=kernel))
        t = _time(fn, src, dst)
        outs[kernel] = [np.asarray(o) for o in fn(src, dst)]
        rows.append({"bench": "kernel_cluster_scatter", "kernel": kernel,
                     "us_per_edge": round(1e6 * t / E, 3)})
    assert all(np.array_equal(a, b) for a, b in
               zip(outs["xla"], outs["pallas"])), \
        "cluster_scatter kernels diverged"
    return rows
