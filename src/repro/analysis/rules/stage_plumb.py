"""STAGE-PLUMB: strategies compose stages; they may not re-plumb stage
internals.

``core/partitioner.py`` holds the partitioning *strategies* — they must
go through ``run_clugp_body`` / the ``repro.core.stages`` pipeline, not
call the pass-level kernels (clustering, game rounds, transform,
restream majority) directly.  Keeping the strategies kernel-free is what
guarantees every strategy exercises the ONE pipeline body the tests and
benches cover.  This rule replaces the old source-grep in
tests/test_stages.py with an AST check: any identifier reference to a
stage internal (call, attribute or import) is a finding.
"""
from __future__ import annotations

import ast

from ..lint import Rule

# pass-level kernels only the stage layer may touch; prefix-matched so
# e.g. majority_vertex_map_np / _jax are both covered
STAGE_INTERNALS = (
    "streaming_clustering",
    "jax_game_rounds",
    "best_response_rounds",
    "transform_np",
    "transform_jax",
    "majority_vertex_map",
)


def _match(name: str) -> str | None:
    for forb in STAGE_INTERNALS:
        if name == forb or name.startswith(forb + "_"):
            return forb
    return None


class StagePlumb(Rule):
    id = "STAGE-PLUMB"
    description = ("strategies (core/partitioner.py) may not call stage "
                   "internals — compose run_clugp_body / stages instead")
    roots = ("src/repro/core/partitioner.py",)

    def run(self, tree, relpath, text):
        out = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Name):
                forb = _match(node.id)
                if forb:
                    out.append(self.finding(
                        relpath, node, forb,
                        f"strategy references stage internal {node.id!r}"))
            elif isinstance(node, ast.Attribute):
                forb = _match(node.attr)
                if forb:
                    out.append(self.finding(
                        relpath, node, forb,
                        f"strategy references stage internal {node.attr!r}"))
            elif isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    forb = _match(alias.name)
                    if forb:
                        out.append(self.finding(
                            relpath, node, forb,
                            f"strategy imports stage internal "
                            f"{alias.name!r}"))
        return out
