"""ModelConfig: one dataclass covering the 10 assigned architectures.

Every config in repro/configs instantiates this with the exact published
numbers; ``reduced()`` derives the CPU smoke-test variant.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

from .layers import round_up


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    softmax_after_topk: bool = False   # deepseek-style
    first_k_dense: int = 0             # leading dense layers
    every: int = 1                     # MoE every Nth layer (jamba: 2)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2                     # d_inner = expand * d_model
    chunk: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                         # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                   # 0 ⇒ d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_period: int = 0                # hybrid: 1 attn layer per period
    attn_index: int = 0                 #   at this index within the period
    n_encoder_layers: int = 0           # encdec only
    prefix_tokens: int = 0              # vlm/audio stub frontend length
    vocab_pad_to: int = 256
    max_seq: int = 8192                 # rope table default
    sub_quadratic: bool = False         # True ⇒ eligible for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab, self.vocab_pad_to)

    def padded_heads(self, mp: int) -> int:
        return round_up(self.n_heads, mp)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/wiring, tiny dims."""
        def shrink(x, lo, hi):
            return max(lo, min(x, hi))
        moe = self.moe
        if moe is not None:
            moe = replace(moe, n_experts=min(moe.n_experts, 8),
                          top_k=min(moe.top_k, 2), d_expert=64,
                          first_k_dense=min(moe.first_k_dense, 1))
        mla = self.mla
        if mla is not None:
            mla = MLAConfig(q_lora=64, kv_lora=32, nope_dim=16, rope_dim=8,
                            v_dim=16)
        ssm = self.ssm
        if ssm is not None:
            ssm = SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16)
        period = self.attn_period
        n_layers = (2 * period if period
                    else shrink(self.n_layers, 2, 2))
        return replace(
            self, n_layers=n_layers, d_model=128,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2) or 2,
            head_dim=32, d_ff=256, vocab=512, vocab_pad_to=64,
            moe=moe, mla=mla, ssm=ssm,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            prefix_tokens=8 if self.prefix_tokens else 0,
            max_seq=256)
