"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


# ------------------------------------------------------------ flash attn

@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (1, 4, 4, 128, 128, 64),
    (2, 4, 2, 128, 256, 64),
    (1, 8, 1, 256, 256, 128),   # MQA
    (2, 6, 2, 128, 128, 32),    # GQA group 3
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, Hq, Hkv, Sq, Skv, D, dtype, causal):
    if causal and Sq != Skv:
        pytest.skip("causal requires square for this sweep")
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (B, Hq, Sq, D), dtype)
    k = jax.random.normal(k2, (B, Hkv, Skv, D), dtype)
    v = jax.random.normal(k3, (B, Hkv, Skv, D), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64,
                              block_kv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_long_context_block_sweep():
    k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(k1, (1, 2, 512, 64), jnp.float32)
    k = jax.random.normal(k2, (1, 2, 512, 64), jnp.float32)
    v = jax.random.normal(k3, (1, 2, 512, 64), jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    for bq, bkv in [(64, 128), (128, 64), (256, 256)]:
        got = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_kv=bkv, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_flash_matches_model_chunked_attention():
    """Kernel ≡ the model's pure-jnp chunked attention (same math)."""
    from repro.models.attention import chunked_attention
    k1, k2, k3 = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(k1, (2, 128, 4, 64), jnp.float32)  # (B,S,H,D)
    k = jax.random.normal(k2, (2, 128, 4, 64), jnp.float32)
    v = jax.random.normal(k3, (2, 128, 4, 64), jnp.float32)
    got_model = chunked_attention(q, k, v, causal=True, block_kv=64)
    got_kernel = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, block_q=64, block_kv=64,
        interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(got_model),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ game BR

@pytest.mark.parametrize("M,kpad,k", [(256, 128, 16), (512, 128, 128),
                                      (256, 256, 200)])
def test_game_bestresponse_matches_ref(M, kpad, k):
    rng = np.random.default_rng(0)
    aff = jnp.asarray(rng.random((M, kpad)) * 10, jnp.float32)
    sizes = jnp.asarray(rng.integers(1, 50, M), jnp.float32)
    row_tot = jnp.asarray(aff.sum(1) + rng.random(M), jnp.float32)
    cur = jnp.asarray(rng.integers(0, k, M), jnp.int32)
    loads = jnp.asarray(rng.random(kpad) * 100, jnp.float32)
    got_b, got_c = ops.game_best_response(aff, sizes, row_tot, cur, loads,
                                          lam=2.5, k=k, block_m=128,
                                          interpret=True)
    want_b, want_c = ref.game_bestresponse_ref(aff, sizes, row_tot, cur,
                                               loads, lam=2.5, k=k)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                               rtol=1e-5)


def test_game_kernel_agrees_with_host_game_step():
    """Kernel best responses == the numpy Gauss–Seidel step's choices under
    a frozen snapshot (Jacobi semantics)."""
    from repro.core import web_graph, streaming_clustering_np, contract, \
        default_vmax, lambda_max
    g = web_graph(scale=9, edge_factor=6, seed=0)
    k = 8
    clus = streaming_clustering_np(g.src, g.dst, g.num_vertices,
                                   default_vmax(g.num_edges, k))
    cg = contract(g.src, g.dst, clus.clu)
    m = cg.m
    mpad = -(-m // 128) * 128
    kpad = 128
    lam = lambda_max(cg, k)
    rng = np.random.default_rng(1)
    assign = rng.integers(0, k, m)
    S = cg.adj.toarray().astype(np.float32)
    onehot = np.eye(k, dtype=np.float32)[assign]
    aff = S @ onehot                                      # (m, k)
    sizes = cg.sizes.astype(np.float32)
    row_tot = S.sum(1)
    loads = np.bincount(assign, weights=sizes, minlength=k)

    aff_p = np.zeros((mpad, kpad), np.float32)
    aff_p[:m, :k] = aff
    sz_p = np.zeros(mpad, np.float32); sz_p[:m] = sizes
    rt_p = np.zeros(mpad, np.float32); rt_p[:m] = row_tot
    cur_p = np.zeros(mpad, np.int32); cur_p[:m] = assign
    ld_p = np.zeros(kpad, np.float32); ld_p[:k] = loads

    got_b, _ = ops.game_best_response(
        jnp.asarray(aff_p), jnp.asarray(sz_p), jnp.asarray(rt_p),
        jnp.asarray(cur_p), jnp.asarray(ld_p), lam=float(lam), k=k,
        block_m=128, interpret=True)
    # oracle: same Jacobi snapshot cost in numpy
    ar = np.arange(k)
    for i in rng.choice(m, size=32, replace=False):
        loads_ex = loads - sizes[i] * (ar == assign[i])
        cost = (lam / k) * sizes[i] * (loads_ex + sizes[i]) \
            + 0.5 * (row_tot[i] - aff[i])
        assert int(got_b[i]) == int(np.argmin(cost))


# ------------------------------------------------------------ ELL SpMV

@pytest.mark.parametrize("R,W,N", [(256, 8, 300), (512, 16, 1000),
                                   (256, 1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ell_spmv_matches_ref(R, W, N, dtype):
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.random((R, W)), dtype)
    cols = jnp.asarray(rng.integers(0, N, (R, W)), jnp.int32)
    x = jnp.asarray(rng.random(N), dtype)
    got = ops.ell_spmv(vals, cols, x, block_m=128, interpret=True)
    want = ref.ell_spmv_ref(vals, cols, x)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol,
                               atol=tol)


# ----------------------------------------------------- cluster scatter

def _cluster_block_inputs(seed, B=128, sdf=0.0):
    """A localized clustering block with realistic slot aliasing: random
    vertex slots in [0, 2B), some dead lanes, a mid-stream table state."""
    rng = np.random.default_rng(seed)
    lu = rng.integers(0, 2 * B, B).astype(np.int32)
    lv = rng.integers(0, 2 * B, B).astype(np.int32)
    live = (rng.random(B) > 0.1).astype(np.int32)
    lv = np.where(live == 1, lv, lu)          # dead lanes alias u == v
    ints = np.stack([lu, lv, live], 1)
    buf = np.full(10 * B, -1, np.int32)
    buf[2 * B:4 * B] = rng.integers(0, 6, 2 * B)
    buf[4 * B:10 * B] = 0
    # pre-cluster a third of the slots into a few existing local clusters
    pre = rng.choice(2 * B, 2 * B // 3, replace=False)
    cl = rng.integers(2 * B, 2 * B + 16, pre.size)
    buf[pre] = cl
    np.add.at(buf, 2 * B + cl, rng.integers(1, 8, pre.size))
    scal = np.array([16, 0, pre.size, int(buf[2*B:4*B].sum())], np.int32)
    return jnp.asarray(ints), jnp.asarray(buf), jnp.asarray(scal)


def _cluster_scan_ref(ints, buf, scal, vmax, allow_split, sdf):
    """Oracle: the XLA inner scan (`.at[].add` fused scatter) over the
    same `edge_decisions` math."""
    from functools import partial
    from repro.core.clustering import _edge_step_local
    B = ints.shape[0]
    step = partial(_edge_step_local, vmax=jnp.float32(vmax),
                   allow_split=allow_split, split_degree_factor=sdf, B=B)
    (buf2, nid, nid0, sv, sd), fires = jax.lax.scan(
        step, (buf, scal[0], scal[1], scal[2], scal[3]), ints)
    return buf2, jnp.stack([nid, nid0, sv, sd]), fires


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("sdf", [0.0, 4.0])
def test_cluster_scatter_matches_xla_scan(seed, sdf):
    ints, buf, scal = _cluster_block_inputs(seed, sdf=sdf)
    vmax = 12.5
    got_buf, got_scal, got_pk = ops.cluster_scatter(
        ints, buf, scal, vmax, allow_split=True, split_degree_factor=sdf,
        interpret=True)
    want_buf, want_scal, want_pk = _cluster_scan_ref(
        ints, buf, scal, vmax, True, sdf)
    np.testing.assert_array_equal(np.asarray(got_buf), np.asarray(want_buf))
    np.testing.assert_array_equal(np.asarray(got_scal), np.asarray(want_scal))
    np.testing.assert_array_equal(np.asarray(got_pk), np.asarray(want_pk))


def test_cluster_scatter_no_split_matches_xla_scan():
    ints, buf, scal = _cluster_block_inputs(7)
    got = ops.cluster_scatter(ints, buf, scal, 9.0, allow_split=False,
                              interpret=True)
    want = _cluster_scan_ref(ints, buf, scal, 9.0, False, 0.0)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_cluster_kernel_full_stream_matches_xla():
    """Whole clustering pass (block localization + carry across blocks)
    is bit-identical between the Pallas strategy and the XLA scan."""
    from repro.core import web_graph
    from repro.core.clustering import streaming_clustering_jax, default_vmax
    g = web_graph(scale=10, edge_factor=5, seed=4)
    vmax = default_vmax(g.num_edges, 8)
    for sdf in (0.0, 4.0):
        outs = {}
        for kern in ("xla", "pallas"):
            outs[kern] = streaming_clustering_jax(
                g.src, g.dst, g.num_vertices, vmax,
                split_degree_factor=sdf, kernel=kern, interpret=True)
        for a, b in zip(outs["xla"], outs["pallas"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resolve_cluster_kernel():
    from repro.core.stages import resolve_cluster_kernel
    assert resolve_cluster_kernel("pallas") == "pallas"
    assert resolve_cluster_kernel("xla") == "xla"
    assert resolve_cluster_kernel("auto") in ("pallas", "xla")
    with pytest.raises(ValueError):
        resolve_cluster_kernel("scan")


def test_partition_cluster_kernel_bit_identical():
    """cluster_kernel='pallas' flows through CLUGPConfig → jit backend and
    lands the exact same assignment as the XLA scatter path."""
    from repro.core.partitioner import partition
    from repro.core.pipeline import CLUGPConfig
    from repro.core import web_graph
    g = web_graph(scale=9, edge_factor=5, seed=2)
    res = {}
    for kern in ("xla", "pallas"):
        r = partition(g.src, g.dst, g.num_vertices,
                      CLUGPConfig(k=4, cluster_kernel=kern), backend="jit")
        res[kern] = r.assign
    np.testing.assert_array_equal(res["xla"], res["pallas"])


def test_ell_spmv_is_pagerank_gather():
    """Kernel reproduces the engine's segment_sum local aggregate."""
    rng = np.random.default_rng(3)
    n, e = 64, 256
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    contrib = rng.random(n).astype(np.float32)
    # ELL by destination rows
    width = int(np.bincount(dst, minlength=n).max())
    vals = np.zeros((n, width), np.float32)
    cols = np.zeros((n, width), np.int32)
    fill = np.zeros(n, np.int32)
    for s, d in zip(src, dst):
        vals[d, fill[d]] = 1.0
        cols[d, fill[d]] = s
        fill[d] += 1
    rows_pad = -(-n // 128) * 128
    vals = np.pad(vals, ((0, rows_pad - n), (0, 0)))
    cols = np.pad(cols, ((0, rows_pad - n), (0, 0)))
    got = ops.ell_spmv(jnp.asarray(vals), jnp.asarray(cols),
                       jnp.asarray(contrib), block_m=128, interpret=True)
    want = np.zeros(n, np.float32)
    np.add.at(want, dst, contrib[src])
    np.testing.assert_allclose(np.asarray(got)[:n], want, rtol=1e-5,
                               atol=1e-5)
