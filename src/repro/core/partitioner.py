"""Backend-parametric CLUGP partitioner — the pipeline itself on the mesh.

The paper's §III-C scalability claim is about the *partitioner's own
runtime*: the three passes parallelize across nodes and restreaming
recovers the quality one-pass streaming leaves behind.  This module turns
``repro.core`` from a host-side reference into a mesh-resident subsystem:

    partition(src, dst, num_vertices, cfg, backend=..., nodes=..., mesh=...)

Three backends share one ``CLUGPConfig`` and one ``CLUGPResult``:

- ``"np"``      — the interpreted host path (``clugp_partition``), kept as
                  the equivalence oracle.  With ``nodes > 1`` it is the
                  host reference of the sharded combine: the stream splits
                  into contiguous slices, each slice runs the three passes
                  in a private cluster-id space, and the per-slice edge
                  assignments concatenate (paper §III-C "combine partial
                  partitioning results").
- ``"jit"``     — single-device fused pipeline: ``lax.scan`` clustering →
                  in-graph label compaction + contraction → batched
                  best-response rounds (Pallas ``game_bestresponse``
                  kernel or the identical XLA fallback) →
                  ``transform_jax`` — all under ONE jit, so the host never
                  touches per-edge state.
- ``"sharded"`` — true §III-C: the edge stream shards over a ``stream``
                  mesh axis (shard_map, specs resolved through
                  ``repro.dist.sharding`` rule tables).  Each device
                  clusters its slice in a private id space and contracts
                  locally; the game plays every device as one §V-D batch
                  against a psum'd global load vector; the transform runs
                  per device with its slice's balance cap; restream priors
                  are psum'd (V, k) majority tables.

``cfg.restream`` adds that many prioritized-restream passes on every
backend (Awadelkarim & Ugander): re-consume the stream with the previous
pass's realized vertex→partition majority as the prior.  Measured effect
in EXPERIMENTS.md §Perf-partitioner.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import numpy as np

import jax
import jax.numpy as jnp

from .clustering import (ClusteringResult, compact_labels_jax, default_vmax,
                         streaming_clustering_jax)
from .game import (contract, jax_cluster_csr, jax_game_rounds,
                   jax_game_rounds_gs, jax_greedy_assign)
from .pipeline import CLUGPConfig, CLUGPResult, clugp_partition
from .transform import (majority_vertex_map_jax, majority_vertex_map_np,
                        transform_jax, transform_np)
from . import metrics

BACKENDS = ("np", "jit", "sharded")
_BLOCK = 256          # game-kernel block: m_cap pads to a multiple of this


def _check_stream(src: np.ndarray) -> None:
    if src.shape[0] == 0:
        raise ValueError(
            "partition: the edge stream is empty (0 edges); there is "
            "nothing to partition")


def _game_mode(kernel: str) -> str:
    """Resolve the game sweep implementation.  ``scan`` = Gauss–Seidel
    over clusters (the CPU-fast host-exact form), ``pallas`` / ``xla`` =
    batched-Jacobi rounds on the ``game_bestresponse`` kernel / its XLA
    fallback (the MXU-shaped form).  ``auto`` picks pallas on TPU and the
    scan everywhere else."""
    if kernel not in ("auto", "scan", "pallas", "xla"):
        raise ValueError(f"unknown game kernel {kernel!r}; expected "
                         "'auto', 'scan', 'pallas' or 'xla'")
    if kernel == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "scan"
    return kernel


def _pad_to(n: int, mult: int) -> int:
    return -(-max(n, 1) // mult) * mult


def partition(src: np.ndarray, dst: np.ndarray, num_vertices: int,
              cfg: CLUGPConfig, *, backend: str = "np", nodes: int = 1,
              mesh=None) -> CLUGPResult:
    """Run the CLUGP pipeline on the chosen backend.

    ``nodes`` is the §III-C stream-split width (np reference combine /
    sharded mesh size).  ``mesh`` overrides the sharded backend's mesh
    (must carry a ``stream`` axis); otherwise one is built over
    ``nodes`` devices."""
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    _check_stream(src)
    if backend == "np":
        if nodes <= 1:
            return clugp_partition(src, dst, num_vertices, cfg)
        return _partition_np_nodes(src, dst, num_vertices, cfg, nodes)
    if backend == "jit":
        return _partition_jit(src, dst, num_vertices, cfg)
    return _partition_sharded(src, dst, num_vertices, cfg, nodes, mesh)


def clugp_partition_parallel(src: np.ndarray, dst: np.ndarray,
                             num_vertices: int, cfg: CLUGPConfig,
                             n_nodes: int = 4) -> CLUGPResult:
    """Compatibility alias for the §III-C host combine — the old
    fake-parallel loop in ``pipeline.py`` is gone; this is
    ``partition(backend="np", nodes=n_nodes)``."""
    return partition(src, dst, num_vertices, cfg, backend="np",
                     nodes=n_nodes)


# --------------------------------------------------------------- np combine

def _partition_np_nodes(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                        cfg: CLUGPConfig, nodes: int) -> CLUGPResult:
    """Host reference of the sharded combine: contiguous ceil(E/n) slices
    (the same chunking shard_map uses), private id spaces per node,
    concatenated edge assignments, then *global* restream passes whose
    majority prior spans all slices (the psum'd table's host twin).

    The merged result is explicit about what it is: per-node clustering /
    cluster-graph objects are not stitched into one fake global object —
    ``clustering``/``cluster_graph``/``cluster_assign`` are None and
    ``stats["per_node"]`` carries each node's private-space summary."""
    E = src.shape[0]
    e_per = -(-E // nodes)
    sub_cfg = dataclasses.replace(cfg, restream=0)
    assign = np.zeros(E, dtype=np.int32)
    per_node = []
    slices = []
    rounds = 0
    clusters = 0
    for i in range(nodes):
        lo, hi = i * e_per, min(E, (i + 1) * e_per)
        if hi <= lo:
            continue
        sub = clugp_partition(src[lo:hi], dst[lo:hi], num_vertices, sub_cfg)
        assign[lo:hi] = sub.assign
        rounds = max(rounds, sub.game_rounds)
        clusters += sub.clustering.num_clusters
        per_node.append({"node": i, "edges": int(hi - lo),
                         "clusters": sub.clustering.num_clusters,
                         "game_rounds": sub.game_rounds})
        slices.append((lo, hi, sub.clustering))
    for _ in range(cfg.restream):
        vp = majority_vertex_map_np(src, dst, assign, num_vertices, cfg.k)
        for lo, hi, clus in slices:
            assign[lo:hi] = transform_np(src[lo:hi], dst[lo:hi], vp,
                                         clus.deg, clus.divided,
                                         cfg.k, cfg.tau)
    res = CLUGPResult(assign, None, None, None, rounds)
    res.stats = metrics.summarize(src, dst, assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = clusters   # sum over private id spaces
    res.stats["game_rounds"] = rounds
    res.stats["backend"] = "np"
    res.stats["nodes"] = nodes
    res.stats["per_node"] = per_node
    return res


# --------------------------------------------------------------- jit backend

def _cluster_graph_arrays(src, dst, compact, m_cap: int, effective: bool,
                          mask=None):
    """Contract the streamed graph against compacted labels, all in-graph:
    per-cluster intra sizes, boundary row totals, and the cross-edge
    cluster endpoints (padded with the drop sentinel ``m_cap``).

    Matches ``contract`` exactly: self-loop edges of clustered vertices
    COUNT toward their cluster's intra size (cs == cd); ``mask`` excludes
    the sharded backend's padding lanes, which are fake self-loops."""
    cs, cd = compact[src], compact[dst]
    ok = (cs >= 0) & (cd >= 0)
    if mask is not None:
        ok = ok & mask
    sent = jnp.int32(m_cap)
    intra = ok & (cs == cd)
    cross = ok & (cs != cd)
    sizes = jnp.zeros((m_cap,), jnp.float32).at[
        jnp.where(intra, cs, sent)].add(1.0, mode="drop")
    xs = jnp.where(cross, cs, sent)
    xd = jnp.where(cross, cd, sent)
    row_tot = (jnp.zeros((m_cap,), jnp.float32)
               .at[xs].add(1.0, mode="drop")
               .at[xd].add(1.0, mode="drop"))
    game_sizes = sizes + row_tot if effective else sizes
    n_cross = cross.sum().astype(jnp.float32)
    return game_sizes, row_tot, xs, xd, n_cross


def _lambda_jax(total, n_cross, k: int, relative_weight):
    """λ_max (Thm 5) / relative-weight λ from traced cluster-graph totals
    (Σ game sizes, #cross edges) — matches ``lambda_max``/
    ``lambda_from_weight`` (adj.sum()/2 == n_cross)."""
    lam_max = jnp.where(total > 0,
                        (k * k) * n_cross / jnp.maximum(total * total, 1.0),
                        1.0)
    if relative_weight is None:
        return lam_max
    w = min(max(relative_weight, 1e-3), 1 - 1e-3)
    lam = lam_max * (w / (1 - w))
    return jnp.where((total > 0) & (n_cross > 0), lam, 1.0)


@partial(jax.jit, static_argnames=(
    "num_vertices", "k", "vmax", "tau", "allow_split", "split_degree_factor",
    "batch_size", "max_rounds", "seed", "game", "effective_sizes",
    "relative_weight", "restream", "game_mode", "id_cap", "m_cap",
    "nnz_cap"))
def _jit_pipeline(src, dst, *, num_vertices: int, k: int, vmax: float,
                  tau: float, allow_split: bool, split_degree_factor: float,
                  batch_size: int, max_rounds: int, seed: int, game: bool,
                  effective_sizes: bool, relative_weight, restream: int,
                  game_mode: str, id_cap: int, m_cap: int, nnz_cap: int):
    """The whole three-pass pipeline (+ restreams) under one jit — the
    host sees only the final arrays, never per-edge state."""
    clu_raw, deg, divided, replicas, next_id = streaming_clustering_jax(
        src, dst, num_vertices, vmax, allow_split=allow_split,
        split_degree_factor=split_degree_factor, id_cap=id_cap)
    compact, m = compact_labels_jax(clu_raw, id_cap)
    game_sizes, row_tot, xs, xd, n_cross = _cluster_graph_arrays(
        src, dst, compact, m_cap, effective_sizes)
    overflow = jnp.bool_(False)
    if game_mode == "scan" and m_cap * (m_cap + 1) >= 2 ** 31:
        game_mode = "xla"    # GS pair keys overflow int32 above ~46k
    if game:
        lam = _lambda_jax(game_sizes.sum(), n_cross, k, relative_weight)
        if game_mode == "scan":
            row, col, w, overflow = jax_cluster_csr(xs, xd, m_cap, nnz_cap)
            cluster_assign, rounds = jax_game_rounds_gs(
                row, col, w, game_sizes, row_tot, k, lam,
                max_rounds=max_rounds, seed=seed)
        else:
            cluster_assign, rounds = jax_game_rounds(
                xs, xd, game_sizes, row_tot, k, lam,
                batch_size=batch_size, max_rounds=max_rounds, seed=seed,
                use_pallas=game_mode == "pallas")
    else:
        cluster_assign = jax_greedy_assign(game_sizes, k)
        rounds = jnp.int32(0)
    vertex_part = cluster_assign[jnp.clip(compact, 0, m_cap - 1)]
    assign = transform_jax(src, dst, vertex_part, deg, divided, k, tau)
    for _ in range(restream):
        vp = majority_vertex_map_jax(src, dst, assign, num_vertices, k)
        assign = transform_jax(src, dst, vp, deg, divided, k, tau)
    return (assign, compact, deg, divided, replicas, m, rounds,
            cluster_assign, overflow, next_id)


def _id_cap_guess(num_vertices: int, num_edges: int) -> int:
    """Initial cluster-id-space guess: ids = allocations (≤ V) + splits
    (usually a fraction of V).  The pipeline re-runs with a doubled cap
    iff the returned next_id hits it — the table is copied per scan block,
    so a tight cap is worth the rare retry."""
    return _pad_to(min(2 * num_vertices + 2048,
                       num_vertices + 2 * num_edges + 2), 1024)


def _m_cap_guess(num_vertices: int) -> int:
    """Initial compacted-cluster-count guess: real streams end with
    m ≪ V (clusters ≈ V_max-sized communities), and the game's per-round
    cost is O(m_cap·k), so guess small and retry on overflow."""
    return _pad_to(min(num_vertices, max(_BLOCK, num_vertices // 4)),
                   _BLOCK)


def _partition_jit(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                   cfg: CLUGPConfig) -> CLUGPResult:
    E = src.shape[0]
    vmax = cfg.vmax if cfg.vmax is not None else default_vmax(E, cfg.k)
    id_cap = _id_cap_guess(num_vertices, E)
    m_cap = _m_cap_guess(num_vertices)
    nnz_cap = 8 * m_cap
    while True:
        out = _jit_pipeline(
            jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
            num_vertices=num_vertices, k=cfg.k, vmax=float(vmax),
            tau=cfg.tau, allow_split=cfg.split,
            split_degree_factor=cfg.split_degree_factor,
            batch_size=cfg.batch_size, max_rounds=cfg.max_rounds,
            seed=cfg.seed, game=cfg.game,
            effective_sizes=cfg.effective_sizes,
            relative_weight=cfg.relative_weight, restream=cfg.restream,
            game_mode=_game_mode(cfg.kernel), id_cap=id_cap, m_cap=m_cap,
            nnz_cap=nnz_cap)
        ok = True
        if int(out[-1]) > id_cap - 2:
            id_cap = min(2 * id_cap, num_vertices + 2 * E + 2)
            ok = False
        if int(out[5]) > m_cap:
            m_cap = min(2 * m_cap, _pad_to(num_vertices, _BLOCK))
            ok = False
        if bool(out[-2]):
            nnz_cap = min(2 * nnz_cap, m_cap * m_cap)
            ok = False
        if ok:
            break
    assign, compact, deg, divided, replicas, m, rounds, cluster_assign = (
        np.asarray(x) for x in out[:-2])
    m = int(m)
    rounds = int(rounds)
    clus = ClusteringResult(compact, deg, divided, replicas, m)
    cg = contract(src, dst, compact)
    res = CLUGPResult(assign, clus, cg, cluster_assign[:m], rounds)
    res.stats = metrics.summarize(src, dst, assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = m
    res.stats["game_rounds"] = rounds
    res.stats["backend"] = "jit"
    return res


# ----------------------------------------------------------- sharded backend

def _stream_spec(mesh, shape: tuple):
    """Resolve the edge-stream PartitionSpec through the dist.sharding
    rule table (the partitioner never names mesh axes directly)."""
    from ..dist.sharding import PARTITIONER_RULES, resolve_spec
    return resolve_spec(shape, ("stream",), PARTITIONER_RULES,
                        dict(mesh.shape))


@lru_cache(maxsize=32)
def _make_sharded_fn(mesh, e_per: int, num_vertices: int, k: int,
                     vmax_opt, tau: float, allow_split: bool,
                     split_degree_factor: float, batch_size: int,
                     max_rounds: int, seed: int, game: bool,
                     effective_sizes: bool, relative_weight,
                     restream: int, game_mode: str, id_cap: int,
                     m_cap: int, nnz_cap: int):
    """Build (and cache, keyed by mesh + statics) the jitted shard_map
    pipeline: one stream slice per device along the ``stream`` axis."""
    from ..dist._compat import shard_map

    n = mesh.shape["stream"]
    spec = _stream_spec(mesh, (n * e_per,))
    axis = "stream"
    if game_mode == "scan" and m_cap * (m_cap + 1) >= 2 ** 31:
        game_mode = "xla"    # GS pair keys overflow int32 above ~46k

    def node_fn(src_b, dst_b, mask_b):
        # padded lanes become self-loops: the clustering scan freezes on
        # them and the transform scan skips them via the mask
        s = jnp.where(mask_b, src_b, 0).astype(jnp.int32)
        d = jnp.where(mask_b, dst_b, 0).astype(jnp.int32)
        e_real = mask_b.sum().astype(jnp.float32)
        # V_max from the slice's REAL edge count — each node derives its
        # own cap from its sub-stream, exactly like the np combine (a
        # global-|E| cap grows node-local clusters 4× too fat at n=4 and
        # costs ~40% RF)
        vmax = (jnp.maximum(2.0, e_real / k) if vmax_opt is None
                else jnp.float32(vmax_opt))
        clu_raw, deg, divided, _, next_id = streaming_clustering_jax(
            s, d, num_vertices, vmax, allow_split=allow_split,
            split_degree_factor=split_degree_factor, id_cap=id_cap)
        compact, m_local = compact_labels_jax(clu_raw, id_cap)
        game_sizes, row_tot, xs, xd, n_cross = _cluster_graph_arrays(
            s, d, compact, m_cap, effective_sizes, mask=mask_b)
        overflow = jnp.int32(0)
        if game:
            # λ from the LOCAL cluster graph, like the host combine:
            # Thm 5's feasible range is a per-id-space quantity, and the
            # global totals under-weight the balance term by ~n (measured
            # +22% RF at n=4); the load vector itself stays global
            lam = _lambda_jax(game_sizes.sum(), n_cross, k,
                              relative_weight)
            if game_mode == "scan":
                row, col, w, ovf = jax_cluster_csr(xs, xd, m_cap, nnz_cap)
                overflow = ovf.astype(jnp.int32)
                cluster_assign, rounds = jax_game_rounds_gs(
                    row, col, w, game_sizes, row_tot, k, lam,
                    max_rounds=max_rounds, seed=seed, axis=axis)
            else:
                cluster_assign, rounds = jax_game_rounds(
                    xs, xd, game_sizes, row_tot, k, lam,
                    batch_size=batch_size, max_rounds=max_rounds,
                    seed=seed, use_pallas=game_mode == "pallas",
                    axis=axis)
        else:
            cluster_assign = jax_greedy_assign(game_sizes, k)
            rounds = jnp.int32(0)
        vertex_part = cluster_assign[jnp.clip(compact, 0, m_cap - 1)]
        lmax = tau * e_real / k          # per-slice balance cap (§III-C)
        assign_b = transform_jax(s, d, vertex_part, deg, divided, k,
                                 mask=mask_b, lmax=lmax)
        for _ in range(restream):
            vp = majority_vertex_map_jax(s, d, assign_b, num_vertices, k,
                                         mask=mask_b, axis=axis)
            assign_b = transform_jax(s, d, vp, deg, divided, k,
                                     mask=mask_b, lmax=lmax)
        return (assign_b, m_local[None], rounds[None], next_id[None],
                overflow[None])

    # check_vma=False: the game's while_loop has no replication rule on
    # the container's jax (0.4.x shard_map check_rep)
    mapped = shard_map(node_fn, mesh=mesh, in_specs=(spec, spec, spec),
                       out_specs=(spec, spec, spec, spec, spec),
                       check_vma=False)
    return jax.jit(mapped)


def _partition_sharded(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                       cfg: CLUGPConfig, nodes: int, mesh) -> CLUGPResult:
    E = src.shape[0]
    if mesh is None:
        if jax.device_count() < nodes:
            raise RuntimeError(
                f"sharded backend needs {nodes} devices but only "
                f"{jax.device_count()} are visible; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={nodes} before "
                f"the first jax import (launch.partition does this for "
                f"--backend sharded)")
        mesh = jax.make_mesh((nodes,), ("stream",))
    n = int(mesh.shape["stream"])
    e_per = -(-E // n)
    e_pad = e_per * n
    src_p = np.zeros(e_pad, dtype=np.int32)
    dst_p = np.zeros(e_pad, dtype=np.int32)
    mask = np.zeros(e_pad, dtype=bool)
    src_p[:E], dst_p[:E], mask[:E] = src, dst, True
    id_cap = _id_cap_guess(num_vertices, e_per)
    m_cap = _m_cap_guess(num_vertices)
    nnz_cap = 8 * m_cap
    while True:
        run = _make_sharded_fn(
            mesh, e_per, num_vertices, cfg.k,
            None if cfg.vmax is None else float(cfg.vmax), cfg.tau,
            cfg.split, cfg.split_degree_factor, cfg.batch_size,
            cfg.max_rounds, cfg.seed, cfg.game, cfg.effective_sizes,
            cfg.relative_weight, cfg.restream, _game_mode(cfg.kernel),
            id_cap, m_cap, nnz_cap)
        with mesh:
            assign_p, m_locals, rounds_arr, next_ids, overflows = run(
                jnp.asarray(src_p), jnp.asarray(dst_p), jnp.asarray(mask))
        ok = True
        if int(np.asarray(next_ids).max()) > id_cap - 2:
            id_cap = min(2 * id_cap, num_vertices + 2 * e_per + 2)
            ok = False
        if int(np.asarray(m_locals).max()) > m_cap:
            m_cap = min(2 * m_cap, _pad_to(num_vertices, _BLOCK))
            ok = False
        if int(np.asarray(overflows).max()) > 0:
            nnz_cap = min(2 * nnz_cap, m_cap * m_cap)
            ok = False
        if ok:
            break
    assign = np.asarray(assign_p)[:E]
    m_locals = np.asarray(m_locals)
    rounds = int(np.asarray(rounds_arr).max())
    res = CLUGPResult(assign, None, None, None, rounds)
    res.stats = metrics.summarize(src, dst, assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = int(m_locals.sum())
    res.stats["game_rounds"] = rounds
    res.stats["backend"] = "sharded"
    res.stats["nodes"] = n
    res.stats["per_node"] = [
        {"node": i, "clusters": int(c)} for i, c in enumerate(m_locals)]
    return res
