"""Assigned architecture configs (``--arch <id>``).  Exact published
numbers; sources per the assignment sheet."""
from __future__ import annotations

import importlib

ARCHS = [
    "llama4_scout_17b_a16e",
    "deepseek_v3_671b",
    "qwen1_5_110b",
    "command_r_35b",
    "stablelm_1_6b",
    "qwen2_7b",
    "pixtral_12b",
    "jamba_1_5_large_398b",
    "mamba2_130m",
    "seamless_m4t_large_v2",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str):
    key = name.replace(".", "_").replace("-", "_")
    key = {"qwen1_5_110b": "qwen1_5_110b"}.get(key, key)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCHS}
