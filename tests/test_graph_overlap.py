"""Overlapped GAS body + convergence early exit + warm start.

Three identity suites for the hot-loop rework:

* **overlap** — the interleaved ragged body (interior gather/local/apply
  during the k−1 ring hops, per-hop partial combine on the frontier) is
  a pure re-ordering: bit-identical values to the phase-ordered body on
  every ragged exchange, and a hard error on exchanges without a ring.
* **early exit** — ``tol`` turns ``iters`` into a cap; the tol run must
  stop strictly early on converging programs and be bit-identical to a
  fixed-iters run at the reported ``iters_run`` (determinism: the loop
  mode changes when we stop, never what we compute).
* **warm start** — ``init_values`` seeds the loop from a previous fixed
  point; re-running from the converged state must cost ≤ 1 iteration
  and land on the same values, including through the serving path after
  an ingest/restream swap.
"""
import numpy as np
import pytest

from conftest import random_graph_and_assign

from repro.dist.halo import RAGGED_EXCHANGES
from repro.graph import build_layout, get_program, simulate_gas
from repro.graph.engine import simulate_gas_many

PROGRAMS = ("pagerank", "cc", "sssp")


def small_layout(seed=3, k=4, n=250):
    src, dst, n, assign = random_graph_and_assign(seed, k, n=n)
    lay = build_layout(src, dst, assign, n, k)
    return lay, n


# ------------------------------------------------------------------ overlap

@pytest.mark.parametrize("exchange", RAGGED_EXCHANGES)
@pytest.mark.parametrize("pname", PROGRAMS)
def test_overlap_bit_identical_to_phase_ordered(exchange, pname):
    lay, n = small_layout()
    prog = get_program(pname, n)
    base = simulate_gas(prog, lay, iters=8, exchange=exchange)
    over = simulate_gas(prog, lay, iters=8, exchange=exchange,
                        overlap=True)
    np.testing.assert_array_equal(over, base)


def test_overlap_rejected_without_a_ring():
    lay, n = small_layout()
    prog = get_program("pagerank", n)
    for exchange in ("dense", "halo", "quantized"):
        with pytest.raises(ValueError, match="overlap"):
            simulate_gas(prog, lay, iters=2, exchange=exchange,
                         overlap=True)


def test_overlap_fused_bundle_bit_identical():
    lay, n = small_layout(seed=5)
    bundle = [get_program(p, n) for p in ("pagerank", "ppr", "centrality")]
    base = simulate_gas_many(bundle, lay, iters=6,
                             exchange="ragged_quantized")
    over = simulate_gas_many(bundle, lay, iters=6,
                             exchange="ragged_quantized", overlap=True)
    for b, o in zip(base, over):
        np.testing.assert_array_equal(o, b)


# --------------------------------------------------------------- early exit

@pytest.mark.parametrize("exchange",
                         ("dense", "halo", "ragged", "ragged_quantized"))
def test_early_exit_matches_fixed_iters_at_iters_run(exchange):
    """tol changes when the loop stops, never what it computes: the tol
    run is bit-identical to a fixed run truncated at iters_run."""
    lay, n = small_layout(seed=11)
    prog = get_program("pagerank", n)
    cap = 100
    v_tol, iters_run = simulate_gas(prog, lay, iters=cap,
                                    exchange=exchange, tol=1e-6,
                                    return_iters=True)
    assert 0 < iters_run < cap
    v_fix = simulate_gas(prog, lay, iters=int(iters_run),
                         exchange=exchange)
    np.testing.assert_array_equal(v_tol, v_fix)


def test_early_exit_int_program_stops_at_fixed_point():
    """CC converges to an exact fixed point: tol=0 stops as soon as one
    sweep changes nothing, and the answer equals the long fixed run."""
    lay, n = small_layout(seed=13)
    prog = get_program("cc", n)
    v_tol, iters_run = simulate_gas(prog, lay, iters=64, exchange="ragged",
                                    tol=0.0, return_iters=True)
    assert iters_run < 64
    np.testing.assert_array_equal(
        v_tol, simulate_gas(prog, lay, iters=64, exchange="ragged"))


def test_tol_none_keeps_fixed_iters_semantics():
    """tol=None is the legacy fixed-iters trace — same values, and
    return_iters reports exactly the requested count."""
    lay, n = small_layout(seed=17)
    prog = get_program("pagerank", n)
    v, it = simulate_gas(prog, lay, iters=7, exchange="ragged",
                         return_iters=True)
    assert it == 7
    np.testing.assert_array_equal(
        v, simulate_gas(prog, lay, iters=7, exchange="ragged"))


def test_zero_iters_returns_init_under_tol():
    lay, n = small_layout(seed=19)
    prog = get_program("pagerank", n)
    v0, it = simulate_gas(prog, lay, iters=0, exchange="ragged", tol=1e-6,
                          return_iters=True)
    assert it == 0
    np.testing.assert_array_equal(
        v0, simulate_gas(prog, lay, iters=0, exchange="ragged"))


# --------------------------------------------------------------- warm start

def test_warm_start_from_fixed_point_costs_one_iteration():
    """Seeding the loop with its own converged output re-converges in a
    single verification sweep and returns the identical values."""
    lay, n = small_layout(seed=23)
    prog = get_program("pagerank", n)
    cold, cold_iters = simulate_gas(prog, lay, iters=100, exchange="ragged",
                                    tol=1e-6, return_iters=True)
    warm, warm_iters = simulate_gas(prog, lay, iters=100, exchange="ragged",
                                    tol=1e-6, init_values=np.asarray(cold),
                                    return_iters=True)
    assert warm_iters <= 1 < cold_iters
    # the verification sweep moves the seeds by at most the residual
    # that stopped the cold run — inside the tol envelope, not bit-equal
    np.testing.assert_allclose(warm, cold, atol=1e-5)


def test_empty_warm_vector_is_a_cold_run():
    """The serving fast path ships np.zeros(0) for programs with no
    cached fixed point — the all-False warm mask must reproduce the cold
    run exactly (warm and cold share one compiled loop)."""
    lay, n = small_layout(seed=29)
    prog = get_program("pagerank", n)
    cold = simulate_gas(prog, lay, iters=12, exchange="ragged", tol=1e-6)
    seeded = simulate_gas(prog, lay, iters=12, exchange="ragged", tol=1e-6,
                          init_values=np.zeros(0))
    np.testing.assert_array_equal(seeded, cold)


# ----------------------------------------------------- interior two-coloring

@pytest.mark.parametrize("seed,k", [(0, 2), (1, 4), (2, 8)])
def test_interior_frontier_stats_consistent(seed, k):
    src, dst, n, assign = random_graph_and_assign(seed, k)
    lay = build_layout(src, dst, assign, n, k)
    st = lay.interior_frontier_stats()
    local = lay.vert_mask.sum(axis=1)
    np.testing.assert_array_equal(st["local_per_part"], local)
    assert st["interior_per_part"] == list(
        (lay.vert_mask & ~lay.frontier).sum(axis=1))
    assert 0.0 <= st["interior_frac_min"] <= st["interior_frac"] <= 1.0


# ------------------------------------------------------ multidevice identity

@pytest.mark.multidevice
def test_shard_map_overlap_and_warm_identity(multidevice):
    """The per-device overlapped body matches the phase-ordered shard_map
    run bit-for-bit, the tol loop reports the same iters_run as the
    stacked simulator, and the multidevice HLO of the overlapped step
    contains EXACTLY as many collective-permutes as the phase-ordered
    one — overlap re-orders compute around the ring, it never adds or
    drops a hop."""
    multidevice("""
        import numpy as np
        from repro.core import CLUGPConfig, web_graph
        from repro.launch.mesh import make_graph_mesh
        from repro.session import GraphSession, SessionConfig

        g = web_graph(scale=10, seed=0)
        sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=8)))
        sess.partition(g.src, g.dst, g.num_vertices).layout()
        mesh = make_graph_mesh(8)
        from repro.analysis.ir import collective_permute_count

        base = sess.run("pagerank", iters=6, exchange="ragged", mesh=mesh)
        over = sess.run("pagerank", iters=6, exchange="ragged", mesh=mesh,
                        overlap=True)
        assert np.array_equal(base, over), "overlap changed the values"

        v_tol, it = sess.run("pagerank", iters=100, exchange="ragged",
                             mesh=mesh, tol=1e-6, return_iters=True)
        assert 0 < it < 100, it
        sim_tol, sim_it = sess.run("pagerank", iters=100,
                                   exchange="ragged", tol=1e-6,
                                   return_iters=True)
        assert it == sim_it, (it, sim_it)
        assert np.array_equal(v_tol, sim_tol)

        counts = {}
        for overlap in (False, True):
            jitted, args = sess.dryrun_step("pagerank", mesh=mesh,
                                            exchange="ragged",
                                            overlap=overlap)
            hlo = jitted.lower(*args).compile().as_text()
            counts[overlap] = collective_permute_count(hlo)
        assert counts[False] > 0, counts
        assert counts[True] == counts[False], counts
        print("shard_map overlap identity OK", counts)
        """, n_devices=8)
