"""AST lint engine: rules, findings, the tracked allowlist, reporting.

A ``Rule`` owns its scan scope (``roots``/``excludes``, repo-relative)
and emits ``Finding``s with a *stable key* (the offending symbol, not a
line number) so allowlist entries survive unrelated edits.  The engine
parses each file once, fans the tree out to every rule in scope, then
reconciles findings against the allowlist:

- a finding matched by an entry is demoted from violation to
  ``allowlisted`` (it still lands in ``results/ANALYSIS.json`` with the
  flag, so the burn-down is visible in the artifact trend);
- an entry whose match count differs from its recorded ``count`` is an
  engine error either way — more matches is a regression, fewer means
  the entry must be tightened or deleted.  Counts only burn down.

``run_lint()`` is what CI (`python -m repro.analysis --check`) and the
structural pytest wrappers (tests/test_stages.py, tests/test_analysis.py)
both call, so the two can never disagree about what the guardrails are.
"""
from __future__ import annotations

import ast
import json
from dataclasses import dataclass, field, replace
from pathlib import Path

SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", "results",
             ".pytest_cache", "node_modules"}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str           # repo-relative, posix separators
    line: int
    col: int
    key: str            # stable, rule-specific (offending symbol)
    message: str
    severity: str = "error"
    allowlisted: bool = False
    justification: str = ""

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}"


@dataclass(frozen=True)
class Allow:
    """One tracked exemption: ``count`` occurrences of ``key`` under
    ``rule`` in ``path``, with a one-line justification.  The engine
    errors when the live count drifts from ``count`` in either
    direction — the list can only shrink deliberately."""
    rule: str
    path: str
    key: str
    count: int
    why: str


class Rule:
    """Base rule: subclasses set ``id``/``description``/``roots`` and
    implement ``run(tree, relpath, text) -> list[Finding]``."""

    id: str = "?"
    description: str = ""
    roots: tuple[str, ...] = ("src/repro",)
    excludes: tuple[str, ...] = ()

    def applies_to(self, relpath: str) -> bool:
        rp = relpath.replace("\\", "/")
        hit = any(rp == r or rp.startswith(r.rstrip("/") + "/")
                  for r in self.roots)
        return hit and not any(rp == e or rp.startswith(e.rstrip("/") + "/")
                               for e in self.excludes)

    def run(self, tree: ast.Module, relpath: str,
            text: str) -> list[Finding]:
        raise NotImplementedError

    def finding(self, relpath: str, node: ast.AST, key: str,
                message: str) -> Finding:
        return Finding(rule=self.id, path=relpath,
                       line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       key=key, message=message)


@dataclass
class Report:
    root: str
    findings: list[Finding] = field(default_factory=list)
    errors: list[str] = field(default_factory=list)   # allowlist mismatches
    parse_failures: list[str] = field(default_factory=list)
    rules: tuple = ()

    @property
    def violations(self) -> list[Finding]:
        return [f for f in self.findings if not f.allowlisted]

    @property
    def ok(self) -> bool:
        return not self.violations and not self.errors \
            and not self.parse_failures

    def by_rule(self, rule_id: str) -> list[Finding]:
        return [f for f in self.findings if f.rule == rule_id]

    def summary_rows(self) -> list[dict]:
        """One trend-diffable row per rule (+ a TOTAL row): ``rule`` is
        the identity, finding counts are lower-is-better numerics, and
        the per-finding detail rides along as a non-numeric list."""
        rows = []
        for rule in self.rules:
            fs = self.by_rule(rule.id)
            allowed = [f for f in fs if f.allowlisted]
            rows.append({
                "bench": "static_analysis", "rule": rule.id,
                "findings": len(fs), "allowlisted": len(allowed),
                "violations": len(fs) - len(allowed),
                "detail": [f"{f.location} {f.key}"
                           + (" [allowlisted]" if f.allowlisted else "")
                           for f in fs],
            })
        rows.append({
            "bench": "static_analysis", "rule": "TOTAL",
            "findings": len(self.findings),
            "allowlisted": sum(f.allowlisted for f in self.findings),
            "violations": len(self.violations),
            "errors": len(self.errors) + len(self.parse_failures),
        })
        return rows

    def format(self, verbose: bool = False) -> str:
        lines = []
        for f in sorted(self.findings,
                        key=lambda f: (f.rule, f.path, f.line)):
            if f.allowlisted and not verbose:
                continue
            tag = " [allowlisted]" if f.allowlisted else ""
            lines.append(f"{f.location}: {f.rule}: {f.message}{tag}")
        lines += [f"allowlist error: {e}" for e in self.errors]
        lines += [f"parse error: {e}" for e in self.parse_failures]
        n_allow = sum(f.allowlisted for f in self.findings)
        lines.append(f"{len(self.findings)} finding(s): "
                     f"{len(self.violations)} violation(s), "
                     f"{n_allow} allowlisted; "
                     f"{len(self.errors)} allowlist error(s)")
        return "\n".join(lines)


def repo_root() -> Path:
    """Nearest ancestor of this file carrying pyproject.toml — the tree
    the default scan covers."""
    p = Path(__file__).resolve()
    for parent in p.parents:
        if (parent / "pyproject.toml").exists():
            return parent
    # editable installs always hit pyproject above; a site-packages
    # install has no tree to lint — caller must pass root explicitly
    raise RuntimeError("repro.analysis: could not locate the repo root "
                       "(no pyproject.toml above the package); pass "
                       "root= explicitly")


def iter_python_files(root: Path, subdirs) -> list[Path]:
    out = []
    for sub in subdirs:
        base = root / sub
        if base.is_file() and base.suffix == ".py":
            out.append(base)
            continue
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if not any(part in SKIP_DIRS for part in p.parts):
                out.append(p)
    # a file can sit under two roots (e.g. "src" and "src/repro/launch")
    seen, uniq = set(), []
    for p in out:
        if p not in seen:
            seen.add(p)
            uniq.append(p)
    return uniq


def lint_file(path: Path, relpath: str, rules) -> tuple[list[Finding],
                                                        str | None]:
    """Parse one file and run every in-scope rule.  Returns (findings,
    parse-error-or-None)."""
    try:
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError) as e:
        return [], f"{relpath}: {type(e).__name__}: {e}"
    found = []
    for rule in rules:
        if rule.applies_to(relpath):
            found.extend(rule.run(tree, relpath, text))
    return found, None


def _apply_allowlist(findings: list[Finding], allowlist) -> tuple[
        list[Finding], list[str]]:
    errors = []
    out = list(findings)
    for entry in allowlist:
        idxs = [i for i, f in enumerate(out)
                if f.rule == entry.rule and f.path == entry.path
                and f.key == entry.key]
        for i in idxs:
            out[i] = replace(out[i], allowlisted=True,
                             justification=entry.why)
        if len(idxs) != entry.count:
            direction = ("regressed — fix the new sites or justify them"
                         if len(idxs) > entry.count else
                         "burned down — shrink the entry's count (or "
                         "delete it) so it cannot grow back")
            errors.append(
                f"{entry.rule} @ {entry.path} key={entry.key!r}: "
                f"allowlist says {entry.count}, tree has {len(idxs)} — "
                f"{direction}")
    return out, errors


def run_lint(root: Path | str | None = None, rules=None,
             allowlist=None) -> Report:
    """Lint the tree under ``root`` (default: the repo) with ``rules``
    (default: the full registry) against ``allowlist`` (default: the
    tracked ``repro.analysis.allowlist.ALLOWLIST``)."""
    if rules is None:
        from .rules import DEFAULT_RULES
        rules = DEFAULT_RULES
    if allowlist is None:
        from .allowlist import ALLOWLIST
        allowlist = ALLOWLIST
    root = Path(root) if root is not None else repo_root()
    # a partial-rule run (pytest wrappers) must not reconcile entries
    # belonging to rules that never scanned
    active = {rule.id for rule in rules}
    allowlist = [a for a in allowlist if a.rule in active]
    subdirs = sorted({r for rule in rules for r in rule.roots})
    report = Report(root=str(root), rules=tuple(rules))
    for path in iter_python_files(root, subdirs):
        relpath = path.relative_to(root).as_posix()
        found, err = lint_file(path, relpath, rules)
        report.findings.extend(found)
        if err:
            report.parse_failures.append(err)
    report.findings, report.errors = _apply_allowlist(report.findings,
                                                      allowlist)
    return report


def write_json(report: Report, out_path: Path) -> list[dict]:
    """Emit the trend-gated artifact: summary rows (one per rule) plus
    one detail row block — a flat list, the shape benchmarks/trend.py
    diffs."""
    rows = report.summary_rows()
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rows, indent=1))
    return rows
