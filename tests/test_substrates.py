"""Substrate tests: optimizer, checkpoint/FT, data pipeline, MoE dispatch,
SSD chunked scan, gradient compression, expert placement."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import ckpt
from repro.data.pipeline import DataConfig, batch_at
from repro.dist.compress import (compress_with_error_feedback,
                                 zero_residual)
from repro.dist.ft import FTConfig, run as ft_run
from repro.train import adafactor, adamw, cosine_schedule


# ---------------------------------------------------------------- optimizer

def _quadratic_params():
    return {"a": jnp.array([1.5, -2.0, 3.0]), "b": jnp.array([[0.5, -0.5]])}


@pytest.mark.parametrize("opt_fn", [adamw, adafactor])
def test_optimizer_decreases_quadratic(opt_fn):
    opt = opt_fn(lr=0.05, weight_decay=0.0)
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(p))

    l0 = float(loss(params))
    for step in range(50):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, step)
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_factored_state_small():
    opt = adafactor(min_factor_dim=4)
    params = {"w": jnp.zeros((8, 16)), "v_small": jnp.zeros((3,))}
    state = opt.init(params)
    assert set(state["f"]["w"]) == {"vr", "vc"}
    assert state["f"]["w"]["vr"].shape == (8,)
    assert state["f"]["w"]["vc"].shape == (16,)
    assert set(state["f"]["v_small"]) == {"v"}


def test_cosine_schedule_shape():
    s = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1e-3) < 1e-9
    assert float(s(100)) < 1e-5


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)}}
    ckpt.save(tmp_path, 7, tree)
    got, step = ckpt.restore_latest(tmp_path, tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"], np.float32),
                                  np.asarray(tree["b"]["c"], np.float32))


def test_checkpoint_skips_torn_writes(tmp_path):
    tree = {"a": jnp.ones((2,))}
    ckpt.save(tmp_path, 1, tree)
    # simulate a torn write: directory without manifest
    (tmp_path / "step_00000009").mkdir()
    got, step = ckpt.restore_latest(tmp_path, tree)
    assert step == 1


def test_checkpoint_latest_wins(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    ckpt.save(tmp_path, 1, tree)
    ckpt.save(tmp_path, 5, {"a": jnp.full((2,), 5.0)})
    got, step = ckpt.restore_latest(tmp_path, tree)
    assert step == 5
    assert float(got["a"][0]) == 5.0


# ---------------------------------------------------------------- FT driver

def _toy_step():
    def step(params, opt_state, batch, i):
        params = jax.tree_util.tree_map(lambda p: p - 0.1 * p, params)
        loss = jnp.sum(params["w"] ** 2)
        return params, opt_state, loss
    return step


def test_ft_restart_continues_from_checkpoint(tmp_path):
    params = {"w": jnp.ones((4,))}
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                   async_checkpoint=False, fail_at_step=12)
    with pytest.raises(RuntimeError, match="injected failure"):
        ft_run(_toy_step(), params, {}, lambda s: None, 20, cfg,
               log_every=0, log_fn=lambda *_: None)
    # restart: resumes from step 10's checkpoint and completes
    cfg2 = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                    async_checkpoint=False)
    p2, _, losses, state = ft_run(_toy_step(), params, {}, lambda s: None,
                                  20, cfg2, log_every=0,
                                  log_fn=lambda *_: None)
    assert state.step == 20
    # resumed run executed steps 11..19 (9 steps), not all 20
    assert len(losses) == 9
    steps = ckpt.list_steps(tmp_path)
    assert 10 in steps and 19 in steps


def test_ft_straggler_detection(tmp_path):
    import time as _t
    calls = []

    def slow_step(params, opt_state, batch, i):
        if int(i) == 6:
            _t.sleep(0.3)
        return params, opt_state, jnp.float32(0.0)

    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                   async_checkpoint=False, straggler_factor=3.0)
    _, _, _, state = ft_run(slow_step, {"w": jnp.ones(2)}, {},
                            lambda s: None, 10, cfg, log_every=0,
                            on_straggler=lambda *a: calls.append(a),
                            log_fn=lambda *_: None)
    assert state.stragglers >= 1
    assert calls


# ---------------------------------------------------------------- data

def test_data_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4)
    b1 = batch_at(cfg, 7)
    b2 = batch_at(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at(cfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host sharding is disjoint streams
    h0 = batch_at(DataConfig(100, 32, 4, n_hosts=2, host_id=0), 3)
    h1 = batch_at(DataConfig(100, 32, 4, n_hosts=2, host_id=1), 3)
    assert h0["tokens"].shape == (2, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


# ---------------------------------------------------------------- MoE

def test_moe_dispatch_matches_reference_when_uncapped():
    from repro.models.moe import moe_apply, moe_init, moe_reference
    key = jax.random.key(0)
    p = moe_init(key, 32, 64, n_experts=4, n_shared=1)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32)
    got = moe_apply(p, x, n_experts=4, top_k=2, capacity_factor=8.0)
    want = moe_reference(p, x, n_experts=4, top_k=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_monotone():
    from repro.models.moe import moe_apply, moe_init
    key = jax.random.key(0)
    p = moe_init(key, 16, 32, n_experts=4)
    x = jax.random.normal(jax.random.key(1), (1, 32, 16), jnp.float32)
    full = moe_apply(p, x, n_experts=4, top_k=2, capacity_factor=8.0)
    tight = moe_apply(p, x, n_experts=4, top_k=2, capacity_factor=0.25)
    # tight capacity zeroes some tokens' expert contribution
    diff = np.abs(np.asarray(full) - np.asarray(tight)).max()
    assert diff > 0


# ---------------------------------------------------------------- SSD

def test_ssd_chunked_matches_sequential_reference():
    from repro.models.mamba import ssd_chunked, ssd_reference
    rng = np.random.default_rng(0)
    b, S, H, dh, N = 2, 64, 3, 8, 16
    x = jnp.asarray(rng.normal(size=(b, S, H, dh)), jnp.float32)
    dt = jnp.asarray(rng.random((b, S, H)) * 0.5 + 0.1, jnp.float32)
    A = -jnp.asarray(rng.random(H) + 0.5, jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, S, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, S, N)), jnp.float32)
    D = jnp.asarray(rng.random(H), jnp.float32)
    for chunk in (8, 16, 32):
        got = ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
        want = ssd_reference(x, dt, A, B, C, D)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_ssd_decode_matches_chunked():
    from repro.models.mamba import (ssd_apply, ssd_decode_step, ssd_init)
    key = jax.random.key(0)
    d_model, d_inner, d_state, head_dim = 16, 32, 8, 8
    p = ssd_init(key, d_model, d_inner, d_state, head_dim)
    x = jax.random.normal(jax.random.key(1), (1, 16, d_model), jnp.float32)
    full = ssd_apply(p, x, d_inner=d_inner, d_state=d_state,
                     head_dim=head_dim, chunk=8)
    state = jnp.zeros((1, d_inner // head_dim, d_state, head_dim),
                      jnp.float32)
    outs = []
    for t in range(16):
        y, state = ssd_decode_step(p, x[:, t:t + 1], state,
                                   d_inner=d_inner, d_state=d_state,
                                   head_dim=head_dim)
        outs.append(np.asarray(y[:, 0]))
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, np.asarray(full), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------- compression

def test_error_feedback_compression_converges():
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                              jnp.float32)}
    res = zero_residual(grads)
    acc = jnp.zeros((64,))
    for _ in range(50):
        cg, res = compress_with_error_feedback(grads, res)
        acc = acc + cg["w"]
    # mean compressed gradient ≈ true gradient (error feedback property)
    np.testing.assert_allclose(np.asarray(acc) / 50,
                               np.asarray(grads["w"]), rtol=0.05, atol=0.02)


# ---------------------------------------------------------------- experts

def test_expert_placement_reduces_a2a():
    from benchmarks.bench_expert_placement import (_correlated_routing,
                                                   a2a_volume)
    from repro.core.expert_placement import place_experts
    top = _correlated_routing(T=4000, E=32, K=2, n_topics=4, seed=0)
    rr = np.arange(32) // 4
    perm = place_experts(top, 32, 8, seed=0)
    assert sorted(perm.tolist()) == list(range(32))   # valid permutation
    game = perm // 4
    assert np.bincount(game, minlength=8).max() == 4  # balanced shards
    assert a2a_volume(top, game, 8) <= a2a_volume(top, rr, 8)
