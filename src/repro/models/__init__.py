"""Pure-functional model stack for the 10 assigned architectures."""
from .config import ModelConfig, MoEConfig, MLAConfig, SSMConfig  # noqa: F401
from .lm import (init_params, abstract_params, forward, forward_train,  # noqa: F401
                 prefill, decode_step, init_cache, layer_groups,
                 param_count, lm_loss)
