"""mamba2-130m [ssm]: 24L d_model=768, attn-free, SSD d_state=128,
vocab=50280 (padded to 50432).  [arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24, d_ff=0,
    vocab=50280, head_dim=64,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128),
    sub_quadratic=True,
)
