"""deepseek-v3-671b [moe]: 61L d_model=7168 128H MLA d_ff=2048(expert)
vocab=129280, 1 shared + 256 routed top-8, first 3 layers dense.
[arXiv:2412.19437; hf]"""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab=129280, head_dim=128,
    mla=MLAConfig(q_lora=1536, kv_lora=512, nope_dim=128, rope_dim=64,
                  v_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  softmax_after_topk=True, first_k_dense=3),
)
