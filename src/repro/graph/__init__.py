"""Distributed vertex-cut graph engine (the paper's PowerGraph deployment)."""
from .partition import (PartitionLayout, build_layout,  # noqa: F401
                        build_layout_reference)
from .engine import (GASProgram, CC_PROGRAM, pagerank_program,  # noqa: F401
                     simulate_gas, simulate_pagerank, simulate_cc,
                     shard_map_gas, shard_map_pagerank, shard_map_cc,
                     gas_step_for_dryrun, pagerank_step_for_dryrun,
                     reference_pagerank, reference_cc)
