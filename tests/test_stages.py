"""The stage protocol (repro.core.stages) and the retired entry points.

The refactor's contract: `run_clugp_body` is the ONLY place the cluster →
contract → game → transform sequence exists, the deprecated PR 5 entry
points (`clugp_partition` / `clugp_partition_parallel`) are gone from the
tree, and the `cfg.unroll` knob is a pure lowering choice.
"""
import numpy as np
import pytest

from repro.core import CLUGPConfig, partition, web_graph


@pytest.fixture(scope="module")
def graph10():
    return web_graph(scale=10, edge_factor=6, seed=3)


# ------------------------------------------------- retired entry points

def test_pr5_shims_removed_from_api():
    """`clugp_partition` / `clugp_partition_parallel` warned for three
    PRs; they are deleted, not shimmed."""
    import repro.core as core
    import repro.core.partitioner as partitioner
    import repro.core.pipeline as pipeline
    for mod in (core, partitioner, pipeline):
        assert not hasattr(mod, "clugp_partition"), mod.__name__
        assert not hasattr(mod, "clugp_partition_parallel"), mod.__name__


def test_no_in_tree_caller_references_pr5_shims():
    """No *identifier* reference to the removed names anywhere in tree —
    now the DEPRECATED-API lint rule (AST-based, so docstrings and the
    ``hasattr(mod, "clugp_partition")`` strings above stop tripping the
    old substring grep)."""
    from repro.analysis import run_lint
    from repro.analysis.rules import DeprecatedApi

    report = run_lint(rules=[DeprecatedApi()])
    removed = [f for f in report.violations
               if not f.key.startswith("comm_bytes_")]
    assert removed == [], [f.location for f in removed]


def test_new_api_does_not_warn(graph10):
    import warnings

    g = graph10
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=4),
                  backend="np")


# ------------------------------------------------------------- one body

def test_single_pipeline_body_shared_by_strategies():
    """Structural guard for the refactor's headline: the cluster →
    contract → game → transform sequence exists exactly once
    (stages.run_clugp_body), and every strategy routes through it."""
    import inspect

    from repro.analysis import run_lint
    from repro.analysis.rules import StagePlumb
    from repro.core import partitioner, stages

    # strategies may not call stage internals directly — only the body
    # (the STAGE-PLUMB lint rule; run here so a -k test run still guards)
    report = run_lint(rules=[StagePlumb()])
    assert report.ok, report.format()
    src = inspect.getsource(partitioner)
    assert src.count("run_clugp_body") >= 3   # np, np-nodes, jit, sharded
    body = inspect.getsource(stages.run_clugp_body)
    for stage in ("stages.cluster", "stages.contract", "stages.game",
                  "stages.transform"):
        assert stage in body


def test_np_nodes_restream_trace_recorded(graph10):
    """The shared restream loop now records the RF trace for the host
    combine too (monotone like the single-stream trace)."""
    g = graph10
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig(k=8, restream=1), backend="np", nodes=3)
    trace = res.stats["restream_rf_trace"]
    assert len(trace) == 2 and trace[1] < trace[0]


# ------------------------------------------------------------- unroll knob

def test_unroll_is_bit_identical_on_jit(graph10):
    """cfg.unroll only changes the clustering scan's lowering — the whole
    deterministic pipeline (greedy game + restream) is bit-identical."""
    g = graph10
    base = partition(g.src, g.dst, g.num_vertices,
                     CLUGPConfig(k=8, game=False, restream=1),
                     backend="jit")
    unrolled = partition(g.src, g.dst, g.num_vertices,
                         CLUGPConfig(k=8, game=False, restream=1, unroll=2),
                         backend="jit")
    np.testing.assert_array_equal(base.assign, unrolled.assign)
    np.testing.assert_array_equal(base.clustering.clu,
                                  unrolled.clustering.clu)


def test_unroll_ignored_by_host_oracle(graph10):
    g = graph10
    a = partition(g.src, g.dst, g.num_vertices,
                  CLUGPConfig(k=4, game=False), backend="np").assign
    b = partition(g.src, g.dst, g.num_vertices,
                  CLUGPConfig(k=4, game=False, unroll=2),
                  backend="np").assign
    np.testing.assert_array_equal(a, b)
