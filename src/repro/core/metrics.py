"""Partition-quality metrics (paper §II-B).

- replication factor  RF = (1/|V|) Σ_v |P(v)|   (Eq. 1 objective)
- relative load balance  k · max|p_i| / |E|     (Eq. 1 constraint)
"""
from __future__ import annotations

import numpy as np


def replication_factor(src: np.ndarray, dst: np.ndarray,
                       assign: np.ndarray, num_vertices: int,
                       k: int) -> float:
    """Σ_p |distinct vertices in p| / |V| — memory-light (no V×k table)."""
    total = 0
    order = np.argsort(assign, kind="stable")
    s, d, a = src[order], dst[order], assign[order]
    bounds = np.searchsorted(a, np.arange(k + 1))
    for p in range(k):
        lo, hi = bounds[p], bounds[p + 1]
        if hi > lo:
            total += np.unique(np.concatenate([s[lo:hi], d[lo:hi]])).shape[0]
    return total / float(num_vertices)


def vertex_partition_counts(src: np.ndarray, dst: np.ndarray,
                            assign: np.ndarray, num_vertices: int,
                            k: int) -> np.ndarray:
    """|P(v)| per vertex (used by the graph engine's mirror tables)."""
    counts = np.zeros(num_vertices, dtype=np.int32)
    order = np.argsort(assign, kind="stable")
    s, d, a = src[order], dst[order], assign[order]
    bounds = np.searchsorted(a, np.arange(k + 1))
    for p in range(k):
        lo, hi = bounds[p], bounds[p + 1]
        if hi > lo:
            verts = np.unique(np.concatenate([s[lo:hi], d[lo:hi]]))
            counts[verts] += 1
    return counts


def load_balance(assign: np.ndarray, k: int) -> float:
    sizes = np.bincount(assign, minlength=k)
    return float(k * sizes.max() / max(1, assign.shape[0]))


def partition_sizes(assign: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(assign, minlength=k).astype(np.int64)


def cut_edges(src_part: np.ndarray, dst_part: np.ndarray) -> int:
    """Edges whose endpoint *vertices* live in different partitions
    (cluster/partition-level cut used by the game objective)."""
    return int(np.sum(src_part != dst_part))


def summarize(src: np.ndarray, dst: np.ndarray, assign: np.ndarray,
              num_vertices: int, k: int) -> dict:
    return {
        "rf": replication_factor(src, dst, assign, num_vertices, k),
        "balance": load_balance(assign, k),
        "sizes": partition_sizes(assign, k).tolist(),
    }
