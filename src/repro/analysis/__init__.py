"""Static-analysis subsystem: machine-checked architecture guardrails.

Two layers:

- **Source lint** (``repro.analysis.lint`` + ``repro.analysis.rules``):
  an AST rule engine over the tree with repo-specific rules —
  RAW-COLLECTIVE (mesh-facing code goes through ``repro.dist``, not raw
  ``lax`` collectives), STAGE-PLUMB (strategies may not re-plumb stage
  internals), SESSION-BYPASS (launchers/examples/benchmarks drive
  ``GraphSession``, not hand-wired partition → layout → engine chains),
  DEPRECATED-API (no calls to the retired ``comm_bytes_*`` shims or the
  removed ``clugp_partition*`` entry points) and JIT-PURITY (no host
  clocks/RNG inside traced code paths).  Findings check against the
  tracked allowlist (``repro.analysis.allowlist``) whose per-entry counts
  may only burn down.

- **IR analyzers** (``repro.analysis.ir``): reusable jaxpr/HLO passes —
  the post-SPMD collective-bytes / collective-permute parsers (the
  ``launch.dryrun`` gates are clients), a retrace counter, a dtype-drift
  check, a loop-carried scatter-copy detector (the XLA:CPU 542 µs/edge
  class of bug) and an unreduced-divergence check for shard_map bodies.

CLI: ``python -m repro.analysis --check [--ir]`` — runs the lint (and
the IR self-audit with ``--ir``), writes ``results/ANALYSIS.json`` for
the CI trend gate, and exits non-zero on any non-allowlisted finding.

This module stays import-light (no jax) so the lint path is fast; import
``repro.analysis.ir`` explicitly for the jaxpr/HLO passes.
"""
from .lint import (Allow, Finding, Report, Rule,  # noqa: F401
                   lint_file, repo_root, run_lint)
