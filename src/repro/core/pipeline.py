"""CLUGP configuration/result types.

The three-pass pipeline body itself lives in ``repro.core.stages``
(``run_clugp_body`` — one parametric body for every backend) and the
strategy wrappers in ``repro.core.partitioner`` (``partition``).  This
module keeps the shared types:

- ``CLUGPConfig`` — frozen (hashable) so device strategies can pass it
  straight through ``jax.jit`` static args and cache keys, and the
  ``GraphSession`` façade can serialize it (`repro.session`).
  Ablations: ``split=False`` (CLUGP-S), ``game=False`` (CLUGP-G).
  ``restream > 0`` re-consumes the stream that many extra times with the
  previous pass's realized vertex→partition majority as the prior
  (prioritized restreaming, beyond the paper).  ``unroll`` unrolls the
  blocked clustering scan's inner per-edge loop (2 = the ROADMAP
  headroom knob; lowering-only, bit-identical results).
- ``CLUGPResult`` — assignment + per-pass state + stats.

The seed's host entry points (deprecated for three PRs) are gone — call
``partition(..., backend="np")`` or drive the chain through
``repro.session.GraphSession``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .clustering import ClusteringResult
from .game import ClusterGraph


@dataclass(frozen=True)
class CLUGPConfig:
    k: int
    tau: float = 1.0
    vmax: float | None = None          # default |E|/k (paper §VI-A)
    split: bool = True                 # CLUGP-S ablation switch
    game: bool = True                  # CLUGP-G ablation switch
    split_degree_factor: float = 0.0   # 0 = paper-faithful; 4 = optimized
    batch_size: int = 6400             # paper §VI-A default
    max_rounds: int = 64
    relative_weight: float | None = None   # Fig. 11b sweep; None ⇒ λ_max
    effective_sizes: bool = False      # beyond-paper: balance |c_i|+boundary
    restream: int = 0                  # extra prioritized-restream passes
    kernel: str = "auto"               # game sweep: "auto" | "pallas" | "xla"
    cluster_kernel: str = "auto"       # clustering scatter: "auto"|"pallas"|"xla"
    unroll: int = 1                    # clustering inner-scan unroll (1 = off)
    seed: int = 0

    @staticmethod
    def paper(k: int, **kw) -> "CLUGPConfig":
        """Paper-faithful profile (§VI-A defaults)."""
        return CLUGPConfig(k=k, **kw)

    @staticmethod
    def optimized(k: int, **kw) -> "CLUGPConfig":
        """Beyond-paper profile: the game balances *effective* cluster sizes
        (intra + expected landing of boundary edges) so transform loads match
        game loads — cuts the overflow-spill fraction 2-4× (EXPERIMENTS.md
        §Perf-partitioner); τ=1.1 gives the spill headroom Fig. 11a studies."""
        kw.setdefault("tau", 1.1)
        kw.setdefault("effective_sizes", True)
        return CLUGPConfig(k=k, **kw)


@dataclass
class CLUGPResult:
    assign: np.ndarray
    clustering: ClusteringResult | None
    cluster_graph: ClusterGraph | None
    cluster_assign: np.ndarray | None
    game_rounds: int
    stats: dict = field(default_factory=dict)
