"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) — the kernel body
executes in Python for correctness validation; on TPU the same call sites
pass interpret=False and get the compiled Mosaic kernel.
"""
from __future__ import annotations

from functools import partial

import jax

from .flash_attention import flash_attention as _flash
from .game_bestresponse import game_bestresponse as _gbr
from .ell_spmv import ell_spmv as _spmv
from .cluster_scatter import cluster_scatter as _cscat

_ON_TPU = jax.default_backend() == "tpu"
DEFAULT_INTERPRET = not _ON_TPU


@partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                   "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128,
                    interpret: bool = DEFAULT_INTERPRET):
    return _flash(q, k, v, causal=causal, block_q=block_q,
                  block_kv=block_kv, interpret=interpret)


@partial(jax.jit, static_argnames=("k", "block_m", "interpret"))
def game_best_response(aff, sizes, row_tot, cur, loads, lam,
                       k: int | None = None, block_m: int = 256,
                       interpret: bool = DEFAULT_INTERPRET):
    return _gbr(aff, sizes, row_tot, cur, loads, lam=lam, k=k,
                block_m=block_m, interpret=interpret)


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def ell_spmv(vals, cols, x, block_m: int = 256,
             interpret: bool = DEFAULT_INTERPRET):
    return _spmv(vals, cols, x, block_m=block_m, interpret=interpret)


@partial(jax.jit, static_argnames=("allow_split", "split_degree_factor",
                                   "interpret"))
def cluster_scatter(ints, buf, scal, vmax, allow_split: bool = True,
                    split_degree_factor: float = 0.0,
                    interpret: bool = DEFAULT_INTERPRET):
    return _cscat(ints, buf, scal, vmax, allow_split=allow_split,
                  split_degree_factor=split_degree_factor,
                  interpret=interpret)
