"""Distribution substrate: named-axis sharding rules, sequence-parallel
decode, error-feedback gradient compression, fault-tolerant training loop,
and pipeline parallelism.

This package is the single place device meshes touch model code: models
tag arrays with logical axis names (``shard(x, "batch", "seq", ...)``)
and the active rule table (``use_rules``) maps tags onto mesh axes.
Everything runs on CPU under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the same code
path the production pod meshes lower through.
"""
from . import _compat  # noqa: F401  (installs jax.shard_map on old jax)
from . import collectives  # noqa: F401  (axis-wide reduction helpers)
from .halo import (DenseExchange, HaloExchange,  # noqa: F401
                   QuantizedHaloExchange, get_exchange)
from .sharding import (CP_SERVE_RULES, MULTI_POD_RULES,  # noqa: F401
                       SINGLE_POD_RULES, shard, use_rules)
