"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from . import ops, ref  # noqa: F401
from .ops import (flash_attention, game_best_response, ell_spmv,  # noqa: F401
                  cluster_scatter)
