"""IR analyzers: reusable jaxpr / post-SPMD-HLO passes.

The HLO parsers (``collective_bytes`` / ``collective_permute_count``)
moved here from ``repro.launch.dryrun`` — the dry-run gates are now
clients, as is any test that wants to assert on compiled wire traffic.
The jaxpr passes catch whole *classes* of regression the unit tests
only catch instance-by-instance:

- ``retrace_count`` — compile-cache churn (the k-sweep promise is ONE
  trace for any number of k values);
- ``dtype_drift`` — silent same-kind widenings (f32→f64 under x64,
  f16→f32 re-promotion of a quantized wire payload, s32→s64 index
  inflation) that double comm/memory without changing results;
- ``scatter_copy_sites`` — computed-index scatters carried through a
  loop body, the XLA:CPU buffer-copy-per-iteration class that cost
  542 µs/edge before the arithmetic one-hot rewrite (EXPERIMENTS.md
  §Perf-partitioner);
- ``unreduced_divergence`` — shard_map outputs claimed replicated while
  the body computes an axis-varying value that never crossed a
  reduction (the bug ``check_rep=False`` stops catching).

Everything here imports jax lazily-enough to keep ``repro.analysis``
(the lint layer) jax-free.
"""
from __future__ import annotations

import re

import numpy as np

import jax

# ---------------------------------------------------------------------------
# Post-SPMD HLO text parsers (moved verbatim from launch/dryrun.py)
# ---------------------------------------------------------------------------

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device output bytes of every collective instruction, by kind.

    Anchored on the instruction name left of ``=`` and summing every
    ``dtype[dims]`` in the output type — which may be a tuple:  XLA:CPU
    lowers ``all_to_all`` to ``(f32[1,H], …×k) all-to-all(…)``.  Async
    ``-done`` halves are skipped (their output repeats the start's)."""
    out = {}
    for line in hlo_text.splitlines():
        head, sep, rest = line.partition("=")
        if not sep:
            continue
        name = head.strip().removeprefix("ROOT").strip().lstrip("%")
        kind = next((kd for kd in COLLECTIVE_KINDS
                     if name.startswith(kd)), None)
        if kind is None or "-done" in name:
            continue
        idx = rest.find(kind)
        out_type = rest[:idx] if idx >= 0 else rest
        shapes = SHAPE_RE.findall(out_type)
        if "-start" in name and len(shapes) > 1:
            # async start tuples are (aliased operand, result, …): the
            # first element is the input, not wire traffic
            shapes = shapes[1:]
        b = 0
        for dt, dims in shapes:
            size = 1
            for d in dims.split(","):
                if d:
                    size *= int(d)
            b += size * DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def collective_permute_count(hlo_text: str) -> int:
    """Number of collective-permute instructions in the post-SPMD HLO.

    Same name-anchoring as ``collective_bytes`` (instruction name left of
    ``=``, async ``-done`` halves skipped so a start/done pair counts
    once).  The overlapped ragged body must keep this count identical to
    the phase-ordered body: overlap re-orders compute around the k−1
    ring hops, it must never add or drop a hop."""
    n = 0
    for line in hlo_text.splitlines():
        head, sep, _ = line.partition("=")
        if not sep:
            continue
        name = head.strip().removeprefix("ROOT").strip().lstrip("%")
        if name.startswith("collective-permute") and "-done" not in name:
            n += 1
    return n


# ---------------------------------------------------------------------------
# Retrace detection (generalizes core.partitioner.sweep_trace_count)
# ---------------------------------------------------------------------------

def trace_counter(fn):
    """Wrap ``fn`` so each *trace* (Python execution under jit) bumps a
    counter; compiled-cache hits don't re-enter Python.  Returns
    ``(wrapped, count)`` — jit the wrapped function, drive it, then call
    ``count()``."""
    n = {"traces": 0}

    def wrapped(*args, **kwargs):
        n["traces"] += 1
        return fn(*args, **kwargs)

    return wrapped, (lambda: n["traces"])


def retrace_count(fn, arg_sets, *, jit_kwargs=None) -> int:
    """Trace count of jitted ``fn`` driven over every ``args`` tuple in
    ``arg_sets``.  A shape-stable function must report 1 no matter how
    many call sites hit it — 1-per-call means an arg is leaking into the
    trace key (python scalar k, a weak-typed constant, a non-hashable
    static)."""
    wrapped, count = trace_counter(fn)
    jfn = jax.jit(wrapped, **(jit_kwargs or {}))
    for args in arg_sets:
        out = jfn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x, out)
    return count()


# ---------------------------------------------------------------------------
# Jaxpr traversal helpers
# ---------------------------------------------------------------------------

def _as_jaxpr(obj):
    """ClosedJaxpr | Jaxpr → Jaxpr."""
    return obj.jaxpr if hasattr(obj, "jaxpr") else obj


def _sub_jaxprs(eqn):
    """Every nested jaxpr hanging off an eqn's params (scan/while/cond
    bodies, pjit/closed_call jaxprs, shard_map bodies, custom_* calls)."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if hasattr(v, "eqns"):              # raw Jaxpr
                yield v
            elif hasattr(v, "jaxpr") and hasattr(_as_jaxpr(v), "eqns"):
                yield _as_jaxpr(v)              # ClosedJaxpr


def iter_eqns(jaxpr, path=()):
    """Depth-first (eqn, path) over a jaxpr and every nested body; the
    path is the chain of enclosing primitive names."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn, path
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, path + (eqn.primitive.name,))


def make_jaxpr(fn, *args, **kwargs):
    """Thin alias so callers don't import jax just for this."""
    return jax.make_jaxpr(fn)(*args, **kwargs)


# ---------------------------------------------------------------------------
# Dtype drift
# ---------------------------------------------------------------------------

def dtype_drift(jaxpr_or_fn, *args, allow=()) -> list[dict]:
    """Same-kind widening conversions anywhere in the jaxpr.

    f32→f64 (x64 leaking in), f16/bf16→f32 (a quantized wire payload
    getting re-promoted before the collective), s32→s64 (index
    inflation) — each doubles bytes silently.  *Kind changes* are not
    drift: u8→f32 is deliberate dequantization, f32→s32 is a cast.
    ``allow`` is an iterable of ``("float16", "float32")``-style name
    pairs to exempt."""
    jaxpr = (jaxpr_or_fn if hasattr(jaxpr_or_fn, "eqns")
             or hasattr(jaxpr_or_fn, "jaxpr")
             else jax.make_jaxpr(jaxpr_or_fn)(*args))
    allowed = {(str(a), str(b)) for a, b in allow}
    sites = []
    for eqn, path in iter_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        old = np.dtype(eqn.invars[0].aval.dtype)
        new = np.dtype(eqn.params["new_dtype"])
        if old.kind == new.kind and new.itemsize > old.itemsize \
                and (old.name, new.name) not in allowed:
            sites.append({
                "old": old.name, "new": new.name,
                "shape": tuple(eqn.invars[0].aval.shape),
                "path": "/".join(path) or "<top>",
            })
    return sites


# ---------------------------------------------------------------------------
# Loop-carried computed-index scatters
# ---------------------------------------------------------------------------

LOOP_PRIMITIVES = frozenset({"scan", "while", "while_loop", "fori_loop"})
SCATTER_PRIMITIVES = frozenset({
    "scatter", "scatter-add", "scatter_add", "scatter_mul", "scatter_min",
    "scatter_max",
})


def scatter_copy_sites(jaxpr_or_fn, *args) -> list[dict]:
    """Computed-index scatters inside loop bodies.

    XLA:CPU can't fuse a scatter whose indices are data-dependent when
    it sits in a loop-carried position: each iteration pays a buffer
    copy plus a scatter kernel call.  The transform pass paid
    542 µs/edge to exactly this before the arithmetic one-hot rewrite
    got it to 9.9 µs/edge — a ``jnp.where(arange(k) == p, …)`` select
    is the fix, not an allowlist entry.

    "Computed" means the index *dataflows from a loop-varying input*
    (the scan carry/xs, the while carry) — a static offset reaches the
    scatter through consts/literals only and each iteration hits the
    same slot, which XLA handles as a dynamic-update-slice."""
    jaxpr = (jaxpr_or_fn if hasattr(jaxpr_or_fn, "eqns")
             or hasattr(jaxpr_or_fn, "jaxpr")
             else jax.make_jaxpr(jaxpr_or_fn)(*args))
    sites = []

    def loop_varying_seed(jaxpr, eqn_name, params):
        if eqn_name == "scan":
            # invars = [consts…, carry…, xs…]; consts are loop-invariant
            return set(jaxpr.invars[params.get("num_consts", 0):])
        return set(jaxpr.invars)

    def visit(jaxpr, path, dyn):
        jaxpr = _as_jaxpr(jaxpr)
        dyn = set(dyn)
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            in_dyn = any(not isinstance(v, jax.core.Literal) and v in dyn
                         for v in eqn.invars)
            in_loop = any(p in LOOP_PRIMITIVES for p in path)
            if in_loop and len(eqn.invars) > 1 and any(
                    name.startswith(p) for p in SCATTER_PRIMITIVES):
                idx = eqn.invars[1]
                if not isinstance(idx, jax.core.Literal) and idx in dyn:
                    sites.append({
                        "primitive": name,
                        "operand_shape": tuple(eqn.invars[0].aval.shape),
                        "path": "/".join(path),
                    })
            if in_dyn:
                dyn.update(eqn.outvars)
            for sub in _sub_jaxprs(eqn):
                sub_j = _as_jaxpr(sub)
                if name in LOOP_PRIMITIVES:
                    seed = loop_varying_seed(sub_j, name, eqn.params)
                else:
                    # non-loop body (cond branch, pjit): inherit the
                    # caller's dynamicity positionally when shapes line
                    # up, else stay conservative and taint everything
                    ins = eqn.invars[-len(sub_j.invars):] \
                        if len(sub_j.invars) <= len(eqn.invars) else None
                    seed = ({bv for bv, ov in zip(sub_j.invars, ins)
                             if not isinstance(ov, jax.core.Literal)
                             and ov in dyn}
                            if ins is not None else set(sub_j.invars))
                visit(sub_j, path + (name,), seed)

    visit(jaxpr, (), set())
    return sites


# ---------------------------------------------------------------------------
# Unreduced divergence across shard_map outputs
# ---------------------------------------------------------------------------

# collectives that *clear* per-device variance over the reduced axis …
REDUCING_PRIMITIVES = frozenset({"psum", "pmax", "pmin", "pmean",
                                 "all_gather", "all_gather_invariant"})
# … and ones that keep values device-varying even though they communicate
VARIANCE_PRESERVING = frozenset({"ppermute", "all_to_all", "pshuffle"})


def _eqn_axes(eqn):
    axes = eqn.params.get("axes") or eqn.params.get("axis_name") or ()
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    return set(axes)


def _body_divergence(inner, in_names, out_names, mesh_axes):
    varying: set = set()

    def is_varying(atom):
        return not isinstance(atom, jax.core.Literal) and atom in varying

    for var, names in zip(inner.invars, in_names):
        if names:               # sharded input: per-device slice differs
            varying.add(var)
    for eqn in inner.eqns:
        name = eqn.primitive.name
        if name == "axis_index":
            out_varying = True
        elif name in REDUCING_PRIMITIVES:
            axes = _eqn_axes(eqn)
            # reducing over the mesh axis clears variance; reducing some
            # *other* axis (vmapped name) does not
            out_varying = (any(is_varying(v) for v in eqn.invars)
                           and not (axes & mesh_axes or not axes))
        elif name in VARIANCE_PRESERVING:
            out_varying = any(is_varying(v) for v in eqn.invars)
        else:
            # default (including nested scan/cond bodies, conservatively):
            # any varying input makes every output varying
            out_varying = any(is_varying(v) for v in eqn.invars)
        if out_varying:
            varying.update(eqn.outvars)
    out = []
    for i, (var, names) in enumerate(zip(inner.outvars, out_names)):
        if not names and is_varying(var):
            out.append(i)
    return out


def unreduced_divergence(jaxpr_or_fn, *args) -> list[dict]:
    """shard_map outputs declared replicated (empty out_names) whose
    value is axis-varying and never crossed a reduction.

    This is the divergence class ``check_rep=False`` (which the ragged
    wires require) stops catching at runtime: every device returns a
    *different* array through an out_spec that promises they're all the
    same, and downstream code silently reads device 0's copy.  Returns
    one record per diverging output with the shard_map's position path.
    """
    jaxpr = (jaxpr_or_fn if hasattr(jaxpr_or_fn, "eqns")
             or hasattr(jaxpr_or_fn, "jaxpr")
             else jax.make_jaxpr(jaxpr_or_fn)(*args))
    findings = []
    for eqn, path in iter_eqns(jaxpr):
        if eqn.primitive.name != "shard_map":
            continue
        inner = _as_jaxpr(eqn.params["jaxpr"])
        mesh = eqn.params.get("mesh")
        mesh_axes = set(getattr(mesh, "axis_names", ()) or ())
        in_names = [dict(n) for n in eqn.params.get("in_names", ())]
        out_names = [dict(n) for n in eqn.params.get("out_names", ())]
        for i in _body_divergence(inner, in_names, out_names, mesh_axes):
            findings.append({
                "output": i,
                "aval": str(inner.outvars[i].aval),
                "path": "/".join(path) or "<top>",
            })
    return findings
