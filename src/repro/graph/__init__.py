"""Distributed vertex-cut graph engine (the paper's PowerGraph deployment)."""
from .partition import (PartitionLayout, build_layout,  # noqa: F401
                        build_layout_reference)
from .engine import (GASProgram, FusedGAS, fuse_programs,  # noqa: F401
                     CC_PROGRAM, CC_SENTINEL, DEGREE_PROGRAM,
                     pagerank_program,
                     labelprop_program, sssp_program, bfs_program,
                     centrality_program, ppr_program,
                     PROGRAM_NAMES, get_program, default_num_seeds,
                     simulate_gas, simulate_pagerank, simulate_cc,
                     simulate_gas_many,
                     shard_map_gas, shard_map_pagerank, shard_map_cc,
                     shard_map_gas_many,
                     gas_step_for_dryrun, pagerank_step_for_dryrun,
                     reference_pagerank, reference_cc, reference_labelprop,
                     reference_sssp, reference_bfs, reference_degree,
                     reference_centrality, reference_ppr)
