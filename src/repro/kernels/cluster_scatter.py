"""Clustering fused-scatter — Pallas TPU kernel for the blocked stream scan.

Paper Alg. 2 is a sequential per-edge transition over vertex→cluster /
degree / volume tables.  The blocked scan in ``core.clustering`` localizes
each 128-edge block into one KB-sized fused table ``buf`` ([0, 2B) vertex
slot → local cluster slot, [2B, 4B) streamed degree, [4B, 10B) cluster
volumes) and runs the exact transition per edge with two fused gathers +
ONE fused 8-lane scatter.  XLA:CPU still charges every computed-index
scatter inside a loop body a buffer copy + kernel call (~1.3 µs measured —
the 9.9 µs/edge floor in EXPERIMENTS.md); this kernel keeps the whole
block table resident in kernel memory instead, so the 8-lane scatter is
eight register→memory read-modify-writes with no buffer copy at all.

``edge_decisions`` is the per-edge register math, shared VERBATIM with the
XLA scan path (``core.clustering._edge_step_local`` composes the same
function) — the two strategies are bit-identical by construction, and the
equivalence suite pins it.

``vmax`` ships as a (1,)-shaped input (like ``lam`` in game_bestresponse):
the sharded backend derives each device's V_max from its slice's real edge
count, so it is data-dependent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def edge_decisions(cu0, cv0, d0, d1, vg0, vg1, live, nid, nid0,
                   seen_v, seen_deg, *, vmax, allow_split: bool,
                   split_degree_factor: float, B: int):
    """One streamed edge's allocation–splitting–migration decisions in
    scalar registers (paper Alg. 2 lines 3–26 + the §IV-A same-cluster tie
    rule and migration post-guard).

    Inputs are the six gathered table entries (endpoint cluster slots,
    streamed degrees, and their clusters' volumes) plus the carried
    counters; outputs are the updated counters, the endpoints' new cluster
    slots, and the ≤4 volume-slot (index, delta) pairs of the fused
    scatter — the caller owns the actual gathers/scatter, so the XLA scan
    and the Pallas kernel share every decision bit."""
    scrap = 6 * B - 1                 # top fresh slot absorbs dead writes

    def sel(p, a0, a1, a2, a3):
        return jnp.where(p == 0, a0, jnp.where(p == 1, a1,
                         jnp.where(p == 2, a2, a3)))

    def bump(p, x, a0, a1, a2, a3):
        return (a0 + jnp.where(p == 0, x, 0), a1 + jnp.where(p == 1, x, 0),
                a2 + jnp.where(p == 2, x, 0), a3 + jnp.where(p == 3, x, 0))

    du = d0 + 1                       # degrees AFTER line 6's increment
    dv = d1 + 1
    duf = du.astype(jnp.float32)
    dvf = dv.astype(jnp.float32)

    # allocation (lines 3-5): u first, then v
    preu, prev = cu0 >= 0, cv0 >= 0
    id0 = jnp.where(preu, cu0, 2 * B + (nid - nid0))
    nid = nid + (live & ~preu).astype(jnp.int32)
    id1 = jnp.where(prev, cv0, 2 * B + (nid - nid0))
    nid = nid + (live & ~prev).astype(jnp.int32)
    same = id0 == id1
    seen_v = seen_v + (live & ~preu).astype(jnp.int32) \
        + (live & ~prev).astype(jnp.int32)
    seen_deg = seen_deg + 2 * live.astype(jnp.int32)
    if split_degree_factor > 0.0:
        dthr = split_degree_factor * seen_deg.astype(jnp.float32) \
            / jnp.maximum(seen_v, 1).astype(jnp.float32)
    else:
        dthr = jnp.float32(0.0)

    # register volumes (v2/v3 are the fresh split slots, created empty)
    v0 = jnp.where(preu, vg0, 0)
    v1 = jnp.where(prev & ~same, vg1, 0)
    v2 = v3 = jnp.int32(0)
    i0, i1 = v0, v1
    lvflag = live.astype(jnp.int32)
    pu = jnp.int32(0)
    pv = jnp.where(same, 0, 1)
    v0, v1, v2, v3 = bump(pu, lvflag, v0, v1, v2, v3)
    v0, v1, v2, v3 = bump(pv, lvflag, v0, v1, v2, v3)

    if allow_split:
        # same-cluster overflow → split only the higher-degree endpoint;
        # different clusters → split u first (lines 8-13), then v (14-18)
        x_is_u = du >= dv
        t1_is_u = jnp.where(same, x_is_u, True)
        pt1 = jnp.where(t1_is_u, pu, pv)
        dt1 = jnp.where(t1_is_u, du, dv)
        fire1 = live & (sel(pt1, v0, v1, v2, v3) >= vmax) \
            & (jnp.where(t1_is_u, duf, dvf) >= dthr)
        f1 = fire1.astype(jnp.int32)
        v0, v1, v2, v3 = bump(pt1, -dt1 * f1, v0, v1, v2, v3)
        v2 = v2 + dt1 * f1
        pu = jnp.where(fire1 & t1_is_u, 2, pu)
        pv = jnp.where(fire1 & ~t1_is_u, 2, pv)
        id2 = 2 * B + (nid - nid0)
        nid = nid + f1
        fire2 = live & ~same & (sel(pv, v0, v1, v2, v3) >= vmax) \
            & (dvf >= dthr)
        f2 = fire2.astype(jnp.int32)
        v0, v1, v2, v3 = bump(pv, -dv * f2, v0, v1, v2, v3)
        v3 = v3 + dv * f2
        id3 = 2 * B + (nid - nid0)
        nid = nid + f2
        pv = jnp.where(fire2, 3, pv)
    else:
        fire1 = fire2 = live & False
        t1_is_u = fire1
        id2 = id3 = jnp.int32(scrap)

    # migration (lines 20-26) with the post-guard
    vu_cur = sel(pu, v0, v1, v2, v3)
    vv_cur = sel(pv, v0, v1, v2, v3)
    both_room = live & (pu != pv) & (vu_cur < vmax) & (vv_cur < vmax)
    u_moves = both_room & (vu_cur <= vv_cur) & (vv_cur + du < vmax)
    v_moves = both_room & (vu_cur > vv_cur) & (vu_cur + dv < vmax)
    mu = u_moves.astype(jnp.int32)
    mv = v_moves.astype(jnp.int32)
    v0, v1, v2, v3 = bump(pu, -du * mu + dv * mv, v0, v1, v2, v3)
    v0, v1, v2, v3 = bump(pv, du * mu - dv * mv, v0, v1, v2, v3)
    pu, pv = (jnp.where(u_moves, pv, pu), jnp.where(v_moves, pu, pv))

    newu = jnp.where(live, sel(pu, id0, id1, id2, id3), cu0)
    newv = jnp.where(live, sel(pv, id0, id1, id2, id3), cv0)
    vol_ids = (jnp.clip(jnp.where(live, id0, scrap), 0, scrap),
               jnp.clip(jnp.where(same, scrap, id1), 0, scrap),
               jnp.clip(jnp.where(fire1, id2, scrap), 0, scrap),
               jnp.clip(jnp.where(fire2, id3, scrap), 0, scrap))
    vol_deltas = (v0 - i0, v1 - i1, v2, v3)
    fire_u = fire1 & t1_is_u
    fire_v = (fire1 & ~t1_is_u) | fire2
    packed = (fire_u.astype(jnp.int32) + 2 * fire_v.astype(jnp.int32))
    return nid, seen_v, seen_deg, newu, newv, vol_ids, vol_deltas, packed


def _cluster_kernel(ints_ref, buf_ref, scal_ref, vmax_ref,
                    buf_out, scal_out, pk_out, *, B: int,
                    allow_split: bool, split_degree_factor: float):
    # the whole block table stays resident in the output block for the
    # full edge loop — the fused 8-lane scatter becomes eight in-memory
    # read-modify-writes (duplicate lanes accumulate, matching .at[].add)
    buf_out[...] = buf_ref[...]
    vmax = vmax_ref[0]
    scrap = 6 * B - 1

    def body(i, carry):
        nid, nid0, seen_v, seen_deg = carry
        lu = ints_ref[i, 0]
        lv_ = ints_ref[i, 1]
        live = ints_ref[i, 2] != 0
        cu0 = buf_out[lu]
        cv0 = buf_out[lv_]
        d0 = buf_out[2 * B + lu]
        d1 = buf_out[2 * B + lv_]
        vg0 = buf_out[4 * B + jnp.clip(cu0, 0, scrap)]
        vg1 = buf_out[4 * B + jnp.clip(cv0, 0, scrap)]
        (nid, seen_v, seen_deg, newu, newv, vol_ids, vol_deltas,
         packed) = edge_decisions(
            cu0, cv0, d0, d1, vg0, vg1, live, nid, nid0, seen_v, seen_deg,
            vmax=vmax, allow_split=allow_split,
            split_degree_factor=split_degree_factor, B=B)
        lvflag = live.astype(jnp.int32)
        # lane 0 is guarded against lu == lv_ (dead self-loop edges alias
        # the two vertex slots; lane 1 carries the whole pointer update)
        buf_out[lu] = buf_out[lu] + jnp.where(lu != lv_, newu - cu0, 0)
        buf_out[lv_] = buf_out[lv_] + (newv - cv0)
        buf_out[2 * B + lu] = buf_out[2 * B + lu] + lvflag
        buf_out[2 * B + lv_] = buf_out[2 * B + lv_] + lvflag
        for a, dlt in zip(vol_ids, vol_deltas):
            buf_out[4 * B + a] = buf_out[4 * B + a] + dlt
        pk_out[i] = packed
        return (nid, nid0, seen_v, seen_deg)

    nid, nid0, seen_v, seen_deg = jax.lax.fori_loop(
        0, B, body,
        (scal_ref[0], scal_ref[1], scal_ref[2], scal_ref[3]))
    scal_out[0] = nid
    scal_out[1] = nid0
    scal_out[2] = seen_v
    scal_out[3] = seen_deg


def cluster_scatter(ints, buf, scal, vmax, *, allow_split: bool = True,
                    split_degree_factor: float = 0.0,
                    interpret: bool = True):
    """One block of the clustering scan: ``ints`` (B, 3) int32 rows of
    (local u slot, local v slot, live); ``buf`` (10B,) int32 fused block
    table; ``scal`` (4,) int32 = (nid, nid0, seen_v, seen_deg); ``vmax``
    python float or traced scalar.  Returns (buf', scal', packed (B,))
    with ``packed`` the per-edge split events (fire_u + 2·fire_v) —
    bit-identical to the XLA inner scan at any input."""
    B = ints.shape[0]
    assert buf.shape == (10 * B,), (buf.shape, B)
    vmax_arr = jnp.asarray(vmax, jnp.float32).reshape((1,))
    kern = functools.partial(
        _cluster_kernel, B=int(B), allow_split=bool(allow_split),
        split_degree_factor=float(split_degree_factor))
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((B, 3), lambda i: (0, 0)),
            pl.BlockSpec((10 * B,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((10 * B,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((B,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((10 * B,), jnp.int32),
            jax.ShapeDtypeStruct((4,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(ints, jnp.int32), buf, jnp.asarray(scal, jnp.int32),
      vmax_arr)
