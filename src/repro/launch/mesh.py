"""Production mesh construction (assignment §MULTI-POD DRY-RUN).

A FUNCTION, not a module constant — importing this module never touches
jax device state.  Callers that need 512 host devices must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
(launch/dryrun.py does; tests spawn subprocesses)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for multi-device subprocess tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_graph_mesh(k: int):
    """The graph engine's mesh: k partitions on one flat axis."""
    return jax.make_mesh((k,), ("parts",))


def make_stream_mesh(n: int):
    """The sharded partitioner's mesh: n stream slices on one flat axis
    (repro.core.partitioner backend="sharded", paper §III-C)."""
    return jax.make_mesh((n,), ("stream",))
