"""Streaming vertex-cut baselines (paper Table I + §VI competitors).

- ``hashing``  : PowerGraph random edge hashing               (low / low)
- ``dbh``      : degree-based hashing, cut the high-deg end   (low / low)
- ``greedy``   : PowerGraph greedy heuristic                  (high / high)
- ``hdrf``     : High-Degree Replicated First                 (high / high)
- ``mint_like``: quasi-streaming batched game (Mint is closed-source; this
  reimplements its published recipe — edge windows assigned jointly by a
  local game on the window's contracted graph)                (med / med)

All use the *partial degree* seen so far (the streaming setting of HDRF) and
maintain per-vertex partition sets A(v) as packed uint64 bitmasks.
"""
from __future__ import annotations

import numpy as np



def _hash2(u: np.ndarray | int, v: np.ndarray | int, k: int):
    return ((np.uint64(u) * np.uint64(0x9E3779B97F4A7C15)
             ^ np.uint64(v) * np.uint64(0xC2B2AE3D27D4EB4F))
            % np.uint64(k))


def hashing(src, dst, num_vertices, k, seed=0):
    """Random edge placement (PowerGraph's default)."""
    u = src.astype(np.uint64)
    v = dst.astype(np.uint64)
    return (((u * np.uint64(0x9E3779B97F4A7C15))
             ^ (v * np.uint64(0xC2B2AE3D27D4EB4F))
             ^ np.uint64(seed)) % np.uint64(k)).astype(np.int32)


class _PartSets:
    """A(v) as packed bitmasks: (V, ceil(k/64)) uint64."""

    def __init__(self, num_vertices: int, k: int):
        self.words = (k + 63) // 64
        self.bits = np.zeros((num_vertices, self.words), dtype=np.uint64)

    def has(self, v: int, p: int) -> bool:
        return bool((self.bits[v, p >> 6] >> np.uint64(p & 63)) & np.uint64(1))

    def add(self, v: int, p: int) -> None:
        self.bits[v, p >> 6] |= np.uint64(1) << np.uint64(p & 63)

    def mask_list(self, v: int, k: int) -> np.ndarray:
        out = np.zeros(k, dtype=bool)
        w = self.bits[v]
        for i in range(self.words):
            word = int(w[i])
            while word:
                b = word & -word
                out[i * 64 + b.bit_length() - 1] = True
                word ^= b
        return out

    def common(self, u: int, v: int) -> np.ndarray:
        return self.bits[u] & self.bits[v]

    def any(self, v: int) -> bool:
        return bool(self.bits[v].any())


def dbh(src, dst, num_vertices, k, seed=0):
    """Degree-Based Hashing (Xie et al. NeurIPS'14): hash on the lower
    partial-degree endpoint so the high-degree vertex is the one cut."""
    E = src.shape[0]
    deg = np.zeros(num_vertices, dtype=np.int64)
    assign = np.zeros(E, dtype=np.int32)
    MASK = (1 << 64) - 1
    for i in range(E):
        u = int(src[i]); v = int(dst[i])
        deg[u] += 1; deg[v] += 1
        key = u if deg[u] <= deg[v] else v
        assign[i] = ((key * 0x9E3779B97F4A7C15 ^ seed) & MASK) % k
    return assign


def greedy(src, dst, num_vertices, k, seed=0):
    """PowerGraph greedy (Gonzalez et al. OSDI'12) with partial degrees."""
    E = src.shape[0]
    sets = _PartSets(num_vertices, k)
    deg = np.zeros(num_vertices, dtype=np.int64)
    loads = np.zeros(k, dtype=np.int64)
    assign = np.zeros(E, dtype=np.int32)
    for i in range(E):
        u = int(src[i]); v = int(dst[i])
        deg[u] += 1; deg[v] += 1
        common = sets.common(u, v)
        if common.any():
            cand = _mask_to_idx(common, k)
        elif sets.any(u) and sets.any(v):
            # both replicated, disjoint: partitions of the higher-remaining-
            # degree endpoint (streaming proxy: higher partial degree)
            cand = _mask_to_idx(sets.bits[u if deg[u] >= deg[v] else v], k)
        elif sets.any(u):
            cand = _mask_to_idx(sets.bits[u], k)
        elif sets.any(v):
            cand = _mask_to_idx(sets.bits[v], k)
        else:
            cand = np.arange(k)
        p = int(cand[np.argmin(loads[cand])])
        assign[i] = p
        loads[p] += 1
        sets.add(u, p)
        sets.add(v, p)
    return assign


def _mask_to_idx(mask_words: np.ndarray, k: int) -> np.ndarray:
    out = []
    for i, w in enumerate(mask_words):
        word = int(w)
        while word:
            b = word & -word
            out.append(i * 64 + b.bit_length() - 1)
            word ^= b
    return np.asarray(out if out else range(k), dtype=np.int64)


def hdrf(src, dst, num_vertices, k, lam: float = 1.0, eps: float = 1.0,
         seed=0):
    """HDRF (Petroni et al. CIKM'15): replicate high-degree vertices first."""
    E = src.shape[0]
    sets = _PartSets(num_vertices, k)
    deg = np.zeros(num_vertices, dtype=np.int64)
    loads = np.zeros(k, dtype=np.float64)
    assign = np.zeros(E, dtype=np.int32)
    ks = np.arange(k)
    for i in range(E):
        u = int(src[i]); v = int(dst[i])
        deg[u] += 1; deg[v] += 1
        du, dv = deg[u], deg[v]
        theta_u = du / (du + dv)
        theta_v = 1.0 - theta_u
        in_u = _mask_to_bool(sets.bits[u], k)
        in_v = _mask_to_bool(sets.bits[v], k)
        g_u = np.where(in_u, 1.0 + (1.0 - theta_u), 0.0)
        g_v = np.where(in_v, 1.0 + (1.0 - theta_v), 0.0)
        maxl, minl = loads.max(), loads.min()
        c_bal = lam * (maxl - loads) / (eps + maxl - minl)
        score = g_u + g_v + c_bal
        p = int(np.argmax(score))
        assign[i] = p
        loads[p] += 1.0
        sets.add(u, p)
        sets.add(v, p)
    return assign


def _mask_to_bool(mask_words: np.ndarray, k: int) -> np.ndarray:
    out = np.zeros(k, dtype=bool)
    for i, w in enumerate(mask_words):
        word = int(w)
        while word:
            b = word & -word
            out[i * 64 + b.bit_length() - 1] = True
            word ^= b
    return out


def mint_like(src, dst, num_vertices, k, window: int = 4096, seed=0):
    """Quasi-streaming batched game in the spirit of Mint (Hua et al.
    TPDS'19): buffer a window of edges, contract it by shared endpoints into
    micro-clusters, assign each micro-cluster by one best-response round
    against the *global* loads plus a stickiness/affinity term from vertices
    already placed in earlier windows, emit, repeat."""
    E = src.shape[0]
    assign = np.zeros(E, dtype=np.int32)
    loads = np.zeros(k, dtype=np.float64)
    vertex_last = np.full(num_vertices, -1, dtype=np.int64)
    norm = k / max(1.0, float(E))       # load term in units of "edges cut"
    for lo in range(0, E, window):
        hi = min(E, lo + window)
        s, d = src[lo:hi], dst[lo:hi]
        labels = _window_components(s, d, num_vertices)
        nlab = int(labels[np.concatenate([s, d])].max()) + 1
        csize = np.bincount(labels[s], minlength=nlab).astype(np.float64)
        # affinity[c, p] = #window vertices of c already resident in p
        aff = np.zeros((nlab, k), dtype=np.float64)
        verts = np.unique(np.concatenate([s, d]))
        placed = verts[vertex_last[verts] >= 0]
        if placed.size:
            np.add.at(aff, (labels[placed], vertex_last[placed]), 1.0)
        order = np.argsort(-csize[:nlab])
        ca = np.zeros(nlab, dtype=np.int64)
        for c in order:
            cost = norm * csize[c] * loads - aff[c]
            p = int(np.argmin(cost))
            ca[c] = p
            loads[p] += csize[c]
        w_assign = ca[labels[s]].astype(np.int32)
        assign[lo:hi] = w_assign
        vertex_last[s] = w_assign
        vertex_last[d] = w_assign
    return assign


def _window_components(s: np.ndarray, d: np.ndarray,
                       num_vertices: int) -> np.ndarray:
    """Union-find over the window's vertices; labels indexed by vertex."""
    parent: dict[int, int] = {}

    def find(x: int) -> int:
        while parent.setdefault(x, x) != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in zip(s.tolist(), d.tolist()):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    roots = {}
    labels = np.zeros(num_vertices, dtype=np.int64)
    for x in parent:
        r = find(x)
        labels[x] = roots.setdefault(r, len(roots))
    return labels


ALL_BASELINES = {
    "hashing": hashing,
    "dbh": dbh,
    "greedy": greedy,
    "hdrf": hdrf,
    "mint": mint_like,
}
