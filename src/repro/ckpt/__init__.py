from .checkpoint import save, restore, restore_latest, list_steps  # noqa: F401
