"""Sharded checkpointing with atomic writes + elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json   — step, tree structure, shapes/dtypes, mesh,
                              arch fingerprint, rng state
            arrays.npz      — flattened leaves keyed by tree path

Fault-tolerance contract:
- writes go to ``step_<N>.tmp`` then os.replace → a reader never sees a
  torn checkpoint; ``restore_latest`` skips trailing garbage.
- restore re-shards onto the *current* mesh/device count (elastic): arrays
  are stored unsharded (gathered) and device_put with the target sharding.
  At smoke scale gathering is free; at production scale this becomes a
  per-shard file layout — same manifest contract (documented in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out, dtypes = {}, {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
            arr = arr.astype(np.float32)      # lossless bf16 → f32
        out[key] = arr
    return out, dtypes


def save(path: str | Path, step: int, tree, extra: dict | None = None):
    path = Path(path)
    final = path / f"step_{step:08d}"
    tmp = Path(str(final) + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, dtypes = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {"step": step, "keys": sorted(flat), "dtypes": dtypes,
                "extra": extra or {}}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def list_steps(path: str | Path) -> list[int]:
    path = Path(path)
    if not path.exists():
        return []
    out = []
    for d in path.iterdir():
        if d.is_dir() and d.name.startswith("step_") \
                and not d.name.endswith(".tmp") \
                and (d / "manifest.json").exists():
            try:
                json.loads((d / "manifest.json").read_text())
                out.append(int(d.name[5:]))
            except (ValueError, json.JSONDecodeError):
                continue   # torn write — skip
    return sorted(out)


def restore(path: str | Path, step: int, target_tree, shardings=None):
    """target_tree provides structure; shardings (optional pytree of
    NamedSharding) re-shards elastically onto the current mesh."""
    path = Path(path) / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    manifest = json.loads((path / "manifest.json").read_text())
    dtypes = manifest.get("dtypes", {})
    leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    for (kpath, leaf), sh in zip(leaves, shard_leaves):
        key = jax.tree_util.keystr(kpath)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        want = dtypes.get(key, str(arr.dtype))
        if "bfloat16" in want:
            arr = jax.numpy.asarray(arr).astype(jax.numpy.bfloat16)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_raw(path: str | Path, step: int):
    """Shape-blind restore: the stored flat ``{keystr: np.ndarray}`` map
    plus the manifest — no target tree, no shape asserts.  For services
    whose array sizes grow between snapshots (live edge ingest): the
    template-checked ``restore`` would reject a snapshot taken after the
    graph grew."""
    path = Path(path) / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    manifest = json.loads((path / "manifest.json").read_text())
    return {k: data[k] for k in data.files}, manifest


def restore_latest(path: str | Path, target_tree, shardings=None):
    steps = list_steps(path)
    if not steps:
        return None, -1
    step = steps[-1]
    return restore(path, step, target_tree, shardings), step
