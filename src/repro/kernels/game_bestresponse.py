"""Game best-response — Pallas TPU kernel for the paper's compute hot spot.

Paper §V: "the retrieval of the Nash equilibrium is compute-bound".  The
inner loop evaluates, for every cluster i in a batch, the cost of each of
the k partition choices

    cost(i, p) = (λ/k)·|c_i|·(loads_p − |c_i|·[a_i = p] + |c_i|)
               + ½·(row_tot_i − A[i, p])

and takes the argmin.  HDRF pays a lock on a global table per edge; CLUGP's
batched game turns this into an embarrassingly-tileable (m × k) sweep —
exactly the MXU/VPU-friendly shape.  The cut-mass matrix A (batch rows ×
k) is produced by a preceding SpMM (cluster adjacency × one-hot assign);
this kernel fuses the cost assembly + argmin so the (m, k) cost matrix
never hits HBM.

Blocks: (block_m, k) rows of A in VMEM; loads (k,) replicated per block;
k is padded to a lane multiple (128) with +inf loads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38


def _br_kernel(aff_ref, sizes_ref, rowtot_ref, cur_ref, loads_ref, lam_ref,
               best_ref, cost_ref, *, k: int, kpad: int):
    aff = aff_ref[...].astype(jnp.float32)           # (bm, kpad)
    sizes = sizes_ref[...].astype(jnp.float32)       # (bm,)
    rowtot = rowtot_ref[...].astype(jnp.float32)     # (bm,)
    cur = cur_ref[...]                               # (bm,)
    loads = loads_ref[...].astype(jnp.float32)       # (kpad,)
    lam = lam_ref[0]                                 # (1,) traced scalar

    bm = aff.shape[0]
    pids = jax.lax.broadcasted_iota(jnp.int32, (bm, kpad), 1)
    own = (pids == cur[:, None]).astype(jnp.float32)
    loads_ex = loads[None, :] - sizes[:, None] * own
    cost = (lam / k) * sizes[:, None] * (loads_ex + sizes[:, None]) \
        + 0.5 * (rowtot[:, None] - aff)
    cost = jnp.where(pids < k, cost, BIG)
    best = jnp.argmin(cost, axis=1).astype(jnp.int32)
    best_ref[...] = best
    cost_ref[...] = jnp.min(cost, axis=1)


def game_bestresponse(aff, sizes, row_tot, cur, loads, *, lam,
                      k: int | None = None, block_m: int = 256,
                      interpret: bool = True):
    """aff: (M, Kpad) cut mass; sizes/row_tot: (M,); cur: (M,) int32;
    loads: (Kpad,).  ``k`` = real partition count (< Kpad ⇒ padded lanes
    masked to +BIG).  ``lam`` may be a python float or a traced scalar —
    the jitted partitioner pipeline computes λ_max from the streamed
    cluster graph, so it is data-dependent and ships to the kernel as a
    (1,)-shaped input rather than a compile-time constant.
    Returns (best (M,), cost (M,))."""
    M, kpad = aff.shape
    if k is None:
        k = kpad
    assert M % block_m == 0
    grid = (M // block_m,)
    lam_arr = jnp.asarray(lam, jnp.float32).reshape((1,))
    kern = functools.partial(_br_kernel, k=int(k), kpad=int(kpad))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, kpad), lambda i: (i, 0)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((kpad,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M,), jnp.int32),
            jax.ShapeDtypeStruct((M,), jnp.float32),
        ],
        interpret=interpret,
    )(aff, sizes, row_tot, cur, loads, lam_arr)
