"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) — chunked form.

The SSD layer computes, per head h with scalar decay a_t = exp(-softplus(Δ_t)A):
    y_t = Σ_{s≤t} (Π_{r=s+1..t} a_r) · (C_t·B_s) · x_s   + D·x_t
which the chunked algorithm evaluates as (intra-chunk quadratic) +
(inter-chunk recurrent state passing) — O(S·C) instead of O(S²).

Used by ``mamba2-130m`` and the Mamba blocks of ``jamba-1.5-large``.
``d_inner`` (heads) shards over the ``model`` axis; the scan carries only
(B, H, dh, N) state so no collectives appear inside the layer.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .layers import Params, linear, linear_init, rmsnorm, rmsnorm_init


def ssd_init(key, d_model: int, d_inner: int, d_state: int, head_dim: int,
             dtype=jnp.float32) -> Params:
    """Separate x/z/BC/dt projections (not the fused in_proj of the
    reference impl) so the d_inner outputs shard cleanly on the model axis
    while the small B/C/dt heads replicate."""
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    return {
        "x_proj": linear_init(ks[0], d_model, d_inner, dtype=dtype),
        "z_proj": linear_init(ks[1], d_model, d_inner, dtype=dtype),
        "bc_proj": linear_init(ks[2], d_model, 2 * d_state, dtype=dtype),
        "dt_proj": linear_init(ks[3], d_model, n_heads, dtype=dtype),
        "out_proj": linear_init(ks[4], d_inner, d_model, dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(dtype)),
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "norm": rmsnorm_init(d_inner, dtype),
    }


def _project(p: Params, x, d_state: int):
    xi = linear(p["x_proj"], x)
    z = linear(p["z_proj"], x)
    bc = linear(p["bc_proj"], x)
    B, C = bc[..., :d_state], bc[..., d_state:]
    dt = linear(p["dt_proj"], x)
    return xi, z, B, C, dt


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int = 128,
                unroll: bool = False):
    """Chunked SSD scan.
    x: (b, S, H, dh); dt: (b, S, H) post-softplus; A: (H,) (negative);
    B, C: (b, S, N).  Returns (b, S, H, dh)."""
    b, S, H, dh = x.shape
    N = B.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, "sequence must be divisible by chunk"
    xc = x.reshape(b, nc, chunk, H, dh)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)

    dA = dtc * A[None, None, None, :]               # (b,nc,c,H) log-decay ≤ 0
    cum = jnp.cumsum(dA, axis=2)                    # within-chunk cumulative
    total = cum[:, :, -1, :]                        # (b,nc,H)

    # ----- intra-chunk (quadratic within chunk) -----
    # decay(t,s) = exp(cum_t - cum_s) for s ≤ t — mask BEFORE exp: the
    # upper triangle is positive and would overflow (NaN grads through
    # the where otherwise).
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bgtn,bgsn->bgts", Cc, Bc)        # (b,nc,t,s)
    w = scores[..., None] * decay                          # (b,nc,t,s,H)
    xin = xc * dtc[..., None]                              # Δ-weighted input
    y_intra = jnp.einsum("bgtsh,bgshd->bgthd", w, xin)

    # ----- chunk states -----
    # state_g = Σ_s exp(total_g - cum_s) · B_s ⊗ (Δ_s x_s)
    sdecay = jnp.exp(total[:, :, None, :] - cum)           # (b,nc,c,H)
    state = jnp.einsum("bgsn,bgsh,bgshd->bghnd", Bc, sdecay, xin)

    # ----- inter-chunk recurrence (scan over chunks) -----
    def step(carry, inp):
        st_prev = carry                                    # (b,H,N,dh)
        st_g, tot_g = inp                                  # (b,H,N,dh),(b,H)
        st_new = st_prev * jnp.exp(tot_g)[:, :, None, None] + st_g
        return st_new, st_prev

    st0 = jnp.zeros((b, H, N, dh), x.dtype)
    _, prev_states = jax.lax.scan(
        step, st0,
        (state.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
        unroll=unroll)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (b,nc,H,N,dh)

    # contribution of carried state: y_t += C_t · exp(cum_t) · st_prev
    y_inter = jnp.einsum("bgtn,bgth,bghnd->bgthd",
                         Cc, jnp.exp(cum), prev_states)

    y = (y_intra + y_inter).reshape(b, S, H, dh)
    return y + x * D[None, None, :, None]


def ssd_reference(x, dt, A, B, C, D):
    """O(S) sequential oracle (tests)."""
    b, S, H, dh = x.shape
    N = B.shape[-1]

    def step(st, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A)[..., None, None]          # (b,H,1,1)
        st = st * decay + jnp.einsum(
            "bn,bh,bhd->bhnd", Bt, dtt, xt)
        y = jnp.einsum("bn,bhnd->bhd", Ct, st)
        return st, y

    st0 = jnp.zeros((b, H, N, dh), x.dtype)
    _, ys = jax.lax.scan(step, st0, (x.transpose(1, 0, 2, 3),
                                     dt.transpose(1, 0, 2),
                                     B.transpose(1, 0, 2),
                                     C.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3)
    return y + x * D[None, None, :, None]


def ssd_apply(p: Params, x: jnp.ndarray, *, d_inner: int, d_state: int,
              head_dim: int, chunk: int = 128,
              unroll: bool = False) -> jnp.ndarray:
    """Full Mamba-2 block (no conv1d — held in the frontier list): in-proj →
    SSD → gated RMSNorm → out-proj.  x: (B, S, d_model)."""
    n_heads = d_inner // head_dim
    xi, z, B, C, dt = _project(p, x, d_state)
    bsz, S, _ = xi.shape
    xi = xi.reshape(bsz, S, n_heads, head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y = ssd_chunked(xi.astype(jnp.float32), dt, A,
                    B.astype(jnp.float32), C.astype(jnp.float32),
                    p["D"].astype(jnp.float32), chunk=chunk, unroll=unroll)
    y = y.reshape(bsz, S, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y)


def ssd_decode_step(p: Params, x, state, *, d_inner: int, d_state: int,
                    head_dim: int):
    """Single-token decode: x (B, 1, d_model), state (B, H, N, dh)."""
    n_heads = d_inner // head_dim
    xi, z, B, C, dt = _project(p, x, d_state)
    bsz = xi.shape[0]
    xi = xi.reshape(bsz, n_heads, head_dim)
    B, C = B[:, 0], C[:, 0]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)[..., None, None]
    state = state * decay + jnp.einsum("bn,bh,bhd->bhnd", B, dt,
                                       xi.astype(jnp.float32))
    y = jnp.einsum("bn,bhnd->bhd", C, state)
    y = y + xi.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y), state
