"""The tracked allowlist — every entry is a justified, counted exemption.

Burn-down contract: the engine errors when the live count for an entry
differs from ``count`` in *either* direction, so the only way to change
this file is to shrink it (fix a site → decrement/delete the entry).
Adding an entry is a reviewed decision, not a lint workaround.
"""
from .lint import Allow

_BENCH_WHY = ("microbenchmark measures the engine primitive itself — "
              "GraphSession indirection would add the overhead under test")
_SHIM_WHY = ("shim-equivalence test deliberately exercises every "
             "deprecated comm_bytes_* wrapper against the router")

ALLOWLIST: tuple[Allow, ...] = (
    # -- SESSION-BYPASS: primitive-level benches ------------------------
    Allow("SESSION-BYPASS", "benchmarks/bench_pagerank.py",
          "build_layout", 4, _BENCH_WHY),
    Allow("SESSION-BYPASS", "benchmarks/bench_pagerank.py",
          "build_layout_reference", 1, _BENCH_WHY),
    Allow("SESSION-BYPASS", "benchmarks/bench_pagerank.py",
          "simulate_pagerank", 1, _BENCH_WHY),
    Allow("SESSION-BYPASS", "benchmarks/bench_pagerank.py",
          "simulate_gas", 1, _BENCH_WHY),
    Allow("SESSION-BYPASS", "benchmarks/bench_pagerank.py",
          "simulate_gas_many", 1, _BENCH_WHY),
    Allow("SESSION-BYPASS", "benchmarks/bench_partitioning.py",
          "build_layout", 1, _BENCH_WHY),
    # -- DEPRECATED-API: the shims' own equivalence test ----------------
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_mirror_sync", 1, _SHIM_WHY),
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_halo", 1, _SHIM_WHY),
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_ragged", 1, _SHIM_WHY),
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_ragged_quantized", 1, _SHIM_WHY),
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_halo_quantized", 1, _SHIM_WHY),
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_fused_quantized", 1, _SHIM_WHY),
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_exchange", 1, _SHIM_WHY),
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_fused", 2, _SHIM_WHY),   # layout + session variants
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_ideal", 1, _SHIM_WHY),
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_dense", 1, _SHIM_WHY),
    Allow("DEPRECATED-API", "tests/test_session.py",
          "comm_bytes_programs", 1, _SHIM_WHY),
)
