"""CLUGP core: the paper's three-pass restreaming vertex-cut partitioner."""
from .graphgen import Graph, web_graph, social_graph, rmat, barabasi, bfs_order, random_stream  # noqa: F401
from .clustering import (streaming_clustering_np, streaming_clustering_jax,  # noqa: F401
                         clustering_result_from_jax, default_vmax,
                         ClusteringResult)
from .game import (contract, best_response_rounds, greedy_assign,  # noqa: F401
                   lambda_max, lambda_from_weight, potential, global_cost,
                   ClusterGraph, GameResult)
from .transform import (transform_np, transform_jax,  # noqa: F401
                        majority_vertex_map_np, majority_vertex_map_jax)
from .pipeline import CLUGPConfig, CLUGPResult  # noqa: F401
from .stages import (StageCtx, StageSet, PipelineOut,  # noqa: F401
                     run_clugp_body, restream_loop,
                     StreamState, stream_state, incremental_assign,
                     restream_assign, HOST_STAGES, JAX_STAGES)
from .partitioner import (BACKENDS, partition,  # noqa: F401
                          partition_sweep, sweep_trace_count)
from . import baselines, metrics, theory  # noqa: F401
