"""Quantized halo exchange: error-feedback pagerank accuracy, exact int32
CC passthrough, byte-model ordering, and int8 lane round-trip properties.
(The shard_map driver equivalences run in tests/test_dist_multidevice.py.)"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import CLUGPConfig, clugp_partition
from repro.core.graphgen import web_graph
from repro.dist.halo import get_exchange
from repro.graph import (CC_PROGRAM, build_layout, pagerank_program,
                         reference_cc, reference_pagerank, simulate_cc,
                         simulate_pagerank)

from conftest import random_graph_and_assign as _random_graph_and_assign


# ------------------------------------------------- error-feedback pagerank

@pytest.mark.parametrize("seed", [0, 1])
def test_quantized_pagerank_converges_to_reference(seed):
    """Delta-coded int8 lanes with error feedback: the residual carries the
    quantization error across iterations, so 30 iterations land within a
    tight tolerance of the fp32 oracle instead of dithering at one int8
    quantization step."""
    src, dst, n, assign = _random_graph_and_assign(seed, 8, n=400)
    lay = build_layout(src, dst, assign, n, 8)
    ref = reference_pagerank(src, dst, n, iters=30)
    pr_q = simulate_pagerank(lay, iters=30, exchange="quantized")
    assert np.abs(pr_q - ref).max() < 1e-5
    # and it matches the exact halo backend to the same tolerance
    pr_h = simulate_pagerank(lay, iters=30, exchange="halo")
    assert np.abs(pr_q - pr_h).max() < 1e-5


def test_quantized_pagerank_on_clugp_partition():
    g = web_graph(scale=10, edge_factor=8, seed=0)
    k = 8
    res = clugp_partition(g.src, g.dst, g.num_vertices,
                          CLUGPConfig.optimized(k))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, k)
    ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
    pr_q = simulate_pagerank(lay, iters=30, exchange="quantized")
    assert np.abs(pr_q - ref).max() < 1e-5


# ------------------------------------------------- exact int32 CC path

@pytest.mark.parametrize("seed", [0, 1])
def test_quantized_cc_is_exact(seed):
    """combine="min" programs skip quantization (int32 labels are exact on
    the wire), so quantized CC is bit-identical to dense/halo CC."""
    src, dst, n, assign = _random_graph_and_assign(seed, 8, n=400)
    lay = build_layout(src, dst, assign, n, 8)
    ref = reference_cc(src, dst, n)
    cc_q = simulate_cc(lay, iters=40, exchange="quantized")
    cc_d = simulate_cc(lay, iters=40, exchange="dense")
    touched = np.zeros(n, bool)
    touched[src] = touched[dst] = True
    np.testing.assert_array_equal(cc_q[touched], ref[touched])
    np.testing.assert_array_equal(cc_q, cc_d)


def test_quantized_state_empty_for_min_and_int_programs():
    """The quantized exchange only materializes reference/residual state
    for lossily-coded (fp32, sum) programs; CC's int32 min payload rides
    the exact halo path with an empty carry."""
    src, dst, n, assign = _random_graph_and_assign(2, 4, n=120)
    lay = build_layout(src, dst, assign, n, 4)
    dev = {f: jnp.asarray(getattr(lay, f))
           for f in ("halo_send", "halo_recv")}
    ex = get_exchange("quantized")
    assert ex.init_state(dev, CC_PROGRAM.dtype, CC_PROGRAM.combine) == ()
    prog = pagerank_program(n)
    state = ex.init_state(dev, prog.dtype, prog.combine)
    assert set(state) == {"reduce", "bcast"}
    for phase in state.values():
        assert set(phase) == {"sref", "sres", "rref"}
        for arr in phase.values():
            assert arr.shape == lay.halo_send.shape
            assert not arr.any()


# ------------------------------------------------- byte model ordering

def test_comm_model_quantized_below_halo_below_dense():
    g = web_graph(scale=10, edge_factor=8, seed=0)
    k = 8
    res = clugp_partition(g.src, g.dst, g.num_vertices,
                          CLUGPConfig.optimized(k))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, k)
    assert lay.comm_bytes_halo_quantized() < lay.comm_bytes_halo()
    assert lay.comm_bytes_halo() < lay.comm_bytes_mirror_sync()
    # int8 codes + one fp32 scale per lane group, 2 phases/iter
    assert lay.comm_bytes_halo_quantized() == \
        2 * k * (k - 1) * (lay.h_max + 4)


def test_dryrun_ordering_gate_flags_regressions():
    from repro.launch.dryrun import check_graph_ordering

    def rec(program, exchange, wire, lossy=True):
        return {"program": program, "exchange": exchange, "status": "ok",
                "lossy_payload": lossy, "collective_bytes_wire": wire}

    good = [rec("pagerank", "dense", 100), rec("pagerank", "halo", 40),
            rec("pagerank", "quantized", 12),
            rec("cc", "dense", 100), rec("cc", "halo", 40),
            # cc ships the exact payload → quantized == halo is allowed
            rec("cc", "quantized", 40, lossy=False)]
    assert check_graph_ordering(good) == []
    bad = [rec("pagerank", "dense", 100), rec("pagerank", "halo", 100),
           rec("pagerank", "quantized", 100)]
    assert len(check_graph_ordering(bad)) == 2
    # a lossy program's quantized cell must be strictly below halo
    tie = good[:2] + [rec("pagerank", "quantized", 40)]
    assert len(check_graph_ordering(tie)) == 1
    failed = good[:5] + [{"program": "cc", "exchange": "quantized",
                          "status": "FAIL: boom"}]
    assert any("boom" in m for m in check_graph_ordering(failed))


# the int8 lane round-trip property tests (hypothesis) live in
# tests/test_properties_halo.py so this module still runs where the
# optional hypothesis dep is absent (module-level importorskip skips a
# whole file, as tests/test_properties.py relies on)
