"""Shared benchmark helpers."""
from __future__ import annotations

import time

from repro.core import (CLUGPConfig, baselines, metrics, partition,
                        random_stream)


def run_partitioner(name: str, g, k: int, seed: int = 0,
                    profile: str = "paper"):
    """Returns (assign, seconds).  CLUGP streams in crawl order; baselines
    get their best order (random — paper §VI-A)."""
    t0 = time.time()
    if name.startswith("clugp"):
        cfg = (CLUGPConfig.optimized(k) if name == "clugp-opt"
               else CLUGPConfig.paper(k))
        if name == "clugp-nosplit":
            cfg = CLUGPConfig(k=k, split=False)
        if name == "clugp-nogame":
            cfg = CLUGPConfig(k=k, game=False)
        res = partition(g.src, g.dst, g.num_vertices, cfg)
        return res.assign, time.time() - t0, res
    gr = random_stream(g, seed=seed)
    t0 = time.time()
    a = baselines.ALL_BASELINES[name](gr.src, gr.dst, g.num_vertices, k,
                                      seed=seed)
    dt = time.time() - t0
    return a, dt, (gr.src, gr.dst)


def stream_for(name: str, g, out):
    """The (src, dst) edge stream an assignment from ``run_partitioner``
    indexes: CLUGP streams in crawl order (g.src/g.dst); baselines were
    scored on their random re-stream, carried in out[2]."""
    if name.startswith("clugp"):
        return g.src, g.dst
    return out[2]


def quality_row(name, g, k, seed=0, out=None):
    """Quality metrics for one partitioner run.  Pass ``out`` (a prior
    ``run_partitioner`` result) to score it without re-partitioning."""
    if out is None:
        out = run_partitioner(name, g, k, seed)
    assign, dt = out[0], out[1]
    src, dst = stream_for(name, g, out)
    rf = metrics.replication_factor(src, dst, assign, g.num_vertices, k)
    bal = metrics.load_balance(assign, k)
    return {"algo": name, "k": k, "rf": round(rf, 4),
            "balance": round(bal, 4), "seconds": round(dt, 4),
            "us_per_edge": round(1e6 * dt / g.num_edges, 3)}
