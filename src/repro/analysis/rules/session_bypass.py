"""SESSION-BYPASS: launchers, examples and benchmarks drive
``GraphSession`` — they don't hand-wire partition → layout → engine.

``GraphSession`` owns device residency, compile caching and the
ingest/serve lifecycle; an entry point that calls ``build_layout`` or
``simulate_gas`` directly gets none of that and silently forks the
supported path.  Benchmarks that *measure the primitives themselves*
are the legitimate exception and live in the allowlist with a
justification.
"""
from __future__ import annotations

import ast

from ..lint import Rule

ENGINE_INTERNALS = frozenset({
    "build_layout", "build_layout_reference",
    "simulate_gas", "simulate_gas_many",
    "shard_map_gas", "shard_map_gas_many",
    "simulate_pagerank", "simulate_cc",
    "shard_map_pagerank", "shard_map_cc",
    "gas_step_for_dryrun",
})


class SessionBypass(Rule):
    id = "SESSION-BYPASS"
    description = ("entry points (launch/, examples/, benchmarks/) drive "
                   "GraphSession, not raw layout/engine internals")
    roots = ("src/repro/launch", "examples", "benchmarks")

    def run(self, tree, relpath, text):
        out = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = (fn.id if isinstance(fn, ast.Name)
                    else fn.attr if isinstance(fn, ast.Attribute)
                    else None)
            if name in ENGINE_INTERNALS:
                out.append(self.finding(
                    relpath, node, name,
                    f"calls engine internal {name}() — drive GraphSession "
                    f"instead"))
        return out
