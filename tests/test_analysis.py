"""repro.analysis: lint rules (planted violations), allowlist burn-down,
IR analyzers, and the dryrun parser-extraction shims."""
import textwrap
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import Allow, run_lint
from repro.analysis import ir
from repro.analysis.rules import (DeprecatedApi, JitPurity, RawCollective,
                                  SessionBypass, StagePlumb)


def plant(tmp_path, relpath, source):
    p = tmp_path / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return p


# ---------------------------------------------------------------- lint rules

def test_raw_collective_planted(tmp_path):
    plant(tmp_path, "src/repro/core/bad.py", """\
        import jax
        from jax import lax
        from jax.lax import ppermute

        def f(x):
            y = jax.lax.psum(x, "parts")
            return lax.all_gather(y, "parts")
        """)
    rep = run_lint(root=tmp_path, rules=[RawCollective()], allowlist=[])
    assert sorted(f.key for f in rep.violations) == \
        ["all_gather", "ppermute", "psum"]


def test_raw_collective_skips_dist_layer(tmp_path):
    plant(tmp_path, "src/repro/dist/collectives.py", """\
        import jax

        def psum(x, axis):
            return jax.lax.psum(x, axis)
        """)
    rep = run_lint(root=tmp_path, rules=[RawCollective()], allowlist=[])
    assert rep.ok, rep.format()


def test_stage_plumb_planted(tmp_path):
    plant(tmp_path, "src/repro/core/partitioner.py", """\
        from .clustering import streaming_clustering
        from . import transform

        def strategy(src, dst):
            clu = streaming_clustering(src, dst)
            return transform.transform_np(src, dst, clu)
        """)
    rep = run_lint(root=tmp_path, rules=[StagePlumb()], allowlist=[])
    keys = sorted(f.key for f in rep.violations)
    assert "streaming_clustering" in keys and "transform_np" in keys


def test_session_bypass_planted(tmp_path):
    plant(tmp_path, "examples/demo.py", """\
        from repro.graph import build_layout, simulate_pagerank

        lay = build_layout(src, dst, V, assign, k)
        pr = simulate_pagerank(lay, iters=30)
        """)
    rep = run_lint(root=tmp_path, rules=[SessionBypass()], allowlist=[])
    assert sorted(f.key for f in rep.violations) == \
        ["build_layout", "simulate_pagerank"]


def test_deprecated_api_planted_and_docstrings_exempt(tmp_path):
    plant(tmp_path, "src/repro/user.py", '''\
        """Docstring mentions clugp_partition and comm_bytes_halo —
        strings never trip the AST rule."""

        def f(lay):
            assert not hasattr(lay, "clugp_partition")   # string: fine
            return lay.comm_bytes_halo() + clugp_partition(lay)
        ''')
    rep = run_lint(root=tmp_path, rules=[DeprecatedApi()], allowlist=[])
    assert sorted(f.key for f in rep.violations) == \
        ["clugp_partition", "comm_bytes_halo"]


def test_jit_purity_planted_direct_and_transitive(tmp_path):
    plant(tmp_path, "src/repro/hot.py", """\
        import time
        import numpy as np
        import jax

        def helper(x):
            return x * np.random.rand()      # impure, called from traced

        @jax.jit
        def step(x):
            return helper(x) + time.time()   # impure, directly traced

        def host_only():
            return time.time()               # untraced host code: fine

        def body(c, _):
            return c + np.random.randn(), None

        def driver(x):
            return jax.lax.scan(body, x, None, length=3)
        """)
    rep = run_lint(root=tmp_path, rules=[JitPurity()], allowlist=[])
    keys = sorted(f.key for f in rep.violations)
    assert keys == ["numpy.random.rand", "numpy.random.randn",
                    "time.time"], keys


def test_jit_purity_allows_static_host_numpy(tmp_path):
    plant(tmp_path, "src/repro/shapes.py", """\
        import numpy as np
        import jax

        @jax.jit
        def f(x):
            pad = int(np.ceil(x.shape[0] / 8)) * 8   # static shape math
            return jax.numpy.pad(x, (0, pad - x.shape[0]))
        """)
    rep = run_lint(root=tmp_path, rules=[JitPurity()], allowlist=[])
    assert rep.ok, rep.format()


# ---------------------------------------------------------- allowlist rules

@pytest.fixture()
def one_violation_tree(tmp_path):
    plant(tmp_path, "examples/demo.py", "lay = build_layout(1, 2)\n")
    return tmp_path


def test_allowlist_demotes_exact_count(one_violation_tree):
    allow = [Allow("SESSION-BYPASS", "examples/demo.py", "build_layout",
                   1, "test")]
    rep = run_lint(root=one_violation_tree, rules=[SessionBypass()],
                   allowlist=allow)
    assert rep.ok and len(rep.findings) == 1 and rep.findings[0].allowlisted


def test_allowlist_errors_on_count_drift_both_ways(one_violation_tree):
    for n in (0, 2):
        allow = [Allow("SESSION-BYPASS", "examples/demo.py",
                       "build_layout", n, "test")]
        rep = run_lint(root=one_violation_tree, rules=[SessionBypass()],
                       allowlist=allow)
        assert not rep.ok and rep.errors, n


def test_allowlist_ignores_entries_for_inactive_rules(one_violation_tree):
    # a partial-rule run (the pytest wrappers) must not reconcile other
    # rules' entries against a tree those rules never scanned
    allow = [Allow("SESSION-BYPASS", "examples/demo.py", "build_layout",
                   1, "test"),
             Allow("DEPRECATED-API", "tests/test_session.py",
                   "comm_bytes_halo", 1, "not scanned here")]
    rep = run_lint(root=one_violation_tree, rules=[SessionBypass()],
                   allowlist=allow)
    assert rep.ok, rep.format()


def test_real_tree_is_clean():
    """The CI gate, as a test: the shipped tree has zero violations and
    an exactly-reconciled allowlist."""
    rep = run_lint()
    assert rep.ok, rep.format()


# ------------------------------------------------------------- IR analyzers

def test_dtype_drift_catches_f16_repromotion():
    def f(x):
        q = x.astype(jnp.float16)        # quantized payload …
        return q.astype(jnp.float32) * 2  # … silently re-promoted

    sites = ir.dtype_drift(f, jnp.ones(8))
    assert [(s["old"], s["new"]) for s in sites] == \
        [("float16", "float32")]


def test_dtype_drift_ignores_dequantize_and_allow():
    def dequant(codes, scale):
        return codes.astype(jnp.float32) * scale   # kind change: fine

    assert ir.dtype_drift(dequant, jnp.zeros(8, jnp.uint8),
                          jnp.float32(0.5)) == []

    def f(x):
        return x.astype(jnp.float16).astype(jnp.float32)

    assert ir.dtype_drift(f, jnp.ones(4),
                          allow=[("float16", "float32")]) == []


def test_retrace_count_stable_vs_leaky():
    def f(x, k):
        return x * k

    stable = ir.retrace_count(
        f, [(jnp.ones(4), jnp.float32(i)) for i in range(4)])
    assert stable == 1, stable

    leaky = ir.retrace_count(
        f, [(jnp.ones(4), float(i)) for i in range(4)],
        jit_kwargs=dict(static_argnums=1))
    assert leaky == 4, leaky


def test_scatter_copy_detected_in_scan_but_not_transform():
    def scat(x, idx):
        def body(c, i):
            return c.at[i].add(1.0), None
        out, _ = jax.lax.scan(body, x, idx)
        return out

    sites = ir.scatter_copy_sites(scat, jnp.zeros(8), jnp.arange(4) % 3)
    assert len(sites) == 1 and sites[0]["path"] == "scan", sites

    # the production transform scan is the arithmetic one-hot rewrite —
    # it must stay scatter-free (EXPERIMENTS.md §Perf-partitioner)
    from functools import partial
    from repro.core.transform import transform_jax
    z = jnp.zeros(16, jnp.int32)
    jx = jax.make_jaxpr(partial(transform_jax, k=4))(
        jnp.arange(10, dtype=jnp.int32), jnp.arange(10, dtype=jnp.int32),
        z, jnp.ones(16, jnp.int32), z)
    assert ir.scatter_copy_sites(jx) == []


def test_static_offset_scatter_not_flagged():
    def f(x):
        def body(c, _):
            return c.at[0].set(1.0), None    # constant index: harmless
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    assert ir.scatter_copy_sites(f, jnp.zeros(8)) == []


def test_unreduced_divergence_planted_and_reduced():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.dist._compat import shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("parts",))

    def bad(x):
        return x.sum()                       # per-shard partial sum

    sm_bad = shard_map(bad, mesh=mesh, in_specs=P("parts"),
                       out_specs=P(), check_rep=False)
    div = ir.unreduced_divergence(sm_bad, jnp.ones(8))
    assert [d["output"] for d in div] == [0], div

    def good(x):
        return jax.lax.psum(x.sum(), "parts")

    sm_good = shard_map(good, mesh=mesh, in_specs=P("parts"),
                        out_specs=P(), check_rep=False)
    assert ir.unreduced_divergence(sm_good, jnp.ones(8)) == []

    def sharded_out(x):
        return x * 2                         # varying but declared so

    sm_ok = shard_map(sharded_out, mesh=mesh, in_specs=P("parts"),
                      out_specs=P("parts"), check_rep=False)
    assert ir.unreduced_divergence(sm_ok, jnp.ones(8)) == []


# -------------------------------------------------- dryrun extraction shims

SAMPLE_HLO = """\
  %x = f32[8,4]{1,0} parameter(0)
  %all-reduce.1 = f32[8,4]{1,0} all-reduce(f32[8,4]{1,0} %x)
  %all-to-all.2 = (f32[1,4]{1,0}, f32[1,4]{1,0}) all-to-all(%a, %b)
  %collective-permute-start.3 = (f32[8]{0}, f32[8]{0}) collective-permute-start(%x)
  %collective-permute-done.3 = f32[8]{0} collective-permute-done(%collective-permute-start.3)
  ROOT %r = f32[8,4]{1,0} add(%x, %x)
"""


def test_dryrun_parser_shims_are_identity_and_warn():
    # import late: dryrun rewrites XLA_FLAGS at import, which only
    # matters before jax initializes (it already has, above)
    from repro.launch import dryrun

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        shim_bytes = dryrun.collective_bytes(SAMPLE_HLO)
        shim_count = dryrun.collective_permute_count(SAMPLE_HLO)
    assert [str(x.message) for x in w
            if issubclass(x.category, DeprecationWarning)], \
        "shims must warn"
    assert shim_bytes == ir.collective_bytes(SAMPLE_HLO)
    assert shim_count == ir.collective_permute_count(SAMPLE_HLO)
    # and the parse itself is sane: 128B all-reduce, 2×16B all-to-all
    # tuple, one async permute pair counted once (32B, done half skipped)
    assert shim_bytes["all-reduce"] == 128
    assert shim_bytes["all-to-all"] == 32
    assert shim_bytes["collective-permute"] == 32
    assert shim_count == 1


def test_dryrun_reexports_parser_constants():
    from repro.launch import dryrun

    assert dryrun.COLLECTIVE_KINDS is ir.COLLECTIVE_KINDS
    assert dryrun.DTYPE_BYTES is ir.DTYPE_BYTES
    assert dryrun.SHAPE_RE is ir.SHAPE_RE
