"""Fault tolerance: checkpoint-restart + straggler watch.

``run`` wraps any ``step_fn(params, opt_state, batch, i)`` in a loop that
- restores the latest intact checkpoint on entry (elastic restart),
- checkpoints every ``ckpt_every`` steps (optionally on a background
  thread) plus once at completion,
- times every step and flags stragglers (step > factor × running median),
- can inject a failure at a given step for restart testing.

``ServiceFT`` is the same machinery for a long-lived process instead of a
bounded loop: the graph service (``repro.serve``) snapshots its resident
edges/assignment through the atomic ``ckpt`` writes and restores them
shape-blind after a kill, and times its microbatches through the same
``StragglerWatch`` the trainer uses.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp

from .. import ckpt


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    resume: str = "auto"               # "auto" restores latest; "none" skips
    async_checkpoint: bool = False     # save on a background thread
    fail_at_step: int | None = None    # inject RuntimeError (tests)
    straggler_factor: float = 0.0      # 0 disables detection
    straggler_warmup: int = 2          # steps of timing history required


@dataclasses.dataclass
class FTState:
    step: int = 0          # next step to execute (== total when done)
    stragglers: int = 0
    restarts: int = 0


def _tree(params, opt_state):
    return {"params": params, "opt": opt_state}


class StragglerWatch:
    """Running-median step timer.  ``observe(dt)`` returns True when the
    step exceeds ``factor`` × the median of the recorded history — the
    median is taken BEFORE ``dt`` is recorded, so one slow step can't
    drown its own baseline.  ``factor=0`` disables; ``warmup`` steps of
    history are required before anything can be flagged."""

    def __init__(self, factor: float, warmup: int = 2, maxlen: int = 256):
        self.factor = factor
        self.warmup = warmup
        self._hist: deque[float] = deque(maxlen=maxlen)
        self.flagged = 0
        self.last_median = 0.0     # baseline the last observe compared to

    def observe(self, dt: float) -> bool:
        slow = False
        if self.factor > 0 and len(self._hist) >= self.warmup:
            self.last_median = statistics.median(self._hist)
            slow = dt > self.factor * max(self.last_median, 1e-9)
        self._hist.append(dt)
        if slow:
            self.flagged += 1
        return slow


class _Saver:
    """Serialized (optionally async) checkpoint writes."""

    def __init__(self, async_mode: bool):
        self.async_mode = async_mode
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def _save(self, ckpt_dir: str, step: int, tree, extra):
        try:
            ckpt.save(ckpt_dir, step, tree, extra=extra)
        except BaseException as e:  # noqa: BLE001 — re-raised in wait()
            self._error = e

    def save(self, ckpt_dir: str, step: int, tree, extra: dict | None = None):
        self.wait()
        tree = jax.tree_util.tree_map(jnp.asarray, tree)
        if self.async_mode:
            self._thread = threading.Thread(
                target=self._save, args=(ckpt_dir, step, tree, extra),
                daemon=True)
            self._thread.start()
        else:
            ckpt.save(ckpt_dir, step, tree, extra=extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err


def run(step_fn: Callable, params, opt_state, data_fn: Callable,
        total_steps: int, cfg: FTConfig, *, log_every: int = 10,
        log_fn: Callable = print, on_straggler: Callable | None = None):
    """Drive ``total_steps`` of training with checkpoint-restart.

    Returns (params, opt_state, losses, state); ``losses`` covers only the
    steps executed in *this* invocation (a restart resumes mid-stream).
    """
    state = FTState()
    start = 0
    if cfg.resume == "auto":
        try:
            restored, step = ckpt.restore_latest(
                cfg.ckpt_dir, _tree(params, opt_state))
        except (AssertionError, KeyError) as e:
            raise RuntimeError(
                f"checkpoint in {cfg.ckpt_dir!r} does not match the current "
                f"model (different arch/config?) — pass resume='none' or a "
                f"fresh ckpt_dir to start over: {e}") from e
        if step >= 0:
            params, opt_state = restored["params"], restored["opt"]
            start = step + 1
            state.restarts = 1
            if log_every:
                log_fn(f"[ft] restored step {step}, resuming at {start}")
    saver = _Saver(cfg.async_checkpoint)
    losses: list[float] = []
    watch = StragglerWatch(cfg.straggler_factor, cfg.straggler_warmup)
    last_saved = -1
    for i in range(start, total_steps):
        if cfg.fail_at_step is not None and i == cfg.fail_at_step:
            saver.wait()
            raise RuntimeError(f"injected failure at step {i}")
        batch = data_fn(i)
        t0 = time.perf_counter()
        params, opt_state, loss = step_fn(params, opt_state, batch,
                                          jnp.int32(i))
        loss = jax.block_until_ready(loss)
        dt = time.perf_counter() - t0
        if watch.observe(dt):
            state.stragglers += 1
            if on_straggler is not None:
                on_straggler(i, dt, watch.last_median)
        losses.append(float(loss))
        state.step = i + 1
        if log_every and i % log_every == 0:
            log_fn(f"[ft] step {i} loss {float(loss):.4f} {dt*1e3:.1f}ms")
        if cfg.ckpt_every and i > 0 and i % cfg.ckpt_every == 0:
            saver.save(cfg.ckpt_dir, i, _tree(params, opt_state))
            last_saved = i
    if total_steps > start and last_saved != total_steps - 1:
        saver.save(cfg.ckpt_dir, total_steps - 1,
                   _tree(params, opt_state))
    saver.wait()
    state.step = max(state.step, start)
    return params, opt_state, losses, state


class ServiceFT:
    """Preemption survival for a long-lived service (``repro.serve``).

    The trainer's loop owns its arrays and their shapes; a graph service
    does not — live ingest grows the resident edge arrays between
    snapshots, so the template-checked ``ckpt.restore`` would reject its
    own last checkpoint.  ``ServiceFT`` keeps the atomic-write/torn-read
    contract but restores SHAPE-BLIND (``ckpt.restore_raw``), carrying a
    JSON ``extra`` (session config blob, watermarks) alongside the
    arrays.  It also hosts the microbatch ``StragglerWatch``.
    """

    def __init__(self, ckpt_dir: str, *, async_checkpoint: bool = False,
                 straggler_factor: float = 0.0, straggler_warmup: int = 2):
        self.ckpt_dir = str(ckpt_dir)
        self._saver = _Saver(async_checkpoint)
        self.watch = StragglerWatch(straggler_factor, straggler_warmup)

    def snapshot(self, step: int, tree, extra: dict | None = None):
        """Atomic (optionally async) snapshot of a flat array tree plus a
        JSON-serializable ``extra`` dict."""
        self._saver.save(self.ckpt_dir, step, tree, extra=extra)

    def restore_latest(self):
        """``(flat, extra, step)`` of the newest intact snapshot, or
        ``(None, None, -1)`` when none exists.  ``flat`` keys are the
        original tree keys (single-level dict snapshots only)."""
        steps = ckpt.list_steps(self.ckpt_dir)
        if not steps:
            return None, None, -1
        flat, manifest = ckpt.restore_raw(self.ckpt_dir, steps[-1])
        flat = {k.strip("[]'\""): v for k, v in flat.items()}
        return flat, manifest.get("extra", {}), steps[-1]

    def wait(self):
        """Block until any in-flight async snapshot lands (re-raises)."""
        self._saver.wait()
