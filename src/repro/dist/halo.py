"""Exchange abstraction for the vertex-cut GAS engine's mirror sync.

The engine's per-iteration communication is two phases over the mirror
replicas (paper §II-B): mirror partials reduce to masters (gather), master
values broadcast back to mirrors (scatter).  This module gives the engine a
pluggable wire format for those phases:

- ``DenseExchange`` — the seed path: ``all_gather`` the full padded
  (L_max,) slab from every device and index into it with the static
  ``red_index`` / ``(owner, own_slot)`` tables.  Bytes ∝ k²·L_max per
  phase, independent of partition quality.
- ``HaloExchange`` — mirror-routed: each device packs only its mirror
  slots into per-destination lanes (``halo_send``) and a single
  ``all_to_all`` delivers every lane to its owner, which scatters via
  ``halo_recv``.  Bytes ∝ k·(k−1)·H_max per phase — within per-pair
  padding of the ideal 2·mirrors volume, so CLUGP's mirror reduction is
  the engine's real wire cost.
- ``QuantizedHaloExchange`` — halo routing with a compressed payload:
  each destination lane group quantizes to int8 codes + one fp32 max-abs
  scale (``dist.compress.quantize_rows``), cutting the per-mirror payload
  ~4× on top of the halo routing cut.  What goes on the wire is the
  **delta** against a reconstruction reference both endpoints advance in
  lockstep, with the quantization error carried in an error-feedback
  residual (1-bit-SGD style) threaded through the iteration carry — as a
  fixed-point program (pagerank) converges its deltas shrink, the scales
  shrink with them, and the reconstruction converges to the exact values
  instead of dithering at one quantization step.  ``combine="min"`` /
  integer programs (CC's label propagation) are already exact in int32, so
  they skip quantization and ship the exact halo payload.

Every backend exposes the same stateful operations (state is ``()`` for
the exact backends and a pytree of lane-shaped reference/residual arrays
for the quantized one, so it threads through ``fori_loop`` carries):

  init_state(dev, dtype, combine)                  -> state
  reduce_to_masters(partial, dev, combine, state)  -> (total, state)
  broadcast_from_masters(master, dev, combine, state) -> (values, state)
  reduce_stacked / broadcast_stacked               — same, on (k, …) stacks

``dev`` is the layout's ``device_arrays()`` pytree — per-device slices in
the shard_map forms, full (k, …) stacks in the stacked forms.  ``combine``
is ``"sum"`` (pagerank) or ``"min"`` (label propagation).  The stacked
forms model the collective with a transpose (all_to_all) / broadcast
(all_gather), so tests and host benchmarks run the identical math.

**Multi-lane (fused multi-program) operations.**  N homogeneous GAS
programs over the same layout can share one exchange per phase: values
grow a leading program axis ((N, L_max) per device), lanes become
(k, N, H_max), and ONE collective ships every program's mirror traffic —
the ``*_multi`` halves below (``init_state_multi`` /
``reduce_to_masters_multi`` / ``broadcast_from_masters_multi`` /
``reduce_stacked_multi`` / ``broadcast_stacked_multi``).  For the exact
backends the fused payload is exactly the concatenation of the separate
payloads; the quantized backend switches to the **fused wire format**:
int4 delta codes packed two-per-byte along the lane axis, with fp16
max-abs scales over 8 subgroups per (destination, program) lane row
(H_max is padded to a multiple of 8, so rows split evenly and the nibble
count is even).  Per-program, per-subgroup scales mean one hot program or
lane can't wash out another's precision — with a single scale per row the
coarse int4 grid stops being a contraction under error feedback and the
iteration plateaus instead of converging.  Halving the code width is what
makes fusing N programs genuinely cheaper than N separate quantized steps
((H/2 + 16)/(H + 4) ≈ 0.55×); the coarser int4 step is absorbed by the
same error-feedback residual, so fixed-point programs still converge to
the exact fixed point, just along a slightly longer transient.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .compress import dequantize_rows, quantize_rows


def _pad_value(combine: str, dtype) -> jnp.ndarray:
    """Identity element fed into padded send lanes; recv pads are dropped
    by the segment reduce regardless, so this only has to be shape-safe
    (and, for the quantized path, keep pad lanes exactly zero)."""
    dtype = jnp.dtype(dtype)
    if combine == "sum":
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(jnp.iinfo(dtype).max, dtype)
    return jnp.asarray(3e38, dtype)


def _segment_combine(vals, segments, num_segments: int, combine: str):
    if combine == "sum":
        return jax.ops.segment_sum(vals, segments,
                                   num_segments=num_segments)
    return jax.ops.segment_min(vals, segments, num_segments=num_segments)


def _merge(local, received, combine: str):
    if combine == "sum":
        return local + received
    return jnp.minimum(local, received)


def _pack(values, lanes, combine: str):
    """values (L_max,) → (k, H_max) send lanes; pad lanes read the
    combine identity appended at index L_max."""
    pad = jnp.full((1,), _pad_value(combine, values.dtype), values.dtype)
    return jnp.concatenate([values, pad])[lanes]


def _unpack(new_master, recv, dev):
    """Scatter received master values into this device's mirror slots
    (each valid lane targets a distinct slot; pads land in the dropped
    L_max bucket); master slots keep their local value."""
    l_max = new_master.shape[0]
    scattered = jnp.zeros((l_max + 1,), new_master.dtype).at[
        dev["halo_send"].reshape(-1)].set(recv.reshape(-1))[:l_max]
    return jnp.where(dev["is_master"], new_master, scattered)


# --------------------------------------------------- multi-lane helpers

def _pack_multi(values, lanes, combine: str):
    """values (N, L_max) → (k, N, H_max) send lanes (program axis rides
    inside each destination block, so one collective ships all N)."""
    n = values.shape[0]
    pad = jnp.full((n, 1), _pad_value(combine, values.dtype), values.dtype)
    ext = jnp.concatenate([values, pad], axis=1)        # (N, L_max+1)
    return jnp.moveaxis(ext[:, lanes], 0, 1)            # (k, N, H_max)


def _unpack_multi(new_master, recv, dev):
    """new_master (N, L_max), recv (k, N, H_max) → (N, L_max) values."""
    return jax.vmap(lambda m, r: _unpack(m, r, dev))(
        new_master, jnp.moveaxis(recv, 1, 0))


def _segment_combine_multi(recv, slots, num_segments: int, combine: str):
    """recv (k, N, H_max) lanes + shared (k, H_max) slot table →
    per-program (N, num_segments-1) reductions."""
    flat_slots = slots.reshape(-1)
    return jax.vmap(
        lambda r: _segment_combine(r.reshape(-1), flat_slots,
                                   num_segments, combine)[:num_segments - 1]
    )(jnp.moveaxis(recv, 1, 0))


_Q4MAX = 7.0
# each (destination, program) lane row splits into this many scale
# subgroups: finer groups isolate hot lanes so the coarse int4 grid stays
# a contraction under error feedback (one scale per whole row diverges),
# while 8 fp16 scales cost only 16 B per row on the wire.  h_max is
# padded to a multiple of 8 (``partition._pad_to``), so rows always
# split evenly and the nibble pack always sees an even lane count.
_NUM_SCALE_GROUPS = 8


def _quantize_groups(err):
    """int4 codes + one fp16 scale per 1/8th of the trailing lane row."""
    shp = err.shape
    grp = err.reshape(*shp[:-1], _NUM_SCALE_GROUPS,
                      shp[-1] // _NUM_SCALE_GROUPS)
    amax = jnp.max(jnp.abs(grp), axis=-1)
    scales = jnp.where(amax > 0, amax / _Q4MAX, 1.0).astype(jnp.float16)
    s = jnp.maximum(scales.astype(jnp.float32), 1e-30)[..., None]
    codes = jnp.clip(jnp.round(grp / s), -_Q4MAX, _Q4MAX).astype(jnp.int8)
    return codes.reshape(shp), scales


def _dequantize_groups(codes, scales):
    """Inverse grid step; both endpoints apply the identical fp16 scales
    received on the wire, so sender/receiver references stay in lockstep."""
    shp = codes.shape
    grp = codes.reshape(*shp[:-1], _NUM_SCALE_GROUPS,
                        shp[-1] // _NUM_SCALE_GROUPS)
    return (grp.astype(jnp.float32) *
            scales.astype(jnp.float32)[..., None]).reshape(shp)


def _nibble_pack(codes):
    """int8 codes in [-7, 7], even trailing dim → two codes per byte."""
    lo = codes[..., 0::2] & 0xF
    hi = codes[..., 1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def _nibble_unpack(packed):
    """Inverse of ``_nibble_pack`` (arithmetic shifts sign-extend)."""
    lo = jnp.right_shift(jnp.left_shift(packed, 4).astype(jnp.int8), 4)
    hi = jnp.right_shift(packed, 4)
    return jnp.stack([lo, hi], axis=-1).reshape(
        *packed.shape[:-1], 2 * packed.shape[-1])


@dataclass(frozen=True)
class DenseExchange:
    """Padded all_gather mirror sync (the seed wire format)."""
    axis: str | None = None
    name = "dense"

    def init_state(self, dev, dtype, combine: str = "sum"):
        return ()

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum",
                          state=()):
        g = jax.lax.all_gather(partial, self.axis)          # (k, L_max)
        return self._reduce_flat(g.reshape(-1), dev, combine), state

    def broadcast_from_masters(self, new_master, dev, combine: str = "sum",
                               state=()):
        g = jax.lax.all_gather(new_master, self.axis)       # (k, L_max)
        return g[dev["owner"], dev["own_slot"]], state

    # -- stacked halves ((k, L_max) arrays on one device) --
    def reduce_stacked(self, partials, dev, combine: str = "sum", state=()):
        flat = partials.reshape(-1)
        return jax.vmap(
            lambda d: self._reduce_flat(flat, d, combine))(dev), state

    def broadcast_stacked(self, masters, dev, combine: str = "sum",
                          state=()):
        return jax.vmap(
            lambda d: masters[d["owner"], d["own_slot"]])(dev), state

    @staticmethod
    def _reduce_flat(flat_gathered, dev, combine: str):
        l_max = dev["vert_gid"].shape[0]
        return _segment_combine(flat_gathered, dev["red_index"],
                                l_max + 1, combine)[:l_max]

    # -- multi-lane halves (fused programs; values carry a leading N) --
    def init_state_multi(self, dev, dtype, combine: str, n: int):
        return ()

    def reduce_to_masters_multi(self, partials, dev, combine: str = "sum",
                                state=()):
        g = jax.lax.all_gather(partials, self.axis)         # (k, N, L_max)
        flat = jnp.moveaxis(g, 1, 0).reshape(g.shape[1], -1)
        return jax.vmap(
            lambda f: self._reduce_flat(f, dev, combine))(flat), state

    def broadcast_from_masters_multi(self, new_masters, dev,
                                     combine: str = "sum", state=()):
        g = jax.lax.all_gather(new_masters, self.axis)      # (k, N, L_max)
        return jax.vmap(
            lambda gn: gn[dev["owner"], dev["own_slot"]]
        )(jnp.moveaxis(g, 1, 0)), state

    def reduce_stacked_multi(self, partials, dev, combine: str = "sum",
                             state=()):
        # partials (k, N, L_max): each program reduces over its own flat
        # (k·L_max) gather, per destination device
        flat = jnp.moveaxis(partials, 1, 0).reshape(partials.shape[1], -1)
        return jnp.moveaxis(jax.vmap(
            lambda f: jax.vmap(
                lambda d: self._reduce_flat(f, d, combine))(dev)
        )(flat), 0, 1), state

    def broadcast_stacked_multi(self, masters, dev, combine: str = "sum",
                                state=()):
        per_prog = jnp.moveaxis(masters, 1, 0)              # (N, k, L_max)
        return jnp.moveaxis(jax.vmap(
            lambda m: jax.vmap(
                lambda d: m[d["owner"], d["own_slot"]])(dev)
        )(per_prog), 0, 1), state

    def bytes_per_iter(self, layout, value_bytes: int = 4) -> int:
        return layout.comm_bytes_mirror_sync(value_bytes)


@dataclass(frozen=True)
class HaloExchange:
    """Mirror-routed all_to_all sync over the layout's halo tables.

    Reduce: pack mirror values into (k, H_max) destination lanes, one
    all_to_all, scatter-combine received lanes into master slots, merge
    with the local partial (a master's own contribution never leaves the
    device).  Broadcast runs the same route backwards: masters pack
    ``halo_recv`` lanes, mirrors scatter via ``halo_send``; master slots
    keep their local value.
    """
    axis: str | None = None
    name = "halo"

    def init_state(self, dev, dtype, combine: str = "sum"):
        return ()

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum",
                          state=()):
        l_max = partial.shape[0]
        send = _pack(partial, dev["halo_send"], combine)
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, H_max)
        agg = _segment_combine(recv.reshape(-1),
                               dev["halo_recv"].reshape(-1),
                               l_max + 1, combine)[:l_max]
        return _merge(partial, agg, combine), state

    def broadcast_from_masters(self, new_master, dev, combine: str = "sum",
                               state=()):
        send = _pack(new_master, dev["halo_recv"], combine)
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, H_max)
        return _unpack(new_master, recv, dev), state

    # -- stacked halves: all_to_all over k virtual devices == transpose --
    def reduce_stacked(self, partials, dev, combine: str = "sum", state=()):
        l_max = partials.shape[1]
        send = jax.vmap(
            lambda v, idx: _pack(v, idx, combine)
        )(partials, dev["halo_send"])                       # (k, k, H_max)
        recv = jnp.swapaxes(send, 0, 1)

        def one(recv_q, slots_q, partial_q):
            agg = _segment_combine(recv_q.reshape(-1),
                                   slots_q.reshape(-1),
                                   l_max + 1, combine)[:l_max]
            return _merge(partial_q, agg, combine)

        return jax.vmap(one)(recv, dev["halo_recv"], partials), state

    def broadcast_stacked(self, masters, dev, combine: str = "sum",
                          state=()):
        send = jax.vmap(
            lambda v, idx: _pack(v, idx, combine)
        )(masters, dev["halo_recv"])                        # (k, k, H_max)
        recv = jnp.swapaxes(send, 0, 1)
        return jax.vmap(
            lambda m, r, d: _unpack(m, r, d)
        )(masters, recv, dev), state

    # -- multi-lane halves (fused programs; values carry a leading N) --
    def init_state_multi(self, dev, dtype, combine: str, n: int):
        return ()

    def reduce_to_masters_multi(self, partials, dev, combine: str = "sum",
                                state=()):
        l_max = partials.shape[1]
        send = _pack_multi(partials, dev["halo_send"], combine)
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, N, H_max)
        agg = _segment_combine_multi(recv, dev["halo_recv"], l_max + 1,
                                     combine)
        return _merge(partials, agg, combine), state

    def broadcast_from_masters_multi(self, new_masters, dev,
                                     combine: str = "sum", state=()):
        send = _pack_multi(new_masters, dev["halo_recv"], combine)
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, N, H_max)
        return _unpack_multi(new_masters, recv, dev), state

    def reduce_stacked_multi(self, partials, dev, combine: str = "sum",
                             state=()):
        l_max = partials.shape[2]
        send = jax.vmap(
            lambda v, idx: _pack_multi(v, idx, combine)
        )(partials, dev["halo_send"])                   # (k, k, N, H_max)
        recv = jnp.swapaxes(send, 0, 1)
        agg = jax.vmap(
            lambda r, s: _segment_combine_multi(r, s, l_max + 1, combine)
        )(recv, dev["halo_recv"])
        return _merge(partials, agg, combine), state

    def broadcast_stacked_multi(self, masters, dev, combine: str = "sum",
                                state=()):
        send = jax.vmap(
            lambda v, idx: _pack_multi(v, idx, combine)
        )(masters, dev["halo_recv"])                    # (k, k, N, H_max)
        recv = jnp.swapaxes(send, 0, 1)
        return jax.vmap(
            lambda m, r, d: _unpack_multi(m, r, d)
        )(masters, recv, dev), state

    def bytes_per_iter(self, layout, value_bytes: int = 4) -> int:
        return layout.comm_bytes_halo(value_bytes)


def lossy_payload(combine: str, dtype) -> bool:
    """Whether the quantized backend may delta-code a program's payload:
    only fp sum-combine values tolerate lossy codes — min-combine and
    integer payloads (CC labels) must ship exact.  The one rule the
    exchange, the dry-run byte models, and the CI gate all derive from."""
    return combine == "sum" and jnp.issubdtype(jnp.dtype(dtype),
                                               jnp.floating)


def _ef_encode_fused(lanes, sref, sres):
    """Error-feedback delta encoder for the fused (multi-program) wire:
    int4 codes nibble-packed two-per-byte along the (even) lane axis,
    fp16 scales over ``_NUM_SCALE_GROUPS`` subgroups per (destination,
    program) lane row.  Same lockstep reference/residual algebra as
    ``_ef_encode``; only the code width, scale granularity, and packing
    differ — H/2 + 16 wire bytes per row vs. the separate int8 steps'
    H + 4, the fused driver's < 0.6× byte win."""
    err = lanes - sref + sres
    codes, scales = _quantize_groups(err)
    deq = _dequantize_groups(codes, scales)
    return sref + deq, err - deq, _nibble_pack(codes), scales


def _ef_decode_fused(packed, scales):
    return _dequantize_groups(_nibble_unpack(packed), scales)


def _ef_encode(lanes, sref, sres):
    """Error-feedback delta encoder for one phase's send lanes.

    err = (lanes − sref) + sres is what the receiver is missing plus the
    carried quantization error; it quantizes per lane group, both
    endpoints advance their reference by the identical dequantized step
    (sref ← sref + deq), and the un-sent remainder becomes the next
    iteration's residual — so sref tracks lanes with an unbiased, shrinking
    error as the program converges."""
    err = lanes - sref + sres
    codes, scales = quantize_rows(err)
    deq = dequantize_rows(codes, scales)
    return sref + deq, err - deq, codes, scales


@dataclass(frozen=True)
class QuantizedHaloExchange:
    """Halo routing with an int8 delta-coded payload (error feedback).

    Same static lane tables as ``HaloExchange``; the wire payload per
    phase is (k, H_max) int8 codes + (k,) fp32 per-lane-group scales —
    ~4× fewer bytes than the fp32 halo lanes.  Each endpoint pair keeps a
    reconstruction reference per lane (``sref`` on the sender, ``rref``
    on the receiver) advanced in lockstep by the dequantized delta, and
    the sender carries the quantization error in ``sres`` (error
    feedback), so a converging fixed-point iteration (pagerank) lands on
    the exact fixed point instead of dithering at one quantization step.

    ``combine="min"`` / integer payloads (CC labels) are exact in int32
    already — quantizing would corrupt the min lattice — so those
    programs get the plain halo wire format (``init_state`` returns the
    empty state and every op delegates).
    """
    axis: str | None = None
    name = "quantized"

    @property
    def _exact(self) -> HaloExchange:
        return HaloExchange(axis=self.axis)

    def init_state(self, dev, dtype, combine: str = "sum"):
        if not lossy_payload(combine, dtype):
            return ()
        zeros = jnp.zeros(dev["halo_send"].shape, jnp.float32)
        lane_state = {"sref": zeros, "sres": zeros, "rref": zeros}
        return {"reduce": lane_state, "bcast": dict(lane_state)}

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum",
                          state=()):
        if not state:
            return self._exact.reduce_to_masters(partial, dev, combine,
                                                 state)
        st = state["reduce"]
        l_max = partial.shape[0]
        lanes = _pack(partial, dev["halo_send"], combine)
        sref, sres, codes, scales = _ef_encode(lanes, st["sref"],
                                               st["sres"])
        rcodes = jax.lax.all_to_all(codes, self.axis, 0, 0)   # int8 wire
        rscales = jax.lax.all_to_all(scales, self.axis, 0, 0)
        rref = st["rref"] + dequantize_rows(rcodes, rscales)
        agg = _segment_combine(rref.reshape(-1),
                               dev["halo_recv"].reshape(-1),
                               l_max + 1, combine)[:l_max]
        total = _merge(partial, agg, combine)
        return total, {**state, "reduce": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def broadcast_from_masters(self, new_master, dev, combine: str = "sum",
                               state=()):
        if not state:
            return self._exact.broadcast_from_masters(new_master, dev,
                                                      combine, state)
        st = state["bcast"]
        lanes = _pack(new_master, dev["halo_recv"], combine)
        sref, sres, codes, scales = _ef_encode(lanes, st["sref"],
                                               st["sres"])
        rcodes = jax.lax.all_to_all(codes, self.axis, 0, 0)   # int8 wire
        rscales = jax.lax.all_to_all(scales, self.axis, 0, 0)
        rref = st["rref"] + dequantize_rows(rcodes, rscales)
        values = _unpack(new_master, rref, dev)
        return values, {**state, "bcast": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    # -- stacked halves: all_to_all over k virtual devices == transpose --
    def reduce_stacked(self, partials, dev, combine: str = "sum", state=()):
        if not state:
            return self._exact.reduce_stacked(partials, dev, combine,
                                              state)
        st = state["reduce"]
        l_max = partials.shape[1]
        lanes = jax.vmap(
            lambda v, idx: _pack(v, idx, combine)
        )(partials, dev["halo_send"])                       # (k, k, H_max)
        sref, sres, codes, scales = _ef_encode(lanes, st["sref"],
                                               st["sres"])
        rref = st["rref"] + dequantize_rows(jnp.swapaxes(codes, 0, 1),
                                            jnp.swapaxes(scales, 0, 1))

        def one(rref_q, slots_q, partial_q):
            agg = _segment_combine(rref_q.reshape(-1), slots_q.reshape(-1),
                                   l_max + 1, combine)[:l_max]
            return _merge(partial_q, agg, combine)

        total = jax.vmap(one)(rref, dev["halo_recv"], partials)
        return total, {**state, "reduce": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def broadcast_stacked(self, masters, dev, combine: str = "sum",
                          state=()):
        if not state:
            return self._exact.broadcast_stacked(masters, dev, combine,
                                                 state)
        st = state["bcast"]
        lanes = jax.vmap(
            lambda v, idx: _pack(v, idx, combine)
        )(masters, dev["halo_recv"])                        # (k, k, H_max)
        sref, sres, codes, scales = _ef_encode(lanes, st["sref"],
                                               st["sres"])
        rref = st["rref"] + dequantize_rows(jnp.swapaxes(codes, 0, 1),
                                            jnp.swapaxes(scales, 0, 1))
        values = jax.vmap(
            lambda m, r, d: _unpack(m, r, d)
        )(masters, rref, dev)
        return values, {**state, "bcast": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    # -- multi-lane halves: the fused wire format (int4 packed codes) --
    def init_state_multi(self, dev, dtype, combine: str, n: int):
        if not lossy_payload(combine, dtype):
            return ()
        # program axis slots in before the lane axis, so the same state
        # pytree serves the per-device ((k, H) tables → (k, N, H) state)
        # and stacked ((k, k, H) → (k, k, N, H)) forms
        shape = dev["halo_send"].shape
        zeros = jnp.zeros((*shape[:-1], n, shape[-1]), jnp.float32)
        lane_state = {"sref": zeros, "sres": zeros, "rref": zeros}
        return {"reduce": lane_state, "bcast": dict(lane_state)}

    def reduce_to_masters_multi(self, partials, dev, combine: str = "sum",
                                state=()):
        if not state:
            return self._exact.reduce_to_masters_multi(partials, dev,
                                                       combine, state)
        st = state["reduce"]
        l_max = partials.shape[1]
        lanes = _pack_multi(partials, dev["halo_send"], combine)
        sref, sres, packed, scales = _ef_encode_fused(lanes, st["sref"],
                                                      st["sres"])
        rpacked = jax.lax.all_to_all(packed, self.axis, 0, 0)  # int4 wire
        rscales = jax.lax.all_to_all(scales, self.axis, 0, 0)
        rref = st["rref"] + _ef_decode_fused(rpacked, rscales)
        agg = _segment_combine_multi(rref, dev["halo_recv"], l_max + 1,
                                     combine)
        total = _merge(partials, agg, combine)
        return total, {**state, "reduce": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def broadcast_from_masters_multi(self, new_masters, dev,
                                     combine: str = "sum", state=()):
        if not state:
            return self._exact.broadcast_from_masters_multi(
                new_masters, dev, combine, state)
        st = state["bcast"]
        lanes = _pack_multi(new_masters, dev["halo_recv"], combine)
        sref, sres, packed, scales = _ef_encode_fused(lanes, st["sref"],
                                                      st["sres"])
        rpacked = jax.lax.all_to_all(packed, self.axis, 0, 0)  # int4 wire
        rscales = jax.lax.all_to_all(scales, self.axis, 0, 0)
        rref = st["rref"] + _ef_decode_fused(rpacked, rscales)
        values = _unpack_multi(new_masters, rref, dev)
        return values, {**state, "bcast": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def reduce_stacked_multi(self, partials, dev, combine: str = "sum",
                             state=()):
        if not state:
            return self._exact.reduce_stacked_multi(partials, dev,
                                                    combine, state)
        st = state["reduce"]
        l_max = partials.shape[2]
        lanes = jax.vmap(
            lambda v, idx: _pack_multi(v, idx, combine)
        )(partials, dev["halo_send"])                   # (k, k, N, H_max)
        sref, sres, packed, scales = _ef_encode_fused(lanes, st["sref"],
                                                      st["sres"])
        rref = st["rref"] + _ef_decode_fused(jnp.swapaxes(packed, 0, 1),
                                             jnp.swapaxes(scales, 0, 1))
        agg = jax.vmap(
            lambda r, s: _segment_combine_multi(r, s, l_max + 1, combine)
        )(rref, dev["halo_recv"])
        total = _merge(partials, agg, combine)
        return total, {**state, "reduce": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def broadcast_stacked_multi(self, masters, dev, combine: str = "sum",
                                state=()):
        if not state:
            return self._exact.broadcast_stacked_multi(masters, dev,
                                                       combine, state)
        st = state["bcast"]
        lanes = jax.vmap(
            lambda v, idx: _pack_multi(v, idx, combine)
        )(masters, dev["halo_recv"])                    # (k, k, N, H_max)
        sref, sres, packed, scales = _ef_encode_fused(lanes, st["sref"],
                                                      st["sres"])
        rref = st["rref"] + _ef_decode_fused(jnp.swapaxes(packed, 0, 1),
                                             jnp.swapaxes(scales, 0, 1))
        values = jax.vmap(
            lambda m, r, d: _unpack_multi(m, r, d)
        )(masters, rref, dev)
        return values, {**state, "bcast": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def bytes_per_iter(self, layout, value_bytes: int = 4,
                       combine: str = "sum", dtype=jnp.float32) -> int:
        if not lossy_payload(combine, dtype):
            return layout.comm_bytes_halo(value_bytes)   # exact passthrough
        # the lossy wire format is fixed by quantize_rows: int8 codes +
        # one fp32 scale per lane group, whatever the value dtype was
        return layout.comm_bytes_halo_quantized()


EXCHANGES = {"dense": DenseExchange, "halo": HaloExchange,
             "quantized": QuantizedHaloExchange}


def get_exchange(name: str, axis: str | None = None):
    """Exchange factory: ``name`` ∈ {"dense", "halo", "quantized"};
    ``axis`` is the mesh axis for the shard_map halves (stacked halves
    ignore it)."""
    try:
        cls = EXCHANGES[name]
    except KeyError:
        raise ValueError(
            f"unknown exchange {name!r}; expected one of "
            f"{sorted(EXCHANGES)}") from None
    return cls(axis=axis)
