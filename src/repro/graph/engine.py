"""Distributed vertex-cut GAS engine (PowerGraph semantics) on shard_map.

Per iteration (paper §II-B): local scatter/gather over the partition's edges
(segment_sum — the ``csr_spmv`` Pallas kernel's op), mirror partials reduced
to masters (all_gather #1 + static ``red_index`` segment reduce), masters
apply, new values broadcast back to mirrors (all_gather #2 + static
``(owner, own_slot)`` gather).  Communication per iteration is two
all_gathers of (k, L_max) values — ∝ replication factor, the quantity the
partitioner optimizes (Fig. 8's mechanism, in bytes).

Two drivers around the same per-device halves:

- ``simulate_*``   : stacked (k, …) arrays on one device — used by tests
                     and host-side benchmarks (bit-identical math).
- ``shard_map_*``  : one partition per mesh device over axis ``parts`` —
                     the production path (multi-pod dry-run lowers this).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .partition import PartitionLayout
from ..dist._compat import shard_map

DAMPING = 0.85


# ----------------------------------------------------------- per-device math

def _local_rank_partial(rank, dev):
    """Scatter phase: Σ_{(u,w)∈E_p, w=v} rank[u]/outdeg[u] per local slot."""
    l_max = dev["vert_gid"].shape[0]
    safe_deg = jnp.maximum(dev["out_deg"], 1)
    contrib = jnp.where(dev["vert_mask"] & (dev["out_deg"] > 0),
                        rank / safe_deg, 0.0)
    contrib = jnp.concatenate([contrib, jnp.zeros((1,), contrib.dtype)])
    per_edge = jnp.where(dev["edge_mask"], contrib[dev["edge_src"]], 0.0)
    return jax.ops.segment_sum(per_edge, dev["edge_dst"],
                               num_segments=l_max + 1)[:l_max]


def _local_dangle(rank, dev):
    """Rank mass sitting on dangling masters (out_deg == 0)."""
    m = dev["vert_mask"] & dev["is_master"] & (dev["out_deg"] == 0)
    return jnp.sum(jnp.where(m, rank, 0.0))


def _reduce_to_master(flat_gathered, dev, combine="sum"):
    l_max = dev["vert_gid"].shape[0]
    if combine == "sum":
        return jax.ops.segment_sum(flat_gathered, dev["red_index"],
                                   num_segments=l_max + 1)[:l_max]
    return jax.ops.segment_min(flat_gathered, dev["red_index"],
                               num_segments=l_max + 1)[:l_max]


def _broadcast_from_master(gathered, dev):
    """gathered: (k, L_max) master values; pick (owner, own_slot)."""
    return gathered[dev["owner"], dev["own_slot"]]


def _pagerank_apply(total_in, dangle, dev, num_vertices):
    base = (1.0 - DAMPING) / num_vertices
    new = base + DAMPING * (total_in + dangle / num_vertices)
    return jnp.where(dev["vert_mask"] & dev["is_master"], new, 0.0)


def _cc_local_min(label, dev):
    """Edge-wise min exchange in both directions (undirected semantics)."""
    l_max = dev["vert_gid"].shape[0]
    big = jnp.asarray(np.float32(np.inf))
    lab = jnp.concatenate([jnp.where(dev["vert_mask"], label, big),
                           jnp.full((1,), big, label.dtype)])
    s, d, m = dev["edge_src"], dev["edge_dst"], dev["edge_mask"]
    vs = jnp.where(m, lab[s], big)
    vd = jnp.where(m, lab[d], big)
    out = jax.ops.segment_min(vs, d, num_segments=l_max + 1)[:l_max]
    out2 = jax.ops.segment_min(vd, s, num_segments=l_max + 1)[:l_max]
    cur = jnp.where(dev["vert_mask"], label, big)
    return jnp.minimum(cur, jnp.minimum(out, out2))


# ----------------------------------------------------------- simulated driver

def _stack_dev(layout: PartitionLayout):
    return jax.tree_util.tree_map(jnp.asarray, layout.device_arrays())


@partial(jax.jit, static_argnames=("iters", "num_vertices"))
def _sim_pagerank(dev, iters: int, num_vertices: int):
    k, l_max = dev["vert_gid"].shape
    rank = jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)

    def body(_, rank):
        partial_ = jax.vmap(_local_rank_partial)(rank, dev)
        flat = partial_.reshape(-1)
        total = jax.vmap(lambda d: _reduce_to_master(flat, d))(
            jax.tree_util.tree_map(lambda x: x, dev))
        dangle = jnp.sum(jax.vmap(_local_dangle)(rank, dev))
        new_master = jax.vmap(
            lambda t, d: _pagerank_apply(t, dangle, d, num_vertices)
        )(total, dev)
        return jax.vmap(lambda d: _broadcast_from_master(new_master, d))(dev)

    return jax.lax.fori_loop(0, iters, body, rank)


@partial(jax.jit, static_argnames=("iters",))
def _sim_cc(dev, iters: int):
    label = jnp.where(dev["vert_mask"], dev["vert_gid"].astype(jnp.float32),
                      jnp.float32(np.inf))

    def body(_, label):
        part = jax.vmap(_cc_local_min)(label, dev)
        flat = part.reshape(-1)
        flat = jnp.where(jnp.isfinite(flat), flat, jnp.float32(3e38))
        total = jax.vmap(lambda d: _reduce_to_master(flat, d, "min"))(dev)
        new_master = jnp.where(dev["vert_mask"] & dev["is_master"], total,
                               jnp.float32(3e38))
        return jax.vmap(lambda d: _broadcast_from_master(new_master, d))(dev)

    return jax.lax.fori_loop(0, iters, body, label)


def _collect_master_values(layout: PartitionLayout, stacked) -> np.ndarray:
    """(k, L_max) per-device values → dense (V,) using master slots."""
    vals = np.asarray(stacked)
    out = np.zeros(layout.num_vertices, dtype=vals.dtype)
    gid = layout.vert_gid
    sel = layout.is_master & layout.vert_mask
    out[gid[sel]] = vals[sel]
    return out


def simulate_pagerank(layout: PartitionLayout, iters: int = 30) -> np.ndarray:
    dev = _stack_dev(layout)
    ranks = _sim_pagerank(dev, iters, layout.num_vertices)
    return _collect_master_values(layout, ranks)


def simulate_cc(layout: PartitionLayout, iters: int = 30) -> np.ndarray:
    dev = _stack_dev(layout)
    labels = _sim_cc(dev, iters)
    return _collect_master_values(layout, labels).astype(np.int64)


# ----------------------------------------------------------- shard_map driver

def shard_map_pagerank(layout: PartitionLayout, mesh: Mesh,
                       iters: int = 30, axis: str = "parts"):
    """Production path: one partition per device along ``axis``.
    Requires mesh axis size == layout.k.  Returns (V,) master ranks plus the
    lowered/compiled step for inspection (dry-run hooks read its HLO)."""
    dev = _stack_dev(layout)
    num_vertices = layout.num_vertices
    spec = P(axis)

    @partial(shard_map, mesh=mesh,
             in_specs=(spec, jax.tree_util.tree_map(lambda _: spec, dev)),
             out_specs=spec)
    def run(rank, dev):
        rank = rank[0]
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)

        def body(_, rank):
            partial_ = _local_rank_partial(rank, dev)
            g = jax.lax.all_gather(partial_, axis)          # (k, L_max)
            total = _reduce_to_master(g.reshape(-1), dev)
            dangle = jax.lax.psum(_local_dangle(rank, dev), axis)
            new_master = _pagerank_apply(total, dangle, dev, num_vertices)
            g2 = jax.lax.all_gather(new_master, axis)       # (k, L_max)
            return _broadcast_from_master(g2, dev)

        out = jax.lax.fori_loop(0, iters, body, rank)
        return out[None]

    rank0 = jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)
    with mesh:
        ranks = run(rank0, dev)
    return _collect_master_values(layout, ranks)


def pagerank_step_for_dryrun(layout: PartitionLayout, mesh: Mesh,
                             axis: str = "parts", iters: int = 1):
    """Returns (jitted_fn, example_args) whose .lower() the dry-run compiles."""
    dev = _stack_dev(layout)
    num_vertices = layout.num_vertices
    spec = P(axis)

    @partial(shard_map, mesh=mesh,
             in_specs=(spec, jax.tree_util.tree_map(lambda _: spec, dev)),
             out_specs=spec)
    def step(rank, dev):
        rank = rank[0]
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)

        def body(_, rank):
            partial_ = _local_rank_partial(rank, dev)
            g = jax.lax.all_gather(partial_, axis)
            total = _reduce_to_master(g.reshape(-1), dev)
            dangle = jax.lax.psum(_local_dangle(rank, dev), axis)
            new_master = _pagerank_apply(total, dangle, dev, num_vertices)
            g2 = jax.lax.all_gather(new_master, axis)
            return _broadcast_from_master(g2, dev)

        return jax.lax.fori_loop(0, iters, body, rank)[None]

    rank0 = jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)
    return jax.jit(step), (rank0, dev)


# ----------------------------------------------------------- oracles

def reference_pagerank(src, dst, num_vertices, iters: int = 30) -> np.ndarray:
    """Dense single-machine oracle with identical dangling handling."""
    outdeg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(outdeg, src, 1)
    rank = np.full(num_vertices, 1.0 / num_vertices)
    base = (1.0 - DAMPING) / num_vertices
    for _ in range(iters):
        contrib = np.where(outdeg > 0, rank / np.maximum(outdeg, 1), 0.0)
        s = np.zeros(num_vertices)
        np.add.at(s, dst, contrib[src])
        dangle = rank[outdeg == 0].sum()
        rank = base + DAMPING * (s + dangle / num_vertices)
    return rank


def reference_cc(src, dst, num_vertices) -> np.ndarray:
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components
    A = sp.coo_matrix((np.ones(len(src)), (src, dst)),
                      shape=(num_vertices, num_vertices))
    _, comp = connected_components(A, directed=False)
    # canonical label: min vertex id of the component (what min-label finds)
    mins = np.full(comp.max() + 1, num_vertices, dtype=np.int64)
    np.minimum.at(mins, comp, np.arange(num_vertices))
    return mins[comp]
