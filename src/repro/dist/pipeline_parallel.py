"""GPipe-style pipeline parallelism over a named "stage" mesh axis.

``pipeline_apply`` shards stacked per-stage parameters (leading dim = S
stages) across the axis and streams M microbatches through the ring with
``ppermute``: tick t has stage s working on microbatch t−s, so the
pipeline fills in S−1 ticks and drains in S−1 — M+S−1 ticks total versus
M·S sequential.  ``reference_apply`` is the single-device oracle.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ._compat import shard_map


def reference_apply(stacked_params, xs, fn):
    """Sequentially run every microbatch through all stages.

    stacked_params: pytree with leading stage dim S; xs: (M, mb, ...);
    fn(x, stage_params) → x.  Returns (M, mb, ...).
    """
    def one(x):
        def step(carry, p):
            return fn(carry, p), None
        y, _ = jax.lax.scan(step, x, stacked_params)
        return y

    return jax.vmap(one)(xs)


def pipeline_apply(mesh, axis: str, stacked_params, xs, fn):
    """Run ``fn`` as an S-stage pipeline on ``mesh[axis]``.

    stacked_params leaves have leading dim S == mesh.shape[axis] and are
    sharded one stage per device; xs (M, mb, ...) microbatches are
    replicated (stage 0 consumes them in order).  Returns the (M, mb, ...)
    outputs of the last stage, replicated.
    """
    S = mesh.shape[axis]
    M = xs.shape[0]
    ticks = M + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]

    def local(p_local, xs_all):
        p_local = jax.tree_util.tree_map(lambda a: a[0], p_local)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs_all.shape[1:]
        state0 = jnp.zeros(mb_shape, xs_all.dtype)
        out0 = jnp.zeros((M,) + mb_shape, xs_all.dtype)

        def tick(carry, t):
            state, outputs = carry
            # stage s receives stage s−1's previous output; stage 0 feeds
            # the next microbatch (clipped reads are never committed)
            prev = jax.lax.ppermute(state, axis, perm)
            fresh = jax.lax.dynamic_index_in_dim(
                xs_all, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            x_in = jnp.where(stage == 0, fresh, prev)
            out = fn(x_in, p_local)
            mb = t - (S - 1)
            write = (stage == S - 1) & (mb >= 0)
            mb_c = jnp.clip(mb, 0, M - 1)
            cur = jax.lax.dynamic_index_in_dim(outputs, mb_c, 0,
                                               keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(write, out, cur), mb_c, 0)
            return (out, outputs), None

        (_, outputs), _ = jax.lax.scan(
            tick, (state0, out0), jnp.arange(ticks))
        # only the last stage wrote; psum replicates its buffer
        return jax.lax.psum(outputs, axis)

    fn_sharded = partial(
        shard_map, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(),
        check_vma=False)(local)
    return fn_sharded(stacked_params, xs)
