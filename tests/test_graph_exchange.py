"""Exchange-layer tests: vectorized build_layout vs the retained reference
builder, halo routing-table invariants, and halo-vs-dense engine
equivalence on random graphs under 8 virtual (stacked) devices."""
import dataclasses

import numpy as np
import pytest

from repro.core import CLUGPConfig, partition
from repro.core.graphgen import web_graph
from repro.graph import (build_layout, build_layout_reference,
                         reference_cc, reference_pagerank, simulate_cc,
                         simulate_pagerank)

from conftest import random_graph_and_assign as _random_graph_and_assign


# ------------------------------------------------------- layout equivalence

@pytest.mark.parametrize("seed,k", [(0, 2), (1, 4), (2, 8), (3, 7)])
def test_vectorized_layout_matches_reference(seed, k):
    src, dst, n, assign = _random_graph_and_assign(seed, k)
    vec = build_layout(src, dst, assign, n, k)
    ref = build_layout_reference(src, dst, assign, n, k)
    for f in dataclasses.fields(vec):
        a, b = getattr(vec, f.name), getattr(ref, f.name)
        if isinstance(a, np.ndarray):
            np.testing.assert_array_equal(a, b, err_msg=f.name)
        else:
            assert a == b, (f.name, a, b)


def test_vectorized_layout_matches_reference_on_partition():
    g = web_graph(scale=9, edge_factor=6, seed=1)
    k = 8
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(k))
    vec = build_layout(g.src, g.dst, res.assign, g.num_vertices, k)
    ref = build_layout_reference(g.src, g.dst, res.assign,
                                 g.num_vertices, k)
    for f in dataclasses.fields(vec):
        a, b = getattr(vec, f.name), getattr(ref, f.name)
        if isinstance(a, np.ndarray):
            np.testing.assert_array_equal(a, b, err_msg=f.name)
        else:
            assert a == b, (f.name, a, b)


def test_layout_sparse_lookup_path_matches_dense():
    """The searchsorted fallback (k·V over the dense-map budget) produces
    the same tables as the dense inverse map: same edges/assignment, but an
    id space big enough that k·V exceeds 1<<25."""
    src, dst, n, assign = _random_graph_and_assign(7, 4, n=120)
    dense = build_layout(src, dst, assign, n, 4)
    big_n = (1 << 25) // 4 + 1
    sparse = build_layout(src, dst, assign, big_n, 4)
    for f in ("edge_src", "edge_dst", "edge_mask", "is_master",
              "own_slot", "halo_send", "halo_recv"):
        np.testing.assert_array_equal(getattr(dense, f),
                                      getattr(sparse, f), err_msg=f)
    np.testing.assert_array_equal(
        dense.vert_gid[dense.vert_mask], sparse.vert_gid[sparse.vert_mask])
    assert dense.mirrors_total == sparse.mirrors_total


# ------------------------------------------------- routing-table invariants

@pytest.mark.parametrize("seed,k", [(0, 4), (5, 8)])
def test_halo_routing_invariants(seed, k):
    src, dst, n, assign = _random_graph_and_assign(seed, k)
    lay = build_layout(src, dst, assign, n, k)
    pad = lay.l_max
    valid_send = lay.halo_send != pad
    valid_recv = lay.halo_recv != pad

    # send/recv lanes pair up exactly: lane (p,q,h) is populated on the
    # sender iff (q,p,h) is populated on the receiver
    np.testing.assert_array_equal(
        valid_send, np.swapaxes(valid_recv, 0, 1))

    # every mirror slot is routed exactly once, and only mirror slots are
    mirror_slots = lay.vert_mask & ~lay.is_master
    for p in range(k):
        sent = lay.halo_send[p][valid_send[p]]
        assert len(sent) == len(set(sent.tolist())), "duplicate send lane"
        np.testing.assert_array_equal(
            np.sort(sent), np.flatnonzero(mirror_slots[p]))
        # no device sends to itself
        assert not valid_send[p, p].any()

    # total routed lanes == mirror count; pads vanish from the count
    assert int(valid_send.sum()) == lay.mirrors_total

    # each lane references the same vertex on both endpoints, and the recv
    # side lands on a master slot of that vertex's owner
    for p in range(k):
        for q in range(k):
            for h in np.flatnonzero(valid_send[p, q]):
                s_slot = lay.halo_send[p, q, h]
                r_slot = lay.halo_recv[q, p, h]
                gid = lay.vert_gid[p, s_slot]
                assert lay.vert_gid[q, r_slot] == gid
                assert lay.is_master[q, r_slot]
                assert lay.owner[p, s_slot] == q


def test_comm_model_halo_between_ideal_and_dense():
    g = web_graph(scale=10, edge_factor=8, seed=0)
    k = 8
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(k))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, k)
    # every mirror has exactly one lane, so the ragged ideal bounds the
    # padded halo volume from below, and the halo volume undercuts the
    # dense k²·L_max slab on any real partition
    assert lay.comm_bytes("ideal") <= lay.comm_bytes("halo")
    assert lay.comm_bytes("halo") < lay.comm_bytes("dense")


# ------------------------------------------------- halo vs dense equivalence

@pytest.mark.parametrize("seed", [0, 1])
def test_simulated_pagerank_halo_matches_dense_and_reference(seed):
    src, dst, n, assign = _random_graph_and_assign(seed, 8, n=400)
    lay = build_layout(src, dst, assign, n, 8)
    ref = reference_pagerank(src, dst, n, iters=25)
    pr_dense = simulate_pagerank(lay, iters=25, exchange="dense")
    pr_halo = simulate_pagerank(lay, iters=25, exchange="halo")
    assert np.abs(pr_dense - ref).max() < 1e-6
    assert np.abs(pr_halo - ref).max() < 1e-6
    assert np.abs(pr_halo - pr_dense).max() < 1e-6


@pytest.mark.parametrize("seed", [0, 1])
def test_simulated_cc_halo_matches_dense_and_reference(seed):
    src, dst, n, assign = _random_graph_and_assign(seed, 8, n=400)
    lay = build_layout(src, dst, assign, n, 8)
    ref = reference_cc(src, dst, n)
    cc_dense = simulate_cc(lay, iters=40, exchange="dense")
    cc_halo = simulate_cc(lay, iters=40, exchange="halo")
    touched = np.zeros(n, bool)
    touched[src] = touched[dst] = True
    np.testing.assert_array_equal(cc_dense[touched], ref[touched])
    np.testing.assert_array_equal(cc_halo[touched], ref[touched])


def test_unknown_exchange_rejected():
    from repro.dist.halo import get_exchange
    with pytest.raises(ValueError, match="unknown exchange"):
        get_exchange("sparse-magic")
    # the engine drivers surface the same error (not a bare KeyError)
    src, dst, n, assign = _random_graph_and_assign(0, 4, n=50)
    lay = build_layout(src, dst, assign, n, 4)
    with pytest.raises(ValueError, match="unknown exchange"):
        simulate_pagerank(lay, iters=1, exchange="sparse-magic")


# ------------------------------------------------- satellite regression

def test_parallel_partition_zero_edges_raises_value_error():
    empty = np.zeros(0, dtype=np.int64)
    with pytest.raises(ValueError, match="zero|empty"):
        partition(empty, empty, 10, CLUGPConfig(k=4))


def test_parallel_partition_tiny_stream_still_works():
    # fewer edges than nodes ⇒ some slices empty; must not crash
    src = np.array([0, 1], dtype=np.int64)
    dst = np.array([1, 2], dtype=np.int64)
    res = partition(src, dst, 3, CLUGPConfig(k=2), nodes=4)
    assert res.assign.shape == (2,)
