"""Synthetic power-law graph generators + BFS stream ordering.

The paper evaluates on real web crawls (uk-2002, arabic-2005, webbase-2001,
it-2004) streamed in BFS order, and one social graph (Twitter).  Offline we
generate graphs in the same degree-law regime:

- ``rmat``       : Kronecker/R-MAT recursive generator — web-graph-like,
                   heavy-tailed in/out degrees (Chakrabarti et al., SDM'04).
- ``barabasi``   : preferential attachment — social-graph-like.
- ``bfs_order``  : relabels vertices by BFS discovery and orders the edge
                   stream the way a crawler would emit it (paper §II fn. 1).
"""
from __future__ import annotations

import numpy as np
from collections import deque
from dataclasses import dataclass


@dataclass(frozen=True)
class Graph:
    """An edge-streamed directed graph.  src/dst are int32 arrays."""
    src: np.ndarray
    dst: np.ndarray
    num_vertices: int

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.num_vertices, dtype=np.int64)
        np.add.at(deg, self.src, 1)
        np.add.at(deg, self.dst, 1)
        return deg


def _dedupe(src: np.ndarray, dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    keep = src != dst                      # drop self loops
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * (int(max(dst.max(), src.max())) + 1) + dst
    _, idx = np.unique(key, return_index=True)
    idx.sort()                             # preserve stream order of first occurrence
    return src[idx], dst[idx]


def _compact(src: np.ndarray, dst: np.ndarray) -> Graph:
    """Relabel vertices to a dense 0..V-1 range (drop isolated ids)."""
    verts, inv = np.unique(np.concatenate([src, dst]), return_inverse=True)
    n = verts.shape[0]
    return Graph(inv[: src.shape[0]].astype(np.int32),
                 inv[src.shape[0]:].astype(np.int32), int(n))


def rmat(scale: int, edge_factor: int = 8, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> Graph:
    """R-MAT generator; scale = log2(#vertices)."""
    rng = np.random.default_rng(seed)
    n_edges = edge_factor * (1 << scale)
    src = np.zeros(n_edges, dtype=np.int64)
    dst = np.zeros(n_edges, dtype=np.int64)
    for level in range(scale):
        r = rng.random(n_edges)
        src_bit = (r >= a + b).astype(np.int64)
        # conditional distribution of dst bit given src bit
        p_dst1_given_src0 = b / (a + b)
        p_dst1_given_src1 = (1.0 - a - b - c) / max(1.0 - a - b, 1e-12)
        r2 = rng.random(n_edges)
        dst_bit = np.where(src_bit == 0, (r2 < p_dst1_given_src0),
                           (r2 < p_dst1_given_src1)).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    src, dst = _dedupe(src, dst)
    return _compact(src, dst)


def barabasi(n: int, m: int = 4, seed: int = 0) -> Graph:
    """Barabási–Albert preferential attachment (directed new→old)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m))
    repeated: list[int] = list(range(m))
    src_l: list[int] = []
    dst_l: list[int] = []
    for v in range(m, n):
        chosen = set()
        while len(chosen) < m:
            chosen.add(int(repeated[rng.integers(len(repeated))])
                       if repeated else int(rng.integers(v)))
        for t in chosen:
            src_l.append(v)
            dst_l.append(t)
            repeated.extend([v, t])
    src, dst = _dedupe(np.asarray(src_l, dtype=np.int64),
                       np.asarray(dst_l, dtype=np.int64))
    return _compact(src, dst)


def bfs_order(g: Graph) -> Graph:
    """Relabel by BFS discovery order and emit the edge stream crawler-style:
    all out-edges of a vertex appear when the vertex is dequeued (Fig. 2)."""
    n, e = g.num_vertices, g.num_edges
    # undirected adjacency in CSR form
    u = np.concatenate([g.src, g.dst])
    v = np.concatenate([g.dst, g.src])
    order = np.argsort(u, kind="stable")
    u, v = u[order], v[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, u + 1, 1)
    np.cumsum(indptr, out=indptr)

    deg = g.degrees()
    seen = np.zeros(n, dtype=bool)
    rank = np.full(n, -1, dtype=np.int64)
    nxt = 0
    # start from the highest-degree vertex of each component (crawl seeds)
    seeds = np.argsort(-deg)
    q: deque[int] = deque()
    for s in seeds:
        s = int(s)
        if seen[s]:
            continue
        seen[s] = True
        q.append(s)
        while q:
            x = q.popleft()
            rank[x] = nxt
            nxt += 1
            for y in v[indptr[x]:indptr[x + 1]]:
                y = int(y)
                if not seen[y]:
                    seen[y] = True
                    q.append(y)
    src = rank[g.src]
    dst = rank[g.dst]
    # stream order: lexicographic by (bfs rank of src, bfs rank of dst)
    order = np.lexsort((dst, src))
    return Graph(src[order].astype(np.int32), dst[order].astype(np.int32), n)


def community_web(n: int, avg_deg: int = 10, avg_site: int = 40,
                  beta: float = 0.08, alpha: float = 2.1,
                  seed: int = 0) -> Graph:
    """Web-crawl-like generator: power-law degrees *and* strong host-level
    locality.  Pages on the same site link densely; a fraction ``beta`` of
    links cross sites, preferentially toward hub pages.  This is the regime
    the paper's premise targets ("the property of web graph clustering"):
    real crawls (uk-2002 etc.) have >90% intra-host links.

    - site sizes ~ power law, capped
    - per-page out-degree ~ zipf(alpha)
    - cross-site targets ~ degree-preferential (power-law in-degree hubs)
    """
    rng = np.random.default_rng(seed)
    # carve [0,n) into sites with power-law sizes
    sizes = []
    total = 0
    while total < n:
        s = min(int(rng.pareto(1.6) * avg_site / 2.0) + 4, n - total, 40 * avg_site)
        sizes.append(s)
        total += s
    starts = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    site_of = np.repeat(np.arange(len(sizes)), sizes)[:n]
    site_start = starts[site_of]
    site_size = np.asarray(sizes)[site_of]

    out_deg = np.minimum(rng.zipf(alpha, size=n) + avg_deg // 2, 10 * avg_deg)
    m_total = int(out_deg.sum())
    src = np.repeat(np.arange(n, dtype=np.int64), out_deg)
    cross = rng.random(m_total) < beta
    # intra-site target: uniform within the source's site
    tgt_local = (site_start[src]
                 + rng.integers(0, np.maximum(site_size[src], 1)))
    # cross-site target: preferential to global hubs (power-law ranks)
    dst = tgt_local.astype(np.int64)
    dst[cross] = rng.zipf(1.5, size=int(cross.sum())) % n
    src, dst = _dedupe(src, dst)
    # vertex ids are already crawl-ordered (site-contiguous); keep the
    # stream in crawl order: all out-links of a page when it is fetched.
    order = np.lexsort((dst, src))
    return _compact(src[order], dst[order])


def web_graph(scale: int = 14, edge_factor: int = 8, seed: int = 0) -> Graph:
    """Web-crawl-like benchmark graph: community structure + power law,
    streamed in crawl (per-host BFS burst) order — the order UbiCrawler-
    style crawlers emit and the paper's §II fn. 1 setting."""
    n = 1 << scale
    return community_web(n, avg_deg=edge_factor, seed=seed)


def rmat_graph(scale: int = 14, edge_factor: int = 8, seed: int = 0) -> Graph:
    """R-MAT + BFS order — a *hard* case with weak community structure."""
    return bfs_order(rmat(scale, edge_factor, seed))


def social_graph(n: int = 8192, m: int = 8, seed: int = 0) -> Graph:
    """Social-network-like benchmark graph (paper's Twitter analogue)."""
    return bfs_order(barabasi(n, m, seed))


def random_stream(g: Graph, seed: int = 0) -> Graph:
    """Random edge order (best order for HDRF/Greedy/Hash/DBH per §VI-A)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.num_edges)
    return Graph(g.src[perm], g.dst[perm], g.num_vertices)
