"""Flash attention (causal, GQA) — Pallas TPU kernel.

TPU adaptation (vs. the CUDA original): the grid's minor-most dimension is
the KV-block index and TPU grids execute sequentially per core, so the
online-softmax state (m, l, acc) lives in VMEM scratch carried across KV
steps — no atomics, no shared-memory tiling.  GQA is folded into the
BlockSpec index maps (q-head → kv-head), so expanded K/V are never
materialized in HBM.  Block shapes default to (128, head_dim) — MXU-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, block_q: int, block_kv: int,
                  causal: bool, kv_steps: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale     # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)                # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qb * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kpos = kb * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip fully-masked blocks (kv block entirely after the q block)
        @pl.when(kb * block_kv <= qb * block_q + block_q - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(kb == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    sm_scale: float | None = None, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = True):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); Hq % Hkv == 0.
    Returns (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, Skv)
    assert Hq % Hkv == 0
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(D)
    kv_steps = Skv // block_kv
    grid = (B, Hq, Sq // block_q, kv_steps)
    group = Hq // Hkv

    kern = functools.partial(
        _flash_kernel, sm_scale=scale, block_q=block_q, block_kv=block_kv,
        causal=causal, kv_steps=kv_steps)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, qb, kb: (b, h, qb, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, qb, kb: (b, h // group, kb, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, qb, kb: (b, h // group, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qb, kb: (b, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
