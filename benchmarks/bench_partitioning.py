"""Paper figures 3–7 and 9–11 as benchmark functions over synthetic web
graphs (see DESIGN.md §3 — offline substitutes in the same degree-law
regime).  Each ``fig*`` function returns CSV-ready rows."""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import (CLUGPConfig, clugp_partition,
                        clugp_partition_parallel, metrics, web_graph)
from repro.core.graphgen import social_graph
from .common import quality_row

ALGOS = ["clugp", "clugp-opt", "hashing", "dbh", "greedy", "hdrf", "mint"]


def fig3_rf_vs_partitions(scale=12, ks=(4, 16, 64, 256), seed=0):
    """Fig. 3: replication factor vs #partitions, web graph."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for k in ks:
        for algo in ALGOS:
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig3_rf_web"
            rows.append(r)
    return rows


def fig4_social(scale=12, ks=(16, 64), seed=1):
    """Fig. 4: social graph (Twitter analogue) — RF + total runtime."""
    g = social_graph(n=1 << scale, m=8, seed=seed)
    rows = []
    for k in ks:
        for algo in ALGOS:
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig4_rf_social"
            rows.append(r)
    return rows


def fig5_graph_size(scales=(10, 11, 12, 13), k=16, seed=0):
    """Fig. 5: RF vs graph size (sampled)."""
    rows = []
    for s in scales:
        g = web_graph(scale=s, edge_factor=8, seed=seed)
        for algo in ("clugp-opt", "hdrf", "hashing"):
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig5_size"
            r["edges"] = g.num_edges
            rows.append(r)
    return rows


def fig6_space(scale=12, ks=(16, 64, 256), seed=0):
    """Fig. 6: resident partitioner state (bytes).  Analytic per §III-V:
    CLUGP O(2|V|) + O(m); HDRF/Greedy O(|V|·k/8) bitsets + loads;
    DBH O(|V|); Hashing O(1); Mint O(window)."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    V, E = g.num_vertices, g.num_edges
    rows = []
    for k in ks:
        m_est = clugp_partition(g.src, g.dst, g.num_vertices,
                                CLUGPConfig(k=k)).stats["num_clusters"]
        space = {
            "clugp": 8 * V + 8 * V + 8 * m_est,     # clu[] + deg[] + game
            "hashing": 0,
            "dbh": 8 * V,
            "greedy": V * ((k + 63) // 64) * 8 + 8 * V,
            "hdrf": V * ((k + 63) // 64) * 8 + 8 * V + 8 * k,
            "mint": 8 * 4096 * 4,
        }
        for algo, b in space.items():
            rows.append({"bench": "fig6_space", "algo": algo, "k": k,
                         "bytes": int(b)})
    return rows


def fig7_runtime_vs_k(scale=12, ks=(4, 16, 64, 256), seed=0):
    """Fig. 7: partitioning runtime scaling in k (µs/edge)."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for k in ks:
        for algo in ("clugp", "hashing", "dbh", "hdrf", "greedy"):
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig7_runtime"
            rows.append(r)
    return rows


def fig9_ablation(scale=12, ks=(4, 16, 64, 256), seed=0):
    """Fig. 9: splitting (CLUGP-S) and game (CLUGP-G) ablations."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for k in ks:
        for algo in ("clugp", "clugp-nosplit", "clugp-nogame"):
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig9_ablation"
            rows.append(r)
    return rows


def fig10_parallelization(scale=12, k=16, seed=0):
    """Fig. 10: (a) distributed nodes (thread analogue) sweep;
    (b) game batch-size sweep."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for nodes in (1, 2, 4, 8):
        t0 = time.time()
        res = clugp_partition_parallel(g.src, g.dst, g.num_vertices,
                                       CLUGPConfig(k=k), n_nodes=nodes)
        rows.append({"bench": "fig10_nodes", "nodes": nodes, "k": k,
                     "rf": round(res.stats["rf"], 4),
                     "seconds": round(time.time() - t0, 4)})
    for bs in (64, 400, 1600, 6400):
        t0 = time.time()
        res = clugp_partition(g.src, g.dst, g.num_vertices,
                              CLUGPConfig(k=k, batch_size=bs))
        rows.append({"bench": "fig10_batch", "batch": bs, "k": k,
                     "rf": round(res.stats["rf"], 4),
                     "rounds": res.game_rounds,
                     "seconds": round(time.time() - t0, 4)})
    return rows


def fig11_weight_and_balance(scale=12, k=16, seed=0):
    """Fig. 11: (a) RF vs relative load balance τ; (b) RF vs relative
    weight of the two game objectives."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for tau in (1.0, 1.2, 1.5, 2.0, 3.0):
        res = clugp_partition(g.src, g.dst, g.num_vertices,
                              CLUGPConfig(k=k, tau=tau))
        rows.append({"bench": "fig11a_tau", "tau": tau, "k": k,
                     "rf": round(res.stats["rf"], 4),
                     "balance": round(res.stats["balance"], 4)})
    for w in (0.1, 0.3, 0.5, 0.7, 0.9):
        res = clugp_partition(g.src, g.dst, g.num_vertices,
                              CLUGPConfig(k=k, relative_weight=w))
        rows.append({"bench": "fig11b_weight", "weight": w, "k": k,
                     "rf": round(res.stats["rf"], 4),
                     "balance": round(res.stats["balance"], 4)})
    return rows
