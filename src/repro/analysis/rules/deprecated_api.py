"""DEPRECATED-API: no new callers of retired surfaces.

Two retired families:

- the per-wire ``comm_bytes_*`` methods (PR 7 consolidated them into the
  keyword-routed ``PartitionLayout.comm_bytes(...)``); the shims still
  exist and warn, but in-tree code must use the router.  The one
  legitimate caller is the shim-equivalence test itself — allowlisted.
- the PR 5 ``clugp_partition`` / ``clugp_partition_parallel`` entry
  points, removed in PR 8.  Any *identifier* reference (name, attribute,
  import) is a finding; mentions inside strings/docstrings — e.g. the
  ``hasattr(mod, "clugp_partition")`` negative tests — are fine, which is
  exactly why this replaced the old substring grep gate.
"""
from __future__ import annotations

import ast

from ..lint import Rule

REMOVED_NAMES = frozenset({"clugp_partition", "clugp_partition_parallel"})
DEPRECATED_PREFIX = "comm_bytes_"


class DeprecatedApi(Rule):
    id = "DEPRECATED-API"
    description = ("no calls to the deprecated comm_bytes_* shims; no "
                   "identifier references to the removed clugp_partition* "
                   "entry points")
    roots = ("src", "examples", "benchmarks", "tests")
    excludes = ("src/repro/analysis",)

    def run(self, tree, relpath, text):
        out = []
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr.startswith(DEPRECATED_PREFIX)):
                out.append(self.finding(
                    relpath, node, node.func.attr,
                    f"calls deprecated shim .{node.func.attr}() — use "
                    f"comm_bytes(...) / session.comm_bytes(...)"))
            elif isinstance(node, ast.Name) and node.id in REMOVED_NAMES:
                out.append(self.finding(
                    relpath, node, node.id,
                    f"references removed entry point {node.id!r}"))
            elif (isinstance(node, ast.Attribute)
                  and node.attr in REMOVED_NAMES):
                out.append(self.finding(
                    relpath, node, node.attr,
                    f"references removed entry point {node.attr!r}"))
            elif isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if alias.name in REMOVED_NAMES:
                        out.append(self.finding(
                            relpath, node, alias.name,
                            f"imports removed entry point {alias.name!r}"))
        return out
