"""Graph-serving launcher: drive a resident GraphServer end to end.

``python -m repro.launch.serve_graph --scale 13 --k 8 --smoke`` builds a
web graph, partitions it, and stands up ``repro.serve.GraphServer``
in-process (no sockets — the driver IS the event loop), then:

1. **queries** — submits a batched mix of score/label/owner/neighbors
   requests, serves them microbatch by microbatch, and (``--smoke``)
   asserts every score reply bit-matches a direct
   ``GraphSession.run``/``run_many`` on the same layout;
2. **ingestion** — streams random edge arrivals through the window
   buffer, recording the RF trace as windows flush and the drift
   watermark triggers prioritized restreams (``--smoke`` asserts at
   least one restream fired and left RF ≤ the drifted RF);
   With ``--tol`` the server runs the convergence early-exit loop
   (``--iters`` becomes a cap) and, after ingestion, replays the same
   query mix **cold** (program inits) and **warm** (pre-swap fixed
   points as seeds) — ``--smoke`` gates warm ``iters_run`` and
   ``query_ms`` strictly below cold;
3. **preemption** — (``--smoke`` + ``--ckpt-dir``) spawns a child copy
   of itself (``--child-snapshot``) that builds the same deterministic
   server, checkpoints through ``dist.ft.ServiceFT``, and SIGKILLs its
   own process mid-serving; the parent resumes from the snapshot and
   asserts the identical config blob, assignment, and query replies.

Writes ``results/BENCH_serve.json`` (query latency, RF trace summary)
for ``benchmarks/trend.py`` to diff across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import CLUGPConfig, web_graph
from repro.dist.ft import ServiceFT
from repro.serve import GraphServer
from repro.session import GraphSession, SessionConfig

SCORE_PROGRAMS = ("pagerank", "degree", "cc", "labelprop")


def build_server(args, ft=None) -> GraphServer:
    """Deterministic graph → session → server from the CLI args — the
    parent, the ``--child-snapshot`` child, and the resumed server all
    reconstruct bit-identical state from the same flags."""
    g = web_graph(scale=args.scale, seed=args.seed)
    cfg = SessionConfig(clugp=CLUGPConfig(k=args.k), backend=args.backend,
                        exchange=args.exchange, iters=args.iters)
    sess = GraphSession(cfg).partition(g.src, g.dst, g.num_vertices)
    sess.layout()
    return GraphServer(sess, max_batch=args.max_batch, window=args.window,
                       rf_watermark=args.watermark,
                       restream_passes=args.restream_passes,
                       tol=args.tol, ft=ft)


def drive_queries(srv: GraphServer, args, check: bool) -> dict:
    """Submit a batched query mix, serve it, optionally verify replies
    against the session run directly on the same layout."""
    rng = np.random.default_rng(args.seed + 1)
    n = srv.sess.num_vertices
    tickets = []
    for i in range(args.queries):
        prog = SCORE_PROGRAMS[i % len(SCORE_PROGRAMS)]
        verts = rng.integers(0, n, 4)
        tickets.append((srv.submit("score", program=prog, vertices=verts),
                        "score", prog, verts))
    for v in rng.integers(0, n, 4):
        tickets.append((srv.submit("owner", vertices=[v]), "owner", None,
                        [v]))
        tickets.append((srv.submit("neighbors", vertices=[v]),
                        "neighbors", None, [v]))
    t0 = time.perf_counter()
    served = srv.serve_pending()
    dt = time.perf_counter() - t0
    replies = {t: srv.result(t) for t, *_ in tickets}
    assert all(r is not None and r.error is None
               for r in replies.values()), "serve loop dropped a request"
    if check:
        # every score reply must bit-match a direct run_many with the
        # SAME (combine, dtype) wire-cell grouping the server fuses —
        # the server only batches/caches, it never changes the compute
        from repro.session import resolve_program
        cells: dict = {}
        for p in SCORE_PROGRAMS:
            prog = resolve_program(p, n)
            cells.setdefault((prog.combine, np.dtype(prog.dtype).name),
                             []).append(p)
        direct = {}
        for progs in cells.values():
            if args.tol is None:
                outs = srv.sess.run_many(progs, iters=args.iters,
                                         exchange=args.exchange)
            else:
                # same tol semantics as the server's step: cold seeds,
                # iters as a cap — bit-match still holds exactly
                outs, _ = srv.sess.run_many(
                    progs, iters=args.iters, exchange=args.exchange,
                    tol=args.tol,
                    init_values=[np.zeros(0)] * len(progs),
                    return_iters=True)
            direct.update(zip(progs, outs))
        for t, kind, prog, verts in tickets:
            if kind == "score":
                want = direct[prog][np.asarray(verts)]
                got = replies[t].value
                assert np.array_equal(got, want), (prog, got, want)
        print(f"[serve] {args.queries} score replies bit-match direct "
              f"run_many ({args.exchange} wire)")
    return {"served": served, "query_ms": dt * 1e3 / max(served, 1),
            "microbatches": srv.stats["microbatches"]}


def drive_ingest(srv: GraphServer, args) -> dict:
    """Stream random edge arrivals until ``--ingest-windows`` windows
    have flushed; return the RF drift/repair summary."""
    rng = np.random.default_rng(args.seed + 2)
    n = srv.sess.num_vertices
    target = srv.stats["windows"] + args.ingest_windows
    while srv.stats["windows"] < target:
        chunk = max(1, args.window // 4)
        srv.ingest(rng.integers(0, n, chunk), rng.integers(0, n, chunk))
    drifted = [v for e, v in srv.rf_trace if e == "window"]
    repaired = [v for e, v in srv.rf_trace if e == "restream"]
    return {"rf_base": srv.rf_trace[0][1],
            "rf_drifted": max(drifted) if drifted else srv.rf_base,
            "rf_post_restream": repaired[-1] if repaired else None,
            "restreams": srv.stats["restreams"],
            "ingested_edges": srv.stats["ingested_edges"]}


def drive_warm_cold(srv: GraphServer, args, check: bool) -> list[dict]:
    """Post-ingest warm-vs-cold comparison (``--tol`` mode only).

    The restream swap flushed the value caches and seeded ``_warm`` with
    the pre-swap fixed points.  This runs the SAME query mix twice over
    the grown graph: once **cold** (warm seeds stashed away — the
    all-False warm mask takes every program back to its init) and once
    **warm** (seeds restored).  Both rounds reuse the while_loop compiled
    during the pre-ingest queries, so ``query_ms`` compares fairly; the
    smoke gate requires the warm round to run strictly fewer iterations
    AND strictly less wall-clock per query than cold."""
    n = srv.sess.num_vertices

    def round_(warm: bool) -> tuple[dict, dict]:
        rng = np.random.default_rng(args.seed + 3)   # same mix both ways
        srv.last_iters_run.clear()
        tickets = []
        for i in range(args.queries):
            prog = SCORE_PROGRAMS[i % len(SCORE_PROGRAMS)]
            verts = rng.integers(0, n, 4)
            tickets.append(
                (srv.submit("score", program=prog, vertices=verts),
                 prog, verts))
        t0 = time.perf_counter()
        served = srv.serve_pending()
        dt = time.perf_counter() - t0
        replies = {t: srv.result(t) for t, *_ in tickets}
        assert all(r is not None and r.error is None
                   for r in replies.values()), "serve loop dropped a request"
        row = {"warm": warm,
               "query_ms": round(dt * 1e3 / max(served, 1), 3),
               "iters_run": max(srv.last_iters_run.values())}
        return row, [(replies[t], p, v) for t, p, v in tickets]

    stash = dict(srv._warm)
    srv._warm.clear()
    srv._values.clear()
    cold, _ = round_(warm=False)
    srv._warm.update(stash)
    srv._values.clear()          # force the warm round to recompute
    warm, warm_replies = round_(warm=True)
    print(f"[serve] post-ingest cold: {cold['iters_run']} iters "
          f"{cold['query_ms']}ms/q — warm: {warm['iters_run']} iters "
          f"{warm['query_ms']}ms/q")
    if check:
        # warm replies must still bit-match a direct run_many with the
        # same tol and the same warm seeds — warm start changes where
        # the loop starts, never what the server computes
        from repro.session import resolve_program
        cells: dict = {}
        for p in SCORE_PROGRAMS:
            prog = resolve_program(p, n)
            cells.setdefault((prog.combine, np.dtype(prog.dtype).name),
                             []).append(p)
        direct = {}
        for progs in cells.values():
            seeds = [stash.get((p, args.exchange), np.zeros(0))
                     for p in progs]
            outs, _ = srv.sess.run_many(
                progs, iters=args.iters, exchange=args.exchange,
                tol=args.tol, init_values=seeds, return_iters=True)
            direct.update(zip(progs, outs))
        for reply, prog, verts in warm_replies:
            want = direct[prog][np.asarray(verts)]
            assert np.array_equal(reply.value, want), (prog, reply.value,
                                                       want)
        assert warm["iters_run"] < cold["iters_run"], (
            f"warm start ran {warm['iters_run']} iters, cold "
            f"{cold['iters_run']} — no repair win")
        assert warm["query_ms"] < cold["query_ms"], (
            f"warm query_ms {warm['query_ms']} not below cold "
            f"{cold['query_ms']}")
        print(f"[serve] warm replies bit-match direct run_many; "
              f"warm {warm['iters_run']} < cold {cold['iters_run']} "
              f"iters and faster per query")
    return [cold, warm]


def child_snapshot(args) -> None:
    """The preemption victim: build the deterministic server, serve one
    microbatch, checkpoint, then SIGKILL this very process — nothing
    after the kill runs, so only the atomic snapshot survives."""
    ft = ServiceFT(args.ckpt_dir)
    srv = build_server(args, ft=ft)
    srv.submit("score", program="pagerank", vertices=[0, 1])
    srv.step()
    srv.checkpoint()
    ft.wait()
    print("[serve-child] snapshot written, dying", flush=True)
    os.kill(os.getpid(), signal.SIGKILL)


def kill_resume_check(args) -> None:
    """Spawn the child, verify it died by SIGKILL, resume from its
    snapshot, and assert the partition state is identical to the
    deterministic reference."""
    cmd = [sys.executable, "-m", "repro.launch.serve_graph",
           "--child-snapshot", "--ckpt-dir", args.ckpt_dir,
           "--scale", str(args.scale), "--k", str(args.k),
           "--exchange", args.exchange, "--backend", args.backend,
           "--iters", str(args.iters), "--seed", str(args.seed),
           "--window", str(args.window)]
    if args.tol is not None:
        cmd += ["--tol", str(args.tol)]
    proc = subprocess.run(cmd, capture_output=True, text=True)
    assert proc.returncode == -signal.SIGKILL, (
        f"child expected to die by SIGKILL, got {proc.returncode}:\n"
        f"{proc.stdout}{proc.stderr}")
    ref = build_server(args)
    srv = GraphServer.resume(ServiceFT(args.ckpt_dir), tol=args.tol)
    assert srv.sess.to_json() == ref.sess.to_json(), "config blob drifted"
    assert np.array_equal(srv.sess.assign, ref.sess.assign), \
        "resumed assignment differs from the pre-kill partition"
    ta = srv.submit("score", program="pagerank", vertices=[0, 1])
    srv.step()
    tb = ref.submit("score", program="pagerank", vertices=[0, 1])
    ref.step()
    assert np.array_equal(srv.result(ta).value, ref.result(tb).value)
    print("[serve] SIGKILL'd child resumed from snapshot: identical "
          "config, assignment, and replies")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--exchange", default="halo")
    ap.add_argument("--backend", default="np")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--tol", type=float, default=None,
                    help="convergence early-exit tolerance: --iters "
                         "becomes a cap, the server's value caches turn "
                         "into warm-start seeds across ingest swaps, and "
                         "BENCH_serve.json gains post-ingest cold/warm "
                         "rows (query_ms, iters_run)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--window", type=int, default=2048)
    ap.add_argument("--ingest-windows", type=int, default=3)
    ap.add_argument("--watermark", type=float, default=1.02)
    ap.add_argument("--restream-passes", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="assert correctness gates (CI mode)")
    ap.add_argument("--child-snapshot", action="store_true",
                    help=argparse.SUPPRESS)   # internal: preemption victim
    ap.add_argument("--out", default=None,
                    help="override results/BENCH_serve.json")
    args = ap.parse_args()

    if args.child_snapshot:
        child_snapshot(args)
        return 0                    # unreachable — SIGKILL above

    srv = build_server(args)
    q = drive_queries(srv, args, check=args.smoke)
    ing = drive_ingest(srv, args)
    wc = (drive_warm_cold(srv, args, check=args.smoke)
          if args.tol is not None else [])
    if args.smoke:
        assert ing["restreams"] >= 1, (
            f"RF watermark never tripped: trace {srv.rf_trace}")
        assert ing["rf_post_restream"] <= ing["rf_drifted"] + 1e-9, ing
        # the grown graph still serves
        t = srv.submit("score", program="pagerank", vertices=[0])
        srv.step()
        assert srv.result(t).error is None
        print(f"[serve] drift {ing['rf_drifted']:.3f} repaired to "
              f"{ing['rf_post_restream']:.3f} over {ing['restreams']} "
              f"restream(s)")
    if args.ckpt_dir and args.smoke:
        kill_resume_check(args)

    row = {"bench": "serve", "scale": args.scale, "k": args.k,
           "exchange": args.exchange, "window": args.window,
           "queries": q["served"], "microbatches": q["microbatches"],
           "query_ms": round(q["query_ms"], 3),
           "rf_base": round(ing["rf_base"], 4),
           "rf_drifted": round(ing["rf_drifted"], 4),
           "rf_post_restream": round(ing["rf_post_restream"], 4)
           if ing["rf_post_restream"] is not None else None,
           "restreams": ing["restreams"],
           "ingested_edges": ing["ingested_edges"]}
    rows = [row]
    if args.tol is not None:
        # pre-ingest row + one post-ingest row per temperature; the
        # warm/tol identity columns keep trend.py from diffing a warm
        # row against a cold one
        row.update({"tol": args.tol, "warm": False})
        for r in wc:
            rows.append({"bench": "serve_post_ingest", "scale": args.scale,
                         "k": args.k, "exchange": args.exchange,
                         "window": args.window, "tol": args.tol,
                         "warm": r["warm"], "iters_cap": args.iters,
                         "iters_run": r["iters_run"],
                         "query_ms": r["query_ms"]})
    out = (Path(args.out) if args.out else
           Path(__file__).resolve().parents[3] / "results"
           / "BENCH_serve.json")
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(rows, indent=1))
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
