"""Named-axis sharding constraints (logical tags → mesh axes).

Model code never names mesh axes; it tags each array dim with a logical
name (``shard(x, "batch", "seq", "heads", None)``) and the *rule table*
active via ``use_rules(rules, mesh)`` decides which mesh axis (if any)
each tag lands on.  Swapping the table re-partitions the whole model —
TP-serve vs CP-serve vs multi-pod train are one-line changes in the
launchers, not edits to model code.

Rule tables:
- ``SINGLE_POD_RULES``  — DP×TP on a ("data", "model") mesh: batch on
  data; heads / experts / vocab on model; decode KV caches sequence-
  sharded on model (SP flash-decode).
- ``MULTI_POD_RULES``   — same, with batch spread over ("pod", "data").
- ``CP_SERVE_RULES``    — context-parallel serving: the *sequence* dim
  shards over model (heads replicated, mp=1) — long-context cells where
  head-sharding runs out.

Outside any ``use_rules`` context ``shard`` is the identity, so single-
device tests and reference paths run unchanged.  Axes that do not evenly
divide their dim are dropped (replicated) — mirroring
``repro.train.shardings.sanitize_specs``: a bad tag can cost performance,
never a compile failure.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

SINGLE_POD_RULES: dict = {
    "batch": "data",
    "seq": None,                      # sequence replicated in TP train
    "heads": "model",
    "kv_heads": None,                 # GQA KV replicated (cheap all-gather)
    "kv_heads_sharded": "model",      # when kv_heads divide the mesh
    "vocab": "model",
    "experts": "model",
    "sp_seq": "model",                # decode caches: sequence-parallel
    "stage": None,
}

MULTI_POD_RULES: dict = {**SINGLE_POD_RULES, "batch": ("pod", "data")}

# the partitioner's edge stream: one contiguous stream slice per device
# along a flat "stream" axis (repro.core.partitioner, paper §III-C)
PARTITIONER_RULES: dict = {
    "stream": "stream",
    "vertex": None,                   # vertex state replicated per node
}

CP_SERVE_RULES: dict = {
    **SINGLE_POD_RULES,
    "seq": "model",                   # context parallelism
    "heads": None,
    "kv_heads_sharded": None,
    "sp_seq": "model",
}

_state = threading.local()


def _stack() -> list:
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


@contextmanager
def use_rules(rules: dict, mesh: Mesh):
    """Activate ``rules`` over ``mesh`` for all ``shard()`` calls in scope
    (re-entrant; innermost context wins).

    The context is read at *trace* time: wrap the first call of a jitted
    function (as the launchers do), not just later calls — a function
    already traced outside the context hits the jit cache and keeps its
    constraint-free compilation.
    """
    _stack().append((rules, mesh))
    try:
        yield
    finally:
        _stack().pop()


def active_rules():
    """(rules, mesh) of the innermost ``use_rules`` context, or None."""
    stack = _stack()
    return stack[-1] if stack else None


def resolve_spec(shape: tuple, tags: tuple, rules: dict,
                 axis_sizes: dict) -> P:
    """Pure tag→PartitionSpec resolution (unit-testable without devices).

    Per dim: look the tag up in ``rules``; drop axes absent from the mesh,
    axes already used by an earlier dim, and axes whose product does not
    divide the dim size (replicate instead).
    """
    assert len(tags) == len(shape), (tags, shape)
    used: set = set()
    entries = []
    for dim, tag in zip(shape, tags):
        ax = rules.get(tag) if tag is not None else None
        if ax is None:
            entries.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in axis_sizes and a not in used)
        size = 1
        for a in axes:
            size *= axis_sizes[a]
        if not axes or dim % size != 0:
            entries.append(None)
            continue
        used.update(axes)
        entries.append(axes if len(axes) > 1 else axes[0])
    return P(*entries)


def shard(x: jax.Array, *tags) -> jax.Array:
    """Constrain ``x``'s sharding per the active rule table; identity when
    no ``use_rules`` context is active.  One tag per dim ("batch", "seq",
    "heads", "vocab", "experts", "sp_seq", ... or None)."""
    ctx = active_rules()
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = resolve_spec(tuple(x.shape), tags, rules, dict(mesh.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
