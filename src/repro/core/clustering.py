"""Pass 1 — streaming clustering (paper Alg. 2).

The *allocation–splitting–migration* framework.  Two interchangeable
implementations with identical semantics (tested against each other):

- ``streaming_clustering_np``  : host fast path (the partitioner runs on the
  host, like the paper's Java pipeline; the stream is inherently sequential).
- ``streaming_clustering_jax`` : ``jax.lax.scan`` over the edge stream with a
  dense carried state — the JAX-native form used under jit and in the
  multi-device pipeline (each distributed node clusters its local stream,
  paper §III-C last paragraph).

State per paper: ``clu[v]`` vertex→cluster, ``deg[v]`` streamed degree,
``vol[c]`` cluster volume (sum of member degrees), ``divided[v]`` mark.
Splitting (lines 9–18) fires when a cluster overflows ``V_max``: the
triggering vertex moves to a fresh cluster, leaving a mirror behind.
Migration (lines 20–26) pulls one endpoint into the larger cluster.

``allow_split=False`` degrades CLUGP to Hollocou et al.'s allocation–
migration (the paper's Holl baseline and the CLUGP-S ablation).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels.cluster_scatter import cluster_scatter, edge_decisions
from ..kernels.ops import DEFAULT_INTERPRET


@dataclass
class ClusteringResult:
    clu: np.ndarray            # vertex -> compact cluster id, int32[V]
    deg: np.ndarray            # streamed degree, int32[V]
    divided: np.ndarray        # bool[V], vertex was split at least once
    replicas: np.ndarray       # int32[V], #mirrors created during clustering
    num_clusters: int

    def cluster_rf(self, num_vertices: int) -> float:
        """Replication factor at cluster granularity (Fig. 2 accounting)."""
        active = self.deg > 0
        return float((active.sum() + self.replicas[active].sum())
                     / max(1, active.sum()))


def _compact_labels(raw: np.ndarray) -> tuple[np.ndarray, int]:
    used, inv = np.unique(raw[raw >= 0], return_inverse=True)
    out = np.full(raw.shape[0], -1, dtype=np.int32)
    out[raw >= 0] = inv.astype(np.int32)
    return out, int(used.shape[0])


def streaming_clustering_np(src: np.ndarray, dst: np.ndarray,
                            num_vertices: int, vmax: float,
                            allow_split: bool = True,
                            split_degree_factor: float = 0.0) -> ClusteringResult:
    """``split_degree_factor`` is a beyond-paper damping knob: a split of
    vertex x only fires if ``deg(x) ≥ factor × mean_streamed_degree`` — the
    replica is only paid when the volume drained (deg x) is worth it.  The
    paper-faithful setting is 0 (always split on overflow, Alg. 2 verbatim);
    the optimized profile uses 4 (see EXPERIMENTS.md §Perf-partitioner)."""
    V = num_vertices
    clu = np.full(V, -1, dtype=np.int64)
    deg = np.zeros(V, dtype=np.int64)
    divided = np.zeros(V, dtype=bool)
    replicas = np.zeros(V, dtype=np.int64)
    # worst case ids: one per vertex + one per split (≤ 2 per edge)
    vol = np.zeros(V + 2 * src.shape[0] + 2, dtype=np.int64)
    next_id = 0
    seen_deg = 0
    seen_v = 0

    cl = clu  # local aliases (python-loop hot path)
    dg = deg
    vl = vol
    for i in range(src.shape[0]):
        u = int(src[i]); v = int(dst[i])
        if u == v:
            continue
        cu = cl[u]
        if cu < 0:                       # allocation (lines 3-5)
            cu = next_id; next_id += 1
            cl[u] = cu
            seen_v += 1
        cv = cl[v]
        if cv < 0:
            cv = next_id; next_id += 1
            cl[v] = cv
            seen_v += 1
        dg[u] += 1; dg[v] += 1           # line 6
        vl[cu] += 1; vl[cv] += 1         # line 7
        seen_deg += 2
        if allow_split:
            dthresh = split_degree_factor * seen_deg / seen_v
            if cu == cv:
                # same-cluster overflow: split only the higher-degree
                # endpoint and keep the edge with the lower-degree one
                # (paper §IV-A divided-vertex tie rule) — splitting both
                # would add a replica for nothing.
                if vl[cu] >= vmax:
                    x = u if dg[u] >= dg[v] else v
                    if dg[x] >= dthresh:
                        nc = next_id; next_id += 1
                        cl[x] = nc
                        divided[x] = True
                        replicas[x] += 1
                        vl[cu] -= dg[x]
                        vl[nc] += dg[x]
            else:
                if vl[cu] >= vmax and dg[u] >= dthresh:   # split u (8-13)
                    nc = next_id; next_id += 1
                    cl[u] = nc
                    divided[u] = True
                    replicas[u] += 1
                    vl[cu] -= dg[u]
                    vl[nc] += dg[u]
                cv = cl[v]
                if vl[cv] >= vmax and dg[v] >= dthresh:   # split v (14-18)
                    nc = next_id; next_id += 1
                    cl[v] = nc
                    divided[v] = True
                    replicas[v] += 1
                    vl[cv] -= dg[v]
                    vl[nc] += dg[v]
        cu = cl[u]; cv = cl[v]           # line 19
        if cu != cv and vl[cu] < vmax and vl[cv] < vmax:   # migration 20-26
            # post-guard: a migration must not overflow the target — an
            # over-full cluster would shred its members via later splits.
            if vl[cu] <= vl[cv]:
                if vl[cv] + dg[u] < vmax:
                    cl[u] = cv
                    vl[cu] -= dg[u]; vl[cv] += dg[u]
            else:
                if vl[cu] + dg[v] < vmax:
                    cl[v] = cu
                    vl[cv] -= dg[v]; vl[cu] += dg[v]

    compact, m = _compact_labels(clu)
    return ClusteringResult(compact, deg.astype(np.int32), divided,
                            replicas.astype(np.int32), m)


# ---------------------------------------------------------------------------
# JAX scan version — identical transition function, device-resident.
#
# Engineered around XLA:CPU's copy-insertion for loop-carried buffers: a
# scatter whose indices are *computed* (data-dependent) copies the whole
# buffer every step (and any cross-buffer dependence does too), so a naive
# per-edge scan over (V,)/(id_cap,) state costs a full memcpy per edge
# (measured ~480 µs/edge at scale 13; a register-tracked variant with one
# fused scatter still ~10-15 µs/edge).  The stream is therefore processed
# in BLOCKS of ``block_size`` edges: per block, the ≤2B touched vertices
# and their ≤2B current clusters are gathered into KB-sized local tables
# once (vectorized sort-unique), an inner scan runs the exact per-edge
# transition on local indices (fresh ids get local slots 2B..6B-1 in
# creation order, so global ids stay monotone), and the block's deltas
# scatter back to the global ``clu``/``deg``/``vol`` in one shot — the
# big-buffer copies amortize over B edges.  Split events are emitted as
# scan outputs (→ divided/replicas), so the carried state is just the
# tables, the id counter, and the two streamed-count scalars.
# ---------------------------------------------------------------------------

def _edge_step_local(carry, x, *, vmax: float, allow_split: bool,
                     split_degree_factor: float, B: int):
    """One streamed edge on the block-local tables, all decisions in
    scalar registers (pure fusable arithmetic — XLA:CPU pays a kernel-call
    per gather/scatter inside a loop body, so the step does exactly two
    fused gathers and one fused scatter and keeps everything else
    elementwise).

    ``buf`` layout: [0, 2B) vertex slot → local cluster slot (-1
    unallocated); [2B, 4B) vertex slot → streamed degree; [4B, 10B) local
    cluster volumes (slots 0..2B-1 = clusters present at block start,
    2B..6B-1 = fresh, in creation order so local slot ``2B + (nid -
    nid0)`` ↔ global id ``nid``).  The ≤4 cluster slots an edge can touch
    hold volumes in registers v0..v3; ``pu``/``pv`` point at the register
    of u's/v's current cluster.  Dead edges (self-loops / padding) zero
    every delta and write slots back unchanged."""
    buf, nid, nid0, seen_v, seen_deg = carry
    ints = x
    lu, lv_ = ints[0], ints[1]
    live = ints[2] != 0
    scrap = 6 * B - 1                 # top fresh slot absorbs dead writes

    # one fused gather: both endpoints' cluster slots + streamed degrees
    g = buf[jnp.stack([lu, lv_, 2 * B + lu, 2 * B + lv_])]
    cu0, cv0 = g[0], g[1]
    # second fused gather: the two clusters' volumes
    vg = buf[jnp.stack([4 * B + jnp.clip(cu0, 0, scrap),
                        4 * B + jnp.clip(cv0, 0, scrap)])]
    # the decision math is shared verbatim with the Pallas fused-scatter
    # kernel (kernels.cluster_scatter) — both strategies are bit-identical
    # by construction
    (nid, seen_v, seen_deg, newu, newv, vol_ids, vol_deltas,
     packed) = edge_decisions(
        cu0, cv0, g[2], g[3], vg[0], vg[1], live, nid, nid0, seen_v,
        seen_deg, vmax=vmax, allow_split=allow_split,
        split_degree_factor=split_degree_factor, B=B)

    # end-of-step write: ONE fused 8-lane scatter-add — the two vertex
    # cluster-pointer deltas, the two degree increments, and the ≤4
    # touched volume slots.  Inside a loop body every scatter at computed
    # indices costs XLA:CPU a buffer copy + kernel call (~1.3 µs), so the
    # step does exactly one.
    lvflag = live.astype(jnp.int32)
    ids = jnp.stack([
        lu, lv_,
        2 * B + lu, 2 * B + lv_,
        4 * B + vol_ids[0], 4 * B + vol_ids[1],
        4 * B + vol_ids[2], 4 * B + vol_ids[3]])
    d = jnp.stack([jnp.where(lu != lv_, newu - cu0, 0),
                   newv - cv0,
                   lvflag, lvflag,
                   vol_deltas[0], vol_deltas[1],
                   vol_deltas[2], vol_deltas[3]])
    buf = buf.at[ids].add(d)
    return (buf, nid, nid0, seen_v, seen_deg), packed


_BIG_ID = np.int32(2 ** 31 - 1)


def _block_step(carry, x, *, vmax: float, allow_split: bool,
                split_degree_factor: float, cap: int, num_vertices: int,
                B: int, unroll: int = 1, kernel: str = "xla",
                interpret: bool = DEFAULT_INTERPRET):
    """Process one block of B edges: localize → inner scan → write back."""
    clu, deg, vol, nid, seen_v, seen_deg = carry
    bu, bv = x
    scrap = cap - 1

    # local vertex table: dense slots for the ≤2B distinct endpoints
    verts = jnp.concatenate([bu, bv])
    perm = jnp.argsort(verts)
    svert = verts[perm]
    firstv = jnp.concatenate([jnp.ones((1,), bool),
                              svert[1:] != svert[:-1]])
    lidx_sorted = (jnp.cumsum(firstv.astype(jnp.int32)) - 1)
    lv_of_pos = jnp.zeros((2 * B,), jnp.int32).at[perm].set(lidx_sorted)
    uvg = jnp.full((2 * B,), num_vertices, jnp.int32).at[
        lidx_sorted].set(svert)
    lu, lv_ = lv_of_pos[:B], lv_of_pos[B:]

    # local cluster table: dense slots for those vertices' current clusters
    cids = clu[jnp.clip(uvg, 0, num_vertices - 1)]
    validc = (uvg < num_vertices) & (cids >= 0)
    keyc = jnp.where(validc, cids, _BIG_ID)
    ucl = jnp.sort(keyc)
    # local cluster slot of each vertex's current cluster (or -1)
    lc = jnp.where(validc,
                   jnp.searchsorted(ucl, keyc).astype(jnp.int32), -1)
    lvol0 = jnp.where(ucl < _BIG_ID,
                      vol[jnp.clip(ucl, 0, scrap)], 0).astype(jnp.int32)
    ldeg0 = deg[jnp.clip(uvg, 0, num_vertices - 1)]

    # fused local state: [0, 2B) vertex → cluster slot, [2B, 4B) vertex
    # degree, [4B, 10B) cluster volumes
    buf = jnp.concatenate([lc, ldeg0, lvol0,
                           jnp.zeros((4 * B,), jnp.int32)])
    nid0 = nid
    live = (bu != bv).astype(jnp.int32)
    ints = jnp.stack([lu, lv_, live], axis=1)   # one slice per step
    if kernel == "pallas":
        # the whole block table stays resident in kernel memory for the
        # full edge loop — no per-step buffer copies (the XLA scan's
        # ~1.3 µs/scatter floor); interpret=True on CPU runs the same
        # kernel body for correctness (bit-identical, tested)
        scal0 = jnp.stack([nid, nid0, seen_v, seen_deg])
        buf, scal, fires = cluster_scatter(
            ints, buf, scal0, vmax, allow_split=allow_split,
            split_degree_factor=split_degree_factor, interpret=interpret)
        nid, seen_v, seen_deg = scal[0], scal[2], scal[3]
    else:
        inner = partial(_edge_step_local, vmax=vmax,
                        allow_split=allow_split,
                        split_degree_factor=split_degree_factor, B=B)
        # ``unroll`` replicates the per-edge transition body (2-edge
        # unroll = the ROADMAP headroom knob): XLA sees consecutive edges'
        # fused scatters back to back and can coalesce their buffer
        # traffic.  Pure lowering choice — the transition semantics are
        # bit-identical.
        (buf, nid, _, seen_v, seen_deg), fires = jax.lax.scan(
            inner, (buf, nid, nid0, seen_v, seen_deg), ints, unroll=unroll)
    lclu, ldeg, lvol = buf[:2 * B], buf[2 * B:4 * B], buf[4 * B:]

    # write back: vertex → global cluster id (fresh slots map to the ids
    # they were created under) + degrees, then one fused delta scatter
    # into vol
    glob_of = jnp.concatenate([ucl, nid0 + jnp.arange(4 * B, dtype=jnp.int32)])
    newclu = jnp.where(lclu >= 0,
                       glob_of[jnp.clip(lclu, 0, 6 * B - 1)], -1)
    uvg_safe = jnp.clip(uvg, 0, num_vertices)
    clu = clu.at[uvg_safe].set(newclu, mode="drop")
    deg = deg.at[uvg_safe].set(ldeg, mode="drop")
    dvol = lvol - jnp.concatenate([lvol0, jnp.zeros((4 * B,), jnp.int32)])
    ids = jnp.where(jnp.concatenate([ucl < _BIG_ID,
                                     dvol[2 * B:] != 0]),
                    jnp.clip(glob_of, 0, scrap), scrap)
    vol = vol.at[ids].add(dvol)
    return (clu, deg, vol, nid, seen_v, seen_deg), fires


def streaming_clustering_jax(src, dst, num_vertices: int, vmax: float,
                             allow_split: bool = True,
                             split_degree_factor: float = 0.0,
                             id_cap: int | None = None,
                             block_size: int = 128, unroll: int = 1,
                             kernel: str = "xla",
                             interpret: bool = DEFAULT_INTERPRET):
    """Blocked lax.scan form; returns raw (non-compacted) labels + state
    arrays (clu, deg, divided, replicas, next_id) — bit-identical to
    ``streaming_clustering_np``.

    ``id_cap`` bounds the cluster-id space (the global volume table,
    copied once per *block*).  The worst case is ``num_vertices + 2·E +
    2`` (the default); callers that can retry (the partitioner backends)
    pass a tight guess and re-run with a doubled cap iff the returned
    ``next_id`` hits it — an overflowed run clips fresh ids into the
    scrap slot, so its labels are invalid but the overflow is detectable.

    ``unroll`` unrolls the inner per-edge scan by that many edges
    (``CLUGPConfig.unroll``); results are bit-identical at any setting.

    ``kernel`` picks the inner-loop strategy: ``"xla"`` = the lax.scan
    over ``_edge_step_local`` (the fused-scatter scan), ``"pallas"`` = the
    ``kernels.cluster_scatter`` fused table-update kernel (interpret mode
    on CPU).  Both share ``edge_decisions`` so results are bit-identical;
    ``unroll`` only applies to the XLA scan.
    """
    E = src.shape[0]
    cap = int(id_cap) if id_cap is not None else num_vertices + 2 * E + 2
    B = int(block_size)
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    # pad to whole blocks with dead (self-loop) edges
    nb = max(1, -(-E // B))
    pad = nb * B - E
    def pad_to_blocks(a, fill):
        return jnp.concatenate(
            [a, jnp.full((pad,), fill, a.dtype)]).reshape(nb, B)
    xs = (pad_to_blocks(src, 0), pad_to_blocks(dst, 0))
    carry = (jnp.full((num_vertices,), -1, dtype=jnp.int32),
             jnp.zeros((num_vertices,), dtype=jnp.int32),
             jnp.zeros((cap,), dtype=jnp.int32),
             jnp.int32(0), jnp.int32(0), jnp.int32(0))
    # vmax may be a python float or a traced scalar (the sharded backend
    # derives each device's V_max from its slice's real edge count)
    step = partial(_block_step, vmax=jnp.float32(vmax),
                   allow_split=allow_split,
                   split_degree_factor=float(split_degree_factor),
                   cap=cap, num_vertices=num_vertices, B=B,
                   unroll=int(unroll), kernel=kernel, interpret=interpret)
    (clu, deg, _, next_id, _, _), fires = jax.lax.scan(step, carry, xs)
    fires = fires.reshape(-1)[:E]
    fire_u = (fires & 1) > 0
    fire_v = (fires & 2) > 0
    divided = (jnp.zeros((num_vertices,), bool)
               .at[src].max(fire_u).at[dst].max(fire_v))
    replicas = (jnp.zeros((num_vertices,), jnp.int32)
                .at[src].add(fire_u.astype(jnp.int32))
                .at[dst].add(fire_v.astype(jnp.int32)))
    return clu, deg, divided, replicas, next_id


def compact_labels_jax(clu, cap: int):
    """In-graph equivalent of ``_compact_labels``: raw cluster ids (< cap)
    → dense 0..m-1 ids in ascending raw-id order (the same order
    ``np.unique`` produces, so the jit pipeline's labels are bit-identical
    to the host path's).  Returns (compact int32[V] with -1 preserved, m).
    """
    valid = clu >= 0
    used = jnp.zeros((cap,), jnp.bool_).at[
        jnp.where(valid, clu, cap)].set(True, mode="drop")
    ranks = (jnp.cumsum(used.astype(jnp.int32)) - 1)
    compact = jnp.where(valid, ranks[jnp.clip(clu, 0, cap - 1)], -1)
    return compact.astype(jnp.int32), used.sum().astype(jnp.int32)


def clustering_result_from_jax(clu, deg, divided, replicas) -> ClusteringResult:
    compact, m = _compact_labels(np.asarray(clu))
    return ClusteringResult(compact, np.asarray(deg), np.asarray(divided),
                            np.asarray(replicas), m)


def default_vmax(num_edges: int, k: int) -> float:
    """Paper §VI-A: V_max = |E| / k."""
    return max(2.0, num_edges / float(k))
