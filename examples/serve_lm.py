"""Batched serving example: prefill + decode with a KV cache on a reduced
qwen2-family model (the decode path is the one the dry-run lowers with a
sequence-sharded cache at (16,16)/(2,16,16)).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.argv = ["serve", "--arch", "qwen2-7b", "--batch", "4",
            "--prompt-len", "16", "--tokens", "24"]

from repro.launch import serve  # noqa: E402

serve.main()
