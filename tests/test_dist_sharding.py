"""Unit tests for the repro.dist substrate beyond the seed suite:
rule-table → PartitionSpec resolution for all three rule sets, and the
error-feedback compression identity (compress + residual round-trip)."""
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compress import (compress_with_error_feedback,
                                 zero_residual)
from repro.dist.sharding import (CP_SERVE_RULES, MULTI_POD_RULES,
                                 SINGLE_POD_RULES, active_rules,
                                 resolve_spec, shard, use_rules)

SINGLE_AXES = {"data": 2, "model": 4}
MULTI_AXES = {"pod": 2, "data": 2, "model": 4}


# ------------------------------------------------------------ rule tables

def test_single_pod_rules_selection():
    # activations (B, S, H, Dh): batch→data, heads→model, seq replicated
    assert resolve_spec((8, 64, 8, 32), ("batch", "seq", "heads", None),
                        SINGLE_POD_RULES, SINGLE_AXES) \
        == P("data", None, "model", None)
    # logits (B, chunk, V): vocab→model
    assert resolve_spec((8, 64, 512), ("batch", None, "vocab"),
                        SINGLE_POD_RULES, SINGLE_AXES) \
        == P("data", None, "model")
    # decode cache (B, Smax, Hkv, Dh): sequence-parallel on model
    assert resolve_spec((8, 64, 2, 32), ("batch", "sp_seq", None, None),
                        SINGLE_POD_RULES, SINGLE_AXES) \
        == P("data", "model", None, None)


def test_multi_pod_rules_selection():
    # batch dim spreads over (pod, data); pod axis must exist in the mesh
    assert resolve_spec((8, 64, 8, 32), ("batch", "seq", "heads", None),
                        MULTI_POD_RULES, MULTI_AXES) \
        == P(("pod", "data"), None, "model", None)
    # on a single-pod mesh the pod axis is dropped, not an error
    assert resolve_spec((8, 64, 8, 32), ("batch", "seq", "heads", None),
                        MULTI_POD_RULES, SINGLE_AXES) \
        == P("data", None, "model", None)


def test_cp_serve_rules_selection():
    # context parallelism: sequence→model, heads replicated
    assert resolve_spec((8, 64, 8, 32), ("batch", "seq", "heads", None),
                        CP_SERVE_RULES, SINGLE_AXES) \
        == P("data", "model", None, None)
    # head-sharded KV is disabled under CP (heads replicated, mp=1)
    assert resolve_spec((8, 64, 2, 32), ("batch", None,
                                         "kv_heads_sharded", None),
                        CP_SERVE_RULES, SINGLE_AXES) \
        == P("data", None, None, None)


def test_resolve_spec_sanitizes_non_dividing_dims():
    # 63 % 4 != 0 → sequence replicated instead of a compile failure
    assert resolve_spec((8, 63, 8, 32), ("batch", "sp_seq", "heads", None),
                        SINGLE_POD_RULES, SINGLE_AXES) \
        == P("data", None, "model", None)
    # heads=2 over model=4 → replicated
    assert resolve_spec((8, 64, 2, 32), ("batch", None, "heads", None),
                        SINGLE_POD_RULES, SINGLE_AXES) \
        == P("data", None, None, None)


def test_resolve_spec_never_reuses_a_mesh_axis():
    # both tags map to "model": first dim wins, second replicates
    assert resolve_spec((64, 512), ("heads", "vocab"),
                        SINGLE_POD_RULES, SINGLE_AXES) == P("model", None)


def test_shard_identity_without_context_and_applies_with_context():
    x = jnp.ones((4, 8))
    assert active_rules() is None
    assert shard(x, "batch", None) is x          # no context → no-op
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_rules(SINGLE_POD_RULES, mesh):
        assert active_rules() == (SINGLE_POD_RULES, mesh)
        y = shard(x, "batch", "vocab")
        # constraint applied (spec resolution is covered above; a 1-device
        # mesh collapses to SingleDeviceSharding) and values unchanged
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert active_rules() is None                # context restored


def test_use_rules_nesting_innermost_wins():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_rules(SINGLE_POD_RULES, mesh):
        with use_rules(CP_SERVE_RULES, mesh):
            assert active_rules()[0] is CP_SERVE_RULES
        assert active_rules()[0] is SINGLE_POD_RULES


# ------------------------------------------------------------ compression

def test_compress_round_trip_identity_each_step():
    """compress→decompress + residual equals the identity at every step:
    compressed + new_residual == grads + old_residual exactly."""
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(128,)), jnp.float32),
             "b": {"c": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)}}
    res = zero_residual(grads)
    for _ in range(10):
        comp, res_new = compress_with_error_feedback(grads, res)
        total_in = jax.tree_util.tree_map(jnp.add, grads, res)
        total_out = jax.tree_util.tree_map(jnp.add, comp, res_new)
        for a, b in zip(jax.tree_util.tree_leaves(total_in),
                        jax.tree_util.tree_leaves(total_out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)
        res = res_new


def test_compress_telescopes_over_steps():
    """Σ_t compressed_t + residual_T == T·grads + residual_0 (telescoping
    error feedback) — the property that makes the mean update unbiased."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    grads = {"w": g}
    res = zero_residual(grads)
    acc = jnp.zeros_like(g)
    T = 25
    for _ in range(T):
        comp, res = compress_with_error_feedback(grads, res)
        acc = acc + comp["w"]
    np.testing.assert_allclose(np.asarray(acc + res["w"]),
                               np.asarray(T * g), rtol=1e-4, atol=1e-4)


def test_zero_residual_structure_and_dtype():
    grads = {"a": jnp.ones((3,), jnp.bfloat16), "b": jnp.ones((2, 2))}
    res = zero_residual(grads)
    assert jax.tree_util.tree_structure(res) == \
        jax.tree_util.tree_structure(grads)
    for leaf in jax.tree_util.tree_leaves(res):
        assert leaf.dtype == jnp.float32
        assert float(jnp.abs(leaf).sum()) == 0.0


def test_compressed_values_are_int8_representable():
    rng = np.random.default_rng(2)
    grads = {"w": jnp.asarray(rng.normal(size=(512,)), jnp.float32)}
    comp, _ = compress_with_error_feedback(grads, zero_residual(grads))
    w = np.asarray(comp["w"])
    scale = np.abs(np.asarray(grads["w"])).max() / 127.0
    codes = w / scale
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert np.abs(codes).max() <= 127 + 1e-4
