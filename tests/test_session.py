"""GraphSession façade (repro.session): config round-trips, the fluent
partition → layout → run chain, external assignments, and the
multi-device smoke (sharded partition + shard_map GAS + dry-run
collective bytes, all from one JSON blob).
"""
import numpy as np
import pytest

from repro.core import CLUGPConfig, partition, web_graph
from repro.graph import build_layout, reference_pagerank, simulate_cc, \
    simulate_pagerank
from repro.session import GraphSession, PROGRAMS, SessionConfig, \
    resolve_program


@pytest.fixture(scope="module")
def graph10():
    return web_graph(scale=10, edge_factor=6, seed=3)


# --------------------------------------------------------------- config

def test_config_json_round_trip():
    cfg = SessionConfig(clugp=CLUGPConfig.optimized(8, restream=2),
                        backend="jit", nodes=1, exchange="quantized",
                        iters=17, pad_multiple=16)
    assert SessionConfig.from_json(cfg.to_json()) == cfg


def test_config_round_trip_identical_partition(graph10):
    """Two sessions built from the same JSON blob partition identically —
    the reproducibility contract."""
    g = graph10
    cfg = SessionConfig(clugp=CLUGPConfig.optimized(8, restream=1))
    s1 = GraphSession(cfg).partition(g.src, g.dst, g.num_vertices)
    s2 = GraphSession.from_json(s1.to_json()).partition(
        g.src, g.dst, g.num_vertices)
    assert s1.cfg == s2.cfg
    np.testing.assert_array_equal(s1.assign, s2.assign)
    assert s1.comm_bytes() == s2.comm_bytes()


def test_config_rejects_bad_values():
    with pytest.raises(ValueError, match="unknown backend"):
        SessionConfig(clugp=CLUGPConfig(k=4), backend="cuda")
    with pytest.raises(ValueError, match="unknown exchange"):
        SessionConfig(clugp=CLUGPConfig(k=4), exchange="carrier-pigeon")
    with pytest.raises(ValueError, match="nodes"):
        SessionConfig(clugp=CLUGPConfig(k=4), nodes=0)
    with pytest.raises(TypeError):
        SessionConfig(clugp={"k": 4})


def test_session_accepts_bare_clugp_config(graph10):
    g = graph10
    sess = GraphSession(CLUGPConfig(k=4), exchange="halo")
    sess.partition(g.src, g.dst, g.num_vertices)
    assert sess.cfg.exchange == "halo"
    assert sess.k == 4


# ----------------------------------------------------------- fluent chain

def test_partition_matches_core_api(graph10):
    g = graph10
    cfg = CLUGPConfig(k=8)
    sess = GraphSession(SessionConfig(clugp=cfg)).partition(
        g.src, g.dst, g.num_vertices)
    res = partition(g.src, g.dst, g.num_vertices, cfg, backend="np")
    np.testing.assert_array_equal(sess.assign, res.assign)
    assert sess.stats["rf"] == res.stats["rf"]


def test_run_pagerank_matches_engine_and_oracle(graph10):
    g = graph10
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=4), iters=20))
    pr = sess.partition(g.src, g.dst, g.num_vertices).layout().run(
        "pagerank")
    direct = simulate_pagerank(sess.partition_layout, iters=20,
                               exchange="halo")
    np.testing.assert_array_equal(pr, direct)
    ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=20)
    assert np.abs(pr - ref).max() < 1e-4


def test_run_cc_int64_labels(graph10):
    g = graph10
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=4)))
    cc = sess.partition(g.src, g.dst, g.num_vertices).run("cc", iters=30)
    assert cc.dtype == np.int64
    np.testing.assert_array_equal(
        cc, simulate_cc(sess.partition_layout, iters=30, exchange="halo"))


def test_layout_lazy_and_explicit(graph10):
    g = graph10
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=4)))
    sess.partition(g.src, g.dst, g.num_vertices)
    lay = sess.partition_layout          # lazily built
    ref = build_layout(g.src, g.dst, sess.assign, g.num_vertices, 4)
    np.testing.assert_array_equal(lay.halo_send, ref.halo_send)
    sess.layout(pad_multiple=16)         # explicit rebuild, wider padding
    assert sess.partition_layout.l_max % 16 == 0


def test_comm_bytes_table(graph10):
    g = graph10
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=4)))
    sess.partition(g.src, g.dst, g.num_vertices)
    cb = sess.comm_bytes()
    lay = sess.partition_layout
    assert cb["ideal"] == lay.comm_bytes("ideal")
    assert cb["quantized"] == lay.comm_bytes("quantized")
    assert cb["halo"] == lay.comm_bytes("halo")
    assert cb["dense_gather"] == lay.comm_bytes("dense")
    assert cb["quantized"] < cb["halo"] < cb["dense_gather"]
    # single-model routing returns the matching table entry
    assert sess.comm_bytes(exchange="halo") == cb["halo"]


def test_run_many_matches_single_runs(graph10):
    g = graph10
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=4), iters=25,
                                      exchange="halo"))
    sess.partition(g.src, g.dst, g.num_vertices)
    d, b = sess.run_many(["sssp", "bfs"])
    assert d.dtype == np.int64 and b.dtype == np.int64
    np.testing.assert_array_equal(d, sess.run("sssp"))
    np.testing.assert_array_equal(b, sess.run("bfs"))


def test_comm_bytes_programs_and_fused(graph10):
    g = graph10
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=4)))
    sess.partition(g.src, g.dst, g.num_vertices)
    lay = sess.partition_layout
    table = sess.comm_bytes(programs=list(PROGRAMS))
    # float sum programs ship the lossy int8 wire; min/int ship exact
    assert table["pagerank"]["quantized"] == \
        lay.comm_bytes("quantized", lossy=True)
    assert table["sssp"]["quantized"] == \
        lay.comm_bytes("quantized", lossy=False)
    for prog in table:
        assert table[prog]["halo"] < table[prog]["dense"]
    # exchange= narrows the per-program rows to plain ints
    narrow = sess.comm_bytes(programs=["pagerank"], exchange="halo")
    assert narrow == {"pagerank": lay.comm_bytes("halo")}
    fused = sess.comm_bytes(programs=["pagerank", "ppr", "centrality"],
                            exchange="quantized", fused=True)
    assert fused == lay.comm_bytes("quantized", programs=3, fused=True)
    assert fused < 3 * table["pagerank"]["quantized"]


def test_comm_bytes_shims_identical_and_warn(graph10):
    """The pre-consolidation entry points survive as DeprecationWarning
    shims that route through the one ``comm_bytes(...)`` — identity on
    every wire format (the PR 5 shim-test pattern)."""
    g = graph10
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=4)))
    sess.partition(g.src, g.dst, g.num_vertices)
    lay = sess.partition_layout
    pairs = [
        (lambda: lay.comm_bytes_mirror_sync(), lay.comm_bytes("dense")),
        (lambda: lay.comm_bytes_halo(), lay.comm_bytes("halo")),
        (lambda: lay.comm_bytes_ragged(), lay.comm_bytes("ragged")),
        (lambda: lay.comm_bytes_ragged_quantized(),
         lay.comm_bytes("ragged_quantized")),
        (lambda: lay.comm_bytes_halo_quantized(),
         lay.comm_bytes("quantized")),
        (lambda: lay.comm_bytes_fused_quantized(3),
         lay.comm_bytes("quantized", programs=3, fused=True)),
        (lambda: lay.comm_bytes_exchange("quantized", lossy=False),
         lay.comm_bytes("quantized", lossy=False)),
        (lambda: lay.comm_bytes_fused(2, "ragged"),
         lay.comm_bytes("ragged", programs=2, fused=True)),
        (lambda: lay.comm_bytes_ideal(), lay.comm_bytes("ideal")),
        (lambda: lay.comm_bytes_dense(), lay.comm_bytes("allreduce")),
        (lambda: sess.comm_bytes_programs(["pagerank"]),
         sess.comm_bytes(programs=["pagerank"])),
        (lambda: sess.comm_bytes_fused(["pagerank", "ppr"],
                                       exchange="quantized"),
         sess.comm_bytes(programs=["pagerank", "ppr"],
                         exchange="quantized", fused=True)),
    ]
    for shim, expected in pairs:
        with pytest.warns(DeprecationWarning):
            assert shim() == expected
    with pytest.raises(ValueError, match="unknown exchange"):
        lay.comm_bytes("carrier-pigeon")
    with pytest.raises(ValueError, match="needs an explicit exchange"):
        lay.comm_bytes(programs=2, fused=True)


def test_run_sweep_lands_on_last_k(graph10):
    """run_sweep: one compiled stacked body partitions at every k, the
    returned table matches the jit backend per k, and the session is left
    on the LAST k's partition ready for layout()/run()."""
    g = graph10
    ks = (4, 8)
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=2)))
    table = sess.run_sweep(g.src, g.dst, g.num_vertices, ks)
    assert sorted(table) == list(ks)
    for k in ks:
        ref = partition(g.src, g.dst, g.num_vertices,
                        CLUGPConfig(k=k), backend="jit")
        np.testing.assert_array_equal(table[k].assign, ref.assign)
    assert sess.k == ks[-1]
    np.testing.assert_array_equal(sess.assign, table[ks[-1]].assign)
    assert sess.partition_layout.k == ks[-1]


def test_with_partition_external_assignment(graph10):
    g = graph10
    rng = np.random.default_rng(0)
    a = rng.integers(0, 4, g.num_edges).astype(np.int32)
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=4)))
    sess.with_partition(g.src, g.dst, g.num_vertices, a)
    assert sess.stats["backend"] == "external"
    assert sess.stats["rf"] > 1.0
    assert sess.comm_bytes()["halo"] > 0
    with pytest.raises(ValueError, match="covers"):
        sess.with_partition(g.src, g.dst, g.num_vertices, a[:-1])


def test_errors_before_partition_and_bad_program(graph10):
    g = graph10
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=4)))
    with pytest.raises(RuntimeError, match="no partition yet"):
        sess.run("pagerank")
    with pytest.raises(RuntimeError, match="no partition yet"):
        sess.layout()
    sess.partition(g.src, g.dst, g.num_vertices)
    with pytest.raises(ValueError, match="unknown program"):
        sess.run("triangle-count")
    with pytest.raises(ValueError, match="unknown program"):
        resolve_program("kcore", 10)
    # the full registry resolves (sssp et al. joined the library)
    for name in sorted({"pagerank", "cc", "sssp", "bfs"}):
        assert resolve_program(name, 10).name == name


# --------------------------------------------------- multidevice smoke

SESSION_SMOKE = """
import numpy as np

from repro.core import CLUGPConfig, web_graph
from repro.launch.mesh import make_graph_mesh
from repro.session import GraphSession, SessionConfig

g = web_graph(scale=9, edge_factor=6, seed=3)
k = 4
cfg = SessionConfig(clugp=CLUGPConfig.optimized(k, restream=1),
                    backend="sharded", nodes=4, exchange="quantized")
s1 = GraphSession(cfg).partition(g.src, g.dst, g.num_vertices)
s2 = GraphSession.from_json(s1.to_json()).partition(g.src, g.dst,
                                                    g.num_vertices)
# the JSON blob reproduces the sharded partition exactly
np.testing.assert_array_equal(s1.assign, s2.assign)
assert s1.stats["backend"] == "sharded" and s1.stats["nodes"] == 4

# shard_map GAS over a real 4-device mesh == stacked simulation, bit for bit
mesh = make_graph_mesh(k)
sim = s1.run("pagerank", iters=15, exchange="dense")
sh = s1.run("pagerank", iters=15, exchange="dense", mesh=mesh)
np.testing.assert_array_equal(sh, sim)

# dry-run cells from round-tripped sessions compile to identical
# collective bytes (the reproducibility contract on the wire)
from repro.analysis.ir import collective_bytes
bytes_ = []
for s in (s1, s2):
    jitted, args = s.dryrun_step("pagerank", mesh=mesh)
    bytes_.append(collective_bytes(jitted.lower(*args).compile().as_text()))
assert bytes_[0] == bytes_[1], bytes_
assert bytes_[0]["total"] > 0, bytes_
print("SESSION_OK", bytes_[0]["total"])
"""


@pytest.mark.multidevice
def test_session_multidevice_smoke(multidevice):
    out = multidevice(SESSION_SMOKE, n_devices=8)
    assert "SESSION_OK" in out
