"""Partitioning-as-a-service: GraphServer query/ingest/preemption suite.

The server only batches, caches, and swaps — it must never change the
compute.  So the gates are identities: batched replies bit-match direct
``GraphSession.run``/``run_many`` on the same layout; a window flush plus
watermark restream leaves RF ≤ the drifted RF (the restream repair is
monotone by construction); a server rebuilt from its ``ServiceFT``
snapshot carries the identical config blob, edges, and assignment.
"""
import numpy as np
import pytest

from conftest import random_graph_and_assign

from repro.core import (CLUGPConfig, incremental_assign, metrics,
                        restream_assign, stream_state, web_graph)
from repro.dist.ft import ServiceFT
from repro.serve import QUERY_KINDS, GraphServer
from repro.session import GraphSession, SessionConfig


def make_server(seed=0, k=4, scale=10, exchange="halo", **kw):
    g = web_graph(scale=scale, seed=seed)
    cfg = SessionConfig(clugp=CLUGPConfig(k=k), iters=8, exchange=exchange)
    sess = GraphSession(cfg).partition(g.src, g.dst, g.num_vertices)
    return GraphServer(sess.layout(), **kw), g


# ------------------------------------------------------------- queries

def test_batched_queries_match_direct_run():
    srv, g = make_server(max_batch=8)
    ref = GraphSession.from_json(srv.sess.to_json()).with_partition(
        g.src, g.dst, g.num_vertices, srv.sess.assign)
    rng = np.random.default_rng(1)
    verts = rng.integers(0, g.num_vertices, 16)
    tickets = {p: srv.submit("score", program=p, vertices=verts)
               for p in ("pagerank", "degree", "cc")}
    t_full = srv.submit("label")          # default cc, full dense vector
    assert srv.serve_pending() == 4
    for p, t in tickets.items():
        want = ref.run(p, iters=8, exchange="halo")[verts]
        got = srv.result(t).value
        assert got.dtype == want.dtype
        assert np.array_equal(got, want), p
    assert np.array_equal(srv.result(t_full).value,
                          ref.run("cc", iters=8, exchange="halo"))


def test_queries_match_on_every_exchange():
    # the server executes through run_many, so its replies are
    # bit-identical to a direct run_many on every wire — lossy included;
    # vs the single-program run the lossy wires differ only by the fused
    # encoding's quantization error (wire tolerance)
    for ex in ("dense", "quantized", "ragged_quantized"):
        srv, g = make_server(exchange=ex)
        ref = GraphSession.from_json(srv.sess.to_json()).with_partition(
            g.src, g.dst, g.num_vertices, srv.sess.assign)
        t = srv.submit("score", program="pagerank")
        srv.step()
        got = srv.result(t).value
        want = ref.run_many(["pagerank"], iters=8, exchange=ex)[0]
        assert np.array_equal(got, want), ex
        single = ref.run("pagerank", iters=8, exchange=ex)
        if ex == "dense":
            assert np.array_equal(got, single)
        else:
            # int8-scale wire error on (V,)-normalized rank mass
            assert np.allclose(got, single, rtol=0.05, atol=2e-4), ex


def test_fused_microbatch_and_value_cache():
    srv, _ = make_server(max_batch=16)
    calls = []
    inner = srv.sess.run_many

    def counting_run_many(progs, **kw):
        calls.append([p.name for p in progs])
        return inner(progs, **kw)

    srv.sess.run_many = counting_run_many
    # pagerank+degree share no cell (f32 vs i32 sum) → two fused calls;
    # cc rides the i32/min cell alone
    for p in ("pagerank", "degree", "cc", "pagerank", "degree"):
        srv.submit("score", program=p, vertices=[0])
    assert srv.step() == 5
    assert srv.stats["microbatches"] == 1
    assert sorted(len(c) for c in calls) == [1, 1, 1]
    # every vector is now cached: a second microbatch computes nothing
    for p in ("pagerank", "degree", "cc"):
        srv.submit("score", program=p, vertices=[1])
    calls.clear()
    srv.step()
    assert calls == []


def test_owner_and_neighbors_queries():
    srv, g = make_server()
    lay = srv.sess.partition_layout
    t1 = srv.submit("owner", vertices=[0, 7, 23])
    t2 = srv.submit("neighbors", vertices=[0, 7])
    srv.serve_pending()
    own = srv.result(t1).value
    assert own.shape == (3,) and own.min() >= 0 and own.max() < lay.k
    # owner really is the master device of that vertex in the layout
    for v, p in zip([0, 7, 23], own):
        gids = lay.vert_gid[p][lay.is_master[p]]
        assert v in gids
    nb = srv.result(t2).value
    want0 = np.unique(np.concatenate([g.dst[g.src == 0],
                                      g.src[g.dst == 0]]))
    assert np.array_equal(nb[0], want0)


def test_bad_requests_are_rejected():
    srv, _ = make_server()
    with pytest.raises(ValueError, match="unknown query kind"):
        srv.submit("foo")
    with pytest.raises(ValueError, match="need vertices"):
        srv.submit("owner")
    t = srv.submit("score", program="not-a-program")
    srv.step()
    assert "unknown program" in srv.result(t).error
    assert tuple(QUERY_KINDS) == ("score", "label", "neighbors", "owner")


# ----------------------------------------------------- incremental path

def test_incremental_assign_seeds_resident_loads():
    src, dst, n, assign = random_graph_and_assign(seed=3, k=4)
    cfg = CLUGPConfig(k=4)
    rng = np.random.default_rng(4)
    ws = rng.integers(0, n, 200)
    wd = rng.integers(0, n, 200)
    wa = incremental_assign(src, dst, ws, wd, assign, n, cfg)
    assert wa.shape == (200,) and wa.min() >= 0 and wa.max() < 4
    # the grown stream respects the grown balance cap τ·(E_old+E_new)/k
    loads = np.bincount(np.concatenate([assign, wa]), minlength=4)
    lmax = cfg.tau * (len(src) + 200) / 4
    assert loads.max() <= int(np.ceil(lmax))
    # stream_state marks exactly the vertices replicated >= 2 partitions
    st = stream_state(src, dst, assign, n, 4)
    v = int(src[0])
    parts = np.unique(assign[(src == v) | (dst == v)])
    assert bool(st.divided[v]) == (len(parts) > 1)


def test_restream_assign_is_monotone():
    src, dst, n, assign = random_graph_and_assign(seed=5, k=8)
    cfg = CLUGPConfig(k=8)
    rf0 = metrics.replication_factor(src, dst, assign, n, 8)
    best, trace = restream_assign(src, dst, assign, n, cfg, passes=2)
    rf1 = metrics.replication_factor(src, dst, best, n, 8)
    assert len(trace) == 2 and trace[0] == pytest.approx(rf0)
    assert rf1 <= rf0 + 1e-12       # never worse than the input


def test_window_ingest_flush_and_watermark_restream():
    srv, g = make_server(window=400, rf_watermark=1.01,
                         restream_passes=2)
    e0 = len(srv.sess.edges[0])
    rng = np.random.default_rng(6)
    n = g.num_vertices
    flushed = False
    for _ in range(4):
        flushed |= srv.ingest(rng.integers(0, n, 110),
                              rng.integers(0, n, 110))
    assert flushed and srv.stats["windows"] >= 1
    assert len(srv.sess.edges[0]) == e0 + 440 - srv._buffered
    drifted = [v for e, v in srv.rf_trace if e == "window"]
    repaired = [v for e, v in srv.rf_trace if e == "restream"]
    assert srv.stats["restreams"] >= 1
    assert repaired[-1] <= max(drifted) + 1e-12
    # the swapped layout serves the grown graph, caches invalidated
    t = srv.submit("score", program="pagerank", vertices=[0])
    srv.step()
    assert srv.result(t).error is None
    assert srv.sess.partition_layout.num_edges == len(srv.sess.edges[0])


def test_tol_server_warm_starts_after_swap():
    """With ``tol`` set the server's value caches double as warm-start
    seeds: after a window flush + restream swaps the layout, the next
    query re-converges from the pre-swap fixed point in strictly fewer
    iterations than a cold run on the grown graph — and lands within the
    convergence envelope of the cold fixed point."""
    srv, g = make_server(window=400, rf_watermark=1.01,
                         restream_passes=2, tol=1e-6, iters=40)
    t = srv.submit("score", program="pagerank", vertices=[0])
    srv.step()
    assert srv.result(t).error is None
    first_iters = max(srv.last_iters_run.values())
    assert 0 < first_iters <= 40
    rng = np.random.default_rng(6)
    n = g.num_vertices
    for _ in range(4):
        srv.ingest(rng.integers(0, n, 110), rng.integers(0, n, 110))
    assert srv.stats["restreams"] >= 1
    assert not srv._values          # swap invalidated the caches...
    assert srv._warm                # ...into warm-start seeds
    srv.last_iters_run.clear()
    t2 = srv.submit("score", program="pagerank", vertices=[0, 1])
    srv.step()
    assert srv.result(t2).error is None
    warm_iters = max(srv.last_iters_run.values())
    cold, cold_iters = srv.sess.run_many(
        ["pagerank"], iters=40, exchange="halo", tol=1e-6,
        init_values=[np.zeros(0)], return_iters=True)
    assert warm_iters < cold_iters, (warm_iters, cold_iters)
    # both runs stopped inside the tol envelope of the same fixed point
    warm_full = srv._values[("pagerank", "halo")]
    np.testing.assert_allclose(warm_full, cold[0], atol=1e-4)


def test_tol_server_cold_and_warm_share_compute_semantics():
    """A tol server with nothing cached runs the cold path through the
    same loop: its replies bit-match a direct ``run_many`` with the same
    tol and empty seeds."""
    srv, g = make_server(tol=1e-6, iters=40)
    verts = [0, 1, 2, 3]
    t = srv.submit("score", program="pagerank", vertices=verts)
    srv.step()
    direct, _ = srv.sess.run_many(
        ["pagerank"], iters=40, exchange="halo", tol=1e-6,
        init_values=[np.zeros(0)], return_iters=True)
    assert np.array_equal(srv.result(t).value, direct[0][verts])


def test_ingest_can_grow_the_vertex_set():
    srv, g = make_server(window=50)
    n0 = srv.sess.num_vertices
    srv.ingest(np.arange(n0, n0 + 50), np.zeros(50, dtype=np.int64))
    assert srv.sess.num_vertices == n0 + 50
    t = srv.submit("owner", vertices=[n0 + 10])
    srv.step()
    assert srv.result(t).error is None


# -------------------------------------------------------- preemption

def test_kill_and_resume_identical_partition(tmp_path):
    srv, g = make_server(window=300, rf_watermark=1.01)
    rng = np.random.default_rng(7)
    srv.ingest(rng.integers(0, g.num_vertices, 300),
               rng.integers(0, g.num_vertices, 300))
    srv.ft = ServiceFT(tmp_path)
    srv.checkpoint()
    srv.ft.wait()
    blob, assign = srv.sess.to_json(), srv.sess.assign.copy()
    t = srv.submit("score", program="pagerank", vertices=[0, 1, 2])
    srv.step()
    want = srv.result(t).value
    del srv                                    # the "kill"
    srv2 = GraphServer.resume(ServiceFT(tmp_path))
    assert srv2.sess.to_json() == blob         # same config blob
    assert np.array_equal(srv2.sess.assign, assign)
    t2 = srv2.submit("score", program="pagerank", vertices=[0, 1, 2])
    srv2.step()
    assert np.array_equal(srv2.result(t2).value, want)


def test_resume_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        GraphServer.resume(ServiceFT(tmp_path))


def test_snapshot_survives_graph_growth(tmp_path):
    # the shape-blind restore path: snapshots of different sizes in the
    # same dir, latest wins
    srv, g = make_server(window=100)
    srv.ft = ServiceFT(tmp_path)
    srv.checkpoint()
    srv.ingest(np.zeros(100, np.int64),
               np.arange(1, 101, dtype=np.int64))
    srv.checkpoint()
    srv.ft.wait()
    srv2 = GraphServer.resume(ServiceFT(tmp_path))
    assert len(srv2.sess.edges[0]) == len(srv.sess.edges[0])


# ------------------------------------------------------- multidevice

@pytest.mark.multidevice
def test_serve_shard_map_smoke(multidevice):
    """The server's fused query step shard_maps one partition per device
    and still bit-matches the single-device simulate path."""
    multidevice("""
        import numpy as np
        from repro.core import CLUGPConfig, web_graph
        from repro.launch.mesh import make_graph_mesh
        from repro.serve import GraphServer
        from repro.session import GraphSession, SessionConfig

        g = web_graph(scale=10, seed=0)
        cfg = SessionConfig(clugp=CLUGPConfig(k=8), iters=6,
                            exchange="halo")
        sess = GraphSession(cfg).partition(g.src, g.dst,
                                           g.num_vertices).layout()
        mesh = make_graph_mesh(8)
        srv = GraphServer(sess, mesh=mesh)
        t1 = srv.submit("score", program="pagerank")
        t2 = srv.submit("score", program="degree")
        srv.serve_pending()
        ref = GraphSession.from_json(sess.to_json()).with_partition(
            g.src, g.dst, g.num_vertices, sess.assign)
        assert np.array_equal(srv.result(t1).value,
                              ref.run("pagerank", iters=6))
        assert np.array_equal(srv.result(t2).value,
                              ref.run("degree", iters=6))
        print("serve shard_map smoke OK")
        """, n_devices=8)
