"""Compare all partitioners across k — a minified Fig. 3/7, plus the
per-iteration GAS wire cost each partition would pay on the engine's two
exchange backends (dense padded all_gather vs mirror-routed halo
all_to_all) next to the ragged ideal.

    PYTHONPATH=src:. python examples/partition_compare.py
"""
import numpy as np

from benchmarks.common import quality_row, run_partitioner, stream_for
from repro.core import web_graph
from repro.graph import build_layout

g = web_graph(scale=12, edge_factor=8, seed=0)
print(f"web graph: |V|={g.num_vertices} |E|={g.num_edges}")
print(f"{'algo':12s} {'k':>4s} {'RF':>8s} {'balance':>8s} {'µs/edge':>9s} "
      f"{'dense kB/it':>12s} {'halo kB/it':>11s} {'ideal kB/it':>12s}")
for k in (4, 16, 64):
    for algo in ("clugp", "clugp-opt", "hashing", "dbh", "greedy", "hdrf",
                 "mint"):
        out = run_partitioner(algo, g, k, 0)
        r = quality_row(algo, g, k, out=out)
        src, dst = stream_for(algo, g, out)
        lay = build_layout(np.asarray(src), np.asarray(dst), out[0],
                           g.num_vertices, k)
        print(f"{r['algo']:12s} {r['k']:>4d} {r['rf']:>8.3f} "
              f"{r['balance']:>8.3f} {r['us_per_edge']:>9.2f} "
              f"{lay.comm_bytes_mirror_sync()/1e3:>12.1f} "
              f"{lay.comm_bytes_halo()/1e3:>11.1f} "
              f"{lay.comm_bytes_ideal()/1e3:>12.1f}")
