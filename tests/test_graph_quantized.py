"""Quantized halo exchange: error-feedback pagerank accuracy, exact int32
CC passthrough, byte-model ordering, and int8 lane round-trip properties.
(The shard_map driver equivalences run in tests/test_dist_multidevice.py.)"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import CLUGPConfig, partition
from repro.core.graphgen import web_graph
from repro.dist.halo import get_exchange
from repro.graph import (CC_PROGRAM, build_layout, pagerank_program,
                         reference_cc, reference_pagerank, simulate_cc,
                         simulate_pagerank)

from conftest import random_graph_and_assign as _random_graph_and_assign


# ------------------------------------------------- error-feedback pagerank

@pytest.mark.parametrize("seed", [0, 1])
def test_quantized_pagerank_converges_to_reference(seed):
    """Delta-coded int8 lanes with error feedback: the residual carries the
    quantization error across iterations, so 30 iterations land within a
    tight tolerance of the fp32 oracle instead of dithering at one int8
    quantization step."""
    src, dst, n, assign = _random_graph_and_assign(seed, 8, n=400)
    lay = build_layout(src, dst, assign, n, 8)
    ref = reference_pagerank(src, dst, n, iters=30)
    pr_q = simulate_pagerank(lay, iters=30, exchange="quantized")
    assert np.abs(pr_q - ref).max() < 1e-5
    # and it matches the exact halo backend to the same tolerance
    pr_h = simulate_pagerank(lay, iters=30, exchange="halo")
    assert np.abs(pr_q - pr_h).max() < 1e-5


def test_quantized_pagerank_on_partition():
    g = web_graph(scale=10, edge_factor=8, seed=0)
    k = 8
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(k))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, k)
    ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
    pr_q = simulate_pagerank(lay, iters=30, exchange="quantized")
    assert np.abs(pr_q - ref).max() < 1e-5


@pytest.mark.parametrize("seed", [0, 1])
def test_ragged_quantized_pagerank_converges_not_diverges(seed):
    """Divergence regression for the top-Δ encoder: sparsified error
    feedback must NOT carry a separate residual (the outstanding delta
    lanes − sref already contains every un-sent lane; re-adding a carry
    doubles them each round, which blew up ~2× per iteration).  The fix
    makes the error strictly SHRINK with more iterations — the old
    encoder passed loose 30-iter checks while exploding by iter 100."""
    src, dst, n, assign = _random_graph_and_assign(seed, 8, n=400)
    lay = build_layout(src, dst, assign, n, 8)
    errs = {}
    for iters in (30, 100):
        ref = reference_pagerank(src, dst, n, iters=iters)
        pr = simulate_pagerank(lay, iters=iters,
                               exchange="ragged_quantized")
        errs[iters] = np.abs(pr - ref).max()
    assert errs[30] < 1e-3, errs
    assert errs[100] < 1e-6, errs
    assert errs[100] < errs[30], errs


# ------------------------------------------------- exact int32 CC path

@pytest.mark.parametrize("seed", [0, 1])
def test_quantized_cc_is_exact(seed):
    """combine="min" programs skip quantization (int32 labels are exact on
    the wire), so quantized CC is bit-identical to dense/halo CC."""
    src, dst, n, assign = _random_graph_and_assign(seed, 8, n=400)
    lay = build_layout(src, dst, assign, n, 8)
    ref = reference_cc(src, dst, n)
    cc_q = simulate_cc(lay, iters=40, exchange="quantized")
    cc_d = simulate_cc(lay, iters=40, exchange="dense")
    touched = np.zeros(n, bool)
    touched[src] = touched[dst] = True
    np.testing.assert_array_equal(cc_q[touched], ref[touched])
    np.testing.assert_array_equal(cc_q, cc_d)


def test_quantized_state_empty_for_min_and_int_programs():
    """The quantized exchange only materializes reference/residual state
    for lossily-coded (fp32, sum) programs; CC's int32 min payload rides
    the exact halo path with an empty carry."""
    src, dst, n, assign = _random_graph_and_assign(2, 4, n=120)
    lay = build_layout(src, dst, assign, n, 4)
    dev = {f: jnp.asarray(getattr(lay, f))
           for f in ("halo_send", "halo_recv")}
    ex = get_exchange("quantized")
    assert ex.init_state(dev, CC_PROGRAM.dtype, CC_PROGRAM.combine) == ()
    prog = pagerank_program(n)
    state = ex.init_state(dev, prog.dtype, prog.combine)
    assert set(state) == {"reduce", "bcast"}
    for phase in state.values():
        assert set(phase) == {"sref", "sres", "rref"}
        for arr in phase.values():
            assert arr.shape == lay.halo_send.shape
            assert not arr.any()


# ------------------------------------------------- byte model ordering

def test_comm_model_quantized_below_halo_below_dense():
    g = web_graph(scale=10, edge_factor=8, seed=0)
    k = 8
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(k))
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, k)
    assert lay.comm_bytes("quantized") < lay.comm_bytes("halo")
    assert lay.comm_bytes("halo") < lay.comm_bytes("dense")
    # int8 codes + one fp32 scale per lane group, 2 phases/iter
    assert lay.comm_bytes("quantized") == \
        2 * k * (k - 1) * (lay.h_max + 4)


def test_dryrun_ordering_gate_flags_regressions():
    from repro.launch.dryrun import check_graph_ordering

    def rec(program, exchange, wire, lossy=True):
        return {"program": program, "exchange": exchange, "status": "ok",
                "lossy_payload": lossy, "collective_bytes_wire": wire}

    def prog(name, d, h, q, rg, rq, lossy=True):
        return [rec(name, "dense", d, lossy), rec(name, "halo", h, lossy),
                rec(name, "quantized", q, lossy),
                rec(name, "ragged", rg, lossy),
                rec(name, "ragged_quantized", rq, lossy)]

    # lossy: quantized < halo < dense, ragged ≤ halo, ragged_q < quantized;
    # exact: quantized == halo and ragged_quantized == ragged are allowed
    good = prog("pagerank", 100, 40, 12, 30, 9) + \
        prog("cc", 100, 40, 40, 30, 30, lossy=False)
    assert check_graph_ordering(good) == []
    bad = prog("pagerank", 100, 100, 100, 100, 100)
    # halo ≥ dense, quantized ≥ halo, ragged_quantized ≥ quantized
    assert len(check_graph_ordering(bad)) == 3
    # a lossy program's quantized cell must be strictly below halo
    tie = prog("pagerank", 100, 40, 40, 30, 9)
    assert len(check_graph_ordering(tie)) == 1
    # the ragged ring may never ship more than the padded halo wire
    fat = prog("pagerank", 100, 40, 12, 41, 9)
    assert any("ragged" in m for m in check_graph_ordering(fat))
    # exact payloads must ride the exact ring: ragged_quantized != ragged
    drift = prog("cc", 100, 40, 40, 30, 29, lossy=False)
    assert any("exact-payload" in m for m in check_graph_ordering(drift))
    # ragged_quantized vs ragged is deliberately ungated for lossy rows
    # (index+scale overhead can exceed tiny exact hops)
    over = prog("pagerank", 100, 40, 12, 8, 9)
    assert check_graph_ordering(over) == []
    failed = good[:9] + [{"program": "cc", "exchange": "ragged_quantized",
                          "status": "FAIL: boom"}]
    assert any("boom" in m for m in check_graph_ordering(failed))


# ------------------------------------------------- int4 group quantizer

def test_quantize_groups_pads_non_multiple_of_8_rows():
    """Regression: lane rows whose width is not a multiple of the 8
    scale subgroups (layouts built with pad_multiple < 8, or ragged hop
    widths) must zero-pad up to one before grouping — the quantizer once
    required divisibility and broke on any other width.  Pad lanes
    quantize to code 0, the trailing dim stays even for the nibble pack,
    and the real lanes round-trip within half a group's grid step."""
    from repro.dist.halo import (_NUM_SCALE_GROUPS, _dequantize_groups,
                                 _quantize_groups)

    rng = np.random.default_rng(0)
    for h in (1, 3, 7, 9, 20, 61):
        err = rng.standard_normal((5, h)).astype(np.float32)
        codes, scales = _quantize_groups(jnp.asarray(err))
        codes = np.asarray(codes)
        assert codes.shape[-1] % _NUM_SCALE_GROUPS == 0, h
        assert codes.shape[-1] % 2 == 0, h
        assert not codes[..., h:].any(), h
        deq = np.asarray(_dequantize_groups(
            jnp.asarray(codes), scales))[..., :h]
        tol = float(np.asarray(scales).max()) / 2 + 1e-6
        assert np.abs(deq - err).max() <= tol, h


# the int8 lane round-trip property tests (hypothesis) live in
# tests/test_properties_halo.py so this module still runs where the
# optional hypothesis dep is absent (module-level importorskip skips a
# whole file, as tests/test_properties.py relies on)
