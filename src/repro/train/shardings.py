"""Parameter → PartitionSpec rules (DP/TP/EP + ZeRO-3 over the data axis).

Paths are parsed into key components (never substring-matched — optimizer
moment keys like ``['v']`` must not collide with the attention value
projection).  ``zero=True`` additionally shards each weight's non-TP dim
over the data axis (FSDP/ZeRO-3 à la GSPMD: the compiler inserts
just-in-time all-gathers); mandatory for the ≥8B archs, off for small ones.

``sanitize_specs`` drops any mesh axis that does not evenly divide its dim
(batch=1 long-context cells, 24-head archs, …) — the fallback is
replication, never a compile failure.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_KEY_RE = re.compile(r"\['([^']+)'\]|\[(\d+)\]")
PARAM_LEAF = {"w", "b", "table", "scale", "bias", "A_log", "D", "dt_bias",
              "gate", "up", "down"}
COLUMN_MODS = {"q", "k", "v", "gate", "up", "q_b", "kv_b", "x_proj",
               "z_proj"}
ROW_MODS = {"o", "down", "out_proj"}
SMALL_MODS = {"q_a", "kv_a", "bc_proj", "dt_proj", "router"}


def _path_tokens(pstr: str) -> list[str]:
    return [a or b for a, b in _KEY_RE.findall(pstr)]


def _mod_leaf_state(pstr: str):
    toks = _path_tokens(pstr)
    state = None
    if toks and (toks[-1] in ("vr", "vc")
                 or (toks[-1] in ("v", "m")
                     and len(toks) >= 2 and toks[-2] in PARAM_LEAF)):
        state = toks[-1]
        toks = toks[:-1]
    leaf = toks[-1] if toks else ""
    mod = toks[-2] if len(toks) >= 2 else ""
    return mod, leaf, state, toks


def _base_spec(mod: str, leaf: str, toks: list[str], ndim: int, zero: bool,
               data_axes) -> list:
    za = data_axes if zero else None
    if ndim <= 1:
        return [None] * ndim
    if leaf == "table":                               # embed (V, D)
        return [ "model", za ]
    if mod == "lm_head":                              # (D, V)
        return [za, "model"]
    if mod == "experts":                              # (E, D, F)/(E, F, D)
        return ["model", za, None]
    if mod in COLUMN_MODS and leaf in ("w", "b"):
        return ([za, "model"] if leaf == "w" else ["model"])
    if mod in ROW_MODS and leaf in ("w", "b"):
        return (["model", za] if leaf == "w" else [None])
    if mod in SMALL_MODS and leaf in ("w", "b"):
        return ([za, None] if leaf == "w" else [None])
    return [None] * ndim


def param_specs(params_tree, *, zero: bool, multi_pod: bool):
    """PartitionSpec pytree for params or optimizer-state trees (adam m/v
    mirror the param; adafactor vr drops the last dim, vc dim -2)."""
    data_axes = ("pod", "data") if multi_pod else "data"

    def spec(path, leaf_arr):
        pstr = jax.tree_util.keystr(path)
        shape = leaf_arr.shape
        extra = 1 if any(t.startswith("g_") for t in _path_tokens(pstr)) \
            else 0
        mod, leaf, state, toks = _mod_leaf_state(pstr)
        core_ndim = len(shape) - extra + (1 if state in ("vr", "vc") else 0)
        s = _base_spec(mod, leaf, toks, core_ndim, zero, data_axes)
        s = (s + [None] * core_ndim)[:core_ndim]
        if state == "vr":
            s = s[:-1]
        elif state == "vc":
            del s[-2]
        ent = [None] * extra + s
        return P(*ent[:len(shape)])

    return jax.tree_util.tree_map_with_path(spec, params_tree)


def sanitize_specs(specs_tree, sds_tree, mesh: Mesh):
    """Drop axes that don't divide their dim (replicate instead)."""
    def fix(spec, sds):
        ent = list(spec) + [None] * (len(sds.shape) - len(spec))
        out = []
        for dim, ax in zip(sds.shape, ent):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            out.append(ax if dim % size == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map(fix, specs_tree, sds_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def named_shardings(specs_tree, mesh: Mesh, sds_tree=None):
    if sds_tree is not None:
        specs_tree = sanitize_specs(specs_tree, sds_tree, mesh)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs_tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_tree, *, multi_pod: bool):
    data_axes = ("pod", "data") if multi_pod else "data"

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        return P(data_axes, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(spec, batch_tree)


def cache_specs(cache_tree, *, multi_pod: bool):
    """Decode caches: KV/latent (L, B, S, …) — batch on data, sequence on
    model (SP flash-decode); SSM states (…, B, H, N, dh) — batch only."""
    data_axes = ("pod", "data") if multi_pod else "data"

    def spec(path, leaf):
        pstr = jax.tree_util.keystr(path)
        nd = leaf.ndim
        if "state" in pstr:                 # (..., B, H, N, dh)
            core = [data_axes, None, None, None]
        elif "lat" in pstr or "rope" in pstr:   # (..., B, S, C)
            core = [data_axes, "model", None]
        else:                               # k/v: (..., B, S, Hkv, Dh)
            core = [data_axes, "model", None, None]
        lead = nd - len(core)
        assert lead >= 0, (pstr, leaf.shape)
        return P(*([None] * lead + core))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)
