"""Pass 1 — streaming clustering (paper Alg. 2).

The *allocation–splitting–migration* framework.  Two interchangeable
implementations with identical semantics (tested against each other):

- ``streaming_clustering_np``  : host fast path (the partitioner runs on the
  host, like the paper's Java pipeline; the stream is inherently sequential).
- ``streaming_clustering_jax`` : ``jax.lax.scan`` over the edge stream with a
  dense carried state — the JAX-native form used under jit and in the
  multi-device pipeline (each distributed node clusters its local stream,
  paper §III-C last paragraph).

State per paper: ``clu[v]`` vertex→cluster, ``deg[v]`` streamed degree,
``vol[c]`` cluster volume (sum of member degrees), ``divided[v]`` mark.
Splitting (lines 9–18) fires when a cluster overflows ``V_max``: the
triggering vertex moves to a fresh cluster, leaving a mirror behind.
Migration (lines 20–26) pulls one endpoint into the larger cluster.

``allow_split=False`` degrades CLUGP to Hollocou et al.'s allocation–
migration (the paper's Holl baseline and the CLUGP-S ablation).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp


@dataclass
class ClusteringResult:
    clu: np.ndarray            # vertex -> compact cluster id, int32[V]
    deg: np.ndarray            # streamed degree, int32[V]
    divided: np.ndarray        # bool[V], vertex was split at least once
    replicas: np.ndarray       # int32[V], #mirrors created during clustering
    num_clusters: int

    def cluster_rf(self, num_vertices: int) -> float:
        """Replication factor at cluster granularity (Fig. 2 accounting)."""
        active = self.deg > 0
        return float((active.sum() + self.replicas[active].sum())
                     / max(1, active.sum()))


def _compact_labels(raw: np.ndarray) -> tuple[np.ndarray, int]:
    used, inv = np.unique(raw[raw >= 0], return_inverse=True)
    out = np.full(raw.shape[0], -1, dtype=np.int32)
    out[raw >= 0] = inv.astype(np.int32)
    return out, int(used.shape[0])


def streaming_clustering_np(src: np.ndarray, dst: np.ndarray,
                            num_vertices: int, vmax: float,
                            allow_split: bool = True,
                            split_degree_factor: float = 0.0) -> ClusteringResult:
    """``split_degree_factor`` is a beyond-paper damping knob: a split of
    vertex x only fires if ``deg(x) ≥ factor × mean_streamed_degree`` — the
    replica is only paid when the volume drained (deg x) is worth it.  The
    paper-faithful setting is 0 (always split on overflow, Alg. 2 verbatim);
    the optimized profile uses 4 (see EXPERIMENTS.md §Perf-partitioner)."""
    V = num_vertices
    clu = np.full(V, -1, dtype=np.int64)
    deg = np.zeros(V, dtype=np.int64)
    divided = np.zeros(V, dtype=bool)
    replicas = np.zeros(V, dtype=np.int64)
    # worst case ids: one per vertex + one per split (≤ 2 per edge)
    vol = np.zeros(V + 2 * src.shape[0] + 2, dtype=np.int64)
    next_id = 0
    seen_deg = 0
    seen_v = 0

    cl = clu  # local aliases (python-loop hot path)
    dg = deg
    vl = vol
    for i in range(src.shape[0]):
        u = int(src[i]); v = int(dst[i])
        if u == v:
            continue
        cu = cl[u]
        if cu < 0:                       # allocation (lines 3-5)
            cu = next_id; next_id += 1
            cl[u] = cu
            seen_v += 1
        cv = cl[v]
        if cv < 0:
            cv = next_id; next_id += 1
            cl[v] = cv
            seen_v += 1
        dg[u] += 1; dg[v] += 1           # line 6
        vl[cu] += 1; vl[cv] += 1         # line 7
        seen_deg += 2
        if allow_split:
            dthresh = split_degree_factor * seen_deg / seen_v
            if cu == cv:
                # same-cluster overflow: split only the higher-degree
                # endpoint and keep the edge with the lower-degree one
                # (paper §IV-A divided-vertex tie rule) — splitting both
                # would add a replica for nothing.
                if vl[cu] >= vmax:
                    x = u if dg[u] >= dg[v] else v
                    if dg[x] >= dthresh:
                        nc = next_id; next_id += 1
                        cl[x] = nc
                        divided[x] = True
                        replicas[x] += 1
                        vl[cu] -= dg[x]
                        vl[nc] += dg[x]
            else:
                if vl[cu] >= vmax and dg[u] >= dthresh:   # split u (8-13)
                    nc = next_id; next_id += 1
                    cl[u] = nc
                    divided[u] = True
                    replicas[u] += 1
                    vl[cu] -= dg[u]
                    vl[nc] += dg[u]
                cv = cl[v]
                if vl[cv] >= vmax and dg[v] >= dthresh:   # split v (14-18)
                    nc = next_id; next_id += 1
                    cl[v] = nc
                    divided[v] = True
                    replicas[v] += 1
                    vl[cv] -= dg[v]
                    vl[nc] += dg[v]
        cu = cl[u]; cv = cl[v]           # line 19
        if cu != cv and vl[cu] < vmax and vl[cv] < vmax:   # migration 20-26
            # post-guard: a migration must not overflow the target — an
            # over-full cluster would shred its members via later splits.
            if vl[cu] <= vl[cv]:
                if vl[cv] + dg[u] < vmax:
                    cl[u] = cv
                    vl[cu] -= dg[u]; vl[cv] += dg[u]
            else:
                if vl[cu] + dg[v] < vmax:
                    cl[v] = cu
                    vl[cv] -= dg[v]; vl[cu] += dg[v]

    compact, m = _compact_labels(clu)
    return ClusteringResult(compact, deg.astype(np.int32), divided,
                            replicas.astype(np.int32), m)


# ---------------------------------------------------------------------------
# JAX scan version — identical transition function, dense carried state.
# ---------------------------------------------------------------------------

def _cluster_step(state, edge, *, vmax: float, allow_split: bool,
                  split_degree_factor: float):
    clu, deg, vol, divided, replicas, next_id, seen_deg, seen_v = state
    u, v = edge[0], edge[1]
    self_loop = u == v

    def alloc(clu, next_id, seen_v, x):
        has = clu[x] >= 0
        cid = jnp.where(has, clu[x], next_id)
        clu = clu.at[x].set(cid)
        next_id = jnp.where(has, next_id, next_id + 1)
        seen_v = jnp.where(has, seen_v, seen_v + 1)
        return clu, next_id, seen_v, cid

    clu, next_id, seen_v, cu = alloc(clu, next_id, seen_v, u)
    clu, next_id, seen_v, cv = alloc(clu, next_id, seen_v, v)
    deg = deg.at[u].add(1).at[v].add(1)
    vol = vol.at[cu].add(1).at[cv].add(1)
    seen_deg = seen_deg + 2

    if allow_split:
        dthresh = split_degree_factor * seen_deg.astype(jnp.float32) \
            / jnp.maximum(seen_v, 1).astype(jnp.float32)
        same = cu == cv

        def split_one(carry, target, fire):
            clu, vol, divided, replicas, next_id = carry
            cx = clu[target]
            dx = deg[target]
            nc = next_id
            clu = clu.at[target].set(jnp.where(fire, nc, cx))
            vol = vol.at[cx].add(jnp.where(fire, -dx, 0))
            vol = vol.at[nc].add(jnp.where(fire, dx, 0))
            divided = divided.at[target].set(divided[target] | fire)
            replicas = replicas.at[target].add(fire.astype(jnp.int32))
            next_id = next_id + fire.astype(jnp.int32)
            return (clu, vol, divided, replicas, next_id)

        carry = (clu, vol, divided, replicas, next_id)
        # same-cluster overflow → split only the higher-degree endpoint;
        # different clusters → split u first (Alg. 2 lines 8-13)
        x = jnp.where(deg[u] >= deg[v], u, v)
        target1 = jnp.where(same, x, u)
        d1ok = deg[target1].astype(jnp.float32) >= dthresh
        fire1 = (vol[clu[target1]] >= vmax) & d1ok
        carry = split_one(carry, target1, fire1)
        clu, vol, divided, replicas, next_id = carry
        # v-split only applies in the different-cluster branch (14-18)
        d2ok = deg[v].astype(jnp.float32) >= dthresh
        fire2 = (~same) & (vol[clu[v]] >= vmax) & d2ok
        carry = split_one(carry, v, fire2)
        clu, vol, divided, replicas, next_id = carry

    cu, cv = clu[u], clu[v]
    both_room = (vol[cu] < vmax) & (vol[cv] < vmax) & (cu != cv)
    du, dv = deg[u], deg[v]
    # migration post-guard: must not overflow the target
    u_moves = both_room & (vol[cu] <= vol[cv]) & (vol[cv] + du < vmax)
    v_moves = both_room & (vol[cu] > vol[cv]) & (vol[cu] + dv < vmax)
    clu = clu.at[u].set(jnp.where(u_moves, cv, clu[u]))
    clu = clu.at[v].set(jnp.where(v_moves, cu, clu[v]))
    vol = vol.at[cu].add(jnp.where(u_moves, -du, 0) + jnp.where(v_moves, dv, 0))
    vol = vol.at[cv].add(jnp.where(u_moves, du, 0) + jnp.where(v_moves, -dv, 0))

    # a self loop must leave the state untouched
    def freeze(new, old):
        return jax.tree_util.tree_map(
            lambda n, o: jnp.where(self_loop, o, n), new, old)

    new_state = (clu, deg, vol, divided, replicas, next_id, seen_deg, seen_v)
    return freeze(new_state, state), None


def streaming_clustering_jax(src, dst, num_vertices: int, vmax: float,
                             allow_split: bool = True,
                             split_degree_factor: float = 0.0):
    """lax.scan form; returns raw (non-compacted) labels + state arrays."""
    E = src.shape[0]
    cap = num_vertices + 2 * E + 2
    state = (
        jnp.full((num_vertices,), -1, dtype=jnp.int32),
        jnp.zeros((num_vertices,), dtype=jnp.int32),
        jnp.zeros((cap,), dtype=jnp.int32),
        jnp.zeros((num_vertices,), dtype=bool),
        jnp.zeros((num_vertices,), dtype=jnp.int32),
        jnp.int32(0),
        jnp.int32(0),
        jnp.int32(0),
    )
    edges = jnp.stack([jnp.asarray(src, jnp.int32),
                       jnp.asarray(dst, jnp.int32)], axis=1)
    step = lambda s, e: _cluster_step(
        s, e, vmax=float(vmax), allow_split=allow_split,
        split_degree_factor=float(split_degree_factor))
    (clu, deg, vol, divided, replicas, next_id, _, _), _ = jax.lax.scan(
        step, state, edges)
    return clu, deg, divided, replicas, next_id


def clustering_result_from_jax(clu, deg, divided, replicas) -> ClusteringResult:
    compact, m = _compact_labels(np.asarray(clu))
    return ClusteringResult(compact, np.asarray(deg), np.asarray(divided),
                            np.asarray(replicas), m)


def default_vmax(num_edges: int, k: int) -> float:
    """Paper §VI-A: V_max = |E| / k."""
    return max(2.0, num_edges / float(k))
