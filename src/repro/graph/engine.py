"""Distributed vertex-cut GAS engine (PowerGraph semantics) on shard_map.

Per iteration (paper §II-B): local scatter/gather over the partition's edges
(segment_sum — the ``csr_spmv`` Pallas kernel's op), mirror partials reduced
to masters, masters apply, new values broadcast back to mirrors.  The two
mirror-sync phases go through the pluggable exchange layer
(``repro.dist.halo``):

- ``exchange="dense"``: two all_gathers of (k, L_max) values — simple, but
  bytes scale with k²·L_max regardless of partition quality (the seed wire
  format).
- ``exchange="halo"``: two all_to_alls over the layout's static mirror
  routing tables — bytes scale with the mirror count (RF−1)·|V|, the
  quantity the partitioner optimizes, so Fig. 8's mechanism shows up on
  the wire.
- ``exchange="quantized"``: halo routing with int8 delta-coded lanes +
  per-lane-group scales and an error-feedback residual threaded through
  the iteration carry — ~4× fewer payload bytes for fp32 programs, exact
  int32 passthrough for ``combine="min"`` programs (CC labels).
- ``exchange="ragged"`` / ``"ragged_quantized"``: the all_to_all's
  cross-pair H_max padding replaced by k−1 ppermute ring hops, each
  padded only to its own distance's lane population (the layout's
  ``halo_schedule()``, baked into the exchange instance as a static
  tuple — which is why the jitted drivers below key their caches on the
  exchange *instance*, not its name).  The quantized variant ships only
  the top-Δ largest error-feedback deltas per hop (int16 index + int8
  code pairs).

The engine is **program-parametric**: a ``GASProgram`` bundles the four
per-device callables (init / local gather-scatter / apply / optional
global aux) plus the combine op and wire dtype, and one pair of drivers
runs any program:

- ``simulate_gas(program, …)``   : stacked (k, …) arrays on one device —
                                   tests and host-side benchmarks.
- ``shard_map_gas(program, …)``  : one partition per mesh device over axis
                                   ``parts`` — the production path.

``simulate_pagerank`` / ``shard_map_pagerank`` / ``simulate_cc`` /
``shard_map_cc`` are thin instantiations of ``pagerank_program()`` /
``CC_PROGRAM`` over those two drivers, so the simulated and shard_map
paths run the same per-device math by construction and can't drift.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .partition import PartitionLayout
from ..dist._compat import shard_map
from ..dist.halo import get_exchange

DAMPING = 0.85
# CC labels are int32 vertex ids; the min-identity sentinel marks padded /
# non-master slots and can never win a minimum against a real id
CC_SENTINEL = int(np.iinfo(np.int32).max)


# ----------------------------------------------------------- program spec

@dataclass(frozen=True)
class GASProgram:
    """One GAS computation as per-device callables over the layout's
    ``device_arrays()`` pytree (all (L_max,)-shaped per device):

      init(dev)               -> initial per-slot values
      local(value, dev)       -> gather/scatter partials over local edges
      apply(total, aux, dev)  -> new master-slot values (others get the
                                 combine identity / sentinel)
      aux(value, dev)         -> optional per-device scalar, reduced
                                 globally (psum / stacked sum) before
                                 ``apply`` — pagerank's dangling mass

    ``combine`` ("sum" | "min") and ``dtype`` fix the mirror-sync wire
    semantics; the quantized exchange uses them to decide whether the
    payload may be lossily delta-coded (fp32 sum) or must ship exact
    (int32 min)."""
    name: str
    combine: str
    dtype: Any
    init: Callable
    local: Callable
    apply: Callable
    aux: Callable | None = None


# ----------------------------------------------------------- per-device math

def _local_rank_partial(rank, dev):
    """Scatter phase: Σ_{(u,w)∈E_p, w=v} rank[u]/outdeg[u] per local slot."""
    l_max = dev["vert_gid"].shape[0]
    safe_deg = jnp.maximum(dev["out_deg"], 1)
    contrib = jnp.where(dev["vert_mask"] & (dev["out_deg"] > 0),
                        rank / safe_deg, 0.0)
    contrib = jnp.concatenate([contrib, jnp.zeros((1,), contrib.dtype)])
    per_edge = jnp.where(dev["edge_mask"], contrib[dev["edge_src"]], 0.0)
    return jax.ops.segment_sum(per_edge, dev["edge_dst"],
                               num_segments=l_max + 1)[:l_max]


def _local_dangle(rank, dev):
    """Rank mass sitting on dangling masters (out_deg == 0)."""
    m = dev["vert_mask"] & dev["is_master"] & (dev["out_deg"] == 0)
    return jnp.sum(jnp.where(m, rank, 0.0))


def _pagerank_apply(total_in, dangle, dev, num_vertices):
    base = (1.0 - DAMPING) / num_vertices
    new = base + DAMPING * (total_in + dangle / num_vertices)
    return jnp.where(dev["vert_mask"] & dev["is_master"], new, 0.0)


@lru_cache(maxsize=None)
def pagerank_program(num_vertices: int) -> GASProgram:
    """Damped pagerank with dangling-mass redistribution (fp32, sum
    combine — the quantized exchange may delta-code its mirror lanes).
    Cached per vertex count so repeated layouts hit the same jit cache."""
    def init(dev):
        return jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)

    def apply(total, dangle, dev):
        return _pagerank_apply(total, dangle, dev, num_vertices)

    return GASProgram(name="pagerank", combine="sum", dtype=jnp.float32,
                      init=init, local=_local_rank_partial, apply=apply,
                      aux=_local_dangle)


def _cc_init(dev):
    return jnp.where(dev["vert_mask"], dev["vert_gid"].astype(jnp.int32),
                     CC_SENTINEL)


def _cc_local_min(label, dev):
    """Edge-wise min exchange in both directions (undirected semantics)."""
    l_max = dev["vert_gid"].shape[0]
    lab = jnp.concatenate([jnp.where(dev["vert_mask"], label, CC_SENTINEL),
                           jnp.full((1,), CC_SENTINEL, label.dtype)])
    s, d, m = dev["edge_src"], dev["edge_dst"], dev["edge_mask"]
    vs = jnp.where(m, lab[s], CC_SENTINEL)
    vd = jnp.where(m, lab[d], CC_SENTINEL)
    out = jax.ops.segment_min(vs, d, num_segments=l_max + 1)[:l_max]
    out2 = jax.ops.segment_min(vd, s, num_segments=l_max + 1)[:l_max]
    cur = jnp.where(dev["vert_mask"], label, CC_SENTINEL)
    return jnp.minimum(cur, jnp.minimum(out, out2))


def _cc_apply(total, aux, dev):
    return jnp.where(dev["vert_mask"] & dev["is_master"], total,
                     CC_SENTINEL)


# label propagation / connected components: int32 labels are exact on the
# wire, so every exchange (incl. "quantized") ships them unquantized
CC_PROGRAM = GASProgram(name="cc", combine="min", dtype=jnp.int32,
                        init=_cc_init, local=_cc_local_min, apply=_cc_apply)


# ------------------------------------------------------- program library
#
# The engine's whole point is program-parametric multi-tenant analytics:
# each program below is a thin GASProgram instantiation with a NumPy
# ``reference_*`` oracle, spanning every wire-semantics cell the exchange
# layer distinguishes — (sum, f32) lossy delta-coded payloads with error
# feedback (pagerank / ppr / centrality), (min, i32) exact label/distance
# lattices (cc / labelprop / sssp / bfs), and (sum, i32) exact counters
# (degree).  Source / seed-set parameters are derived deterministically
# from the vertex-id space so no extra layout tables are needed.

DEFAULT_SOURCE = 0


def default_num_seeds(num_vertices: int) -> int:
    """Seed-set size for labelprop/ppr: ~V/256, at least 2."""
    return max(2, num_vertices // 256)


def _masked_ext(values, mask, fill):
    """(L_max,) values → (L_max+1,) with invalid slots and the trailing
    pad bucket forced to ``fill`` (what edge endpoint gathers read)."""
    safe = jnp.where(mask, values, fill)
    return jnp.concatenate([safe, jnp.full((1,), fill, safe.dtype)])


def _sssp_weight(gu, gv):
    """Deterministic positive edge weight from the endpoint gids (1..11)
    — gives SSSP a genuinely weighted metric with no edge-weight table."""
    return 1 + (3 * gu + 7 * gv) % 11


def _edge_gids(dev):
    gid_ext = jnp.concatenate([dev["vert_gid"],
                               jnp.full((1,), -1, jnp.int32)])
    return gid_ext[dev["edge_src"]], gid_ext[dev["edge_dst"]]


def _relax_local(dist, dev, weight_fn):
    """One Bellman-Ford relaxation over the local directed edges:
    min over incoming (u → v) of dist[u] + w(u, v), min'd with current."""
    l_max = dev["vert_gid"].shape[0]
    d_ext = _masked_ext(dist, dev["vert_mask"], CC_SENTINEL)
    du = d_ext[dev["edge_src"]]
    gu, gv = _edge_gids(dev)
    w = weight_fn(gu, gv)
    # clamping before the add keeps sentinel+w from wrapping int32
    cand = jnp.where(dev["edge_mask"] & (du < CC_SENTINEL),
                     jnp.minimum(du, CC_SENTINEL - 64) + w, CC_SENTINEL)
    relaxed = jax.ops.segment_min(cand, dev["edge_dst"],
                                  num_segments=l_max + 1)[:l_max]
    cur = jnp.where(dev["vert_mask"], dist, CC_SENTINEL)
    return jnp.minimum(cur, relaxed)


def _distance_program(name: str, source: int, weight_fn) -> GASProgram:
    def init(dev):
        at_src = dev["vert_mask"] & (dev["vert_gid"] == source)
        return jnp.where(at_src, 0, CC_SENTINEL).astype(jnp.int32)

    def local(dist, dev):
        return _relax_local(dist, dev, weight_fn)

    def apply(total, aux, dev):
        clamped = jnp.where(dev["vert_gid"] == source, 0, total)
        return jnp.where(dev["vert_mask"] & dev["is_master"], clamped,
                         CC_SENTINEL)

    return GASProgram(name=name, combine="min", dtype=jnp.int32,
                      init=init, local=local, apply=apply)


@lru_cache(maxsize=None)
def sssp_program(source: int = DEFAULT_SOURCE) -> GASProgram:
    """Single-source shortest paths (Bellman-Ford relaxations) under the
    deterministic gid-hash weights — (min, i32), exact on every wire."""
    return _distance_program("sssp", source, _sssp_weight)


@lru_cache(maxsize=None)
def bfs_program(source: int = DEFAULT_SOURCE) -> GASProgram:
    """BFS levels from ``source`` (unit-weight min-plus) — (min, i32)."""
    return _distance_program("bfs", source, lambda gu, gv: 1)


@lru_cache(maxsize=None)
def labelprop_program(num_vertices: int,
                      num_seeds: int | None = None) -> GASProgram:
    """Seeded directed label propagation — the paper's own motivating
    workload: vertices with gid < num_seeds hold their own gid as a fixed
    label; everything else takes the min label over in-neighbors each
    round.  Directed propagation + clamped seeds distinguish it from CC's
    undirected min-label contagion.  (min, i32), exact on every wire."""
    ns = default_num_seeds(num_vertices) if num_seeds is None else num_seeds

    def init(dev):
        seeded = dev["vert_mask"] & (dev["vert_gid"] < ns)
        return jnp.where(seeded, dev["vert_gid"].astype(jnp.int32),
                         CC_SENTINEL)

    def local(label, dev):
        l_max = dev["vert_gid"].shape[0]
        lab_ext = _masked_ext(label, dev["vert_mask"], CC_SENTINEL)
        prop = jnp.where(dev["edge_mask"], lab_ext[dev["edge_src"]],
                         CC_SENTINEL)
        out = jax.ops.segment_min(prop, dev["edge_dst"],
                                  num_segments=l_max + 1)[:l_max]
        cur = jnp.where(dev["vert_mask"], label, CC_SENTINEL)
        return jnp.minimum(cur, out)

    def apply(total, aux, dev):
        seeded = dev["vert_gid"] < ns
        clamped = jnp.where(seeded, dev["vert_gid"].astype(jnp.int32),
                            total)
        return jnp.where(dev["vert_mask"] & dev["is_master"], clamped,
                         CC_SENTINEL)

    return GASProgram(name="labelprop", combine="min", dtype=jnp.int32,
                      init=init, local=local, apply=apply)


def _degree_local(value, dev):
    """Per-slot incident-edge count (out at src + in at dst); ignores the
    carried value, so any iteration count ≥ 1 yields the same answer."""
    l_max = dev["vert_gid"].shape[0]
    ones = dev["edge_mask"].astype(jnp.int32)
    out = jax.ops.segment_sum(ones, dev["edge_src"],
                              num_segments=l_max + 1)[:l_max]
    inc = jax.ops.segment_sum(ones, dev["edge_dst"],
                              num_segments=l_max + 1)[:l_max]
    return out + inc


# total degree: the (sum, i32) wire cell — an integer sum combine ships
# exact on the quantized backend (lossy_payload is False)
DEGREE_PROGRAM = GASProgram(
    name="degree", combine="sum", dtype=jnp.int32,
    init=lambda dev: jnp.zeros(dev["vert_gid"].shape, jnp.int32),
    local=_degree_local,
    apply=lambda total, aux, dev: jnp.where(
        dev["vert_mask"] & dev["is_master"], total, 0))


def _cent_local(value, dev):
    """In-neighbor sum without degree normalization (A^T x)."""
    l_max = dev["vert_gid"].shape[0]
    contrib = _masked_ext(value, dev["vert_mask"],
                          jnp.zeros((), value.dtype))
    per_edge = jnp.where(dev["edge_mask"], contrib[dev["edge_src"]], 0.0)
    return jax.ops.segment_sum(per_edge, dev["edge_dst"],
                               num_segments=l_max + 1)[:l_max]


def _cent_aux(value, dev):
    """Global L1 mass of the current iterate (masters only)."""
    m = dev["vert_mask"] & dev["is_master"]
    return jnp.sum(jnp.where(m, value, 0.0))


@lru_cache(maxsize=None)
def centrality_program(num_vertices: int) -> GASProgram:
    """Approximate (eigenvector-style) centrality: damped power iteration
    x ← (1−d)/V + d·(Aᵀx)/‖x‖₁, the L1-normalized Katz/eigenvector hybrid
    — the normalization rides the engine's global-aux reduction.  (sum,
    f32): the quantized wire delta-codes it with error feedback."""
    base = (1.0 - DAMPING) / num_vertices

    def init(dev):
        return jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)

    def apply(total, norm, dev):
        new = base + DAMPING * total / jnp.maximum(norm, 1e-30)
        return jnp.where(dev["vert_mask"] & dev["is_master"], new, 0.0)

    return GASProgram(name="centrality", combine="sum", dtype=jnp.float32,
                      init=init, local=_cent_local, apply=apply,
                      aux=_cent_aux)


@lru_cache(maxsize=None)
def ppr_program(num_vertices: int,
                num_seeds: int | None = None) -> GASProgram:
    """Personalized pagerank: teleport (and dangling) mass lands on the
    seed set {gid < num_seeds} instead of uniformly — same local
    scatter/aux as pagerank, different apply.  (sum, f32) lossy wire."""
    ns = default_num_seeds(num_vertices) if num_seeds is None else num_seeds

    def init(dev):
        seeded = dev["vert_mask"] & (dev["vert_gid"] < ns)
        return jnp.where(seeded, 1.0 / ns, 0.0)

    def apply(total, dangle, dev):
        seeded = dev["vert_gid"] < ns
        teleport = jnp.where(seeded,
                             (1.0 - DAMPING) / ns + DAMPING * dangle / ns,
                             0.0)
        return jnp.where(dev["vert_mask"] & dev["is_master"],
                         DAMPING * total + teleport, 0.0)

    return GASProgram(name="ppr", combine="sum", dtype=jnp.float32,
                      init=init, local=_local_rank_partial, apply=apply,
                      aux=_local_dangle)


PROGRAM_NAMES = ("pagerank", "cc", "labelprop", "sssp", "bfs", "degree",
                 "centrality", "ppr")


def get_program(name: str, num_vertices: int) -> GASProgram:
    """Program registry: name → GASProgram with the library defaults
    (source vertex 0, ~V/256 seeds).  Factories are lru-cached so
    repeated lookups share one program instance (and its jit cache)."""
    if name == "pagerank":
        return pagerank_program(num_vertices)
    if name == "cc":
        return CC_PROGRAM
    if name == "labelprop":
        return labelprop_program(num_vertices)
    if name == "sssp":
        return sssp_program()
    if name == "bfs":
        return bfs_program()
    if name == "degree":
        return DEGREE_PROGRAM
    if name == "centrality":
        return centrality_program(num_vertices)
    if name == "ppr":
        return ppr_program(num_vertices)
    raise ValueError(f"unknown program {name!r}; expected one of "
                     f"{PROGRAM_NAMES}")


# ----------------------------------------------------------- shared body

def _gas_body(program: GASProgram, ex, dev, axis: str | None = None):
    """One GAS iteration as a ``fori_loop`` body over (value, state).

    ``axis=None`` is the stacked form: ``dev`` holds full (k, …) stacks,
    per-device callables vmap over the leading axis, and the exchange's
    ``*_stacked`` halves model the collectives.  With a mesh axis it is
    the per-device form run inside shard_map.  Both forms call the same
    ``program`` callables, so the simulated and production paths cannot
    drift."""
    stacked = axis is None

    def body(_, carry):
        value, state = carry
        if program.aux is not None:
            aux = (jnp.sum(jax.vmap(program.aux)(value, dev)) if stacked
                   else jax.lax.psum(program.aux(value, dev), axis))
        else:
            aux = None
        if stacked:
            partial_ = jax.vmap(program.local)(value, dev)
            total, state = ex.reduce_stacked(partial_, dev,
                                             program.combine, state)
            new_master = jax.vmap(
                lambda t, d: program.apply(t, aux, d))(total, dev)
            value, state = ex.broadcast_stacked(new_master, dev,
                                                program.combine, state)
        else:
            partial_ = program.local(value, dev)
            total, state = ex.reduce_to_masters(partial_, dev,
                                                program.combine, state)
            new_master = program.apply(total, aux, dev)
            value, state = ex.broadcast_from_masters(new_master, dev,
                                                     program.combine, state)
        return value, state

    return body


# ----------------------------------------------------------- simulated driver

def _stack_dev(layout: PartitionLayout, exchange: str | None = None):
    return jax.tree_util.tree_map(jnp.asarray,
                                  layout.device_arrays(exchange))


@partial(jax.jit, static_argnames=("program", "iters", "ex"))
def _sim_gas(program: GASProgram, dev, iters: int, ex):
    # ``ex`` is the exchange INSTANCE (frozen dataclass, hashable): the
    # ragged formats carry their per-layout lane schedule in the
    # instance, so the instance — not the exchange name — is the cache key
    value = jax.vmap(program.init)(dev)
    # iters == 0 must return init values without even tracing the loop
    # body — a trip-count-0 fori_loop still bakes its collectives into
    # the HLO, which the dry-run byte parser would then count
    if iters:
        state = ex.init_state(dev, program.dtype, program.combine)
        body = _gas_body(program, ex, dev)
        value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
    return value


def _collect_master_values(layout: PartitionLayout, stacked) -> np.ndarray:
    """(k, L_max) per-device values → dense (V,) using master slots."""
    vals = np.asarray(stacked)
    out = np.zeros(layout.num_vertices, dtype=vals.dtype)
    gid = layout.vert_gid
    sel = layout.is_master & layout.vert_mask
    out[gid[sel]] = vals[sel]
    return out


def simulate_gas(program: GASProgram, layout: PartitionLayout,
                 iters: int = 30, exchange: str = "dense") -> np.ndarray:
    """Stacked one-device driver for any GAS program (bit-identical math
    to ``shard_map_gas`` — the collectives become transposes/gathers)."""
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout)
    values = _sim_gas(program, dev, iters, ex)
    return _collect_master_values(layout, values)


def simulate_pagerank(layout: PartitionLayout, iters: int = 30,
                      exchange: str = "dense") -> np.ndarray:
    return simulate_gas(pagerank_program(layout.num_vertices), layout,
                        iters, exchange)


def simulate_cc(layout: PartitionLayout, iters: int = 30,
                exchange: str = "dense") -> np.ndarray:
    return simulate_gas(CC_PROGRAM, layout, iters,
                        exchange).astype(np.int64)


# ----------------------------------------------------------- shard_map driver

def shard_map_gas(program: GASProgram, layout: PartitionLayout, mesh: Mesh,
                  iters: int = 30, axis: str = "parts",
                  exchange: str = "dense") -> np.ndarray:
    """Production path: one partition per device along ``axis``.
    Requires mesh axis size == layout.k.  ``exchange`` picks the mirror
    wire format (see module docstring).  Returns (V,) master values."""
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout, axis=axis)
    spec = P(axis)

    @partial(shard_map, mesh=mesh,
             in_specs=(jax.tree_util.tree_map(lambda _: spec, dev),),
             out_specs=spec)
    def run(dev):
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        value = program.init(dev)
        if iters:
            state = ex.init_state(dev, program.dtype, program.combine)
            body = _gas_body(program, ex, dev, axis)
            value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
        return value[None]

    with mesh:
        values = run(dev)
    return _collect_master_values(layout, values)


def shard_map_pagerank(layout: PartitionLayout, mesh: Mesh,
                       iters: int = 30, axis: str = "parts",
                       exchange: str = "dense") -> np.ndarray:
    return shard_map_gas(pagerank_program(layout.num_vertices), layout,
                         mesh, iters=iters, axis=axis, exchange=exchange)


def shard_map_cc(layout: PartitionLayout, mesh: Mesh, iters: int = 30,
                 axis: str = "parts", exchange: str = "dense") -> np.ndarray:
    return shard_map_gas(CC_PROGRAM, layout, mesh, iters=iters, axis=axis,
                         exchange=exchange).astype(np.int64)


# ------------------------------------------------- fused multi-program driver

@dataclass(frozen=True)
class FusedGAS:
    """N homogeneous GAS programs executed as one fused iteration over a
    shared ``PartitionLayout``: per-program local/apply math runs stacked
    along a leading program axis, and the mirror sync ships **one**
    collective per phase with all programs' lanes concatenated (per-
    program scale groups on the quantized wire — see
    ``repro.dist.halo``'s ``*_multi`` ops).  Programs must share one
    (combine, dtype) wire cell; hashable so it can be a jit static."""
    programs: tuple[GASProgram, ...]

    def __post_init__(self):
        if not self.programs:
            raise ValueError("FusedGAS needs at least one program")
        combines = {p.combine for p in self.programs}
        dtypes = {np.dtype(p.dtype).name for p in self.programs}
        if len(combines) > 1 or len(dtypes) > 1:
            raise ValueError(
                "fused programs must share one (combine, dtype) wire "
                f"cell; got combines {sorted(combines)} and dtypes "
                f"{sorted(dtypes)}")

    @property
    def combine(self) -> str:
        return self.programs[0].combine

    @property
    def dtype(self):
        return self.programs[0].dtype

    @property
    def name(self) -> str:
        return "+".join(p.name for p in self.programs)


def fuse_programs(programs) -> FusedGAS:
    """Coerce a GASProgram sequence (or an existing FusedGAS) to FusedGAS."""
    if isinstance(programs, FusedGAS):
        return programs
    return FusedGAS(tuple(programs))


def _gas_body_multi(fused: FusedGAS, ex, dev, axis: str | None = None):
    """One fused GAS iteration over (values, state) where values carry a
    program axis: (N, L_max) per device, (k, N, L_max) stacked.  The
    per-program math is a python loop over traced stacks (unrolled at
    trace time — N is small), but each mirror-sync phase is a single
    ``*_multi`` exchange call, i.e. one collective for all N programs."""
    stacked = axis is None
    programs = fused.programs
    n = len(programs)

    def global_aux(value):
        idx = [i for i, p in enumerate(programs) if p.aux is not None]
        auxes: list = [None] * n
        if idx:
            if stacked:
                per = jnp.stack([
                    jnp.sum(jax.vmap(programs[i].aux)(value[:, i], dev))
                    for i in idx])
            else:
                per = jax.lax.psum(
                    jnp.stack([programs[i].aux(value[i], dev)
                               for i in idx]), axis)
            for j, i in enumerate(idx):
                auxes[i] = per[j]
        return auxes

    def body(_, carry):
        value, state = carry
        auxes = global_aux(value)
        if stacked:
            partials = jnp.stack(
                [jax.vmap(programs[i].local)(value[:, i], dev)
                 for i in range(n)], axis=1)
            total, state = ex.reduce_stacked_multi(partials, dev,
                                                   fused.combine, state)
            new_master = jnp.stack(
                [jax.vmap(lambda t, d, i=i: programs[i].apply(
                    t, auxes[i], d))(total[:, i], dev)
                 for i in range(n)], axis=1)
            value, state = ex.broadcast_stacked_multi(new_master, dev,
                                                      fused.combine, state)
        else:
            partials = jnp.stack([programs[i].local(value[i], dev)
                                  for i in range(n)])
            total, state = ex.reduce_to_masters_multi(partials, dev,
                                                      fused.combine, state)
            new_master = jnp.stack(
                [programs[i].apply(total[i], auxes[i], dev)
                 for i in range(n)])
            value, state = ex.broadcast_from_masters_multi(
                new_master, dev, fused.combine, state)
        return value, state

    return body


@partial(jax.jit, static_argnames=("fused", "iters", "ex"))
def _sim_gas_many(fused: FusedGAS, dev, iters: int, ex):
    value = jnp.stack([jax.vmap(p.init)(dev) for p in fused.programs],
                      axis=1)
    if iters:
        state = ex.init_state_multi(dev, fused.dtype, fused.combine,
                                    len(fused.programs))
        body = _gas_body_multi(fused, ex, dev)
        value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
    return value


def simulate_gas_many(programs, layout: PartitionLayout, iters: int = 30,
                      exchange: str = "dense") -> list[np.ndarray]:
    """Stacked one-device driver for a fused program bundle; returns one
    dense (V,) master-value array per program, in bundle order."""
    fused = fuse_programs(programs)
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout)
    values = _sim_gas_many(fused, dev, iters, ex)
    return [_collect_master_values(layout, values[:, i])
            for i in range(len(fused.programs))]


def shard_map_gas_many(programs, layout: PartitionLayout, mesh: Mesh,
                       iters: int = 30, axis: str = "parts",
                       exchange: str = "dense") -> list[np.ndarray]:
    """Production fused path: N programs per device along ``axis``, one
    mirror-sync collective per phase for the whole bundle."""
    fused = fuse_programs(programs)
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout, axis=axis)
    spec = P(axis)

    @partial(shard_map, mesh=mesh,
             in_specs=(jax.tree_util.tree_map(lambda _: spec, dev),),
             out_specs=spec)
    def run(dev):
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        value = jnp.stack([p.init(dev) for p in fused.programs])
        if iters:
            state = ex.init_state_multi(dev, fused.dtype, fused.combine,
                                        len(fused.programs))
            body = _gas_body_multi(fused, ex, dev, axis)
            value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
        return value[None]

    with mesh:
        values = run(dev)
    return [_collect_master_values(layout, values[:, i])
            for i in range(len(fused.programs))]


def gas_step_for_dryrun(program, layout: PartitionLayout,
                        mesh: Mesh, axis: str = "parts", iters: int = 1,
                        exchange: str = "dense"):
    """Returns (jitted_fn, example_args) whose .lower() the dry-run compiles
    — the graph dry-run parses each backend's collective bytes out of the
    post-SPMD HLO (``launch/dryrun.py --graph``).

    ``program`` may be a single ``GASProgram``, or a program sequence /
    ``FusedGAS``, in which case the compiled step is the fused
    multi-program iteration (one collective per phase for the bundle) so
    the dry-run can compare fused vs. separate wire bytes."""
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout, axis=axis)
    spec = P(axis)
    fused = (None if isinstance(program, GASProgram)
             else fuse_programs(program))

    @partial(shard_map, mesh=mesh,
             in_specs=(jax.tree_util.tree_map(lambda _: spec, dev),),
             out_specs=spec)
    def step(dev):
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        if fused is None:
            value = program.init(dev)
            if iters:
                state = ex.init_state(dev, program.dtype, program.combine)
                body = _gas_body(program, ex, dev, axis)
                value, _ = jax.lax.fori_loop(0, iters, body,
                                             (value, state))
        else:
            value = jnp.stack([p.init(dev) for p in fused.programs])
            if iters:
                state = ex.init_state_multi(dev, fused.dtype,
                                            fused.combine,
                                            len(fused.programs))
                body = _gas_body_multi(fused, ex, dev, axis)
                value, _ = jax.lax.fori_loop(0, iters, body,
                                             (value, state))
        return value[None]

    return jax.jit(step), (dev,)


def pagerank_step_for_dryrun(layout: PartitionLayout, mesh: Mesh,
                             axis: str = "parts", iters: int = 1,
                             exchange: str = "dense"):
    return gas_step_for_dryrun(pagerank_program(layout.num_vertices),
                               layout, mesh, axis=axis, iters=iters,
                               exchange=exchange)


# ----------------------------------------------------------- oracles

def reference_pagerank(src, dst, num_vertices, iters: int = 30) -> np.ndarray:
    """Dense single-machine oracle with identical dangling handling."""
    outdeg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(outdeg, src, 1)
    rank = np.full(num_vertices, 1.0 / num_vertices)
    base = (1.0 - DAMPING) / num_vertices
    for _ in range(iters):
        contrib = np.where(outdeg > 0, rank / np.maximum(outdeg, 1), 0.0)
        s = np.zeros(num_vertices)
        np.add.at(s, dst, contrib[src])
        dangle = rank[outdeg == 0].sum()
        rank = base + DAMPING * (s + dangle / num_vertices)
    return rank


def reference_cc(src, dst, num_vertices) -> np.ndarray:
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components
    A = sp.coo_matrix((np.ones(len(src)), (src, dst)),
                      shape=(num_vertices, num_vertices))
    _, comp = connected_components(A, directed=False)
    # canonical label: min vertex id of the component (what min-label finds)
    mins = np.full(comp.max() + 1, num_vertices, dtype=np.int64)
    np.minimum.at(mins, comp, np.arange(num_vertices))
    return mins[comp]


def _reference_relax(src, dst, num_vertices, iters, source, weights):
    """Shared Bellman-Ford oracle: iterates the exact per-round relaxation
    the engine runs, so it matches at any iteration count (converged or
    not) — unreachable vertices keep CC_SENTINEL."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    dist = np.full(num_vertices, CC_SENTINEL, dtype=np.int64)
    dist[source] = 0
    for _ in range(iters):
        du = dist[src]
        cand = np.where(du < CC_SENTINEL,
                        np.minimum(du, CC_SENTINEL - 64) + weights,
                        CC_SENTINEL)
        new = dist.copy()
        np.minimum.at(new, dst, cand)
        new[source] = 0
        dist = new
    return dist


def reference_sssp(src, dst, num_vertices, iters: int = 40,
                   source: int = DEFAULT_SOURCE) -> np.ndarray:
    """SSSP under the deterministic gid-hash weights w(u,v)=1+(3u+7v)%11."""
    s = np.asarray(src, dtype=np.int64)
    d = np.asarray(dst, dtype=np.int64)
    w = 1 + (3 * s + 7 * d) % 11
    return _reference_relax(s, d, num_vertices, iters, source, w)


def reference_bfs(src, dst, num_vertices, iters: int = 40,
                  source: int = DEFAULT_SOURCE) -> np.ndarray:
    """BFS levels from ``source`` over directed edges."""
    s = np.asarray(src, dtype=np.int64)
    return _reference_relax(s, dst, num_vertices, iters, source,
                            np.ones(len(s), dtype=np.int64))


def reference_labelprop(src, dst, num_vertices, iters: int = 40,
                        num_seeds: int | None = None) -> np.ndarray:
    """Seeded directed min-label propagation; non-seeds that no seed ever
    reaches keep CC_SENTINEL."""
    ns = default_num_seeds(num_vertices) if num_seeds is None else num_seeds
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    lab = np.full(num_vertices, CC_SENTINEL, dtype=np.int64)
    lab[:ns] = np.arange(ns)
    for _ in range(iters):
        new = lab.copy()
        np.minimum.at(new, dst, lab[src])
        new[:ns] = np.arange(ns)
        lab = new
    return lab


def reference_degree(src, dst, num_vertices) -> np.ndarray:
    """Total (in+out) degree, counting duplicate edges like the engine."""
    deg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(deg, np.asarray(src, dtype=np.int64), 1)
    np.add.at(deg, np.asarray(dst, dtype=np.int64), 1)
    return deg


def reference_centrality(src, dst, num_vertices,
                         iters: int = 30) -> np.ndarray:
    """L1-normalized damped power iteration x ← (1−d)/V + d·(Aᵀx)/‖x‖₁."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    x = np.full(num_vertices, 1.0 / num_vertices)
    base = (1.0 - DAMPING) / num_vertices
    for _ in range(iters):
        s = np.zeros(num_vertices)
        np.add.at(s, dst, x[src])
        x = base + DAMPING * s / max(x.sum(), 1e-30)
    return x


def reference_ppr(src, dst, num_vertices, iters: int = 30,
                  num_seeds: int | None = None) -> np.ndarray:
    """Personalized pagerank with teleport + dangling mass on the seeds."""
    ns = default_num_seeds(num_vertices) if num_seeds is None else num_seeds
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    outdeg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(outdeg, src, 1)
    e = np.zeros(num_vertices)
    e[:ns] = 1.0 / ns
    rank = e.copy()
    for _ in range(iters):
        contrib = np.where(outdeg > 0, rank / np.maximum(outdeg, 1), 0.0)
        s = np.zeros(num_vertices)
        np.add.at(s, dst, contrib[src])
        dangle = rank[outdeg == 0].sum()
        rank = DAMPING * s + (1.0 - DAMPING) * e + DAMPING * dangle * e
    return rank
