"""RAW-COLLECTIVE: mesh collectives go through ``repro.dist``, not raw
``jax.lax``.

The dist layer owns the sharding rule tables, the halo-exchange wire
formats and the named-axis reduction helpers
(``repro.dist.collectives``); a raw ``lax.psum`` elsewhere bypasses the
comm-bytes accounting and the axis-name plumbing those layers maintain.
Flags attribute access ``lax.<collective>`` / ``jax.lax.<collective>``
and ``from jax.lax import <collective>`` anywhere under ``src/repro``
except the dist layer itself.
"""
from __future__ import annotations

import ast

from ..lint import Rule

COLLECTIVES = frozenset({
    "psum", "pmax", "pmin", "pmean",
    "all_gather", "all_to_all", "ppermute", "pshuffle", "psum_scatter",
})


def _is_lax(node: ast.expr) -> bool:
    # `lax.psum` or `jax.lax.psum`
    if isinstance(node, ast.Name):
        return node.id == "lax"
    if isinstance(node, ast.Attribute):
        return node.attr == "lax"
    return False


class RawCollective(Rule):
    id = "RAW-COLLECTIVE"
    description = ("no raw lax collectives outside repro/dist — use "
                   "repro.dist.collectives / the halo exchange registry")
    roots = ("src/repro",)
    excludes = ("src/repro/dist", "src/repro/analysis")

    def run(self, tree, relpath, text):
        out = []
        for node in ast.walk(tree):
            if (isinstance(node, ast.Attribute)
                    and node.attr in COLLECTIVES
                    and _is_lax(node.value)):
                out.append(self.finding(
                    relpath, node, node.attr,
                    f"raw lax.{node.attr} — route through "
                    f"repro.dist.collectives"))
            elif (isinstance(node, ast.ImportFrom)
                  and node.module == "jax.lax"):
                for alias in node.names:
                    if alias.name in COLLECTIVES:
                        out.append(self.finding(
                            relpath, node, alias.name,
                            f"imports {alias.name} from jax.lax — route "
                            f"through repro.dist.collectives"))
        return out
