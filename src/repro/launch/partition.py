"""Graph-partitioning launcher — the paper's own workload.

``python -m repro.launch.partition --scale 13 --k 16 --algo clugp-opt``
partitions a synthetic web crawl and reports RF / balance / runtime, then
(optionally) runs distributed PageRank on the result via the shard_map GAS
engine (--pagerank, needs a mesh with k devices or --simulate).

``--backend {np,jit,sharded}`` picks the partitioner implementation
(repro.core.partitioner): the host oracle, the single-device fused jit
pipeline, or the §III-C stream-sharded shard_map pipeline over ``--nodes``
devices.  ``--restream N`` adds N prioritized-restream passes.  jax must
see enough devices for the sharded backend, so the arg parse happens
BEFORE any jax import and sets XLA_FLAGS itself.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--algo", default="clugp-opt",
                    choices=["clugp", "clugp-opt", "clugp-parallel",
                             "hashing", "dbh", "greedy", "hdrf", "mint"])
    ap.add_argument("--backend", default="np",
                    choices=["np", "jit", "sharded"],
                    help="partitioner implementation for clugp algos")
    ap.add_argument("--nodes", type=int, default=4,
                    help="stream-split width: sharded mesh size / "
                         "clugp-parallel node count")
    ap.add_argument("--restream", type=int, default=0,
                    help="extra prioritized-restream passes")
    ap.add_argument("--graph", default="web", choices=["web", "social"])
    ap.add_argument("--pagerank", action="store_true")
    ap.add_argument("--exchange", default="halo",
                    choices=["dense", "halo", "quantized"],
                    help="mirror-sync wire format for --pagerank")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def partition_with(args, g):
    import numpy as np

    from repro.core import (CLUGPConfig, baselines, partition,
                            random_stream)

    algo, k, seed = args.algo, args.k, args.seed
    if algo.startswith("clugp"):
        cfg = (CLUGPConfig.optimized(k) if algo == "clugp-opt"
               else CLUGPConfig.paper(k))
        cfg = dataclasses.replace(cfg, restream=args.restream)
        # --nodes drives the stream split for the sharded backend and for
        # the legacy clugp-parallel alias (np multi-node combine)
        nodes = (1 if args.backend == "np" and algo != "clugp-parallel"
                 else args.nodes)
        res = partition(g.src, g.dst, g.num_vertices, cfg,
                        backend=args.backend, nodes=nodes)
        return res.assign
    gr = random_stream(g, seed=seed)
    a = baselines.ALL_BASELINES[algo](gr.src, gr.dst, g.num_vertices, k)
    # map back to the original stream order for downstream use
    out = np.zeros_like(a)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.num_edges)
    out[perm] = a
    return out


def main():
    args = build_parser().parse_args()
    if args.backend == "sharded":
        # must land before the first jax import — the device count locks
        # then.  An existing flag with a smaller count is raised to
        # --nodes (jax hasn't initialized yet, so overriding is safe).
        import re

        flags = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                      flags)
        if m is None or int(m.group(1)) < args.nodes:
            flags = re.sub(
                r"--xla_force_host_platform_device_count=\d+", "", flags)
            os.environ["XLA_FLAGS"] = (
                flags +
                f" --xla_force_host_platform_device_count={args.nodes}")

    import numpy as np

    from repro.core import metrics, web_graph
    from repro.core.graphgen import social_graph

    g = (web_graph(scale=args.scale, seed=args.seed) if args.graph == "web"
         else social_graph(n=1 << args.scale, seed=args.seed))
    print(f"graph: V={g.num_vertices} E={g.num_edges}")
    t0 = time.time()
    assign = partition_with(args, g)
    dt = time.time() - t0
    rf = metrics.replication_factor(g.src, g.dst, assign, g.num_vertices,
                                    args.k)
    bal = metrics.load_balance(assign, args.k)
    label = args.algo if not args.algo.startswith("clugp") \
        else f"{args.algo}[{args.backend}, restream={args.restream}]"
    print(f"{label}: rf={rf:.3f} balance={bal:.3f} "
          f"time={dt:.2f}s ({1e6*dt/g.num_edges:.2f} µs/edge)")

    if args.pagerank:
        from repro.graph import (build_layout, reference_pagerank,
                                 simulate_pagerank)
        lay = build_layout(g.src, g.dst, assign, g.num_vertices, args.k)
        t0 = time.time()
        pr = simulate_pagerank(lay, iters=30, exchange=args.exchange)
        dt = time.time() - t0
        ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
        print(f"pagerank[{args.exchange}]: {dt:.2f}s  "
              f"max|err|={np.abs(pr-ref).max():.2e}  "
              f"comm/iter: ideal={lay.comm_bytes_ideal()/1e6:.2f}MB "
              f"quantized={lay.comm_bytes_halo_quantized()/1e6:.2f}MB "
              f"halo={lay.comm_bytes_halo()/1e6:.2f}MB "
              f"dense-gather={lay.comm_bytes_mirror_sync()/1e6:.2f}MB "
              f"allreduce={lay.comm_bytes_dense()/1e6:.2f}MB")


if __name__ == "__main__":
    main()
