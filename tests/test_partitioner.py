"""Cross-backend partitioner equivalence (repro.core.partitioner).

The "np" backend is the oracle; "jit" must match it bit-for-bit wherever
both sides are deterministic (clustering labels, greedy game, transform,
restream priors) and within tolerance where the game RNG differs;
"sharded" is exercised in a multi-device subprocess and judged against
the same-split-width np combine.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (CLUGPConfig, partition,
                        partition_sweep, sweep_trace_count, web_graph)


@pytest.fixture(scope="module")
def graph10():
    return web_graph(scale=10, edge_factor=6, seed=3)


# ------------------------------------------------------------- api basics

def test_unknown_backend_raises(graph10):
    g = graph10
    with pytest.raises(ValueError, match="unknown backend"):
        partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=4),
                  backend="cuda")


def test_unknown_kernel_raises(graph10):
    g = graph10
    with pytest.raises(ValueError, match="unknown game kernel"):
        partition(g.src, g.dst, g.num_vertices,
            CLUGPConfig(k=4, kernel="mxu"), backend="jit")


def test_empty_stream_raises_every_backend():
    empty = np.zeros(0, dtype=np.int64)
    for backend in ("np", "jit", "sharded"):
        with pytest.raises(ValueError, match="empty"):
            partition(empty, empty, 10, CLUGPConfig(k=4), backend=backend)


# ------------------------------------------------- np ↔ jit bit equivalence

def test_jit_clustering_labels_bit_identical(graph10):
    """Pass 1 parity: the fused jit pipeline's compacted labels equal the
    host oracle's exactly (same raw-id creation order, same compaction)."""
    g = graph10
    cfg = CLUGPConfig(k=8)
    r_np = partition(g.src, g.dst, g.num_vertices, cfg, backend="np")
    r_jit = partition(g.src, g.dst, g.num_vertices, cfg, backend="jit")
    np.testing.assert_array_equal(r_np.clustering.clu, r_jit.clustering.clu)
    np.testing.assert_array_equal(r_np.clustering.deg, r_jit.clustering.deg)
    np.testing.assert_array_equal(r_np.clustering.divided,
                                  r_jit.clustering.divided)
    assert r_np.clustering.num_clusters == r_jit.clustering.num_clusters


def test_jit_nogame_pipeline_bit_identical(graph10):
    """With the deterministic greedy game the WHOLE pipeline (clustering →
    greedy → transform → restream) is bit-identical np ↔ jit."""
    g = graph10
    cfg = CLUGPConfig(k=8, game=False, restream=1)
    a_np = partition(g.src, g.dst, g.num_vertices, cfg, backend="np").assign
    a_jit = partition(g.src, g.dst, g.num_vertices, cfg,
                      backend="jit").assign
    np.testing.assert_array_equal(a_np, a_jit)


def test_jit_game_rf_close_to_np(graph10):
    """Game RNG/sweep schedules differ, so quality (not bits) must match:
    RF within 10% of the host oracle."""
    g = graph10
    cfg = CLUGPConfig(k=8)
    rf_np = partition(g.src, g.dst, g.num_vertices, cfg,
                      backend="np").stats["rf"]
    rf_jit = partition(g.src, g.dst, g.num_vertices, cfg,
                       backend="jit").stats["rf"]
    assert rf_jit <= rf_np * 1.10


def test_jit_pallas_kernel_path(graph10):
    """The Pallas batched-Jacobi game (interpret mode on CPU) produces a
    valid partition of comparable quality."""
    g = graph10
    cfg = CLUGPConfig(k=8, kernel="pallas")
    res = partition(g.src, g.dst, g.num_vertices, cfg, backend="jit")
    assert res.assign.shape == (g.num_edges,)
    assert res.assign.min() >= 0 and res.assign.max() < 8
    rf_np = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=8),
                      backend="np").stats["rf"]
    assert res.stats["rf"] <= rf_np * 1.25


def test_jit_balance_cap_respected(graph10):
    g = graph10
    for tau in (1.0, 1.5):
        res = partition(g.src, g.dst, g.num_vertices,
                  CLUGPConfig(k=8, tau=tau), backend="jit")
        sizes = np.bincount(res.assign, minlength=8)
        assert sizes.max() <= int(np.ceil(tau * g.num_edges / 8)) + 1


def test_cluster_csr_rejects_int32_overflow():
    """Backstop for the GS game's int32 pair-key space: above ~46k
    clusters the builder must refuse (the partitioner backends fall back
    to the Jacobi game before ever calling it)."""
    import jax.numpy as jnp

    from repro.core.game import jax_cluster_csr

    xs = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError, match="overflows the int32"):
        jax_cluster_csr(xs, xs, 65536, 64)


def test_jit_tiny_stream_with_self_loops_bit_identical():
    """Regression: self-loop edges of clustered vertices count toward
    their cluster's intra size in ``contract`` — the in-graph contraction
    must match (it once dropped them and diverged on greedy ties)."""
    src = np.array([0, 1, 2, 2, 3], dtype=np.int64)
    dst = np.array([1, 2, 2, 3, 0], dtype=np.int64)
    cfg = CLUGPConfig(k=2, game=False, restream=1)
    a_np = partition(src, dst, 5, cfg, backend="np").assign
    a_jit = partition(src, dst, 5, cfg, backend="jit").assign
    np.testing.assert_array_equal(a_np, a_jit)


# --------------------------------------------------------------- restream

def test_restream_strictly_improves_rf(graph10):
    """Regression for the PR's restreaming claim: one prioritized
    restream pass strictly cuts RF on the scale-10 web graph."""
    g = graph10
    base = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=8),
                     backend="np")
    once = partition(g.src, g.dst, g.num_vertices,
               CLUGPConfig(k=8, restream=1), backend="np")
    assert once.stats["rf"] < base.stats["rf"]
    trace = once.stats["restream_rf_trace"]
    assert len(trace) == 2 and trace[1] < trace[0]


def test_restream_improves_jit_too(graph10):
    g = graph10
    base = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=8),
                     backend="jit")
    once = partition(g.src, g.dst, g.num_vertices,
               CLUGPConfig(k=8, restream=1), backend="jit")
    assert once.stats["rf"] < base.stats["rf"]


# --------------------------------------------------- compile-once k-sweep

def test_sweep_matches_per_k_jit_bitwise(graph10):
    """The stacked k-sweep (every k under ONE ``lax.scan`` body, lanes
    padded to k_max with a traced live count) must reproduce the per-k
    jit backend BIT-FOR-BIT at every k — dead-lane masking may never
    leak into a live partition's argmin, λ, or balance cap."""
    g = graph10
    ks = (4, 8)
    cfg = CLUGPConfig(k=ks[-1])
    results = partition_sweep(g.src, g.dst, g.num_vertices, cfg, ks)
    for k, res in zip(ks, results):
        ref = partition(g.src, g.dst, g.num_vertices,
                        dataclasses.replace(cfg, k=k), backend="jit")
        np.testing.assert_array_equal(res.assign, ref.assign,
                                      err_msg=f"k={k}")
        assert res.assign.min() >= 0 and res.assign.max() < k
        assert res.stats["rf"] == ref.stats["rf"]
        assert res.stats["sweep"] and res.stats["k_max"] == ks[-1]


def test_sweep_repeat_adds_zero_compiles(graph10):
    """Compile-once contract: a warm repeat of the sweep (same stream
    shape, same ks) reuses the cached executable — the traced k_real /
    vmax inputs keep per-k variation out of the jit cache key."""
    g = graph10
    cfg = CLUGPConfig(k=8)
    partition_sweep(g.src, g.dst, g.num_vertices, cfg, (4, 8))
    before = sweep_trace_count()
    again = partition_sweep(g.src, g.dst, g.num_vertices, cfg, (4, 8))
    assert sweep_trace_count() == before
    assert len(again) == 2


def test_sweep_validates_ks(graph10):
    g = graph10
    for bad in ((), (0, 4), (-1,)):
        with pytest.raises(ValueError, match="at least one k"):
            partition_sweep(g.src, g.dst, g.num_vertices,
                            CLUGPConfig(k=4), bad)


# ------------------------------------------------------- np nodes combine

def test_np_nodes_combine_honest_stats(graph10):
    """Satellite regression: the merged result no longer masquerades the
    last node's clustering as global state — per-node summaries are
    explicit and the cluster count sums private id spaces."""
    g = graph10
    res = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=8),
                    backend="np", nodes=3)
    assert res.clustering is None and res.cluster_graph is None
    per_node = res.stats["per_node"]
    assert len(per_node) == 3
    assert res.stats["num_clusters"] == sum(n["clusters"] for n in per_node)
    assert res.stats["nodes"] == 3
    assert sum(n["edges"] for n in per_node) == g.num_edges


def test_np_nodes_kwarg_combines(graph10):
    g = graph10
    res = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=8),
                    nodes=4)
    assert res.assign.shape == (g.num_edges,)
    assert res.stats["nodes"] == 4


def test_np_nodes_restream_improves(graph10):
    g = graph10
    base = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=8),
                     backend="np", nodes=4)
    once = partition(g.src, g.dst, g.num_vertices,
               CLUGPConfig(k=8, restream=1), backend="np", nodes=4)
    assert once.stats["rf"] < base.stats["rf"]


# ------------------------------------------------------- device residency

def test_build_layout_accepts_device_resident_assignment(graph10):
    """partition → build_layout without a host round-trip: jax arrays go
    straight in and every table matches the np-input build."""
    import jax.numpy as jnp

    from repro.graph import build_layout

    g = graph10
    res = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=4),
                    backend="jit")
    lay_np = build_layout(g.src, g.dst, res.assign, g.num_vertices, 4)
    lay_dev = build_layout(jnp.asarray(g.src), jnp.asarray(g.dst),
                           jnp.asarray(res.assign), g.num_vertices, 4)
    for f in ("edge_src", "edge_dst", "vert_gid", "is_master", "owner",
              "own_slot", "halo_send", "halo_recv"):
        np.testing.assert_array_equal(getattr(lay_np, f),
                                      getattr(lay_dev, f))


# ------------------------------------------------------- sharded (8 dev)

SHARDED_CODE = """
import numpy as np
from repro.core import CLUGPConfig, partition, web_graph

g = web_graph(scale=10, edge_factor=6, seed=3)
k, nodes = 8, 4
cfg = CLUGPConfig(k=k, restream=1)
r_np = partition(g.src, g.dst, g.num_vertices, cfg, backend="np",
           nodes=nodes)
r_sh = partition(g.src, g.dst, g.num_vertices, cfg, backend="sharded",
           nodes=nodes)
assert r_sh.assign.shape == (g.num_edges,)
assert r_sh.assign.min() >= 0 and r_sh.assign.max() < k
# balance: every device respects its slice cap, so the global cap holds
assert r_sh.stats["balance"] <= cfg.tau + 0.05, r_sh.stats["balance"]
# quality within 10% of the same-split-width host combine
assert r_sh.stats["rf"] <= r_np.stats["rf"] * 1.10, (
    r_sh.stats["rf"], r_np.stats["rf"])
# honest merged stats: private-id-space cluster counts per node
assert len(r_sh.stats["per_node"]) == nodes
assert r_sh.stats["num_clusters"] == sum(
    n["clusters"] for n in r_sh.stats["per_node"])
# greedy path is bit-identical to the host combine on every device
cfg_g = CLUGPConfig(k=k, game=False)
a_np = partition(g.src, g.dst, g.num_vertices, cfg_g, backend="np",
           nodes=nodes).assign
a_sh = partition(g.src, g.dst, g.num_vertices, cfg_g, backend="sharded",
           nodes=nodes).assign
np.testing.assert_array_equal(a_np, a_sh)
print("SHARDED_OK", r_sh.stats["rf"])
"""


@pytest.mark.multidevice
def test_sharded_backend_multidevice(multidevice):
    out = multidevice(SHARDED_CODE, n_devices=8)
    assert "SHARDED_OK" in out


def test_sharded_raises_without_devices(graph10):
    g = graph10
    with pytest.raises(RuntimeError, match="devices"):
        partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=4),
                  backend="sharded", nodes=64)
