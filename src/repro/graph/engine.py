"""Distributed vertex-cut GAS engine (PowerGraph semantics) on shard_map.

Per iteration (paper §II-B): local scatter/gather over the partition's edges
(segment_sum — the ``csr_spmv`` Pallas kernel's op), mirror partials reduced
to masters, masters apply, new values broadcast back to mirrors.  The two
mirror-sync phases go through the pluggable exchange layer
(``repro.dist.halo``):

- ``exchange="dense"``: two all_gathers of (k, L_max) values — simple, but
  bytes scale with k²·L_max regardless of partition quality (the seed wire
  format).
- ``exchange="halo"``: two all_to_alls over the layout's static mirror
  routing tables — bytes scale with the mirror count (RF−1)·|V|, the
  quantity the partitioner optimizes, so Fig. 8's mechanism shows up on
  the wire.
- ``exchange="quantized"``: halo routing with int8 delta-coded lanes +
  per-lane-group scales and an error-feedback residual threaded through
  the iteration carry — ~4× fewer payload bytes for fp32 programs, exact
  int32 passthrough for ``combine="min"`` programs (CC labels).

The engine is **program-parametric**: a ``GASProgram`` bundles the four
per-device callables (init / local gather-scatter / apply / optional
global aux) plus the combine op and wire dtype, and one pair of drivers
runs any program:

- ``simulate_gas(program, …)``   : stacked (k, …) arrays on one device —
                                   tests and host-side benchmarks.
- ``shard_map_gas(program, …)``  : one partition per mesh device over axis
                                   ``parts`` — the production path.

``simulate_pagerank`` / ``shard_map_pagerank`` / ``simulate_cc`` /
``shard_map_cc`` are thin instantiations of ``pagerank_program()`` /
``CC_PROGRAM`` over those two drivers, so the simulated and shard_map
paths run the same per-device math by construction and can't drift.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .partition import PartitionLayout
from ..dist._compat import shard_map
from ..dist.halo import get_exchange

DAMPING = 0.85
# CC labels are int32 vertex ids; the min-identity sentinel marks padded /
# non-master slots and can never win a minimum against a real id
CC_SENTINEL = int(np.iinfo(np.int32).max)


# ----------------------------------------------------------- program spec

@dataclass(frozen=True)
class GASProgram:
    """One GAS computation as per-device callables over the layout's
    ``device_arrays()`` pytree (all (L_max,)-shaped per device):

      init(dev)               -> initial per-slot values
      local(value, dev)       -> gather/scatter partials over local edges
      apply(total, aux, dev)  -> new master-slot values (others get the
                                 combine identity / sentinel)
      aux(value, dev)         -> optional per-device scalar, reduced
                                 globally (psum / stacked sum) before
                                 ``apply`` — pagerank's dangling mass

    ``combine`` ("sum" | "min") and ``dtype`` fix the mirror-sync wire
    semantics; the quantized exchange uses them to decide whether the
    payload may be lossily delta-coded (fp32 sum) or must ship exact
    (int32 min)."""
    name: str
    combine: str
    dtype: Any
    init: Callable
    local: Callable
    apply: Callable
    aux: Callable | None = None


# ----------------------------------------------------------- per-device math

def _local_rank_partial(rank, dev):
    """Scatter phase: Σ_{(u,w)∈E_p, w=v} rank[u]/outdeg[u] per local slot."""
    l_max = dev["vert_gid"].shape[0]
    safe_deg = jnp.maximum(dev["out_deg"], 1)
    contrib = jnp.where(dev["vert_mask"] & (dev["out_deg"] > 0),
                        rank / safe_deg, 0.0)
    contrib = jnp.concatenate([contrib, jnp.zeros((1,), contrib.dtype)])
    per_edge = jnp.where(dev["edge_mask"], contrib[dev["edge_src"]], 0.0)
    return jax.ops.segment_sum(per_edge, dev["edge_dst"],
                               num_segments=l_max + 1)[:l_max]


def _local_dangle(rank, dev):
    """Rank mass sitting on dangling masters (out_deg == 0)."""
    m = dev["vert_mask"] & dev["is_master"] & (dev["out_deg"] == 0)
    return jnp.sum(jnp.where(m, rank, 0.0))


def _pagerank_apply(total_in, dangle, dev, num_vertices):
    base = (1.0 - DAMPING) / num_vertices
    new = base + DAMPING * (total_in + dangle / num_vertices)
    return jnp.where(dev["vert_mask"] & dev["is_master"], new, 0.0)


@lru_cache(maxsize=None)
def pagerank_program(num_vertices: int) -> GASProgram:
    """Damped pagerank with dangling-mass redistribution (fp32, sum
    combine — the quantized exchange may delta-code its mirror lanes).
    Cached per vertex count so repeated layouts hit the same jit cache."""
    def init(dev):
        return jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)

    def apply(total, dangle, dev):
        return _pagerank_apply(total, dangle, dev, num_vertices)

    return GASProgram(name="pagerank", combine="sum", dtype=jnp.float32,
                      init=init, local=_local_rank_partial, apply=apply,
                      aux=_local_dangle)


def _cc_init(dev):
    return jnp.where(dev["vert_mask"], dev["vert_gid"].astype(jnp.int32),
                     CC_SENTINEL)


def _cc_local_min(label, dev):
    """Edge-wise min exchange in both directions (undirected semantics)."""
    l_max = dev["vert_gid"].shape[0]
    lab = jnp.concatenate([jnp.where(dev["vert_mask"], label, CC_SENTINEL),
                           jnp.full((1,), CC_SENTINEL, label.dtype)])
    s, d, m = dev["edge_src"], dev["edge_dst"], dev["edge_mask"]
    vs = jnp.where(m, lab[s], CC_SENTINEL)
    vd = jnp.where(m, lab[d], CC_SENTINEL)
    out = jax.ops.segment_min(vs, d, num_segments=l_max + 1)[:l_max]
    out2 = jax.ops.segment_min(vd, s, num_segments=l_max + 1)[:l_max]
    cur = jnp.where(dev["vert_mask"], label, CC_SENTINEL)
    return jnp.minimum(cur, jnp.minimum(out, out2))


def _cc_apply(total, aux, dev):
    return jnp.where(dev["vert_mask"] & dev["is_master"], total,
                     CC_SENTINEL)


# label propagation / connected components: int32 labels are exact on the
# wire, so every exchange (incl. "quantized") ships them unquantized
CC_PROGRAM = GASProgram(name="cc", combine="min", dtype=jnp.int32,
                        init=_cc_init, local=_cc_local_min, apply=_cc_apply)


# ----------------------------------------------------------- shared body

def _gas_body(program: GASProgram, ex, dev, axis: str | None = None):
    """One GAS iteration as a ``fori_loop`` body over (value, state).

    ``axis=None`` is the stacked form: ``dev`` holds full (k, …) stacks,
    per-device callables vmap over the leading axis, and the exchange's
    ``*_stacked`` halves model the collectives.  With a mesh axis it is
    the per-device form run inside shard_map.  Both forms call the same
    ``program`` callables, so the simulated and production paths cannot
    drift."""
    stacked = axis is None

    def body(_, carry):
        value, state = carry
        if program.aux is not None:
            aux = (jnp.sum(jax.vmap(program.aux)(value, dev)) if stacked
                   else jax.lax.psum(program.aux(value, dev), axis))
        else:
            aux = None
        if stacked:
            partial_ = jax.vmap(program.local)(value, dev)
            total, state = ex.reduce_stacked(partial_, dev,
                                             program.combine, state)
            new_master = jax.vmap(
                lambda t, d: program.apply(t, aux, d))(total, dev)
            value, state = ex.broadcast_stacked(new_master, dev,
                                                program.combine, state)
        else:
            partial_ = program.local(value, dev)
            total, state = ex.reduce_to_masters(partial_, dev,
                                                program.combine, state)
            new_master = program.apply(total, aux, dev)
            value, state = ex.broadcast_from_masters(new_master, dev,
                                                     program.combine, state)
        return value, state

    return body


# ----------------------------------------------------------- simulated driver

def _stack_dev(layout: PartitionLayout, exchange: str | None = None):
    return jax.tree_util.tree_map(jnp.asarray,
                                  layout.device_arrays(exchange))


@partial(jax.jit, static_argnames=("program", "iters", "exchange"))
def _sim_gas(program: GASProgram, dev, iters: int, exchange: str):
    ex = get_exchange(exchange)
    value = jax.vmap(program.init)(dev)
    state = ex.init_state(dev, program.dtype, program.combine)
    body = _gas_body(program, ex, dev)
    value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
    return value


def _collect_master_values(layout: PartitionLayout, stacked) -> np.ndarray:
    """(k, L_max) per-device values → dense (V,) using master slots."""
    vals = np.asarray(stacked)
    out = np.zeros(layout.num_vertices, dtype=vals.dtype)
    gid = layout.vert_gid
    sel = layout.is_master & layout.vert_mask
    out[gid[sel]] = vals[sel]
    return out


def simulate_gas(program: GASProgram, layout: PartitionLayout,
                 iters: int = 30, exchange: str = "dense") -> np.ndarray:
    """Stacked one-device driver for any GAS program (bit-identical math
    to ``shard_map_gas`` — the collectives become transposes/gathers)."""
    dev = _stack_dev(layout, exchange)
    values = _sim_gas(program, dev, iters, exchange)
    return _collect_master_values(layout, values)


def simulate_pagerank(layout: PartitionLayout, iters: int = 30,
                      exchange: str = "dense") -> np.ndarray:
    return simulate_gas(pagerank_program(layout.num_vertices), layout,
                        iters, exchange)


def simulate_cc(layout: PartitionLayout, iters: int = 30,
                exchange: str = "dense") -> np.ndarray:
    return simulate_gas(CC_PROGRAM, layout, iters,
                        exchange).astype(np.int64)


# ----------------------------------------------------------- shard_map driver

def shard_map_gas(program: GASProgram, layout: PartitionLayout, mesh: Mesh,
                  iters: int = 30, axis: str = "parts",
                  exchange: str = "dense") -> np.ndarray:
    """Production path: one partition per device along ``axis``.
    Requires mesh axis size == layout.k.  ``exchange`` picks the mirror
    wire format (see module docstring).  Returns (V,) master values."""
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, axis)
    spec = P(axis)

    @partial(shard_map, mesh=mesh,
             in_specs=(jax.tree_util.tree_map(lambda _: spec, dev),),
             out_specs=spec)
    def run(dev):
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        value = program.init(dev)
        state = ex.init_state(dev, program.dtype, program.combine)
        body = _gas_body(program, ex, dev, axis)
        value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
        return value[None]

    with mesh:
        values = run(dev)
    return _collect_master_values(layout, values)


def shard_map_pagerank(layout: PartitionLayout, mesh: Mesh,
                       iters: int = 30, axis: str = "parts",
                       exchange: str = "dense") -> np.ndarray:
    return shard_map_gas(pagerank_program(layout.num_vertices), layout,
                         mesh, iters=iters, axis=axis, exchange=exchange)


def shard_map_cc(layout: PartitionLayout, mesh: Mesh, iters: int = 30,
                 axis: str = "parts", exchange: str = "dense") -> np.ndarray:
    return shard_map_gas(CC_PROGRAM, layout, mesh, iters=iters, axis=axis,
                         exchange=exchange).astype(np.int64)


def gas_step_for_dryrun(program: GASProgram, layout: PartitionLayout,
                        mesh: Mesh, axis: str = "parts", iters: int = 1,
                        exchange: str = "dense"):
    """Returns (jitted_fn, example_args) whose .lower() the dry-run compiles
    — the graph dry-run parses each backend's collective bytes out of the
    post-SPMD HLO (``launch/dryrun.py --graph``)."""
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, axis)
    spec = P(axis)

    @partial(shard_map, mesh=mesh,
             in_specs=(jax.tree_util.tree_map(lambda _: spec, dev),),
             out_specs=spec)
    def step(dev):
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        value = program.init(dev)
        state = ex.init_state(dev, program.dtype, program.combine)
        body = _gas_body(program, ex, dev, axis)
        value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
        return value[None]

    return jax.jit(step), (dev,)


def pagerank_step_for_dryrun(layout: PartitionLayout, mesh: Mesh,
                             axis: str = "parts", iters: int = 1,
                             exchange: str = "dense"):
    return gas_step_for_dryrun(pagerank_program(layout.num_vertices),
                               layout, mesh, axis=axis, iters=iters,
                               exchange=exchange)


# ----------------------------------------------------------- oracles

def reference_pagerank(src, dst, num_vertices, iters: int = 30) -> np.ndarray:
    """Dense single-machine oracle with identical dangling handling."""
    outdeg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(outdeg, src, 1)
    rank = np.full(num_vertices, 1.0 / num_vertices)
    base = (1.0 - DAMPING) / num_vertices
    for _ in range(iters):
        contrib = np.where(outdeg > 0, rank / np.maximum(outdeg, 1), 0.0)
        s = np.zeros(num_vertices)
        np.add.at(s, dst, contrib[src])
        dangle = rank[outdeg == 0].sum()
        rank = base + DAMPING * (s + dangle / num_vertices)
    return rank


def reference_cc(src, dst, num_vertices) -> np.ndarray:
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components
    A = sp.coo_matrix((np.ones(len(src)), (src, dst)),
                      shape=(num_vertices, num_vertices))
    _, comp = connected_components(A, directed=False)
    # canonical label: min vertex id of the component (what min-label finds)
    mins = np.full(comp.max() + 1, num_vertices, dtype=np.int64)
    np.minimum.at(mins, comp, np.arange(num_vertices))
    return mins[comp]
