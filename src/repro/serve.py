"""Partitioning-as-a-service: a long-lived graph server over GraphSession.

The batch workflow partitions a stream once, runs its analytics, and
exits.  ``GraphServer`` keeps the partitioned graph and its vertex-cut
``PartitionLayout`` *resident* and answers queries against them forever:

- **Queries** (``submit``/``step``/``result``): vertex scores for any
  registry GAS program, component/propagation labels, 1-hop
  neighborhoods, and "which partition owns v".  Requests land on an
  in-process queue; ``step`` drains one microbatch, groups the score
  queries that share a (combine, dtype) wire cell, executes each group
  as ONE fused ``run_many`` step (single mirror-sync collective per
  phase), then scatters replies — continuous batching, graph-style.
  Computed (V,) value vectors are cached per (program, exchange) until
  the graph changes, so repeat queries are O(1) lookups.
- **Live ingestion** (``ingest``): edge arrivals buffer into a window;
  a full window is assigned *incrementally* against the resident
  partition (``core.stages.incremental_assign`` — one greedy Alg. 1
  pass over the window, seeded with the current per-partition loads)
  and the layout is rebuilt and swapped atomically between
  microbatches.  When replication drifts past ``rf_watermark`` ×
  the baseline, a prioritized restream seeded by the current
  assignment (``core.stages.restream_assign``) repairs it and resets
  the baseline.
- **Preemption survival** (``checkpoint``/``resume``): the session's
  ``snapshot()`` tree + config blob ride ``dist.ft.ServiceFT``'s atomic
  shape-blind checkpoints; a SIGKILL'd server restarted from the same
  directory resumes with the identical partition (same ``to_json``,
  same assignment — tested).  Microbatch times feed the same
  ``StragglerWatch`` the trainer uses.

Single-process by design: the request queue is in-proc and the driver
(``repro.launch.serve_graph``) calls ``step`` in a loop — no sockets, so
the whole service is testable under pytest and CI.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any

import numpy as np

from .core import metrics
from .core.stages import incremental_assign, restream_assign
from .session import GraphSession, resolve_program

QUERY_KINDS = ("score", "label", "neighbors", "owner")
# per-kind default program: "label" queries read the min-combine label
# programs (cc components by default), "score" the float rank programs
DEFAULT_PROGRAM = {"score": "pagerank", "label": "cc"}


@dataclasses.dataclass
class Reply:
    ticket: int
    kind: str
    value: Any = None
    error: str | None = None


class GraphServer:
    """A resident ``GraphSession`` behind a microbatched request queue.

    ``session`` must already hold a partition (``partition(...)`` or
    ``with_partition(...)``).  ``mesh`` (axis size == k) makes every
    fused query step shard_map one partition per device; ``mesh=None``
    simulates on one device — bit-identical by construction, so replies
    match ``session.run_many`` either way.  ``ft`` (a
    ``dist.ft.ServiceFT``) enables ``checkpoint``/``resume`` and the
    microbatch straggler watch.
    """

    def __init__(self, session: GraphSession, *, max_batch: int = 64,
                 window: int = 4096, rf_watermark: float = 1.05,
                 restream_passes: int = 2, iters: int | None = None,
                 tol: float | None = None, mesh=None, ft=None):
        session._require_partition()
        self.sess = session
        self.max_batch = int(max_batch)
        self.window = int(window)
        self.rf_watermark = float(rf_watermark)
        self.restream_passes = int(restream_passes)
        self.iters = iters
        # tol switches query compute to the convergence early-exit loop
        # (iters becomes a cap) AND turns the value caches into
        # warm-start state: after an ingest/restream swap the previous
        # fixed point seeds the rerun, so post-swap queries pay a
        # handful of repair iterations instead of a full cold run
        self.tol = tol
        self.mesh = mesh
        self.ft = ft
        self._queue: queue.Queue = queue.Queue()
        self._replies: dict[int, Reply] = {}
        self._next_ticket = 0
        self._ckpt_step = -1
        self._values: dict = {}     # (program, exchange) -> dense (V,)
        self._warm: dict = {}       # pre-swap fixed points (same keys)
        self.last_iters_run: dict = {}   # wire cell -> executed iters
        self._csr = None            # (indptr, neighbors) over BOTH dirs
        self._owner_of = None       # (V,) master partition per vertex
        self._buf_src: list = []
        self._buf_dst: list = []
        self._buffered = 0
        self.rf_base = self._rf_now()
        self.rf_trace: list = [("start", self.rf_base)]
        self.stats = {"queries": 0, "microbatches": 0, "ingested_edges": 0,
                      "windows": 0, "restreams": 0, "stragglers": 0}

    # ---------------------------------------------------------- queries

    def submit(self, kind: str, *, program=None, vertices=None,
               exchange: str | None = None) -> int:
        """Enqueue a request; returns a ticket for ``result``.

        ``score``/``label`` take a program (name or GASProgram) and
        optional vertex ids (None = the full dense vector);
        ``neighbors``/``owner`` require vertex ids."""
        if kind not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; expected one "
                             f"of {QUERY_KINDS}")
        if kind in ("neighbors", "owner") and vertices is None:
            raise ValueError(f"{kind!r} queries need vertices=")
        if program is None:
            program = DEFAULT_PROGRAM.get(kind)
        ticket = self._next_ticket
        self._next_ticket += 1
        verts = None if vertices is None else np.atleast_1d(
            np.asarray(vertices))
        self._queue.put((ticket, kind, program, verts, exchange))
        return ticket

    def result(self, ticket: int) -> Reply | None:
        """Pop the reply for ``ticket`` (None while still queued)."""
        return self._replies.pop(ticket, None)

    def pending(self) -> int:
        return self._queue.qsize()

    def step(self) -> int:
        """Serve ONE microbatch: drain up to ``max_batch`` requests,
        compute every missing score vector — one fused ``run_many`` per
        (combine, dtype, exchange) group — and scatter replies.  Returns
        the number of requests served (0 = queue empty)."""
        batch = []
        while len(batch) < self.max_batch:
            try:
                batch.append(self._queue.get_nowait())
            except queue.Empty:
                break
        if not batch:
            return 0
        t0 = time.perf_counter()
        self._ensure_host_tables()
        needed: dict = {}
        resolved = []
        for ticket, kind, program, verts, exchange in batch:
            key = None
            if kind in ("score", "label"):
                try:
                    prog = resolve_program(program, self.sess.num_vertices)
                except ValueError as e:
                    self._replies[ticket] = Reply(ticket, kind,
                                                  error=str(e))
                    continue
                ex = exchange or self.sess.cfg.exchange
                key = (prog.name, ex)
                if key not in self._values:
                    needed[key] = (prog, ex)
            resolved.append((ticket, kind, key, verts))
        if needed:
            cells: dict = {}
            for key, (prog, ex) in needed.items():
                cell = (prog.combine, np.dtype(prog.dtype).name, ex)
                cells.setdefault(cell, []).append(prog)
            for cell, progs in cells.items():
                ex = cell[2]
                if self.tol is None:
                    outs = self.sess.run_many(progs, iters=self.iters,
                                              exchange=ex, mesh=self.mesh)
                else:
                    # ALWAYS pass explicit init_values — a cold program
                    # (no cached fixed point) ships an empty vector,
                    # which the engine maps to its init, so warm and
                    # cold rounds share ONE compiled while_loop and
                    # query_ms compares fairly
                    seeds = [self._warm.get((p.name, ex),
                                            np.zeros(0)) for p in progs]
                    outs, iters_run = self.sess.run_many(
                        progs, iters=self.iters, exchange=ex,
                        mesh=self.mesh, tol=self.tol, init_values=seeds,
                        return_iters=True)
                    self.last_iters_run[cell] = int(iters_run)
                for prog, out in zip(progs, outs):
                    self._values[(prog.name, ex)] = out
        for ticket, kind, key, verts in resolved:
            try:
                self._replies[ticket] = Reply(
                    ticket, kind, value=self._answer(kind, key, verts))
            except Exception as e:  # noqa: BLE001 — per-request errors
                self._replies[ticket] = Reply(ticket, kind, error=str(e))
        dt = time.perf_counter() - t0
        if self.ft is not None and self.ft.watch.observe(dt):
            self.stats["stragglers"] += 1
        self.stats["microbatches"] += 1
        self.stats["queries"] += len(batch)
        return len(batch)

    def serve_pending(self) -> int:
        """Drain the whole queue (microbatch by microbatch)."""
        total = 0
        while True:
            n = self.step()
            if n == 0:
                return total
            total += n

    def _answer(self, kind: str, key, verts):
        if kind in ("score", "label"):
            vals = self._values[key]
            return vals.copy() if verts is None else vals[verts]
        if kind == "owner":
            return self._owner_of[verts]
        indptr, nbrs = self._csr                    # neighbors
        return [np.unique(nbrs[indptr[int(v)]:indptr[int(v) + 1]])
                for v in verts]

    def _ensure_host_tables(self):
        if self._csr is None:
            src, dst = self.sess.edges
            n = self.sess.num_vertices
            ends = np.concatenate([src, dst]).astype(np.int64)
            nbrs = np.concatenate([dst, src]).astype(np.int64)
            order = np.argsort(ends, kind="stable")
            indptr = np.zeros(n + 1, np.int64)
            indptr[1:] = np.bincount(ends, minlength=n).cumsum()
            self._csr = (indptr, nbrs[order])
        if self._owner_of is None:
            lay = self.sess.partition_layout
            own = np.zeros(self.sess.num_vertices, np.int32)
            for p in range(lay.k):
                own[lay.vert_gid[p][lay.is_master[p]]] = p
            self._owner_of = own

    # ---------------------------------------------------------- ingest

    def ingest(self, src, dst) -> bool:
        """Buffer live edge arrivals; when a full ``window`` has
        accumulated, flush it (incremental assign + layout swap + drift
        check).  Returns True when a flush happened."""
        src = np.atleast_1d(np.asarray(src))
        dst = np.atleast_1d(np.asarray(dst))
        if src.shape != dst.shape:
            raise ValueError("ingest: src/dst length mismatch")
        self._buf_src.append(src)
        self._buf_dst.append(dst)
        self._buffered += src.shape[0]
        self.stats["ingested_edges"] += src.shape[0]
        if self._buffered >= self.window:
            self.flush_window()
            return True
        return False

    def flush_window(self) -> bool:
        """Assign the buffered window against the resident partition and
        swap the grown graph in.  One greedy pass over the window only —
        the resident assignment is untouched; the balance cap covers the
        grown stream.  Past the RF watermark this triggers a restream."""
        if self._buffered == 0:
            return False
        ws = np.concatenate(self._buf_src)
        wd = np.concatenate(self._buf_dst)
        self._buf_src, self._buf_dst, self._buffered = [], [], 0
        src, dst = self.sess.edges
        assign = self.sess.assign
        nv = int(max(self.sess.num_vertices,
                     ws.max(initial=-1) + 1, wd.max(initial=-1) + 1))
        wa = incremental_assign(src, dst, ws, wd, assign, nv,
                                self.sess.cfg.clugp)
        self._swap(np.concatenate([src, ws]), np.concatenate([dst, wd]),
                   np.concatenate([assign, wa]), nv)
        self.stats["windows"] += 1
        rf_now = self._rf_now()
        self.rf_trace.append(("window", rf_now))
        if rf_now > self.rf_watermark * self.rf_base:
            self.restream()
        return True

    def restream(self, passes: int | None = None) -> tuple:
        """Repair drift: prioritized restream of the WHOLE resident
        stream seeded by the current assignment, then swap and reset the
        RF baseline.  Returns the pre-pass RF trace."""
        src, dst = self.sess.edges
        new_assign, trace = restream_assign(
            src, dst, self.sess.assign, self.sess.num_vertices,
            self.sess.cfg.clugp,
            passes=self.restream_passes if passes is None else passes)
        self._swap(src, dst, new_assign, self.sess.num_vertices)
        self.stats["restreams"] += 1
        self.rf_base = self._rf_now()
        self.rf_trace.append(("restream", self.rf_base))
        return trace

    def _swap(self, src, dst, assign, num_vertices: int):
        # the swap is atomic from the query path's view: the driver is
        # single-threaded, so a microbatch only ever sees the layout
        # fully rebuilt (layout() raises before a half-built state could
        # be cached) and freshly invalidated value/host tables
        self.sess.with_partition(src, dst, num_vertices, assign).layout()
        # the outgoing fixed points become warm-start seeds for the
        # grown graph (values are dense (V,) keyed by gid, so they
        # survive the remap; new vertices fall back to program init)
        self._warm.update(self._values)
        self._values.clear()
        self._csr = None
        self._owner_of = None

    def _rf_now(self) -> float:
        src, dst = self.sess.edges
        return metrics.replication_factor(src, dst, self.sess.assign,
                                          self.sess.num_vertices,
                                          self.sess.k)

    # ------------------------------------------------------ preemption

    def checkpoint(self, step: int | None = None) -> int:
        """Snapshot graph + partition + config through ``ServiceFT``
        (atomic write; async if the ft was built that way)."""
        if self.ft is None:
            raise RuntimeError("GraphServer: no ServiceFT attached — "
                               "pass ft= to enable checkpointing")
        if step is None:
            step = self._ckpt_step + 1
        self._ckpt_step = step
        extra = {"config": self.sess.to_json(),
                 "num_vertices": self.sess.num_vertices,
                 "rf_base": self.rf_base}
        self.ft.snapshot(step, self.sess.snapshot(), extra=extra)
        return step

    @classmethod
    def resume(cls, ft, **kw) -> "GraphServer":
        """Rebuild a server from the newest intact ``ServiceFT``
        snapshot: identical config blob, identical edges and
        edge→partition assignment (no re-partitioning)."""
        flat, extra, step = ft.restore_latest()
        if flat is None:
            raise FileNotFoundError(
                f"no snapshot under {ft.ckpt_dir!r} to resume from")
        sess = GraphSession.from_snapshot(extra["config"], flat,
                                          int(extra["num_vertices"]))
        srv = cls(sess, ft=ft, **kw)
        srv.rf_base = float(extra.get("rf_base", srv.rf_base))
        srv._ckpt_step = step
        return srv
