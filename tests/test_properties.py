"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional dev dep)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (CLUGPConfig, partition, contract,
                        best_response_rounds, default_vmax, global_cost,
                        lambda_max, metrics, potential,
                        streaming_clustering_np, transform_np)
from repro.core.graphgen import _compact


@st.composite
def small_graphs(draw):
    n = draw(st.integers(8, 60))
    e = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    # preferential-ish attachment for power-law-ish degrees
    src = rng.integers(0, n, e)
    dst = (rng.zipf(1.8, e) - 1) % n
    keep = src != dst
    if keep.sum() < 2:
        src, dst = np.array([0, 1]), np.array([1, 2])
    else:
        src, dst = src[keep], dst[keep]
    return _compact(src.astype(np.int64), dst.astype(np.int64))


@given(small_graphs(), st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_partition_is_total_and_balanced(g, k):
    res = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=k))
    assert res.assign.shape[0] == g.num_edges
    assert 0 <= res.assign.min() and res.assign.max() < k
    sizes = np.bincount(res.assign, minlength=k)
    assert sizes.max() <= int(np.ceil(g.num_edges / k)) + 1   # τ=1 cap
    rf = metrics.replication_factor(g.src, g.dst, res.assign,
                                    g.num_vertices, k)
    assert 1.0 <= rf <= k


@given(small_graphs(), st.integers(2, 6))
@settings(max_examples=25, deadline=None)
def test_clustering_state_invariants(g, k):
    vmax = default_vmax(g.num_edges, k)
    res = streaming_clustering_np(g.src, g.dst, g.num_vertices, vmax)
    streamed = np.zeros(g.num_vertices, bool)
    streamed[g.src] = streamed[g.dst] = True
    streamed &= (g.src != g.dst)[0] or streamed   # keep mask as-is
    # every streamed vertex has a cluster and correct degree
    deg = np.zeros(g.num_vertices, np.int64)
    sl = g.src != g.dst
    np.add.at(deg, g.src[sl], 1)
    np.add.at(deg, g.dst[sl], 1)
    assert (res.clu[deg > 0] >= 0).all()
    np.testing.assert_array_equal(res.deg, deg)
    # cluster ids compact
    used = np.unique(res.clu[res.clu >= 0])
    assert used.shape[0] == res.num_clusters
    np.testing.assert_array_equal(used, np.arange(res.num_clusters))
    # replicas only on divided vertices
    assert (res.replicas[~res.divided] == 0).all()


@given(small_graphs(), st.integers(2, 6), st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_game_monotone_potential_and_cost_sandwich(g, k, seed):
    clus = streaming_clustering_np(g.src, g.dst, g.num_vertices,
                                   default_vmax(g.num_edges, k))
    cg = contract(g.src, g.dst, clus.clu)
    if cg.m == 0:
        return
    lam = lambda_max(cg, k)
    res = best_response_rounds(cg, k, lam=lam, batch_size=None,
                               track_potential=True, seed=seed)
    tr = res.potential_trace
    assert all(b <= a + 1e-6 for a, b in zip(tr, tr[1:]))
    phi = potential(cg, res.assign, k, lam)
    cost = global_cost(cg, res.assign, k, lam)
    assert phi - 1e-9 <= cost <= 2 * phi + 1e-9        # Thm 8 lemma


@given(small_graphs(), st.integers(2, 6),
       st.floats(1.0, 2.0))
@settings(max_examples=20, deadline=None)
def test_transform_respects_tau(g, k, tau):
    clus = streaming_clustering_np(g.src, g.dst, g.num_vertices,
                                   default_vmax(g.num_edges, k))
    cg = contract(g.src, g.dst, clus.clu)
    res = best_response_rounds(cg, k, batch_size=None)
    vp = res.assign[np.maximum(clus.clu, 0)].astype(np.int32)
    assign = transform_np(g.src, g.dst, vp, clus.deg, clus.divided, k, tau)
    sizes = np.bincount(assign, minlength=k)
    lmax = tau * g.num_edges / k
    assert sizes.max() <= int(np.ceil(lmax)) + 1
