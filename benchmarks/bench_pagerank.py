"""Fig. 8: performance on the real distributed system (PowerGraph →
shard_map GAS engine).  Reports per-iteration communication volume
(mirror-sync bytes — proportional to RF, the paper's mechanism) and local
compute cost per partitioner, plus wall time of the simulated engine."""
from __future__ import annotations

import time

import numpy as np

from repro.core import web_graph
from repro.graph import build_layout, reference_pagerank, simulate_pagerank
from .common import run_partitioner


def fig8_pagerank(scale=11, k=8, iters=20, seed=0):
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for algo in ("clugp-opt", "clugp", "hdrf", "hashing", "dbh"):
        out = run_partitioner(algo, g, k, seed)
        assign = out[0]
        if algo.startswith("clugp"):
            src, dst = g.src, g.dst
        else:
            src, dst = out[2]
        lay = build_layout(src, dst, assign, g.num_vertices, k)
        t0 = time.time()
        pr = simulate_pagerank(lay, iters=iters)
        dt = time.time() - t0
        ref = reference_pagerank(src, dst, g.num_vertices, iters=iters)
        err = float(np.abs(pr - ref).max())
        rows.append({
            "bench": "fig8_pagerank", "algo": algo, "k": k,
            "comm_mb_per_iter": round(lay.comm_bytes_ideal() / 1e6, 4),
            "comm_dense_mb": round(lay.comm_bytes_dense() / 1e6, 4),
            "local_edges_max": int(lay.e_max),
            "mirrors": int(lay.mirrors_total),
            "engine_seconds": round(dt, 3),
            "max_err": err,
        })
        assert err < 1e-5, (algo, err)
    return rows
