"""Mixture-of-Experts with sort-based capacity dispatch (static shapes).

Routing variants cover the assigned archs:
- llama4-scout    : 16 experts, top-1 + shared expert
- deepseek-v3     : 256 routed top-8 (softmax-after-topk, aux-loss-free
                    bias), 1 shared expert, first-k dense layers
- jamba-1.5       : 16 experts, top-2 softmax

Expert parallelism: experts live on the ``model`` ("expert") mesh axis; the
dispatch gather/scatter lowers to all-to-all / collective-permute under
GSPMD via sharding constraints (verified in the dry-run HLO).  The CLUGP
bridge (repro.core.expert_placement) permutes the expert→shard map to
co-locate co-activated experts — the paper's game applied to the
expert-affinity graph (beyond-paper, DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Params, ffn, ffn_init, linear, linear_init
from ..dist.sharding import shard


def moe_init(key, d_model: int, d_expert: int, n_experts: int,
             n_shared: int = 0, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)

    def bank(key, n):
        kk = jax.random.split(key, 3)
        s = 1.0 / math.sqrt(d_model)
        return {
            "gate": jax.random.normal(kk[0], (n, d_model, d_expert), dtype) * s,
            "up": jax.random.normal(kk[1], (n, d_model, d_expert), dtype) * s,
            "down": jax.random.normal(kk[2], (n, d_expert, d_model), dtype)
                    / math.sqrt(d_expert),
        }

    p = {"router": linear_init(ks[0], d_model, n_experts, dtype=dtype),
         "experts": bank(ks[1], n_experts)}
    if n_shared:
        p["shared"] = ffn_init(ks[2], d_model, n_shared * d_expert,
                               gated=True, dtype=dtype)
    return p


def moe_apply(p: Params, x: jnp.ndarray, *, n_experts: int,
              top_k: int, capacity_factor: float = 1.25,
              router_softmax_after_topk: bool = False,
              router_bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """x: (B, S, D) → (B, S, D).  GShard-style *grouped* sort-dispatch:
    each batch row is a dispatch group with its own capacity, so expert
    batches are (G, E, C, D) sharded (data, experts, ·, ·) — both mesh axes
    divide the compute.  (Hillclimb #1, EXPERIMENTS.md §Perf: a global
    dispatch left (E, C, D) replicated across the 16 data shards — 16×
    redundant expert FLOPs.)  Tokens over capacity are dropped (GShard
    semantics); the shared expert (if any) is always-on."""
    B, S, D = x.shape
    T = S                                # tokens per group
    capacity = max(1, int(capacity_factor * T * top_k / n_experts))

    logits = linear(p["router"], x).astype(jnp.float32)     # (B, S, E)
    sel = logits if router_bias is None else logits + router_bias
    _, top_idx = jax.lax.top_k(sel, top_k)                  # (B, S, K)
    if router_softmax_after_topk:
        gates = jax.nn.softmax(
            jnp.take_along_axis(logits, top_idx, axis=2), -1)
    else:
        gates = jnp.take_along_axis(jax.nn.softmax(logits, -1), top_idx, 2)

    def dispatch_tables(top_g, gate_g):
        """Per group: (S, K) → token/gate tables of shape (E·C,)."""
        flat_e = top_g.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
        flat_g = gate_g.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        e_s, t_s, g_s = flat_e[order], flat_t[order], flat_g[order]
        pos = jnp.arange(T * top_k) - jnp.searchsorted(e_s, e_s)
        keep = pos < capacity
        slot = jnp.where(keep, e_s * capacity + pos, n_experts * capacity)
        tok = jnp.full((n_experts * capacity + 1,), T, jnp.int32)
        tok = tok.at[slot].set(t_s, mode="drop")[:-1]
        gat = jnp.zeros((n_experts * capacity + 1,), jnp.float32)
        gat = gat.at[slot].set(jnp.where(keep, g_s, 0.0), mode="drop")[:-1]
        return tok, gat

    tok_table, gate_table = jax.vmap(dispatch_tables)(top_idx, gates)
    # dispatch gather: (B, S+1, D)[g, tok] → (G, E, C, D); under GSPMD the
    # (data → experts) resharding is the all-to-all.
    xg = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], 1)
    ex_in = jnp.take_along_axis(
        xg, tok_table[..., None].astype(jnp.int32), axis=1
    ).reshape(B, n_experts, capacity, D)
    ex_in = shard(ex_in, "batch", "experts", None, None)

    w = p["experts"]
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ex_in,
                               w["gate"].astype(ex_in.dtype))) \
        * jnp.einsum("gecd,edf->gecf", ex_in, w["up"].astype(ex_in.dtype))
    h = shard(h, "batch", "experts", None, None)
    ex_out = jnp.einsum("gecf,efd->gecd", h, w["down"].astype(h.dtype))
    ex_out = shard(ex_out, "batch", "experts", None, None)

    # combine: weighted scatter-add back to each group's tokens
    flat_out = ex_out.reshape(B, n_experts * capacity, D) \
        .astype(jnp.float32)
    weighted = flat_out * gate_table[..., None]

    def combine(tok, wo):
        y = jnp.zeros((T + 1, D), jnp.float32)
        return y.at[tok].add(wo)[:T]

    out = jax.vmap(combine)(tok_table, weighted).astype(x.dtype)
    out = shard(out, "batch", None, None)
    if "shared" in p:
        out = out + ffn(p["shared"], x)
    return out


def moe_reference(p: Params, x: jnp.ndarray, *, n_experts: int, top_k: int,
                  router_softmax_after_topk: bool = False,
                  router_bias: jnp.ndarray | None = None) -> jnp.ndarray:
    """No-capacity oracle: every token visits its top-k experts densely
    (tiny shapes only — the kernel/test reference)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = linear(p["router"], xt).astype(jnp.float32)
    sel = logits if router_bias is None else logits + router_bias
    _, top_idx = jax.lax.top_k(sel, top_k)
    if router_softmax_after_topk:
        gates = jax.nn.softmax(
            jnp.take_along_axis(logits, top_idx, axis=1), -1)
    else:
        gates = jnp.take_along_axis(jax.nn.softmax(logits, -1), top_idx, 1)
    w = p["experts"]
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, w["gate"].astype(xt.dtype))) \
        * jnp.einsum("td,edf->tef", xt, w["up"].astype(xt.dtype))
    all_out = jnp.einsum("tef,efd->ted", h, w["down"].astype(h.dtype))
    onehot = jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32)  # T,K,E
    comb = jnp.einsum("tke,tk->te", onehot, gates)
    out = jnp.einsum("ted,te->td", all_out.astype(jnp.float32), comb)
    y = out.astype(x.dtype)
    if "shared" in p:
        y = y + ffn(p["shared"], xt)
    return y.reshape(B, S, D)
