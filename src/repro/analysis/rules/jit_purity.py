"""JIT-PURITY: no host clocks or host RNG inside traced code paths.

A ``time.time()`` / ``random.random()`` / ``np.random.*`` call inside a
jitted function executes ONCE at trace time and bakes a constant into
the compiled program — the classic "my timestamp never changes" /
"my noise is identical every step" bug.  Static host math (plain
``np.*`` shape arithmetic) is fine; it's the *stateful* host calls that
are wrong under trace.

Traced contexts are found structurally, without importing the module:

- functions decorated with ``jit`` / ``jax.jit`` / ``partial(jax.jit,
  ...)`` or wrapped by ``shard_map``;
- functions passed to tracing higher-order entry points at the
  positions JAX traces them: ``jit``/``shard_map``/``vmap``/``grad``
  arg 0, ``lax.scan`` arg 0, ``lax.fori_loop`` arg 2,
  ``lax.while_loop`` args 0-1, ``lax.cond`` args 1-2, ``lax.switch``
  args 1+;
- known always-traced bodies by name (``run_clugp_body``,
  ``_gas_body``, ``_gas_body_multi``);
- transitively: any module-local function *called from* a traced
  function (fixpoint over same-file call edges).
"""
from __future__ import annotations

import ast

from ..lint import Rule

# module path prefixes whose calls are impure under trace
IMPURE_MODULES = ("time", "random", "numpy.random")

TRACING_DECORATORS = frozenset({"jit", "shard_map", "pmap", "checkpoint"})
SEED_NAMES = frozenset({"run_clugp_body", "_gas_body", "_gas_body_multi"})
# callable-name -> argument positions that get traced
HOF_TRACED_ARGS = {
    "jit": (0,), "shard_map": (0,), "vmap": (0,), "pmap": (0,),
    "grad": (0,), "value_and_grad": (0,), "checkpoint": (0,),
    "scan": (0,), "fori_loop": (2,), "while_loop": (0, 1),
    "cond": (1, 2), "switch": None,  # None → every arg from 1 on
}


def _callable_name(fn: ast.expr) -> str | None:
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _dotted(node: ast.expr) -> str | None:
    """`np.random.rand` → "np.random.rand"; None if not a pure chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _ModuleIndex:
    """Per-file symbol tables: import aliases, function defs, call edges."""

    def __init__(self, tree: ast.Module):
        self.alias_to_module: dict[str, str] = {}   # np -> numpy
        self.name_to_module: dict[str, str] = {}    # time -> time.time
        self.defs: dict[str, list[ast.AST]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:            # import numpy.random as nr
                        self.alias_to_module[a.asname] = a.name
                    else:                   # import numpy[.random] binds
                        head = a.name.split(".")[0]     # the head name
                        self.alias_to_module[head] = head
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.name_to_module[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(node.name, []).append(node)

    def resolve(self, call: ast.Call) -> str | None:
        """Fully-qualified dotted path of the call target, through import
        aliases — `np.random.rand()` → "numpy.random.rand"."""
        dotted = _dotted(call.func)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        if head in self.alias_to_module:
            base = self.alias_to_module[head]
            return f"{base}.{rest}" if rest else base
        if head in self.name_to_module:
            base = self.name_to_module[head]
            return f"{base}.{rest}" if rest else base
        return dotted

    def impure(self, call: ast.Call) -> str | None:
        path = self.resolve(call)
        if path is None:
            return None
        for mod in IMPURE_MODULES:
            if path == mod or path.startswith(mod + "."):
                return path
        return None


def _decorated_traced(fn) -> bool:
    for dec in fn.decorator_list:
        name = _callable_name(dec if not isinstance(dec, ast.Call)
                              else dec.func)
        if name in TRACING_DECORATORS:
            return True
        if isinstance(dec, ast.Call) and _callable_name(dec.func) == \
                "partial" and dec.args:
            inner = _callable_name(dec.args[0])
            if inner in TRACING_DECORATORS:
                return True
    return False


def _traced_arg_exprs(tree: ast.Module):
    """Expressions handed to tracing HOFs at their traced positions."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _callable_name(node.func)
        if name not in HOF_TRACED_ARGS:
            continue
        positions = HOF_TRACED_ARGS[name]
        if positions is None:  # switch: every branch callable
            yield from node.args[1:]
        else:
            for i in positions:
                if i < len(node.args):
                    yield node.args[i]
        for kw in node.keywords:
            if kw.arg in ("f", "fun", "body_fun", "cond_fun", "body"):
                yield kw.value


class JitPurity(Rule):
    id = "JIT-PURITY"
    description = ("no host clocks / host RNG (time.*, random.*, "
                   "np.random.*) inside traced code paths")
    roots = ("src/repro",)
    excludes = ("src/repro/analysis",)

    def run(self, tree, relpath, text):
        index = _ModuleIndex(tree)
        traced: set[int] = set()          # id() of traced def nodes
        worklist: list[ast.AST] = []

        def mark(fn):
            if id(fn) not in traced:
                traced.add(id(fn))
                worklist.append(fn)

        for defs in index.defs.values():
            for fn in defs:
                if _decorated_traced(fn) or fn.name in SEED_NAMES:
                    mark(fn)
        lambda_bodies: list[ast.Lambda] = []
        for expr in _traced_arg_exprs(tree):
            if isinstance(expr, ast.Lambda):
                lambda_bodies.append(expr)
            else:
                name = _callable_name(expr)
                for fn in index.defs.get(name or "", []):
                    mark(fn)

        # fixpoint: functions called from traced bodies are traced too
        # (lambda args to HOFs also pull in the local functions they call)
        def local_callees(node):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    name = _callable_name(sub.func)
                    yield from index.defs.get(name or "", [])

        for lam in lambda_bodies:
            for fn in local_callees(lam):
                mark(fn)
        while worklist:
            fn = worklist.pop()
            for callee in local_callees(fn):
                mark(callee)

        out = []
        seen_calls: set[int] = set()
        bodies = [fn for defs in index.defs.values() for fn in defs
                  if id(fn) in traced] + lambda_bodies
        for body in bodies:
            for sub in ast.walk(body):
                if (isinstance(sub, ast.Call) and id(sub) not in seen_calls):
                    path = index.impure(sub)
                    if path:
                        seen_calls.add(id(sub))
                        ctx = getattr(body, "name", "<lambda>")
                        out.append(self.finding(
                            relpath, sub, path,
                            f"host-impure call {path}() inside traced "
                            f"context {ctx!r} — value is baked in at "
                            f"trace time"))
        return out
