"""Core CLUGP tests: three-pass pipeline, theory invariants, parity."""
import numpy as np
import pytest

from repro.core import (CLUGPConfig, best_response_rounds,
                        contract, partition,
                        default_vmax, global_cost, lambda_max, metrics,
                        potential, streaming_clustering_jax,
                        streaming_clustering_np, theory, transform_jax,
                        transform_np, web_graph)
from repro.core.clustering import clustering_result_from_jax
from repro.core.graphgen import random_stream
from repro.core import baselines


@pytest.fixture(scope="module")
def small_graph():
    return web_graph(scale=10, edge_factor=6, seed=3)


@pytest.fixture(scope="module")
def clugp_result(small_graph):
    g = small_graph
    return partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=8))


# ---------------------------------------------------------------- pipeline

def test_every_edge_assigned_exactly_once(small_graph, clugp_result):
    g = small_graph
    assert clugp_result.assign.shape == (g.num_edges,)
    assert clugp_result.assign.min() >= 0
    assert clugp_result.assign.max() < 8


def test_balance_cap_respected(small_graph):
    g = small_graph
    for tau in (1.0, 1.2, 2.0):
        res = partition(g.src, g.dst, g.num_vertices,
                        CLUGPConfig(k=8, tau=tau))
        sizes = np.bincount(res.assign, minlength=8)
        lmax = tau * g.num_edges / 8
        assert sizes.max() <= int(np.ceil(lmax)) + 1


def test_rf_beats_hashing(small_graph, clugp_result):
    """Fig. 3's headline at test scale: CLUGP ≪ random hashing."""
    g = small_graph
    h = baselines.hashing(g.src, g.dst, g.num_vertices, 8)
    rf_h = metrics.replication_factor(g.src, g.dst, h, g.num_vertices, 8)
    assert clugp_result.stats["rf"] < rf_h * 0.75


def test_optimized_profile_at_least_as_good(small_graph):
    g = small_graph
    paper = partition(g.src, g.dst, g.num_vertices,
                      CLUGPConfig.paper(8))
    opt = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(8))
    assert opt.stats["rf"] <= paper.stats["rf"] * 1.05


def test_parallel_pipeline_matches_quality(small_graph):
    g = small_graph
    res = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=8),
                    nodes=4)
    h = baselines.hashing(g.src, g.dst, g.num_vertices, 8)
    rf_h = metrics.replication_factor(g.src, g.dst, h, g.num_vertices, 8)
    assert res.stats["rf"] < rf_h


# ---------------------------------------------------------------- clustering

def test_clustering_covers_all_streamed_vertices(small_graph):
    g = small_graph
    clus = streaming_clustering_np(g.src, g.dst, g.num_vertices,
                                   default_vmax(g.num_edges, 8))
    streamed = np.zeros(g.num_vertices, bool)
    streamed[g.src] = True
    streamed[g.dst] = True
    assert (clus.clu[streamed] >= 0).all()
    assert (clus.deg[streamed] > 0).all()


def test_clustering_jax_matches_np(small_graph):
    g = small_graph
    n = 2000  # scan is O(E) python-free but slow to trace on huge inputs
    src, dst = g.src[:n], g.dst[:n]
    vmax = default_vmax(n, 8)
    ref = streaming_clustering_np(src, dst, g.num_vertices, vmax)
    out = streaming_clustering_jax(src, dst, g.num_vertices, vmax)
    got = clustering_result_from_jax(*out[:4])
    np.testing.assert_array_equal(got.clu, ref.clu)
    np.testing.assert_array_equal(got.deg, ref.deg)
    np.testing.assert_array_equal(got.divided, ref.divided)
    assert got.num_clusters == ref.num_clusters


def test_split_reduces_cluster_rf_vs_holl(small_graph):
    """Thm 1 direction at cluster granularity: CLUGP's split bookkeeping
    never does worse than Holl **in cluster-level replicas** when the
    degree damping is active (the paper's intended regime)."""
    g = small_graph
    vmax = default_vmax(g.num_edges, 64)
    clugp = streaming_clustering_np(g.src, g.dst, g.num_vertices, vmax,
                                    split_degree_factor=4.0)
    holl = streaming_clustering_np(g.src, g.dst, g.num_vertices, vmax,
                                   allow_split=False)
    # Holl has zero cluster-level replicas by construction; the comparison
    # that matters is end-to-end RF at large k (Fig. 9) — checked in
    # benchmarks; here we check split bookkeeping consistency instead.
    assert clugp.replicas.sum() >= 0
    assert (clugp.replicas[~clugp.divided] == 0).all()
    assert (clugp.replicas[clugp.divided] >= 1).all()
    assert holl.replicas.sum() == 0


def test_dmin_theory_monotonicity():
    """Thm 2: d_min^clugp(r) ≥ d_min^holl(r) for r ≥ 2."""
    rs = np.arange(2, 64)
    d_c = theory.d_min_clugp(rs, vmax=10_000, dmax=500)
    d_h = theory.d_min_holl(rs)
    assert (d_c >= d_h).all()
    assert (np.diff(d_c) >= 0).all()


def test_rf_upper_bound_ordering():
    """Thm 1: the Eq. 4 bound for CLUGP ≤ the Eq. 5 bound for Holl."""
    bound_c = theory.rf_upper_bound(m=256, gamma=1.0, alpha=2.2,
                                    d_min_fn=theory.d_min_clugp,
                                    vmax=10_000, dmax=500)
    bound_h = theory.rf_upper_bound(m=256, gamma=1.0, alpha=2.2,
                                    d_min_fn=theory.d_min_holl)
    assert bound_c <= bound_h


# ---------------------------------------------------------------- game

@pytest.fixture(scope="module")
def cluster_graph(small_graph):
    g = small_graph
    clus = streaming_clustering_np(g.src, g.dst, g.num_vertices,
                                   default_vmax(g.num_edges, 8))
    return contract(g.src, g.dst, clus.clu)


def test_game_converges_and_potential_monotone(cluster_graph):
    """Thm 4: exact potential game ⇒ sequential best response monotonically
    decreases Φ and terminates."""
    res = best_response_rounds(cluster_graph, 8, batch_size=None,
                               track_potential=True, max_rounds=64)
    assert res.rounds < 64
    tr = res.potential_trace
    assert all(b <= a + 1e-6 for a, b in zip(tr, tr[1:]))


def test_nash_no_improving_move(cluster_graph):
    """At the fixed point no cluster can unilaterally improve (Def. 3)."""
    k = 8
    cg = cluster_graph
    lam = lambda_max(cg, k)
    res = best_response_rounds(cg, k, lam=lam, batch_size=None)
    assign = res.assign.astype(np.int64)
    sizes = cg.sizes.astype(np.float64)
    loads = np.bincount(assign, weights=sizes, minlength=k)
    S = cg.adj
    row_tot = np.asarray(S.sum(axis=1)).ravel()
    ar = np.arange(k)
    rng = np.random.default_rng(0)
    for i in rng.choice(cg.m, size=min(cg.m, 64), replace=False):
        nbrs = S.indices[S.indptr[i]:S.indptr[i + 1]]
        w = S.data[S.indptr[i]:S.indptr[i + 1]]
        aff = np.bincount(assign[nbrs], weights=w, minlength=k)
        loads_ex = loads - sizes[i] * (ar == assign[i])
        cost = (lam / k) * sizes[i] * (loads_ex + sizes[i]) \
            + 0.5 * (row_tot[i] - aff)
        assert cost[assign[i]] <= cost.min() + 1e-6


def test_round_bound(cluster_graph):
    """Thm 6: #rounds ≤ Σ|e(c_i, V\\c_i)|."""
    res = best_response_rounds(cluster_graph, 8, batch_size=None)
    assert res.rounds <= max(1.0, theory.game_round_bound(cluster_graph))


def test_potential_vs_cost_sandwich(cluster_graph):
    """Thm 8's key lemma: Φ(Λ) ≤ φ(Λ) ≤ 2Φ(Λ)."""
    rng = np.random.default_rng(1)
    for _ in range(5):
        assign = rng.integers(0, 8, cluster_graph.m)
        lam = lambda_max(cluster_graph, 8)
        phi = potential(cluster_graph, assign, 8, lam)
        cost = global_cost(cluster_graph, assign, 8, lam)
        assert phi - 1e-9 <= cost <= 2 * phi + 1e-9


def test_pos_bound_small_instance():
    """Thm 8: equilibrium cost ≤ 2× brute-force optimum on tiny instances."""
    rng = np.random.default_rng(0)
    src = rng.integers(0, 12, 60).astype(np.int32)
    dst = rng.integers(0, 12, 60).astype(np.int32)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    clu = np.arange(12) // 2            # 6 clusters of 2 vertices
    cg = contract(src, dst, clu.astype(np.int32))
    k, lam = 2, 1.0
    opt = theory.brute_force_optimum(cg, k, lam)
    res = best_response_rounds(cg, k, lam=lam, batch_size=None, seed=3)
    eq_cost = global_cost(cg, res.assign, k, lam)
    assert eq_cost <= theory.pos_bound() * opt + 1e-6
    assert eq_cost <= theory.poa_bound(k) * opt + 1e-6   # Thm 7 (weaker)


def test_batched_game_close_to_sequential(cluster_graph):
    """§V-D: batched (parallel) game quality ≈ sequential quality."""
    k = 8
    lam = lambda_max(cluster_graph, k)
    seq = best_response_rounds(cluster_graph, k, lam=lam, batch_size=None)
    bat = best_response_rounds(cluster_graph, k, lam=lam, batch_size=64)
    c_seq = global_cost(cluster_graph, seq.assign, k, lam)
    c_bat = global_cost(cluster_graph, bat.assign, k, lam)
    assert c_bat <= c_seq * 1.10


# ---------------------------------------------------------------- transform

def test_transform_jax_matches_np(small_graph):
    g = small_graph
    k = 8
    clus = streaming_clustering_np(g.src, g.dst, g.num_vertices,
                                   default_vmax(g.num_edges, k))
    cg = contract(g.src, g.dst, clus.clu)
    res = best_response_rounds(cg, k)
    vp = res.assign[np.maximum(clus.clu, 0)].astype(np.int32)
    ref = transform_np(g.src, g.dst, vp, clus.deg, clus.divided, k, 1.0)
    got = np.asarray(transform_jax(g.src, g.dst, vp, clus.deg,
                                   clus.divided, k, 1.0))
    np.testing.assert_array_equal(ref, got)


# ---------------------------------------------------------------- baselines

@pytest.mark.parametrize("name", sorted(baselines.ALL_BASELINES))
def test_baseline_valid_assignment(small_graph, name):
    g = random_stream(small_graph, seed=5)
    a = baselines.ALL_BASELINES[name](g.src, g.dst, g.num_vertices, 8)
    assert a.shape == (g.num_edges,)
    assert a.min() >= 0 and a.max() < 8
    rf = metrics.replication_factor(g.src, g.dst, a, g.num_vertices, 8)
    assert 1.0 <= rf <= 8.0


def test_quality_ordering_on_web_graph():
    """Table I at test scale: heuristic ≻ hashing on web graphs."""
    g = web_graph(scale=11, edge_factor=8, seed=1)
    gr = random_stream(g, seed=2)
    k = 16
    rf = {}
    for name in ("hashing", "hdrf"):
        a = baselines.ALL_BASELINES[name](gr.src, gr.dst, g.num_vertices, k)
        rf[name] = metrics.replication_factor(gr.src, gr.dst, a,
                                              g.num_vertices, k)
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig.optimized(k))
    assert rf["hdrf"] < rf["hashing"]
    assert res.stats["rf"] < rf["hashing"]
