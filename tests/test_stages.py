"""The stage protocol (repro.core.stages) and the deprecation shims.

The refactor's contract: `run_clugp_body` is the ONLY place the cluster →
contract → game → transform sequence exists, the old entry points are
warning shims over it with bit-identical results, and the `cfg.unroll`
knob is a pure lowering choice.
"""
import numpy as np
import pytest

from repro.core import (CLUGPConfig, clugp_partition,
                        clugp_partition_parallel, partition, web_graph)


@pytest.fixture(scope="module")
def graph10():
    return web_graph(scale=10, edge_factor=6, seed=3)


# -------------------------------------------------------- deprecation shims

def test_clugp_partition_shim_identical_to_new_api(graph10):
    """The old host entry point warns and returns the same CLUGPResult as
    the stage-body np strategy — assignment, stats, and per-pass state."""
    g = graph10
    cfg = CLUGPConfig(k=8, restream=1)
    with pytest.warns(DeprecationWarning, match="clugp_partition is "
                                                "deprecated"):
        old = clugp_partition(g.src, g.dst, g.num_vertices, cfg)
    new = partition(g.src, g.dst, g.num_vertices, cfg, backend="np")
    np.testing.assert_array_equal(old.assign, new.assign)
    np.testing.assert_array_equal(old.clustering.clu, new.clustering.clu)
    np.testing.assert_array_equal(old.cluster_assign, new.cluster_assign)
    assert old.game_rounds == new.game_rounds
    assert old.stats == new.stats
    assert "restream_rf_trace" in new.stats


def test_clugp_partition_parallel_shim_identical(graph10):
    g = graph10
    cfg = CLUGPConfig(k=8, restream=1)
    with pytest.warns(DeprecationWarning, match="clugp_partition_parallel"):
        old = clugp_partition_parallel(g.src, g.dst, g.num_vertices, cfg,
                                       n_nodes=3)
    new = partition(g.src, g.dst, g.num_vertices, cfg, backend="np",
                    nodes=3)
    np.testing.assert_array_equal(old.assign, new.assign)
    assert old.stats == new.stats
    assert old.stats["per_node"] == new.stats["per_node"]


def test_new_api_does_not_warn(graph10):
    import warnings

    g = graph10
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=4),
                  backend="np")


# ------------------------------------------------------------- one body

def test_single_pipeline_body_shared_by_strategies():
    """Structural guard for the refactor's headline: the cluster →
    contract → game → transform sequence exists exactly once
    (stages.run_clugp_body), and every strategy routes through it."""
    import inspect

    from repro.core import partitioner, stages

    src = inspect.getsource(partitioner)
    # strategies may not call stage internals directly — only the body
    for fn in ("streaming_clustering", "jax_game_rounds", "transform_np",
               "transform_jax", "best_response_rounds",
               "majority_vertex_map"):
        assert fn not in src, f"partitioner re-plumbs stage {fn!r}"
    assert src.count("run_clugp_body") >= 3   # np, np-nodes, jit, sharded
    body = inspect.getsource(stages.run_clugp_body)
    for stage in ("stages.cluster", "stages.contract", "stages.game",
                  "stages.transform"):
        assert stage in body


def test_np_nodes_restream_trace_recorded(graph10):
    """The shared restream loop now records the RF trace for the host
    combine too (monotone like the single-stream trace)."""
    g = graph10
    res = partition(g.src, g.dst, g.num_vertices,
                    CLUGPConfig(k=8, restream=1), backend="np", nodes=3)
    trace = res.stats["restream_rf_trace"]
    assert len(trace) == 2 and trace[1] < trace[0]


# ------------------------------------------------------------- unroll knob

def test_unroll_is_bit_identical_on_jit(graph10):
    """cfg.unroll only changes the clustering scan's lowering — the whole
    deterministic pipeline (greedy game + restream) is bit-identical."""
    g = graph10
    base = partition(g.src, g.dst, g.num_vertices,
                     CLUGPConfig(k=8, game=False, restream=1),
                     backend="jit")
    unrolled = partition(g.src, g.dst, g.num_vertices,
                         CLUGPConfig(k=8, game=False, restream=1, unroll=2),
                         backend="jit")
    np.testing.assert_array_equal(base.assign, unrolled.assign)
    np.testing.assert_array_equal(base.clustering.clu,
                                  unrolled.clustering.clu)


def test_unroll_ignored_by_host_oracle(graph10):
    g = graph10
    a = partition(g.src, g.dst, g.num_vertices,
                  CLUGPConfig(k=4, game=False), backend="np").assign
    b = partition(g.src, g.dst, g.num_vertices,
                  CLUGPConfig(k=4, game=False, unroll=2),
                  backend="np").assign
    np.testing.assert_array_equal(a, b)
