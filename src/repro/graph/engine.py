"""Distributed vertex-cut GAS engine (PowerGraph semantics) on shard_map.

Per iteration (paper §II-B): local scatter/gather over the partition's edges
(segment_sum — the ``csr_spmv`` Pallas kernel's op), mirror partials reduced
to masters, masters apply, new values broadcast back to mirrors.  The two
mirror-sync phases go through the pluggable exchange layer
(``repro.dist.halo``):

- ``exchange="dense"``: two all_gathers of (k, L_max) values — simple, but
  bytes scale with k²·L_max regardless of partition quality (the seed wire
  format).
- ``exchange="halo"``: two all_to_alls over the layout's static mirror
  routing tables — bytes scale with the mirror count (RF−1)·|V|, the
  quantity the partitioner optimizes, so Fig. 8's mechanism shows up on
  the wire.
- ``exchange="quantized"``: halo routing with int8 delta-coded lanes +
  per-lane-group scales and an error-feedback residual threaded through
  the iteration carry — ~4× fewer payload bytes for fp32 programs, exact
  int32 passthrough for ``combine="min"`` programs (CC labels).
- ``exchange="ragged"`` / ``"ragged_quantized"``: the all_to_all's
  cross-pair H_max padding replaced by k−1 ppermute ring hops, each
  padded only to its own distance's lane population (the layout's
  ``halo_schedule()``, baked into the exchange instance as a static
  tuple — which is why the jitted drivers below key their caches on the
  exchange *instance*, not its name).  The quantized variant ships only
  the top-Δ largest error-feedback deltas per hop (int16 index + int8
  code pairs).

The engine is **program-parametric**: a ``GASProgram`` bundles the four
per-device callables (init / local gather-scatter / apply / optional
global aux) plus the combine op and wire dtype, and one pair of drivers
runs any program:

- ``simulate_gas(program, …)``   : stacked (k, …) arrays on one device —
                                   tests and host-side benchmarks.
- ``shard_map_gas(program, …)``  : one partition per mesh device over axis
                                   ``parts`` — the production path.

``simulate_pagerank`` / ``shard_map_pagerank`` / ``simulate_cc`` /
``shard_map_cc`` are thin instantiations of ``pagerank_program()`` /
``CC_PROGRAM`` over those two drivers, so the simulated and shard_map
paths run the same per-device math by construction and can't drift.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .partition import PartitionLayout
from ..dist import collectives as coll
from ..dist._compat import shard_map
from ..dist.halo import RAGGED_EXCHANGES, get_exchange

DAMPING = 0.85
# CC labels are int32 vertex ids; the min-identity sentinel marks padded /
# non-master slots and can never win a minimum against a real id
CC_SENTINEL = int(np.iinfo(np.int32).max)


# ----------------------------------------------------------- program spec

@dataclass(frozen=True)
class GASProgram:
    """One GAS computation as per-device callables over the layout's
    ``device_arrays()`` pytree (all (L_max,)-shaped per device):

      init(dev)               -> initial per-slot values
      local(value, dev)       -> gather/scatter partials over local edges
      apply(total, aux, dev)  -> new master-slot values (others get the
                                 combine identity / sentinel)
      aux(value, dev)         -> optional per-device scalar, reduced
                                 globally (psum / stacked sum) before
                                 ``apply`` — pagerank's dangling mass

    ``combine`` ("sum" | "min") and ``dtype`` fix the mirror-sync wire
    semantics; the quantized exchange uses them to decide whether the
    payload may be lossily delta-coded (fp32 sum) or must ship exact
    (int32 min)."""
    name: str
    combine: str
    dtype: Any
    init: Callable
    local: Callable
    apply: Callable
    aux: Callable | None = None


# ----------------------------------------------------------- per-device math

def _local_rank_partial(rank, dev):
    """Scatter phase: Σ_{(u,w)∈E_p, w=v} rank[u]/outdeg[u] per local slot."""
    l_max = dev["vert_gid"].shape[0]
    safe_deg = jnp.maximum(dev["out_deg"], 1)
    contrib = jnp.where(dev["vert_mask"] & (dev["out_deg"] > 0),
                        rank / safe_deg, 0.0)
    contrib = jnp.concatenate([contrib, jnp.zeros((1,), contrib.dtype)])
    per_edge = jnp.where(dev["edge_mask"], contrib[dev["edge_src"]], 0.0)
    return jax.ops.segment_sum(per_edge, dev["edge_dst"],
                               num_segments=l_max + 1)[:l_max]


def _local_dangle(rank, dev):
    """Rank mass sitting on dangling masters (out_deg == 0)."""
    m = dev["vert_mask"] & dev["is_master"] & (dev["out_deg"] == 0)
    return jnp.sum(jnp.where(m, rank, 0.0))


def _pagerank_apply(total_in, dangle, dev, num_vertices):
    base = (1.0 - DAMPING) / num_vertices
    new = base + DAMPING * (total_in + dangle / num_vertices)
    return jnp.where(dev["vert_mask"] & dev["is_master"], new, 0.0)


@lru_cache(maxsize=None)
def pagerank_program(num_vertices: int) -> GASProgram:
    """Damped pagerank with dangling-mass redistribution (fp32, sum
    combine — the quantized exchange may delta-code its mirror lanes).
    Cached per vertex count so repeated layouts hit the same jit cache."""
    def init(dev):
        return jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)

    def apply(total, dangle, dev):
        return _pagerank_apply(total, dangle, dev, num_vertices)

    return GASProgram(name="pagerank", combine="sum", dtype=jnp.float32,
                      init=init, local=_local_rank_partial, apply=apply,
                      aux=_local_dangle)


def _cc_init(dev):
    return jnp.where(dev["vert_mask"], dev["vert_gid"].astype(jnp.int32),
                     CC_SENTINEL)


def _cc_local_min(label, dev):
    """Edge-wise min exchange in both directions (undirected semantics)."""
    l_max = dev["vert_gid"].shape[0]
    lab = jnp.concatenate([jnp.where(dev["vert_mask"], label, CC_SENTINEL),
                           jnp.full((1,), CC_SENTINEL, label.dtype)])
    s, d, m = dev["edge_src"], dev["edge_dst"], dev["edge_mask"]
    vs = jnp.where(m, lab[s], CC_SENTINEL)
    vd = jnp.where(m, lab[d], CC_SENTINEL)
    out = jax.ops.segment_min(vs, d, num_segments=l_max + 1)[:l_max]
    out2 = jax.ops.segment_min(vd, s, num_segments=l_max + 1)[:l_max]
    cur = jnp.where(dev["vert_mask"], label, CC_SENTINEL)
    return jnp.minimum(cur, jnp.minimum(out, out2))


def _cc_apply(total, aux, dev):
    return jnp.where(dev["vert_mask"] & dev["is_master"], total,
                     CC_SENTINEL)


# label propagation / connected components: int32 labels are exact on the
# wire, so every exchange (incl. "quantized") ships them unquantized
CC_PROGRAM = GASProgram(name="cc", combine="min", dtype=jnp.int32,
                        init=_cc_init, local=_cc_local_min, apply=_cc_apply)


# ------------------------------------------------------- program library
#
# The engine's whole point is program-parametric multi-tenant analytics:
# each program below is a thin GASProgram instantiation with a NumPy
# ``reference_*`` oracle, spanning every wire-semantics cell the exchange
# layer distinguishes — (sum, f32) lossy delta-coded payloads with error
# feedback (pagerank / ppr / centrality), (min, i32) exact label/distance
# lattices (cc / labelprop / sssp / bfs), and (sum, i32) exact counters
# (degree).  Source / seed-set parameters are derived deterministically
# from the vertex-id space so no extra layout tables are needed.

DEFAULT_SOURCE = 0


def default_num_seeds(num_vertices: int) -> int:
    """Seed-set size for labelprop/ppr: ~V/256, at least 2."""
    return max(2, num_vertices // 256)


def _masked_ext(values, mask, fill):
    """(L_max,) values → (L_max+1,) with invalid slots and the trailing
    pad bucket forced to ``fill`` (what edge endpoint gathers read)."""
    safe = jnp.where(mask, values, fill)
    return jnp.concatenate([safe, jnp.full((1,), fill, safe.dtype)])


def _sssp_weight(gu, gv):
    """Deterministic positive edge weight from the endpoint gids (1..11)
    — gives SSSP a genuinely weighted metric with no edge-weight table."""
    return 1 + (3 * gu + 7 * gv) % 11


def _edge_gids(dev):
    gid_ext = jnp.concatenate([dev["vert_gid"],
                               jnp.full((1,), -1, jnp.int32)])
    return gid_ext[dev["edge_src"]], gid_ext[dev["edge_dst"]]


def _relax_local(dist, dev, weight_fn):
    """One Bellman-Ford relaxation over the local directed edges:
    min over incoming (u → v) of dist[u] + w(u, v), min'd with current."""
    l_max = dev["vert_gid"].shape[0]
    d_ext = _masked_ext(dist, dev["vert_mask"], CC_SENTINEL)
    du = d_ext[dev["edge_src"]]
    gu, gv = _edge_gids(dev)
    w = weight_fn(gu, gv)
    # clamping before the add keeps sentinel+w from wrapping int32
    cand = jnp.where(dev["edge_mask"] & (du < CC_SENTINEL),
                     jnp.minimum(du, CC_SENTINEL - 64) + w, CC_SENTINEL)
    relaxed = jax.ops.segment_min(cand, dev["edge_dst"],
                                  num_segments=l_max + 1)[:l_max]
    cur = jnp.where(dev["vert_mask"], dist, CC_SENTINEL)
    return jnp.minimum(cur, relaxed)


def _distance_program(name: str, source: int, weight_fn) -> GASProgram:
    def init(dev):
        at_src = dev["vert_mask"] & (dev["vert_gid"] == source)
        return jnp.where(at_src, 0, CC_SENTINEL).astype(jnp.int32)

    def local(dist, dev):
        return _relax_local(dist, dev, weight_fn)

    def apply(total, aux, dev):
        clamped = jnp.where(dev["vert_gid"] == source, 0, total)
        return jnp.where(dev["vert_mask"] & dev["is_master"], clamped,
                         CC_SENTINEL)

    return GASProgram(name=name, combine="min", dtype=jnp.int32,
                      init=init, local=local, apply=apply)


@lru_cache(maxsize=None)
def sssp_program(source: int = DEFAULT_SOURCE) -> GASProgram:
    """Single-source shortest paths (Bellman-Ford relaxations) under the
    deterministic gid-hash weights — (min, i32), exact on every wire."""
    return _distance_program("sssp", source, _sssp_weight)


@lru_cache(maxsize=None)
def bfs_program(source: int = DEFAULT_SOURCE) -> GASProgram:
    """BFS levels from ``source`` (unit-weight min-plus) — (min, i32)."""
    return _distance_program("bfs", source, lambda gu, gv: 1)


@lru_cache(maxsize=None)
def labelprop_program(num_vertices: int,
                      num_seeds: int | None = None) -> GASProgram:
    """Seeded directed label propagation — the paper's own motivating
    workload: vertices with gid < num_seeds hold their own gid as a fixed
    label; everything else takes the min label over in-neighbors each
    round.  Directed propagation + clamped seeds distinguish it from CC's
    undirected min-label contagion.  (min, i32), exact on every wire."""
    ns = default_num_seeds(num_vertices) if num_seeds is None else num_seeds

    def init(dev):
        seeded = dev["vert_mask"] & (dev["vert_gid"] < ns)
        return jnp.where(seeded, dev["vert_gid"].astype(jnp.int32),
                         CC_SENTINEL)

    def local(label, dev):
        l_max = dev["vert_gid"].shape[0]
        lab_ext = _masked_ext(label, dev["vert_mask"], CC_SENTINEL)
        prop = jnp.where(dev["edge_mask"], lab_ext[dev["edge_src"]],
                         CC_SENTINEL)
        out = jax.ops.segment_min(prop, dev["edge_dst"],
                                  num_segments=l_max + 1)[:l_max]
        cur = jnp.where(dev["vert_mask"], label, CC_SENTINEL)
        return jnp.minimum(cur, out)

    def apply(total, aux, dev):
        seeded = dev["vert_gid"] < ns
        clamped = jnp.where(seeded, dev["vert_gid"].astype(jnp.int32),
                            total)
        return jnp.where(dev["vert_mask"] & dev["is_master"], clamped,
                         CC_SENTINEL)

    return GASProgram(name="labelprop", combine="min", dtype=jnp.int32,
                      init=init, local=local, apply=apply)


def _degree_local(value, dev):
    """Per-slot incident-edge count (out at src + in at dst); ignores the
    carried value, so any iteration count ≥ 1 yields the same answer."""
    l_max = dev["vert_gid"].shape[0]
    ones = dev["edge_mask"].astype(jnp.int32)
    out = jax.ops.segment_sum(ones, dev["edge_src"],
                              num_segments=l_max + 1)[:l_max]
    inc = jax.ops.segment_sum(ones, dev["edge_dst"],
                              num_segments=l_max + 1)[:l_max]
    return out + inc


# total degree: the (sum, i32) wire cell — an integer sum combine ships
# exact on the quantized backend (lossy_payload is False)
DEGREE_PROGRAM = GASProgram(
    name="degree", combine="sum", dtype=jnp.int32,
    init=lambda dev: jnp.zeros(dev["vert_gid"].shape, jnp.int32),
    local=_degree_local,
    apply=lambda total, aux, dev: jnp.where(
        dev["vert_mask"] & dev["is_master"], total, 0))


def _cent_local(value, dev):
    """In-neighbor sum without degree normalization (A^T x)."""
    l_max = dev["vert_gid"].shape[0]
    contrib = _masked_ext(value, dev["vert_mask"],
                          jnp.zeros((), value.dtype))
    per_edge = jnp.where(dev["edge_mask"], contrib[dev["edge_src"]], 0.0)
    return jax.ops.segment_sum(per_edge, dev["edge_dst"],
                               num_segments=l_max + 1)[:l_max]


def _cent_aux(value, dev):
    """Global L1 mass of the current iterate (masters only)."""
    m = dev["vert_mask"] & dev["is_master"]
    return jnp.sum(jnp.where(m, value, 0.0))


@lru_cache(maxsize=None)
def centrality_program(num_vertices: int) -> GASProgram:
    """Approximate (eigenvector-style) centrality: damped power iteration
    x ← (1−d)/V + d·(Aᵀx)/‖x‖₁, the L1-normalized Katz/eigenvector hybrid
    — the normalization rides the engine's global-aux reduction.  (sum,
    f32): the quantized wire delta-codes it with error feedback."""
    base = (1.0 - DAMPING) / num_vertices

    def init(dev):
        return jnp.where(dev["vert_mask"], 1.0 / num_vertices, 0.0)

    def apply(total, norm, dev):
        new = base + DAMPING * total / jnp.maximum(norm, 1e-30)
        return jnp.where(dev["vert_mask"] & dev["is_master"], new, 0.0)

    return GASProgram(name="centrality", combine="sum", dtype=jnp.float32,
                      init=init, local=_cent_local, apply=apply,
                      aux=_cent_aux)


@lru_cache(maxsize=None)
def ppr_program(num_vertices: int,
                num_seeds: int | None = None) -> GASProgram:
    """Personalized pagerank: teleport (and dangling) mass lands on the
    seed set {gid < num_seeds} instead of uniformly — same local
    scatter/aux as pagerank, different apply.  (sum, f32) lossy wire."""
    ns = default_num_seeds(num_vertices) if num_seeds is None else num_seeds

    def init(dev):
        seeded = dev["vert_mask"] & (dev["vert_gid"] < ns)
        return jnp.where(seeded, 1.0 / ns, 0.0)

    def apply(total, dangle, dev):
        seeded = dev["vert_gid"] < ns
        teleport = jnp.where(seeded,
                             (1.0 - DAMPING) / ns + DAMPING * dangle / ns,
                             0.0)
        return jnp.where(dev["vert_mask"] & dev["is_master"],
                         DAMPING * total + teleport, 0.0)

    return GASProgram(name="ppr", combine="sum", dtype=jnp.float32,
                      init=init, local=_local_rank_partial, apply=apply,
                      aux=_local_dangle)


PROGRAM_NAMES = ("pagerank", "cc", "labelprop", "sssp", "bfs", "degree",
                 "centrality", "ppr")


def get_program(name: str, num_vertices: int) -> GASProgram:
    """Program registry: name → GASProgram with the library defaults
    (source vertex 0, ~V/256 seeds).  Factories are lru-cached so
    repeated lookups share one program instance (and its jit cache)."""
    if name == "pagerank":
        return pagerank_program(num_vertices)
    if name == "cc":
        return CC_PROGRAM
    if name == "labelprop":
        return labelprop_program(num_vertices)
    if name == "sssp":
        return sssp_program()
    if name == "bfs":
        return bfs_program()
    if name == "degree":
        return DEGREE_PROGRAM
    if name == "centrality":
        return centrality_program(num_vertices)
    if name == "ppr":
        return ppr_program(num_vertices)
    raise ValueError(f"unknown program {name!r}; expected one of "
                     f"{PROGRAM_NAMES}")


# ----------------------------------------------------------- shared body

def _check_overlap(exchange: str, overlap: bool) -> None:
    """The overlapped body needs per-hop partial combine + the layout's
    interior/frontier split — only the ragged ring exchanges provide
    both (dense/halo sync in one monolithic collective, so there is
    nothing to overlap against)."""
    if overlap and exchange not in RAGGED_EXCHANGES:
        raise ValueError(
            f"overlap=True needs a ragged ring exchange "
            f"{RAGGED_EXCHANGES}; got {exchange!r}")


def _gas_body(program: GASProgram, ex, dev, axis: str | None = None,
              overlap: bool = False):
    """One GAS iteration as a ``fori_loop`` body over (value, state).

    ``axis=None`` is the stacked form: ``dev`` holds full (k, …) stacks,
    per-device callables vmap over the leading axis, and the exchange's
    ``*_stacked`` halves model the collectives.  With a mesh axis it is
    the per-device form run inside shard_map.  Both forms call the same
    ``program`` callables, so the simulated and production paths cannot
    drift.

    ``overlap=True`` (ragged exchanges only) restructures the reduce →
    apply dependency chain: the ring reduce folds each hop's lanes into
    the master accumulator as it lands (``hopwise``), and the apply of
    **interior** vertices (``~dev["frontier"]`` — single-replica, so
    their aggregate has no mirror contribution) is computed from the
    local partial alone, with no data dependence on any ppermute.  The
    scheduler is therefore free to run the interior gather/apply while
    the ring is still in flight; frontier slots select the exchanged
    total.  Interior slots satisfy total == partial bit-exactly (the
    hop accumulator holds the combine identity there), so the overlapped
    body is bit-identical to the phase-ordered one — same collectives,
    same values, shorter critical path."""
    stacked = axis is None

    def body(_, carry):
        value, state = carry
        if program.aux is not None:
            aux = (jnp.sum(jax.vmap(program.aux)(value, dev)) if stacked
                   else coll.psum(program.aux(value, dev), axis))
        else:
            aux = None
        if stacked:
            partial_ = jax.vmap(program.local)(value, dev)
            if overlap:
                total, state = ex.reduce_stacked(
                    partial_, dev, program.combine, state, hopwise=True)
                app = jax.vmap(lambda t, d: program.apply(t, aux, d))
                new_master = jnp.where(dev["frontier"], app(total, dev),
                                       app(partial_, dev))
            else:
                total, state = ex.reduce_stacked(partial_, dev,
                                                 program.combine, state)
                new_master = jax.vmap(
                    lambda t, d: program.apply(t, aux, d))(total, dev)
            value, state = ex.broadcast_stacked(new_master, dev,
                                                program.combine, state)
        else:
            partial_ = program.local(value, dev)
            if overlap:
                total, state = ex.reduce_to_masters(
                    partial_, dev, program.combine, state, hopwise=True)
                new_master = jnp.where(
                    dev["frontier"], program.apply(total, aux, dev),
                    program.apply(partial_, aux, dev))
            else:
                total, state = ex.reduce_to_masters(partial_, dev,
                                                    program.combine, state)
                new_master = program.apply(total, aux, dev)
            value, state = ex.broadcast_from_masters(new_master, dev,
                                                     program.combine, state)
        return value, state

    return body


# --------------------------------------------------- early-exit residual

def _residual(new, old, mask, axis: str | None = None):
    """Masked max-norm residual between iterates, as f32.  Integer
    (min/counter) programs difference in int64 first — any real change
    is ≥ 1 and survives the f32 cast, so ``res > tol`` at tol ≥ 0 means
    "not yet at the fixed point" exactly; f32 programs use |Δ| directly.
    With a mesh ``axis`` the result is pmax'd so every device sees the
    same residual and the while_loop trip count stays lockstep."""
    if jnp.issubdtype(jnp.asarray(new).dtype, jnp.integer):
        # |Δ| without widening: values live in [0, iinfo.max] (labels /
        # distances / counters), so max−min is exact in the native dtype
        d = jnp.maximum(new, old) - jnp.minimum(new, old)
    else:
        d = jnp.abs(new - old)
    r = jnp.max(jnp.where(mask, d, 0)).astype(jnp.float32)
    return coll.pmax(r, axis)


def _converge_loop(body, value, state, iters: int, tol: float, mask,
                   axis: str | None = None):
    """``lax.while_loop`` form of the GAS iteration: ``iters`` becomes a
    cap and the loop exits once the masked master residual drops to
    ``tol``.  Returns (value, iters_run).  Running the fixed-``iters``
    path for exactly ``iters_run`` iterations reproduces the same value
    bit-for-bit — the body is shared, only the trip count differs."""
    def cond(carry):
        i, _, _, res = carry
        return (i < iters) & (res > tol)

    def wbody(carry):
        i, v, st, _ = carry
        nv, nst = body(i, (v, st))
        return i + 1, nv, nst, _residual(nv, v, mask, axis)

    i, value, _, _ = jax.lax.while_loop(
        cond, wbody,
        (jnp.int32(0), value, state, jnp.float32(jnp.inf)))
    return value, i


def _warm_tables(layout: PartitionLayout, dtype, init_values):
    """Host-side dense (V_old,) warm vector → per-slot (k, L_max) value
    and validity tables.  Vertices the old fixed point knew (gid <
    len(init_values)) seed from it; everything else keeps ``program.
    init``.  An empty vector yields an all-False mask — the cold run —
    so warm and cold share ONE compiled loop (same trace shapes)."""
    dense = (np.zeros(0) if init_values is None
             else np.asarray(init_values))
    n = dense.shape[0]
    gid = layout.vert_gid
    known = layout.vert_mask & (gid < n)
    safe = np.clip(gid, 0, max(n - 1, 0))
    vals = np.where(known, dense[safe] if n else 0, 0)
    vals = vals.astype(np.dtype(jnp.dtype(dtype).name))
    return jnp.asarray(vals), jnp.asarray(known)


# ----------------------------------------------------------- simulated driver

def _stack_dev(layout: PartitionLayout, exchange: str | None = None):
    return jax.tree_util.tree_map(jnp.asarray,
                                  layout.device_arrays(exchange))


@partial(jax.jit,
         static_argnames=("program", "iters", "ex", "tol", "overlap"))
def _sim_gas(program: GASProgram, dev, iters: int, ex,
             tol: float | None = None, overlap: bool = False, warm=None):
    # ``ex`` is the exchange INSTANCE (frozen dataclass, hashable): the
    # ragged formats carry their per-layout lane schedule in the
    # instance, so the instance — not the exchange name — is the cache key
    value = jax.vmap(program.init)(dev)
    if warm is not None:
        wvals, wmask = warm
        value = jnp.where(wmask, wvals, value)
    # iters == 0 must return init values without even tracing the loop
    # body — a trip-count-0 fori_loop still bakes its collectives into
    # the HLO, which the dry-run byte parser would then count
    if not iters:
        return value if tol is None else (value, jnp.int32(0))
    state = ex.init_state(dev, program.dtype, program.combine)
    body = _gas_body(program, ex, dev, overlap=overlap)
    if tol is None:
        value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
        return value
    mask = dev["vert_mask"] & dev["is_master"]
    return _converge_loop(body, value, state, iters, tol, mask)


def _collect_master_values(layout: PartitionLayout, stacked) -> np.ndarray:
    """(k, L_max) per-device values → dense (V,) using master slots."""
    vals = np.asarray(stacked)
    out = np.zeros(layout.num_vertices, dtype=vals.dtype)
    gid = layout.vert_gid
    sel = layout.is_master & layout.vert_mask
    out[gid[sel]] = vals[sel]
    return out


def simulate_gas(program: GASProgram, layout: PartitionLayout,
                 iters: int = 30, exchange: str = "dense", *,
                 tol: float | None = None, overlap: bool = False,
                 init_values=None, return_iters: bool = False):
    """Stacked one-device driver for any GAS program (bit-identical math
    to ``shard_map_gas`` — the collectives become transposes/gathers).

    ``tol`` switches the loop to convergence early exit: ``iters``
    becomes a cap and the run stops once the master-slot residual
    max-norm drops to ``tol`` (``return_iters=True`` also returns the
    executed iteration count).  ``overlap`` runs the interleaved
    interior/frontier body (ragged exchanges only — bit-identical, see
    ``_gas_body``).  ``init_values`` warm-starts from a dense (V_old,)
    value vector, e.g. a previously converged fixed point."""
    _check_overlap(exchange, overlap)
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout)
    warm = (None if init_values is None
            else _warm_tables(layout, program.dtype, init_values))
    out = _sim_gas(program, dev, iters, ex, tol, overlap, warm)
    values, iters_run = (out, iters) if tol is None else out
    dense = _collect_master_values(layout, values)
    return (dense, int(iters_run)) if return_iters else dense


def simulate_pagerank(layout: PartitionLayout, iters: int = 30,
                      exchange: str = "dense", **kw):
    return simulate_gas(pagerank_program(layout.num_vertices), layout,
                        iters, exchange, **kw)


def simulate_cc(layout: PartitionLayout, iters: int = 30,
                exchange: str = "dense", **kw):
    out = simulate_gas(CC_PROGRAM, layout, iters, exchange, **kw)
    if kw.get("return_iters"):
        value, iters_run = out
        return value.astype(np.int64), iters_run
    return out.astype(np.int64)


# ----------------------------------------------------------- shard_map driver

def shard_map_gas(program: GASProgram, layout: PartitionLayout, mesh: Mesh,
                  iters: int = 30, axis: str = "parts",
                  exchange: str = "dense", *, tol: float | None = None,
                  overlap: bool = False, init_values=None,
                  return_iters: bool = False):
    """Production path: one partition per device along ``axis``.
    Requires mesh axis size == layout.k.  ``exchange`` picks the mirror
    wire format (see module docstring).  Returns (V,) master values.
    ``tol`` / ``overlap`` / ``init_values`` / ``return_iters`` as in
    ``simulate_gas`` — the residual is pmax'd across the mesh so every
    device exits the while_loop on the same iteration."""
    _check_overlap(exchange, overlap)
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout, axis=axis)
    spec = P(axis)
    warm = (None if init_values is None
            else _warm_tables(layout, program.dtype, init_values))
    args = (dev,) if warm is None else (dev, warm)
    specs = tuple(jax.tree_util.tree_map(lambda _: spec, a) for a in args)

    # the while_loop in the tol path has no shard_map replication rule
    # on pinned jax — the residual is pmax'd, so every device agrees on
    # the trip count and the check is safe to skip
    @partial(shard_map, mesh=mesh, in_specs=specs,
             out_specs=spec if tol is None else (spec, spec),
             check_vma=tol is None)
    def run(dev, *warm_arg):
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        value = program.init(dev)
        if warm_arg:
            wvals, wmask = jax.tree_util.tree_map(lambda x: x[0],
                                                  warm_arg[0])
            value = jnp.where(wmask, wvals, value)
        if not iters:
            return (value[None] if tol is None
                    else (value[None], jnp.zeros((1,), jnp.int32)))
        state = ex.init_state(dev, program.dtype, program.combine)
        body = _gas_body(program, ex, dev, axis, overlap=overlap)
        if tol is None:
            value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
            return value[None]
        mask = dev["vert_mask"] & dev["is_master"]
        value, i = _converge_loop(body, value, state, iters, tol, mask,
                                  axis)
        return value[None], i[None]

    with mesh:
        out = run(*args)
    values, iters_run = (out, iters) if tol is None else out
    dense = _collect_master_values(layout, values)
    if return_iters:
        return dense, int(np.asarray(iters_run).reshape(-1)[0])
    return dense


def shard_map_pagerank(layout: PartitionLayout, mesh: Mesh,
                       iters: int = 30, axis: str = "parts",
                       exchange: str = "dense") -> np.ndarray:
    return shard_map_gas(pagerank_program(layout.num_vertices), layout,
                         mesh, iters=iters, axis=axis, exchange=exchange)


def shard_map_cc(layout: PartitionLayout, mesh: Mesh, iters: int = 30,
                 axis: str = "parts", exchange: str = "dense") -> np.ndarray:
    return shard_map_gas(CC_PROGRAM, layout, mesh, iters=iters, axis=axis,
                         exchange=exchange).astype(np.int64)


# ------------------------------------------------- fused multi-program driver

@dataclass(frozen=True)
class FusedGAS:
    """N homogeneous GAS programs executed as one fused iteration over a
    shared ``PartitionLayout``: per-program local/apply math runs stacked
    along a leading program axis, and the mirror sync ships **one**
    collective per phase with all programs' lanes concatenated (per-
    program scale groups on the quantized wire — see
    ``repro.dist.halo``'s ``*_multi`` ops).  Programs must share one
    (combine, dtype) wire cell; hashable so it can be a jit static."""
    programs: tuple[GASProgram, ...]

    def __post_init__(self):
        if not self.programs:
            raise ValueError("FusedGAS needs at least one program")
        combines = {p.combine for p in self.programs}
        dtypes = {np.dtype(p.dtype).name for p in self.programs}
        if len(combines) > 1 or len(dtypes) > 1:
            raise ValueError(
                "fused programs must share one (combine, dtype) wire "
                f"cell; got combines {sorted(combines)} and dtypes "
                f"{sorted(dtypes)}")

    @property
    def combine(self) -> str:
        return self.programs[0].combine

    @property
    def dtype(self):
        return self.programs[0].dtype

    @property
    def name(self) -> str:
        return "+".join(p.name for p in self.programs)


def fuse_programs(programs) -> FusedGAS:
    """Coerce a GASProgram sequence (or an existing FusedGAS) to FusedGAS."""
    if isinstance(programs, FusedGAS):
        return programs
    return FusedGAS(tuple(programs))


def _gas_body_multi(fused: FusedGAS, ex, dev, axis: str | None = None,
                    overlap: bool = False):
    """One fused GAS iteration over (values, state) where values carry a
    program axis: (N, L_max) per device, (k, N, L_max) stacked.  The
    per-program math is a python loop over traced stacks (unrolled at
    trace time — N is small), but each mirror-sync phase is a single
    ``*_multi`` exchange call, i.e. one collective for all N programs.
    ``overlap`` interleaves interior apply with the ragged ring exactly
    like ``_gas_body`` (the frontier mask broadcasts over the program
    axis)."""
    stacked = axis is None
    programs = fused.programs
    n = len(programs)

    def global_aux(value):
        idx = [i for i, p in enumerate(programs) if p.aux is not None]
        auxes: list = [None] * n
        if idx:
            if stacked:
                per = jnp.stack([
                    jnp.sum(jax.vmap(programs[i].aux)(value[:, i], dev))
                    for i in idx])
            else:
                per = coll.psum(
                    jnp.stack([programs[i].aux(value[i], dev)
                               for i in idx]), axis)
            for j, i in enumerate(idx):
                auxes[i] = per[j]
        return auxes

    def body(_, carry):
        value, state = carry
        auxes = global_aux(value)
        if stacked:
            partials = jnp.stack(
                [jax.vmap(programs[i].local)(value[:, i], dev)
                 for i in range(n)], axis=1)

            def apply_all(tot):
                return jnp.stack(
                    [jax.vmap(lambda t, d, i=i: programs[i].apply(
                        t, auxes[i], d))(tot[:, i], dev)
                     for i in range(n)], axis=1)

            if overlap:
                total, state = ex.reduce_stacked_multi(
                    partials, dev, fused.combine, state, hopwise=True)
                new_master = jnp.where(dev["frontier"][:, None, :],
                                       apply_all(total),
                                       apply_all(partials))
            else:
                total, state = ex.reduce_stacked_multi(
                    partials, dev, fused.combine, state)
                new_master = apply_all(total)
            value, state = ex.broadcast_stacked_multi(new_master, dev,
                                                      fused.combine, state)
        else:
            partials = jnp.stack([programs[i].local(value[i], dev)
                                  for i in range(n)])

            def apply_all(tot):
                return jnp.stack(
                    [programs[i].apply(tot[i], auxes[i], dev)
                     for i in range(n)])

            if overlap:
                total, state = ex.reduce_to_masters_multi(
                    partials, dev, fused.combine, state, hopwise=True)
                new_master = jnp.where(dev["frontier"][None, :],
                                       apply_all(total),
                                       apply_all(partials))
            else:
                total, state = ex.reduce_to_masters_multi(
                    partials, dev, fused.combine, state)
                new_master = apply_all(total)
            value, state = ex.broadcast_from_masters_multi(
                new_master, dev, fused.combine, state)
        return value, state

    return body


@partial(jax.jit,
         static_argnames=("fused", "iters", "ex", "tol", "overlap"))
def _sim_gas_many(fused: FusedGAS, dev, iters: int, ex,
                  tol: float | None = None, overlap: bool = False,
                  warm=None):
    value = jnp.stack([jax.vmap(p.init)(dev) for p in fused.programs],
                      axis=1)
    if warm is not None:
        wvals, wmask = warm
        value = jnp.where(wmask, wvals, value)
    if not iters:
        return value if tol is None else (value, jnp.int32(0))
    state = ex.init_state_multi(dev, fused.dtype, fused.combine,
                                len(fused.programs))
    body = _gas_body_multi(fused, ex, dev, overlap=overlap)
    if tol is None:
        value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
        return value
    mask = (dev["vert_mask"] & dev["is_master"])[:, None, :]
    return _converge_loop(body, value, state, iters, tol, mask)


def _warm_tables_many(layout: PartitionLayout, fused: FusedGAS,
                      init_values):
    """Per-program warm tables stacked along the program axis:
    ``init_values`` is one dense (V_old,) vector or None per program
    (None → all-False mask, i.e. that program starts cold)."""
    pairs = [_warm_tables(layout, fused.dtype, iv) for iv in init_values]
    return (jnp.stack([v for v, _ in pairs], axis=1),
            jnp.stack([m for _, m in pairs], axis=1))


def simulate_gas_many(programs, layout: PartitionLayout, iters: int = 30,
                      exchange: str = "dense", *,
                      tol: float | None = None, overlap: bool = False,
                      init_values=None, return_iters: bool = False):
    """Stacked one-device driver for a fused program bundle; returns one
    dense (V,) master-value array per program, in bundle order.  ``tol``
    (early exit; residual = max over all programs), ``overlap``, and
    per-program ``init_values`` as in ``simulate_gas``."""
    _check_overlap(exchange, overlap)
    fused = fuse_programs(programs)
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout)
    warm = (None if init_values is None
            else _warm_tables_many(layout, fused, init_values))
    out = _sim_gas_many(fused, dev, iters, ex, tol, overlap, warm)
    values, iters_run = (out, iters) if tol is None else out
    dense = [_collect_master_values(layout, values[:, i])
             for i in range(len(fused.programs))]
    return (dense, int(iters_run)) if return_iters else dense


def shard_map_gas_many(programs, layout: PartitionLayout, mesh: Mesh,
                       iters: int = 30, axis: str = "parts",
                       exchange: str = "dense", *,
                       tol: float | None = None, overlap: bool = False,
                       init_values=None, return_iters: bool = False):
    """Production fused path: N programs per device along ``axis``, one
    mirror-sync collective per phase for the whole bundle.  ``tol`` /
    ``overlap`` / ``init_values`` / ``return_iters`` as in
    ``simulate_gas_many``."""
    _check_overlap(exchange, overlap)
    fused = fuse_programs(programs)
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout, axis=axis)
    spec = P(axis)
    warm = (None if init_values is None
            else _warm_tables_many(layout, fused, init_values))
    args = (dev,) if warm is None else (dev, warm)
    specs = tuple(jax.tree_util.tree_map(lambda _: spec, a) for a in args)

    # see shard_map_gas: the tol while_loop needs the replication check
    # off on pinned jax; the pmax'd residual keeps trip counts aligned
    @partial(shard_map, mesh=mesh, in_specs=specs,
             out_specs=spec if tol is None else (spec, spec),
             check_vma=tol is None)
    def run(dev, *warm_arg):
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        value = jnp.stack([p.init(dev) for p in fused.programs])
        if warm_arg:
            wvals, wmask = jax.tree_util.tree_map(lambda x: x[0],
                                                  warm_arg[0])
            value = jnp.where(wmask, wvals, value)
        if not iters:
            return (value[None] if tol is None
                    else (value[None], jnp.zeros((1,), jnp.int32)))
        state = ex.init_state_multi(dev, fused.dtype, fused.combine,
                                    len(fused.programs))
        body = _gas_body_multi(fused, ex, dev, axis, overlap=overlap)
        if tol is None:
            value, _ = jax.lax.fori_loop(0, iters, body, (value, state))
            return value[None]
        mask = (dev["vert_mask"] & dev["is_master"])[None, :]
        value, i = _converge_loop(body, value, state, iters, tol, mask,
                                  axis)
        return value[None], i[None]

    with mesh:
        out = run(*args)
    values, iters_run = (out, iters) if tol is None else out
    dense = [_collect_master_values(layout, values[:, i])
             for i in range(len(fused.programs))]
    if return_iters:
        return dense, int(np.asarray(iters_run).reshape(-1)[0])
    return dense


def gas_step_for_dryrun(program, layout: PartitionLayout,
                        mesh: Mesh, axis: str = "parts", iters: int = 1,
                        exchange: str = "dense", overlap: bool = False):
    """Returns (jitted_fn, example_args) whose .lower() the dry-run compiles
    — the graph dry-run parses each backend's collective bytes out of the
    post-SPMD HLO (``launch/dryrun.py --graph``).

    ``program`` may be a single ``GASProgram``, or a program sequence /
    ``FusedGAS``, in which case the compiled step is the fused
    multi-program iteration (one collective per phase for the bundle) so
    the dry-run can compare fused vs. separate wire bytes.  ``overlap``
    compiles the interleaved interior/frontier body (ragged exchanges
    only) — the dry-run gates that its wire bytes and collective-permute
    count match the phase-ordered step exactly."""
    _check_overlap(exchange, overlap)
    dev = _stack_dev(layout, exchange)
    ex = get_exchange(exchange, layout, axis=axis)
    spec = P(axis)
    fused = (None if isinstance(program, GASProgram)
             else fuse_programs(program))

    @partial(shard_map, mesh=mesh,
             in_specs=(jax.tree_util.tree_map(lambda _: spec, dev),),
             out_specs=spec)
    def step(dev):
        dev = jax.tree_util.tree_map(lambda x: x[0], dev)
        if fused is None:
            value = program.init(dev)
            if iters:
                state = ex.init_state(dev, program.dtype, program.combine)
                body = _gas_body(program, ex, dev, axis, overlap=overlap)
                value, _ = jax.lax.fori_loop(0, iters, body,
                                             (value, state))
        else:
            value = jnp.stack([p.init(dev) for p in fused.programs])
            if iters:
                state = ex.init_state_multi(dev, fused.dtype,
                                            fused.combine,
                                            len(fused.programs))
                body = _gas_body_multi(fused, ex, dev, axis,
                                       overlap=overlap)
                value, _ = jax.lax.fori_loop(0, iters, body,
                                             (value, state))
        return value[None]

    return jax.jit(step), (dev,)


def pagerank_step_for_dryrun(layout: PartitionLayout, mesh: Mesh,
                             axis: str = "parts", iters: int = 1,
                             exchange: str = "dense"):
    return gas_step_for_dryrun(pagerank_program(layout.num_vertices),
                               layout, mesh, axis=axis, iters=iters,
                               exchange=exchange)


# ----------------------------------------------------------- oracles

def reference_pagerank(src, dst, num_vertices, iters: int = 30) -> np.ndarray:
    """Dense single-machine oracle with identical dangling handling."""
    outdeg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(outdeg, src, 1)
    rank = np.full(num_vertices, 1.0 / num_vertices)
    base = (1.0 - DAMPING) / num_vertices
    for _ in range(iters):
        contrib = np.where(outdeg > 0, rank / np.maximum(outdeg, 1), 0.0)
        s = np.zeros(num_vertices)
        np.add.at(s, dst, contrib[src])
        dangle = rank[outdeg == 0].sum()
        rank = base + DAMPING * (s + dangle / num_vertices)
    return rank


def reference_cc(src, dst, num_vertices) -> np.ndarray:
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components
    A = sp.coo_matrix((np.ones(len(src)), (src, dst)),
                      shape=(num_vertices, num_vertices))
    _, comp = connected_components(A, directed=False)
    # canonical label: min vertex id of the component (what min-label finds)
    mins = np.full(comp.max() + 1, num_vertices, dtype=np.int64)
    np.minimum.at(mins, comp, np.arange(num_vertices))
    return mins[comp]


def _reference_relax(src, dst, num_vertices, iters, source, weights):
    """Shared Bellman-Ford oracle: iterates the exact per-round relaxation
    the engine runs, so it matches at any iteration count (converged or
    not) — unreachable vertices keep CC_SENTINEL."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    dist = np.full(num_vertices, CC_SENTINEL, dtype=np.int64)
    dist[source] = 0
    for _ in range(iters):
        du = dist[src]
        cand = np.where(du < CC_SENTINEL,
                        np.minimum(du, CC_SENTINEL - 64) + weights,
                        CC_SENTINEL)
        new = dist.copy()
        np.minimum.at(new, dst, cand)
        new[source] = 0
        dist = new
    return dist


def reference_sssp(src, dst, num_vertices, iters: int = 40,
                   source: int = DEFAULT_SOURCE) -> np.ndarray:
    """SSSP under the deterministic gid-hash weights w(u,v)=1+(3u+7v)%11."""
    s = np.asarray(src, dtype=np.int64)
    d = np.asarray(dst, dtype=np.int64)
    w = 1 + (3 * s + 7 * d) % 11
    return _reference_relax(s, d, num_vertices, iters, source, w)


def reference_bfs(src, dst, num_vertices, iters: int = 40,
                  source: int = DEFAULT_SOURCE) -> np.ndarray:
    """BFS levels from ``source`` over directed edges."""
    s = np.asarray(src, dtype=np.int64)
    return _reference_relax(s, dst, num_vertices, iters, source,
                            np.ones(len(s), dtype=np.int64))


def reference_labelprop(src, dst, num_vertices, iters: int = 40,
                        num_seeds: int | None = None) -> np.ndarray:
    """Seeded directed min-label propagation; non-seeds that no seed ever
    reaches keep CC_SENTINEL."""
    ns = default_num_seeds(num_vertices) if num_seeds is None else num_seeds
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    lab = np.full(num_vertices, CC_SENTINEL, dtype=np.int64)
    lab[:ns] = np.arange(ns)
    for _ in range(iters):
        new = lab.copy()
        np.minimum.at(new, dst, lab[src])
        new[:ns] = np.arange(ns)
        lab = new
    return lab


def reference_degree(src, dst, num_vertices) -> np.ndarray:
    """Total (in+out) degree, counting duplicate edges like the engine."""
    deg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(deg, np.asarray(src, dtype=np.int64), 1)
    np.add.at(deg, np.asarray(dst, dtype=np.int64), 1)
    return deg


def reference_centrality(src, dst, num_vertices,
                         iters: int = 30) -> np.ndarray:
    """L1-normalized damped power iteration x ← (1−d)/V + d·(Aᵀx)/‖x‖₁."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    x = np.full(num_vertices, 1.0 / num_vertices)
    base = (1.0 - DAMPING) / num_vertices
    for _ in range(iters):
        s = np.zeros(num_vertices)
        np.add.at(s, dst, x[src])
        x = base + DAMPING * s / max(x.sum(), 1e-30)
    return x


def reference_ppr(src, dst, num_vertices, iters: int = 30,
                  num_seeds: int | None = None) -> np.ndarray:
    """Personalized pagerank with teleport + dangling mass on the seeds."""
    ns = default_num_seeds(num_vertices) if num_seeds is None else num_seeds
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    outdeg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(outdeg, src, 1)
    e = np.zeros(num_vertices)
    e[:ns] = 1.0 / ns
    rank = e.copy()
    for _ in range(iters):
        contrib = np.where(outdeg > 0, rank / np.maximum(outdeg, 1), 0.0)
        s = np.zeros(num_vertices)
        np.add.at(s, dst, contrib[src])
        dangle = rank[outdeg == 0].sum()
        rank = DAMPING * s + (1.0 - DAMPING) * e + DAMPING * dangle * e
    return rank
