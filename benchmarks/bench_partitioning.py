"""Paper figures 3–7 and 9–12 as benchmark functions over synthetic web
graphs (offline substitutes in the same degree-law regime — see
EXPERIMENTS.md §Method).  Each ``fig*`` function returns CSV-ready rows.

Run as a module to produce the partitioner-backend artifact:

    PYTHONPATH=src python -m benchmarks.bench_partitioning --tiny --check

writes ``results/BENCH_partition.json`` (µs/edge + RF per backend per k,
plus the stacked-k-sweep compile counts and the cluster-kernel identity
cells, the CI ``partitioner-bench`` artifact) and ``--check`` gates
RF(sharded) ≤ 1.10 · RF(np), compile-once on the stacked sweep, and
xla/pallas cluster-kernel agreement."""
from __future__ import annotations

import sys
import time

from repro.core import CLUGPConfig, partition, web_graph
from repro.core.graphgen import social_graph
from .common import quality_row

ALGOS = ["clugp", "clugp-opt", "hashing", "dbh", "greedy", "hdrf", "mint"]


def fig3_rf_vs_partitions(scale=12, ks=(4, 16, 64, 256), seed=0):
    """Fig. 3: replication factor vs #partitions, web graph."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for k in ks:
        for algo in ALGOS:
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig3_rf_web"
            rows.append(r)
    return rows


def fig4_social(scale=12, ks=(16, 64), seed=1):
    """Fig. 4: social graph (Twitter analogue) — RF + total runtime."""
    g = social_graph(n=1 << scale, m=8, seed=seed)
    rows = []
    for k in ks:
        for algo in ALGOS:
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig4_rf_social"
            rows.append(r)
    return rows


def fig5_graph_size(scales=(10, 11, 12, 13), k=16, seed=0):
    """Fig. 5: RF vs graph size (sampled)."""
    rows = []
    for s in scales:
        g = web_graph(scale=s, edge_factor=8, seed=seed)
        for algo in ("clugp-opt", "hdrf", "hashing"):
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig5_size"
            r["edges"] = g.num_edges
            rows.append(r)
    return rows


def fig6_space(scale=12, ks=(16, 64, 256), seed=0):
    """Fig. 6: resident partitioner state (bytes).  Analytic per §III-V:
    CLUGP O(2|V|) + O(m); HDRF/Greedy O(|V|·k/8) bitsets + loads;
    DBH O(|V|); Hashing O(1); Mint O(window)."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    V, E = g.num_vertices, g.num_edges
    rows = []
    for k in ks:
        m_est = partition(g.src, g.dst, g.num_vertices,
                          CLUGPConfig(k=k)).stats["num_clusters"]
        space = {
            "clugp": 8 * V + 8 * V + 8 * m_est,     # clu[] + deg[] + game
            "hashing": 0,
            "dbh": 8 * V,
            "greedy": V * ((k + 63) // 64) * 8 + 8 * V,
            "hdrf": V * ((k + 63) // 64) * 8 + 8 * V + 8 * k,
            "mint": 8 * 4096 * 4,
        }
        for algo, b in space.items():
            rows.append({"bench": "fig6_space", "algo": algo, "k": k,
                         "bytes": int(b)})
    return rows


def fig7_runtime_vs_k(scale=12, ks=(4, 16, 64, 256), seed=0):
    """Fig. 7: partitioning runtime scaling in k (µs/edge)."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for k in ks:
        for algo in ("clugp", "hashing", "dbh", "hdrf", "greedy"):
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig7_runtime"
            rows.append(r)
    return rows


def fig9_ablation(scale=12, ks=(4, 16, 64, 256), seed=0):
    """Fig. 9: splitting (CLUGP-S) and game (CLUGP-G) ablations."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for k in ks:
        for algo in ("clugp", "clugp-nosplit", "clugp-nogame"):
            r = quality_row(algo, g, k, seed)
            r["bench"] = "fig9_ablation"
            rows.append(r)
    return rows


def fig10_parallelization(scale=12, k=16, seed=0):
    """Fig. 10: (a) distributed nodes (thread analogue) sweep;
    (b) game batch-size sweep."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for nodes in (1, 2, 4, 8):
        t0 = time.time()
        res = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=k),
                        backend="np", nodes=nodes)
        rows.append({"bench": "fig10_nodes", "nodes": nodes, "k": k,
                     "rf": round(res.stats["rf"], 4),
                     "seconds": round(time.time() - t0, 4)})
    for bs in (64, 400, 1600, 6400):
        t0 = time.time()
        res = partition(g.src, g.dst, g.num_vertices,
                        CLUGPConfig(k=k, batch_size=bs))
        rows.append({"bench": "fig10_batch", "batch": bs, "k": k,
                     "rf": round(res.stats["rf"], 4),
                     "rounds": res.game_rounds,
                     "seconds": round(time.time() - t0, 4)})
    return rows


def fig12_runtime_vs_k(scale=12, ks=(16, 64, 256), seed=0,
                       backends=("np", "jit", "sharded"), nodes=4,
                       restream=0, repeats=2, unroll=1):
    """Fig. 12 (this repo): partitioner backend runtime vs k — the
    §III-C headline, the partitioner's own runtime on the mesh — driven
    through the ``GraphSession`` façade (each cell is one serializable
    session config).

    ``edge_us`` is warm time (best of ``repeats`` after one warm-up call
    that pays jit compilation; the np oracle has no compile and is timed
    directly).  ``unroll > 1`` adds an extra jit cell with the clustering
    inner-scan unrolled that much (the ROADMAP headroom knob) so
    ``trend.py`` tracks its µs/edge next to the unroll=1 baseline.  The
    sharded backend needs ``nodes`` visible devices and is skipped (with
    a stderr note) when the process has fewer — CI runs under
    XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    import jax

    from repro.session import GraphSession, SessionConfig

    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    # the np oracle runs at BOTH split widths: nodes=1 is the runtime
    # baseline and quality reference for "jit"; nodes=n is the host twin
    # of the sharded combine (a §III-C split costs RF by itself — paper
    # Fig. 10 — so "sharded" must be judged against the same-width combine)
    cells = []
    for backend in backends:
        if backend == "np":
            cells.append(("np", 1, 1))
            if nodes > 1 and "sharded" in backends:
                cells.append(("np", nodes, 1))
        else:
            cells.append((backend, nodes if backend == "sharded" else 1, 1))
    if unroll > 1 and "jit" in backends:
        cells.append(("jit", 1, unroll))
    rows = []
    for k in ks:
        np_us = None
        for backend, b_nodes, b_unroll in cells:
            if backend == "sharded" and jax.device_count() < nodes:
                print(f"fig12: skipping sharded (k={k}) — "
                      f"{jax.device_count()} devices < {nodes} nodes; "
                      f"set XLA_FLAGS=--xla_force_host_platform_"
                      f"device_count={nodes}", file=sys.stderr)
                continue
            cfg = CLUGPConfig(k=k, restream=restream, unroll=b_unroll)
            sess = GraphSession(SessionConfig(clugp=cfg, backend=backend,
                                              nodes=b_nodes))
            times = []
            if backend != "np":   # warm-up pays compilation
                sess.partition(g.src, g.dst, g.num_vertices)
            # every backend (np included) reports best-of-repeats, so the
            # trend table's never-noise treatment of edge_us stays honest
            for _ in range(repeats):
                t0 = time.time()
                sess.partition(g.src, g.dst, g.num_vertices)
                times.append(time.time() - t0)
            res = sess.result
            edge_us = 1e6 * min(times) / g.num_edges
            if (backend, b_nodes) == ("np", 1):
                np_us = edge_us
            row = {"bench": "fig12_runtime", "algo": "clugp",
                   "backend": backend, "nodes": b_nodes, "k": k,
                   "restream": restream, "unroll": b_unroll,
                   "rf": round(res.stats["rf"], 4),
                   "balance": round(res.stats["balance"], 4),
                   "edge_us": round(edge_us, 3),
                   "game_rounds": res.game_rounds}
            if np_us is not None and (backend, b_nodes) != ("np", 1):
                row["speedup_vs_np"] = round(np_us / edge_us, 2)
            rows.append(row)
    return rows


def fig12_cluster_kernels(scale=10, k=8, seed=0, repeats=2):
    """Kernel-identity cells: the SAME jit pipeline with the clustering
    inner loop on the XLA fused-scatter scan vs the Pallas fused
    table-update kernel (interpret mode off-TPU).  The two cells are
    bit-identical by construction (shared ``edge_decisions``) — asserted
    here — so the rows differ only in ``edge_us``; ``kernel`` is the
    trend identity field."""
    import numpy as np

    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows, assigns = [], {}
    for kernel in ("xla", "pallas"):
        cfg = CLUGPConfig(k=k, cluster_kernel=kernel)
        partition(g.src, g.dst, g.num_vertices, cfg, backend="jit")
        times = []
        for _ in range(repeats):
            t0 = time.time()
            res = partition(g.src, g.dst, g.num_vertices, cfg,
                            backend="jit")
            times.append(time.time() - t0)
        assigns[kernel] = res.assign
        rows.append({"bench": "fig12_kernel", "algo": "clugp",
                     "backend": "jit", "kernel": kernel, "k": k,
                     "rf": round(res.stats["rf"], 4),
                     "edge_us": round(1e6 * min(times) / g.num_edges, 3)})
    if not np.array_equal(assigns["xla"], assigns["pallas"]):
        raise AssertionError(
            "fig12_kernel: pallas and xla cluster kernels diverged")
    return rows


def fig12_sweep(scale=10, ks=(4, 8, 16), seed=0):
    """Compile-once stacked k-sweep vs per-k jit: ``partition_sweep``
    stacks every k's stage body under one ``lax.scan`` with k_max-padded
    lanes and a traced per-step k, so the whole sweep compiles once
    (+ adaptive-cap retries) while the per-k path compiles once per k
    (+ its own retries).  Rows carry the compile counts and wall-clock;
    per-k RF parity rows let the gate assert the masked-lane math did not
    move quality (measured: bit-identical to the per-k jit backend)."""
    from repro.core import partition_sweep, sweep_trace_count
    from repro.core.partitioner import _jit_body

    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    ks_tag = "+".join(str(k) for k in ks)
    rows = []

    c0 = _jit_body._cache_size()
    t0 = time.time()
    per_k = [partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=k),
                       backend="jit") for k in ks]
    t_perk = time.time() - t0
    rows.append({"bench": "fig12_sweep", "mode": "per-k", "ks": ks_tag,
                 "compiles": _jit_body._cache_size() - c0,
                 "seconds": round(t_perk, 3)})

    s0 = sweep_trace_count()
    t0 = time.time()
    swept = partition_sweep(g.src, g.dst, g.num_vertices,
                            CLUGPConfig(k=max(ks)), ks)
    t_cold = time.time() - t0
    rows.append({"bench": "fig12_sweep", "mode": "stacked-cold",
                 "ks": ks_tag, "compiles": sweep_trace_count() - s0,
                 "seconds": round(t_cold, 3)})

    s1 = sweep_trace_count()
    t0 = time.time()
    swept = partition_sweep(g.src, g.dst, g.num_vertices,
                            CLUGPConfig(k=max(ks)), ks)
    t_warm = time.time() - t0
    rows.append({"bench": "fig12_sweep", "mode": "stacked-warm",
                 "ks": ks_tag, "compiles": sweep_trace_count() - s1,
                 "seconds": round(t_warm, 3)})

    for k, r_sweep, r_jit in zip(ks, swept, per_k):
        rows.append({"bench": "fig12_sweep_rf", "ks": ks_tag, "k": k,
                     "rf": round(r_sweep.stats["rf"], 4),
                     "rf_jit": round(r_jit.stats["rf"], 4)})
    return rows


def fig11_weight_and_balance(scale=12, k=16, seed=0):
    """Fig. 11: (a) RF vs relative load balance τ; (b) RF vs relative
    weight of the two game objectives."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for tau in (1.0, 1.2, 1.5, 2.0, 3.0):
        res = partition(g.src, g.dst, g.num_vertices,
                        CLUGPConfig(k=k, tau=tau))
        rows.append({"bench": "fig11a_tau", "tau": tau, "k": k,
                     "rf": round(res.stats["rf"], 4),
                     "balance": round(res.stats["balance"], 4)})
    for w in (0.1, 0.3, 0.5, 0.7, 0.9):
        res = partition(g.src, g.dst, g.num_vertices,
                        CLUGPConfig(k=k, relative_weight=w))
        rows.append({"bench": "fig11b_weight", "weight": w, "k": k,
                     "rf": round(res.stats["rf"], 4),
                     "balance": round(res.stats["balance"], 4)})
    return rows


def interior_frontier_rows(scale=10, ks=(4, 8), seed=0) -> list[dict]:
    """Interior fraction of the built layout per k — the overlap headroom
    the interleaved GAS body hides behind the ring hops.  Interior
    vertices (single replica) compute while the k−1 ppermute hops are in
    flight; RF → 1 drives interior_frac → 1, so this trends partition
    quality from the engine's point of view, next to RF/balance."""
    from repro.graph.partition import build_layout

    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for k in ks:
        res = partition(g.src, g.dst, g.num_vertices,
                        CLUGPConfig.optimized(k))
        lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, k)
        st = lay.interior_frontier_stats()
        rows.append({"bench": "interior_frontier", "k": k, "scale": scale,
                     "rf": round(res.stats["rf"], 4),
                     "interior_frac": round(st["interior_frac"], 4),
                     "interior_frac_min": round(st["interior_frac_min"],
                                                4)})
    return rows


def _partition_artifact(args) -> int:
    """Backend sweep → results/BENCH_partition.json (+ optional gate)."""
    import json
    from pathlib import Path

    if args.tiny:
        scale, ks, nodes = 9, (4, 8), 4
    else:
        scale, ks, nodes = args.scale, tuple(args.ks), args.nodes
    rows = []
    # the sweep + kernel-identity cells run FIRST so their per-k compile
    # counts are not hidden by a cache fig12_runtime already warmed
    rows += fig12_sweep(scale=scale, ks=ks)
    rows += fig12_cluster_kernels(scale=scale, k=ks[-1])
    rows += interior_frontier_rows(scale=scale, ks=ks)
    for restream in (0, args.restream) if args.restream else (0,):
        # the unroll cell rides the restream=0 sweep only: it is a
        # lowering knob (bit-identical results), so one µs/edge row per k
        # is what trend.py needs
        rows += fig12_runtime_vs_k(scale=scale, ks=ks, nodes=nodes,
                                   restream=restream,
                                   unroll=args.unroll if restream == 0
                                   else 1)
    results = Path(__file__).resolve().parents[1] / "results"
    results.mkdir(exist_ok=True)
    out = results / "BENCH_partition.json"
    out.write_text(json.dumps(rows, indent=1))
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    print(f"wrote {out} ({len(rows)} rows)")
    if args.check:
        by_key = {(r["k"], r["restream"], r["backend"], r["nodes"],
                   r["unroll"]): r for r in rows
                  if r["bench"] == "fig12_runtime"}
        failures = []
        # compile-once gate: a warm stacked sweep must not retrace, and a
        # cold sweep must compile no more than the per-k path
        sweep = {r["mode"]: r for r in rows if r["bench"] == "fig12_sweep"}
        if not sweep:
            failures.append("fig12_sweep rows missing")
        else:
            if sweep["stacked-warm"]["compiles"] != 0:
                failures.append(
                    f"stacked k-sweep retraced on a warm repeat "
                    f"({sweep['stacked-warm']['compiles']} compiles)")
            if sweep["stacked-cold"]["compiles"] > sweep["per-k"]["compiles"]:
                failures.append(
                    f"stacked k-sweep compiled "
                    f"{sweep['stacked-cold']['compiles']}x vs "
                    f"{sweep['per-k']['compiles']}x per-k")
        for r in rows:
            if r["bench"] == "fig12_sweep_rf" \
                    and r["rf"] > r["rf_jit"] * 1.10:
                failures.append(
                    f"RF(stacked sweep, k={r['k']}) = {r['rf']} exceeds "
                    f"1.10 x RF(jit) = {r['rf_jit']}")
        kern = {r["kernel"]: r for r in rows
                if r["bench"] == "fig12_kernel"}
        if set(kern) != {"xla", "pallas"}:
            failures.append(f"fig12_kernel cells missing: have "
                            f"{sorted(kern)}")
        elif kern["xla"]["rf"] != kern["pallas"]["rf"]:
            failures.append(
                f"cluster kernels disagree on RF: xla {kern['xla']['rf']} "
                f"vs pallas {kern['pallas']['rf']}")
        for (k, rs, backend, nd, un), r in by_key.items():
            if backend == "np":
                continue
            # each device backend is judged against the np oracle run at
            # the SAME split width (the split itself costs RF — Fig. 10);
            # the oracle never unrolls (host loops have no scan)
            ref = by_key.get((k, rs, "np", nd, 1))
            if ref is None:
                continue
            if r["rf"] > ref["rf"] * 1.10:
                failures.append(
                    f"RF({backend}, k={k}, restream={rs}, nodes={nd}, "
                    f"unroll={un}) = {r['rf']} exceeds 1.10 x "
                    f"RF(np, nodes={nd}) = {ref['rf']}")
        missing = [b for b in ("np", "jit", "sharded")
                   if not any(r.get("backend") == b for r in rows)]
        if missing:
            failures.append(f"backends missing from sweep: {missing}")
        if failures:
            print("partitioner-bench gate FAILED:", file=sys.stderr)
            for f in failures:
                print(f"  {f}", file=sys.stderr)
            return 1
        print("partitioner-bench gate OK: all backends present, "
              "RF within 10% of the np oracle, the stacked k-sweep "
              "compiles once (0 warm retraces), and both cluster "
              "kernels agree")
    return 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI profile: scale-9 graph, k in (4, 8)")
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--ks", type=int, nargs="+", default=[16, 64, 256])
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--restream", type=int, default=1,
                    help="also sweep this restream depth (0 disables)")
    ap.add_argument("--unroll", type=int, default=2,
                    help="extra jit cell with the clustering inner scan "
                         "unrolled this much (1 disables)")
    ap.add_argument("--check", action="store_true",
                    help="fail unless all 3 backends ran, RF is within "
                         "10%% of the np oracle, the stacked k-sweep "
                         "compiles once (0 warm retraces), and both "
                         "cluster kernels agree bit-for-bit")
    sys.exit(_partition_artifact(ap.parse_args()))
