"""Exchange abstraction for the vertex-cut GAS engine's mirror sync.

The engine's per-iteration communication is two phases over the mirror
replicas (paper §II-B): mirror partials reduce to masters (gather), master
values broadcast back to mirrors (scatter).  This module gives the engine a
pluggable wire format for those phases:

- ``DenseExchange`` — the seed path: ``all_gather`` the full padded
  (L_max,) slab from every device and index into it with the static
  ``red_index`` / ``(owner, own_slot)`` tables.  Bytes ∝ k²·L_max per
  phase, independent of partition quality.
- ``HaloExchange`` — mirror-routed: each device packs only its mirror
  slots into per-destination lanes (``halo_send``) and a single
  ``all_to_all`` delivers every lane to its owner, which scatters via
  ``halo_recv``.  Bytes ∝ k·(k−1)·H_max per phase — within per-pair
  padding of the ideal 2·mirrors volume, so CLUGP's mirror reduction is
  the engine's real wire cost.
- ``RaggedHaloExchange`` — halo routing without the cross-pair padding:
  the padded ``all_to_all`` ships H_max lanes for *every* ordered pair,
  so one hot (p, q) cell inflates the whole collective.  The ragged
  exchange instead walks the k−1 ring distances with one ``ppermute``
  each — hop s moves every device's (p → (p+s) mod k) lanes at once,
  padded only to that distance's max population H_s (the layout's
  ``halo_schedule``, baked into the exchange instance as a static
  tuple so it jits).  Σ_s H_s ≤ (k−1)·H_max always, and the gap is the
  replication-factor skew CLUGP leaves behind — bytes land within
  per-distance padding of the ideal 2·mirrors volume.  Zero-population
  distances are skipped at trace time.
- ``RaggedQuantizedHaloExchange`` — ragged routing with a **top-Δ**
  sparsified payload: per hop the sender quantizes only the
  T_s = ⌈top_delta·H_s⌉ largest-|Δ| lanes of its error-feedback delta
  (int16 lane indices + int8 codes + one fp32 scale), leaving the rest
  in the residual for a later iteration.  As a fixed-point program
  converges its deltas concentrate, so shipping the heavy quarter per
  step loses little transient speed while cutting bytes below even the
  dense-delta quantized wire.
- ``QuantizedHaloExchange`` — halo routing with a compressed payload:
  each destination lane group quantizes to int8 codes + one fp32 max-abs
  scale (``dist.compress.quantize_rows``), cutting the per-mirror payload
  ~4× on top of the halo routing cut.  What goes on the wire is the
  **delta** against a reconstruction reference both endpoints advance in
  lockstep, with the quantization error carried in an error-feedback
  residual (1-bit-SGD style) threaded through the iteration carry — as a
  fixed-point program (pagerank) converges its deltas shrink, the scales
  shrink with them, and the reconstruction converges to the exact values
  instead of dithering at one quantization step.  ``combine="min"`` /
  integer programs (CC's label propagation) are already exact in int32, so
  they skip quantization and ship the exact halo payload.

Every backend exposes the same stateful operations (state is ``()`` for
the exact backends and a pytree of lane-shaped reference/residual arrays
for the quantized one, so it threads through ``fori_loop`` carries):

  init_state(dev, dtype, combine)                  -> state
  reduce_to_masters(partial, dev, combine, state)  -> (total, state)
  broadcast_from_masters(master, dev, combine, state) -> (values, state)
  reduce_stacked / broadcast_stacked               — same, on (k, …) stacks

``dev`` is the layout's ``device_arrays()`` pytree — per-device slices in
the shard_map forms, full (k, …) stacks in the stacked forms.  ``combine``
is ``"sum"`` (pagerank) or ``"min"`` (label propagation).  The stacked
forms model the collective with a transpose (all_to_all) / broadcast
(all_gather), so tests and host benchmarks run the identical math.

**Multi-lane (fused multi-program) operations.**  N homogeneous GAS
programs over the same layout can share one exchange per phase: values
grow a leading program axis ((N, L_max) per device), lanes become
(k, N, H_max), and ONE collective ships every program's mirror traffic —
the ``*_multi`` halves below (``init_state_multi`` /
``reduce_to_masters_multi`` / ``broadcast_from_masters_multi`` /
``reduce_stacked_multi`` / ``broadcast_stacked_multi``).  For the exact
backends the fused payload is exactly the concatenation of the separate
payloads; the quantized backend switches to the **fused wire format**:
int4 delta codes packed two-per-byte along the lane axis, with fp16
max-abs scales over 8 subgroups per (destination, program) lane row
(H_max is padded to a multiple of 8, so rows split evenly and the nibble
count is even).  Per-program, per-subgroup scales mean one hot program or
lane can't wash out another's precision — with a single scale per row the
coarse int4 grid stops being a contraction under error feedback and the
iteration plateaus instead of converging.  Halving the code width is what
makes fusing N programs genuinely cheaper than N separate quantized steps
((H/2 + 16)/(H + 4) ≈ 0.55×); the coarser int4 step is absorbed by the
same error-feedback residual, so fixed-point programs still converge to
the exact fixed point, just along a slightly longer transient.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .compress import dequantize_rows, quantize_rows


def _pad_value(combine: str, dtype) -> jnp.ndarray:
    """Identity element fed into padded send lanes; recv pads are dropped
    by the segment reduce regardless, so this only has to be shape-safe
    (and, for the quantized path, keep pad lanes exactly zero)."""
    dtype = jnp.dtype(dtype)
    if combine == "sum":
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(jnp.iinfo(dtype).max, dtype)
    return jnp.asarray(3e38, dtype)


def _segment_combine(vals, segments, num_segments: int, combine: str):
    if combine == "sum":
        return jax.ops.segment_sum(vals, segments,
                                   num_segments=num_segments)
    return jax.ops.segment_min(vals, segments, num_segments=num_segments)


def _merge(local, received, combine: str):
    if combine == "sum":
        return local + received
    return jnp.minimum(local, received)


def _pack(values, lanes, combine: str):
    """values (L_max,) → (k, H_max) send lanes; pad lanes read the
    combine identity appended at index L_max."""
    pad = jnp.full((1,), _pad_value(combine, values.dtype), values.dtype)
    return jnp.concatenate([values, pad])[lanes]


def _unpack(new_master, recv, dev):
    """Scatter received master values into this device's mirror slots
    (each valid lane targets a distinct slot; pads land in the dropped
    L_max bucket); master slots keep their local value."""
    l_max = new_master.shape[0]
    scattered = jnp.zeros((l_max + 1,), new_master.dtype).at[
        dev["halo_send"].reshape(-1)].set(recv.reshape(-1))[:l_max]
    return jnp.where(dev["is_master"], new_master, scattered)


# --------------------------------------------------- multi-lane helpers

def _pack_multi(values, lanes, combine: str):
    """values (N, L_max) → (k, N, H_max) send lanes (program axis rides
    inside each destination block, so one collective ships all N)."""
    n = values.shape[0]
    pad = jnp.full((n, 1), _pad_value(combine, values.dtype), values.dtype)
    ext = jnp.concatenate([values, pad], axis=1)        # (N, L_max+1)
    return jnp.moveaxis(ext[:, lanes], 0, 1)            # (k, N, H_max)


def _unpack_multi(new_master, recv, dev):
    """new_master (N, L_max), recv (k, N, H_max) → (N, L_max) values."""
    return jax.vmap(lambda m, r: _unpack(m, r, dev))(
        new_master, jnp.moveaxis(recv, 1, 0))


def _segment_combine_multi(recv, slots, num_segments: int, combine: str):
    """recv (k, N, H_max) lanes + shared (k, H_max) slot table →
    per-program (N, num_segments-1) reductions."""
    flat_slots = slots.reshape(-1)
    return jax.vmap(
        lambda r: _segment_combine(r.reshape(-1), flat_slots,
                                   num_segments, combine)[:num_segments - 1]
    )(jnp.moveaxis(recv, 1, 0))


_Q4MAX = 7.0
# each (destination, program) lane row splits into this many scale
# subgroups: finer groups isolate hot lanes so the coarse int4 grid stays
# a contraction under error feedback (one scale per whole row diverges),
# while 8 fp16 scales cost only 16 B per row on the wire.  h_max is
# padded to a multiple of 8 (``partition._pad_to``), so rows always
# split evenly and the nibble pack always sees an even lane count.
_NUM_SCALE_GROUPS = 8


def _quantize_groups(err):
    """int4 codes + one fp16 scale per 1/8th of the trailing lane row.

    Rows whose lane count is not a multiple of ``_NUM_SCALE_GROUPS`` are
    zero-padded up to one before grouping — pad lanes quantize to code 0
    and decoders slice them back off — so the returned codes always have
    a trailing dim divisible by 8 (and therefore even, which is what the
    nibble pack needs), whatever ``h_max`` the layout was padded to."""
    n = err.shape[-1]
    n8 = -(-n // _NUM_SCALE_GROUPS) * _NUM_SCALE_GROUPS
    if n8 != n:
        err = jnp.pad(err, [(0, 0)] * (err.ndim - 1) + [(0, n8 - n)])
    shp = err.shape
    grp = err.reshape(*shp[:-1], _NUM_SCALE_GROUPS,
                      n8 // _NUM_SCALE_GROUPS)
    amax = jnp.max(jnp.abs(grp), axis=-1)
    scales = jnp.where(amax > 0, amax / _Q4MAX, 1.0).astype(jnp.float16)
    s = jnp.maximum(scales.astype(jnp.float32), 1e-30)[..., None]
    codes = jnp.clip(jnp.round(grp / s), -_Q4MAX, _Q4MAX).astype(jnp.int8)
    return codes.reshape(shp), scales


def _dequantize_groups(codes, scales):
    """Inverse grid step; both endpoints apply the identical fp16 scales
    received on the wire, so sender/receiver references stay in lockstep."""
    shp = codes.shape
    grp = codes.reshape(*shp[:-1], _NUM_SCALE_GROUPS,
                        shp[-1] // _NUM_SCALE_GROUPS)
    return (grp.astype(jnp.float32) *
            scales.astype(jnp.float32)[..., None]).reshape(shp)


def _nibble_pack(codes):
    """int8 codes in [-7, 7], even trailing dim → two codes per byte."""
    lo = codes[..., 0::2] & 0xF
    hi = codes[..., 1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def _nibble_unpack(packed):
    """Inverse of ``_nibble_pack`` (arithmetic shifts sign-extend)."""
    lo = jnp.right_shift(jnp.left_shift(packed, 4).astype(jnp.int8), 4)
    hi = jnp.right_shift(packed, 4)
    return jnp.stack([lo, hi], axis=-1).reshape(
        *packed.shape[:-1], 2 * packed.shape[-1])


@dataclass(frozen=True)
class DenseExchange:
    """Padded all_gather mirror sync (the seed wire format)."""
    axis: str | None = None
    name = "dense"

    def init_state(self, dev, dtype, combine: str = "sum"):
        return ()

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum",
                          state=()):
        g = jax.lax.all_gather(partial, self.axis)          # (k, L_max)
        return self._reduce_flat(g.reshape(-1), dev, combine), state

    def broadcast_from_masters(self, new_master, dev, combine: str = "sum",
                               state=()):
        g = jax.lax.all_gather(new_master, self.axis)       # (k, L_max)
        return g[dev["owner"], dev["own_slot"]], state

    # -- stacked halves ((k, L_max) arrays on one device) --
    def reduce_stacked(self, partials, dev, combine: str = "sum", state=()):
        flat = partials.reshape(-1)
        return jax.vmap(
            lambda d: self._reduce_flat(flat, d, combine))(dev), state

    def broadcast_stacked(self, masters, dev, combine: str = "sum",
                          state=()):
        return jax.vmap(
            lambda d: masters[d["owner"], d["own_slot"]])(dev), state

    @staticmethod
    def _reduce_flat(flat_gathered, dev, combine: str):
        l_max = dev["vert_gid"].shape[0]
        return _segment_combine(flat_gathered, dev["red_index"],
                                l_max + 1, combine)[:l_max]

    # -- multi-lane halves (fused programs; values carry a leading N) --
    def init_state_multi(self, dev, dtype, combine: str, n: int):
        return ()

    def reduce_to_masters_multi(self, partials, dev, combine: str = "sum",
                                state=()):
        g = jax.lax.all_gather(partials, self.axis)         # (k, N, L_max)
        flat = jnp.moveaxis(g, 1, 0).reshape(g.shape[1], -1)
        return jax.vmap(
            lambda f: self._reduce_flat(f, dev, combine))(flat), state

    def broadcast_from_masters_multi(self, new_masters, dev,
                                     combine: str = "sum", state=()):
        g = jax.lax.all_gather(new_masters, self.axis)      # (k, N, L_max)
        return jax.vmap(
            lambda gn: gn[dev["owner"], dev["own_slot"]]
        )(jnp.moveaxis(g, 1, 0)), state

    def reduce_stacked_multi(self, partials, dev, combine: str = "sum",
                             state=()):
        # partials (k, N, L_max): each program reduces over its own flat
        # (k·L_max) gather, per destination device
        flat = jnp.moveaxis(partials, 1, 0).reshape(partials.shape[1], -1)
        return jnp.moveaxis(jax.vmap(
            lambda f: jax.vmap(
                lambda d: self._reduce_flat(f, d, combine))(dev)
        )(flat), 0, 1), state

    def broadcast_stacked_multi(self, masters, dev, combine: str = "sum",
                                state=()):
        per_prog = jnp.moveaxis(masters, 1, 0)              # (N, k, L_max)
        return jnp.moveaxis(jax.vmap(
            lambda m: jax.vmap(
                lambda d: m[d["owner"], d["own_slot"]])(dev)
        )(per_prog), 0, 1), state

    def bytes_per_iter(self, layout, value_bytes: int = 4) -> int:
        return layout.comm_bytes("dense", value_bytes=value_bytes)


@dataclass(frozen=True)
class HaloExchange:
    """Mirror-routed all_to_all sync over the layout's halo tables.

    Reduce: pack mirror values into (k, H_max) destination lanes, one
    all_to_all, scatter-combine received lanes into master slots, merge
    with the local partial (a master's own contribution never leaves the
    device).  Broadcast runs the same route backwards: masters pack
    ``halo_recv`` lanes, mirrors scatter via ``halo_send``; master slots
    keep their local value.
    """
    axis: str | None = None
    name = "halo"

    def init_state(self, dev, dtype, combine: str = "sum"):
        return ()

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum",
                          state=()):
        l_max = partial.shape[0]
        send = _pack(partial, dev["halo_send"], combine)
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, H_max)
        agg = _segment_combine(recv.reshape(-1),
                               dev["halo_recv"].reshape(-1),
                               l_max + 1, combine)[:l_max]
        return _merge(partial, agg, combine), state

    def broadcast_from_masters(self, new_master, dev, combine: str = "sum",
                               state=()):
        send = _pack(new_master, dev["halo_recv"], combine)
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, H_max)
        return _unpack(new_master, recv, dev), state

    # -- stacked halves: all_to_all over k virtual devices == transpose --
    def reduce_stacked(self, partials, dev, combine: str = "sum", state=()):
        l_max = partials.shape[1]
        send = jax.vmap(
            lambda v, idx: _pack(v, idx, combine)
        )(partials, dev["halo_send"])                       # (k, k, H_max)
        recv = jnp.swapaxes(send, 0, 1)

        def one(recv_q, slots_q, partial_q):
            agg = _segment_combine(recv_q.reshape(-1),
                                   slots_q.reshape(-1),
                                   l_max + 1, combine)[:l_max]
            return _merge(partial_q, agg, combine)

        return jax.vmap(one)(recv, dev["halo_recv"], partials), state

    def broadcast_stacked(self, masters, dev, combine: str = "sum",
                          state=()):
        send = jax.vmap(
            lambda v, idx: _pack(v, idx, combine)
        )(masters, dev["halo_recv"])                        # (k, k, H_max)
        recv = jnp.swapaxes(send, 0, 1)
        return jax.vmap(
            lambda m, r, d: _unpack(m, r, d)
        )(masters, recv, dev), state

    # -- multi-lane halves (fused programs; values carry a leading N) --
    def init_state_multi(self, dev, dtype, combine: str, n: int):
        return ()

    def reduce_to_masters_multi(self, partials, dev, combine: str = "sum",
                                state=()):
        l_max = partials.shape[1]
        send = _pack_multi(partials, dev["halo_send"], combine)
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, N, H_max)
        agg = _segment_combine_multi(recv, dev["halo_recv"], l_max + 1,
                                     combine)
        return _merge(partials, agg, combine), state

    def broadcast_from_masters_multi(self, new_masters, dev,
                                     combine: str = "sum", state=()):
        send = _pack_multi(new_masters, dev["halo_recv"], combine)
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, N, H_max)
        return _unpack_multi(new_masters, recv, dev), state

    def reduce_stacked_multi(self, partials, dev, combine: str = "sum",
                             state=()):
        l_max = partials.shape[2]
        send = jax.vmap(
            lambda v, idx: _pack_multi(v, idx, combine)
        )(partials, dev["halo_send"])                   # (k, k, N, H_max)
        recv = jnp.swapaxes(send, 0, 1)
        agg = jax.vmap(
            lambda r, s: _segment_combine_multi(r, s, l_max + 1, combine)
        )(recv, dev["halo_recv"])
        return _merge(partials, agg, combine), state

    def broadcast_stacked_multi(self, masters, dev, combine: str = "sum",
                                state=()):
        send = jax.vmap(
            lambda v, idx: _pack_multi(v, idx, combine)
        )(masters, dev["halo_recv"])                    # (k, k, N, H_max)
        recv = jnp.swapaxes(send, 0, 1)
        return jax.vmap(
            lambda m, r, d: _unpack_multi(m, r, d)
        )(masters, recv, dev), state

    def bytes_per_iter(self, layout, value_bytes: int = 4) -> int:
        return layout.comm_bytes("halo", value_bytes=value_bytes)


def lossy_payload(combine: str, dtype) -> bool:
    """Whether the quantized backend may delta-code a program's payload:
    only fp sum-combine values tolerate lossy codes — min-combine and
    integer payloads (CC labels) must ship exact.  The one rule the
    exchange, the dry-run byte models, and the CI gate all derive from."""
    return combine == "sum" and jnp.issubdtype(jnp.dtype(dtype),
                                               jnp.floating)


def _ef_encode_fused(lanes, sref, sres):
    """Error-feedback delta encoder for the fused (multi-program) wire:
    int4 codes nibble-packed two-per-byte along the (even) lane axis,
    fp16 scales over ``_NUM_SCALE_GROUPS`` subgroups per (destination,
    program) lane row.  Same lockstep reference/residual algebra as
    ``_ef_encode``; only the code width, scale granularity, and packing
    differ — H/2 + 16 wire bytes per row vs. the separate int8 steps'
    H + 4, the fused driver's < 0.6× byte win."""
    err = lanes - sref + sres
    codes, scales = _quantize_groups(err)
    deq = _dequantize_groups(codes, scales)[..., :err.shape[-1]]
    return sref + deq, err - deq, _nibble_pack(codes), scales


def _ef_decode_fused(packed, scales, n):
    """Unpack + dequantize a fused wire payload back to ``n`` lanes
    (the encoder may have zero-padded the row up to a multiple of 8)."""
    return _dequantize_groups(_nibble_unpack(packed), scales)[..., :n]


def _ef_encode(lanes, sref, sres):
    """Error-feedback delta encoder for one phase's send lanes.

    err = (lanes − sref) + sres is what the receiver is missing plus the
    carried quantization error; it quantizes per lane group, both
    endpoints advance their reference by the identical dequantized step
    (sref ← sref + deq), and the un-sent remainder becomes the next
    iteration's residual — so sref tracks lanes with an unbiased, shrinking
    error as the program converges."""
    err = lanes - sref + sres
    codes, scales = quantize_rows(err)
    deq = dequantize_rows(codes, scales)
    return sref + deq, err - deq, codes, scales


@dataclass(frozen=True)
class QuantizedHaloExchange:
    """Halo routing with an int8 delta-coded payload (error feedback).

    Same static lane tables as ``HaloExchange``; the wire payload per
    phase is (k, H_max) int8 codes + (k,) fp32 per-lane-group scales —
    ~4× fewer bytes than the fp32 halo lanes.  Each endpoint pair keeps a
    reconstruction reference per lane (``sref`` on the sender, ``rref``
    on the receiver) advanced in lockstep by the dequantized delta, and
    the sender carries the quantization error in ``sres`` (error
    feedback), so a converging fixed-point iteration (pagerank) lands on
    the exact fixed point instead of dithering at one quantization step.

    ``combine="min"`` / integer payloads (CC labels) are exact in int32
    already — quantizing would corrupt the min lattice — so those
    programs get the plain halo wire format (``init_state`` returns the
    empty state and every op delegates).
    """
    axis: str | None = None
    name = "quantized"

    @property
    def _exact(self) -> HaloExchange:
        return HaloExchange(axis=self.axis)

    def init_state(self, dev, dtype, combine: str = "sum"):
        if not lossy_payload(combine, dtype):
            return ()
        zeros = jnp.zeros(dev["halo_send"].shape, jnp.float32)
        lane_state = {"sref": zeros, "sres": zeros, "rref": zeros}
        return {"reduce": lane_state, "bcast": dict(lane_state)}

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum",
                          state=()):
        if not state:
            return self._exact.reduce_to_masters(partial, dev, combine,
                                                 state)
        st = state["reduce"]
        l_max = partial.shape[0]
        lanes = _pack(partial, dev["halo_send"], combine)
        sref, sres, codes, scales = _ef_encode(lanes, st["sref"],
                                               st["sres"])
        rcodes = jax.lax.all_to_all(codes, self.axis, 0, 0)   # int8 wire
        rscales = jax.lax.all_to_all(scales, self.axis, 0, 0)
        rref = st["rref"] + dequantize_rows(rcodes, rscales)
        agg = _segment_combine(rref.reshape(-1),
                               dev["halo_recv"].reshape(-1),
                               l_max + 1, combine)[:l_max]
        total = _merge(partial, agg, combine)
        return total, {**state, "reduce": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def broadcast_from_masters(self, new_master, dev, combine: str = "sum",
                               state=()):
        if not state:
            return self._exact.broadcast_from_masters(new_master, dev,
                                                      combine, state)
        st = state["bcast"]
        lanes = _pack(new_master, dev["halo_recv"], combine)
        sref, sres, codes, scales = _ef_encode(lanes, st["sref"],
                                               st["sres"])
        rcodes = jax.lax.all_to_all(codes, self.axis, 0, 0)   # int8 wire
        rscales = jax.lax.all_to_all(scales, self.axis, 0, 0)
        rref = st["rref"] + dequantize_rows(rcodes, rscales)
        values = _unpack(new_master, rref, dev)
        return values, {**state, "bcast": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    # -- stacked halves: all_to_all over k virtual devices == transpose --
    def reduce_stacked(self, partials, dev, combine: str = "sum", state=()):
        if not state:
            return self._exact.reduce_stacked(partials, dev, combine,
                                              state)
        st = state["reduce"]
        l_max = partials.shape[1]
        lanes = jax.vmap(
            lambda v, idx: _pack(v, idx, combine)
        )(partials, dev["halo_send"])                       # (k, k, H_max)
        sref, sres, codes, scales = _ef_encode(lanes, st["sref"],
                                               st["sres"])
        rref = st["rref"] + dequantize_rows(jnp.swapaxes(codes, 0, 1),
                                            jnp.swapaxes(scales, 0, 1))

        def one(rref_q, slots_q, partial_q):
            agg = _segment_combine(rref_q.reshape(-1), slots_q.reshape(-1),
                                   l_max + 1, combine)[:l_max]
            return _merge(partial_q, agg, combine)

        total = jax.vmap(one)(rref, dev["halo_recv"], partials)
        return total, {**state, "reduce": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def broadcast_stacked(self, masters, dev, combine: str = "sum",
                          state=()):
        if not state:
            return self._exact.broadcast_stacked(masters, dev, combine,
                                                 state)
        st = state["bcast"]
        lanes = jax.vmap(
            lambda v, idx: _pack(v, idx, combine)
        )(masters, dev["halo_recv"])                        # (k, k, H_max)
        sref, sres, codes, scales = _ef_encode(lanes, st["sref"],
                                               st["sres"])
        rref = st["rref"] + dequantize_rows(jnp.swapaxes(codes, 0, 1),
                                            jnp.swapaxes(scales, 0, 1))
        values = jax.vmap(
            lambda m, r, d: _unpack(m, r, d)
        )(masters, rref, dev)
        return values, {**state, "bcast": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    # -- multi-lane halves: the fused wire format (int4 packed codes) --
    def init_state_multi(self, dev, dtype, combine: str, n: int):
        if not lossy_payload(combine, dtype):
            return ()
        # program axis slots in before the lane axis, so the same state
        # pytree serves the per-device ((k, H) tables → (k, N, H) state)
        # and stacked ((k, k, H) → (k, k, N, H)) forms
        shape = dev["halo_send"].shape
        zeros = jnp.zeros((*shape[:-1], n, shape[-1]), jnp.float32)
        lane_state = {"sref": zeros, "sres": zeros, "rref": zeros}
        return {"reduce": lane_state, "bcast": dict(lane_state)}

    def reduce_to_masters_multi(self, partials, dev, combine: str = "sum",
                                state=()):
        if not state:
            return self._exact.reduce_to_masters_multi(partials, dev,
                                                       combine, state)
        st = state["reduce"]
        l_max = partials.shape[1]
        lanes = _pack_multi(partials, dev["halo_send"], combine)
        sref, sres, packed, scales = _ef_encode_fused(lanes, st["sref"],
                                                      st["sres"])
        rpacked = jax.lax.all_to_all(packed, self.axis, 0, 0)  # int4 wire
        rscales = jax.lax.all_to_all(scales, self.axis, 0, 0)
        rref = st["rref"] + _ef_decode_fused(rpacked, rscales,
                                             st["rref"].shape[-1])
        agg = _segment_combine_multi(rref, dev["halo_recv"], l_max + 1,
                                     combine)
        total = _merge(partials, agg, combine)
        return total, {**state, "reduce": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def broadcast_from_masters_multi(self, new_masters, dev,
                                     combine: str = "sum", state=()):
        if not state:
            return self._exact.broadcast_from_masters_multi(
                new_masters, dev, combine, state)
        st = state["bcast"]
        lanes = _pack_multi(new_masters, dev["halo_recv"], combine)
        sref, sres, packed, scales = _ef_encode_fused(lanes, st["sref"],
                                                      st["sres"])
        rpacked = jax.lax.all_to_all(packed, self.axis, 0, 0)  # int4 wire
        rscales = jax.lax.all_to_all(scales, self.axis, 0, 0)
        rref = st["rref"] + _ef_decode_fused(rpacked, rscales,
                                             st["rref"].shape[-1])
        values = _unpack_multi(new_masters, rref, dev)
        return values, {**state, "bcast": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def reduce_stacked_multi(self, partials, dev, combine: str = "sum",
                             state=()):
        if not state:
            return self._exact.reduce_stacked_multi(partials, dev,
                                                    combine, state)
        st = state["reduce"]
        l_max = partials.shape[2]
        lanes = jax.vmap(
            lambda v, idx: _pack_multi(v, idx, combine)
        )(partials, dev["halo_send"])                   # (k, k, N, H_max)
        sref, sres, packed, scales = _ef_encode_fused(lanes, st["sref"],
                                                      st["sres"])
        rref = st["rref"] + _ef_decode_fused(jnp.swapaxes(packed, 0, 1),
                                             jnp.swapaxes(scales, 0, 1),
                                             st["rref"].shape[-1])
        agg = jax.vmap(
            lambda r, s: _segment_combine_multi(r, s, l_max + 1, combine)
        )(rref, dev["halo_recv"])
        total = _merge(partials, agg, combine)
        return total, {**state, "reduce": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def broadcast_stacked_multi(self, masters, dev, combine: str = "sum",
                                state=()):
        if not state:
            return self._exact.broadcast_stacked_multi(masters, dev,
                                                       combine, state)
        st = state["bcast"]
        lanes = jax.vmap(
            lambda v, idx: _pack_multi(v, idx, combine)
        )(masters, dev["halo_recv"])                    # (k, k, N, H_max)
        sref, sres, packed, scales = _ef_encode_fused(lanes, st["sref"],
                                                      st["sres"])
        rref = st["rref"] + _ef_decode_fused(jnp.swapaxes(packed, 0, 1),
                                             jnp.swapaxes(scales, 0, 1),
                                             st["rref"].shape[-1])
        values = jax.vmap(
            lambda m, r, d: _unpack_multi(m, r, d)
        )(masters, rref, dev)
        return values, {**state, "bcast": {"sref": sref, "sres": sres,
                                           "rref": rref}}

    def bytes_per_iter(self, layout, value_bytes: int = 4,
                       combine: str = "sum", dtype=jnp.float32) -> int:
        # exact payloads pass through at full width; the lossy wire
        # format is fixed by quantize_rows: int8 codes + one fp32 scale
        # per lane group, whatever the value dtype was
        return layout.comm_bytes("quantized",
                                 lossy=lossy_payload(combine, dtype),
                                 value_bytes=value_bytes)


# ------------------------------------------------- ragged ring exchanges

def _scatter_last(idx, vals, n):
    """Dense (..., n) array with ``vals`` placed at ``idx`` along the
    last axis (indices within a row are distinct — top_k output)."""
    flat_i = idx.reshape(-1, idx.shape[-1])
    flat_v = vals.reshape(-1, vals.shape[-1])
    out = jax.vmap(
        lambda i, v: jnp.zeros((n,), vals.dtype).at[i].set(v)
    )(flat_i, flat_v)
    return out.reshape(*idx.shape[:-1], n)


def _row(table, i, h):
    """Traced row ``table[i, :h]`` of a (k, H_max) per-device table."""
    return jax.lax.dynamic_index_in_dim(table, i, 0, keepdims=False)[:h]


def _acc_init(shape, dtype, combine: str):
    """Hopwise reduce accumulator init: the same fill ``segment_sum`` /
    ``segment_min`` start their output buffers from, so accumulating
    hop-by-hop reproduces the deferred segment reduce bit-for-bit
    (x + 0 is exact; min against the fill is the identity)."""
    dtype = jnp.dtype(dtype)
    if combine == "sum":
        return jnp.zeros(shape, dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.full(shape, jnp.iinfo(dtype).max, dtype)
    return jnp.full(shape, jnp.inf, dtype)


def _hop_accumulate(acc, slots, recv, combine: str):
    """Fold one hop's received lanes into the running (L_max+1,) master
    accumulator the moment they land.  Valid lanes within a hop target
    distinct master slots (one source partition → distinct vertices);
    pads all target the dropped L_max bucket.  Per slot this applies at
    most one contribution per hop, in hop order — exactly the input
    order the deferred ``_segment_combine`` over the concatenated hops
    reduces in, so the two forms agree bitwise."""
    if combine == "sum":
        return acc.at[slots].add(recv)
    return acc.at[slots].min(recv)


DEFAULT_TOP_DELTA = 0.25


@dataclass(frozen=True)
class RaggedHaloExchange:
    """Mirror-routed sync over k−1 ppermute ring hops, each padded only
    to its own distance's lane population (``schedule`` — the layout's
    ``halo_schedule()``, static so the instance hashes as a jit key).

    Hop s pairs every device p with owner (p+s) mod k; lanes are packed
    at the front of each (p, q) row of the halo tables, so the prefix
    slice [:H_s] covers every real lane at that distance.  Reduce runs
    all hops, then ONE segment-combine over the concatenated received
    lanes; broadcast scatters each hop straight into the mirror slots
    (each mirror receives from exactly one owner on exactly one hop).

    ``hopwise=True`` on the reduce halves folds each hop's lanes into a
    running master accumulator the moment they arrive instead of
    deferring one big segment reduce — bit-identical output
    (``_hop_accumulate``), but every hop's recv is consumable as soon
    as its ppermute lands, which is what lets the overlapped GAS body
    (``engine._gas_body(overlap=True)``) interleave interior compute
    with the ring without lengthening the collective critical path.
    """
    axis: str | None = None
    schedule: tuple = ()
    name = "ragged"

    @property
    def k(self) -> int:
        return len(self.schedule) + 1

    def _hops(self):
        """(distance, H_s) for the populated distances only."""
        return [(s, h) for s, h in enumerate(self.schedule, 1) if h > 0]

    def init_state(self, dev, dtype, combine: str = "sum"):
        return ()

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum",
                          state=(), *, hopwise: bool = False):
        l_max = partial.shape[0]
        k = self.k
        me = jax.lax.axis_index(self.axis)
        if hopwise:
            hops = self._hops()
            if not hops:
                return partial, state
            acc = _acc_init((l_max + 1,), partial.dtype, combine)
            for s, h in hops:
                send = _pack(partial,
                             _row(dev["halo_send"], (me + s) % k, h),
                             combine)
                recv = jax.lax.ppermute(
                    send, self.axis, [(p, (p + s) % k) for p in range(k)])
                acc = _hop_accumulate(
                    acc, _row(dev["halo_recv"], (me - s) % k, h), recv,
                    combine)
            return _merge(partial, acc[:l_max], combine), state
        recvs, slots = [], []
        for s, h in self._hops():
            send = _pack(partial, _row(dev["halo_send"], (me + s) % k, h),
                         combine)
            recv = jax.lax.ppermute(
                send, self.axis, [(p, (p + s) % k) for p in range(k)])
            recvs.append(recv)
            slots.append(_row(dev["halo_recv"], (me - s) % k, h))
        if not recvs:
            return partial, state
        agg = _segment_combine(jnp.concatenate(recvs),
                               jnp.concatenate(slots),
                               l_max + 1, combine)[:l_max]
        return _merge(partial, agg, combine), state

    def broadcast_from_masters(self, new_master, dev, combine: str = "sum",
                               state=()):
        l_max = new_master.shape[0]
        k = self.k
        me = jax.lax.axis_index(self.axis)
        scattered = jnp.zeros((l_max + 1,), new_master.dtype)
        for s, h in self._hops():
            # owner q ships to mirror (q−s) mod k — the reverse route of
            # reduce hop s, so the same H_s covers it
            send = _pack(new_master,
                         _row(dev["halo_recv"], (me - s) % k, h), combine)
            recv = jax.lax.ppermute(
                send, self.axis, [(p, (p - s) % k) for p in range(k)])
            wslot = _row(dev["halo_send"], (me + s) % k, h)
            scattered = scattered.at[wslot].set(recv)
        return jnp.where(dev["is_master"], new_master,
                         scattered[:l_max]), state

    # -- stacked halves: ppermute over k virtual devices == jnp.roll --
    def reduce_stacked(self, partials, dev, combine: str = "sum", state=(),
                       *, hopwise: bool = False):
        l_max = partials.shape[1]
        ar = jnp.arange(self.k)
        if hopwise:
            hops = self._hops()
            if not hops:
                return partials, state
            acc = _acc_init((self.k, l_max + 1), partials.dtype, combine)
            for s, h in hops:
                rows = dev["halo_send"][ar, (ar + s) % self.k, :h]
                send = jax.vmap(
                    lambda v, r: _pack(v, r, combine))(partials, rows)
                recv = jnp.roll(send, s, axis=0)
                wslots = dev["halo_recv"][ar, (ar - s) % self.k, :h]
                acc = jax.vmap(
                    lambda a, sl, r: _hop_accumulate(a, sl, r, combine)
                )(acc, wslots, recv)
            return jax.vmap(
                lambda pq, a: _merge(pq, a[:l_max], combine)
            )(partials, acc), state
        recvs, slots = [], []
        for s, h in self._hops():
            rows = dev["halo_send"][ar, (ar + s) % self.k, :h]
            send = jax.vmap(
                lambda v, r: _pack(v, r, combine))(partials, rows)
            recvs.append(jnp.roll(send, s, axis=0))
            slots.append(dev["halo_recv"][ar, (ar - s) % self.k, :h])
        if not recvs:
            return partials, state
        recv_all = jnp.concatenate(recvs, axis=1)
        slot_all = jnp.concatenate(slots, axis=1)

        def one(r, sl, pq):
            agg = _segment_combine(r, sl, l_max + 1, combine)[:l_max]
            return _merge(pq, agg, combine)

        return jax.vmap(one)(recv_all, slot_all, partials), state

    def broadcast_stacked(self, masters, dev, combine: str = "sum",
                          state=()):
        l_max = masters.shape[1]
        ar = jnp.arange(self.k)
        scattered = jnp.zeros((self.k, l_max + 1), masters.dtype)
        for s, h in self._hops():
            rows = dev["halo_recv"][ar, (ar - s) % self.k, :h]
            send = jax.vmap(
                lambda v, r: _pack(v, r, combine))(masters, rows)
            recv = jnp.roll(send, -s, axis=0)
            wslots = dev["halo_send"][ar, (ar + s) % self.k, :h]
            scattered = jax.vmap(
                lambda a, w, r: a.at[w].set(r))(scattered, wslots, recv)
        return jnp.where(dev["is_master"], masters,
                         scattered[:, :l_max]), state

    # -- multi-lane halves: exact payloads concatenate, so fusing is a
    # static python loop over programs sharing each hop's route --
    def init_state_multi(self, dev, dtype, combine: str, n: int):
        return ()

    def reduce_to_masters_multi(self, partials, dev, combine: str = "sum",
                                state=(), *, hopwise: bool = False):
        outs = [self.reduce_to_masters(p, dev, combine, hopwise=hopwise)[0]
                for p in partials]
        return jnp.stack(outs), state

    def broadcast_from_masters_multi(self, new_masters, dev,
                                     combine: str = "sum", state=()):
        outs = [self.broadcast_from_masters(m, dev, combine)[0]
                for m in new_masters]
        return jnp.stack(outs), state

    def reduce_stacked_multi(self, partials, dev, combine: str = "sum",
                             state=(), *, hopwise: bool = False):
        outs = [self.reduce_stacked(p, dev, combine, hopwise=hopwise)[0]
                for p in jnp.moveaxis(partials, 1, 0)]
        return jnp.moveaxis(jnp.stack(outs), 0, 1), state

    def broadcast_stacked_multi(self, masters, dev, combine: str = "sum",
                                state=()):
        outs = [self.broadcast_stacked(m, dev, combine)[0]
                for m in jnp.moveaxis(masters, 1, 0)]
        return jnp.moveaxis(jnp.stack(outs), 0, 1), state

    def bytes_per_iter(self, layout, value_bytes: int = 4) -> int:
        return layout.comm_bytes("ragged", value_bytes=value_bytes)


@dataclass(frozen=True)
class RaggedQuantizedHaloExchange:
    """Ragged ring routing with a top-Δ sparsified error-feedback
    payload: per hop only the T_s = ⌈top_delta·H_s⌉ largest-|Δ| lanes of
    the delta ship, as int16 lane indices + int8 codes + one fp32
    max-abs scale; un-sent lanes simply stay outstanding in the
    reference gap and ship a later iteration once they dominate.
    References advance in lockstep like ``QuantizedHaloExchange``
    (``sref`` on the sender row, ``rref`` on the receiver row), but
    there is deliberately NO carried ``sres`` residual: under top-Δ
    sparsification the outstanding delta (lanes − sref) already *is*
    the residual, and a separate carry would double-count every un-sent
    lane each round (err ← 2·err — exponential divergence; the padded
    encoder tolerates the carry only because it quantizes every lane,
    which makes that recurrence contract).

    Non-lossy programs (min-combine / integer payloads) delegate to the
    exact ``RaggedHaloExchange`` wire, like the padded quantized backend
    does."""
    axis: str | None = None
    schedule: tuple = ()
    top_delta: float = DEFAULT_TOP_DELTA
    name = "ragged_quantized"

    @property
    def k(self) -> int:
        return len(self.schedule) + 1

    @property
    def _exact(self) -> RaggedHaloExchange:
        return RaggedHaloExchange(axis=self.axis, schedule=self.schedule)

    def _hops(self):
        return [(s, h) for s, h in enumerate(self.schedule, 1) if h > 0]

    def _top(self, h: int) -> int:
        return min(h, max(1, math.ceil(self.top_delta * h)))

    def init_state(self, dev, dtype, combine: str = "sum"):
        if not lossy_payload(combine, dtype):
            return ()
        # lead dims: () for the per-device (k, H_max) tables, (k,) for
        # the stacked (k, k, H_max) ones — one state pytree serves both
        lead = dev["halo_send"].shape[:-2]

        def lanes():
            return tuple({"sref": jnp.zeros((*lead, h), jnp.float32),
                          "rref": jnp.zeros((*lead, h), jnp.float32)}
                         for _, h in self._hops())

        return {"reduce": lanes(), "bcast": lanes()}

    def _encode(self, lanes, st, h):
        """Top-Δ error-feedback step for one hop: returns the advanced
        sender state and the (idx, codes, scales) wire triplet.  The
        outstanding delta is recomputed from the reference each call —
        quantization error and un-sent lanes both live in (lanes −
        sref) and need no separate carry (see the class docstring)."""
        err = lanes - st["sref"]
        t = self._top(h)
        _, idx = jax.lax.top_k(jnp.abs(err), t)
        vals = jnp.take_along_axis(err, idx, -1)
        codes, scales = quantize_rows(vals)
        deq = _scatter_last(idx, dequantize_rows(codes, scales), h)
        return ({"sref": st["sref"] + deq, "rref": st["rref"]},
                (idx.astype(jnp.int16), codes, scales))

    @staticmethod
    def _decode(ridx, rcodes, rscales, h):
        return _scatter_last(ridx.astype(jnp.int32),
                             dequantize_rows(rcodes, rscales), h)

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum",
                          state=(), *, hopwise: bool = False):
        if not state:
            return self._exact.reduce_to_masters(partial, dev, combine,
                                                 state, hopwise=hopwise)
        l_max = partial.shape[0]
        k = self.k
        me = jax.lax.axis_index(self.axis)
        acc = _acc_init((l_max + 1,), partial.dtype, combine)
        new_st, rrefs, slots = [], [], []
        for (s, h), st in zip(self._hops(), state["reduce"]):
            lanes = _pack(partial, _row(dev["halo_send"], (me + s) % k, h),
                          combine)
            st, wire = self._encode(lanes, st, h)
            perm = [(p, (p + s) % k) for p in range(k)]
            ridx, rcodes, rscales = (
                jax.lax.ppermute(w, self.axis, perm) for w in wire)
            rref = st["rref"] + self._decode(ridx, rcodes, rscales, h)
            new_st.append({**st, "rref": rref})
            slot = _row(dev["halo_recv"], (me - s) % k, h)
            if hopwise:
                # consume this hop's advanced reference immediately —
                # same per-slot contribution sequence as the deferred
                # segment reduce (see RaggedHaloExchange docstring)
                acc = _hop_accumulate(acc, slot, rref.astype(partial.dtype),
                                      combine)
            else:
                rrefs.append(rref)
                slots.append(slot)
        if not new_st:
            return partial, state
        if hopwise:
            return _merge(partial, acc[:l_max], combine), \
                {**state, "reduce": tuple(new_st)}
        agg = _segment_combine(jnp.concatenate(rrefs),
                               jnp.concatenate(slots),
                               l_max + 1, combine)[:l_max]
        return _merge(partial, agg, combine), \
            {**state, "reduce": tuple(new_st)}

    def broadcast_from_masters(self, new_master, dev, combine: str = "sum",
                               state=()):
        if not state:
            return self._exact.broadcast_from_masters(new_master, dev,
                                                      combine, state)
        l_max = new_master.shape[0]
        k = self.k
        me = jax.lax.axis_index(self.axis)
        scattered = jnp.zeros((l_max + 1,), new_master.dtype)
        new_st = []
        for (s, h), st in zip(self._hops(), state["bcast"]):
            lanes = _pack(new_master,
                          _row(dev["halo_recv"], (me - s) % k, h), combine)
            st, wire = self._encode(lanes, st, h)
            perm = [(p, (p - s) % k) for p in range(k)]
            ridx, rcodes, rscales = (
                jax.lax.ppermute(w, self.axis, perm) for w in wire)
            rref = st["rref"] + self._decode(ridx, rcodes, rscales, h)
            new_st.append({**st, "rref": rref})
            wslot = _row(dev["halo_send"], (me + s) % k, h)
            scattered = scattered.at[wslot].set(rref)
        values = jnp.where(dev["is_master"], new_master,
                           scattered[:l_max])
        return values, {**state, "bcast": tuple(new_st)}

    # -- stacked halves: ppermute over k virtual devices == jnp.roll --
    def reduce_stacked(self, partials, dev, combine: str = "sum", state=(),
                       *, hopwise: bool = False):
        if not state:
            return self._exact.reduce_stacked(partials, dev, combine,
                                              state, hopwise=hopwise)
        l_max = partials.shape[1]
        ar = jnp.arange(self.k)
        acc = _acc_init((self.k, l_max + 1), partials.dtype, combine)
        new_st, rrefs, slots = [], [], []
        for (s, h), st in zip(self._hops(), state["reduce"]):
            rows = dev["halo_send"][ar, (ar + s) % self.k, :h]
            lanes = jax.vmap(
                lambda v, r: _pack(v, r, combine))(partials, rows)
            st, wire = self._encode(lanes, st, h)
            ridx, rcodes, rscales = (jnp.roll(w, s, axis=0) for w in wire)
            rref = st["rref"] + self._decode(ridx, rcodes, rscales, h)
            new_st.append({**st, "rref": rref})
            wslots = dev["halo_recv"][ar, (ar - s) % self.k, :h]
            if hopwise:
                acc = jax.vmap(
                    lambda a, sl, r: _hop_accumulate(a, sl, r, combine)
                )(acc, wslots, rref.astype(partials.dtype))
            else:
                rrefs.append(rref)
                slots.append(wslots)
        if not new_st:
            return partials, state
        if hopwise:
            return jax.vmap(
                lambda pq, a: _merge(pq, a[:l_max], combine)
            )(partials, acc), {**state, "reduce": tuple(new_st)}
        recv_all = jnp.concatenate(rrefs, axis=1)
        slot_all = jnp.concatenate(slots, axis=1)

        def one(r, sl, pq):
            agg = _segment_combine(r, sl, l_max + 1, combine)[:l_max]
            return _merge(pq, agg, combine)

        return jax.vmap(one)(recv_all, slot_all, partials), \
            {**state, "reduce": tuple(new_st)}

    def broadcast_stacked(self, masters, dev, combine: str = "sum",
                          state=()):
        if not state:
            return self._exact.broadcast_stacked(masters, dev, combine,
                                                 state)
        l_max = masters.shape[1]
        ar = jnp.arange(self.k)
        scattered = jnp.zeros((self.k, l_max + 1), masters.dtype)
        new_st = []
        for (s, h), st in zip(self._hops(), state["bcast"]):
            rows = dev["halo_recv"][ar, (ar - s) % self.k, :h]
            lanes = jax.vmap(
                lambda v, r: _pack(v, r, combine))(masters, rows)
            st, wire = self._encode(lanes, st, h)
            ridx, rcodes, rscales = (jnp.roll(w, -s, axis=0) for w in wire)
            rref = st["rref"] + self._decode(ridx, rcodes, rscales, h)
            new_st.append({**st, "rref": rref})
            wslots = dev["halo_send"][ar, (ar + s) % self.k, :h]
            scattered = jax.vmap(
                lambda a, w, r: a.at[w].set(r))(scattered, wslots, rref)
        values = jnp.where(dev["is_master"], masters,
                           scattered[:, :l_max])
        return values, {**state, "bcast": tuple(new_st)}

    # -- multi-lane halves: per-program states, shared hop routes --
    def init_state_multi(self, dev, dtype, combine: str, n: int):
        if not lossy_payload(combine, dtype):
            return ()
        return tuple(self.init_state(dev, dtype, combine)
                     for _ in range(n))

    def reduce_to_masters_multi(self, partials, dev, combine: str = "sum",
                                state=(), *, hopwise: bool = False):
        if not state:
            return self._exact.reduce_to_masters_multi(
                partials, dev, combine, state, hopwise=hopwise)
        outs, sts = [], []
        for p, st in zip(partials, state):
            o, ns = self.reduce_to_masters(p, dev, combine, st,
                                           hopwise=hopwise)
            outs.append(o)
            sts.append(ns)
        return jnp.stack(outs), tuple(sts)

    def broadcast_from_masters_multi(self, new_masters, dev,
                                     combine: str = "sum", state=()):
        if not state:
            return self._exact.broadcast_from_masters_multi(
                new_masters, dev, combine, state)
        outs, sts = [], []
        for m, st in zip(new_masters, state):
            o, ns = self.broadcast_from_masters(m, dev, combine, st)
            outs.append(o)
            sts.append(ns)
        return jnp.stack(outs), tuple(sts)

    def reduce_stacked_multi(self, partials, dev, combine: str = "sum",
                             state=(), *, hopwise: bool = False):
        if not state:
            return self._exact.reduce_stacked_multi(
                partials, dev, combine, state, hopwise=hopwise)
        outs, sts = [], []
        for p, st in zip(jnp.moveaxis(partials, 1, 0), state):
            o, ns = self.reduce_stacked(p, dev, combine, st,
                                        hopwise=hopwise)
            outs.append(o)
            sts.append(ns)
        return jnp.moveaxis(jnp.stack(outs), 0, 1), tuple(sts)

    def broadcast_stacked_multi(self, masters, dev, combine: str = "sum",
                                state=()):
        if not state:
            return self._exact.broadcast_stacked_multi(masters, dev,
                                                       combine, state)
        outs, sts = [], []
        for m, st in zip(jnp.moveaxis(masters, 1, 0), state):
            o, ns = self.broadcast_stacked(m, dev, combine, st)
            outs.append(o)
            sts.append(ns)
        return jnp.moveaxis(jnp.stack(outs), 0, 1), tuple(sts)

    def bytes_per_iter(self, layout, value_bytes: int = 4,
                       combine: str = "sum", dtype=jnp.float32) -> int:
        return layout.comm_bytes("ragged_quantized",
                                 lossy=lossy_payload(combine, dtype),
                                 top_delta=self.top_delta,
                                 value_bytes=value_bytes)


EXCHANGES = {"dense": DenseExchange, "halo": HaloExchange,
             "quantized": QuantizedHaloExchange,
             "ragged": RaggedHaloExchange,
             "ragged_quantized": RaggedQuantizedHaloExchange}

# the ONE list of valid wire-format names — session / dryrun /
# benchmarks / argparse choices all resolve through this instead of
# re-spelling the five names
EXCHANGE_NAMES = tuple(EXCHANGES)

# the ragged wire formats need the layout's static per-distance schedule
RAGGED_EXCHANGES = ("ragged", "ragged_quantized")


def get_exchange(name: str, layout=None, *, axis: str | None = None,
                 top_delta: float | None = None):
    """Exchange registry: ``name`` ∈ ``EXCHANGE_NAMES``; ``axis`` is the
    mesh axis for the shard_map halves (stacked halves ignore it).  The
    ragged wire formats additionally need ``layout`` — their static
    per-distance lane schedule (``layout.halo_schedule()``) is baked
    into the (hashable) instance so it can key jit caches.
    ``top_delta`` tunes the ragged-quantized sparsification fraction."""
    if name not in EXCHANGES:
        raise ValueError(
            f"unknown exchange {name!r}; expected one of "
            f"{sorted(EXCHANGE_NAMES)}")
    if name in RAGGED_EXCHANGES:
        if layout is None:
            raise ValueError(
                f"exchange {name!r} needs layout= for its static "
                "per-distance lane schedule (layout.halo_schedule())")
        schedule = tuple(int(h) for h in layout.halo_schedule())
        if name == "ragged":
            return RaggedHaloExchange(axis=axis, schedule=schedule)
        return RaggedQuantizedHaloExchange(
            axis=axis, schedule=schedule,
            top_delta=DEFAULT_TOP_DELTA if top_delta is None else top_delta)
    return EXCHANGES[name](axis=axis)
