"""Benchmark trend report: diff a fresh BENCH_*.json against the previous
run's artifact and print a delta table (ROADMAP open item — CI uploads
BENCH_*.json per PR; this script makes regressions visible in the job
summary).

    python benchmarks/trend.py --old prev_bench --new results [--summary]

``--old`` / ``--new`` accept either a BENCH_*.json file or a directory to
scan for one.  Rows are keyed by their non-numeric fields (bench, algo,
exchange, …); numeric fields are diffed.  A missing previous artifact is
not an error (first run on a branch): the script prints a note and exits 0.
With ``--summary`` the markdown table is also appended to
``$GITHUB_STEP_SUMMARY`` when that variable is set.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

# relative change below which a delta is noise, and above which a row is
# flagged; wall-time rows jitter on shared CI runners, and error-magnitude
# columns (max_err ~1e-8) jitter at float noise, so neither gets flagged
REL_EPS = 0.02
FLAG_REL = 0.25
NOISE_HINTS = ("seconds", "_s", "us_per", "runtime", "err")
FLAG_ABS_FLOOR = 1e-6
# fields where bigger is better — flag polarity inverts (drop → ⚠)
GOOD_UP_HINTS = ("speedup",)
# bytes/iter and mirror-count columns are the paper's headline quantity:
# lower is better (the default polarity), and they are never noise — a
# byte regression must always surface in the delta table, even though
# "mirrors" etc. would otherwise be eligible for future noise hints.
# "edge_us" is the partitioner-backend runtime column (BENCH_partition):
# unlike the legacy wall-time columns it is a best-of-N warm measurement
# and the artifact's whole point, so it diffs lower-is-better instead of
# hiding as noise; "us_per_edge" is its kernel-cell twin
# (kernel_cluster_scatter / fig12 kernel-identity rows), and "compiles"
# counts jit compilations of the stacked k-sweep — fewer is the whole
# point of compile-once batching
GOOD_DOWN_HINTS = ("bytes", "_mb", "comm", "mirrors", "edge_us",
                   "us_per_edge", "compiles", "query_ms", "rf_",
                   "findings", "allowlisted", "violations", "errors")
# "findings"/"allowlisted"/"violations"/"errors" are the static-analysis
# artifact's per-rule counts (results/ANALYSIS.json): the allowlist's
# burn-down contract makes them lower-is-better and never-noise — any
# increase is a regression the CI diff must flag, not jitter
# "query_ms" is the serve artifact's per-query latency (best-effort warm
# measurement, the row's whole point — diffs lower-is-better instead of
# hiding as noise) and "rf_" its replication watermarks (rf_base /
# rf_drifted / rf_post_restream): a quality regression in the serving
# drift/repair path must always surface
# numeric fields that identify a row rather than measure it — part of the
# match key, never diffed (fig3/fig7 emit one row per k with identical
# string fields, so k etc. must disambiguate; "program"/"fused" key the
# graph dry-run's per-program matrix rows and its fused-bundle row, so a
# byte move on one program never aliases another's; "kernel" keys the
# cluster-scatter / game kernel-identity cells)
IDENTITY_FIELDS = ("k", "scale", "iters", "seed", "shards", "E", "K",
                   "n_nodes", "exchange", "nodes", "restream", "backend",
                   "unroll", "program", "fused", "kernel", "window",
                   "overlap", "warm", "tol")
# identity fields added after a baseline was recorded get a default, so
# pre-existing artifacts (rows without the key) still match their
# successors instead of degenerating into removed-row/new-row noise
# ("overlap"/"tol" key the dryrun overlap and early-exit cells, "warm"
# the serve artifact's post-ingest cold/warm pair)
IDENTITY_DEFAULTS = {"unroll": 1, "fused": False, "kernel": "xla",
                     "overlap": False, "warm": False, "tol": None}


def find_bench(path: str) -> Path | None:
    p = Path(path)
    if p.is_file():
        return p
    if p.is_dir():
        cands = sorted(list(p.rglob("BENCH_*.json"))
                       + list(p.rglob("ANALYSIS.json")),
                       key=lambda f: f.stat().st_mtime)
        if cands:
            return cands[-1]
        legacy = p / "bench.json"
        if legacy.exists():
            return legacy
    return None


def row_key(row: dict) -> tuple:
    # identity numerics + scalar non-numerics; nested structures (e.g. the
    # dryrun rows' per-device collective-byte dicts) are unhashable and
    # not identity, so they stay out of the key
    items = {k: v for k, v in row.items()
             if k in IDENTITY_FIELDS or isinstance(v, (str, bool))}
    for k, default in IDENTITY_DEFAULTS.items():
        items.setdefault(k, default)
    return tuple(sorted(items.items()))


def numeric_fields(row: dict) -> dict:
    return {k: v for k, v in row.items()
            if k not in IDENTITY_FIELDS
            and isinstance(v, (int, float)) and not isinstance(v, bool)}


def is_noise_field(name: str) -> bool:
    if any(h in name for h in GOOD_DOWN_HINTS + GOOD_UP_HINTS):
        return False
    return any(h in name for h in NOISE_HINTS)


def row_label(row: dict) -> str:
    return " ".join(f"{k}={v}" if k in IDENTITY_FIELDS else str(v)
                    for k, v in
                    sorted((k, v) for k, v in row.items()
                           if isinstance(v, (str, bool))
                           or k in IDENTITY_FIELDS))


def diff_rows(old_rows: list[dict], new_rows: list[dict]) -> list[dict]:
    old_by_key = {row_key(r): r for r in old_rows}
    new_keys = {row_key(r) for r in new_rows}
    out = []
    for key, prev in old_by_key.items():
        if key not in new_keys:   # coverage shrank — say so
            out.append({"label": row_label(prev) or str(key),
                        "field": "(removed row)", "old": None,
                        "new": None, "rel": None, "flag": "gone"})
    for row in new_rows:
        key = row_key(row)
        prev = old_by_key.get(key)
        label = row_label(row)
        if prev is None:
            out.append({"label": label or str(key), "field": "(new row)",
                        "old": None, "new": None, "rel": None,
                        "flag": "new"})
            continue
        for field, new_v in numeric_fields(row).items():
            if is_noise_field(field):
                # timing / float-error columns jitter on shared runners
                # (+15%..+476% observed run-to-run) and would bury every
                # substantive delta; they stay in the artifacts only
                continue
            old_v = prev.get(field)
            if not isinstance(old_v, (int, float)) \
                    or isinstance(old_v, bool):
                continue
            denom = max(abs(old_v), 1e-12)
            rel = (new_v - old_v) / denom
            if abs(rel) < REL_EPS:
                continue
            flag = ""
            if abs(rel) >= FLAG_REL \
                    and max(abs(old_v), abs(new_v)) >= FLAG_ABS_FLOOR:
                worse = rel < 0 if any(h in field for h in GOOD_UP_HINTS) \
                    else rel > 0
                flag = "⚠" if worse else "✓"
            out.append({"label": label or str(key), "field": field,
                        "old": old_v, "new": new_v, "rel": rel,
                        "flag": flag})
    return out


def fmt_table(deltas: list[dict], old_name: str, new_name: str) -> str:
    lines = [f"### Benchmark trend: `{new_name}` vs `{old_name}`", ""]
    if not deltas:
        lines.append("No numeric field moved by more than "
                     f"{REL_EPS:.0%} — benchmarks are flat.")
        return "\n".join(lines)
    lines += ["| row | field | old | new | Δ | |",
              "|---|---|---:|---:|---:|---|"]
    for d in deltas:
        if d["field"] in ("(new row)", "(removed row)"):
            lines.append(f"| {d['label']} | *{d['field']}* | — | — | — | |")
            continue
        lines.append(
            f"| {d['label']} | {d['field']} | {d['old']:g} | "
            f"{d['new']:g} | {d['rel']:+.1%} | {d['flag']} |")
    lines += ["", f"(noise gate {REL_EPS:.0%}; ⚠/✓ flags moves ≥ "
                  f"{FLAG_REL:.0%}; timing/error columns omitted — see "
                  f"the artifacts)"]
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--old", required=True,
                    help="previous BENCH_*.json (file or dir to scan)")
    ap.add_argument("--new", required=True,
                    help="fresh BENCH_*.json (file or dir to scan)")
    ap.add_argument("--summary", action="store_true",
                    help="also append to $GITHUB_STEP_SUMMARY if set")
    args = ap.parse_args()

    new_f = find_bench(args.new)
    if new_f is None:
        print(f"trend: no BENCH_*.json under {args.new}", file=sys.stderr)
        return 1
    old_f = find_bench(args.old)
    if old_f is None:
        txt = (f"### Benchmark trend\n\nno previous artifact under "
               f"`{args.old}` — nothing to diff (first run?)")
    else:
        try:
            deltas = diff_rows(json.loads(old_f.read_text()),
                               json.loads(new_f.read_text()))
            txt = fmt_table(deltas, old_f.name, new_f.name)
        except (json.JSONDecodeError, TypeError, AttributeError) as e:
            # a corrupt / partially-downloaded artifact must not fail the
            # job (the fresh artifact still needs to upload as baseline)
            txt = (f"### Benchmark trend\n\ncould not diff against "
                   f"`{old_f}`: {type(e).__name__}: {e}")
    print(txt)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if args.summary and summary:
        with open(summary, "a") as fh:
            fh.write(txt + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
