"""Pass 2 — game-theoretic cluster partitioning (paper §V, Alg. 3).

Each cluster is a selfish player choosing one of k partitions to minimize

    φ(a_i) = (λ/k)·|c_i|·|a_i|  +  ½·(|e(c_i, V\\a_i)| + |e(V\\a_i, c_i)|)

This is an exact potential game (Thm 4) with potential

    Φ(Λ)  = (λ/2k)·Σ|p_i|²  +  ½·Σ|e(p_i, V\\p_i)|

so sequential best response converges to a Nash equilibrium; the paper
parallelizes by batching clusters (contiguous IDs — BFS locality, §V-D) and
running batches concurrently against a shared snapshot.  We reproduce both:
``best_response_rounds`` (host, vectorized-Jacobi-within-batch /
Gauss–Seidel-across-batches) and a jitted JAX variant used by shard_map
(one batch per device) and by the Pallas ``game_bestresponse`` kernel.

λ defaults to its maximum feasible value (Thm 5), the paper's §VI setting:
    λ_max = k²·Σ|e(c_i, V\\c_i)|  /  (Σ|c_i|)²
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import scipy.sparse as sp

import jax
import jax.numpy as jnp


@dataclass
class ClusterGraph:
    """Contracted graph: vertices = clusters."""
    sizes: np.ndarray          # |c_i| = intra-cluster edge counts, int64[m]
    adj: sp.csr_matrix         # symmetrized inter-cluster edge counts, m×m
    vertex_cluster: np.ndarray  # original vertex -> cluster id
    m: int

    @property
    def total_cut_capacity(self) -> int:
        """Σ_i |e(c_i, V\\c_i)| — Thm 5/6 constant (each directed cross edge
        counted once per incident cluster, i.e. adj.sum() counts it twice
        after symmetrization... adj already = W + Wᵀ so row sums are it)."""
        return int(self.adj.sum()) // 1  # Σ_i row_sum = Σ_i |e(c_i,·)|+|e(·,c_i)|


def contract(src: np.ndarray, dst: np.ndarray, clu: np.ndarray) -> ClusterGraph:
    """Build the cluster multigraph from the vertex→cluster table."""
    cs, cd = clu[src], clu[dst]
    m = int(clu.max()) + 1 if clu.size else 0
    intra = cs == cd
    sizes = np.bincount(cs[intra], minlength=m).astype(np.int64)
    xs, xd = cs[~intra], cd[~intra]
    w = np.ones(xs.shape[0], dtype=np.int64)
    W = sp.coo_matrix((w, (xs, xd)), shape=(m, m)).tocsr()
    S = (W + W.T).tocsr()
    S.sum_duplicates()
    return ClusterGraph(sizes, S, clu, m)


def lambda_max(cg: ClusterGraph, k: int) -> float:
    """Thm 5 upper end of the feasible λ range (paper's default)."""
    total_sizes = float(cg.sizes.sum())
    if total_sizes <= 0:
        return 1.0
    # Σ_i |e(c_i,V\c_i)| with both directions = adj row sums / but each
    # directed edge contributes to exactly two clusters' boundaries; the
    # paper's Σ counts per-cluster boundary edges, i.e. adj.sum()/2 per
    # direction pair — use the symmetric total/2 (per-cluster out+in)/2.
    total_cut = float(cg.adj.sum()) / 2.0
    return (k * k) * total_cut / (total_sizes * total_sizes)


def lambda_from_weight(cg: ClusterGraph, k: int, weight: float) -> float:
    """Relative-weight parameterization (paper Fig. 11b): weight∈(0,1) is
    the share of the load-balance term; 0.5 ⇒ the Eq. 15 equal-importance
    setting scaled so both terms match at a uniform random assignment."""
    total_sizes = float(cg.sizes.sum())
    total_cut = float(cg.adj.sum()) / 2.0
    if total_sizes <= 0 or total_cut <= 0:
        return 1.0
    base = k * total_cut / (total_sizes * total_sizes / k)
    w = min(max(weight, 1e-3), 1 - 1e-3)
    return base * (w / (1 - w))


@dataclass
class GameResult:
    assign: np.ndarray         # cluster -> partition, int32[m]
    rounds: int
    potential_trace: list
    moves: int


def potential(cg: ClusterGraph, assign: np.ndarray, k: int,
              lam: float) -> float:
    """Φ(Λ) (Definition 4)."""
    loads = np.bincount(assign, weights=cg.sizes, minlength=k)
    load_term = lam / (2.0 * k) * float((loads ** 2).sum())
    A = cg.adj.tocoo()
    cross = float(A.data[assign[A.row] != assign[A.col]].sum()) / 2.0
    # cross counts each undirected-symmetrized pair once ⇒ Σ_p |e(p,V\p)| =
    # (directed cross edges) = cross  (adj = W+Wᵀ, /2 restores W totals)
    return load_term + 0.5 * cross


def global_cost(cg: ClusterGraph, assign: np.ndarray, k: int,
                lam: float) -> float:
    """φ(Λ) (Eq. 10)."""
    loads = np.bincount(assign, weights=cg.sizes, minlength=k)
    load_term = lam / k * float((loads ** 2).sum())
    A = cg.adj.tocoo()
    cross = float(A.data[assign[A.row] != assign[A.col]].sum()) / 2.0
    return load_term + cross


def best_response_rounds(cg: ClusterGraph, k: int, lam: float | None = None,
                         batch_size: int | None = None,
                         max_rounds: int = 64, seed: int = 0,
                         track_potential: bool = False,
                         base_loads: np.ndarray | None = None) -> GameResult:
    """Alg. 3 with the paper's §V-D batching.

    Batches are the parallel unit (one per thread/device).  A batch plays
    *sequentially* (Gauss–Seidel) against the live load table; the cut-mass
    table ``A`` is refreshed per batch (threads see a per-batch snapshot of
    other players' choices — the paper's shared-nothing approximation).
    ``batch_size=None`` ⇒ one batch = fully sequential best response with a
    guaranteed monotone potential (exact potential game, Thm 4).

    ``base_loads`` adds exogenous per-partition load (used by the Mint-like
    baseline's sliding window and by the distributed pipeline where other
    nodes' loads are synced in).
    """
    m = cg.m
    if m == 0:
        return GameResult(np.zeros(0, np.int32), 0, [], 0)
    if lam is None:
        lam = lambda_max(cg, k)
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, k, size=m).astype(np.int64)   # Alg.3 line 2
    sizes = cg.sizes.astype(np.float64)
    loads = np.bincount(assign, weights=sizes, minlength=k)
    if base_loads is not None:
        loads = loads + base_loads.astype(np.float64)
    S = cg.adj.astype(np.float64)
    indptr, indices, data = S.indptr, S.indices, S.data
    row_tot = np.asarray(S.sum(axis=1)).ravel().astype(np.float64)
    if batch_size is None:
        batch_size = m
    trace = []
    total_moves = 0
    ar = np.arange(k)
    for rnd in range(max_rounds):
        moved = 0
        for lo in range(0, m, batch_size):
            hi = min(m, lo + batch_size)
            for i in range(lo, hi):          # Gauss–Seidel sweep (live state)
                sz = sizes[i]
                cur = assign[i]
                nbrs = indices[indptr[i]:indptr[i + 1]]
                w = data[indptr[i]:indptr[i + 1]]
                # cut mass into each partition: A[p] = Σ_{j: a_j=p} S[i,j]
                aff = np.bincount(assign[nbrs], weights=w, minlength=k)
                loads_ex = loads - sz * (ar == cur)
                cost = (lam / k) * sz * (loads_ex + sz) \
                    + 0.5 * (row_tot[i] - aff)
                best = int(np.argmin(cost))
                if cost[best] + 1e-9 < cost[cur]:
                    loads[cur] -= sz
                    loads[best] += sz
                    assign[i] = best
                    moved += 1
        total_moves += moved
        if track_potential:
            trace.append(potential(cg, assign, k, lam))
        if moved == 0:
            return GameResult(assign.astype(np.int32), rnd + 1, trace,
                              total_moves)
    return GameResult(assign.astype(np.int32), max_rounds, trace, total_moves)


def greedy_assign(cg: ClusterGraph, k: int) -> np.ndarray:
    """CLUGP-G ablation (§VI-B): big clusters → least-loaded partitions."""
    order = np.argsort(-cg.sizes)
    loads = np.zeros(k, dtype=np.int64)
    assign = np.zeros(cg.m, dtype=np.int32)
    for c in order:
        p = int(np.argmin(loads))
        assign[c] = p
        loads[p] += int(cg.sizes[c])
    return assign


# ---------------------------------------------------------------------------
# JAX batched best-response round (dense adjacency) — jit/shard_map building
# block; the Pallas kernel in repro.kernels.game_bestresponse implements the
# same contraction with CSR tiles.
# ---------------------------------------------------------------------------

def jax_best_response_round(S, sizes, assign, loads, k: int, lam: float,
                            batch_slice=None):
    """One Jacobi batch update.  S: dense (b, m) adjacency rows of the batch,
    sizes: (b,), assign_all: (m,), loads: (k,). Returns new batch assign."""
    onehot = jax.nn.one_hot(assign, k, dtype=S.dtype)         # (m, k)
    A = S @ onehot                                            # (b, k)
    row_tot = S.sum(axis=1, keepdims=True)
    if batch_slice is None:
        cur = assign
        sz = sizes[:, None]
    else:
        cur = jax.lax.dynamic_slice_in_dim(assign, batch_slice, S.shape[0])
        sz = jax.lax.dynamic_slice_in_dim(sizes, batch_slice, S.shape[0])[:, None]
    loads_ex = loads[None, :] - sz * jax.nn.one_hot(cur, k, dtype=S.dtype)
    cost = (lam / k) * sz * (loads_ex + sz) + 0.5 * (row_tot - A)
    return jnp.argmin(cost, axis=1).astype(jnp.int32)
