"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16e top-2, Mamba:attn 7:1 interleave (1 attn per 8-layer
period, MoE every 2nd layer).  [arXiv:2403.19887; hf]"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, every=2),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128),
    attn_period=8, attn_index=3, sub_quadratic=True,
)
