"""Training launcher: ``python -m repro.launch.train --arch stablelm-1.6b
--steps 200 --reduced`` — end-to-end driver (data → train_step → ckpt/FT).

On this CPU container use --reduced (or --d-model etc. overrides); on a
real cluster drop --reduced and point --mesh at the pod slice.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, batch_at
from repro.dist.ft import FTConfig, run as ft_run
from repro.models import init_params
from repro.train import (cosine_schedule, get_optimizer, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8-quantize gradients before the optimizer "
                         "(repro.dist.compress); measure the collective-"
                         "byte delta with launch.dryrun --compress-grads")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model)

    params = init_params(cfg, jax.random.key(args.seed))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    sched = cosine_schedule(args.lr, warmup=args.steps // 10,
                            total=args.steps)
    opt = get_optimizer(args.optimizer, schedule=sched)
    opt_state = opt.init(params)
    compress_fn = None
    if args.compress_grads:
        from repro.dist.compress import make_grad_compressor
        compress_fn = make_grad_compressor()
    step_fn = jax.jit(make_train_step(
        cfg, opt, dtype=jnp.float32, micro_batches=args.micro_batches,
        block_kv=max(32, args.seq // 4), loss_chunk=max(32, args.seq // 4),
        compress_grads=compress_fn))

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)

    def data_fn(step):
        b = batch_at(dcfg, step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                  resume=args.resume)
    t0 = time.time()
    params, opt_state, losses, state = ft_run(
        step_fn, params, opt_state, data_fn, args.steps, ft,
        log_every=args.log_every)
    dt = time.time() - t0
    if not losses:
        print(f"already complete at step {state.step} "
              f"(restored checkpoint); nothing to do")
        return
    print(f"done: {len(losses)} steps in {dt:.1f}s  "
          f"loss {losses[0]:.3f} → {losses[-1]:.3f}  "
          f"stragglers={state.stragglers}")
    if state.restarts == 0:
        # a resumed tail can be a handful of near-converged steps whose
        # loss noise defeats this check; only gate from-scratch runs
        assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
