"""Compare all partitioners across k — a minified Fig. 3/7.

    PYTHONPATH=src python examples/partition_compare.py
"""
from benchmarks.common import quality_row
from repro.core import web_graph

g = web_graph(scale=12, edge_factor=8, seed=0)
print(f"web graph: |V|={g.num_vertices} |E|={g.num_edges}")
print(f"{'algo':12s} {'k':>4s} {'RF':>8s} {'balance':>8s} {'µs/edge':>9s}")
for k in (4, 16, 64):
    for algo in ("clugp", "clugp-opt", "hashing", "dbh", "greedy", "hdrf",
                 "mint"):
        r = quality_row(algo, g, k)
        print(f"{r['algo']:12s} {r['k']:>4d} {r['rf']:>8.3f} "
              f"{r['balance']:>8.3f} {r['us_per_edge']:>9.2f}")
