"""Named-axis collective helpers — the one door mesh reductions go through.

The ROADMAP guardrail says mesh-facing code routes through ``repro.dist``,
not raw ``jax.lax`` collectives, and ``repro.analysis``'s RAW-COLLECTIVE
lint rule machine-checks it: outside this package, ``lax.psum`` & co. are
findings.  These helpers are the sanctioned spelling.  They all take
``axis=None`` to mean "no mesh" and degrade to the single-host identity,
which is exactly the ``jax.lax.psum(x, axis) if axis is not None else x``
pattern the engine/game/transform call sites used to hand-roll — the
stacked simulators and the shard_map production path share one body and
differ only in whether an axis is bound.

Wire-shaping collectives (all_to_all routing tables, ppermute rings,
quantized payloads) live in ``repro.dist.halo`` behind the exchange
registry; this module only carries the axis-wide reductions and index
helpers that appear inside shared jit/shard_map bodies.
"""
from __future__ import annotations

import jax


def psum(x, axis: str | None = None):
    """Sum ``x`` across the mesh ``axis``; identity when ``axis`` is None
    (the stacked/single-host form of the same body)."""
    return jax.lax.psum(x, axis) if axis is not None else x


def pmax(x, axis: str | None = None):
    """Max of ``x`` across the mesh ``axis``; identity when unbound."""
    return jax.lax.pmax(x, axis) if axis is not None else x


def pmin(x, axis: str | None = None):
    """Min of ``x`` across the mesh ``axis``; identity when unbound."""
    return jax.lax.pmin(x, axis) if axis is not None else x


def axis_index(axis: str):
    """This device's position along ``axis`` (for per-device seeding)."""
    return jax.lax.axis_index(axis)
