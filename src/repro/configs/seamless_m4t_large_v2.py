"""seamless-m4t-large-v2 [audio]: enc-dec 24L+24L d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206; the speech frontend is a STUB — input_specs
provides precomputed frame embeddings.  [arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_encoder_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=8192, vocab=256206, head_dim=64, norm="layernorm",
    prefix_tokens=0,
)
