"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness; plus a decode step."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import (decode_step, forward_train, init_cache,
                          init_params)


def make_batch(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    if cfg.family == "encdec":
        half = S // 2
        return {"src_embeds": jnp.asarray(
                    rng.normal(size=(B, half, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (B, half)), jnp.int32),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab, (B, half)), jnp.int32)}
    if cfg.family == "vlm":
        P = cfg.prefix_tokens
        return {"prefix_embeds": jnp.asarray(
                    rng.normal(size=(B, P, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (B, S - P)), jnp.int32),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    return {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_train(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    loss = forward_train(params, batch, cfg, dtype=jnp.float32,
                         block_kv=16, loss_chunk=16)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss NaN"
    # a plausible CE magnitude for random init over vocab 512
    assert 1.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_grads_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(1))
    batch = make_batch(cfg, key=1)
    loss, grads = jax.value_and_grad(
        lambda p: forward_train(p, batch, cfg, dtype=jnp.float32,
                                block_kv=16, loss_chunk=16))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(2))
    B, S = 2, 32
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    tokens = jnp.zeros((B, 1), jnp.int32)
    memory = (jnp.zeros((B, 8, cfg.d_model), jnp.float32)
              if cfg.family == "encdec" else None)
    logits, cache2 = decode_step(params, cache, tokens, jnp.int32(0), cfg,
                                 dtype=jnp.float32, memory=memory)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


def test_decode_matches_forward_gqa():
    """Sequential decode logits == teacher-forced forward logits (GQA)."""
    cfg = get_config("qwen2_7b").reduced()
    params = init_params(cfg, jax.random.key(3))
    B, S = 1, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    from repro.models import forward
    from repro.models.layers import linear
    x = forward(params, {"tokens": toks}, cfg, dtype=jnp.float32,
                block_kv=8, remat=False)
    full_logits = linear(params["lm_head"], x)
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1],
                                jnp.int32(t), cfg, dtype=jnp.float32)
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), rtol=2e-3,
                               atol=2e-3)


def test_decode_matches_forward_mla():
    import dataclasses
    cfg = get_config("deepseek_v3_671b").reduced()
    # capacity drops differ between 8-token forward and 1-token decode
    # (expected GShard semantics) — compare drop-free.
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.key(4))
    B, S = 1, 8
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    from repro.models import forward
    from repro.models.layers import linear
    x = forward(params, {"tokens": toks}, cfg, dtype=jnp.float32,
                block_kv=8, remat=False)
    full_logits = linear(params["lm_head"], x)
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1],
                                jnp.int32(t), cfg, dtype=jnp.float32)
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), rtol=2e-3,
                               atol=2e-3)


def test_decode_matches_forward_ssm():
    cfg = get_config("mamba2_130m").reduced()
    params = init_params(cfg, jax.random.key(5))
    B, S = 1, 16   # multiple of reduced chunk (16)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    from repro.models import forward
    from repro.models.layers import linear
    x = forward(params, {"tokens": toks}, cfg, dtype=jnp.float32,
                remat=False)
    full_logits = linear(params["lm_head"], x)
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1],
                                jnp.int32(t), cfg, dtype=jnp.float32)
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), rtol=5e-3,
                               atol=5e-3)
