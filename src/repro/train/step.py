"""train_step / serve_step builders — the functions the dry-run lowers and
the launchers execute.

Features: microbatch gradient accumulation (lax.scan), remat inside the
layer scans (models), optional gradient compression (error-feedback int8 —
repro.dist.compress), optimizer fused in.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import decode_step as _decode_step
from repro.models import forward_train, prefill as _prefill
from repro.models.config import ModelConfig
from .optimizer import Optimizer


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *, mp: int = 1,
                    dtype=jnp.bfloat16, micro_batches: int = 1,
                    block_kv: int = 1024, loss_chunk: int = 512,
                    compress_grads=None, unroll: bool = False):
    """Returns train_step(params, opt_state, batch, step) →
    (params, opt_state, loss)."""

    def loss_fn(params, batch):
        # cast weights to compute dtype *before* use: the ZeRO all-gathers
        # then move bf16, not fp32 — 2× collective reduction (hillclimb #2,
        # EXPERIMENTS.md §Perf).  Cast is differentiable; masters stay fp32.
        params_c = jax.tree_util.tree_map(
            lambda p: p.astype(dtype)
            if (p.ndim >= 2 and p.dtype == jnp.float32) else p, params)
        return forward_train(params_c, batch, cfg, mp=mp, dtype=dtype,
                             block_kv=block_kv, loss_chunk=loss_chunk,
                             unroll=unroll)

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch, step):
        if micro_batches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(micro_batches, b // micro_batches,
                                 *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def acc(carry, mb):
                tot, g = carry
                l, gi = grad_fn(params, mb)
                g = jax.tree_util.tree_map(jnp.add, g, gi)
                return (tot + l, g), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.float32(0), zeros), micro)
            loss = loss / micro_batches
            grads = jax.tree_util.tree_map(
                lambda g: g / micro_batches, grads)
        if compress_grads is not None:
            grads = compress_grads(grads)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ModelConfig, *, mp: int = 1, dtype=jnp.bfloat16,
                      block_kv: int = 1024, unroll: bool = False):
    def prefill_step(params, batch):
        logits, hidden = _prefill(params, batch, cfg, mp=mp, dtype=dtype,
                                  block_kv=block_kv, unroll=unroll)
        return logits

    return prefill_step


def make_decode_fn(cfg: ModelConfig, *, mp: int = 1, dtype=jnp.bfloat16,
                   unroll: bool = False):
    def serve_step(params, cache, tokens, index, memory=None):
        logits, cache = _decode_step(params, cache, tokens, index, cfg,
                                     mp=mp, dtype=dtype, memory=memory,
                                     unroll=unroll)
        return logits, cache

    return serve_step
