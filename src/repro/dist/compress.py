"""Error-feedback gradient compression (int8) for cross-partition reduces.

The partitioning logic of the paper cuts mirror traffic by lowering the
replication factor; this module cuts the *per-mirror payload*: gradients
quantize to int8 (max-abs per-tensor scale) before the reduce, and the
quantization error is carried in a residual that is re-added next step
(error feedback), so the time-averaged update is unbiased.

API:
  zero_residual(tree)                    — initial residual state
  compress_with_error_feedback(g, res)   — (compressed, new_residual)
  compressed_psum(x, axis)               — int8 quantize → psum → dequant
  make_grad_compressor()                 — stateless grads→grads callable
                                           for make_train_step
  quantize_rows(x) / dequantize_rows(c, s)
                                         — int8 codes + max-abs scale per
                                           trailing row; the lane-group
                                           quantizer the halo exchange's
                                           quantized wire format reuses
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_QMAX = 127.0


def _scale_of(x: jnp.ndarray) -> jnp.ndarray:
    amax = jnp.max(jnp.abs(x))
    return jnp.where(amax > 0, amax / _QMAX, 1.0)


def _quantize_dequantize(x: jnp.ndarray) -> jnp.ndarray:
    scale = _scale_of(x)
    q = jnp.clip(jnp.round(x / scale), -_QMAX, _QMAX)
    return q * scale


def quantize_rows(x: jnp.ndarray, qmax: float = _QMAX):
    """Max-abs int8 quantization per trailing row: ``x`` (..., n) →
    (codes int8 (..., n), scales f32 (...)).  Each leading index gets its
    own scale — for the halo exchange these rows are per-destination lane
    groups, so one hot lane can't wash out another destination's
    precision.  All-zero rows take scale 1 so dequantization is exact."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scales = jnp.where(amax > 0, amax / qmax, 1.0)
    codes = jnp.clip(jnp.round(xf / scales[..., None]),
                     -qmax, qmax).astype(jnp.int8)
    return codes, scales


def dequantize_rows(codes: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``quantize_rows``: (..., n) int8 codes × (...) scales →
    (..., n) f32.  Exact for the codes produced by ``quantize_rows`` (the
    round-trip error lives in the encoder's residual, not here)."""
    return codes.astype(jnp.float32) * scales[..., None]


def zero_residual(grads):
    """Residual pytree of zeros matching ``grads``."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_with_error_feedback(grads, residual):
    """Returns (compressed_grads, new_residual).

    Per leaf: t = g + residual; compressed = Q(t); new_residual = t − Q(t)
    — so compressed + new_residual == g + residual exactly, and over T
    steps the mean compressed gradient converges to the true gradient.
    """
    totals = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    compressed = jax.tree_util.tree_map(_quantize_dequantize, totals)
    new_residual = jax.tree_util.tree_map(
        lambda t, c: t - c, totals, compressed)
    return compressed, new_residual


def make_grad_compressor():
    """Stateless per-leaf int8 quantize-dequantize, in the grads→grads
    shape ``make_train_step(compress_grads=…)`` accepts.  Unlike
    ``compress_with_error_feedback`` this carries no residual across steps
    — it is the launcher-facing hook (``--compress-grads``) for runs whose
    step signature can't thread extra state.

    Note on wire bytes: in the jit/GSPMD path the gradient all-reduces
    happen inside the backward pass, *before* this hook runs, so it bounds
    update precision without shrinking collectives (``launch.dryrun
    --compress-grads`` measures exactly that: delta ≈ 0).  Cutting the
    gradient wire itself needs ``compressed_psum`` inside a shard_map'd
    step — the open follow-up in ROADMAP.md."""
    def compress(grads):
        return jax.tree_util.tree_map(_quantize_dequantize, grads)
    return compress


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-quantized psum inside shard_map: agree on a global scale
    (pmax of |x|), quantize locally to int8 codes, sum as int16 (the
    accumulator stays overflow-safe up to 256 participants: 256·127 <
    2¹⁵), dequantize.  The wire payload is the int16 code tensor + one
    scalar — a 2× byte reduction on the cross-partition reduce vs fp32
    (ring all-reduce sends partial sums, so the accumulator width is the
    wire width; a gather-based int8 layout would reach 4×)."""
    xf = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.where(amax > 0, amax / _QMAX, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -_QMAX, _QMAX)
    total = jax.lax.psum(q.astype(jnp.int16), axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)
