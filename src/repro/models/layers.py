"""Pure-functional building blocks (no flax): params are nested dicts of
jnp arrays; every module is (init, apply) pairs.  Sharding is expressed
with jax.lax.with_sharding_constraint using logical axis rules resolved by
repro.dist.sharding.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def linear_init(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32) -> Params:
    w = jax.random.normal(key, (d_in, d_out), dtype) / math.sqrt(d_in)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.square(xf - mu).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: Params, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["table"].astype(x.dtype).T


# --------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, max_pos: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(t, inv)                      # (max_pos, head_dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate) * up


def gelu_ffn_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["down"], jax.nn.gelu(linear(p["up"], x)))


def ffn_init(key, d_model: int, d_ff: int, gated: bool = True,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    if gated:
        return {"gate": linear_init(ks[0], d_model, d_ff, dtype=dtype),
                "up": linear_init(ks[1], d_model, d_ff, dtype=dtype),
                "down": linear_init(ks[2], d_ff, d_model, dtype=dtype)}
    return {"up": linear_init(ks[0], d_model, d_ff, dtype=dtype),
            "down": linear_init(ks[1], d_ff, d_model, dtype=dtype)}


def ffn(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "gate" in p:
        return linear(p["down"], swiglu(linear(p["gate"], x),
                                        linear(p["up"], x)))
    return gelu_ffn_apply(p, x)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
