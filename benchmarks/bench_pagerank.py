"""Fig. 8: performance on the real distributed system (PowerGraph →
shard_map GAS engine).  Reports per-iteration communication volume for all
three exchange backends (dense padded all_gather, mirror-routed halo
all_to_all, int8-quantized halo) next to the ragged ideal — the dense→halo
byte reduction is the paper's mechanism (mirror count) showing up on the
wire, and halo→quantized is the per-mirror payload cut composing with it —
plus local compute cost per partitioner and wall time of the simulated
engine.

``layout_build_bench`` times the vectorized ``build_layout`` against the
retained reference builder (the PR-2 layout-build speedup)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import web_graph
from repro.dist.halo import lossy_payload
from repro.graph import (PROGRAM_NAMES, build_layout,
                         build_layout_reference, get_program,
                         reference_bfs, reference_cc, reference_centrality,
                         reference_degree, reference_labelprop,
                         reference_pagerank, reference_ppr, reference_sssp,
                         simulate_gas, simulate_gas_many, simulate_pagerank)
from .common import run_partitioner, stream_for


def fig8_pagerank(scale=11, k=8, iters=20, seed=0):
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for algo in ("clugp-opt", "clugp", "hdrf", "hashing", "dbh"):
        out = run_partitioner(algo, g, k, seed)
        assign = out[0]
        src, dst = stream_for(algo, g, out)
        lay = build_layout(src, dst, assign, g.num_vertices, k)
        ref = reference_pagerank(src, dst, g.num_vertices, iters=iters)
        row = {
            "bench": "fig8_pagerank", "algo": algo, "k": k,
            "comm_mb_per_iter": round(lay.comm_bytes("ideal") / 1e6, 4),
            "comm_mb_dense_padded": round(
                lay.comm_bytes("dense") / 1e6, 4),
            "comm_mb_halo_padded": round(lay.comm_bytes("halo") / 1e6, 4),
            "comm_mb_halo_quantized": round(
                lay.comm_bytes("quantized") / 1e6, 4),
            "comm_dense_mb": round(lay.comm_bytes("allreduce") / 1e6, 4),
            "local_edges_max": int(lay.e_max),
            "mirrors": int(lay.mirrors_total),
        }
        for exchange in ("dense", "halo", "quantized"):
            t0 = time.time()
            pr = simulate_pagerank(lay, iters=iters, exchange=exchange)
            dt = time.time() - t0
            err = float(np.abs(pr - ref).max())
            row[f"engine_seconds_{exchange}"] = round(dt, 3)
            row[f"max_err_{exchange}"] = err
            # delta-coded error feedback converges with the iteration, but
            # at finite iters the int8 path keeps a small dither floor
            tol = 1e-5 if exchange != "quantized" else 1e-4
            assert err < tol, (algo, exchange, err)
        rows.append(row)
    return rows


FUSED_BUNDLE = ("pagerank", "ppr", "centrality")

_REF = {
    "pagerank": lambda s, d, n, it: reference_pagerank(s, d, n, iters=it),
    "cc": lambda s, d, n, it: reference_cc(s, d, n),
    "labelprop": lambda s, d, n, it: reference_labelprop(s, d, n, iters=it),
    "sssp": lambda s, d, n, it: reference_sssp(s, d, n, iters=it),
    "bfs": lambda s, d, n, it: reference_bfs(s, d, n, iters=it),
    "degree": lambda s, d, n, it: reference_degree(s, d, n),
    "centrality": lambda s, d, n, it: reference_centrality(s, d, n,
                                                           iters=it),
    "ppr": lambda s, d, n, it: reference_ppr(s, d, n, iters=it),
}


def program_matrix_bench(scale=10, k=8, iters=20, seed=0):
    """Program-library wire table: one row per GAS program with its
    modelled bytes/iter under all three exchanges (the quantized column
    is lossy-aware — min/int payloads ship exact and pay halo bytes),
    engine-vs-oracle max error and engine wall time on the quantized
    wire, plus one fused-bundle row whose ``fused_vs_separate`` column
    is the headline ratio the CI dry-run gates at < 0.6."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    out = run_partitioner("clugp-opt", g, k, seed)
    lay = build_layout(g.src, g.dst, out[0], g.num_vertices, k)
    rows = []
    for name in PROGRAM_NAMES:
        prog = get_program(name, g.num_vertices)
        lossy = lossy_payload(prog.combine, prog.dtype)
        # frontier programs need the label/distance wave to close before
        # they can match a converged oracle (cc's reference runs to
        # fixpoint); the per-round oracles match at any count
        it = max(iters, 40) if name == "cc" else iters
        ref = _REF[name](g.src, g.dst, g.num_vertices, it)
        t0 = time.time()
        got = simulate_gas(prog, lay, iters=it, exchange="quantized")
        dt = time.time() - t0
        err = float(np.abs(got.astype(np.float64) -
                           ref.astype(np.float64)).max())
        tol = 1e-4 if lossy else 0.0
        assert err <= tol, (name, err)
        rows.append({
            "bench": "program_matrix", "program": name, "k": k,
            "fused": False, "lossy_payload": lossy,
            "comm_mb_dense": round(lay.comm_bytes("dense") / 1e6, 4),
            "comm_mb_halo": round(lay.comm_bytes("halo") / 1e6, 4),
            "comm_mb_quantized": round(
                lay.comm_bytes("quantized", lossy=lossy) / 1e6, 4),
            "engine_seconds_quantized": round(dt, 3),
            "max_err_quantized": err,
        })
    # fused bundle: one wire per phase for N programs vs N separate wires
    progs = [get_program(p, g.num_vertices) for p in FUSED_BUNDLE]
    t0 = time.time()
    outs = simulate_gas_many(progs, lay, iters=iters, exchange="quantized")
    dt = time.time() - t0
    for name, got in zip(FUSED_BUNDLE, outs):
        ref = _REF[name](g.src, g.dst, g.num_vertices, iters)
        assert float(np.abs(got - ref).max()) < 1e-3, name
    fused_mb = lay.comm_bytes("quantized", programs=len(progs),
                              fused=True) / 1e6
    sep_mb = lay.comm_bytes("quantized", programs=len(progs),
                            lossy=True) / 1e6
    rows.append({
        "bench": "program_matrix", "program": "+".join(FUSED_BUNDLE),
        "k": k, "fused": True, "lossy_payload": True,
        "comm_mb_fused_quantized": round(fused_mb, 4),
        "comm_mb_separate_quantized": round(sep_mb, 4),
        "fused_vs_separate": round(fused_mb / sep_mb, 4),
        "engine_seconds_fused": round(dt, 3),
    })
    return rows


def layout_build_bench(scale=12, k=8, seed=0, repeats=3):
    """Vectorized vs reference ``build_layout`` wall time on a CLUGP
    partition — the table the ≥5× layout-build speedup claim reads from."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    out = run_partitioner("clugp-opt", g, k, seed)
    assign = out[0]
    args = (g.src, g.dst, assign, g.num_vertices, k)
    build_layout(*args)          # warm caches
    t0 = time.time()
    for _ in range(repeats):
        lay = build_layout(*args)
    vec_s = (time.time() - t0) / repeats
    t0 = time.time()
    ref_lay = build_layout_reference(*args)
    ref_s = time.time() - t0
    assert lay.mirrors_total == ref_lay.mirrors_total
    return [{
        "bench": "layout_build", "k": k, "scale": scale,
        "num_vertices": g.num_vertices, "num_edges": g.num_edges,
        "vectorized_s": round(vec_s, 4),
        "reference_s": round(ref_s, 4),
        "speedup": round(ref_s / max(vec_s, 1e-9), 2),
    }]
