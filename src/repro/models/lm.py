"""Composable LM covering all 10 assigned architectures.

Layer-homogeneous groups are stacked (init via vmap) and applied with
``lax.scan`` + ``jax.checkpoint`` (remat) so the HLO stays one-layer-sized —
essential for the 512-device dry-run compiles.

Entry points (all pure):
  init_params(cfg, key, mp)            — real weights (smoke scale)
  abstract_params(cfg, mp)             — ShapeDtypeStructs (dry-run scale)
  forward_train(params, batch, cfg)    — mean CE loss (chunked logits)
  prefill(params, batch, cfg)          — forward + emitted KV/SSM caches
  decode_step(params, cache, ...)      — one token, SP-sharded caches
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention as A
from . import layers as L
from . import mamba as SSM
from . import moe as M
from .config import ModelConfig
from ..dist import decode as DEC
from ..dist.sharding import shard

Params = dict[str, Any]


# ---------------------------------------------------------------- structure

def layer_groups(cfg: ModelConfig) -> list[tuple[str, int]]:
    if cfg.family == "encdec":
        return [("enc", cfg.n_encoder_layers), ("dec", cfg.n_layers)]
    if cfg.family == "hybrid":
        assert cfg.n_layers % cfg.attn_period == 0
        return [("hyb", cfg.n_layers // cfg.attn_period)]
    if cfg.family == "ssm":
        return [("ssd", cfg.n_layers)]
    if cfg.moe is not None:
        fk = cfg.moe.first_k_dense
        out = []
        if fk:
            out.append(("dense", fk))
        out.append(("moe", cfg.n_layers - fk))
        return out
    return [("dense", cfg.n_layers)]


def _norm_init(cfg, d):
    return (L.rmsnorm_init(d) if cfg.norm == "rmsnorm"
            else L.layernorm_init(d))


def _norm(cfg, p, x):
    return L.rmsnorm(p, x) if cfg.norm == "rmsnorm" else L.layernorm(p, x)


def _attn_init(cfg: ModelConfig, key, mp: int) -> Params:
    if cfg.mla is not None:
        m = cfg.mla
        return A.mla_init(key, cfg.d_model, cfg.n_heads, q_lora=m.q_lora,
                          kv_lora=m.kv_lora, nope_dim=m.nope_dim,
                          rope_dim=m.rope_dim, v_dim=m.v_dim,
                          pad_heads_to=mp)
    return A.gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.hd, cfg.qkv_bias, pad_heads_to=mp)


def _ffn_or_moe_init(cfg: ModelConfig, key, kind: str) -> Params:
    if kind == "moe":
        mo = cfg.moe
        return M.moe_init(key, cfg.d_model, mo.d_expert, mo.n_experts,
                          mo.n_shared)
    return L.ffn_init(key, cfg.d_model, cfg.d_ff,
                      gated=(cfg.norm == "rmsnorm"))


def _init_one_layer(cfg: ModelConfig, group: str, key, mp: int) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if group == "ssd":
        s = cfg.ssm
        return {"ln1": _norm_init(cfg, d),
                "ssd": SSM.ssd_init(ks[0], d, s.expand * d, s.d_state,
                                    s.head_dim)}
    if group == "hyb":
        s = cfg.ssm
        period = cfg.attn_period
        sub = []
        for i in range(period):
            kk = jax.random.split(ks[i % 8] if i < 8 else ks[7], 3)
            mix = ({"attn": _attn_init(cfg, kk[0], mp)}
                   if i == cfg.attn_index else
                   {"ssd": SSM.ssd_init(kk[0], d, s.expand * d, s.d_state,
                                        s.head_dim)})
            kind = "moe" if (cfg.moe and i % cfg.moe.every == 1) else "ffn"
            sub.append({"ln1": _norm_init(cfg, d),
                        "ln2": _norm_init(cfg, d),
                        **mix,
                        "ffn_kind": kind,
                        "ffn": _ffn_or_moe_init(cfg, kk[1], kind)})
        # strip non-array marker into structure: handled by body statically
        for s_ in sub:
            s_.pop("ffn_kind")
        return {"sub": sub}
    if group == "enc":
        return {"ln1": _norm_init(cfg, d), "ln2": _norm_init(cfg, d),
                "attn": _attn_init(cfg, ks[0], mp),
                "ffn": _ffn_or_moe_init(cfg, ks[1], "ffn")}
    if group == "dec":
        return {"ln1": _norm_init(cfg, d), "ln2": _norm_init(cfg, d),
                "ln3": _norm_init(cfg, d),
                "attn": _attn_init(cfg, ks[0], mp),
                "xattn": _attn_init(cfg, ks[1], mp),
                "ffn": _ffn_or_moe_init(cfg, ks[2], "ffn")}
    kind = "moe" if group == "moe" else "ffn"
    return {"ln1": _norm_init(cfg, d), "ln2": _norm_init(cfg, d),
            "attn": _attn_init(cfg, ks[0], mp),
            "ffn": _ffn_or_moe_init(cfg, ks[1], kind)}


def init_params(cfg: ModelConfig, key, mp: int = 1) -> Params:
    ks = jax.random.split(key, 4 + len(layer_groups(cfg)))
    p: Params = {
        "embed": L.embedding_init(ks[0], cfg.padded_vocab, cfg.d_model),
        "lm_head": L.linear_init(ks[1], cfg.d_model, cfg.padded_vocab),
        "ln_f": _norm_init(cfg, cfg.d_model),
    }
    for gi, (group, count) in enumerate(layer_groups(cfg)):
        gkeys = jax.random.split(ks[3 + gi], count)
        p[f"g_{group}"] = jax.vmap(
            lambda k: _init_one_layer(cfg, group, k, mp))(gkeys)
    return p


def abstract_params(cfg: ModelConfig, mp: int = 1, dtype=None) -> Params:
    tree = jax.eval_shape(
        lambda k: init_params(cfg, k, mp), jax.random.key(0))
    if dtype is not None:
        # serving stores weights in compute dtype (no fp32 masters)
        tree = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype)
            if s.dtype == jnp.float32 and len(s.shape) >= 2 else s, tree)
    return tree


def param_count(cfg: ModelConfig, mp: int = 1) -> int:
    tree = abstract_params(cfg, mp)
    return sum(int(np_prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def np_prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ---------------------------------------------------------------- blocks

def _self_attention(p, x, cfg: ModelConfig, mp: int, positions,
                    causal: bool = True, block_kv: int = 1024,
                    return_kv: bool = False, kv_override=None):
    B, S, _ = x.shape
    hp = L.round_up(cfg.n_heads, mp)
    if cfg.mla is not None:
        m = cfg.mla
        out = A.mla_attention(p, x, n_heads=cfg.n_heads, q_lora=m.q_lora,
                              kv_lora=m.kv_lora, nope_dim=m.nope_dim,
                              rope_dim=m.rope_dim, v_dim=m.v_dim,
                              pad_heads_to=mp, positions=positions,
                              causal=causal, block_kv=block_kv)
        return (out, None) if return_kv else out
    q, k, v = A.gqa_project(p, x, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                            head_dim=cfg.hd, pad_heads_to=mp,
                            positions=positions, rope_theta=cfg.rope_theta)
    if kv_override is not None:
        k, v = kv_override
    q = shard(q, "batch", "seq", "heads", None)
    # KV: shard heads when they divide the mesh; otherwise replicate —
    # under the CP profile the replication is the GQA KV all-gather
    # (kv_heads ≪ heads ⇒ far cheaper than residual ARs)
    kv_tag = "kv_heads_sharded" if cfg.n_kv_heads % mp == 0 else "kv_heads"
    k = shard(k, "batch", None, kv_tag, None)
    v = shard(v, "batch", None, kv_tag, None)
    out = A.chunked_attention(q, A.expand_kv(k, hp), A.expand_kv(v, hp),
                              causal=causal, block_kv=block_kv)
    out = shard(out, "batch", "seq", "heads", None)
    y = L.linear(p["o"], out.reshape(B, S, hp * cfg.hd))
    return (y, (k, v)) if return_kv else y


def _ffn_apply(p, x, cfg: ModelConfig, kind: str):
    if kind == "moe":
        mo = cfg.moe
        return M.moe_apply(p, x, n_experts=mo.n_experts, top_k=mo.top_k,
                           capacity_factor=mo.capacity_factor,
                           router_softmax_after_topk=mo.softmax_after_topk)
    return L.ffn(p, x)


def _make_block(cfg: ModelConfig, group: str, mp: int, block_kv: int,
                memory=None, unroll: bool = False):
    """Returns body(x, lp) for lax.scan over the group's stacked params."""
    def dense_body(x, lp, kind):
        pos = jnp.arange(x.shape[1])[None, :]
        h = _self_attention(lp["attn"], _norm(cfg, lp["ln1"], x), cfg, mp,
                            pos, causal=True, block_kv=block_kv)
        x = x + h
        x = x + _ffn_apply(lp["ffn"], _norm(cfg, lp["ln2"], x), cfg, kind)
        return shard(x, "batch", "seq", None)

    if group in ("dense", "moe"):
        kind = "moe" if group == "moe" else "ffn"
        return lambda x, lp: dense_body(x, lp, kind)
    if group == "ssd":
        s = cfg.ssm

        def ssd_body(x, lp):
            h = SSM.ssd_apply(lp["ssd"], _norm(cfg, lp["ln1"], x),
                              d_inner=s.expand * cfg.d_model,
                              d_state=s.d_state, head_dim=s.head_dim,
                              chunk=s.chunk)
            return shard(x + h, "batch", None, None)
        return ssd_body
    if group == "hyb":
        s = cfg.ssm

        def hyb_body(x, lp):
            pos = jnp.arange(x.shape[1])[None, :]
            for i in range(cfg.attn_period):
                sub = lp["sub"][i]
                hin = _norm(cfg, sub["ln1"], x)
                if i == cfg.attn_index:
                    h = _self_attention(sub["attn"], hin, cfg, mp, pos,
                                        causal=True, block_kv=block_kv)
                else:
                    h = SSM.ssd_apply(sub["ssd"], hin,
                                      d_inner=s.expand * cfg.d_model,
                                      d_state=s.d_state,
                                      head_dim=s.head_dim, chunk=s.chunk)
                x = x + h
                kind = "moe" if (cfg.moe and i % cfg.moe.every == 1) else "ffn"
                x = x + _ffn_apply(sub["ffn"], _norm(cfg, sub["ln2"], x),
                                   cfg, kind)
            return shard(x, "batch", "seq", None)
        return hyb_body
    if group == "enc":
        def enc_body(x, lp):
            pos = jnp.arange(x.shape[1])[None, :]
            x = x + _self_attention(lp["attn"], _norm(cfg, lp["ln1"], x),
                                    cfg, mp, pos, causal=False,
                                    block_kv=block_kv)
            x = x + L.ffn(lp["ffn"], _norm(cfg, lp["ln2"], x))
            return shard(x, "batch", "seq", None)
        return enc_body
    if group == "dec":
        def dec_body(x, lp):
            B, S, _ = x.shape
            pos = jnp.arange(S)[None, :]
            x = x + _self_attention(lp["attn"], _norm(cfg, lp["ln1"], x),
                                    cfg, mp, pos, causal=True,
                                    block_kv=block_kv)
            # cross attention over encoder memory
            hp = L.round_up(cfg.n_heads, mp)
            h = _norm(cfg, lp["ln2"], x)
            q = L.linear(lp["xattn"]["q"], h).reshape(B, S, hp, cfg.hd)
            mem = memory
            Sm = mem.shape[1]
            k = L.linear(lp["xattn"]["k"], mem).reshape(
                B, Sm, cfg.n_kv_heads, cfg.hd)
            v = L.linear(lp["xattn"]["v"], mem).reshape(
                B, Sm, cfg.n_kv_heads, cfg.hd)
            out = A.chunked_attention(q, A.expand_kv(k, hp),
                                      A.expand_kv(v, hp), causal=False,
                                      block_kv=block_kv)
            x = x + L.linear(lp["xattn"]["o"], out.reshape(B, S, hp * cfg.hd))
            x = x + L.ffn(lp["ffn"], _norm(cfg, lp["ln3"], x))
            return shard(x, "batch", "seq", None)
        return dec_body
    raise ValueError(group)


def _scan_group(x, stacked, body, remat: bool = True,
                unroll: bool = False):
    fn = jax.checkpoint(body) if remat else body

    def step(carry, lp):
        return fn(carry, lp), None

    x, _ = jax.lax.scan(step, x, stacked, unroll=unroll)
    return x


# ---------------------------------------------------------------- forward

def embed_inputs(params, batch, cfg: ModelConfig, dtype):
    """Returns (x, labels, memory).  Stub frontends provide precomputed
    embeddings (``prefix_embeds`` / ``src_embeds``) per the assignment."""
    memory = None
    if cfg.family == "encdec":
        mem = batch["src_embeds"].astype(dtype)
        x = L.embed(params["embed"], batch["tokens"], dtype)
        return x, batch.get("labels"), mem
    x = L.embed(params["embed"], batch["tokens"], dtype)
    if cfg.prefix_tokens and "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(dtype), x], 1)
    return x, batch.get("labels"), memory


def forward(params, batch, cfg: ModelConfig, mp: int = 1,
            dtype=jnp.bfloat16, block_kv: int = 1024,
            remat: bool = True, unroll: bool = False) -> jnp.ndarray:
    """Returns final hidden states (B, S, D)."""
    x, _, memory = embed_inputs(params, batch, cfg, dtype)
    x = shard(x, "batch", "seq", None)
    if cfg.family == "encdec":
        enc_body = _make_block(cfg, "enc", mp, block_kv, unroll=unroll)
        memory = _scan_group(memory, params["g_enc"], enc_body, remat,
                             unroll)
        body = _make_block(cfg, "dec", mp, block_kv, memory=memory,
                           unroll=unroll)
        x = _scan_group(x, params["g_dec"], body, remat, unroll)
    else:
        for group, _count in layer_groups(cfg):
            body = _make_block(cfg, group, mp, block_kv, unroll=unroll)
            x = _scan_group(x, params[f"g_{group}"], body, remat, unroll)
    return _norm(cfg, params["ln_f"], x)


def lm_loss(params, x, labels, cfg: ModelConfig, chunk: int = 512,
            unroll: bool = False):
    """Chunked CE: logits (B, chunk, V) never materialize (B, S, V)."""
    B, S, D = x.shape
    nch = -(-S // chunk)
    pad = nch * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = x.reshape(B, nch, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nch, chunk).transpose(1, 0, 2)
    w = params["lm_head"]

    def body(carry, inp):
        tot, cnt = carry
        xb, lb = inp
        logits = L.linear(w, xb).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], -1)[..., 0]
        mask = lb >= 0
        tot = tot + jnp.sum(jnp.where(mask, lse - gold, 0.0))
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc), unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)


def forward_train(params, batch, cfg: ModelConfig, mp: int = 1,
                  dtype=jnp.bfloat16, block_kv: int = 1024,
                  loss_chunk: int = 512,
                  unroll: bool = False) -> jnp.ndarray:
    x = forward(params, batch, cfg, mp, dtype, block_kv, unroll=unroll)
    return lm_loss(params, x, batch["labels"], cfg, loss_chunk, unroll)


# ---------------------------------------------------------------- serving

def _project_decode_qkv(lp, x, cfg, mp, index):
    B = x.shape[0]
    hp = L.round_up(cfg.n_heads, mp)
    pos = jnp.full((B, 1), index, jnp.int32)
    q, k, v = A.gqa_project(lp, x, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                            head_dim=cfg.hd, pad_heads_to=mp, positions=pos,
                            rope_theta=cfg.rope_theta)
    return q, k, v, hp


def _attn_decode(lp, x, crow, cfg, mp, index):
    """x (B,1,D); crow: {'k','v'} (B,Smax,Hkv,Dh) sequence-sharded."""
    B = x.shape[0]
    q, k, v, hp = _project_decode_qkv(lp, x, cfg, mp, index)
    ck = DEC.sp_cache_update(crow["k"], k, index)
    cv = DEC.sp_cache_update(crow["v"], v, index)
    out = DEC.sp_decode_attention(q, ck, cv, index)
    y = L.linear(lp["o"], out.reshape(B, 1, hp * cfg.hd))
    return y, {"k": ck, "v": cv}


def _mla_decode(lp, x, crow, cfg, mp, index):
    m = cfg.mla
    B = x.shape[0]
    hp = L.round_up(cfg.n_heads, mp)
    pos = jnp.full((B, 1), index, jnp.int32)
    q = L.linear(lp["q_b"], L.linear(lp["q_a"], x)).reshape(
        B, 1, hp, m.nope_dim + m.rope_dim)
    q_nope, q_rope = q[..., :m.nope_dim], q[..., m.nope_dim:]
    q_rope = A.apply_rope(q_rope, pos)
    kv = L.linear(lp["kv_a"], x)
    lat_row, k_rope_row = kv[..., :m.kv_lora], kv[..., m.kv_lora:]
    k_rope_row = A.apply_rope(k_rope_row[:, :, None, :], pos)[:, :, 0, :]
    clat = DEC.sp_latent_cache_update(crow["lat"], lat_row, index)
    crop = DEC.sp_latent_cache_update(crow["rope"], k_rope_row, index)
    # absorbed projections: W_uk: (kv_lora, H, nope), W_uv: (kv_lora, H, v)
    wkv = lp["kv_b"]["w"].reshape(m.kv_lora, hp, m.nope_dim + m.v_dim)
    w_uk = wkv[..., :m.nope_dim]
    w_uv = wkv[..., m.nope_dim:]
    q_lat = jnp.einsum("bhd,chd->bhc", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    o_lat = DEC.sp_decode_attention_latent(
        q_lat, q_rope[:, 0], clat, crop, index,
        nope_dim=m.nope_dim, rope_dim=m.rope_dim)
    o = jnp.einsum("bhc,chv->bhv", o_lat, w_uv.astype(jnp.float32))
    y = L.linear(lp["o"], o.reshape(B, 1, hp * m.v_dim).astype(x.dtype))
    return y, {"lat": clat, "rope": crop}


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               mp: int = 1, dtype=jnp.bfloat16) -> dict:
    cache: dict = {}
    s = cfg.ssm
    for group, count in layer_groups(cfg):
        if group in ("dense", "moe", "dec"):
            if cfg.mla is not None:
                m = cfg.mla
                cache[group] = {
                    "lat": jnp.zeros((count, batch_size, max_len, m.kv_lora),
                                     dtype),
                    "rope": jnp.zeros((count, batch_size, max_len,
                                       m.rope_dim), dtype)}
            else:
                kv = (count, batch_size, max_len, cfg.n_kv_heads, cfg.hd)
                cache[group] = {"k": jnp.zeros(kv, dtype),
                                "v": jnp.zeros(kv, dtype)}
        elif group == "ssd":
            h = (s.expand * cfg.d_model) // s.head_dim
            cache[group] = {"state": jnp.zeros(
                (count, batch_size, h, s.d_state, s.head_dim), jnp.float32)}
        elif group == "hyb":
            h = (s.expand * cfg.d_model) // s.head_dim
            kv = (count, batch_size, max_len, cfg.n_kv_heads, cfg.hd)
            cache[group] = {
                "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                "state": jnp.zeros((count, cfg.attn_period - 1, batch_size,
                                    h, s.d_state, s.head_dim), jnp.float32)}
    return cache


def decode_step(params, cache, tokens, index, cfg: ModelConfig, mp: int = 1,
                dtype=jnp.bfloat16, memory=None, unroll: bool = False):
    """tokens (B,1) → (logits (B,1,V), new cache).  ``index`` is the global
    position being written."""
    x = L.embed(params["embed"], tokens, dtype)
    s = cfg.ssm
    new_cache = {}
    for group, _count in layer_groups(cfg):
        if group == "enc":
            continue
        stacked = params[f"g_{group}"]
        crows = cache[group]

        if group in ("dense", "moe"):
            kind = "moe" if group == "moe" else "ffn"

            def body(carry, xs):
                x = carry
                lp, crow = xs
                h = _norm(cfg, lp["ln1"], x)
                if cfg.mla is not None:
                    y, nc = _mla_decode(lp["attn"], h, crow, cfg, mp, index)
                else:
                    y, nc = _attn_decode(lp["attn"], h, crow, cfg, mp, index)
                x = x + y
                x = x + _ffn_apply(lp["ffn"], _norm(cfg, lp["ln2"], x), cfg,
                                   kind)
                return x, nc

            x, nc = jax.lax.scan(body, x, (stacked, crows), unroll=unroll)
            new_cache[group] = nc
        elif group == "ssd":
            def body(carry, xs):
                x = carry
                lp, st = xs
                h, st2 = SSM.ssd_decode_step(
                    lp["ssd"], _norm(cfg, lp["ln1"], x), st["state"],
                    d_inner=s.expand * cfg.d_model, d_state=s.d_state,
                    head_dim=s.head_dim)
                return x + h, {"state": st2}

            x, nc = jax.lax.scan(body, x, (stacked, crows), unroll=unroll)
            new_cache[group] = nc
        elif group == "hyb":
            def body(carry, xs):
                x = carry
                lp, crow = xs
                states = []
                si = 0
                nc = dict(crow)
                for i in range(cfg.attn_period):
                    sub = lp["sub"][i]
                    h = _norm(cfg, sub["ln1"], x)
                    if i == cfg.attn_index:
                        y, kv = _attn_decode(sub["attn"], h,
                                             {"k": crow["k"], "v": crow["v"]},
                                             cfg, mp, index)
                        nc["k"], nc["v"] = kv["k"], kv["v"]
                    else:
                        y, st2 = SSM.ssd_decode_step(
                            sub["ssd"], h, crow["state"][si],
                            d_inner=s.expand * cfg.d_model,
                            d_state=s.d_state, head_dim=s.head_dim)
                        states.append(st2)
                        si += 1
                    x = x + y
                    kind = ("moe" if (cfg.moe and i % cfg.moe.every == 1)
                            else "ffn")
                    x = x + _ffn_apply(sub["ffn"], _norm(cfg, sub["ln2"], x),
                                       cfg, kind)
                nc["state"] = jnp.stack(states)
                return x, nc

            x, nc = jax.lax.scan(body, x, (stacked, crows), unroll=unroll)
            new_cache[group] = nc
        elif group == "dec":
            def body(carry, xs):
                x = carry
                lp, crow = xs
                h = _norm(cfg, lp["ln1"], x)
                y, nc = _attn_decode(lp["attn"], h, crow, cfg, mp, index)
                x = x + y
                # cross attention against fixed memory
                B = x.shape[0]
                hp = L.round_up(cfg.n_heads, mp)
                h = _norm(cfg, lp["ln2"], x)
                q = L.linear(lp["xattn"]["q"], h).reshape(B, 1, hp, cfg.hd)
                Sm = memory.shape[1]
                k = L.linear(lp["xattn"]["k"], memory).reshape(
                    B, Sm, cfg.n_kv_heads, cfg.hd)
                v = L.linear(lp["xattn"]["v"], memory).reshape(
                    B, Sm, cfg.n_kv_heads, cfg.hd)
                out = A.chunked_attention(q, A.expand_kv(k, hp),
                                          A.expand_kv(v, hp), causal=False)
                x = x + L.linear(lp["xattn"]["o"],
                                 out.reshape(B, 1, hp * cfg.hd))
                x = x + L.ffn(lp["ffn"], _norm(cfg, lp["ln3"], x))
                return x, nc

            x, nc = jax.lax.scan(body, x, (stacked, crows), unroll=unroll)
            new_cache[group] = nc

    x = _norm(cfg, params["ln_f"], x)
    logits = L.linear(params["lm_head"], x)
    return logits, new_cache


def prefill(params, batch, cfg: ModelConfig, mp: int = 1,
            dtype=jnp.bfloat16, block_kv: int = 1024,
            unroll: bool = False):
    """Forward pass returning (last-position logits, final hidden).  The
    dry-run's prefill cell lowers this; cache emission for chat serving is
    covered by decode cells + tests at smoke scale via repeated decode."""
    x = forward(params, batch, cfg, mp, dtype, block_kv, unroll=unroll)
    logits = L.linear(params["lm_head"], x[:, -1:])
    return logits, x
