"""Vertex-cut partition layout: from an edge→partition assignment to the
static padded per-device tables the GAS engine runs on.

PowerGraph semantics (paper §II-B): each vertex that appears in several
partitions has one **master** replica (here: the partition holding most of
its edges, ties → lowest id) and mirrors elsewhere.  Per GAS iteration the
mirrors' partial aggregates flow to the master (gather), the master applies
the update, and the new value flows back (scatter) — the two all_gather
phases below.  Communication per iteration is therefore proportional to the
number of mirrors, i.e. to (RF − 1)·|V| — the quantity CLUGP minimizes.

All tables are padded to static shapes so the engine jits/shard_maps:

  edge_src/edge_dst (k, E_max)  local-slot endpoints, padded with L_max
  vert_gid          (k, L_max)  local slot → global vertex id (pad: V)
  owner / own_slot  (k, L_max)  master device + slot there
  red_index         (k, k·L_max) flat all_gather entry → my owned slot
  out_deg           (k, L_max)  global out-degree (pagerank)
"""
from __future__ import annotations

from dataclasses import dataclass, fields

import numpy as np


@dataclass
class PartitionLayout:
    k: int
    num_vertices: int
    num_edges: int
    e_max: int
    l_max: int
    edge_src: np.ndarray     # (k, E_max) int32, local slots; pad = l_max
    edge_dst: np.ndarray     # (k, E_max)
    edge_mask: np.ndarray    # (k, E_max) bool
    vert_gid: np.ndarray     # (k, L_max) int32; pad = num_vertices
    vert_mask: np.ndarray    # (k, L_max) bool
    is_master: np.ndarray    # (k, L_max) bool
    owner: np.ndarray        # (k, L_max) int32 master device; pad = 0
    own_slot: np.ndarray     # (k, L_max) int32 slot in owner's table; pad 0
    red_index: np.ndarray    # (k, k*L_max) int32 → my slot or l_max (drop)
    out_deg: np.ndarray      # (k, L_max) int32 global out-degree
    mirrors_total: int       # Σ_v (|P(v)| − 1)

    def device_arrays(self) -> dict:
        """The pytree of arrays each device needs (leading k axis)."""
        return {f: getattr(self, f) for f in
                ("edge_src", "edge_dst", "edge_mask", "vert_gid",
                 "vert_mask", "is_master", "owner", "own_slot",
                 "red_index", "out_deg")}

    # -- communication model (bytes per GAS iteration, per §Fig-8 bench) --
    def comm_bytes_mirror_sync(self, value_bytes: int = 4) -> int:
        """all_gather(k, L_max) twice: every device receives k·L_max values
        per phase — but only mirror slots carry signal; ragged-compressed
        links would move 2·mirrors·bytes.  We report the padded (actual)
        and ideal (mirror-only) volumes."""
        return 2 * self.k * self.k * self.l_max * value_bytes

    def comm_bytes_ideal(self, value_bytes: int = 4) -> int:
        return 2 * self.mirrors_total * value_bytes

    def comm_bytes_dense(self, value_bytes: int = 4) -> int:
        """dense psum baseline: ring all-reduce over (V,) per device."""
        return 2 * (self.k - 1) * self.num_vertices * value_bytes


def build_layout(src: np.ndarray, dst: np.ndarray, assign: np.ndarray,
                 num_vertices: int, k: int,
                 pad_multiple: int = 8) -> PartitionLayout:
    E = src.shape[0]
    order = np.argsort(assign, kind="stable")
    s, d, a = src[order], dst[order], assign[order]
    bounds = np.searchsorted(a, np.arange(k + 1))

    # global out degree
    gdeg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(gdeg, src, 1)

    # per-partition local vertex tables + master election by edge count
    locals_: list[np.ndarray] = []
    counts = np.zeros((0,))
    vert_count = {}
    per_part_counts: list[dict] = []
    for p in range(k):
        lo, hi = bounds[p], bounds[p + 1]
        verts, cnt = np.unique(np.concatenate([s[lo:hi], d[lo:hi]]),
                               return_counts=True)
        locals_.append(verts)
        per_part_counts.append(dict(zip(verts.tolist(), cnt.tolist())))

    # master = partition with max edge count of v (ties → lowest partition)
    best_cnt = np.zeros(num_vertices, dtype=np.int64)
    master_of = np.full(num_vertices, -1, dtype=np.int64)
    for p in range(k):
        verts = locals_[p]
        cnt = np.array([per_part_counts[p][int(v)] for v in verts],
                       dtype=np.int64)
        better = cnt > best_cnt[verts]
        upd = verts[better]
        best_cnt[upd] = cnt[better]
        master_of[upd] = p

    l_max = max((len(v) for v in locals_), default=1)
    l_max = int(np.ceil(max(l_max, 1) / pad_multiple) * pad_multiple)
    e_max = int(max(bounds[1:] - bounds[:-1], default=1))
    e_max = int(np.ceil(max(e_max, 1) / pad_multiple) * pad_multiple)

    vert_gid = np.full((k, l_max), num_vertices, dtype=np.int32)
    vert_mask = np.zeros((k, l_max), dtype=bool)
    is_master = np.zeros((k, l_max), dtype=bool)
    out_deg = np.zeros((k, l_max), dtype=np.int32)
    slot_of = {}         # (p, gid) -> slot
    for p in range(k):
        verts = locals_[p]
        n = len(verts)
        vert_gid[p, :n] = verts
        vert_mask[p, :n] = True
        is_master[p, :n] = master_of[verts] == p
        out_deg[p, :n] = gdeg[verts]
        for sl, v in enumerate(verts.tolist()):
            slot_of[(p, v)] = sl

    owner = np.zeros((k, l_max), dtype=np.int32)
    own_slot = np.zeros((k, l_max), dtype=np.int32)
    for p in range(k):
        verts = locals_[p]
        for sl, v in enumerate(verts.tolist()):
            o = int(master_of[v])
            owner[p, sl] = o
            own_slot[p, sl] = slot_of[(o, v)]

    # reduce map: flat all_gather entry (j*L_max + slot) → my slot (if I am
    # the owner of that entry's vertex) else l_max (dropped)
    red_index = np.full((k, k * l_max), l_max, dtype=np.int32)
    for j in range(k):
        verts = locals_[j]
        for sl, v in enumerate(verts.tolist()):
            o = int(master_of[v])
            red_index[o, j * l_max + sl] = slot_of[(o, v)]

    edge_src = np.full((k, e_max), l_max, dtype=np.int32)
    edge_dst = np.full((k, e_max), l_max, dtype=np.int32)
    edge_mask = np.zeros((k, e_max), dtype=bool)
    for p in range(k):
        lo, hi = bounds[p], bounds[p + 1]
        n = hi - lo
        if n == 0:
            continue
        edge_src[p, :n] = [slot_of[(p, int(x))] for x in s[lo:hi]]
        edge_dst[p, :n] = [slot_of[(p, int(x))] for x in d[lo:hi]]
        edge_mask[p, :n] = True

    replic = np.zeros(num_vertices, dtype=np.int64)
    for p in range(k):
        replic[locals_[p]] += 1
    mirrors_total = int(np.maximum(replic - 1, 0).sum())

    return PartitionLayout(
        k=k, num_vertices=num_vertices, num_edges=E, e_max=e_max,
        l_max=l_max, edge_src=edge_src, edge_dst=edge_dst,
        edge_mask=edge_mask, vert_gid=vert_gid, vert_mask=vert_mask,
        is_master=is_master, owner=owner, own_slot=own_slot,
        red_index=red_index, out_deg=out_deg, mirrors_total=mirrors_total)
