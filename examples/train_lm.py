"""End-to-end training driver: train a ~100M-param stablelm-family model
for a few hundred steps on the synthetic pipeline, with checkpointing and
restart (the paper's kind is graph analytics — see distributed_pagerank.py
for that driver; this one exercises the LM substrate).

Full run (~100M params, slow on 1 CPU core):
    PYTHONPATH=src python examples/train_lm.py --steps 300
Quick check:
    PYTHONPATH=src python examples/train_lm.py --steps 40 --tiny
"""
import argparse
import sys

_ap = argparse.ArgumentParser()
_ap.add_argument("--steps", type=int, default=300)
_ap.add_argument("--tiny", action="store_true")
_ARGS, _ = _ap.parse_known_args()
sys.argv = [sys.argv[0]]  # keep launch.train's parser clean

from repro.launch import train as train_launcher  # noqa: E402


def main():
    args = _ARGS

    if args.tiny:
        argv = ["--arch", "stablelm-1.6b", "--reduced",
                "--steps", str(args.steps), "--batch", "8", "--seq", "64",
                "--lr", "3e-3", "--ckpt-dir", "/tmp/repro_train_lm_tiny"]
    else:
        # ~100M: stablelm wiring at 12 layers × 768
        argv = ["--arch", "stablelm-1.6b", "--layers", "12",
                "--d-model", "768", "--steps", str(args.steps),
                "--batch", "8", "--seq", "256", "--lr", "1e-3",
                "--ckpt-dir", "/tmp/repro_train_lm_100m"]
    sys.argv = ["train"] + argv
    train_launcher.main()


if __name__ == "__main__":
    main()
