import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600):
    """Run ``code`` in a subprocess with n virtual host devices.
    (XLA device count locks at first jax init, so multi-device paths are
    exercised out-of-process; the main process keeps 1 device.)"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def multidevice():
    return run_multidevice
