"""input_specs(): ShapeDtypeStruct stand-ins for every (arch × shape) cell.

Shapes (assignment sheet):
  train_4k     seq_len=4096    global_batch=256   (training)
  prefill_32k  seq_len=32768   global_batch=32    (inference-prefill)
  decode_32k   seq_len=32768   global_batch=128   (decode: 1 new token)
  long_500k    seq_len=524288  global_batch=1     (long-context decode —
               sub-quadratic archs only; full-attention archs are recorded
               as skipped, see DESIGN.md §Arch-applicability)

``decode_*``/``long_*`` lower ``serve_step`` (decode_step with a KV cache of
seq_len); encoder-decoder decodes against a stubbed encoder memory.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import ModelConfig, init_cache

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def cell_is_skipped(cfg: ModelConfig, shape_name: str) -> str | None:
    """Returns a skip reason or None."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return "skipped(full-attention: 500k dense KV is out of scope)"
    return None


def input_specs(cfg: ModelConfig, shape_name: str, mp: int = 1,
                dtype=jnp.bfloat16) -> dict:
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    i32 = jnp.int32

    if kind in ("train", "prefill"):
        if cfg.family == "encdec":
            half = S // 2
            batch = {"src_embeds": sds((B, half, cfg.d_model), dtype),
                     "tokens": sds((B, half), i32)}
            if kind == "train":
                batch["labels"] = sds((B, half), i32)
            return {"batch": batch}
        if cfg.family == "vlm":
            P = cfg.prefix_tokens
            batch = {"prefix_embeds": sds((B, P, cfg.d_model), dtype),
                     "tokens": sds((B, S - P), i32)}
            if kind == "train":
                batch["labels"] = sds((B, S), i32)
            return {"batch": batch}
        batch = {"tokens": sds((B, S), i32)}
        if kind == "train":
            batch["labels"] = sds((B, S), i32)
        return {"batch": batch}

    # decode: token + cache (+ encoder memory for encdec)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, mp=mp, dtype=dtype))
    out = {"tokens": sds((B, 1), i32), "cache": cache,
           "index": sds((), i32)}
    if cfg.family == "encdec":
        out["memory"] = sds((B, S // 2, cfg.d_model), dtype)
    return out
