"""The rule registry.  Each module defines one architecture guardrail;
``DEFAULT_RULES`` is what ``python -m repro.analysis --check`` and the
pytest wrappers run."""
from .raw_collective import RawCollective
from .stage_plumb import StagePlumb
from .session_bypass import SessionBypass
from .deprecated_api import DeprecatedApi
from .jit_purity import JitPurity

DEFAULT_RULES = (
    RawCollective(),
    StagePlumb(),
    SessionBypass(),
    DeprecatedApi(),
    JitPurity(),
)

__all__ = ["DEFAULT_RULES", "RawCollective", "StagePlumb", "SessionBypass",
           "DeprecatedApi", "JitPurity"]
