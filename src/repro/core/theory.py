"""Closed-form theory quantities from the paper (used by tests/benches).

- Thm 1/2: d_min bounds for CLUGP vs Holl and the RF upper bound (Eq. 4/5).
- Thm 5:   λ range.
- Thm 6:   game round bound Σ|e(c_i, V\\c_i)|.
- Thm 7/8: PoA ≤ k+1, PoS ≤ 2.
"""
from __future__ import annotations

import numpy as np

from .game import ClusterGraph, global_cost


def d_min_clugp(r: np.ndarray | int, vmax: float, dmax: float) -> np.ndarray:
    """Eq. 8: min degree of a vertex replicated r≥2 times under CLUGP."""
    r = np.asarray(r, dtype=np.float64)
    return (vmax - 1.0) * (1.0 - (1.0 - 1.0 / (1.0 + dmax)) ** (r - 1.0)) + 2.0


def d_min_holl(r: np.ndarray | int) -> np.ndarray:
    """§IV-B: Holl replicates a degree-(r-1) vertex r times in the worst case."""
    return np.maximum(np.asarray(r, dtype=np.float64) - 1.0, 1.0)


def rf_upper_bound(m: int, gamma: float, alpha: float,
                   d_min_fn, **kw) -> float:
    """Eq. 4/5 with θ_r = (γ/(d_min(r)-1))^(α-1)."""
    rs = np.arange(max(2, int(gamma)), m)
    d = np.maximum(d_min_fn(rs, **kw) if kw else d_min_fn(rs), 1.0 + 1e-9)
    theta = np.minimum((gamma / (d - 1.0)) ** (alpha - 1.0), 1.0)
    return 1.0 + float(theta.sum())


def game_round_bound(cg: ClusterGraph) -> float:
    """Thm 6: rounds ≤ Σ_i |e(c_i, V\\c_i)| (symmetrized boundary /2)."""
    return float(cg.adj.sum()) / 2.0


def poa_bound(k: int) -> float:
    return k + 1.0


def pos_bound() -> float:
    return 2.0


def brute_force_optimum(cg: ClusterGraph, k: int, lam: float) -> float:
    """Exhaustive φ(Λ) minimum — only for tiny m (tests of Thm 7/8)."""
    m = cg.m
    assert m * np.log2(k) <= 22, "brute force limited to tiny instances"
    best = np.inf
    assign = np.zeros(m, dtype=np.int64)
    total = k ** m
    for code in range(total):
        x = code
        for i in range(m):
            assign[i] = x % k
            x //= k
        best = min(best, global_cost(cg, assign, k, lam))
    return best


def fit_power_law_alpha(degrees: np.ndarray, d_min: int = 2) -> float:
    """MLE α̂ = 1 + n / Σ ln(d/(d_min-0.5)) (Clauset et al.)."""
    d = degrees[degrees >= d_min].astype(np.float64)
    if d.size == 0:
        return 2.0
    return 1.0 + d.size / float(np.log(d / (d_min - 0.5)).sum())
