"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows + writes results/bench.json.

  PYTHONPATH=src python -m benchmarks.run            # full suite
  PYTHONPATH=src python -m benchmarks.run --quick    # smaller graphs
  PYTHONPATH=src python -m benchmarks.run --tiny --tag smoke   # CI smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke profile: scale-8 graphs, k=4, core "
                         "suites only (seconds, not minutes)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--tag", default=None,
                    help="also write results/BENCH_<tag>.json")
    args = ap.parse_args()
    scale = 11 if args.quick else 12

    from . import bench_partitioning as bp
    from .bench_pagerank import (fig8_pagerank, layout_build_bench,
                                 program_matrix_bench)
    from .bench_kernels import kernels_microbench
    from .bench_expert_placement import expert_placement_bench

    if args.tiny:
        suites = {
            "fig3_rf_web": lambda: bp.fig3_rf_vs_partitions(
                scale=8, ks=(4,)),
            "fig7_runtime": lambda: bp.fig7_runtime_vs_k(
                scale=8, ks=(4,)),
            # backend sweep incl. the sharded-backend smoke (runs on the
            # CI job's 8 virtual devices; skips itself when too few)
            "fig12_runtime": lambda: bp.fig12_runtime_vs_k(
                scale=8, ks=(4,), nodes=4, repeats=1),
            "fig8_pagerank": lambda: fig8_pagerank(scale=8, k=4, iters=10),
            # one row per GAS program (modelled bytes per exchange +
            # oracle error) and the fused-vs-separate ratio column
            "program_matrix": lambda: program_matrix_bench(
                scale=8, k=4, iters=10),
            "layout_build": lambda: layout_build_bench(scale=8, k=4),
            "expert_placement": lambda: expert_placement_bench(
                E=16, K=2, shards=4),
        }
        run_suites(suites, args)
        return

    suites = {
        "fig3_rf_web": lambda: bp.fig3_rf_vs_partitions(scale=scale),
        "fig4_social": lambda: bp.fig4_social(scale=scale),
        "fig5_size": lambda: bp.fig5_graph_size(
            scales=tuple(range(scale - 2, scale + 1))),
        "fig6_space": lambda: bp.fig6_space(scale=scale),
        "fig7_runtime": lambda: bp.fig7_runtime_vs_k(scale=scale),
        "fig8_pagerank": lambda: fig8_pagerank(scale=scale - 1),
        "program_matrix": lambda: program_matrix_bench(scale=scale - 2),
        "layout_build": lambda: layout_build_bench(scale=scale),
        "fig9_ablation": lambda: bp.fig9_ablation(scale=scale),
        "fig10_parallel": lambda: bp.fig10_parallelization(scale=scale),
        "fig12_runtime": lambda: bp.fig12_runtime_vs_k(
            scale=scale, ks=(16, 64), nodes=4),
        "fig11_weight": lambda: bp.fig11_weight_and_balance(scale=scale),
        "kernels": kernels_microbench,
        "expert_placement": expert_placement_bench,
    }
    run_suites(suites, args)


def run_suites(suites: dict, args) -> None:
    if args.only:
        suites = {k: v for k, v in suites.items() if args.only in k}

    all_rows = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}:{e}", file=sys.stderr)
            raise
        dt = time.time() - t0
        all_rows.extend(rows)
        for r in rows:
            derived = ";".join(f"{k}={v}" for k, v in r.items()
                               if k != "bench")
            print(f"{r.get('bench', name)},"
                  f"{r.get('us_per_edge', round(1e6 * dt / max(len(rows), 1), 1))},"
                  f"{derived}")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "bench.json").write_text(json.dumps(all_rows, indent=1))
    if args.tag:
        (RESULTS / f"BENCH_{args.tag}.json").write_text(
            json.dumps(all_rows, indent=1))

    # roofline summary appended if dry-run records exist
    try:
        from .roofline import report
        for sub, label in (("dryrun", "baseline"),
                           ("dryrun_opt", "optimized")):
            txt = report(subdir=sub)
            print(f"\n# ---- roofline {label} (single-pod, per-device) ----")
            print(txt)
    except Exception as e:  # noqa: BLE001
        print(f"# roofline unavailable: {e}")


if __name__ == "__main__":
    main()
