"""The CLUGP three-pass pipeline (paper §III) + the parallel variant.

``clugp_partition`` = streaming clustering → cluster-partitioning game →
partition transformation.  Ablations: ``split=False`` (CLUGP-S),
``game=False`` (CLUGP-G, greedy cluster placement).

``clugp_partition_parallel`` mirrors §III-C's distributed mode: the edge
stream is split across ``n_nodes`` (each node clusters + games its local
sub-stream against a private id space) and the per-node edge assignments are
concatenated — the paper's "combine partial partitioning results".
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .clustering import (ClusteringResult, default_vmax,
                         streaming_clustering_np)
from .game import (ClusterGraph, best_response_rounds, contract,
                   greedy_assign, lambda_from_weight, lambda_max)
from .transform import transform_np
from . import metrics


@dataclass
class CLUGPConfig:
    k: int
    tau: float = 1.0
    vmax: float | None = None          # default |E|/k (paper §VI-A)
    split: bool = True                 # CLUGP-S ablation switch
    game: bool = True                  # CLUGP-G ablation switch
    split_degree_factor: float = 0.0   # 0 = paper-faithful; 4 = optimized
    batch_size: int = 6400             # paper §VI-A default
    max_rounds: int = 64
    relative_weight: float | None = None   # Fig. 11b sweep; None ⇒ λ_max
    effective_sizes: bool = False      # beyond-paper: balance |c_i|+boundary
    seed: int = 0

    @staticmethod
    def paper(k: int, **kw) -> "CLUGPConfig":
        """Paper-faithful profile (§VI-A defaults)."""
        return CLUGPConfig(k=k, **kw)

    @staticmethod
    def optimized(k: int, **kw) -> "CLUGPConfig":
        """Beyond-paper profile: the game balances *effective* cluster sizes
        (intra + expected landing of boundary edges) so transform loads match
        game loads — cuts the overflow-spill fraction 2-4× (EXPERIMENTS.md
        §Perf-partitioner); τ=1.1 gives the spill headroom Fig. 11a studies."""
        kw.setdefault("tau", 1.1)
        kw.setdefault("effective_sizes", True)
        return CLUGPConfig(k=k, **kw)


@dataclass
class CLUGPResult:
    assign: np.ndarray
    clustering: ClusteringResult
    cluster_graph: ClusterGraph
    cluster_assign: np.ndarray
    game_rounds: int
    stats: dict = field(default_factory=dict)


def clugp_partition(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                    cfg: CLUGPConfig) -> CLUGPResult:
    E = src.shape[0]
    vmax = cfg.vmax if cfg.vmax is not None else default_vmax(E, cfg.k)
    # Pass 1: streaming clustering
    clus = streaming_clustering_np(src, dst, num_vertices, vmax,
                                   allow_split=cfg.split,
                                   split_degree_factor=cfg.split_degree_factor)
    # Pass 2: cluster partitioning
    cg = contract(src, dst, clus.clu)
    game_cg = cg
    if cfg.effective_sizes:
        boundary = np.asarray(cg.adj.sum(axis=1)).ravel()
        game_cg = ClusterGraph(cg.sizes + boundary, cg.adj,
                               cg.vertex_cluster, cg.m)
    if cfg.game:
        lam = (lambda_max(game_cg, cfg.k) if cfg.relative_weight is None
               else lambda_from_weight(game_cg, cfg.k, cfg.relative_weight))
        game = best_response_rounds(game_cg, cfg.k, lam=lam,
                                    batch_size=cfg.batch_size,
                                    max_rounds=cfg.max_rounds, seed=cfg.seed)
        cluster_assign, rounds = game.assign, game.rounds
    else:
        cluster_assign, rounds = greedy_assign(game_cg, cfg.k), 0
    # Pass 3: transformation
    vertex_part = cluster_assign[np.maximum(clus.clu, 0)].astype(np.int32)
    assign = transform_np(src, dst, vertex_part, clus.deg, clus.divided,
                          cfg.k, cfg.tau)
    res = CLUGPResult(assign, clus, cg, cluster_assign, rounds)
    res.stats = metrics.summarize(src, dst, assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = clus.num_clusters
    res.stats["game_rounds"] = rounds
    return res


def clugp_partition_parallel(src: np.ndarray, dst: np.ndarray,
                             num_vertices: int, cfg: CLUGPConfig,
                             n_nodes: int = 4) -> CLUGPResult:
    """Distributed mode (§III-C): split the stream, run the three passes per
    node on its slice, concatenate the edge assignments."""
    E = src.shape[0]
    if E == 0:
        raise ValueError(
            "clugp_partition_parallel: the edge stream is empty (0 edges); "
            "there is nothing to partition")
    bounds = np.linspace(0, E, n_nodes + 1).astype(np.int64)
    assign = np.zeros(E, dtype=np.int32)
    rounds = 0
    clusters = 0
    last = None
    for i in range(n_nodes):
        lo, hi = bounds[i], bounds[i + 1]
        if hi <= lo:
            continue
        sub_cfg = CLUGPConfig(**{**cfg.__dict__})
        sub = clugp_partition(src[lo:hi], dst[lo:hi], num_vertices, sub_cfg)
        assign[lo:hi] = sub.assign
        rounds = max(rounds, sub.game_rounds)
        clusters += sub.clustering.num_clusters
        last = sub
    res = CLUGPResult(assign, last.clustering, last.cluster_graph,
                      last.cluster_assign, rounds)
    res.stats = metrics.summarize(src, dst, assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = clusters
    res.stats["game_rounds"] = rounds
    return res
