"""CLI: ``python -m repro.analysis --check [--ir]``.

Runs the source lint (always) and the IR self-audit (``--ir``), prints
findings, writes the trend-gated artifact to ``results/ANALYSIS.json``
and exits non-zero on any non-allowlisted finding, allowlist-count
mismatch or failed IR invariant.  ``--check`` is accepted for symmetry
with the other gates (``launch.dryrun --check``); it is the default and
only behaviour.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .lint import repo_root, run_lint


def ir_audit() -> tuple[list[dict], list[str]]:
    """Self-audit: run the jaxpr passes over the repo's own hot bodies.

    Each row mirrors a lint rule row (lower-is-better counts) so the
    trend gate covers compiled-IR health the same way it covers source
    health:

    - ``dtype-drift`` over the stacked quantized pagerank body — the
      wire payload must stay narrow end-to-end;
    - ``scatter-copy`` over the jitted transform scan — the arithmetic
      one-hot rewrite must not regress back to a loop-carried scatter;
    - ``unreduced-divergence`` over the shard_mapped GAS step;
    - ``retrace`` over the transform entry — shape-stable args must
      reuse one trace.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from functools import partial

    from . import ir
    from repro.core import CLUGPConfig, web_graph
    from repro.core.transform import transform_jax
    from repro.graph.engine import _gas_body, _stack_dev, get_exchange
    from repro.session import GraphSession, resolve_program

    errors: list[str] = []
    rows: list[dict] = []

    def row(check: str, sites: list, detail=None):
        rows.append({"bench": "ir_audit", "rule": check,
                     "findings": len(sites), "violations": len(sites),
                     "allowlisted": 0,
                     "detail": detail if detail is not None
                     else [str(s) for s in sites]})
        if sites:
            errors.append(f"{check}: {sites}")

    g = web_graph(scale=8, edge_factor=8, seed=0)
    k = 4
    sess = GraphSession(CLUGPConfig.optimized(k))
    sess.partition(g.src, g.dst, g.num_vertices)
    lay = sess.partition_layout

    # 1. dtype drift in one sweep of the stacked quantized GAS body (the
    #    wire payload must stay u8 codes + f32 scales — no f16→f32
    #    re-promotion, no x64 leak)
    prog = resolve_program("pagerank", g.num_vertices)
    dev = _stack_dev(lay, "quantized")
    ex = get_exchange("quantized", lay)
    body = _gas_body(prog, ex, dev)
    value0 = jax.vmap(prog.init)(dev)
    state0 = ex.init_state(dev, prog.dtype, prog.combine)
    step_jaxpr = ir.make_jaxpr(lambda carry: body(0, carry),
                               (value0, state0))
    row("dtype-drift", ir.dtype_drift(step_jaxpr))

    # 2. loop-carried computed-index scatters in the transform scan
    vp = np.zeros(g.num_vertices, np.int32)
    deg = np.ones(g.num_vertices, np.int32)
    div = np.zeros(g.num_vertices, np.int32)
    tr_jaxpr = ir.make_jaxpr(
        partial(transform_jax, k=k),
        jnp.asarray(g.src, jnp.int32), jnp.asarray(g.dst, jnp.int32),
        jnp.asarray(vp), jnp.asarray(deg), jnp.asarray(div))
    row("scatter-copy", ir.scatter_copy_sites(tr_jaxpr))

    # 3. divergence across the quantized step (stacked body has no
    #    shard_map eqns → trivially clean; still exercises the walker)
    row("unreduced-divergence", ir.unreduced_divergence(step_jaxpr))

    # 4. retraces: 3 same-shape transform calls must share one trace
    arg_sets = [
        (jnp.asarray(g.src, jnp.int32), jnp.asarray(g.dst, jnp.int32),
         jnp.asarray(np.full(g.num_vertices, i % k, np.int32)),
         jnp.asarray(deg), jnp.asarray(div))
        for i in range(3)]
    n = ir.retrace_count(partial(transform_jax, k=k), arg_sets)
    extra = n - 1
    row("retrace", [f"{n} traces for 3 same-shape calls"] if extra else [],
        detail=[f"traces={n}"])
    return rows, errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--check", action="store_true",
                    help="run the lint gate (default behaviour)")
    ap.add_argument("--ir", action="store_true",
                    help="additionally run the IR self-audit (imports "
                         "jax, compiles small cells)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="artifact path (default results/ANALYSIS.json "
                         "under the repo root)")
    ap.add_argument("--root", default=None,
                    help="tree to lint (default: the repo root)")
    ap.add_argument("--verbose", action="store_true",
                    help="also print allowlisted findings")
    args = ap.parse_args(argv)

    root = Path(args.root) if args.root else repo_root()
    report = run_lint(root=root)
    print(report.format(verbose=args.verbose))

    rows = report.summary_rows()
    ir_errors: list[str] = []
    if args.ir:
        ir_rows, ir_errors = ir_audit()
        rows += ir_rows
        for e in ir_errors:
            print(f"ir audit: {e}")
        print(f"ir audit: {len(ir_rows)} check(s), "
              f"{len(ir_errors)} failure(s)")

    out = Path(args.json_out) if args.json_out \
        else root / "results" / "ANALYSIS.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=1))
    print(f"wrote {out}")
    return 0 if report.ok and not ir_errors else 1


if __name__ == "__main__":
    sys.exit(main())
