"""Graph-partitioning launcher — the paper's own workload.

``python -m repro.launch.partition --scale 13 --k 16 --algo clugp-opt``
partitions a synthetic web crawl and reports RF / balance / runtime, then
(optionally) runs distributed PageRank on the result via the shard_map GAS
engine (--pagerank, needs a mesh with k devices or --simulate).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (CLUGPConfig, baselines, clugp_partition,
                        clugp_partition_parallel, metrics, random_stream,
                        web_graph)
from repro.core.graphgen import social_graph


def partition_with(algo: str, g, k: int, seed: int = 0):
    if algo.startswith("clugp"):
        cfg = (CLUGPConfig.optimized(k) if algo == "clugp-opt"
               else CLUGPConfig.paper(k))
        res = clugp_partition(g.src, g.dst, g.num_vertices, cfg)
        return res.assign
    if algo == "clugp-parallel":
        res = clugp_partition_parallel(g.src, g.dst, g.num_vertices,
                                       CLUGPConfig.optimized(k), n_nodes=4)
        return res.assign
    gr = random_stream(g, seed=seed)
    a = baselines.ALL_BASELINES[algo](gr.src, gr.dst, g.num_vertices, k)
    # map back to the original stream order for downstream use
    out = np.zeros_like(a)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.num_edges)
    out[perm] = a
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--algo", default="clugp-opt",
                    choices=["clugp", "clugp-opt", "clugp-parallel",
                             "hashing", "dbh", "greedy", "hdrf", "mint"])
    ap.add_argument("--graph", default="web", choices=["web", "social"])
    ap.add_argument("--pagerank", action="store_true")
    ap.add_argument("--exchange", default="halo",
                    choices=["dense", "halo", "quantized"],
                    help="mirror-sync wire format for --pagerank")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g = (web_graph(scale=args.scale, seed=args.seed) if args.graph == "web"
         else social_graph(n=1 << args.scale, seed=args.seed))
    print(f"graph: V={g.num_vertices} E={g.num_edges}")
    t0 = time.time()
    assign = partition_with(args.algo, g, args.k, args.seed)
    dt = time.time() - t0
    rf = metrics.replication_factor(g.src, g.dst, assign, g.num_vertices,
                                    args.k)
    bal = metrics.load_balance(assign, args.k)
    print(f"{args.algo}: rf={rf:.3f} balance={bal:.3f} "
          f"time={dt:.2f}s ({1e6*dt/g.num_edges:.2f} µs/edge)")

    if args.pagerank:
        from repro.graph import (build_layout, reference_pagerank,
                                 simulate_pagerank)
        lay = build_layout(g.src, g.dst, assign, g.num_vertices, args.k)
        t0 = time.time()
        pr = simulate_pagerank(lay, iters=30, exchange=args.exchange)
        dt = time.time() - t0
        ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
        print(f"pagerank[{args.exchange}]: {dt:.2f}s  "
              f"max|err|={np.abs(pr-ref).max():.2e}  "
              f"comm/iter: ideal={lay.comm_bytes_ideal()/1e6:.2f}MB "
              f"quantized={lay.comm_bytes_halo_quantized()/1e6:.2f}MB "
              f"halo={lay.comm_bytes_halo()/1e6:.2f}MB "
              f"dense-gather={lay.comm_bytes_mirror_sync()/1e6:.2f}MB "
              f"allreduce={lay.comm_bytes_dense()/1e6:.2f}MB")


if __name__ == "__main__":
    main()
