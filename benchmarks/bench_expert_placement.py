"""Beyond-paper: CLUGP game as MoE expert placement (DESIGN.md §4).
Measures cross-shard all-to-all hops under round-robin vs game placement
on a synthetic correlated-routing workload (topic-clustered experts)."""
from __future__ import annotations

import numpy as np

from repro.core.expert_placement import a2a_volume, place_experts


def _correlated_routing(T=20000, E=64, K=2, n_topics=8, seed=0):
    """Tokens draw a topic; topics prefer a clique of experts."""
    rng = np.random.default_rng(seed)
    topic_of = rng.integers(0, n_topics, T)
    cliques = rng.permutation(E).reshape(n_topics, E // n_topics)
    top = np.zeros((T, K), dtype=np.int64)
    for t in range(T):
        cl = cliques[topic_of[t]]
        if rng.random() < 0.85:
            top[t] = rng.choice(cl, K, replace=False)
        else:
            top[t] = rng.choice(E, K, replace=False)
    return top


def expert_placement_bench(E=64, K=2, shards=8, seed=0):
    top = _correlated_routing(E=E, K=K, seed=seed)
    rr = np.arange(E) // (E // shards)                 # round-robin blocks
    perm = place_experts(top, E, shards, seed=seed)
    game = perm // (E // shards)
    rows = [{
        "bench": "expert_placement", "experts": E, "topk": K,
        "shards": shards,
        "a2a_roundrobin": a2a_volume(top, rr, shards),
        "a2a_clugp_game": a2a_volume(top, game, shards),
    }]
    r = rows[0]
    r["reduction"] = round(1 - r["a2a_clugp_game"] / r["a2a_roundrobin"], 4)
    return rows
