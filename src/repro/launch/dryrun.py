import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this AOT-compiles the real train/prefill/decode step against
ShapeDtypeStruct inputs (no allocation), prints memory_analysis() (fits?)
and cost_analysis() (FLOPs/bytes), parses collective bytes out of the
post-SPMD HLO, and appends a JSON record consumed by the roofline report
(benchmarks/roofline.py → EXPERIMENTS.md §Dry-run/§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import sys
import time
import traceback
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.dist.halo import EXCHANGE_NAMES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, cell_is_skipped, input_specs
from repro.dist.sharding import (CP_SERVE_RULES, MULTI_POD_RULES,
                                 SINGLE_POD_RULES, use_rules)
from repro.models import abstract_params
from repro.train import (batch_specs, cache_specs, get_optimizer,
                         make_decode_fn, make_prefill_step, make_train_step,
                         param_specs)
from repro.train.shardings import sanitize_specs


def _shardings(specs, sds, mesh):
    specs = sanitize_specs(specs, sds, mesh)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))

RESULTS = Path(__file__).resolve().parents[3] / "results"

# v5e hardware constants (assignment §ROOFLINE)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

# The HLO collective parsers moved to repro.analysis.ir (PR 10) — the
# names below are deprecation shims so external `dryrun.collective_bytes`
# callers keep working; in-file call sites use the ir implementations.
from repro.analysis.ir import (COLLECTIVE_KINDS, DTYPE_BYTES,  # noqa: F401
                               SHAPE_RE)
from repro.analysis.ir import collective_bytes as _collective_bytes
from repro.analysis.ir import \
    collective_permute_count as _collective_permute_count


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on new jax, [dict] on 0.4.x."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def collective_bytes(hlo_text: str) -> dict:
    """Deprecated shim — use ``repro.analysis.ir.collective_bytes``."""
    warnings.warn(
        "repro.launch.dryrun.collective_bytes moved to "
        "repro.analysis.ir.collective_bytes", DeprecationWarning,
        stacklevel=2)
    return _collective_bytes(hlo_text)


def collective_permute_count(hlo_text: str) -> int:
    """Deprecated shim — use
    ``repro.analysis.ir.collective_permute_count``."""
    warnings.warn(
        "repro.launch.dryrun.collective_permute_count moved to "
        "repro.analysis.ir.collective_permute_count", DeprecationWarning,
        stacklevel=2)
    return _collective_permute_count(hlo_text)


def zero_default(cfg) -> bool:
    from repro.models import param_count
    # ZeRO-shard anything ≥ ~8B params (replicated fp32 wouldn't fit HBM)
    return param_count(cfg, mp=16) >= 8e9


def optimizer_default(cfg) -> str:
    from repro.models import param_count
    return "adafactor" if param_count(cfg, mp=16) >= 3e10 else "adamw"


def cfg_with_counts(cfg, counts: dict):
    """A config whose layer_groups() counts equal ``counts`` — the probe
    models for per-layer cost extrapolation."""
    import dataclasses
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_encoder_layers=counts["enc"],
                                   n_layers=counts["dec"])
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg,
                                   n_layers=counts["hyb"] * cfg.attn_period)
    if cfg.family == "ssm":
        return dataclasses.replace(cfg, n_layers=counts["ssd"])
    if cfg.moe is not None and cfg.moe.first_k_dense:
        moe = dataclasses.replace(cfg.moe, first_k_dense=counts["dense"])
        return dataclasses.replace(
            cfg, moe=moe, n_layers=counts["dense"] + counts["moe"])
    if cfg.moe is not None:
        return dataclasses.replace(cfg, n_layers=counts["moe"])
    return dataclasses.replace(cfg, n_layers=counts["dense"])


def build_cell(cfg, shape_name: str, mesh, rules, *, mp: int,
               multi_pod: bool, block_kv: int = 1024, loss_chunk: int = 512,
               zero: bool | None = None, unroll: bool = False,
               compress: bool = False):
    """Returns (jitted_fn, example_args_shapes) for lowering."""
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        params_sds = abstract_params(cfg, mp)
        zero = zero_default(cfg) if zero is None else zero
    else:
        # serving: bf16 weights, no optimizer ⇒ drop ZeRO *when the bf16
        # weights fit replicated over data* (≤8 GB/device after TP) —
        # removes every per-layer all-gather from the serve path
        # (hillclimb #3).  ≥100B archs keep data-axis weight sharding.
        from repro.models import param_count
        params_sds = abstract_params(cfg, mp, dtype=jnp.bfloat16)
        if zero is None:
            zero = (2 * param_count(cfg, mp=mp) / mesh.shape["model"]) \
                > 8 * 2**30
    pspecs = param_specs(params_sds, zero=zero, multi_pod=multi_pod)
    p_shardings = _shardings(pspecs, params_sds, mesh)
    specs = input_specs(cfg, shape_name, mp=mp)

    if kind == "train":
        opt = get_optimizer(optimizer_default(cfg))
        opt_sds = jax.eval_shape(opt.init, params_sds)
        o_specs = param_specs(opt_sds, zero=zero, multi_pod=multi_pod)
        o_shardings = _shardings(o_specs, opt_sds, mesh)
        b_specs = batch_specs(specs["batch"], multi_pod=multi_pod)
        b_shardings = _shardings(b_specs, specs["batch"], mesh)
        compress_fn = None
        if compress:
            from repro.dist.compress import make_grad_compressor
            compress_fn = make_grad_compressor()
        step_fn = make_train_step(cfg, opt, mp=mp, block_kv=block_kv,
                                  loss_chunk=loss_chunk, unroll=unroll,
                                  compress_grads=compress_fn)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shardings, o_shardings, b_shardings, None),
            out_shardings=(p_shardings, o_shardings, None))
        args = (params_sds, opt_sds, specs["batch"],
                jax.ShapeDtypeStruct((), jnp.int32))
        return jitted, args

    if kind == "prefill":
        b_specs = batch_specs(specs["batch"], multi_pod=multi_pod)
        b_shardings = _shardings(b_specs, specs["batch"], mesh)
        fn = make_prefill_step(cfg, mp=mp, block_kv=block_kv,
                               unroll=unroll)
        jitted = jax.jit(fn, in_shardings=(p_shardings, b_shardings))
        return jitted, (params_sds, specs["batch"])

    # decode
    c_specs = cache_specs(specs["cache"], multi_pod=multi_pod)
    c_shardings = _shardings(c_specs, specs["cache"], mesh)
    da = ("pod", "data") if multi_pod else "data"
    tok_sh = _shardings(P(da, None), specs["tokens"], mesh)
    fn = make_decode_fn(cfg, mp=mp, unroll=unroll)
    if cfg.family == "encdec":
        mem_sh = _shardings(P(da, None, None), specs["memory"], mesh)
        jitted = jax.jit(
            lambda p, c, t, i, m: fn(p, c, t, i, memory=m),
            in_shardings=(p_shardings, c_shardings, tok_sh, None, mem_sh),
            out_shardings=(None, c_shardings))
        args = (params_sds, specs["cache"], specs["tokens"],
                specs["index"], specs["memory"])
    else:
        jitted = jax.jit(
            fn,
            in_shardings=(p_shardings, c_shardings, tok_sh, None),
            out_shardings=(None, c_shardings))
        args = (params_sds, specs["cache"], specs["tokens"], specs["index"])
    return jitted, args


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             block_kv: int = 1024, loss_chunk: int = 512, tag: str = "",
             mp_override: int | None = None, rules_name: str = "tp",
             compress: bool = False) -> dict:
    cfg = get_config(arch)
    compress = compress and SHAPES[shape_name]["kind"] == "train"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "tag": tag or "baseline", "compress_grads": compress}
    skip = cell_is_skipped(cfg, shape_name)
    if skip:
        rec["status"] = skip
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}.json").write_text(
            json.dumps(rec, indent=1))
        print(f"[{arch} × {shape_name} × {mesh_kind}] {skip}")
        return rec
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = MULTI_POD_RULES if multi_pod else SINGLE_POD_RULES
    if rules_name == "cp":
        rules = CP_SERVE_RULES
    mp = mp_override or (1 if rules_name == "cp" else mesh.shape["model"])
    t0 = time.time()
    try:
        with use_rules(rules, mesh):
            jitted, args = build_cell(cfg, shape_name, mesh, rules, mp=mp,
                                      multi_pod=multi_pod,
                                      block_kv=block_kv,
                                      loss_chunk=loss_chunk,
                                      compress=compress)
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            if compress:
                # surface the collective-byte delta vs the uncompressed
                # step (ROADMAP open item): compile the baseline too
                base_jit, base_args = build_cell(
                    cfg, shape_name, mesh, rules, mp=mp,
                    multi_pod=multi_pod, block_kv=block_kv,
                    loss_chunk=loss_chunk, compress=False)
                base_coll = _collective_bytes(
                    base_jit.lower(*base_args).compile().as_text())
        mem = compiled.memory_analysis()
        cost = cost_dict(compiled)
        hlo = compiled.as_text()
        coll = _collective_bytes(hlo)
        if compress:
            rec["collective_bytes_uncompressed"] = base_coll
            rec["collective_delta_bytes"] = base_coll["total"] - coll["total"]
            print(f"  compress-grads delta: {base_coll['total']:.3e}B → "
                  f"{coll['total']:.3e}B "
                  f"({rec['collective_delta_bytes']:+.3e}B)")
        rec.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes": coll,
            "memory": {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes",
                                              0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak_bytes": int(
                    getattr(mem, "peak_memory_in_bytes",
                            getattr(mem, "temp_size_in_bytes", 0))),
            },
            "n_devices": mesh.size,
        })
        print(f"[{arch} × {shape_name} × {mesh_kind} × {rec['tag']}] OK  "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s  "
              f"flops={rec['flops']:.3e}  coll={coll['total']:.3e}B")
        print("  memory_analysis:", rec["memory"])
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = f"FAIL: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[{arch} × {shape_name} × {mesh_kind}] FAIL: {e}",
              file=sys.stderr)
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = out_dir / f"{arch}__{shape_name}__{mesh_kind}" \
        f"{('__' + tag) if tag else ''}.json"
    fname.write_text(json.dumps(rec, indent=1))
    return rec


# every engine wire format, straight from the exchange registry —
# dryrun stopped re-spelling the list
GRAPH_EXCHANGES = EXCHANGE_NAMES

# the padded all_to_all backends count a self lane in their HLO output
# shape that never crosses the wire; the ragged ppermute ring has no
# self hop, so its HLO bytes ARE the wire bytes
SELF_LANE_EXCHANGES = ("halo", "quantized")
# the fused-vs-separate CI gate compiles this homogeneous (f32, sum)
# bundle as ONE fused step and compares its wire bytes against the sum
# of the three separate quantized steps (threshold FUSED_GATE_RATIO)
FUSED_BUNDLE = ("pagerank", "ppr", "centrality")
FUSED_GATE_RATIO = 0.6
# the overlapped ragged body re-orders interior compute around the k−1
# ppermute ring hops (per-hop partial combine).  CI compiles these cells
# with overlap=True and requires wire bytes AND collective-permute count
# identical to the phase-ordered cell: overlap hides hop latency, it
# must never add, drop, or grow a hop.
OVERLAP_CELLS = (("pagerank", "ragged"), ("sssp", "ragged"),
                 ("pagerank", "ragged_quantized"))
# the early-exit cell EXECUTES pagerank under tol on the bench graph and
# gates iters_run strictly under the cap, with the tol run's values
# bit-identical to a fixed-iters run at the reported iters_run
EARLY_EXIT_TOL = 1e-6
EARLY_EXIT_CAP = 60


def _graph_comm_model(lay, exchange: str, lossy: bool) -> int:
    """The layout's modelled bytes/iter for one (program, backend) cell.
    ``lossy`` is ``halo.lossy_payload(program.combine, program.dtype)`` —
    min/int programs (CC labels) ship the exact full-width payload on
    the quantized backends, so their model is the exact-wire volume."""
    return lay.comm_bytes(exchange, lossy=lossy)


def run_graph_cell(out_dir: Path, scale: int = 10, k: int = 8,
                   iters: int = 1, tag: str = "") -> list[dict]:
    """GAS-engine dry-run: lower one GAS step per (program × exchange
    backend) on a k-device mesh — the full ``repro.graph`` program
    library (pagerank/cc/labelprop/sssp/bfs/degree/centrality/ppr)
    across dense / halo / quantized — and parse the measured collective
    bytes out of the post-SPMD HLO, next to the layout's modelled
    volumes.  A final fused cell compiles the ``FUSED_BUNDLE`` programs
    as ONE multi-program step (single exchange per phase, int4 fused
    wire) so ``check_graph_ordering`` can gate fused < 0.6 × Σ separate.
    One JSON record per cell; the full table also lands in
    ``results/BENCH_dryrun.json`` (the CI ``graph-dryrun`` job's
    artifact and regression gate).

    HLO bytes are per-device; ×k (minus the all_to_all self lane, which
    never crosses the wire) gives the fleet wire volume comparable to
    the ``PartitionLayout.comm_bytes(exchange)`` models and the
    ``comm_bytes("ideal")`` lower bound.

    The whole partition → layout → GAS-cell chain is driven through the
    ``GraphSession`` façade — this function only owns the HLO parsing and
    the record bookkeeping.
    """
    from repro.core import CLUGPConfig, web_graph
    from repro.dist.halo import lossy_payload
    from repro.graph import PROGRAM_NAMES
    from repro.launch.mesh import make_graph_mesh
    from repro.session import GraphSession, SessionConfig, resolve_program

    g = web_graph(scale=scale, edge_factor=8, seed=0)
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig.optimized(k)))
    sess.partition(g.src, g.dst, g.num_vertices).layout()
    lay = sess.partition_layout
    mesh = make_graph_mesh(k)
    base = {"bench": "graph_dryrun", "k": k, "scale": scale,
            "iters": iters, "num_vertices": g.num_vertices,
            "num_edges": g.num_edges, "l_max": lay.l_max,
            "h_max": lay.h_max, "mirrors": lay.mirrors_total,
            "comm_bytes_ideal": lay.comm_bytes("ideal")}

    def compile_cell(rec, step_arg, exchange, overlap=False):
        t0 = time.time()
        try:
            jitted, args = sess.dryrun_step(step_arg, mesh=mesh,
                                            iters=iters,
                                            exchange=exchange,
                                            overlap=overlap)
            compiled = jitted.lower(*args).compile()
            hlo = compiled.as_text()
            coll = _collective_bytes(hlo)
            total = coll["total"] * k
            # collectives sit once in the fori_loop body, so the HLO
            # count (and the self-lane correction) is per iteration
            # whatever ``iters`` is.  The all_to_all self lane (counted
            # by the HLO output shape, never on the wire) carries one
            # lane group's payload: model / (2 phases × k·(k−1) groups)
            # — which generalizes to the fused cell's N-program rows.
            # The ragged ppermute ring has no self hop (distances run
            # 1..k−1), and dense all_gathers none either: correction 0.
            self_lane = (rec["comm_bytes_model"] // (2 * k * (k - 1))
                         if exchange in SELF_LANE_EXCHANGES else 0)
            wire = total - 2 * k * self_lane
            rec.update({
                "status": "ok",
                "compile_s": round(time.time() - t0, 1),
                "collective_bytes_per_device": coll,
                "collective_bytes_total": total,
                "collective_bytes_wire": wire,
                "collective_permute_count": _collective_permute_count(hlo),
            })
            ov = " × overlap" if overlap else ""
            print(f"[graph × {rec['program']} × {exchange}{ov}] OK  "
                  f"hlo={wire:.3e}B/iter (fleet wire)  "
                  f"model={rec['comm_bytes_model']:.3e}B  "
                  f"ideal={rec['comm_bytes_ideal']:.3e}B")
        except Exception as e:  # noqa: BLE001
            rec["status"] = f"FAIL: {type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-2000:]
            print(f"[graph × {rec['program']} × {exchange}] FAIL: {e}",
                  file=sys.stderr)
        return rec

    recs = []
    for pname in PROGRAM_NAMES:
        prog = resolve_program(pname, g.num_vertices)
        lossy = lossy_payload(prog.combine, prog.dtype)
        for exchange in GRAPH_EXCHANGES:
            rec = {**base, "program": pname, "exchange": exchange,
                   "fused": False, "overlap": False,
                   "lossy_payload": lossy,
                   "comm_bytes_model": _graph_comm_model(lay, exchange,
                                                         lossy)}
            recs.append(compile_cell(rec, pname, exchange))
        ok = {r["exchange"]: r for r in recs
              if r["program"] == pname and r.get("status") == "ok"}
        if len(ok) == len(GRAPH_EXCHANGES):
            d = ok["dense"]["collective_bytes_wire"]
            h = ok["halo"]["collective_bytes_wire"]
            q = ok["quantized"]["collective_bytes_wire"]
            rg = ok["ragged"]["collective_bytes_wire"]
            rq = ok["ragged_quantized"]["collective_bytes_wire"]
            print(f"  {pname}: dense→halo {h / max(d, 1):.3f}×  "
                  f"halo→quantized {q / max(h, 1):.3f}×  "
                  f"halo→ragged {rg / max(h, 1):.3f}×  "
                  f"quantized→ragged_q {rq / max(q, 1):.3f}×  "
                  f"(ideal/dense = "
                  f"{ok['dense']['comm_bytes_ideal'] / max(d, 1):.3f})")

    # the fused cell: FUSED_BUNDLE as ONE multi-program quantized step
    bundle = [resolve_program(p, g.num_vertices) for p in FUSED_BUNDLE]
    lossy = lossy_payload(bundle[0].combine, bundle[0].dtype)
    rec = {**base, "program": "+".join(FUSED_BUNDLE),
           "exchange": "quantized", "fused": True, "overlap": False,
           "fused_programs": list(FUSED_BUNDLE), "lossy_payload": lossy,
           "comm_bytes_model": lay.comm_bytes(
               "quantized", programs=len(bundle), fused=True, lossy=lossy)}
    rec = compile_cell(rec, list(FUSED_BUNDLE), "quantized")
    recs.append(rec)
    sep = [r for r in recs
           if r["program"] in FUSED_BUNDLE and r["exchange"] == "quantized"
           and r.get("status") == "ok"]
    if rec.get("status") == "ok" and len(sep) == len(FUSED_BUNDLE):
        total_sep = sum(r["collective_bytes_wire"] for r in sep)
        print(f"  fused {rec['program']}: "
              f"{rec['collective_bytes_wire']:.3e}B vs separate "
              f"{total_sep:.3e}B → "
              f"{rec['collective_bytes_wire'] / max(total_sep, 1):.3f}× "
              f"(gate < {FUSED_GATE_RATIO})")

    # overlapped ragged cells: interior compute interleaved with the
    # ring hops — same traffic, same hop count, by construction and gate
    for pname, exchange in OVERLAP_CELLS:
        prog = resolve_program(pname, g.num_vertices)
        lossy = lossy_payload(prog.combine, prog.dtype)
        rec = {**base, "program": pname, "exchange": exchange,
               "fused": False, "overlap": True, "lossy_payload": lossy,
               "comm_bytes_model": _graph_comm_model(lay, exchange,
                                                     lossy)}
        recs.append(compile_cell(rec, pname, exchange, overlap=True))

    # early-exit executed cell: pagerank under tol, then a fixed-iters
    # rerun at the reported iters_run — must be bit-identical
    import numpy as np
    try:
        t0 = time.time()
        v_tol, iters_run = sess.run(
            "pagerank", iters=EARLY_EXIT_CAP, exchange="ragged",
            tol=EARLY_EXIT_TOL, return_iters=True)
        v_fix = sess.run("pagerank", iters=int(iters_run),
                         exchange="ragged")
        rec = {**base, "program": "pagerank", "exchange": "ragged",
               "fused": False, "overlap": False, "tol": EARLY_EXIT_TOL,
               "iters_cap": EARLY_EXIT_CAP, "iters_run": int(iters_run),
               "early_exit_bitmatch":
                   bool(np.array_equal(np.asarray(v_tol),
                                       np.asarray(v_fix))),
               "status": "ok",
               "compile_s": round(time.time() - t0, 1)}
        print(f"[graph × pagerank × ragged × tol={EARLY_EXIT_TOL}] OK  "
              f"iters_run={rec['iters_run']}/{EARLY_EXIT_CAP}  "
              f"bitmatch={rec['early_exit_bitmatch']}")
    except Exception as e:  # noqa: BLE001
        rec = {**base, "program": "pagerank", "exchange": "ragged",
               "fused": False, "overlap": False, "tol": EARLY_EXIT_TOL,
               "iters_cap": EARLY_EXIT_CAP,
               "status": f"FAIL: {type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        print(f"[graph × pagerank × ragged × tol] FAIL: {e}",
              file=sys.stderr)
    recs.append(rec)
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = out_dir / (f"graph__gas__k{k}"
                       f"{('__' + tag) if tag else ''}.json")
    fname.write_text(json.dumps(recs, indent=1))
    bench_rows = [{kk: v for kk, v in r.items() if kk != "traceback"}
                  for r in recs]
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_dryrun.json").write_text(
        json.dumps(bench_rows, indent=1))
    return recs


def check_graph_ordering(recs: list[dict]) -> list[str]:
    """The CI regression gate on the paper's headline quantity: **per
    program**, measured wire bytes/iter must order quantized < halo <
    dense, and the ragged ring must never ship more than its padded
    counterpart: ragged ≤ halo (equality only when every distance's lane
    count is already H_max) and, for lossy payloads, ragged_quantized <
    quantized.  Programs whose quantized cells ship an exact payload
    (min/int — the record's ``lossy_payload`` flag, derived from the
    program spec) allow quantized == halo and require ragged_quantized ==
    ragged (the non-lossy ragged_quantized path delegates to the exact
    ring).  ragged_quantized vs ragged is deliberately NOT gated: at tiny
    per-hop lane counts the index+scale overhead (3·T+4 vs 4·H bytes)
    can exceed the exact payload.  Fused rows (``fused: true``) are
    excluded from the per-program ordering and instead gate the fused
    win: the fused step's wire bytes must be < ``FUSED_GATE_RATIO`` × the
    sum of its bundle programs' separate quantized steps.  Overlap rows
    (``overlap: true``) gate the interleaved ragged body: wire bytes and
    collective-permute count must equal the phase-ordered cell exactly.
    Early-exit rows (``tol`` set) gate ``iters_run`` strictly under the
    cap with the tol run bit-identical to a fixed-iters run at
    ``iters_run``.  Returns the list of violations (empty == pass)."""
    msgs = [f"{r.get('program', '?')}/{r.get('exchange', '?')}: "
            f"{r.get('status')}"
            for r in recs if r.get("status") != "ok"]
    by = {(r["program"], r["exchange"]): r
          for r in recs if r.get("status") == "ok" and not r.get("fused")
          and not r.get("overlap") and r.get("tol") is None}
    for prog in sorted({p for p, _ in by}):
        cells = {e: by.get((prog, e)) for e in GRAPH_EXCHANGES}
        if any(c is None for c in cells.values()):
            continue    # the missing cell is already reported above
        wire = {e: c["collective_bytes_wire"] for e, c in cells.items()}
        d, h, q = wire["dense"], wire["halo"], wire["quantized"]
        rg, rq = wire["ragged"], wire["ragged_quantized"]
        if h >= d:
            msgs.append(f"{prog}: halo bytes/iter {h} ≥ dense {d}")
        if rg > h:
            msgs.append(f"{prog}: ragged bytes/iter {rg} > halo {h}")
        if cells["quantized"].get("lossy_payload", True):
            if q >= h:
                msgs.append(f"{prog}: quantized bytes/iter {q} ≥ halo {h}")
            if rq >= q:
                msgs.append(f"{prog}: ragged_quantized bytes/iter {rq} "
                            f"≥ quantized {q}")
        else:
            if q > h:
                msgs.append(f"{prog}: quantized bytes/iter {q} > halo {h}")
            if rq != rg:
                msgs.append(f"{prog}: exact-payload ragged_quantized "
                            f"bytes/iter {rq} != ragged {rg}")
    for r in recs:
        if not r.get("fused") or r.get("status") != "ok":
            continue
        bundle = r.get("fused_programs") or r["program"].split("+")
        sep = [by.get((p, "quantized")) for p in bundle]
        if None in sep:
            missing = [p for p, c in zip(bundle, sep) if c is None]
            msgs.append(f"{r['program']}: fused gate needs separate "
                        f"quantized cells for {missing}")
            continue
        total_sep = sum(c["collective_bytes_wire"] for c in sep)
        fused_wire = r["collective_bytes_wire"]
        if fused_wire >= FUSED_GATE_RATIO * total_sep:
            msgs.append(
                f"{r['program']}: fused bytes/iter {fused_wire} ≥ "
                f"{FUSED_GATE_RATIO} × Σ separate ({total_sep})")
    # overlap gate: the interleaved body is a pure re-ordering — wire
    # bytes and collective-permute count must equal the phase-ordered
    # cell exactly
    for r in recs:
        if not r.get("overlap") or r.get("status") != "ok":
            continue
        ref = by.get((r["program"], r["exchange"]))
        if ref is None:
            msgs.append(f"{r['program']}/{r['exchange']}: overlap gate "
                        f"needs the phase-ordered cell")
            continue
        if r["collective_bytes_wire"] != ref["collective_bytes_wire"]:
            msgs.append(
                f"{r['program']}/{r['exchange']}: overlapped bytes/iter "
                f"{r['collective_bytes_wire']} != phase-ordered "
                f"{ref['collective_bytes_wire']}")
        if (r.get("collective_permute_count")
                != ref.get("collective_permute_count")):
            msgs.append(
                f"{r['program']}/{r['exchange']}: overlapped "
                f"collective-permute count "
                f"{r.get('collective_permute_count')} != phase-ordered "
                f"{ref.get('collective_permute_count')}")
    # early-exit gate: tol must stop strictly before the cap, and the
    # tol run must be bit-identical to a fixed run at iters_run
    for r in recs:
        if (r.get("tol") is None or r.get("fused")
                or r.get("status") != "ok"):
            continue
        if not r["iters_run"] < r["iters_cap"]:
            msgs.append(
                f"{r['program']}/{r['exchange']}: tol={r['tol']} ran "
                f"iters_run={r['iters_run']} — not strictly under the "
                f"cap {r['iters_cap']}")
        if not r.get("early_exit_bitmatch"):
            msgs.append(
                f"{r['program']}/{r['exchange']}: tol run not "
                f"bit-identical to fixed-iters run at "
                f"iters_run={r.get('iters_run')}")
    return msgs


def _lower_probe(cfg, shape_name, mesh, rules, *, mp, block_kv, loss_chunk):
    """Compile one probe model (all scans UNROLLED) and return its raw
    flops/bytes/collective-bytes — trip counts are real in the HLO text."""
    from repro.dist.sharding import use_rules as _ur
    with _ur(rules, mesh):
        jitted, args = build_cell(cfg, shape_name, mesh, rules, mp=mp,
                                  multi_pod=False, block_kv=block_kv,
                                  loss_chunk=loss_chunk, unroll=True)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    cost = cost_dict(compiled)
    coll = _collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"])}


def run_probe_cell(arch: str, shape_name: str, out_dir: Path,
                   block_kv: int = 1024, loss_chunk: int = 512,
                   tag: str = "", rules_name: str = "tp") -> dict:
    """Per-layer cost extrapolation on the single-pod mesh:
    total = outside + Σ_g L_g · layer_g, where layer_g comes from
    (counts[g]=2) − (counts[g]=1) probe compiles with unrolled scans.
    (XLA:CPU's cost analysis counts while bodies once — see EXPERIMENTS.md
    §Method; probes make every trip count explicit.)"""
    from repro.models.lm import layer_groups
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": "single",
           "tag": (tag or "baseline") + "-probe"}
    skip = cell_is_skipped(cfg, shape_name)
    if skip:
        rec["status"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=False)
    rules = CP_SERVE_RULES if rules_name == "cp" else SINGLE_POD_RULES
    mp = 1 if rules_name == "cp" else mesh.shape["model"]
    groups = layer_groups(cfg)
    base_counts = {name: 1 for name, _ in groups}
    t0 = time.time()
    try:
        base = _lower_probe(cfg_with_counts(cfg, base_counts), shape_name,
                            mesh, rules, mp=mp, block_kv=block_kv,
                            loss_chunk=loss_chunk)
        per_layer = {}
        for name, _ in groups:
            counts = dict(base_counts)
            counts[name] = 2
            probe = _lower_probe(cfg_with_counts(cfg, counts), shape_name,
                                 mesh, rules, mp=mp, block_kv=block_kv,
                                 loss_chunk=loss_chunk)
            per_layer[name] = {k: probe[k] - base[k] for k in base}
        outside = {k: base[k] - sum(per_layer[n][k] for n, _ in groups)
                   for k in base}
        totals = {k: outside[k] + sum(cnt * per_layer[n][k]
                                      for n, cnt in groups)
                  for k in base}
        rec.update({
            "status": "ok",
            "probe_s": round(time.time() - t0, 1),
            "base": base, "per_layer": per_layer, "outside": outside,
            "totals": totals,
            "groups": {n: c for n, c in groups},
            "n_devices": mesh.size,
        })
        print(f"[probe {arch} × {shape_name} × {rec['tag']}] "
              f"flops={totals['flops']:.3e} bytes={totals['bytes']:.3e} "
              f"coll={totals['coll']:.3e} ({rec['probe_s']}s)")
    except Exception as e:  # noqa: BLE001
        rec["status"] = f"FAIL: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[probe {arch} × {shape_name}] FAIL: {e}", file=sys.stderr)
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = out_dir / (f"{arch}__{shape_name}__probe"
                       f"{('__' + tag) if tag else ''}.json")
    fname.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="per-layer cost probes (single-pod only)")
    ap.add_argument("--graph", action="store_true",
                    help="GAS-engine cells: compile one step per (program "
                         "× exchange backend) for the full program "
                         "library plus the fused 3-program bundle, report "
                         "measured collective bytes vs the layout's "
                         "modelled volumes, and write "
                         "results/BENCH_dryrun.json")
    ap.add_argument("--graph-scale", type=int, default=10)
    ap.add_argument("--graph-k", type=int, default=8)
    ap.add_argument("--check", action="store_true",
                    help="with --graph: exit 1 unless measured wire bytes "
                         "order quantized < halo < dense per program "
                         "(exact int payloads allow quantized == halo), "
                         "ragged ≤ halo and ragged_quantized < quantized "
                         "(== ragged for exact payloads), the fused "
                         "bundle ships < 0.6× the bytes of its separate "
                         "quantized steps, the overlapped ragged cells "
                         "match their phase-ordered twins in bytes and "
                         "collective-permute count, and the tol cell "
                         "early-exits under its cap bit-identically")
    ap.add_argument("--compress-grads", action="store_true",
                    help="train cells: int8 gradient quantization; also "
                         "compiles the uncompressed step and prints the "
                         "collective-byte delta (≈0 in the jit path — "
                         "GSPMD reduces grads before the hook runs; see "
                         "repro.dist.compress.make_grad_compressor)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--rules", default="tp", choices=["tp", "cp"])
    ap.add_argument("--block-kv", type=int, default=1024)
    ap.add_argument("--loss-chunk", type=int, default=512)
    ap.add_argument("--out", default=str(RESULTS / "dryrun"))
    args = ap.parse_args()

    out_dir = Path(args.out)
    if args.graph:
        recs = run_graph_cell(out_dir, scale=args.graph_scale,
                              k=args.graph_k, tag=args.tag)
        n_fail = sum(str(r.get("status", "")).startswith("FAIL")
                     for r in recs)
        if args.check:
            msgs = check_graph_ordering(recs)
            for m in msgs:
                print(f"collective-bytes gate: {m}", file=sys.stderr)
            if not msgs:
                print("collective-bytes gate: quantized < halo < dense, "
                      "ragged ≤ halo and ragged_quantized < quantized "
                      "hold for every program, the fused bundle "
                      f"ships < {FUSED_GATE_RATIO}× its separate steps, "
                      "overlap cells match phase-ordered bytes and "
                      "collective-permute count, and tol early-exits "
                      "under the cap bit-identically")
            sys.exit(1 if msgs else 0)
        sys.exit(1 if n_fail else 0)
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            if args.probe:
                rec = run_probe_cell(arch, shape, out_dir,
                                     block_kv=args.block_kv,
                                     loss_chunk=args.loss_chunk,
                                     tag=args.tag, rules_name=args.rules)
                if str(rec.get("status", "")).startswith("FAIL"):
                    n_fail += 1
                continue
            for mk in meshes:
                rec = run_cell(arch, shape, mk, out_dir,
                               block_kv=args.block_kv,
                               loss_chunk=args.loss_chunk, tag=args.tag,
                               rules_name=args.rules,
                               compress=args.compress_grads)
                if str(rec.get("status", "")).startswith("FAIL"):
                    n_fail += 1
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
