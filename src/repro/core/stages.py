"""The CLUGP pipeline as a stage protocol — ONE parametric body.

The paper's pipeline is three composable passes (§III): streaming
clustering → cluster partitioning (the game) → partition transformation,
plus optional prioritized-restream passes (Awadelkarim & Ugander).  PR 4
gave the pipeline three backends but expressed the pass sequence three
times (`_partition_np_nodes`, `_jit_pipeline`, `_make_sharded_fn`), each
re-plumbing mask/axis/vmax by hand.  This module is the fix the ROADMAP
named: the pass structure is the stable abstraction, so the API exposes
**stages**, not backends.

- ``StageCtx`` carries everything that distinguishes a strategy run:
  the live-edge ``mask`` (sharded padding), the mesh ``axis`` for psum
  hooks (None = local), the per-slice ``vmax`` (float or traced scalar),
  the transform balance-cap override ``lmax``, the resolved game kernel,
  and the static id/m/nnz caps of the device paths.
- ``ClusterStage`` / ``ContractStage`` / ``GameStage`` /
  ``TransformStage`` / ``RestreamLoop`` are the pure, jit-able stage
  callables; a ``StageSet`` bundles one implementation of each.
- ``run_clugp_body(src, dst, ctx, cfg, stages)`` is the ONE pipeline
  body.  ``"np"`` executes it with ``HOST_STAGES`` (the interpreted
  host adapters, kept as the equivalence oracle), ``"jit"`` and
  ``"sharded"`` with ``JAX_STAGES`` — the sharded strategy only differs
  by what it puts in the ctx (mask, ``axis="stream"``, traced vmax,
  per-slice lmax), exactly the way PR 3's ``_gas_body`` unified the GAS
  drivers.

Strategy wrappers (jit entry, shard_map entry, host combine, adaptive
cap retries) live in ``repro.core.partitioner``; the façade over
partition → layout → GAS is ``repro.session.GraphSession``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Protocol

import numpy as np

import jax
import jax.numpy as jnp

from . import metrics
from .clustering import (compact_labels_jax, streaming_clustering_jax,
                         streaming_clustering_np)
from .game import (ClusterGraph, best_response_rounds, contract,
                   greedy_assign, jax_cluster_csr, jax_game_rounds,
                   jax_game_rounds_gs, jax_greedy_assign, lambda_from_weight,
                   lambda_max)
from .transform import (majority_vertex_map_jax, majority_vertex_map_np,
                        transform_jax, transform_np)


# ----------------------------------------------------------------- context

@dataclass(frozen=True)
class StageCtx:
    """Per-run stage context: everything the three strategies used to
    re-plumb by hand.  Host runs only need ``num_vertices`` and ``vmax``;
    device runs add the static caps; sharded runs add mask/axis/lmax
    (traced values are fine — the ctx never crosses a jit boundary)."""
    num_vertices: int
    vmax: Any                  # float (host/jit) or traced scalar (sharded)
    mask: Any = None           # live-edge mask; None = every lane is real
    axis: str | None = None    # mesh axis for psum hooks; None = local
    lmax: Any = None           # transform balance-cap override (per slice)
    game_mode: str = "scan"    # resolved kernel: "scan" | "xla" | "pallas"
    id_cap: int = 0            # cluster-id space (jax clustering scan)
    m_cap: int = 0             # compacted-cluster cap (game tables)
    nnz_cap: int = 0           # aggregated cluster-CSR lanes (GS game)
    k_real: Any = None         # traced live-partition count of a k_max-
    #                            padded sweep step; None = cfg.k is real


# ------------------------------------------------------------- stage protocol

class ClusterStage(Protocol):
    """Pass 1: edge stream → clustering state (labels, degrees, marks)."""
    def __call__(self, src, dst, ctx: StageCtx, cfg) -> Any: ...


class ContractStage(Protocol):
    """Streamed graph × labels → cluster-graph state for the game."""
    def __call__(self, src, dst, cstate, ctx: StageCtx, cfg) -> Any: ...


class GameStage(Protocol):
    """Pass 2: cluster graph → (cluster→partition, rounds, overflow)."""
    def __call__(self, gstate, ctx: StageCtx, cfg) -> tuple: ...


class TransformStage(Protocol):
    """Pass 3: stream × vertex→partition prior → edge→partition."""
    def __call__(self, src, dst, vertex_part, cstate, ctx: StageCtx,
                 cfg) -> Any: ...


class RestreamLoop(Protocol):
    """Prioritized restreams over (possibly sliced) streams — the shape of
    ``restream_loop`` below."""
    def __call__(self, src, dst, assign, parts, ctx: StageCtx, cfg,
                 stages) -> tuple: ...


@dataclass(frozen=True)
class StageSet:
    """One implementation of every stage.  ``vertex_part`` joins passes 1
    and 2 (cluster assignment → vertex prior); ``prior`` is the restream
    majority map; ``trace`` (host only) samples RF before each restream
    pass for the ``restream_rf_trace`` stat."""
    cluster: Callable
    contract: Callable
    game: Callable
    vertex_part: Callable
    transform: Callable
    prior: Callable
    trace: Callable | None = None


# ------------------------------------------------------------- stage states

class JaxCluster(NamedTuple):
    compact: Any               # int32[V] dense labels, -1 = never streamed
    deg: Any                   # int32[V] streamed degree
    divided: Any               # bool[V] split at least once
    replicas: Any              # int32[V] mirrors created while clustering
    m: Any                     # traced cluster count (≤ m_cap or overflowed)
    next_id: Any               # traced raw-id high-water mark (cap retry)


class JaxGraph(NamedTuple):
    sizes: Any                 # (m_cap,) game sizes (intra [+ boundary])
    row_tot: Any               # (m_cap,) boundary row totals
    xs: Any                    # cross-edge cluster endpoints (pad: m_cap)
    xd: Any
    n_cross: Any               # traced cross-edge count (λ_max)


class HostGraph(NamedTuple):
    cg: ClusterGraph           # the contraction (result object)
    game_cg: ClusterGraph      # what the game balances (effective sizes)


class PipelineOut(NamedTuple):
    assign: Any
    cluster: Any               # ClusteringResult (host) / JaxCluster (jax)
    graph: Any                 # HostGraph / JaxGraph
    cluster_assign: Any
    rounds: Any
    overflow: Any              # GS nnz-cap overflow flag (host: False)
    trace: tuple               # pre-pass RF per restream (host runs only)


# ----------------------------------------------------------------- the body

def run_clugp_body(src, dst, ctx: StageCtx, cfg, stages: StageSet
                   ) -> PipelineOut:
    """THE pipeline body — the only place the cluster → contract → game →
    transform (→ restream) sequence exists.  Every backend strategy runs
    this exact function; they differ only in the ``stages`` adapters and
    what they put in ``ctx``."""
    cstate = stages.cluster(src, dst, ctx, cfg)
    gstate = stages.contract(src, dst, cstate, ctx, cfg)
    cluster_assign, rounds, overflow = stages.game(gstate, ctx, cfg)
    vp = stages.vertex_part(cluster_assign, cstate, ctx)
    assign = stages.transform(src, dst, vp, cstate, ctx, cfg)
    assign, trace = restream_loop(src, dst, assign, [(None, cstate, ctx)],
                                  ctx, cfg, stages)
    return PipelineOut(assign, cstate, gstate, cluster_assign, rounds,
                       overflow, trace)


def restream_loop(src, dst, assign, parts, ctx: StageCtx, cfg,
                  stages: StageSet) -> tuple:
    """The RestreamLoop stage: ``cfg.restream`` prioritized passes — the
    previous pass's realized majority becomes the prior, the transform
    re-runs per stream slice.

    ``parts`` is ``[(sl, cstate, ctx_slice), …]``: one entry covering the
    whole stream (``sl=None`` — the in-body form every backend uses) or
    one per contiguous host-combine slice (``sl`` a python ``slice``; the
    prior then spans all slices while each transform sees only its own —
    the §III-C combine's host twin of the sharded psum'd prior)."""
    trace = []
    for _ in range(int(cfg.restream)):
        if stages.trace is not None:
            trace.append(stages.trace(src, dst, assign, ctx, cfg))
        vp = stages.prior(src, dst, assign, ctx, cfg)
        if len(parts) == 1 and parts[0][0] is None:
            _, cstate, pctx = parts[0]
            assign = stages.transform(src, dst, vp, cstate, pctx, cfg)
        else:
            assign = np.concatenate([
                stages.transform(src[sl], dst[sl], vp, cstate, pctx, cfg)
                for sl, cstate, pctx in parts])
    return assign, tuple(trace)


# ------------------------------------------------------------ host adapters

def _host_cluster(src, dst, ctx, cfg):
    return streaming_clustering_np(
        src, dst, ctx.num_vertices, ctx.vmax, allow_split=cfg.split,
        split_degree_factor=cfg.split_degree_factor)


def _host_contract(src, dst, cstate, ctx, cfg):
    cg = contract(src, dst, cstate.clu)
    game_cg = cg
    if cfg.effective_sizes:
        boundary = np.asarray(cg.adj.sum(axis=1)).ravel()
        game_cg = ClusterGraph(cg.sizes + boundary, cg.adj,
                               cg.vertex_cluster, cg.m)
    return HostGraph(cg, game_cg)


def _host_game(gstate, ctx, cfg):
    if not cfg.game:
        return greedy_assign(gstate.game_cg, cfg.k), 0, False
    lam = (lambda_max(gstate.game_cg, cfg.k)
           if cfg.relative_weight is None
           else lambda_from_weight(gstate.game_cg, cfg.k,
                                   cfg.relative_weight))
    game = best_response_rounds(gstate.game_cg, cfg.k, lam=lam,
                                batch_size=cfg.batch_size,
                                max_rounds=cfg.max_rounds, seed=cfg.seed)
    return game.assign, game.rounds, False


def _host_vertex_part(cluster_assign, cstate, ctx):
    return cluster_assign[np.maximum(cstate.clu, 0)].astype(np.int32)


def _host_transform(src, dst, vp, cstate, ctx, cfg):
    return transform_np(src, dst, vp, cstate.deg, cstate.divided,
                        cfg.k, cfg.tau)


def _host_prior(src, dst, assign, ctx, cfg):
    return majority_vertex_map_np(src, dst, assign, ctx.num_vertices, cfg.k)


def _host_trace(src, dst, assign, ctx, cfg):
    return metrics.replication_factor(src, dst, assign, ctx.num_vertices,
                                      cfg.k)


HOST_STAGES = StageSet(cluster=_host_cluster, contract=_host_contract,
                       game=_host_game, vertex_part=_host_vertex_part,
                       transform=_host_transform, prior=_host_prior,
                       trace=_host_trace)


# ------------------------------------------------------------- jax adapters

def resolve_game_mode(kernel: str, m_cap: int) -> str:
    """Resolve the game sweep implementation.  ``scan`` = Gauss–Seidel
    over clusters (the CPU-fast host-exact form), ``pallas`` / ``xla`` =
    batched-Jacobi rounds on the ``game_bestresponse`` kernel / its XLA
    fallback (the MXU-shaped form).  ``auto`` picks pallas on TPU and the
    scan everywhere else; the scan falls back to ``xla`` when ``m_cap``
    overflows its int32 pair-key space (~46k clusters)."""
    if kernel not in ("auto", "scan", "pallas", "xla"):
        raise ValueError(f"unknown game kernel {kernel!r}; expected "
                         "'auto', 'scan', 'pallas' or 'xla'")
    mode = kernel
    if kernel == "auto":
        mode = "pallas" if jax.default_backend() == "tpu" else "scan"
    if mode == "scan" and m_cap * (m_cap + 1) >= 2 ** 31:
        return "xla"
    return mode


def resolve_cluster_kernel(kernel: str) -> str:
    """Resolve the clustering fused-scatter strategy.  ``xla`` = the
    lax.scan inner loop (one fused 8-lane ``.at[].add`` per edge),
    ``pallas`` = ``kernels.cluster_scatter`` keeping the block table
    resident in kernel memory (bit-identical — both compose
    ``edge_decisions``).  ``auto`` picks pallas on TPU and the XLA scan
    everywhere else (interpret-mode Pallas is a correctness path, not a
    fast path, on CPU)."""
    if kernel not in ("auto", "pallas", "xla"):
        raise ValueError(f"unknown cluster kernel {kernel!r}; expected "
                         "'auto', 'pallas' or 'xla'")
    if kernel == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return kernel


def cluster_graph_arrays(src, dst, compact, m_cap: int, effective: bool,
                         mask=None):
    """Contract the streamed graph against compacted labels, all in-graph:
    per-cluster intra sizes, boundary row totals, and the cross-edge
    cluster endpoints (padded with the drop sentinel ``m_cap``).

    Matches ``contract`` exactly: self-loop edges of clustered vertices
    COUNT toward their cluster's intra size (cs == cd); ``mask`` excludes
    the sharded backend's padding lanes, which are fake self-loops."""
    cs, cd = compact[src], compact[dst]
    ok = (cs >= 0) & (cd >= 0)
    if mask is not None:
        ok = ok & mask
    sent = jnp.int32(m_cap)
    intra = ok & (cs == cd)
    cross = ok & (cs != cd)
    sizes = jnp.zeros((m_cap,), jnp.float32).at[
        jnp.where(intra, cs, sent)].add(1.0, mode="drop")
    xs = jnp.where(cross, cs, sent)
    xd = jnp.where(cross, cd, sent)
    row_tot = (jnp.zeros((m_cap,), jnp.float32)
               .at[xs].add(1.0, mode="drop")
               .at[xd].add(1.0, mode="drop"))
    game_sizes = sizes + row_tot if effective else sizes
    n_cross = cross.sum().astype(jnp.float32)
    return JaxGraph(game_sizes, row_tot, xs, xd, n_cross)


def lambda_jax(total, n_cross, k: int, relative_weight, k_real=None):
    """λ_max (Thm 5) / relative-weight λ from traced cluster-graph totals
    (Σ game sizes, #cross edges) — matches ``lambda_max``/
    ``lambda_from_weight`` (adj.sum()/2 == n_cross).  ``k_real`` (traced)
    substitutes the live partition count of a k_max-padded sweep step."""
    kf = jnp.float32(k) if k_real is None else k_real.astype(jnp.float32)
    lam_max = jnp.where(total > 0,
                        (kf * kf) * n_cross / jnp.maximum(total * total,
                                                          1.0),
                        1.0)
    if relative_weight is None:
        return lam_max
    w = min(max(relative_weight, 1e-3), 1 - 1e-3)
    lam = lam_max * (w / (1 - w))
    return jnp.where((total > 0) & (n_cross > 0), lam, 1.0)


def _jax_cluster(src, dst, ctx, cfg):
    clu_raw, deg, divided, replicas, next_id = streaming_clustering_jax(
        src, dst, ctx.num_vertices, ctx.vmax, allow_split=cfg.split,
        split_degree_factor=cfg.split_degree_factor, id_cap=ctx.id_cap,
        unroll=cfg.unroll,
        kernel=resolve_cluster_kernel(cfg.cluster_kernel))
    compact, m = compact_labels_jax(clu_raw, ctx.id_cap)
    return JaxCluster(compact, deg, divided, replicas, m, next_id)


def _jax_contract(src, dst, cstate, ctx, cfg):
    return cluster_graph_arrays(src, dst, cstate.compact, ctx.m_cap,
                                cfg.effective_sizes, mask=ctx.mask)


def _jax_game(gstate, ctx, cfg):
    overflow = jnp.bool_(False)
    if not cfg.game:
        return (jax_greedy_assign(gstate.sizes, cfg.k, k_real=ctx.k_real),
                jnp.int32(0), overflow)
    # λ from the LOCAL cluster graph on every strategy: Thm 5's feasible
    # range is a per-id-space quantity (sharded global totals under-weight
    # the balance term by ~n — measured +22% RF at n=4); the load vector
    # the game plays against is still psum'd under ctx.axis.
    lam = lambda_jax(gstate.sizes.sum(), gstate.n_cross, cfg.k,
                     cfg.relative_weight, k_real=ctx.k_real)
    # the Pallas game kernel bakes k into its grid, so traced-k sweep
    # steps play the identical XLA fallback math instead
    mode = ("xla" if ctx.game_mode == "pallas" and ctx.k_real is not None
            else ctx.game_mode)
    if mode == "scan":
        row, col, w, overflow = jax_cluster_csr(gstate.xs, gstate.xd,
                                                ctx.m_cap, ctx.nnz_cap)
        cluster_assign, rounds = jax_game_rounds_gs(
            row, col, w, gstate.sizes, gstate.row_tot, cfg.k, lam,
            max_rounds=cfg.max_rounds, seed=cfg.seed, axis=ctx.axis,
            k_real=ctx.k_real)
    else:
        cluster_assign, rounds = jax_game_rounds(
            gstate.xs, gstate.xd, gstate.sizes, gstate.row_tot, cfg.k, lam,
            batch_size=cfg.batch_size, max_rounds=cfg.max_rounds,
            seed=cfg.seed, use_pallas=mode == "pallas",
            axis=ctx.axis, k_real=ctx.k_real)
    return cluster_assign, rounds, overflow


def _jax_vertex_part(cluster_assign, cstate, ctx):
    return cluster_assign[jnp.clip(cstate.compact, 0, ctx.m_cap - 1)]


def _jax_transform(src, dst, vp, cstate, ctx, cfg):
    return transform_jax(src, dst, vp, cstate.deg, cstate.divided, cfg.k,
                         cfg.tau, mask=ctx.mask, lmax=ctx.lmax,
                         k_real=ctx.k_real)


def _jax_prior(src, dst, assign, ctx, cfg):
    return majority_vertex_map_jax(src, dst, assign, ctx.num_vertices,
                                   cfg.k, mask=ctx.mask, axis=ctx.axis)


JAX_STAGES = StageSet(cluster=_jax_cluster, contract=_jax_contract,
                      game=_jax_game, vertex_part=_jax_vertex_part,
                      transform=_jax_transform, prior=_jax_prior)


# -------------------------------------------------------------- serving
# Incremental window assignment + warm restream — the partitioning-as-a-
# service entry points (``repro.serve``).  Window-based streaming
# partitioning (PAPERS.md) absorbs live edge arrivals by assigning a
# buffered window greedily against the loads the resident partition
# already carries; when quality drifts past a watermark, a prioritized
# restream seeded by the current assignment rebuilds it (Awadelkarim &
# Ugander's warm prior, the same ``restream_loop`` every backend runs).

class StreamState(NamedTuple):
    """The duck-typed ``(deg, divided)`` pair the host transform stage
    reads off its cluster state — here derived from a RESIDENT partition
    instead of a clustering pass: ``deg`` is streamed endpoint degree,
    ``divided`` marks vertices already replicated across ≥ 2 partitions
    (cutting them again is free, Alg. 1 lines 17-19)."""
    deg: np.ndarray
    divided: np.ndarray


def stream_state(src, dst, assign, num_vertices: int,
                 k: int) -> StreamState:
    """Derive the transform stage's per-vertex state from an existing
    edge→partition assignment (no re-clustering)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    assign = np.asarray(assign)
    ends = np.concatenate([src, dst]).astype(np.int64)
    deg = np.bincount(ends, minlength=num_vertices).astype(np.int32)
    cnt = np.bincount(ends * k + np.tile(assign, 2),
                      minlength=num_vertices * k)
    divided = (cnt.reshape(num_vertices, k) > 0).sum(axis=1) > 1
    return StreamState(deg, divided)


def incremental_assign(src, dst, new_src, new_dst, assign,
                       num_vertices: int, cfg, *, state=None,
                       prior=None) -> np.ndarray:
    """Assign a NEW edge window against the resident partition: one
    greedy Alg. 1 pass over the window only, primed with the majority
    vertex map of the current assignment and seeded with the current
    per-partition loads; the balance cap covers the grown stream
    (τ·(E_old+E_new)/k).  Returns the window's edge→partition slice —
    the resident assignment is untouched.  ``state``/``prior`` can be
    passed in to amortize across windows."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    assign = np.asarray(assign)
    if prior is None:
        prior = majority_vertex_map_np(src, dst, assign, num_vertices,
                                       cfg.k)
    if state is None:
        state = stream_state(src, dst, assign, num_vertices, cfg.k)
    loads = np.bincount(assign, minlength=cfg.k).astype(np.int64)
    total = src.shape[0] + np.asarray(new_src).shape[0]
    lmax = cfg.tau * total / float(cfg.k)
    return transform_np(np.asarray(new_src), np.asarray(new_dst), prior,
                        state.deg, state.divided, cfg.k, cfg.tau,
                        loads=loads, lmax=lmax)


def restream_assign(src, dst, assign, num_vertices: int, cfg, *,
                    passes: int = 1, stages: StageSet = HOST_STAGES
                    ) -> tuple:
    """Full prioritized restream seeded by the CURRENT assignment — the
    drift-repair path: ``passes`` extra Alg. 1 passes over the whole
    stream, each primed with the previous pass's realized majority (one
    ``restream_loop`` pass at a time).  MONOTONE: returns the best-RF
    assignment seen, the input included — a repair pass can never leave
    the resident partition worse than the drift it was asked to fix.
    Returns ``(best_assign, rf_trace)`` where ``rf_trace[i]`` is the RF
    before pass ``i`` (entry 0 = the drifted RF)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    cur = np.asarray(assign)
    st = stream_state(src, dst, cur, num_vertices, cfg.k)
    ctx = StageCtx(num_vertices=num_vertices, vmax=0.0)
    rcfg = dataclasses.replace(cfg, restream=1)

    def rf(a):
        return metrics.replication_factor(src, dst, a, num_vertices,
                                          cfg.k)

    best, best_rf = cur, rf(cur)
    trace = []
    for _ in range(int(passes)):
        trace.append(rf(cur))
        cur, _ = restream_loop(src, dst, cur, [(None, st, ctx)], ctx,
                               rcfg, stages)
        r = rf(cur)
        if r < best_rf:
            best, best_rf = cur, r
    return best, tuple(trace)
