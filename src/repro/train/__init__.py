"""Training substrate: optimizers, sharding specs, step builders."""
from .optimizer import adamw, adafactor, get_optimizer, cosine_schedule, Optimizer  # noqa: F401
from .shardings import param_specs, named_shardings, batch_specs, cache_specs  # noqa: F401
from .step import make_train_step, make_prefill_step, make_decode_fn  # noqa: F401
