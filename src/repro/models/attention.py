"""Attention: GQA (optional QKV bias, RoPE) and MLA (DeepSeek latent KV).

Memory discipline on TPU:
- training/prefill uses block-chunked online-softmax attention
  (``chunked_attention`` — the pure-jnp form of the flash kernel in
  repro.kernels.flash_attention; same math, bounded VMEM-sized blocks);
- decode uses a sequence-sharded KV cache with a logsumexp merge across
  shards (flash-decoding adapted to TPU collectives) — see repro.dist.decode.

Head padding: Q heads are padded up to a multiple of the model-axis size so
head-sharded einsums always divide the mesh; padded heads carry zero weights
(their FLOPs show up in the roofline's MODEL_FLOPS/HLO ratio — hillclimb #2
removes them).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Params, apply_rope, linear, linear_init, round_up

NEG_INF = -1e30


def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             qkv_bias: bool = False, pad_heads_to: int = 1,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    hp = round_up(n_heads, pad_heads_to)
    kvp = n_kv if n_kv % pad_heads_to == 0 else n_kv  # replicate if uneven
    return {
        "q": linear_init(ks[0], d_model, hp * head_dim, qkv_bias, dtype),
        "k": linear_init(ks[1], d_model, kvp * head_dim, qkv_bias, dtype),
        "v": linear_init(ks[2], d_model, kvp * head_dim, qkv_bias, dtype),
        "o": linear_init(ks[3], hp * head_dim, d_model, False, dtype),
    }


def gqa_project(p: Params, x, *, n_heads, n_kv, head_dim, pad_heads_to,
                positions, rope_theta=10000.0):
    B, S, _ = x.shape
    hp = round_up(n_heads, pad_heads_to)
    q = linear(p["q"], x).reshape(B, S, hp, head_dim)
    k = linear(p["k"], x).reshape(B, S, n_kv, head_dim)
    v = linear(p["v"], x).reshape(B, S, n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def expand_kv(k, n_q_heads_padded: int):
    """(B,S,Hkv,Dh) → (B,S,Hq,Dh) by repeating groups (padded heads reuse
    group 0 — their Q weights are zero so the result is exact)."""
    B, S, hkv, dh = k.shape
    reps = -(-n_q_heads_padded // hkv)
    k = jnp.repeat(k, reps, axis=2)[:, :, :n_q_heads_padded]
    return k


def chunked_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                      block_kv: int = 1024, sm_scale: float | None = None,
                      unroll: bool = False):
    """Online-softmax attention, O(S·block) memory.  q: (B,Sq,H,Dh),
    k/v: (B,Skv,H,Dh) (already group-expanded).  Returns (B,Sq,H,Dh)."""
    B, Sq, H, Dh = q.shape
    Dv = v.shape[-1]
    Skv = k.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(Dh)
    qf = (q * scale).astype(jnp.float32).transpose(0, 2, 1, 3)  # B,H,Sq,Dh
    kf = k.astype(jnp.float32).transpose(0, 2, 3, 1)            # B,H,Dh,Skv
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)            # B,H,Skv,Dv
    nblk = -(-Skv // block_kv)
    pad = nblk * block_kv - Skv
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, 0), (0, pad)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kf.reshape(B, H, Dh, nblk, block_kv).transpose(3, 0, 1, 2, 4)
    vb = vf.reshape(B, H, nblk, block_kv, Dv).transpose(2, 0, 1, 3, 4)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, blk):
        m, l, acc, idx = carry
        kblk, vblk = blk
        s = qf @ kblk                                  # (B,H,Sq,block)
        kpos = idx * block_kv + jnp.arange(block_kv)
        mask = kpos[None, :] < Skv
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + pexp.sum(-1)
        acc_new = acc * alpha[..., None] + pexp @ vblk
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, jnp.int32(0)),
                                     (kb, vb), unroll=unroll)
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                   sm_scale: float | None = None):
    """Reference einsum attention (small S; oracle for kernels/tests)."""
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = jnp.arange(Skv)[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ------------------------------------------------------------------- MLA

def mla_init(key, d_model: int, n_heads: int, *, q_lora: int, kv_lora: int,
             nope_dim: int, rope_dim: int, v_dim: int,
             pad_heads_to: int = 1, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    hp = round_up(n_heads, pad_heads_to)
    return {
        "q_a": linear_init(ks[0], d_model, q_lora, dtype=dtype),
        "q_b": linear_init(ks[1], q_lora, hp * (nope_dim + rope_dim),
                           dtype=dtype),
        "kv_a": linear_init(ks[2], d_model, kv_lora + rope_dim, dtype=dtype),
        "kv_b": linear_init(ks[3], kv_lora, hp * (nope_dim + v_dim),
                            dtype=dtype),
        "o": linear_init(ks[4], hp * v_dim, d_model, dtype=dtype),
    }


def mla_attention(p: Params, x, *, n_heads, q_lora, kv_lora, nope_dim,
                  rope_dim, v_dim, pad_heads_to, positions, causal=True,
                  block_kv: int = 1024):
    """DeepSeek-V3 Multi-head Latent Attention (decompressed compute form).
    The latent cache form (cache kv_a output only) is used on the decode
    path — see repro.dist.decode.mla_decode."""
    B, S, _ = x.shape
    hp = round_up(n_heads, pad_heads_to)
    q = linear(p["q_b"], linear(p["q_a"], x)).reshape(
        B, S, hp, nope_dim + rope_dim)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    kv = linear(p["kv_a"], x)
    latent, k_rope = kv[..., :kv_lora], kv[..., kv_lora:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions)     # shared head
    q_rope = apply_rope(q_rope, positions)
    kvb = linear(p["kv_b"], latent).reshape(B, S, hp, nope_dim + v_dim)
    k_nope, v = kvb[..., :nope_dim], kvb[..., nope_dim:]
    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate([k_nope,
                          jnp.broadcast_to(k_rope, (B, S, hp, rope_dim))], -1)
    scale = 1.0 / math.sqrt(nope_dim + rope_dim)
    out = chunked_attention(qf, kf, v, causal=causal, block_kv=block_kv,
                            sm_scale=scale)
    return linear(p["o"], out.reshape(B, S, hp * v_dim))
