"""Fig. 8: performance on the real distributed system (PowerGraph →
shard_map GAS engine).  Reports per-iteration communication volume for all
three exchange backends (dense padded all_gather, mirror-routed halo
all_to_all, int8-quantized halo) next to the ragged ideal — the dense→halo
byte reduction is the paper's mechanism (mirror count) showing up on the
wire, and halo→quantized is the per-mirror payload cut composing with it —
plus local compute cost per partitioner and wall time of the simulated
engine.

``layout_build_bench`` times the vectorized ``build_layout`` against the
retained reference builder (the PR-2 layout-build speedup)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import web_graph
from repro.graph import (build_layout, build_layout_reference,
                         reference_pagerank, simulate_pagerank)
from .common import run_partitioner, stream_for


def fig8_pagerank(scale=11, k=8, iters=20, seed=0):
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    rows = []
    for algo in ("clugp-opt", "clugp", "hdrf", "hashing", "dbh"):
        out = run_partitioner(algo, g, k, seed)
        assign = out[0]
        src, dst = stream_for(algo, g, out)
        lay = build_layout(src, dst, assign, g.num_vertices, k)
        ref = reference_pagerank(src, dst, g.num_vertices, iters=iters)
        row = {
            "bench": "fig8_pagerank", "algo": algo, "k": k,
            "comm_mb_per_iter": round(lay.comm_bytes_ideal() / 1e6, 4),
            "comm_mb_dense_padded": round(
                lay.comm_bytes_mirror_sync() / 1e6, 4),
            "comm_mb_halo_padded": round(lay.comm_bytes_halo() / 1e6, 4),
            "comm_mb_halo_quantized": round(
                lay.comm_bytes_halo_quantized() / 1e6, 4),
            "comm_dense_mb": round(lay.comm_bytes_dense() / 1e6, 4),
            "local_edges_max": int(lay.e_max),
            "mirrors": int(lay.mirrors_total),
        }
        for exchange in ("dense", "halo", "quantized"):
            t0 = time.time()
            pr = simulate_pagerank(lay, iters=iters, exchange=exchange)
            dt = time.time() - t0
            err = float(np.abs(pr - ref).max())
            row[f"engine_seconds_{exchange}"] = round(dt, 3)
            row[f"max_err_{exchange}"] = err
            # delta-coded error feedback converges with the iteration, but
            # at finite iters the int8 path keeps a small dither floor
            tol = 1e-5 if exchange != "quantized" else 1e-4
            assert err < tol, (algo, exchange, err)
        rows.append(row)
    return rows


def layout_build_bench(scale=12, k=8, seed=0, repeats=3):
    """Vectorized vs reference ``build_layout`` wall time on a CLUGP
    partition — the table the ≥5× layout-build speedup claim reads from."""
    g = web_graph(scale=scale, edge_factor=8, seed=seed)
    out = run_partitioner("clugp-opt", g, k, seed)
    assign = out[0]
    args = (g.src, g.dst, assign, g.num_vertices, k)
    build_layout(*args)          # warm caches
    t0 = time.time()
    for _ in range(repeats):
        lay = build_layout(*args)
    vec_s = (time.time() - t0) / repeats
    t0 = time.time()
    ref_lay = build_layout_reference(*args)
    ref_s = time.time() - t0
    assert lay.mirrors_total == ref_lay.mirrors_total
    return [{
        "bench": "layout_build", "k": k, "scale": scale,
        "num_vertices": g.num_vertices, "num_edges": g.num_edges,
        "vectorized_s": round(vec_s, 4),
        "reference_s": round(ref_s, 4),
        "speedup": round(ref_s / max(vec_s, 1e-9), 2),
    }]
