"""The CLUGP three-pass pipeline (paper §III) — host reference path.

``clugp_partition`` = streaming clustering → cluster-partitioning game →
partition transformation.  Ablations: ``split=False`` (CLUGP-S),
``game=False`` (CLUGP-G, greedy cluster placement).  ``restream > 0``
re-consumes the stream that many extra times with the previous pass's
realized vertex→partition majority as the prior (free-cut reuse +
load-aware reassign) — prioritized restreaming, beyond the paper.

This module is the **"np" backend** of the backend-parametric partitioner
(``repro.core.partitioner``): the interpreted host loops stay as the
equivalence oracle, while the ``"jit"`` and ``"sharded"`` backends run the
same three passes device-resident.  The old ``clugp_partition_parallel``
host loop over nodes lives on there as the sharded combine's reference.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .clustering import (ClusteringResult, default_vmax,
                         streaming_clustering_np)
from .game import (ClusterGraph, best_response_rounds, contract,
                   greedy_assign, lambda_from_weight, lambda_max)
from .transform import majority_vertex_map_np, transform_np
from . import metrics


@dataclass
class CLUGPConfig:
    k: int
    tau: float = 1.0
    vmax: float | None = None          # default |E|/k (paper §VI-A)
    split: bool = True                 # CLUGP-S ablation switch
    game: bool = True                  # CLUGP-G ablation switch
    split_degree_factor: float = 0.0   # 0 = paper-faithful; 4 = optimized
    batch_size: int = 6400             # paper §VI-A default
    max_rounds: int = 64
    relative_weight: float | None = None   # Fig. 11b sweep; None ⇒ λ_max
    effective_sizes: bool = False      # beyond-paper: balance |c_i|+boundary
    restream: int = 0                  # extra prioritized-restream passes
    kernel: str = "auto"               # game sweep: "auto" | "pallas" | "xla"
    seed: int = 0

    @staticmethod
    def paper(k: int, **kw) -> "CLUGPConfig":
        """Paper-faithful profile (§VI-A defaults)."""
        return CLUGPConfig(k=k, **kw)

    @staticmethod
    def optimized(k: int, **kw) -> "CLUGPConfig":
        """Beyond-paper profile: the game balances *effective* cluster sizes
        (intra + expected landing of boundary edges) so transform loads match
        game loads — cuts the overflow-spill fraction 2-4× (EXPERIMENTS.md
        §Perf-partitioner); τ=1.1 gives the spill headroom Fig. 11a studies."""
        kw.setdefault("tau", 1.1)
        kw.setdefault("effective_sizes", True)
        return CLUGPConfig(k=k, **kw)


@dataclass
class CLUGPResult:
    assign: np.ndarray
    clustering: ClusteringResult | None
    cluster_graph: ClusterGraph | None
    cluster_assign: np.ndarray | None
    game_rounds: int
    stats: dict = field(default_factory=dict)


def clugp_partition(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                    cfg: CLUGPConfig) -> CLUGPResult:
    E = src.shape[0]
    vmax = cfg.vmax if cfg.vmax is not None else default_vmax(E, cfg.k)
    # Pass 1: streaming clustering
    clus = streaming_clustering_np(src, dst, num_vertices, vmax,
                                   allow_split=cfg.split,
                                   split_degree_factor=cfg.split_degree_factor)
    # Pass 2: cluster partitioning
    cg = contract(src, dst, clus.clu)
    game_cg = cg
    if cfg.effective_sizes:
        boundary = np.asarray(cg.adj.sum(axis=1)).ravel()
        game_cg = ClusterGraph(cg.sizes + boundary, cg.adj,
                               cg.vertex_cluster, cg.m)
    if cfg.game:
        lam = (lambda_max(game_cg, cfg.k) if cfg.relative_weight is None
               else lambda_from_weight(game_cg, cfg.k, cfg.relative_weight))
        game = best_response_rounds(game_cg, cfg.k, lam=lam,
                                    batch_size=cfg.batch_size,
                                    max_rounds=cfg.max_rounds, seed=cfg.seed)
        cluster_assign, rounds = game.assign, game.rounds
    else:
        cluster_assign, rounds = greedy_assign(game_cg, cfg.k), 0
    # Pass 3: transformation
    vertex_part = cluster_assign[np.maximum(clus.clu, 0)].astype(np.int32)
    assign = transform_np(src, dst, vertex_part, clus.deg, clus.divided,
                          cfg.k, cfg.tau)
    # Restream passes: the realized edge placement becomes the next prior
    rf_trace = []
    for _ in range(cfg.restream):
        rf_trace.append(metrics.replication_factor(
            src, dst, assign, num_vertices, cfg.k))
        vp = majority_vertex_map_np(src, dst, assign, num_vertices, cfg.k)
        assign = transform_np(src, dst, vp, clus.deg, clus.divided,
                              cfg.k, cfg.tau)
    res = CLUGPResult(assign, clus, cg, cluster_assign, rounds)
    res.stats = metrics.summarize(src, dst, assign, num_vertices, cfg.k)
    res.stats["num_clusters"] = clus.num_clusters
    res.stats["game_rounds"] = rounds
    res.stats["backend"] = "np"
    if cfg.restream:
        rf_trace.append(res.stats["rf"])
        res.stats["restream_rf_trace"] = [round(r, 4) for r in rf_trace]
    return res
