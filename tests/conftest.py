import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def random_graph_and_assign(seed: int, k: int, n: int = 300,
                            e_factor: int = 5):
    """Zipf-ish random digraph with compacted vertex ids plus a random
    edge→partition assignment — the shared generator for the exchange /
    quantized-halo suites.  Compaction matters: the engine (like the
    repo's generators) assumes every vertex 0..n-1 appears in some edge;
    isolated vertices would be dangling mass the distributed tables can't
    see."""
    rng = np.random.default_rng(seed)
    e = n * e_factor
    src = rng.integers(0, n, e)
    dst = (rng.zipf(1.7, e) - 1) % n
    keep = src != dst
    src, dst = src[keep].astype(np.int64), dst[keep].astype(np.int64)
    verts = np.unique(np.concatenate([src, dst]))
    src = np.searchsorted(verts, src)
    dst = np.searchsorted(verts, dst)
    n = int(verts.shape[0])
    assign = rng.integers(0, k, src.shape[0]).astype(np.int32)
    return src, dst, n, assign


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600):
    """Run ``code`` in a subprocess with n virtual host devices.
    (XLA device count locks at first jax init, so multi-device paths are
    exercised out-of-process; the main process keeps 1 device.)"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def multidevice():
    return run_multidevice


def pytest_collection_modifyitems(config, items):
    """`-m "not multidevice"` vs `-m multidevice` must partition the
    suite (the CI tests / tests-multidevice job split): any test that
    drives the subprocess runner (the ``multidevice`` fixture) without
    carrying the ``multidevice`` marker aborts collection."""
    unmarked = [item.nodeid for item in items
                if "multidevice" in getattr(item, "fixturenames", ())
                and item.get_closest_marker("multidevice") is None]
    if unmarked:
        raise pytest.UsageError(
            "subprocess multidevice tests missing the @pytest.mark."
            "multidevice marker (the CI job split would silently skip "
            "them): " + ", ".join(unmarked))
