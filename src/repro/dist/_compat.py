"""Forward-compat shims for older jax (container pins 0.4.x).

``jax.shard_map`` with the ``check_vma`` kwarg landed after 0.4.37; the
tests and newer call sites use that spelling, so alias it onto
``jax.experimental.shard_map.shard_map`` (whose equivalent kwarg is
``check_rep``) when missing.  Import order is safe: every ``repro.dist``
consumer imports this package before touching ``jax.shard_map``.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                  check_rep=None, **kwargs):
        if check_rep is None:
            check_rep = check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep,
                          **kwargs)

    jax.shard_map = shard_map
