"""Distributed vertex-cut graph engine (the paper's PowerGraph deployment)."""
from .partition import (PartitionLayout, build_layout,  # noqa: F401
                        build_layout_reference)
from .engine import (simulate_pagerank, simulate_cc, shard_map_pagerank,  # noqa: F401
                     pagerank_step_for_dryrun, reference_pagerank,
                     reference_cc)
