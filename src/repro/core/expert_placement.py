"""CLUGP → MoE expert placement (beyond-paper bridge).

The paper's cluster-partitioning game (§V) assigns clusters to partitions
minimizing load imbalance + cut edges.  An MoE layer's all-to-all volume
has exactly this structure: experts that co-fire for the same token want
to live on the same EP shard (one dispatch hop instead of two); shard load
must stay balanced or the slowest shard gates the step.

Mapping:  cluster  → expert,   |c_i| → expert token-load,
          e(c_i,c_j) → co-activation count (tokens routing to both i and j
          within the same top-k set),  k → EP shards.

The shared expert (DeepSeek) is the paper's "high-degree vertex": it
co-fires with everything, so — like the splitting rule would — we replicate
it on every shard rather than place it.

Output: a permutation mapping expert id → shard, usable to re-order the
expert bank so GSPMD's contiguous EP sharding realizes the placement.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp

from .game import ClusterGraph, best_response_rounds


def coactivation_graph(top_idx: np.ndarray, n_experts: int,
                       loads: np.ndarray | None = None) -> ClusterGraph:
    """top_idx: (T, K) routed expert ids per token."""
    T, K = top_idx.shape
    sizes = np.bincount(top_idx.reshape(-1), minlength=n_experts) \
        .astype(np.int64)
    rows, cols = [], []
    for a in range(K):
        for b in range(a + 1, K):
            rows.append(top_idx[:, a])
            cols.append(top_idx[:, b])
    if rows:
        r = np.concatenate(rows)
        c = np.concatenate(cols)
        W = sp.coo_matrix((np.ones(r.shape[0], np.int64), (r, c)),
                          shape=(n_experts, n_experts)).tocsr()
        S = (W + W.T).tocsr()
    else:
        S = sp.csr_matrix((n_experts, n_experts), dtype=np.int64)
    return ClusterGraph(sizes, S, np.arange(n_experts), n_experts)


def place_experts(top_idx: np.ndarray, n_experts: int, n_shards: int,
                  seed: int = 0) -> np.ndarray:
    """Returns perm (n_experts,): expert id → new position, such that
    contiguous blocks of n_experts/n_shards land on the same EP shard and
    co-activated experts share blocks."""
    cg = coactivation_graph(top_idx, n_experts)
    res = best_response_rounds(cg, n_shards, batch_size=None, seed=seed)
    shard_of = res.assign
    per = n_experts // n_shards
    # pack: fill shards to exactly `per` experts each (stable overflow spill)
    order = np.argsort(shard_of, kind="stable")
    perm = np.zeros(n_experts, dtype=np.int64)
    slots = {s: 0 for s in range(n_shards)}
    spill = []
    for e in order:
        s = int(shard_of[e])
        if slots[s] < per:
            perm[e] = s * per + slots[s]
            slots[s] += 1
        else:
            spill.append(e)
    for e in spill:
        s = min(slots, key=slots.get)
        perm[e] = s * per + slots[s]
        slots[s] += 1
    return perm


def a2a_volume(top_idx: np.ndarray, shard_of_expert: np.ndarray,
               n_shards: int) -> int:
    """Dispatch fan-out: Σ_tokens #distinct destination shards among the
    token's top-k experts.  Tokens are spread over DP shards independent of
    topic, so per-expert hop counts are placement-invariant; what the game
    minimizes is the *fan-out* — co-activated experts on one shard turn two
    dispatch messages (and two combine returns) into one."""
    T, K = top_idx.shape
    shards = shard_of_expert[top_idx]              # (T, K)
    shards_sorted = np.sort(shards, axis=1)
    distinct = 1 + (shards_sorted[:, 1:] != shards_sorted[:, :-1]).sum(1)
    return int(distinct.sum())
