"""Hypothesis property tests for the quantized halo wire format: int8
lane-group quantization round-trip and pack→quantize→unpack through real
routing tables.  (Deterministic quantized-exchange coverage lives in
tests/test_graph_quantized.py; this module self-skips without the
optional hypothesis dep, like tests/test_properties.py.)"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional dev dep)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.dist.compress import dequantize_rows, quantize_rows  # noqa: E402
from repro.dist.halo import _pack, get_exchange  # noqa: E402
from repro.graph import build_layout, get_program, simulate_gas  # noqa: E402
from repro.graph.engine import _stack_dev  # noqa: E402

from conftest import random_graph_and_assign  # noqa: E402


@given(st.integers(0, 2**16), st.integers(2, 8), st.integers(1, 16),
       st.floats(1e-6, 1e6))
@settings(max_examples=40, deadline=None)
def test_int8_lane_quantize_roundtrip(seed, k, h_max, magnitude):
    """Per-lane-group max-abs quantization: codes stay in [-127, 127] and
    the dequantized row is within half a quantization step of the input,
    per lane group, at any magnitude."""
    rng = np.random.default_rng(seed)
    lanes = (rng.standard_normal((k, h_max)) * magnitude).astype(np.float32)
    codes, scales = quantize_rows(jnp.asarray(lanes))
    codes, scales = np.asarray(codes), np.asarray(scales)
    assert codes.dtype == np.int8
    assert (np.abs(codes) <= 127).all()
    deq = np.asarray(dequantize_rows(jnp.asarray(codes),
                                     jnp.asarray(scales)))
    np.testing.assert_allclose(deq, lanes, atol=float(scales.max()) / 2 +
                               1e-6 * magnitude)


def test_all_zero_rows_roundtrip_exactly():
    # scale falls back to 1 so dequantization stays exact
    z_codes, z_scales = quantize_rows(jnp.zeros((3, 5), jnp.float32))
    assert not np.asarray(z_codes).any()
    np.testing.assert_array_equal(np.asarray(z_scales), 1.0)


@given(st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_int8_pack_unpack_roundtrip_through_halo_tables(seed):
    """End-to-end lane property on real routing tables: pack mirror values
    into destination lane groups, quantize, dequantize, scatter back —
    every mirror slot recovers its own value within half its lane group's
    quantization step, and pad lanes stay exactly zero."""
    k = 4
    src, dst, n, assign = random_graph_and_assign(seed, k, n=200)
    lay = build_layout(src, dst, assign, n, k)
    rng = np.random.default_rng(seed + 1)
    for p in range(k):
        values = rng.standard_normal(lay.l_max).astype(np.float32)
        lanes = np.asarray(_pack(jnp.asarray(values),
                                 jnp.asarray(lay.halo_send[p]), "sum"))
        pad_mask = lay.halo_send[p] == lay.l_max
        np.testing.assert_array_equal(lanes[pad_mask], 0.0)
        codes, scales = quantize_rows(jnp.asarray(lanes))
        deq = np.asarray(dequantize_rows(codes, scales))
        step = np.asarray(scales)[:, None]
        valid = ~pad_mask
        assert (np.abs(deq - lanes)[valid] <=
                (step / 2 + 1e-7).repeat(lanes.shape[1], 1)[valid]).all()
        # scatter back: each valid lane targets its own mirror slot
        back = np.zeros(lay.l_max + 1, np.float32)
        back[lay.halo_send[p].reshape(-1)] = deq.reshape(-1)
        mirror = lay.vert_mask[p] & ~lay.is_master[p]
        slots = np.flatnonzero(mirror)
        if slots.size:
            assert np.abs(back[slots] - values[slots]).max() <= \
                float(np.asarray(scales).max()) / 2 + 1e-6


@given(st.integers(0, 2**16), st.integers(2, 8))
@settings(max_examples=15, deadline=None)
def test_ragged_ring_routes_every_mirror_exactly_once(seed, k):
    """The ragged ppermute ring is a pure re-routing of the padded halo
    all_to_all.  Small-integer fp32 payloads make the check exact: their
    sums are the same whatever the association order, so if any mirror
    lane were dropped, duplicated, or delivered to the wrong slot by the
    per-distance prefix slicing, the stacked reduce or broadcast would
    differ from the halo wire — instead both phases agree BIT-FOR-BIT on
    any random graph/assignment."""
    src, dst, n, assign = random_graph_and_assign(seed, k, n=200)
    lay = build_layout(src, dst, assign, n, k)
    rng = np.random.default_rng(seed + 7)
    partials = jnp.asarray(
        rng.integers(0, 512, (k, lay.l_max)).astype(np.float32))
    outs = {}
    for name in ("halo", "ragged"):
        ex = get_exchange(name, layout=lay)
        dev = _stack_dev(lay, name)
        red, _ = ex.reduce_stacked(partials, dev, combine="sum")
        bro, _ = ex.broadcast_stacked(red, dev, combine="sum")
        outs[name] = (np.asarray(red), np.asarray(bro))
    np.testing.assert_array_equal(outs["ragged"][0], outs["halo"][0])
    np.testing.assert_array_equal(outs["ragged"][1], outs["halo"][1])


@given(st.integers(0, 2**16), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_interior_frontier_is_exact_two_coloring(seed, k):
    """The layout's interior/frontier split is an exact two-coloring of
    the local vertex tables on any random graph/assignment: the two
    classes are disjoint, together they cover exactly the local rows,
    frontier == (global replication > 1) row for row, and every real
    mirror lane in the ragged ring's send tables targets a frontier slot
    — no interior vertex ever waits on (or feeds) a ring hop, which is
    what lets the overlapped body compute it mid-flight."""
    src, dst, n, assign = random_graph_and_assign(seed, k, n=200)
    lay = build_layout(src, dst, assign, n, k)
    interior = lay.vert_mask & ~lay.frontier
    frontier = lay.vert_mask & lay.frontier
    assert not (interior & frontier).any()
    np.testing.assert_array_equal(interior | frontier, lay.vert_mask)
    assert not (lay.frontier & ~lay.vert_mask).any(), \
        "frontier colored a pad row"
    replic = np.zeros(n, np.int64)
    np.add.at(replic, lay.vert_gid[lay.vert_mask], 1)
    np.testing.assert_array_equal(
        frontier[lay.vert_mask], replic[lay.vert_gid[lay.vert_mask]] > 1)
    # every mirror is frontier, and every real halo_send lane (pad slots
    # point at l_max) addresses a frontier-colored local slot
    mirrors = lay.vert_mask & ~lay.is_master
    assert frontier[mirrors].all()
    for p in range(k):
        slots = lay.halo_send[p][lay.halo_send[p] != lay.l_max]
        assert frontier[p, slots].all() if slots.size else True


@given(st.integers(0, 2**16), st.sampled_from(["sssp", "labelprop"]),
       st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_int_programs_exchange_invariant(seed, name, k):
    """Exchange invariance for exact (min/int) payloads: SSSP distances
    and labelprop labels are bit-identical under dense, halo, quantized
    AND both ragged wires on any random graph/assignment — the lossy
    backends' error-feedback paths are bypassed for non-lossy payloads
    (``ragged_quantized`` delegates to the exact ring), so compression
    can never perturb an int frontier."""
    src, dst, n, assign = random_graph_and_assign(seed, k, n=150)
    lay = build_layout(src, dst, assign, n, k)
    prog = get_program(name, n)
    dense = simulate_gas(prog, lay, iters=25, exchange="dense")
    for exchange in ("halo", "quantized", "ragged", "ragged_quantized"):
        got = simulate_gas(prog, lay, iters=25, exchange=exchange)
        np.testing.assert_array_equal(got, dense,
                                      err_msg=f"{name}/{exchange}")
