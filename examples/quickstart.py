"""Quickstart: the GraphSession façade — partition a synthetic web crawl
with CLUGP, build the vertex-cut layout, and run distributed PageRank and
connected components, all from one serializable session config.

    PYTHONPATH=src python examples/quickstart.py

Under XLA_FLAGS=--xla_force_host_platform_device_count=8 (what CI's
examples-smoke job sets) the GAS programs also run as real shard_map
collectives, one partition per virtual device.
"""
import numpy as np

import jax

from repro.core import CLUGPConfig, baselines, metrics, random_stream, web_graph
from repro.graph import reference_cc, reference_pagerank
from repro.launch.mesh import make_graph_mesh
from repro.session import GraphSession, SessionConfig

K = 8

g = web_graph(scale=12, edge_factor=8, seed=0)
print(f"web graph: |V|={g.num_vertices} |E|={g.num_edges}")

for name, cfg in [("CLUGP (paper)", CLUGPConfig.paper(K)),
                  ("CLUGP (optimized)", CLUGPConfig.optimized(K))]:
    sess = GraphSession(cfg).partition(g.src, g.dst, g.num_vertices)
    print(f"{name:20s} RF={sess.stats['rf']:.3f} "
          f"balance={sess.stats['balance']:.3f} "
          f"clusters={sess.stats['num_clusters']} "
          f"game_rounds={sess.stats['game_rounds']}")

gr = random_stream(g, seed=1)
for name in ("hdrf", "hashing"):
    a = baselines.ALL_BASELINES[name](gr.src, gr.dst, g.num_vertices, K)
    rf = metrics.replication_factor(gr.src, gr.dst, a, g.num_vertices, K)
    print(f"{name:20s} RF={rf:.3f} "
          f"balance={metrics.load_balance(a, K):.3f}")

# the whole pipeline as ONE object — and the config round-trips through
# JSON, so this exact run is reproducible from a blob
sess = GraphSession(SessionConfig(clugp=CLUGPConfig.optimized(K),
                                  backend="jit", exchange="quantized"))
sess = GraphSession.from_json(sess.to_json())
sess.partition(g.src, g.dst, g.num_vertices).layout()

# with >= K devices the programs shard_map one partition per device;
# otherwise the stacked simulator runs the same per-device math
mesh = make_graph_mesh(K) if jax.device_count() >= K else None
where = f"shard_map over {K} devices" if mesh else "stacked simulation"
pr = sess.run("pagerank", iters=30, mesh=mesh)
ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
print(f"pagerank ({where}): max|err| vs single-machine oracle = "
      f"{np.abs(pr - ref).max():.2e}")
# convergence is the intent here, so let tol stop the loop: iters is
# just the cap, and iters_run reports how many sweeps CC actually took
cc, iters_run = sess.run("cc", iters=40, mesh=mesh, tol=0,
                         return_iters=True)
rcc = reference_cc(g.src, g.dst, g.num_vertices)
print(f"cc ({where}): label match vs oracle = "
      f"{np.mean(cc == rcc)*100:.1f}% (converged in {iters_run} sweeps)")

cb = sess.comm_bytes()
print("mirror-sync comm/iter: "
      f"quantized={cb['quantized']/1e6:.2f} MB "
      f"halo={cb['halo']/1e6:.2f} MB "
      f"dense-gather={cb['dense_gather']/1e6:.2f} MB "
      f"(ragged ideal {cb['ideal']/1e6:.2f} MB, "
      f"allreduce baseline {cb['allreduce']/1e6:.2f} MB)")
