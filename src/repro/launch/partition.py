"""Graph-partitioning launcher — a thin client of the GraphSession façade.

``python -m repro.launch.partition --scale 13 --k 16 --algo clugp-opt``
partitions a synthetic web crawl and reports RF / balance / runtime, then
(optionally) runs distributed PageRank on the result via the session's
GAS engine (--pagerank).

``--backend {np,jit,sharded}`` picks the partitioner strategy
(repro.core.partitioner): the host oracle, the single-device fused jit
pipeline, or the §III-C stream-sharded shard_map pipeline over ``--nodes``
devices.  ``--restream N`` adds N prioritized-restream passes.  jax must
see enough devices for the sharded backend, so the arg parse happens
BEFORE any jax import and sets XLA_FLAGS itself; after jax initializes,
the requested ``--nodes`` is validated against the realizable device
count so a mismatch fails with a clear message instead of a shard_map
shape error deep inside jax.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--algo", default="clugp-opt",
                    choices=["clugp", "clugp-opt", "clugp-parallel",
                             "hashing", "dbh", "greedy", "hdrf", "mint"])
    ap.add_argument("--backend", default="np",
                    choices=["np", "jit", "sharded"],
                    help="partitioner implementation for clugp algos")
    ap.add_argument("--nodes", type=int, default=4,
                    help="stream-split width: sharded mesh size / "
                         "clugp-parallel node count")
    ap.add_argument("--restream", type=int, default=0,
                    help="extra prioritized-restream passes")
    ap.add_argument("--unroll", type=int, default=1,
                    help="clustering inner-scan unroll (device backends)")
    ap.add_argument("--graph", default="web", choices=["web", "social"])
    ap.add_argument("--pagerank", action="store_true")
    ap.add_argument("--exchange", default="halo",
                    choices=["dense", "halo", "quantized"],
                    help="mirror-sync wire format for --pagerank")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def validate_nodes(args) -> None:
    """Fail fast (and clearly) when the requested stream-split width is
    not realizable as XLA devices — without this, the mismatch surfaces
    as a shard_map sharding/shape error deep inside jax.  Must run after
    the XLA_FLAGS setup and the first jax import."""
    import jax

    if args.nodes < 1:
        sys.exit(f"error: --nodes must be >= 1, got {args.nodes}")
    if args.backend != "sharded":
        return
    have = jax.device_count()
    if have < args.nodes:
        plat = jax.default_backend()
        hint = (
            "XLA_FLAGS=--xla_force_host_platform_device_count=N only "
            "creates virtual CPU devices; on "
            f"'{plat}' the device count is fixed by the hardware"
            if plat != "cpu" else
            "the device count locked at the first jax import — make sure "
            "nothing imported jax before this launcher set XLA_FLAGS")
        sys.exit(
            f"error: --backend sharded --nodes {args.nodes} needs "
            f"{args.nodes} XLA devices but only {have} "
            f"{'is' if have == 1 else 'are'} realizable on platform "
            f"'{plat}' ({hint})")


def session_for(args, g):
    """Build the (serializable) session this invocation describes and run
    the partition strategy on the graph.  Baseline algos adopt their
    assignment into the same session type, so the downstream layout /
    engine / comm accounting is identical for every algo."""
    import numpy as np

    from repro.core import CLUGPConfig, baselines, random_stream
    from repro.session import GraphSession, SessionConfig

    algo, k, seed = args.algo, args.k, args.seed
    if algo.startswith("clugp"):
        cfg = (CLUGPConfig.optimized(k) if algo == "clugp-opt"
               else CLUGPConfig.paper(k))
        cfg = dataclasses.replace(cfg, restream=args.restream,
                                  unroll=args.unroll)
        # --nodes drives the stream split for the sharded backend and for
        # the legacy clugp-parallel alias (np multi-node combine)
        nodes = (1 if args.backend == "np" and algo != "clugp-parallel"
                 else args.nodes)
        sess = GraphSession(SessionConfig(
            clugp=cfg, backend=args.backend, nodes=nodes,
            exchange=args.exchange))
        return sess.partition(g.src, g.dst, g.num_vertices)
    gr = random_stream(g, seed=seed)
    a = baselines.ALL_BASELINES[algo](gr.src, gr.dst, g.num_vertices, k)
    # map back to the original stream order for downstream use
    out = np.zeros_like(a)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.num_edges)
    out[perm] = a
    sess = GraphSession(SessionConfig(clugp=CLUGPConfig(k=k),
                                      exchange=args.exchange))
    return sess.with_partition(g.src, g.dst, g.num_vertices, out)


def main():
    args = build_parser().parse_args()
    if args.backend == "sharded":
        # must land before the first jax import — the device count locks
        # then.  An existing flag with a smaller count is raised to
        # --nodes (jax hasn't initialized yet, so overriding is safe).
        import re

        flags = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                      flags)
        if m is None or int(m.group(1)) < args.nodes:
            flags = re.sub(
                r"--xla_force_host_platform_device_count=\d+", "", flags)
            os.environ["XLA_FLAGS"] = (
                flags +
                f" --xla_force_host_platform_device_count={args.nodes}")

    import numpy as np

    from repro.core import web_graph
    from repro.core.graphgen import social_graph

    validate_nodes(args)

    g = (web_graph(scale=args.scale, seed=args.seed) if args.graph == "web"
         else social_graph(n=1 << args.scale, seed=args.seed))
    print(f"graph: V={g.num_vertices} E={g.num_edges}")
    t0 = time.time()
    sess = session_for(args, g)
    dt = time.time() - t0
    label = args.algo if not args.algo.startswith("clugp") \
        else f"{args.algo}[{args.backend}, restream={args.restream}]"
    print(f"{label}: rf={sess.stats['rf']:.3f} "
          f"balance={sess.stats['balance']:.3f} "
          f"time={dt:.2f}s ({1e6*dt/g.num_edges:.2f} µs/edge)")

    if args.pagerank:
        from repro.graph import reference_pagerank
        sess.layout()
        st = sess.partition_layout.interior_frontier_stats()
        print(f"interior/frontier: frac={st['interior_frac']:.3f} "
              f"min={st['interior_frac_min']:.3f} "
              f"(overlap headroom — interior rows compute during the "
              f"ring hops)")
        t0 = time.time()
        pr = sess.run("pagerank", iters=30)
        dt = time.time() - t0
        ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
        cb = sess.comm_bytes()
        print(f"pagerank[{args.exchange}]: {dt:.2f}s  "
              f"max|err|={np.abs(pr-ref).max():.2e}  "
              f"comm/iter: ideal={cb['ideal']/1e6:.2f}MB "
              f"quantized={cb['quantized']/1e6:.2f}MB "
              f"halo={cb['halo']/1e6:.2f}MB "
              f"dense-gather={cb['dense_gather']/1e6:.2f}MB "
              f"allreduce={cb['allreduce']/1e6:.2f}MB")


if __name__ == "__main__":
    main()
