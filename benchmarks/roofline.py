"""§Roofline report: combine full-cell dry-run records (memory, sharding
proof) with probe records (trip-count-exact flops/bytes/collectives) into
the three-term roofline table.

Terms per (arch × shape), single-pod (16,16) mesh, v5e constants:
    compute    = flops_dev / 197e12            [s]
    memory     = bytes_dev / 819e9             [s]
    collective = coll_bytes_dev / (3 · 50e9)   [s]   (v5e: 3 usable ICI
                                                      links per direction
                                                      on a 2D torus slice)
MODEL_FLOPS = 6·N_active·D_tokens (per device: /256); ratio vs HLO flops
shows padded-head/remat/capacity waste.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, get_config
from repro.launch.specs import SHAPES, cell_is_skipped
from repro.models import param_count
from repro.models.lm import abstract_params, np_prod

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ICI_LINKS = 3
RESULTS = Path(__file__).resolve().parents[1] / "results"


def active_params(arch: str) -> float:
    """N_active (MoE: shared + top-k experts + attention/embed only)."""
    cfg = get_config(arch)
    n_total = param_count(cfg, mp=1)
    if cfg.moe is None:
        return float(n_total)
    # expert bank contribution scaled by top_k/E
    tree = abstract_params(cfg, 1)
    expert_bytes = 0
    for path, leaf in _walk(tree):
        if "experts" in path:
            expert_bytes += np_prod(leaf.shape)
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return float(n_total - expert_bytes * (1.0 - frac))


def _walk(tree):
    import jax
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        yield jax.tree_util.keystr(path), leaf


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·tokens for train; 2·N_active·tokens for prefill/decode,
    GLOBAL (divide by chips for per-device).  Enc-dec: each token passes
    one of the two stacks (×0.5)."""
    sh = SHAPES[shape_name]
    cfg = get_config(arch)
    n = active_params(arch)
    half = 0.5 if cfg.family == "encdec" else 1.0
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * n * tokens * half
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * n * tokens * half
    return 2.0 * n * sh["global_batch"] * half   # decode: 1 token each


def analytic_hbm_bytes(arch: str, shape_name: str, n_dev: int = 256) -> float:
    """Napkin lower bound on per-device HBM traffic (perfect fusion:
    intermediates stay in VMEM).  The true value lies between this and the
    HLO bytes-accessed upper bound; see EXPERIMENTS.md §Method.

    train:  weights fwd+bwd reads (bf16) + grad/master/moment RW (fp32,
            ZeRO-sharded) + layer-boundary activations ×(fwd write, bwd
            read, remat re-write) + chunked logits.
    prefill: weight reads + activations + KV cache writes.
    decode:  weight reads + full KV cache read + one row write.
    """
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    N = param_count(cfg, mp=16)
    n_loc = N / n_dev
    D = cfg.d_model
    L = cfg.n_layers + cfg.n_encoder_layers
    act_tokens = B * S / 16          # per-device tokens (dp=16)
    if sh["kind"] == "train":
        w = 4 * n_loc * 2            # bf16 gathered reads, fwd+bwd (ZeRO)
        opt = 20 * n_loc             # grads + master + moments fp32 RW
        acts = 6 * L * act_tokens * D * 2
        logits = 2 * act_tokens * cfg.padded_vocab * 2 / 16  # vocab-sharded
        return w + opt + acts + logits
    if sh["kind"] == "prefill":
        w = 2 * n_loc
        acts = 2 * L * act_tokens * D * 2
        kv = L * act_tokens * cfg.n_kv_heads * cfg.hd * 2 * 2
        return w + acts + kv
    # decode: B tokens, KV cache length S sequence-sharded over 16
    w = 2 * n_loc
    kv_read = (L * (B / 16) * (S / 16) * cfg.n_kv_heads * cfg.hd * 2 * 2
               if cfg.family != "ssm" else 0)
    if cfg.mla is not None:
        m = cfg.mla
        kv_read = L * (B / 16) * (S / 16) * (m.kv_lora + m.rope_dim) * 2
    ssm = 0
    if cfg.ssm is not None:
        s = cfg.ssm
        h = s.expand * D // s.head_dim
        ssm = L * (B / 16) * h * s.d_state * s.head_dim * 4 * 2
    return w + kv_read + ssm


def load(tag: str = "baseline", subdir: str = "dryrun"):
    """Returns {(arch, shape): row} merged from full + probe records."""
    out = {}
    for arch in ARCHS:
        for shape in SHAPES:
            cfg = get_config(arch)
            skip = cell_is_skipped(cfg, shape)
            key = (arch, shape)
            if skip:
                out[key] = {"arch": arch, "shape": shape, "status": skip}
                continue
            suffix = "" if tag == "baseline" else f"__{tag}"
            full_p = RESULTS / subdir / f"{arch}__{shape}__single{suffix}.json"
            probe_p = RESULTS / subdir / f"{arch}__{shape}__probe{suffix}.json"
            if not (full_p.exists() and probe_p.exists()):
                out[key] = {"arch": arch, "shape": shape,
                            "status": "missing records"}
                continue
            full = json.loads(full_p.read_text())
            probe = json.loads(probe_p.read_text())
            if probe.get("status") != "ok" or full.get("status") != "ok":
                out[key] = {"arch": arch, "shape": shape,
                            "status": f"probe={probe.get('status')} "
                                      f"full={full.get('status')}"}
                continue
            t = probe["totals"]
            n_dev = full["n_devices"]
            # probe extrapolation can go slightly negative when XLA CSEs
            # collectives across unrolled layers — clamp (noted in §Method)
            t = {k: max(v, 0.0) for k, v in t.items()}
            compute = t["flops"] / PEAK_FLOPS
            mem_hi = t["bytes"] / HBM_BW               # HLO upper bound
            mem_lo = analytic_hbm_bytes(arch, shape, n_dev) / HBM_BW
            memory = mem_lo                            # dominant-term basis
            coll = t["coll"] / (ICI_BW * ICI_LINKS)
            dom = max((compute, "compute"), (memory, "memory"),
                      (coll, "collective"))
            mf = model_flops(arch, shape) / n_dev
            out[key] = {
                "arch": arch, "shape": shape, "status": "ok",
                "flops_dev": t["flops"], "bytes_dev": t["bytes"],
                "coll_dev": t["coll"],
                "t_compute_s": compute,
                "t_memory_lo_s": mem_lo, "t_memory_hi_s": mem_hi,
                "t_collective_s": coll,
                "dominant": dom[1],
                "bound_s": dom[0],
                "model_flops_dev": mf,
                "useful_ratio": mf / max(t["flops"], 1.0),
                "roofline_frac": compute / max(dom[0], 1e-30),
                "peak_mem_gb": full["memory"]["peak_bytes"] / 2**30,
                "fits_16g": full["memory"]["peak_bytes"] < 16 * 2**30,
            }
    return out


def report(tag: str = "baseline", subdir: str = "dryrun"):
    rows = load(tag, subdir)
    out = []
    for (arch, shape), r in sorted(rows.items()):
        if r.get("status") != "ok":
            out.append(f"{arch:26s} {shape:12s} {r.get('status')}")
            continue
        out.append(
            f"{arch:26s} {shape:12s} comp={r['t_compute_s']:.3e}s "
            f"mem={r['t_memory_lo_s']:.2e}..{r['t_memory_hi_s']:.2e}s "
            f"coll={r['t_collective_s']:.3e}s "
            f"dom={r['dominant']:10s} roofline={r['roofline_frac']:.2f} "
            f"useful={r['useful_ratio']:.2f} "
            f"peak={r['peak_mem_gb']:.1f}GB")
    return "\n".join(out)


def roofline_rows(tag: str = "baseline", subdir: str = "dryrun"):
    return [r for r in load(tag, subdir).values()]


if __name__ == "__main__":
    import sys
    sub = sys.argv[1] if len(sys.argv) > 1 else "dryrun"
    print(report(subdir=sub))
