from .checkpoint import (save, restore, restore_raw,  # noqa: F401
                         restore_latest, list_steps)
