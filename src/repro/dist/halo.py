"""Exchange abstraction for the vertex-cut GAS engine's mirror sync.

The engine's per-iteration communication is two phases over the mirror
replicas (paper §II-B): mirror partials reduce to masters (gather), master
values broadcast back to mirrors (scatter).  This module gives the engine a
pluggable wire format for those phases:

- ``DenseExchange`` — the seed path: ``all_gather`` the full padded
  (L_max,) slab from every device and index into it with the static
  ``red_index`` / ``(owner, own_slot)`` tables.  Bytes ∝ k²·L_max per
  phase, independent of partition quality.
- ``HaloExchange`` — mirror-routed: each device packs only its mirror
  slots into per-destination lanes (``halo_send``) and a single
  ``all_to_all`` delivers every lane to its owner, which scatters via
  ``halo_recv``.  Bytes ∝ k·(k−1)·H_max per phase — within per-pair
  padding of the ideal 2·mirrors volume, so CLUGP's mirror reduction is
  the engine's real wire cost.

Each backend exposes the same four operations:

  reduce_to_masters(partial, dev, combine)    per-device, inside shard_map
  broadcast_from_masters(new_master, dev)     per-device, inside shard_map
  reduce_stacked(partials, dev, combine)      stacked (k, L_max) one-device
  broadcast_stacked(masters, dev)             stacked (k, L_max) one-device

``dev`` is the layout's ``device_arrays()`` pytree — per-device slices in
the shard_map forms, full (k, …) stacks in the stacked forms.  ``combine``
is ``"sum"`` (pagerank) or ``"min"`` (label propagation).  The stacked
forms model the collective with a transpose (all_to_all) / broadcast
(all_gather), so tests and host benchmarks run the identical math.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

# identity element fed into padded send lanes; recv pads are dropped by the
# segment reduce regardless, so this only has to be shape-safe
_PAD_VALUE = {"sum": 0.0, "min": 3e38}


def _segment_combine(vals, segments, num_segments: int, combine: str):
    if combine == "sum":
        return jax.ops.segment_sum(vals, segments,
                                   num_segments=num_segments)
    return jax.ops.segment_min(vals, segments, num_segments=num_segments)


def _merge(local, received, combine: str):
    if combine == "sum":
        return local + received
    return jnp.minimum(local, received)


@dataclass(frozen=True)
class DenseExchange:
    """Padded all_gather mirror sync (the seed wire format)."""
    axis: str | None = None
    name = "dense"

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum"):
        g = jax.lax.all_gather(partial, self.axis)          # (k, L_max)
        return self._reduce_flat(g.reshape(-1), dev, combine)

    def broadcast_from_masters(self, new_master, dev):
        g = jax.lax.all_gather(new_master, self.axis)       # (k, L_max)
        return g[dev["owner"], dev["own_slot"]]

    # -- stacked halves ((k, L_max) arrays on one device) --
    def reduce_stacked(self, partials, dev, combine: str = "sum"):
        flat = partials.reshape(-1)
        return jax.vmap(
            lambda d: self._reduce_flat(flat, d, combine))(dev)

    def broadcast_stacked(self, masters, dev):
        return jax.vmap(lambda d: masters[d["owner"], d["own_slot"]])(dev)

    @staticmethod
    def _reduce_flat(flat_gathered, dev, combine: str):
        l_max = dev["vert_gid"].shape[0]
        return _segment_combine(flat_gathered, dev["red_index"],
                                l_max + 1, combine)[:l_max]

    def bytes_per_iter(self, layout, value_bytes: int = 4) -> int:
        return layout.comm_bytes_mirror_sync(value_bytes)


@dataclass(frozen=True)
class HaloExchange:
    """Mirror-routed all_to_all sync over the layout's halo tables.

    Reduce: pack mirror values into (k, H_max) destination lanes, one
    all_to_all, scatter-combine received lanes into master slots, merge
    with the local partial (a master's own contribution never leaves the
    device).  Broadcast runs the same route backwards: masters pack
    ``halo_recv`` lanes, mirrors scatter via ``halo_send``; master slots
    keep their local value.
    """
    axis: str | None = None
    name = "halo"

    # -- per-device halves (inside shard_map over ``axis``) --
    def reduce_to_masters(self, partial, dev, combine: str = "sum"):
        l_max = partial.shape[0]
        send = self._pack(partial, dev["halo_send"], combine)
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, H_max)
        agg = _segment_combine(recv.reshape(-1),
                               dev["halo_recv"].reshape(-1),
                               l_max + 1, combine)[:l_max]
        return _merge(partial, agg, combine)

    def broadcast_from_masters(self, new_master, dev):
        l_max = new_master.shape[0]
        send = self._pack(new_master, dev["halo_recv"], "sum")
        recv = jax.lax.all_to_all(send, self.axis, 0, 0)    # (k, H_max)
        return self._unpack(new_master, recv, dev)

    # -- stacked halves: all_to_all over k virtual devices == transpose --
    def reduce_stacked(self, partials, dev, combine: str = "sum"):
        l_max = partials.shape[1]
        send = jax.vmap(
            lambda v, idx: self._pack(v, idx, combine)
        )(partials, dev["halo_send"])                       # (k, k, H_max)
        recv = jnp.swapaxes(send, 0, 1)

        def one(recv_q, slots_q, partial_q):
            agg = _segment_combine(recv_q.reshape(-1),
                                   slots_q.reshape(-1),
                                   l_max + 1, combine)[:l_max]
            return _merge(partial_q, agg, combine)

        return jax.vmap(one)(recv, dev["halo_recv"], partials)

    def broadcast_stacked(self, masters, dev):
        send = jax.vmap(
            lambda v, idx: self._pack(v, idx, "sum")
        )(masters, dev["halo_recv"])                        # (k, k, H_max)
        recv = jnp.swapaxes(send, 0, 1)
        return jax.vmap(
            lambda m, r, d: self._unpack(m, r, d)
        )(masters, recv, dev)

    @staticmethod
    def _pack(values, lanes, combine: str):
        """values (L_max,) → (k, H_max) send lanes; pad lanes read the
        combine identity appended at index L_max."""
        pad = jnp.full((1,), _PAD_VALUE[combine], values.dtype)
        return jnp.concatenate([values, pad])[lanes]

    @staticmethod
    def _unpack(new_master, recv, dev):
        """Scatter received master values into this device's mirror slots
        (each valid lane targets a distinct slot; pads land in the dropped
        L_max bucket); master slots keep their local value."""
        l_max = new_master.shape[0]
        scattered = jnp.zeros((l_max + 1,), new_master.dtype).at[
            dev["halo_send"].reshape(-1)].set(recv.reshape(-1))[:l_max]
        return jnp.where(dev["is_master"], new_master, scattered)

    def bytes_per_iter(self, layout, value_bytes: int = 4) -> int:
        return layout.comm_bytes_halo(value_bytes)


EXCHANGES = {"dense": DenseExchange, "halo": HaloExchange}


def get_exchange(name: str, axis: str | None = None):
    """Exchange factory: ``name`` ∈ {"dense", "halo"}; ``axis`` is the mesh
    axis for the shard_map halves (stacked halves ignore it)."""
    try:
        cls = EXCHANGES[name]
    except KeyError:
        raise ValueError(
            f"unknown exchange {name!r}; expected one of "
            f"{sorted(EXCHANGES)}") from None
    return cls(axis=axis)
