"""Optimizers (no optax): AdamW and factored Adafactor.

State trees mirror the parameter tree, so the ZeRO sharding specs of the
params apply leaf-for-leaf to the optimizer state (Adafactor's factored
second moment collapses one dim — its specs drop that axis).

Memory per param:  AdamW fp32 m+v = 8 B;  Adafactor (β1=0) ≈ 4 B/(row+col)
— the ≥100B archs default to Adafactor (see launch/train.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable   # (grads, state, params, step) -> (params, state)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def _map_leaves(fn, grads, *rest):
    """tree_map where ``rest`` trees may have dict-structured per-leaf
    state: flattens all trees up to grads' structure."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    rest_leaves = [treedef.flatten_up_to(r) for r in rest]
    out = [fn(g, *(r[i] for r in rest_leaves))
           for i, g in enumerate(leaves)]
    n = len(out[0])
    return tuple(treedef.unflatten([o[j] for o in out]) for j in range(n))


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          schedule=None):
    sched = schedule or (lambda s: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            delta = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps) \
                + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), \
                m2, v2

        p2, m2, v2 = _map_leaves(upd, grads, state["m"], state["v"], params)
        return p2, {"m": m2, "v": v2}

    return Optimizer("adamw", init, update)


def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_rms=1.0,
              min_factor_dim=128, weight_decay=0.0, schedule=None):
    """Factored second-moment Adafactor (β1=0, Shazeer & Stern 2018)."""
    sched = schedule or (lambda s: lr)

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_factor_dim \
            and p.shape[-2] >= min_factor_dim

    def init(params):
        def z(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step)
        beta = 1.0 - stepf ** (-decay)

        def upd(g, f, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in f:
                vr = beta * f["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * f["vc"] + (1 - beta) * g2.mean(-2)
                denom = vr[..., None] * vc[..., None, :] \
                    / jnp.maximum(vr.mean(-1)[..., None, None], eps)
                u = g * jax.lax.rsqrt(denom + eps)
                f2 = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                f2 = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_rms)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), f2

        p2, f2 = _map_leaves(upd, grads, state["f"], params)
        return p2, {"f": f2}

    return Optimizer("adafactor", init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor}[name](**kw)
