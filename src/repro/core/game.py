"""Pass 2 — game-theoretic cluster partitioning (paper §V, Alg. 3).

Each cluster is a selfish player choosing one of k partitions to minimize

    φ(a_i) = (λ/k)·|c_i|·|a_i|  +  ½·(|e(c_i, V\\a_i)| + |e(V\\a_i, c_i)|)

This is an exact potential game (Thm 4) with potential

    Φ(Λ)  = (λ/2k)·Σ|p_i|²  +  ½·Σ|e(p_i, V\\p_i)|

so sequential best response converges to a Nash equilibrium; the paper
parallelizes by batching clusters (contiguous IDs — BFS locality, §V-D) and
running batches concurrently against a shared snapshot.  We reproduce both:
``best_response_rounds`` (host, vectorized-Jacobi-within-batch /
Gauss–Seidel-across-batches) and a jitted JAX variant used by shard_map
(one batch per device) and by the Pallas ``game_bestresponse`` kernel.

λ defaults to its maximum feasible value (Thm 5), the paper's §VI setting:
    λ_max = k²·Σ|e(c_i, V\\c_i)|  /  (Σ|c_i|)²
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import scipy.sparse as sp

import jax
import jax.numpy as jnp

from ..dist import collectives as coll


@dataclass
class ClusterGraph:
    """Contracted graph: vertices = clusters."""
    sizes: np.ndarray          # |c_i| = intra-cluster edge counts, int64[m]
    adj: sp.csr_matrix         # symmetrized inter-cluster edge counts, m×m
    vertex_cluster: np.ndarray  # original vertex -> cluster id
    m: int

    @property
    def total_cut_capacity(self) -> int:
        """Σ_i |e(c_i, V\\c_i)| — Thm 5/6 constant (each directed cross edge
        counted once per incident cluster, i.e. adj.sum() counts it twice
        after symmetrization... adj already = W + Wᵀ so row sums are it)."""
        return int(self.adj.sum()) // 1  # Σ_i row_sum = Σ_i |e(c_i,·)|+|e(·,c_i)|


def contract(src: np.ndarray, dst: np.ndarray, clu: np.ndarray) -> ClusterGraph:
    """Build the cluster multigraph from the vertex→cluster table."""
    cs, cd = clu[src], clu[dst]
    m = int(clu.max()) + 1 if clu.size else 0
    intra = cs == cd
    sizes = np.bincount(cs[intra], minlength=m).astype(np.int64)
    xs, xd = cs[~intra], cd[~intra]
    w = np.ones(xs.shape[0], dtype=np.int64)
    W = sp.coo_matrix((w, (xs, xd)), shape=(m, m)).tocsr()
    S = (W + W.T).tocsr()
    S.sum_duplicates()
    return ClusterGraph(sizes, S, clu, m)


def lambda_max(cg: ClusterGraph, k: int) -> float:
    """Thm 5 upper end of the feasible λ range (paper's default)."""
    total_sizes = float(cg.sizes.sum())
    if total_sizes <= 0:
        return 1.0
    # Σ_i |e(c_i,V\c_i)| with both directions = adj row sums / but each
    # directed edge contributes to exactly two clusters' boundaries; the
    # paper's Σ counts per-cluster boundary edges, i.e. adj.sum()/2 per
    # direction pair — use the symmetric total/2 (per-cluster out+in)/2.
    total_cut = float(cg.adj.sum()) / 2.0
    return (k * k) * total_cut / (total_sizes * total_sizes)


def lambda_from_weight(cg: ClusterGraph, k: int, weight: float) -> float:
    """Relative-weight parameterization (paper Fig. 11b): weight∈(0,1) is
    the share of the load-balance term; 0.5 ⇒ the Eq. 15 equal-importance
    setting scaled so both terms match at a uniform random assignment."""
    total_sizes = float(cg.sizes.sum())
    total_cut = float(cg.adj.sum()) / 2.0
    if total_sizes <= 0 or total_cut <= 0:
        return 1.0
    base = k * total_cut / (total_sizes * total_sizes / k)
    w = min(max(weight, 1e-3), 1 - 1e-3)
    return base * (w / (1 - w))


@dataclass
class GameResult:
    assign: np.ndarray         # cluster -> partition, int32[m]
    rounds: int
    potential_trace: list
    moves: int


def potential(cg: ClusterGraph, assign: np.ndarray, k: int,
              lam: float) -> float:
    """Φ(Λ) (Definition 4)."""
    loads = np.bincount(assign, weights=cg.sizes, minlength=k)
    load_term = lam / (2.0 * k) * float((loads ** 2).sum())
    A = cg.adj.tocoo()
    cross = float(A.data[assign[A.row] != assign[A.col]].sum()) / 2.0
    # cross counts each undirected-symmetrized pair once ⇒ Σ_p |e(p,V\p)| =
    # (directed cross edges) = cross  (adj = W+Wᵀ, /2 restores W totals)
    return load_term + 0.5 * cross


def global_cost(cg: ClusterGraph, assign: np.ndarray, k: int,
                lam: float) -> float:
    """φ(Λ) (Eq. 10)."""
    loads = np.bincount(assign, weights=cg.sizes, minlength=k)
    load_term = lam / k * float((loads ** 2).sum())
    A = cg.adj.tocoo()
    cross = float(A.data[assign[A.row] != assign[A.col]].sum()) / 2.0
    return load_term + cross


def best_response_rounds(cg: ClusterGraph, k: int, lam: float | None = None,
                         batch_size: int | None = None,
                         max_rounds: int = 64, seed: int = 0,
                         track_potential: bool = False,
                         base_loads: np.ndarray | None = None) -> GameResult:
    """Alg. 3 with the paper's §V-D batching.

    Batches are the parallel unit (one per thread/device).  A batch plays
    *sequentially* (Gauss–Seidel) against the live load table; the cut-mass
    table ``A`` is refreshed per batch (threads see a per-batch snapshot of
    other players' choices — the paper's shared-nothing approximation).
    ``batch_size=None`` ⇒ one batch = fully sequential best response with a
    guaranteed monotone potential (exact potential game, Thm 4).

    ``base_loads`` adds exogenous per-partition load (used by the Mint-like
    baseline's sliding window and by the distributed pipeline where other
    nodes' loads are synced in).
    """
    m = cg.m
    if m == 0:
        return GameResult(np.zeros(0, np.int32), 0, [], 0)
    if lam is None:
        lam = lambda_max(cg, k)
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, k, size=m).astype(np.int64)   # Alg.3 line 2
    sizes = cg.sizes.astype(np.float64)
    loads = np.bincount(assign, weights=sizes, minlength=k)
    if base_loads is not None:
        loads = loads + base_loads.astype(np.float64)
    S = cg.adj.astype(np.float64)
    indptr, indices, data = S.indptr, S.indices, S.data
    row_tot = np.asarray(S.sum(axis=1)).ravel().astype(np.float64)
    if batch_size is None:
        batch_size = m
    trace = []
    total_moves = 0
    ar = np.arange(k)
    for rnd in range(max_rounds):
        moved = 0
        for lo in range(0, m, batch_size):
            hi = min(m, lo + batch_size)
            for i in range(lo, hi):          # Gauss–Seidel sweep (live state)
                sz = sizes[i]
                cur = assign[i]
                nbrs = indices[indptr[i]:indptr[i + 1]]
                w = data[indptr[i]:indptr[i + 1]]
                # cut mass into each partition: A[p] = Σ_{j: a_j=p} S[i,j]
                aff = np.bincount(assign[nbrs], weights=w, minlength=k)
                loads_ex = loads - sz * (ar == cur)
                cost = (lam / k) * sz * (loads_ex + sz) \
                    + 0.5 * (row_tot[i] - aff)
                best = int(np.argmin(cost))
                if cost[best] + 1e-9 < cost[cur]:
                    loads[cur] -= sz
                    loads[best] += sz
                    assign[i] = best
                    moved += 1
        total_moves += moved
        if track_potential:
            trace.append(potential(cg, assign, k, lam))
        if moved == 0:
            return GameResult(assign.astype(np.int32), rnd + 1, trace,
                              total_moves)
    return GameResult(assign.astype(np.int32), max_rounds, trace, total_moves)


def greedy_assign(cg: ClusterGraph, k: int) -> np.ndarray:
    """CLUGP-G ablation (§VI-B): big clusters → least-loaded partitions.
    Stable sort so ties break by cluster id — the jit backend's
    ``jax_greedy_assign`` (jnp.argsort is stable) then matches bit-for-bit.
    """
    order = np.argsort(-cg.sizes, kind="stable")
    loads = np.zeros(k, dtype=np.int64)
    assign = np.zeros(cg.m, dtype=np.int32)
    for c in order:
        p = int(np.argmin(loads))
        assign[c] = p
        loads[p] += int(cg.sizes[c])
    return assign


# ---------------------------------------------------------------------------
# JAX batched best-response round (dense adjacency) — jit/shard_map building
# block; the Pallas kernel in repro.kernels.game_bestresponse implements the
# same contraction with CSR tiles.
# ---------------------------------------------------------------------------

_LANE_BIG = jnp.float32(3e38)   # masks partition lanes >= the traced k_real


def _mask_lanes(cost, k_real, lanes=None):
    """Disable partition lanes past the traced live count ``k_real`` (the
    compile-once k-sweep pads every per-k problem to k_max lanes).  With
    ``k_real=None`` (the static-k strategies) this is the identity."""
    if k_real is None:
        return cost
    if lanes is None:
        lanes = jax.lax.broadcasted_iota(jnp.int32, cost.shape,
                                         cost.ndim - 1)
    return jnp.where(lanes < k_real, cost, _LANE_BIG)


def jax_greedy_assign(sizes, k: int, k_real=None):
    """jit/shard_map form of ``greedy_assign`` over padded (m_cap,) sizes.
    Bit-identical to the host version: both sort stably by (-size, id) and
    break load ties toward the lowest partition id.  Padded clusters have
    size 0 — they land wherever argmin points but carry no vertices and
    add no load.  ``k_real`` (traced) restricts the argmin to the live
    lanes of a k_max-padded sweep step."""
    m_cap = sizes.shape[0]
    order = jnp.argsort(-sizes)                 # jnp.argsort is stable

    def body(i, carry):
        loads, assign = carry
        c = order[i]
        p = jnp.argmin(_mask_lanes(loads, k_real)).astype(jnp.int32)
        return loads.at[p].add(sizes[c]), assign.at[c].set(p)

    loads0 = jnp.zeros((k,), sizes.dtype)
    assign0 = jnp.zeros((m_cap,), jnp.int32)
    _, assign = jax.lax.fori_loop(0, m_cap, body, (loads0, assign0))
    return assign


def jax_game_rounds(xs, xd, sizes, row_tot, k: int, lam, *,
                    batch_size: int, max_rounds: int, seed: int,
                    use_pallas: bool = False, block_m: int = 256,
                    axis: str | None = None, damping: float = 0.5,
                    k_real=None):
    """Batched best-response rounds (Alg. 3 + §V-D) as a pure jax program.

    The cluster graph arrives as its cross-edge list: ``xs``/``xd`` are the
    (padded) cluster endpoints of every inter-cluster edge — padding uses
    the out-of-range sentinel ``m_cap`` so scatter-adds drop it.  Each
    batch recomputes its cut-mass rows from the live assignment (the
    host's per-batch snapshot refresh), plays Jacobi *within* the batch,
    and updates the load table between batches (Gauss–Seidel across
    batches).  Under ``axis`` (shard_map) each device owns a private id
    space and acts as one §V-D batch: load deltas are psum'd after every
    batch so remote players see a fresh global load vector, and the
    convergence test is the psum'd move count.

    Jacobi-within-batch needs ``damping``: unlike the host's Gauss–Seidel
    sweep, simultaneous best responses herd toward the currently
    least-loaded partitions and oscillate, so each round only a random
    ``damping`` fraction of improving players actually moves (the standard
    parallel-local-search fix).  Damped Jacobi plateaus rather than
    reaching an exact Nash point (a small cycle of players keeps wanting
    to chase each other), so termination uses the game's own potential
    Φ (Thm 4): the round loop tracks the best-Φ assignment seen and stops
    once Φ has not improved for ``stall_rounds`` consecutive rounds —
    returning the best snapshot, not the last thrash.

    ``lam`` is a traced scalar (λ_max of the streamed cluster graph).
    With ``use_pallas`` the per-batch argmin sweep runs on the
    ``game_bestresponse`` Pallas kernel (k padded to a 128-lane multiple);
    otherwise the identical XLA fallback math.  ``k_real`` (traced, XLA
    path only — the Pallas kernel bakes k in) plays the game on the live
    lanes of a k_max-padded sweep step.  Returns (assign (m_cap,) int32,
    rounds)."""
    if k_real is not None and use_pallas:
        raise ValueError("jax_game_rounds: the Pallas kernel needs a "
                         "static k; run traced-k sweeps on the xla/scan "
                         "game modes")
    m_cap = sizes.shape[0]
    kpad = ((k + 127) // 128) * 128 if use_pallas else k
    sizes = sizes.astype(jnp.float32)
    row_tot = row_tot.astype(jnp.float32)
    lam = jnp.asarray(lam, jnp.float32)
    kf = (jnp.float32(k) if k_real is None
          else k_real.astype(jnp.float32))
    n_batches = max(1, -(-m_cap // batch_size))
    ar = jnp.arange(m_cap)

    key = jax.random.PRNGKey(seed)
    if axis is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axis))
    assign0 = jax.random.randint(key, (m_cap,), 0,
                                 k if k_real is None else k_real,
                                 dtype=jnp.int32)
    loads0 = jnp.zeros((kpad,), jnp.float32).at[assign0].add(sizes)
    loads0 = coll.psum(loads0, axis)

    def psum_(x):
        return coll.psum(x, axis)

    def batch_body(b, carry):
        assign, loads, moved, rnd = carry
        aff = (jnp.zeros((m_cap, kpad), jnp.float32)
               .at[xs, assign[jnp.clip(xd, 0, m_cap - 1)]]
               .add(1.0, mode="drop")
               .at[xd, assign[jnp.clip(xs, 0, m_cap - 1)]]
               .add(1.0, mode="drop"))
        if use_pallas:
            from ..kernels.game_bestresponse import game_bestresponse
            interpret = jax.default_backend() != "tpu"
            best, best_cost = game_bestresponse(
                aff, sizes, row_tot, assign, loads, lam=lam, k=k,
                block_m=block_m, interpret=interpret)
        else:
            pids = jax.lax.broadcasted_iota(jnp.int32, (m_cap, kpad), 1)
            own = (pids == assign[:, None]).astype(jnp.float32)
            loads_ex = loads[None, :] - sizes[:, None] * own
            cost = (lam / kf) * sizes[:, None] * (loads_ex + sizes[:, None]) \
                + 0.5 * (row_tot[:, None] - aff)
            cost = _mask_lanes(cost, k_real, pids)
            best = jnp.argmin(cost, axis=1).astype(jnp.int32)
            best_cost = jnp.min(cost, axis=1)
        cost_cur = (lam / kf) * sizes * loads[assign] \
            + 0.5 * (row_tot - aff[ar, assign])
        in_batch = (ar >= b * batch_size) & (ar < (b + 1) * batch_size)
        # strict improvement with an f32-relative margin: absolute 1e-9
        # (the host's f64 threshold) is below float32 resolution at
        # realistic cost magnitudes and lets cost ties flap forever
        margin = 1e-6 + 1e-5 * jnp.abs(cost_cur)
        wants = in_batch & (best_cost + margin < cost_cur)
        damp_key = jax.random.fold_in(key, rnd * n_batches + b + 1)
        # decay the move probability round by round: late-game herding of
        # small clusters between near-equal partitions is what keeps
        # Jacobi sweeps from settling
        p = jnp.maximum(damping * 0.92 ** rnd.astype(jnp.float32), 0.08)
        move = wants & jax.random.bernoulli(damp_key, p, (m_cap,))
        msz = jnp.where(move, sizes, 0.0)
        delta = (jnp.zeros((kpad,), jnp.float32)
                 .at[best].add(msz).at[assign].add(-msz))
        assign = jnp.where(move, best, assign)
        loads = loads + psum_(delta)
        moved = moved + psum_(wants.sum().astype(jnp.int32))
        return assign, loads, moved, rnd

    def potential(assign, loads):
        """Φ (Definition 4) from the live tables — the cut mass is
        recomputed from the cross-edge list; Σ_i (row_tot − aff[i,a_i])
        double-counts each symmetrized pair, hence the 0.25."""
        aff = (jnp.zeros((m_cap, kpad), jnp.float32)
               .at[xs, assign[jnp.clip(xd, 0, m_cap - 1)]]
               .add(1.0, mode="drop")
               .at[xd, assign[jnp.clip(xs, 0, m_cap - 1)]]
               .add(1.0, mode="drop"))
        cut = psum_(jnp.sum(row_tot - aff[ar, assign]))
        load_sq = jnp.sum(loads * loads)        # loads are already global
        return (lam / (2 * kf)) * load_sq + 0.25 * cut

    stall_rounds = 4

    def round_body(carry):
        assign, loads, rnd, _, best_assign, best_phi, stall = carry
        assign, loads, moved, _ = jax.lax.fori_loop(
            0, n_batches, batch_body, (assign, loads, jnp.int32(0), rnd))
        phi = potential(assign, loads)
        better = phi < best_phi - 1e-6 * jnp.abs(best_phi)
        best_assign = jnp.where(better, assign, best_assign)
        best_phi = jnp.minimum(phi, best_phi)
        stall = jnp.where(better, 0, stall + 1)
        return assign, loads, rnd + 1, moved, best_assign, best_phi, stall

    def cond(carry):
        _, _, rnd, moved, _, _, stall = carry
        return (moved > 0) & (rnd < max_rounds) & (stall < stall_rounds)

    # best_phi starts at a huge FINITE value: with inf the round-1
    # improvement test computes inf - inf = NaN, 'better' is False, and
    # best_assign would stay the random initial assignment
    _, _, rounds, _, best_assign, _, _ = jax.lax.while_loop(
        cond, round_body,
        (assign0, loads0, jnp.int32(0), jnp.int32(1), assign0,
         jnp.float32(3e38), jnp.int32(0)))
    return best_assign, rounds


def jax_cluster_csr(xs, xd, m_cap: int, nnz_cap: int):
    """In-graph aggregated edge list of the cluster multigraph from its
    cross-edge endpoints (padded lanes = ``m_cap``): the distinct
    symmetrized (row, col) pairs with their multiplicities, compacted
    into ``nnz_cap`` lanes (pad row = ``m_cap``).  Returns (row, col, w,
    overflow) — callers retry with a doubled ``nnz_cap`` when the flag
    fires, like the partitioner's other adaptive caps.  Aggregation
    matters twice: the per-round cut-mass scatter walks nnz lanes at
    ~100 ns each on XLA:CPU, and distinct pairs are ~10× fewer than raw
    cross edges on web graphs."""
    # int32 keys: fine while m_cap·(m_cap+1) < 2³¹, i.e. m_cap ≤ ~46k —
    # the partitioner backends fall back to the Jacobi game above that
    if m_cap * (m_cap + 1) >= 2 ** 31:
        raise ValueError(
            f"jax_cluster_csr: m_cap={m_cap} overflows the int32 "
            f"pair-key space (limit ~46340); use the 'xla'/'pallas' "
            f"game kernel instead")
    big = jnp.int32(m_cap * m_cap)
    ok = (xs < m_cap) & (xd < m_cap)
    key = jnp.concatenate([xs * m_cap + xd, xd * m_cap + xs])
    key = jnp.where(jnp.concatenate([ok, ok]), key, big)
    sk = jnp.sort(key)
    first = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    first = first & (sk < big)
    start = jnp.searchsorted(sk, sk, side="left")
    mult = jnp.searchsorted(sk, sk, side="right") - start
    rank = jnp.cumsum(first.astype(jnp.int32)) - 1
    slot = jnp.where(first, rank, nnz_cap)
    row = jnp.full((nnz_cap,), m_cap, jnp.int32).at[slot].set(
        (sk // m_cap).astype(jnp.int32), mode="drop")
    col = jnp.zeros((nnz_cap,), jnp.int32).at[slot].set(
        (sk % m_cap).astype(jnp.int32), mode="drop")
    w = jnp.zeros((nnz_cap,), jnp.float32).at[slot].set(
        mult.astype(jnp.float32), mode="drop")
    overflow = (jnp.where(first, rank, -1).max() + 1) > nnz_cap
    return row, col, w, overflow


def jax_game_rounds_gs(row, col, w, sizes, row_tot, k: int, lam, *,
                       max_rounds: int, seed: int,
                       axis: str | None = None, k_real=None):
    """Gauss–Seidel-on-loads best response as a lax.scan over clusters —
    the CPU-fast form of Alg. 3 (the batched-Jacobi ``jax_game_rounds``
    needs damping and ~10× the rounds).  Per round the cut-mass table
    aff[i, p] is computed once from the round-start assignment (one
    aggregated scatter over the distinct cluster pairs); the sweep then
    plays clusters sequentially against the LIVE load table, i.e. one
    round = one §V-D batch snapshot for the cut term with Gauss–Seidel
    load accounting.  The snapshot approximation can cycle instead of
    reaching an exact Nash point, so termination tracks the potential Φ
    (Thm 4): the loop keeps the best-Φ assignment seen and stops when a
    sweep moves nothing or Φ stalls for ``stall_rounds`` rounds.

    Under ``axis`` each device sweeps its private clusters (one batch
    per device) and loads/moves are psum'd between rounds.  ``k_real``
    (traced) plays on the live lanes of a k_max-padded sweep step."""
    m_cap = sizes.shape[0]
    sizes = sizes.astype(jnp.float32)
    row_tot = row_tot.astype(jnp.float32)
    lam = jnp.asarray(lam, jnp.float32)
    kf = (jnp.float32(k) if k_real is None
          else k_real.astype(jnp.float32))

    key = jax.random.PRNGKey(seed)
    if axis is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axis))
    assign0 = jax.random.randint(key, (m_cap,), 0,
                                 k if k_real is None else k_real,
                                 dtype=jnp.int32)
    loads0 = jnp.zeros((k,), jnp.float32).at[assign0].add(sizes)
    loads0 = coll.psum(loads0, axis)

    lanes = jnp.arange(k)
    ar = jnp.arange(m_cap, dtype=jnp.int32)

    def cluster_step(carry, x):
        assign, loads, moved = carry
        i, aff, sz, rt = x
        cur = assign[i]
        own = (lanes == cur).astype(jnp.float32)
        loads_ex = loads - sz * own
        cost = (lam / kf) * sz * (loads_ex + sz) + 0.5 * (rt - aff)
        cost = _mask_lanes(cost, k_real, lanes)
        best = jnp.argmin(cost).astype(jnp.int32)
        move = cost[best] + 1e-6 + 1e-5 * jnp.abs(cost[cur]) < cost[cur]
        newa = jnp.where(move, best, cur)
        loads = loads + sz * ((lanes == newa).astype(jnp.float32) - own) \
            * move.astype(jnp.float32)
        assign = assign.at[i].set(newa)     # i is streamed in → in-place
        return (assign, loads, moved + move.astype(jnp.int32)), None

    def aff_of(assign):
        return (jnp.zeros((m_cap, k), jnp.float32)
                .at[row, assign[jnp.clip(col, 0, m_cap - 1)]]
                .add(w, mode="drop"))

    def phi_of(assign, loads, aff):
        """Φ (Definition 4); Σ_i (row_tot − aff[i,a_i]) double-counts
        each symmetrized pair, hence the 0.25."""
        cut = coll.psum(jnp.sum(row_tot - aff[ar, assign]), axis)
        return (lam / (2 * kf)) * jnp.sum(loads * loads) + 0.25 * cut

    stall_rounds = 4

    def round_body(carry):
        assign, loads, rnd, _, best_assign, best_phi, stall = carry
        aff = aff_of(assign)
        phi = phi_of(assign, loads, aff)
        better = phi < best_phi
        best_assign = jnp.where(better, assign, best_assign)
        stall = jnp.where(phi < best_phi - 1e-6 * jnp.abs(best_phi),
                          0, stall + 1)
        best_phi = jnp.minimum(phi, best_phi)
        (assign, loads, moved), _ = jax.lax.scan(
            cluster_step, (assign, loads, jnp.int32(0)),
            (ar, aff, sizes, row_tot))
        if axis is not None:
            # remote batches see this round's deltas only now (§V-D
            # shared-nothing approximation)
            local = jnp.zeros((k,), jnp.float32).at[assign].add(sizes)
            loads = coll.psum(local, axis)
            moved = coll.psum(moved, axis)
        return (assign, loads, rnd + 1, moved, best_assign, best_phi,
                stall)

    def cond(carry):
        _, _, rnd, moved, _, _, stall = carry
        return (moved > 0) & (rnd < max_rounds) & (stall < stall_rounds)

    # finite sentinel: an inf best_phi makes the stall margin NaN on
    # round 1 (inf - inf) and silently burns one stall round
    assign, loads, rounds, _, best_assign, best_phi, _ = jax.lax.while_loop(
        cond, round_body,
        (assign0, loads0, jnp.int32(0), jnp.int32(1), assign0,
         jnp.float32(3e38), jnp.int32(0)))
    # the final sweep's state was never Φ-checked inside the loop
    phi = phi_of(assign, loads, aff_of(assign))
    best_assign = jnp.where(phi < best_phi, assign, best_assign)
    return best_assign, rounds


def jax_best_response_round(S, sizes, assign, loads, k: int, lam: float,
                            batch_slice=None):
    """One Jacobi batch update.  S: dense (b, m) adjacency rows of the batch,
    sizes: (b,), assign_all: (m,), loads: (k,). Returns new batch assign."""
    onehot = jax.nn.one_hot(assign, k, dtype=S.dtype)         # (m, k)
    A = S @ onehot                                            # (b, k)
    row_tot = S.sum(axis=1, keepdims=True)
    if batch_slice is None:
        cur = assign
        sz = sizes[:, None]
    else:
        cur = jax.lax.dynamic_slice_in_dim(assign, batch_slice, S.shape[0])
        sz = jax.lax.dynamic_slice_in_dim(sizes, batch_slice, S.shape[0])[:, None]
    loads_ex = loads[None, :] - sz * jax.nn.one_hot(cur, k, dtype=S.dtype)
    cost = (lam / k) * sz * (loads_ex + sz) + 0.5 * (row_tot - A)
    return jnp.argmin(cost, axis=1).astype(jnp.int32)
