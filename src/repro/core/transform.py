"""Pass 3 — partition transformation (paper Alg. 1).

Restream the edges and turn the vertex→partition mapping (join of passes
1 and 2) into an edge→partition assignment, strictly enforcing the balance
cap L_max = τ·|E|/k:

  - both endpoints' partitions full   → any underflow partition (least load)
  - same partition                    → keep
  - an endpoint was divided (has mirrors) → reuse the mirror side (free cut)
  - otherwise                         → cut the higher-degree endpoint
                                        (HDRF-style, lines 20-22)

Space O(k) (the load array), time O(|E|) — matching §III-C.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def transform_np(src: np.ndarray, dst: np.ndarray,
                 vertex_part: np.ndarray, deg: np.ndarray,
                 divided: np.ndarray, k: int, tau: float = 1.0) -> np.ndarray:
    E = src.shape[0]
    lmax = tau * E / float(k)
    loads = np.zeros(k, dtype=np.int64)
    assign = np.zeros(E, dtype=np.int32)
    vp = vertex_part
    for i in range(E):
        u = int(src[i]); v = int(dst[i])
        pu = int(vp[u]); pv = int(vp[v])
        if loads[pu] >= lmax or loads[pv] >= lmax:      # lines 6-14
            if loads[pu] < lmax:
                p = pu
            elif loads[pv] < lmax:
                p = pv
            else:
                p = int(np.argmin(loads))
        elif pu == pv:                                   # lines 15-16
            p = pu
        elif divided[u]:                                 # lines 17-19
            p = pv
        elif divided[v]:
            p = pu
        elif deg[v] > deg[u]:                            # lines 20-22
            p = pu
        else:
            p = pv
        assign[i] = p
        loads[p] += 1
    return assign


def _transform_step(loads, edge, *, lmax: float, k: int):
    u, v, pu, pv, du, dv, divu, divv = edge
    full_u = loads[pu] >= lmax
    full_v = loads[pv] >= lmax
    least = jnp.argmin(loads).astype(jnp.int32)
    overflow_choice = jnp.where(~full_u, pu, jnp.where(~full_v, pv, least))
    same = pu == pv
    mirror_choice = jnp.where(divu.astype(bool), pv, pu)
    has_mirror = (divu > 0) | (divv > 0)
    degree_choice = jnp.where(dv > du, pu, pv)
    normal = jnp.where(same, pu,
                       jnp.where(has_mirror, mirror_choice, degree_choice))
    p = jnp.where(full_u | full_v, overflow_choice, normal).astype(jnp.int32)
    loads = loads.at[p].add(1)
    return loads, p


def transform_jax(src, dst, vertex_part, deg, divided, k: int,
                  tau: float = 1.0):
    """lax.scan form of Alg. 1 (used inside the jitted pipeline)."""
    E = src.shape[0]
    lmax = tau * E / float(k)
    vp = jnp.asarray(vertex_part, jnp.int32)
    edges = jnp.stack([
        jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
        vp[src], vp[dst],
        jnp.asarray(deg, jnp.int32)[src], jnp.asarray(deg, jnp.int32)[dst],
        jnp.asarray(divided, jnp.int32)[src],
        jnp.asarray(divided, jnp.int32)[dst],
    ], axis=1)
    loads0 = jnp.zeros((k,), dtype=jnp.int32)
    step = lambda s, e: _transform_step(s, e, lmax=lmax, k=k)
    _, assign = jax.lax.scan(step, loads0, edges)
    return assign
