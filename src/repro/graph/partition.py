"""Vertex-cut partition layout: from an edge→partition assignment to the
static padded per-device tables the GAS engine runs on.

PowerGraph semantics (paper §II-B): each vertex that appears in several
partitions has one **master** replica (here: the partition holding most of
its edges, ties → lowest id) and mirrors elsewhere.  Per GAS iteration the
mirrors' partial aggregates flow to the master (gather), the master applies
the update, and the new value flows back (scatter).  Communication per
iteration is therefore proportional to the number of mirrors, i.e. to
(RF − 1)·|V| — the quantity CLUGP minimizes.

Two wire formats are materialized for the exchange layer
(``repro.dist.halo``):

- the **dense** tables (``red_index`` / ``owner`` / ``own_slot``) that back
  the padded all_gather path — bytes ∝ k²·L_max no matter how good the
  partition is; and
- the **halo routing tables**: for every ordered device pair (p, q) the
  static send list of p's mirror slots owned by q and the matching recv
  list of q's master slots, padded per-pair to ``H_max`` so they jit.
  The mirror-only backend moves 2·k·(k−1)·H_max values per iteration —
  within per-pair padding of the ideal 2·mirrors volume, so partition
  quality shows up on the wire.

All tables are padded to static shapes so the engine jits/shard_maps:

  edge_src/edge_dst (k, E_max)    local-slot endpoints, padded with L_max
  vert_gid          (k, L_max)    local slot → global vertex id (pad: V)
  owner / own_slot  (k, L_max)    master device + slot there
  red_index         (k, k·L_max)  flat all_gather entry → my owned slot
  out_deg           (k, L_max)    global out-degree (pagerank)
  halo_send         (k, k, H_max) [p, q, h] → p's mirror slot whose h-th
                                  value goes to owner q (pad: L_max)
  halo_recv         (k, k, H_max) [q, p, h] → q's master slot where the
                                  h-th value from p lands (pad: L_max)
  halo_cnt          (k, k)        [p, q] → number of REAL mirror lanes in
                                  halo_send[p, q] (lanes are packed at the
                                  front of each pair row, so the first
                                  halo_cnt[p, q] entries are valid)

``halo_cnt`` is what makes the **ragged** exchanges possible: the padded
halo wire ships H_max = max over all pairs for *every* pair, so one hot
(p, q) cell inflates the whole all_to_all.  The ragged exchange instead
runs k−1 ``ppermute`` hops — hop s moves the (p, (p+s) mod k) lanes for
every p at once — each padded only to that *distance's* max population
H_s = max_p halo_cnt[p, (p+s) mod k] (``halo_schedule``).  Skewed
replication factors (the common case on web graphs) make Σ_s H_s ≪
(k−1)·H_max.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np


@dataclass
class PartitionLayout:
    k: int
    num_vertices: int
    num_edges: int
    e_max: int
    l_max: int
    h_max: int               # per-device-pair halo pad length
    edge_src: np.ndarray     # (k, E_max) int32, local slots; pad = l_max
    edge_dst: np.ndarray     # (k, E_max)
    edge_mask: np.ndarray    # (k, E_max) bool
    vert_gid: np.ndarray     # (k, L_max) int32; pad = num_vertices
    vert_mask: np.ndarray    # (k, L_max) bool
    is_master: np.ndarray    # (k, L_max) bool
    owner: np.ndarray        # (k, L_max) int32 master device; pad = 0
    own_slot: np.ndarray     # (k, L_max) int32 slot in owner's table; pad 0
    red_index: np.ndarray    # (k, k*L_max) int32 → my slot or l_max (drop)
    out_deg: np.ndarray      # (k, L_max) int32 global out-degree
    halo_send: np.ndarray    # (k, k, H_max) int32 mirror slots; pad = l_max
    halo_recv: np.ndarray    # (k, k, H_max) int32 master slots; pad = l_max
    halo_cnt: np.ndarray     # (k, k) int32 real lanes per ordered pair
    frontier: np.ndarray     # (k, L_max) bool: replicated vertex (its
    #                          master aggregate depends on mirror lanes);
    #                          interior = vert_mask & ~frontier
    mirrors_total: int       # Σ_v (|P(v)| − 1)

    # per-device tables every backend needs, and each wire format's own
    COMMON_TABLES = ("edge_src", "edge_dst", "edge_mask", "vert_gid",
                     "vert_mask", "is_master", "out_deg")
    EXCHANGE_TABLES = {"dense": ("owner", "own_slot", "red_index"),
                       "halo": ("halo_send", "halo_recv"),
                       # quantized rides the same routing tables; only the
                       # payload encoding differs (int8 codes + scales)
                       "quantized": ("halo_send", "halo_recv"),
                       # the ragged exchanges slice prefixes of the same
                       # tables per ppermute distance (lanes are packed at
                       # the front of each pair row); the static schedule
                       # itself travels in the exchange instance, not as a
                       # device array.  ``frontier`` is what lets the
                       # overlapped body apply interior vertices while the
                       # ring is still in flight.
                       "ragged": ("halo_send", "halo_recv", "frontier"),
                       "ragged_quantized": ("halo_send", "halo_recv",
                                            "frontier")}

    def device_arrays(self, exchange: str | None = None) -> dict:
        """The pytree of arrays each device needs (leading k axis).
        ``exchange`` restricts the wire-format tables to one backend so the
        other format's tables (red_index is the largest, k²·L_max) never
        ship to devices; None includes both."""
        if exchange is not None and exchange not in self.EXCHANGE_TABLES:
            raise ValueError(
                f"unknown exchange {exchange!r}; expected one of "
                f"{sorted(self.EXCHANGE_TABLES)}")
        keys = self.COMMON_TABLES + (
            tuple(t for ts in self.EXCHANGE_TABLES.values() for t in ts)
            if exchange is None else self.EXCHANGE_TABLES[exchange])
        return {f: getattr(self, f) for f in dict.fromkeys(keys)}

    def interior_frontier_stats(self) -> dict:
        """Interior/frontier split of the local vertex tables — the
        overlap headroom of the partition.  Interior vertices (single
        replica) can be gathered/applied while the ragged ring is still
        in flight; frontier vertices (replication > 1) must wait for
        their mirror lanes.  Returns per-partition interior counts and
        fractions plus the global interior fraction — another lens on
        partition quality next to RF (RF → 1 drives interior_frac → 1)."""
        local = self.vert_mask.sum(axis=1)
        interior = (self.vert_mask & ~self.frontier).sum(axis=1)
        with np.errstate(invalid="ignore"):
            frac = np.where(local > 0, interior / np.maximum(local, 1), 1.0)
        total_local = int(local.sum())
        return {
            "interior_per_part": interior.astype(int).tolist(),
            "local_per_part": local.astype(int).tolist(),
            "interior_frac_per_part": [round(float(f), 6) for f in frac],
            "interior_frac": (float(interior.sum()) / total_local
                              if total_local else 1.0),
            "interior_frac_min": float(frac.min(initial=1.0)),
        }

    # -- communication model (bytes per GAS iteration, per §Fig-8 bench) --
    #
    # ONE public entry point: ``comm_bytes(...)`` routes every wire-format
    # model by keyword.  The historical per-format methods
    # (``comm_bytes_mirror_sync`` … ``comm_bytes_dense``) are
    # ``DeprecationWarning`` shims over it, identity-tested.

    # every name ``comm_bytes`` routes: the five engine wire formats plus
    # the two bounds ("ideal" = 2·mirrors, "allreduce" = dense psum) and
    # the legacy table key "dense_gather" (alias of "dense")
    COMM_MODELS = ("allreduce", "dense", "dense_gather", "halo", "ideal",
                   "quantized", "ragged", "ragged_quantized")

    def comm_bytes(self, exchange: str | None = None, *, programs: int = 1,
                   fused: bool = False, lossy: bool = True,
                   value_bytes: int = 4, top_delta: float = 0.25):
        """Modelled mirror-sync wire bytes per GAS iteration, keyword-
        routed:

        - ``comm_bytes()`` — the full per-exchange table (the Fig. 8
          accounting): ideal / ragged_quantized / quantized / ragged /
          halo / dense_gather / allreduce.
        - ``comm_bytes(exchange)`` — one model.  ``exchange`` is any of
          ``COMM_MODELS``; ``lossy`` is ``halo.lossy_payload(combine,
          dtype)`` — min/int programs ship the exact full-width payload
          on the quantized backends.
        - ``comm_bytes(exchange, programs=N, fused=True)`` — N
          homogeneous programs as one fused step (single collective per
          phase; the int4 fused wire when quantized + lossy).
        """
        if exchange is None:
            if fused or programs != 1:
                raise ValueError(
                    "comm_bytes(programs=..., fused=...) needs an "
                    "explicit exchange=")
            return {"ideal": self._bytes_ideal(value_bytes),
                    "ragged_quantized": self._bytes_ragged_quantized(
                        top_delta),
                    "quantized": self._bytes_halo_quantized(),
                    "ragged": self._bytes_ragged(value_bytes),
                    "halo": self._bytes_halo(value_bytes),
                    "dense_gather": self._bytes_dense_gather(value_bytes),
                    "allreduce": self._bytes_allreduce(value_bytes)}
        if exchange not in self.COMM_MODELS:
            raise ValueError(
                f"unknown exchange {exchange!r}; expected one of "
                f"{self.COMM_MODELS}")
        if fused and exchange == "quantized" and lossy:
            return self._bytes_fused_quantized(programs)
        single = {
            "dense": lambda: self._bytes_dense_gather(value_bytes),
            "dense_gather": lambda: self._bytes_dense_gather(value_bytes),
            "halo": lambda: self._bytes_halo(value_bytes),
            "quantized": lambda: (self._bytes_halo_quantized() if lossy
                                  else self._bytes_halo(value_bytes)),
            "ragged": lambda: self._bytes_ragged(value_bytes),
            "ragged_quantized": lambda: (
                self._bytes_ragged_quantized(top_delta) if lossy
                else self._bytes_ragged(value_bytes)),
            "ideal": lambda: self._bytes_ideal(value_bytes),
            "allreduce": lambda: self._bytes_allreduce(value_bytes),
        }[exchange]()
        return programs * single

    def _bytes_dense_gather(self, value_bytes: int = 4) -> int:
        """Dense backend: all_gather(k, L_max) twice — every device receives
        k·L_max values per phase regardless of mirror count."""
        return 2 * self.k * self.k * self.l_max * value_bytes

    def _bytes_halo(self, value_bytes: int = 4) -> int:
        """Halo backend: all_to_all(k, H_max) twice — each device puts
        (k−1)·H_max values on the wire per phase (the self block never
        leaves the device)."""
        return 2 * self.k * (self.k - 1) * self.h_max * value_bytes

    def halo_schedule(self) -> tuple:
        """Static per-distance lane counts for the ragged ring exchange:
        entry s−1 is H_s = max_p halo_cnt[p, (p+s) mod k] for hop
        distance s = 1..k−1.  Every device sends its (p → (p+s) mod k)
        lanes on hop s, padded only to that distance's max population;
        H_s = 0 hops are skipped at trace time."""
        k = self.k
        ar = np.arange(k)
        return tuple(int(self.halo_cnt[ar, (ar + s) % k].max(initial=0))
                     for s in range(1, k))

    def _bytes_ragged(self, value_bytes: int = 4) -> int:
        """Ragged exact exchange: per phase every device sends Σ_s H_s
        values over k−1 ppermute hops (no self lane, no cross-pair
        padding) — always ≤ the padded halo volume, and equal to the
        ideal 2·mirrors volume when the per-distance maxima are tight."""
        return 2 * self.k * sum(self.halo_schedule()) * value_bytes

    def _bytes_ragged_quantized(self, top_delta: float = 0.25) -> int:
        """Ragged top-Δ exchange: per hop the sender ships only the
        T_s = max(1, ⌈top_delta·H_s⌉) largest-|Δ| lanes as (int16 lane
        index + int8 code) pairs plus one fp32 max-abs scale — the rest
        stays in the error-feedback residual for a later iteration."""
        total = 0
        for h in self.halo_schedule():
            if h == 0:
                continue
            t = min(h, max(1, int(np.ceil(top_delta * h))))
            total += 3 * t + 4          # 2 B index + 1 B code + scale/H_s
        return 2 * self.k * total

    def _bytes_halo_quantized(self, code_bytes: int = 1,
                              scale_bytes: int = 4) -> int:
        """Quantized halo backend (fp32 programs): each of the k·(k−1)
        off-diagonal lane groups ships H_max int8 codes plus one fp32
        max-abs scale per phase — ~4× below the exact halo wire once
        H_max ≫ scale_bytes.  Min/int programs ship the exact halo
        payload instead (see ``repro.dist.halo``)."""
        return 2 * self.k * (self.k - 1) * (
            self.h_max * code_bytes + scale_bytes)

    # the fused quantized wire ships fp16 scales over 8 subgroups per
    # (destination, program) lane row — 16 B/row (halo._NUM_SCALE_GROUPS)
    FUSED_SCALE_BYTES = 16

    def _bytes_fused_quantized(self, n_programs: int) -> int:
        """Fused multi-program quantized wire (``repro.dist.halo``
        ``*_multi`` on the quantized backend): N lossy programs share one
        all_to_all per phase whose codes are int4 nibble-packed two per
        byte, with fp16 scales over 8 subgroups per (destination,
        program) lane row — (H/2 + 16)/(H + 4) ≈ 0.55× the bytes of N
        separate int8 quantized steps.  The encoder pads each row up to
        a multiple of 8 internally (``halo._quantize_groups``), so the
        wire width is ⌈H_max/8⌉·8 nibbles — H_max itself need not
        divide by 8."""
        h8 = -(-self.h_max // 8) * 8
        return 2 * self.k * (self.k - 1) * n_programs * (
            h8 // 2 + self.FUSED_SCALE_BYTES)

    def _bytes_ideal(self, value_bytes: int = 4) -> int:
        """Ragged lower bound: every mirror value moves exactly once per
        phase — 2·mirrors·bytes per iteration."""
        return 2 * self.mirrors_total * value_bytes

    def _bytes_allreduce(self, value_bytes: int = 4) -> int:
        """dense psum baseline: ring all-reduce over (V,) per device."""
        return 2 * (self.k - 1) * self.num_vertices * value_bytes

    # -- deprecated per-format methods (thin shims over comm_bytes) --

    def _deprecated(self, old: str, new: str):
        warnings.warn(
            f"PartitionLayout.{old} is deprecated; use "
            f"PartitionLayout.{new}", DeprecationWarning, stacklevel=3)

    def comm_bytes_mirror_sync(self, value_bytes: int = 4) -> int:
        self._deprecated("comm_bytes_mirror_sync", "comm_bytes('dense')")
        return self.comm_bytes("dense", value_bytes=value_bytes)

    def comm_bytes_halo(self, value_bytes: int = 4) -> int:
        self._deprecated("comm_bytes_halo", "comm_bytes('halo')")
        return self.comm_bytes("halo", value_bytes=value_bytes)

    def comm_bytes_ragged(self, value_bytes: int = 4) -> int:
        self._deprecated("comm_bytes_ragged", "comm_bytes('ragged')")
        return self.comm_bytes("ragged", value_bytes=value_bytes)

    def comm_bytes_ragged_quantized(self, top_delta: float = 0.25,
                                    value_bytes: int = 4) -> int:
        self._deprecated("comm_bytes_ragged_quantized",
                         "comm_bytes('ragged_quantized')")
        return self.comm_bytes("ragged_quantized", top_delta=top_delta,
                               value_bytes=value_bytes)

    def comm_bytes_halo_quantized(self, code_bytes: int = 1,
                                  scale_bytes: int = 4) -> int:
        self._deprecated("comm_bytes_halo_quantized",
                         "comm_bytes('quantized')")
        return self._bytes_halo_quantized(code_bytes, scale_bytes)

    def comm_bytes_fused_quantized(self, n_programs: int) -> int:
        self._deprecated("comm_bytes_fused_quantized",
                         "comm_bytes('quantized', programs=N, fused=True)")
        return self._bytes_fused_quantized(n_programs)

    def comm_bytes_exchange(self, exchange: str, *, lossy: bool = True,
                            value_bytes: int = 4) -> int:
        self._deprecated("comm_bytes_exchange", "comm_bytes(exchange)")
        return self.comm_bytes(exchange, lossy=lossy,
                               value_bytes=value_bytes)

    def comm_bytes_fused(self, n_programs: int, exchange: str, *,
                         lossy: bool = True, value_bytes: int = 4) -> int:
        self._deprecated(
            "comm_bytes_fused",
            "comm_bytes(exchange, programs=N, fused=True)")
        return self.comm_bytes(exchange, programs=n_programs, fused=True,
                               lossy=lossy, value_bytes=value_bytes)

    def comm_bytes_ideal(self, value_bytes: int = 4) -> int:
        self._deprecated("comm_bytes_ideal", "comm_bytes('ideal')")
        return self.comm_bytes("ideal", value_bytes=value_bytes)

    def comm_bytes_dense(self, value_bytes: int = 4) -> int:
        self._deprecated("comm_bytes_dense", "comm_bytes('allreduce')")
        return self.comm_bytes("allreduce", value_bytes=value_bytes)


def _pad_to(n: int, pad_multiple: int) -> int:
    return int(np.ceil(max(n, 1) / pad_multiple) * pad_multiple)


def build_layout(src: np.ndarray, dst: np.ndarray, assign: np.ndarray,
                 num_vertices: int, k: int,
                 pad_multiple: int = 8) -> PartitionLayout:
    """Vectorized layout builder — pure np.unique/searchsorted/bincount
    passes, no per-vertex Python loops (≥5× the reference builder at 10k
    vertices; see ``build_layout_reference`` for the retained oracle).

    Accepts device-resident (jax) arrays directly: the jit/sharded
    partitioner backends hand their edge→partition assignment straight in
    and the single ``np.asarray`` below is the only host transfer — no
    per-edge host loop ever touches the assignment."""
    E = src.shape[0]
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    assign = np.asarray(assign)
    order = np.argsort(assign, kind="stable")
    s, d, a = src[order], dst[order], assign[order].astype(np.int64)
    bounds = np.searchsorted(a, np.arange(k + 1))

    # global out degree
    gdeg = np.bincount(src, minlength=num_vertices)

    # one row per (partition, vertex) replica, with its endpoint count.
    # np.unique on the fused key sorts by (partition, vertex), so rows are
    # grouped by partition with vertices ascending — the same order the
    # reference builder's per-partition np.unique produces.
    key = np.concatenate([a, a]) * num_vertices + np.concatenate([s, d])
    uniq, cnt = np.unique(key, return_counts=True)
    up = uniq // num_vertices        # partition of each replica row
    uv = uniq % num_vertices         # vertex gid of each replica row
    n_rows = uniq.shape[0]

    # master election: per vertex, the partition with max endpoint count,
    # ties → lowest partition id.  lexsort is keyed last-to-first.
    elect = np.lexsort((up, -cnt, uv))
    uv_e, up_e = uv[elect], up[elect]
    first = np.ones(n_rows, dtype=bool)
    np.not_equal(uv_e[1:], uv_e[:-1], out=first[1:])
    master_of = np.full(num_vertices, -1, dtype=np.int64)
    master_of[uv_e[first]] = up_e[first]

    part_sizes = np.bincount(up, minlength=k)
    l_max = _pad_to(int(part_sizes.max(initial=1)), pad_multiple)
    e_max = _pad_to(int(max(bounds[1:] - bounds[:-1], default=1)),
                    pad_multiple)

    # local slot of each replica row = rank within its partition group
    row_start = np.searchsorted(up, np.arange(k + 1))
    slot = np.arange(n_rows) - row_start[up]

    if k * num_vertices <= (1 << 25):
        # dense inverse map: O(1) per lookup, ≤128 MiB of int32
        _lookup = np.empty(k * num_vertices, dtype=np.int32)
        _lookup[uniq] = slot

        def slot_of(parts: np.ndarray, verts: np.ndarray) -> np.ndarray:
            """Vectorized (partition, gid) → local slot."""
            return _lookup[parts * num_vertices + verts]
    else:
        def slot_of(parts: np.ndarray, verts: np.ndarray) -> np.ndarray:
            """Vectorized (partition, gid) → local slot via sorted keys."""
            return slot[np.searchsorted(uniq, parts * num_vertices + verts)]

    replic = np.bincount(uv, minlength=num_vertices)

    vert_gid = np.full((k, l_max), num_vertices, dtype=np.int32)
    vert_mask = np.zeros((k, l_max), dtype=bool)
    is_master = np.zeros((k, l_max), dtype=bool)
    out_deg = np.zeros((k, l_max), dtype=np.int32)
    owner = np.zeros((k, l_max), dtype=np.int32)
    own_slot = np.zeros((k, l_max), dtype=np.int32)
    frontier = np.zeros((k, l_max), dtype=bool)
    row_owner = master_of[uv]
    row_own_slot = slot_of(row_owner, uv)
    row_is_master = row_owner == up
    row_deg = gdeg[uv]
    row_frontier = replic[uv] > 1
    # rows are grouped by partition, so per-partition contiguous slice
    # copies beat a (k, slot) fancy scatter by ~5×
    for p in range(k):
        r0, r1 = int(row_start[p]), int(row_start[p + 1])
        n = r1 - r0
        if n == 0:
            continue
        rows = slice(r0, r1)
        vert_gid[p, :n] = uv[rows]
        vert_mask[p, :n] = True
        is_master[p, :n] = row_is_master[rows]
        out_deg[p, :n] = row_deg[rows]
        owner[p, :n] = row_owner[rows]
        own_slot[p, :n] = row_own_slot[rows]
        frontier[p, :n] = row_frontier[rows]

    # reduce map: flat all_gather entry (j*L_max + slot) → my slot (if I am
    # the owner of that entry's vertex) else l_max (dropped)
    red_index = np.full((k, k * l_max), l_max, dtype=np.int32)
    red_index[row_owner, up * l_max + slot] = row_own_slot

    edge_src = np.full((k, e_max), l_max, dtype=np.int32)
    edge_dst = np.full((k, e_max), l_max, dtype=np.int32)
    edge_mask = np.zeros((k, e_max), dtype=bool)
    if E:
        src_slots = slot_of(a, s)
        dst_slots = slot_of(a, d)
        # edges are sorted by partition: contiguous copies, no scatter
        for p in range(k):
            lo, hi = int(bounds[p]), int(bounds[p + 1])
            n = hi - lo
            if n == 0:
                continue
            edge_src[p, :n] = src_slots[lo:hi]
            edge_dst[p, :n] = dst_slots[lo:hi]
            edge_mask[p, :n] = True

    # halo routing tables: one lane per mirror replica, grouped by the
    # ordered (mirror partition, owner partition) pair and padded to the
    # max pair population H_max — every mirror is routed exactly once.
    mir = row_owner != up
    mp_, mq = up[mir], row_owner[mir]
    m_slot, m_own_slot = slot[mir], row_own_slot[mir]
    pair = mp_ * k + mq
    po = np.argsort(pair, kind="stable")
    pair_s = pair[po]
    lane = np.arange(pair_s.shape[0]) - np.searchsorted(pair_s, pair_s)
    h_max = _pad_to(int(lane.max(initial=-1)) + 1, pad_multiple)
    halo_send = np.full((k, k, h_max), l_max, dtype=np.int32)
    halo_recv = np.full((k, k, h_max), l_max, dtype=np.int32)
    halo_send[mp_[po], mq[po], lane] = m_slot[po]
    halo_recv[mq[po], mp_[po], lane] = m_own_slot[po]
    halo_cnt = np.bincount(pair, minlength=k * k).reshape(k, k) \
        .astype(np.int32)

    mirrors_total = int(np.maximum(replic - 1, 0).sum())

    return PartitionLayout(
        k=k, num_vertices=num_vertices, num_edges=E, e_max=e_max,
        l_max=l_max, h_max=h_max, edge_src=edge_src, edge_dst=edge_dst,
        edge_mask=edge_mask, vert_gid=vert_gid, vert_mask=vert_mask,
        is_master=is_master, owner=owner, own_slot=own_slot,
        red_index=red_index, out_deg=out_deg, halo_send=halo_send,
        halo_recv=halo_recv, halo_cnt=halo_cnt, frontier=frontier,
        mirrors_total=mirrors_total)


def build_layout_reference(src: np.ndarray, dst: np.ndarray,
                           assign: np.ndarray, num_vertices: int, k: int,
                           pad_multiple: int = 8) -> PartitionLayout:
    """The seed O(V·k) dict/loop builder, retained as the equivalence
    oracle for ``build_layout`` (tests compare every table)."""
    E = src.shape[0]
    order = np.argsort(assign, kind="stable")
    s, d, a = src[order], dst[order], assign[order]
    bounds = np.searchsorted(a, np.arange(k + 1))

    # global out degree
    gdeg = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(gdeg, src, 1)

    # per-partition local vertex tables + master election by edge count
    locals_: list[np.ndarray] = []
    per_part_counts: list[dict] = []
    for p in range(k):
        lo, hi = bounds[p], bounds[p + 1]
        verts, cnt = np.unique(np.concatenate([s[lo:hi], d[lo:hi]]),
                               return_counts=True)
        locals_.append(verts)
        per_part_counts.append(dict(zip(verts.tolist(), cnt.tolist())))

    # master = partition with max edge count of v (ties → lowest partition)
    best_cnt = np.zeros(num_vertices, dtype=np.int64)
    master_of = np.full(num_vertices, -1, dtype=np.int64)
    for p in range(k):
        verts = locals_[p]
        cnt = np.array([per_part_counts[p][int(v)] for v in verts],
                       dtype=np.int64)
        better = cnt > best_cnt[verts]
        upd = verts[better]
        best_cnt[upd] = cnt[better]
        master_of[upd] = p

    l_max = max((len(v) for v in locals_), default=1)
    l_max = _pad_to(l_max, pad_multiple)
    e_max = _pad_to(int(max(bounds[1:] - bounds[:-1], default=1)),
                    pad_multiple)

    vert_gid = np.full((k, l_max), num_vertices, dtype=np.int32)
    vert_mask = np.zeros((k, l_max), dtype=bool)
    is_master = np.zeros((k, l_max), dtype=bool)
    out_deg = np.zeros((k, l_max), dtype=np.int32)
    slot_of = {}         # (p, gid) -> slot
    for p in range(k):
        verts = locals_[p]
        n = len(verts)
        vert_gid[p, :n] = verts
        vert_mask[p, :n] = True
        is_master[p, :n] = master_of[verts] == p
        out_deg[p, :n] = gdeg[verts]
        for sl, v in enumerate(verts.tolist()):
            slot_of[(p, v)] = sl

    owner = np.zeros((k, l_max), dtype=np.int32)
    own_slot = np.zeros((k, l_max), dtype=np.int32)
    for p in range(k):
        verts = locals_[p]
        for sl, v in enumerate(verts.tolist()):
            o = int(master_of[v])
            owner[p, sl] = o
            own_slot[p, sl] = slot_of[(o, v)]

    # reduce map: flat all_gather entry (j*L_max + slot) → my slot (if I am
    # the owner of that entry's vertex) else l_max (dropped)
    red_index = np.full((k, k * l_max), l_max, dtype=np.int32)
    for j in range(k):
        verts = locals_[j]
        for sl, v in enumerate(verts.tolist()):
            o = int(master_of[v])
            red_index[o, j * l_max + sl] = slot_of[(o, v)]

    edge_src = np.full((k, e_max), l_max, dtype=np.int32)
    edge_dst = np.full((k, e_max), l_max, dtype=np.int32)
    edge_mask = np.zeros((k, e_max), dtype=bool)
    for p in range(k):
        lo, hi = bounds[p], bounds[p + 1]
        n = hi - lo
        if n == 0:
            continue
        edge_src[p, :n] = [slot_of[(p, int(x))] for x in s[lo:hi]]
        edge_dst[p, :n] = [slot_of[(p, int(x))] for x in d[lo:hi]]
        edge_mask[p, :n] = True

    # halo routing: per ordered (mirror, owner) pair, mirrors in local-slot
    # order — the same grouping the vectorized builder emits.
    pair_lanes: dict = {}
    for p in range(k):
        for sl, v in enumerate(locals_[p].tolist()):
            o = int(master_of[v])
            if o == p:
                continue
            pair_lanes.setdefault((p, o), []).append(
                (sl, slot_of[(o, v)]))
    h_max = max((len(v) for v in pair_lanes.values()), default=0)
    h_max = _pad_to(h_max, pad_multiple)
    halo_send = np.full((k, k, h_max), l_max, dtype=np.int32)
    halo_recv = np.full((k, k, h_max), l_max, dtype=np.int32)
    halo_cnt = np.zeros((k, k), dtype=np.int32)
    for (p, o), lanes in pair_lanes.items():
        halo_cnt[p, o] = len(lanes)
        for h, (sl, osl) in enumerate(lanes):
            halo_send[p, o, h] = sl
            halo_recv[o, p, h] = osl

    replic = np.zeros(num_vertices, dtype=np.int64)
    for p in range(k):
        replic[locals_[p]] += 1
    mirrors_total = int(np.maximum(replic - 1, 0).sum())

    frontier = np.zeros((k, l_max), dtype=bool)
    for p in range(k):
        verts = locals_[p]
        frontier[p, :len(verts)] = replic[verts] > 1

    return PartitionLayout(
        k=k, num_vertices=num_vertices, num_edges=E, e_max=e_max,
        l_max=l_max, h_max=h_max, edge_src=edge_src, edge_dst=edge_dst,
        edge_mask=edge_mask, vert_gid=vert_gid, vert_mask=vert_mask,
        is_master=is_master, owner=owner, own_slot=own_slot,
        red_index=red_index, out_deg=out_deg, halo_send=halo_send,
        halo_recv=halo_recv, halo_cnt=halo_cnt, frontier=frontier,
        mirrors_total=mirrors_total)
