"""Quickstart: partition a synthetic web crawl with CLUGP (paper-faithful
and optimized profiles), compare against HDRF/hashing, and run distributed
PageRank on the result.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (CLUGPConfig, baselines, clugp_partition, metrics,
                        random_stream, web_graph)
from repro.graph import build_layout, reference_pagerank, simulate_pagerank

K = 16

g = web_graph(scale=12, edge_factor=8, seed=0)
print(f"web graph: |V|={g.num_vertices} |E|={g.num_edges}")

for name, cfg in [("CLUGP (paper)", CLUGPConfig.paper(K)),
                  ("CLUGP (optimized)", CLUGPConfig.optimized(K))]:
    res = clugp_partition(g.src, g.dst, g.num_vertices, cfg)
    print(f"{name:20s} RF={res.stats['rf']:.3f} "
          f"balance={res.stats['balance']:.3f} "
          f"clusters={res.stats['num_clusters']} "
          f"game_rounds={res.stats['game_rounds']}")

gr = random_stream(g, seed=1)
for name in ("hdrf", "hashing"):
    a = baselines.ALL_BASELINES[name](gr.src, gr.dst, g.num_vertices, K)
    rf = metrics.replication_factor(gr.src, gr.dst, a, g.num_vertices, K)
    print(f"{name:20s} RF={rf:.3f} "
          f"balance={metrics.load_balance(a, K):.3f}")

# distributed PageRank on the optimized partition (simulated k-device GAS)
res = clugp_partition(g.src, g.dst, g.num_vertices, CLUGPConfig.optimized(K))
lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, K)
pr = simulate_pagerank(lay, iters=30)
ref = reference_pagerank(g.src, g.dst, g.num_vertices, iters=30)
print(f"pagerank max|err| vs single-machine oracle: "
      f"{np.abs(pr - ref).max():.2e}")
print(f"mirror-sync comm/iter: {lay.comm_bytes_ideal()/1e6:.2f} MB "
      f"(dense baseline {lay.comm_bytes_dense()/1e6:.2f} MB)")
