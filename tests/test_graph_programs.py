"""GAS program library (repro.graph.engine): every program in the
registry matches its NumPy oracle under all three exchange backends,
fused multi-program execution matches the per-program runs, iters=0
returns init values untouched (the dry-run byte parser depends on it),
and the fused comm model / CI ordering gate behave."""
import numpy as np
import pytest

from repro.graph import (CC_SENTINEL, FusedGAS, PROGRAM_NAMES, build_layout,
                         default_num_seeds, fuse_programs, get_program,
                         reference_bfs, reference_cc, reference_centrality,
                         reference_degree, reference_labelprop,
                         reference_pagerank, reference_ppr, reference_sssp,
                         simulate_gas, simulate_gas_many)

from conftest import random_graph_and_assign

# repro.launch.dryrun mutates XLA_FLAGS (512 virtual devices) at import,
# so it must only be imported inside tests, after jax has initialized —
# a module-level import at collection time would change the whole tier-1
# process's device count (test_graph_quantized.py does the same)

EXCHANGES = ("dense", "halo", "quantized")

# per-program iteration budget (int programs need the frontier to close)
# and oracle thunk; float programs are judged within the quantized
# error-feedback tolerance, int programs must be bit-exact everywhere
ITERS = {"pagerank": 30, "cc": 40, "labelprop": 40, "sssp": 40, "bfs": 40,
         "degree": 2, "centrality": 30, "ppr": 30}


@pytest.fixture(scope="module")
def case():
    src, dst, n, assign = random_graph_and_assign(0, 8, n=400)
    lay = build_layout(src, dst, assign, n, 8)
    refs = {
        "pagerank": reference_pagerank(src, dst, n, iters=30),
        "cc": reference_cc(src, dst, n),
        "labelprop": reference_labelprop(src, dst, n, iters=40),
        "sssp": reference_sssp(src, dst, n, iters=40),
        "bfs": reference_bfs(src, dst, n, iters=40),
        "degree": reference_degree(src, dst, n),
        "centrality": reference_centrality(src, dst, n, iters=30),
        "ppr": reference_ppr(src, dst, n, iters=30),
    }
    return src, dst, n, lay, refs


# ------------------------------------------------- program × exchange matrix

@pytest.mark.parametrize("exchange", EXCHANGES)
@pytest.mark.parametrize("name", PROGRAM_NAMES)
def test_program_matches_oracle(case, name, exchange):
    _, _, n, lay, refs = case
    got = simulate_gas(get_program(name, n), lay, iters=ITERS[name],
                       exchange=exchange)
    ref = refs[name]
    if np.issubdtype(got.dtype, np.floating):
        assert np.abs(got - ref).max() < 1e-5
    else:
        # min/int payloads ship exactly on every backend — incl. quantized,
        # whose EF path is bypassed for non-lossy payloads
        np.testing.assert_array_equal(got.astype(np.int64), ref)


def test_registry_rejects_unknown_program():
    with pytest.raises(ValueError, match="unknown program"):
        get_program("triangle-count", 10)


def test_sssp_unreachable_vertices_keep_sentinel(case):
    src, dst, n, lay, refs = case
    # seeds are gid < num_seeds for labelprop; SSSP has one source — any
    # vertex the oracle leaves at the sentinel must stay there on-device
    got = simulate_gas(get_program("sssp", n), lay, iters=40,
                       exchange="halo")
    unreachable = refs["sssp"] == CC_SENTINEL
    assert (got[unreachable] == CC_SENTINEL).all()
    assert got[0] == 0      # the source itself


@pytest.mark.parametrize("backend", ["np", "jit"])
def test_programs_match_oracle_on_partitioner_layouts(backend):
    """The oracle match holds on real CLUGP partitions from the host and
    device partitioner backends, not just random assignments (the
    sharded backend's layout is exercised in the multidevice suite —
    device count locks at first jax init)."""
    from repro.core import CLUGPConfig, partition, web_graph
    g = web_graph(scale=9, edge_factor=6, seed=1)
    res = partition(g.src, g.dst, g.num_vertices, CLUGPConfig(k=4),
                    backend=backend)
    lay = build_layout(g.src, g.dst, res.assign, g.num_vertices, 4)
    refs = {
        "labelprop": reference_labelprop(g.src, g.dst, g.num_vertices,
                                         iters=40),
        "sssp": reference_sssp(g.src, g.dst, g.num_vertices, iters=40),
        "ppr": reference_ppr(g.src, g.dst, g.num_vertices, iters=30),
    }
    for name, ref in refs.items():
        prog = get_program(name, g.num_vertices)
        for exchange in EXCHANGES:
            got = simulate_gas(prog, lay, iters=ITERS[name],
                               exchange=exchange)
            if np.issubdtype(got.dtype, np.floating):
                assert np.abs(got - ref).max() < 1e-5, (name, exchange)
            else:
                np.testing.assert_array_equal(
                    got.astype(np.int64), ref,
                    err_msg=f"{backend}/{name}/{exchange}")


# ------------------------------------------------------------ fused driver

@pytest.mark.parametrize("exchange", EXCHANGES)
def test_fused_f32_bundle_matches_references(case, exchange):
    _, _, n, lay, refs = case
    names = ("pagerank", "ppr", "centrality")
    outs = simulate_gas_many([get_program(p, n) for p in names], lay,
                             iters=30, exchange=exchange)
    # the fused quantized wire is int4 (vs int8 separate) so its EF
    # tolerance is wider; dense/halo fused math is the separate math
    tol = 5e-4 if exchange == "quantized" else 1e-5
    for name, got in zip(names, outs):
        assert np.abs(got - refs[name]).max() < tol, name


@pytest.mark.parametrize("exchange", EXCHANGES)
def test_fused_i32_bundle_bit_exact(case, exchange):
    _, _, n, lay, refs = case
    names = ("sssp", "bfs", "labelprop")
    progs = [get_program(p, n) for p in names]
    outs = simulate_gas_many(progs, lay, iters=40, exchange=exchange)
    for name, prog, got in zip(names, progs, outs):
        np.testing.assert_array_equal(got.astype(np.int64), refs[name],
                                      err_msg=f"{name}/{exchange}")
        # fused ≡ single-program run, bit for bit (same exchange)
        np.testing.assert_array_equal(
            got, simulate_gas(prog, lay, iters=40, exchange=exchange),
            err_msg=f"{name}/{exchange} fused vs single")


def test_fused_rejects_heterogeneous_and_empty(case):
    _, _, n, _, _ = case
    with pytest.raises(ValueError, match="combine|dtype"):
        FusedGAS((get_program("pagerank", n), get_program("cc", n)))
    with pytest.raises(ValueError, match="at least one"):
        fuse_programs([])
    # fuse_programs normalizes to a FusedGAS with stable identity fields
    fused = fuse_programs([get_program("sssp", n), get_program("bfs", n)])
    assert fused.combine == "min" and fused.name == "sssp+bfs"


# -------------------------------------------------------- iters=0 regression

@pytest.mark.parametrize("exchange", EXCHANGES)
def test_iters_zero_returns_init(case, exchange):
    """Regression: a trip-count-0 fori_loop still bakes its collectives
    into the HLO, so iters=0 must skip the loop entirely and return the
    program's init values unchanged."""
    _, _, n, lay, _ = case
    pr0 = simulate_gas(get_program("pagerank", n), lay, iters=0,
                       exchange=exchange)
    np.testing.assert_array_equal(
        pr0, np.full(n, np.float32(1.0 / n), np.float32))
    d0, b0 = simulate_gas_many(
        [get_program("sssp", n), get_program("bfs", n)], lay, iters=0,
        exchange=exchange)
    for got in (d0, b0):
        assert got[0] == 0
        assert (got[1:] == CC_SENTINEL).all()


# ------------------------------------------------------- fused comm model

def test_fused_comm_model_beats_separate(case):
    from repro.launch.dryrun import FUSED_GATE_RATIO
    _, _, _, lay, _ = case
    for nprog in (2, 3, 4):
        fused = lay.comm_bytes("quantized", programs=nprog, fused=True)
        sep = lay.comm_bytes("quantized", programs=nprog, lossy=True)
        assert fused == lay._bytes_fused_quantized(nprog)
        # int4 halves the lane payload; the fp16 subgroup scales cost 16
        # bytes/row vs the separate int8 row's 4 — a net win once
        # h_max > 24, which every padded layout satisfies
        assert fused < sep
    # at the CI gate scale (h_max == 200) the modelled ratio clears the
    # 0.6 gate with margin: (200//2 + 16) / (200 + 4) ≈ 0.569
    h = 200
    assert (h // 2 + lay.FUSED_SCALE_BYTES) < FUSED_GATE_RATIO * (h + 4)


def test_check_graph_ordering_fused_gate():
    from repro.launch.dryrun import check_graph_ordering

    def cell(prog, ex, wire, **kw):
        return {"program": prog, "exchange": ex, "status": "ok",
                "collective_bytes_wire": wire, **kw}

    sep = [cell("pagerank", "dense", 1000), cell("pagerank", "halo", 100),
           cell("pagerank", "quantized", 30, lossy_payload=True),
           cell("ppr", "dense", 1000), cell("ppr", "halo", 100),
           cell("ppr", "quantized", 30, lossy_payload=True)]
    good = cell("pagerank+ppr", "quantized", 30, fused=True,
                fused_programs=["pagerank", "ppr"])
    assert check_graph_ordering(sep + [good]) == []
    # fused step shipping ≥ 0.6 × Σ separate fails the gate
    bad = dict(good, collective_bytes_wire=40)
    msgs = check_graph_ordering(sep + [bad])
    assert len(msgs) == 1 and "fused" in msgs[0]
    # a fused row whose bundle lacks separate quantized cells is itself
    # a violation (the gate can't silently vacuously pass)
    orphan = dict(good, fused_programs=["pagerank", "centrality"])
    msgs = check_graph_ordering(sep + [orphan])
    assert len(msgs) == 1 and "centrality" in msgs[0]


# --------------------------------------------------------------- seeds

def test_default_num_seeds_floor():
    assert default_num_seeds(10) == 2
    assert default_num_seeds(1024) == 4
