"""GraphSession — one object from edge stream to distributed analytics.

The repo's workload is a three-hop chain: CLUGP partition the stream
(`repro.core.partition`), build the vertex-cut device tables
(`repro.graph.build_layout`), run GAS programs over a mesh with a chosen
mirror wire format (`repro.graph.engine` × `repro.dist.halo`).  Before
this module every launcher/benchmark/example hand-wired the chain; the
session makes it one fluent object with a **serializable config**, so a
run is reproducible from a JSON blob:

    from repro.session import GraphSession, SessionConfig
    from repro.core import CLUGPConfig

    sess = GraphSession(SessionConfig(clugp=CLUGPConfig.optimized(8),
                                      backend="jit", exchange="quantized"))
    sess = GraphSession.from_json(sess.to_json())     # round-trips
    pr = sess.partition(src, dst, V).layout().run("pagerank")
    cc = sess.run("cc", mesh=make_graph_mesh(8))      # same layout, any mesh
    sess.comm_bytes()        # modelled wire bytes/iter per exchange

``partition`` accepts any backend (`np`/`jit`/`sharded`, `nodes` for the
§III-C stream split); ``with_partition`` adopts an external edge→partition
assignment (baselines) so the layout/engine/accounting half of the session
works on it; ``run`` takes a program name (any of ``PROGRAMS`` — the
``repro.graph.engine`` library: pagerank/cc/labelprop/sssp/bfs/degree/
centrality/ppr) or any ``GASProgram`` and simulates on one device
(``mesh=None``) or shard_maps one partition per device; ``run_many``
executes N homogeneous programs as one fused loop with a single mirror
exchange per phase; ``dryrun_step`` hands the compile-only cell (single
or fused) to ``launch.dryrun --graph``; ``comm_bytes(programs=...,
exchange=..., fused=...)`` is the one keyword-routed comm accounting
entry point (per-exchange table, per-program rows, fused bundles).
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from dataclasses import dataclass

import numpy as np

from .core import metrics
from .core.partitioner import BACKENDS, partition, partition_sweep
from .core.pipeline import CLUGPConfig, CLUGPResult
from .dist.halo import EXCHANGE_NAMES, lossy_payload
from .graph import (GASProgram, PROGRAM_NAMES, PartitionLayout,
                    build_layout, fuse_programs, gas_step_for_dryrun,
                    get_program, shard_map_gas, shard_map_gas_many,
                    simulate_gas, simulate_gas_many)

# the session validates/enumerates wire formats through the ONE registry
EXCHANGES = EXCHANGE_NAMES
PROGRAMS = PROGRAM_NAMES


def resolve_program(program, num_vertices: int) -> GASProgram:
    """Name → library GASProgram (a GASProgram passes through)."""
    if isinstance(program, GASProgram):
        return program
    if program in PROGRAMS:
        return get_program(program, num_vertices)
    raise ValueError(f"unknown program {program!r}; expected a GASProgram "
                     f"or one of {PROGRAMS}")


@dataclass(frozen=True)
class SessionConfig:
    """Everything a reproducible partition→layout→GAS run needs.  Frozen
    and JSON-round-trippable (``to_json``/``from_json``): two sessions
    built from the same blob produce identical partitions and compile
    identical GAS cells (tested)."""
    clugp: CLUGPConfig
    backend: str = "np"        # partitioner strategy: np | jit | sharded
    nodes: int = 1             # §III-C stream-split width
    exchange: str = "halo"     # default mirror wire format for run()
    iters: int = 30            # default GAS iterations
    pad_multiple: int = 8      # layout table padding

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; expected "
                             f"one of {BACKENDS}")
        if self.exchange not in EXCHANGES:
            raise ValueError(f"unknown exchange {self.exchange!r}; "
                             f"expected one of {EXCHANGES}")
        if self.nodes < 1:
            raise ValueError(f"nodes must be >= 1, got {self.nodes}")
        if not isinstance(self.clugp, CLUGPConfig):
            raise TypeError("SessionConfig.clugp must be a CLUGPConfig")

    def to_json(self) -> str:
        # asdict recurses into the nested CLUGPConfig
        return json.dumps(dataclasses.asdict(self), indent=1)

    @classmethod
    def from_json(cls, text: str) -> "SessionConfig":
        d = json.loads(text)
        clugp = CLUGPConfig(**d.pop("clugp"))
        return cls(clugp=clugp, **d)


class GraphSession:
    """Fluent façade: ``GraphSession(cfg).partition(...).layout().run(...)``.

    ``partition``/``with_partition``/``layout`` return ``self`` for
    chaining; ``run`` returns the program's dense (V,) master values.
    The layout is built lazily by ``run``/``comm_bytes`` if ``layout()``
    was not called explicitly."""

    def __init__(self, cfg: SessionConfig | CLUGPConfig, **overrides):
        if isinstance(cfg, CLUGPConfig):
            cfg = SessionConfig(clugp=cfg, **overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        if not isinstance(cfg, SessionConfig):
            raise TypeError("GraphSession takes a SessionConfig or a "
                            "CLUGPConfig (+ SessionConfig overrides)")
        self.cfg = cfg
        self.result: CLUGPResult | None = None
        self._layout: PartitionLayout | None = None
        self._src = self._dst = None
        self._num_vertices: int | None = None

    # ----------------------------------------------------------- config

    @property
    def k(self) -> int:
        return self.cfg.clugp.k

    def to_json(self) -> str:
        return self.cfg.to_json()

    @classmethod
    def from_json(cls, text: str) -> "GraphSession":
        return cls(SessionConfig.from_json(text))

    # -------------------------------------------------------- partition

    def partition(self, src, dst, num_vertices: int, *,
                  mesh=None) -> "GraphSession":
        """Run the configured CLUGP backend on the edge stream."""
        self._adopt_graph(src, dst, num_vertices)
        self.result = partition(self._src, self._dst, self._num_vertices,
                                self.cfg.clugp, backend=self.cfg.backend,
                                nodes=self.cfg.nodes, mesh=mesh)
        return self

    def run_sweep(self, src, dst, num_vertices: int, ks) -> dict:
        """Partition the stream at every ``k`` in ``ks`` under ONE
        compiled stacked body (``repro.core.partition_sweep`` — jit
        semantics, k_max-padded lanes, traced per-step k).  Returns
        ``{k: CLUGPResult}`` in input order and leaves the session on the
        LAST k's partition, ready for ``layout()``/``run()``; re-run
        ``partition`` or adopt another sweep entry via ``with_partition``
        to work on a different k."""
        self._adopt_graph(src, dst, num_vertices)
        results = partition_sweep(self._src, self._dst,
                                  self._num_vertices, self.cfg.clugp, ks)
        table = dict(zip((int(k) for k in ks), results))
        last_k = int(tuple(ks)[-1])
        self.cfg = dataclasses.replace(
            self.cfg, clugp=dataclasses.replace(self.cfg.clugp, k=last_k))
        self.result = table[last_k]
        return table

    def with_partition(self, src, dst, num_vertices: int,
                       assign) -> "GraphSession":
        """Adopt an externally computed edge→partition assignment (e.g. a
        baseline partitioner) so layout/run/comm accounting work on it."""
        self._adopt_graph(src, dst, num_vertices)
        assign = np.asarray(assign)
        if assign.shape[0] != self._src.shape[0]:
            raise ValueError(
                f"assignment covers {assign.shape[0]} edges but the "
                f"stream has {self._src.shape[0]}")
        res = CLUGPResult(assign, None, None, None, 0)
        res.stats = metrics.summarize(self._src, self._dst, assign,
                                      self._num_vertices, self.k)
        res.stats["backend"] = "external"
        self.result = res
        return self

    def _adopt_graph(self, src, dst, num_vertices: int) -> None:
        self._src = np.asarray(src)
        self._dst = np.asarray(dst)
        self._num_vertices = int(num_vertices)
        self._layout = None
        self.result = None

    def _require_partition(self) -> None:
        if self.result is None:
            raise RuntimeError(
                "GraphSession: no partition yet — call partition(src, dst, "
                "V) or with_partition(...) first")

    @property
    def assign(self) -> np.ndarray:
        self._require_partition()
        return self.result.assign

    @property
    def stats(self) -> dict:
        self._require_partition()
        return self.result.stats

    @property
    def num_vertices(self) -> int:
        if self._num_vertices is None:
            raise RuntimeError("GraphSession: no graph yet — call "
                               "partition(...) or with_partition(...)")
        return self._num_vertices

    @property
    def edges(self) -> tuple:
        """(src, dst) of the adopted edge stream."""
        if self._src is None:
            raise RuntimeError("GraphSession: no graph yet — call "
                               "partition(...) or with_partition(...)")
        return self._src, self._dst

    # --------------------------------------------------------- snapshots

    def snapshot(self) -> dict:
        """Host-side array tree of the session's graph + partition — what
        ``dist.ft.ServiceFT`` checkpoints for a serving process.  Pair it
        with ``to_json()`` (the config half) and ``num_vertices``;
        ``from_snapshot`` rebuilds an equivalent session."""
        self._require_partition()
        return {"src": np.asarray(self._src).copy(),
                "dst": np.asarray(self._dst).copy(),
                "assign": np.asarray(self.result.assign).copy()}

    @classmethod
    def from_snapshot(cls, config_json: str, tree: dict,
                      num_vertices: int) -> "GraphSession":
        """Rebuild a session from ``to_json()`` + ``snapshot()`` output:
        same config blob, same edges, same edge→partition assignment (no
        re-partitioning — the snapshot IS the partition)."""
        sess = cls.from_json(config_json)
        return sess.with_partition(tree["src"], tree["dst"], num_vertices,
                                   tree["assign"])

    # ----------------------------------------------------------- layout

    def layout(self, pad_multiple: int | None = None) -> "GraphSession":
        """Build the vertex-cut device tables for the current partition."""
        self._require_partition()
        self._layout = build_layout(
            self._src, self._dst, self.result.assign, self._num_vertices,
            self.k, pad_multiple or self.cfg.pad_multiple)
        return self

    @property
    def partition_layout(self) -> PartitionLayout:
        if self._layout is None:
            self.layout()
        return self._layout

    def comm_bytes(self, programs=None, exchange: str | None = None,
                   fused: bool = False):
        """Modelled mirror-sync wire bytes per GAS iteration — the one
        keyword-routed comm accounting entry point:

        - ``comm_bytes()`` — the per-exchange table dict (the Fig. 8
          accounting: every wire format plus the ragged ideal and the
          dense psum baseline).
        - ``comm_bytes(exchange="halo")`` — one model's bytes (int).
        - ``comm_bytes(programs=[...])`` — per-program rows
          ``{program: {exchange: bytes}}`` with per-program lossy-ness
          (int/min programs ship exact on the quantized wires — the
          rows the dry-run gate asserts); narrow to ``{program: bytes}``
          with ``exchange=``.
        - ``comm_bytes(programs=[...], fused=True)`` — one fused step's
          bytes (single collective per phase; int4 fused wire when
          lossy).  ``exchange`` defaults to the session exchange.
        """
        lay = self.partition_layout
        if programs is None:
            if fused:
                raise ValueError(
                    "comm_bytes(fused=True) needs programs=[...]")
            return lay.comm_bytes(exchange)
        if fused:
            bundle = fuse_programs(
                [resolve_program(p, self._num_vertices) for p in programs])
            lossy = lossy_payload(bundle.combine, bundle.dtype)
            return lay.comm_bytes(exchange or self.cfg.exchange,
                                  programs=len(bundle.programs),
                                  fused=True, lossy=lossy)
        table = {}
        for p in programs:
            prog = resolve_program(p, self._num_vertices)
            lossy = lossy_payload(prog.combine, prog.dtype)
            if exchange is None:
                table[prog.name] = {ex: lay.comm_bytes(ex, lossy=lossy)
                                    for ex in EXCHANGE_NAMES}
            else:
                table[prog.name] = lay.comm_bytes(exchange, lossy=lossy)
        return table

    def comm_bytes_programs(self, programs=PROGRAMS) -> dict:
        """Deprecated — use ``comm_bytes(programs=[...])``."""
        warnings.warn(
            "GraphSession.comm_bytes_programs is deprecated; use "
            "GraphSession.comm_bytes(programs=[...])",
            DeprecationWarning, stacklevel=2)
        return self.comm_bytes(programs=programs)

    def comm_bytes_fused(self, programs, exchange: str | None = None) -> int:
        """Deprecated — use ``comm_bytes(programs=[...], fused=True)``."""
        warnings.warn(
            "GraphSession.comm_bytes_fused is deprecated; use "
            "GraphSession.comm_bytes(programs=[...], fused=True)",
            DeprecationWarning, stacklevel=2)
        return self.comm_bytes(programs=programs, exchange=exchange,
                               fused=True)

    # ------------------------------------------------------------- GAS

    def run(self, program="pagerank", *, iters: int | None = None,
            exchange: str | None = None, mesh=None, axis: str = "parts",
            tol: float | None = None, overlap: bool = False,
            init_values=None, return_iters: bool = False):
        """Run a GAS program on the session's layout and return the dense
        (V,) master values.  ``mesh=None`` simulates the stacked k-device
        engine on one device; with a mesh (axis size == k) the program
        shard_maps one partition per device — bit-identical results by
        construction (shared ``_gas_body``).

        ``tol`` turns ``iters`` into a cap: the loop exits once the
        master residual max-norm drops to ``tol`` (``return_iters=True``
        additionally returns the executed count).  ``overlap`` runs the
        interleaved interior/frontier body (ragged exchanges only);
        ``init_values`` warm-starts from a dense (V_old,) vector."""
        lay = self.partition_layout
        prog = resolve_program(program, self._num_vertices)
        iters = self.cfg.iters if iters is None else iters
        exchange = exchange or self.cfg.exchange
        kw = dict(tol=tol, overlap=overlap, init_values=init_values,
                  return_iters=return_iters)
        if mesh is None:
            out = simulate_gas(prog, lay, iters=iters, exchange=exchange,
                               **kw)
        else:
            out = shard_map_gas(prog, lay, mesh, iters=iters, axis=axis,
                                exchange=exchange, **kw)
        out, iters_run = out if return_iters else (out, iters)
        if np.issubdtype(out.dtype, np.integer):
            out = out.astype(np.int64)     # label/distance programs
        return (out, iters_run) if return_iters else out

    def run_many(self, programs, *, iters: int | None = None,
                 exchange: str | None = None, mesh=None,
                 axis: str = "parts", tol: float | None = None,
                 overlap: bool = False, init_values=None,
                 return_iters: bool = False):
        """Run N homogeneous programs as one fused GAS loop — a single
        mirror-sync collective per phase carries every program's lanes
        (``repro.graph.engine.FusedGAS``).  Returns one dense (V,) array
        per program, in input order.  ``tol`` / ``overlap`` /
        ``init_values`` (one dense vector or None per program) /
        ``return_iters`` as in ``run``."""
        lay = self.partition_layout
        progs = [resolve_program(p, self._num_vertices) for p in programs]
        iters = self.cfg.iters if iters is None else iters
        exchange = exchange or self.cfg.exchange
        kw = dict(tol=tol, overlap=overlap, init_values=init_values,
                  return_iters=return_iters)
        if mesh is None:
            outs = simulate_gas_many(progs, lay, iters=iters,
                                     exchange=exchange, **kw)
        else:
            outs = shard_map_gas_many(progs, lay, mesh, iters=iters,
                                      axis=axis, exchange=exchange, **kw)
        outs, iters_run = outs if return_iters else (outs, iters)
        outs = [o.astype(np.int64)
                if np.issubdtype(o.dtype, np.integer) else o
                for o in outs]
        return (outs, iters_run) if return_iters else outs

    def dryrun_step(self, program="pagerank", *, mesh, iters: int = 1,
                    exchange: str | None = None, axis: str = "parts",
                    overlap: bool = False):
        """(jitted_fn, example_args) for one shard_map GAS step — what
        ``launch.dryrun --graph`` lowers to parse collective bytes.
        ``program`` may be a name/GASProgram or a sequence of them; a
        sequence compiles the fused multi-program step.  ``overlap``
        compiles the interleaved ragged body."""
        lay = self.partition_layout
        if isinstance(program, (list, tuple)):
            prog = [resolve_program(p, self._num_vertices)
                    for p in program]
        else:
            prog = resolve_program(program, self._num_vertices)
        return gas_step_for_dryrun(prog, lay, mesh, axis=axis, iters=iters,
                                   exchange=exchange or self.cfg.exchange,
                                   overlap=overlap)
