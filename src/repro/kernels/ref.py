"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30
BIG = 3.0e38


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sm_scale: float | None = None):
    """q: (B,Hq,Sq,D); k/v: (B,Hkv,Skv,D)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(D)
    group = Hq // Hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vx.astype(jnp.float32)).astype(q.dtype)


def game_bestresponse_ref(aff, sizes, row_tot, cur, loads, *, lam: float,
                          k: int | None = None):
    M, kpad = aff.shape
    if k is None:
        k = kpad
    pids = jnp.arange(kpad)[None, :]
    own = (pids == cur[:, None]).astype(jnp.float32)
    loads_ex = loads[None, :].astype(jnp.float32) - sizes[:, None] * own
    cost = (lam / k) * sizes[:, None].astype(jnp.float32) \
        * (loads_ex + sizes[:, None]) \
        + 0.5 * (row_tot[:, None].astype(jnp.float32) - aff)
    cost = jnp.where(pids < k, cost, BIG)
    return jnp.argmin(cost, 1).astype(jnp.int32), jnp.min(cost, 1)


def ell_spmv_ref(vals, cols, x):
    return (vals.astype(jnp.float32)
            * x.astype(jnp.float32)[cols]).sum(axis=1)
