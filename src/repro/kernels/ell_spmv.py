"""ELL SpMV — Pallas TPU kernel for the PageRank local gather.

TPU adaptation of the paper's PowerGraph scatter/gather hot loop: CSR rows
have data-dependent lengths (hostile to the VPU), so the engine's local
aggregation is laid out as padded ELL — (rows, width) value/column tables,
width = max in-degree of the row block, columns padded to a zero slot.
y[r] = Σ_j vals[r, j] · x[cols[r, j]].

The dense x vector lives whole in VMEM (one block): the engine's per-device
vertex tables are ≤ ~hundreds of KB, far under the ~16 MB VMEM budget —
this is the structural win over GPU gather/scatter (no cache misses, one
DMA).  Grid over row blocks.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(vals_ref, cols_ref, x_ref, y_ref):
    vals = vals_ref[...].astype(jnp.float32)       # (bm, W)
    cols = cols_ref[...]                           # (bm, W) int32
    x = x_ref[...].astype(jnp.float32)             # (N,)
    gathered = x[cols]                             # vectorized VMEM gather
    y_ref[...] = (vals * gathered).sum(axis=1)


def ell_spmv(vals, cols, x, *, block_m: int = 256, interpret: bool = True):
    """vals/cols: (R, W); x: (N,) (cols padded with an index whose x is 0).
    Returns y: (R,) float32."""
    R, W = vals.shape
    N = x.shape[0]
    assert R % block_m == 0, (R, block_m)
    grid = (R // block_m,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, W), lambda i: (i, 0)),
            pl.BlockSpec((block_m, W), lambda i: (i, 0)),
            pl.BlockSpec((N,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.float32),
        interpret=interpret,
    )(vals, cols, x)
