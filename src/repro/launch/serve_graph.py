"""Graph-serving launcher: drive a resident GraphServer end to end.

``python -m repro.launch.serve_graph --scale 13 --k 8 --smoke`` builds a
web graph, partitions it, and stands up ``repro.serve.GraphServer``
in-process (no sockets — the driver IS the event loop), then:

1. **queries** — submits a batched mix of score/label/owner/neighbors
   requests, serves them microbatch by microbatch, and (``--smoke``)
   asserts every score reply bit-matches a direct
   ``GraphSession.run``/``run_many`` on the same layout;
2. **ingestion** — streams random edge arrivals through the window
   buffer, recording the RF trace as windows flush and the drift
   watermark triggers prioritized restreams (``--smoke`` asserts at
   least one restream fired and left RF ≤ the drifted RF);
3. **preemption** — (``--smoke`` + ``--ckpt-dir``) spawns a child copy
   of itself (``--child-snapshot``) that builds the same deterministic
   server, checkpoints through ``dist.ft.ServiceFT``, and SIGKILLs its
   own process mid-serving; the parent resumes from the snapshot and
   asserts the identical config blob, assignment, and query replies.

Writes ``results/BENCH_serve.json`` (query latency, RF trace summary)
for ``benchmarks/trend.py`` to diff across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import CLUGPConfig, web_graph
from repro.dist.ft import ServiceFT
from repro.serve import GraphServer
from repro.session import GraphSession, SessionConfig

SCORE_PROGRAMS = ("pagerank", "degree", "cc", "labelprop")


def build_server(args, ft=None) -> GraphServer:
    """Deterministic graph → session → server from the CLI args — the
    parent, the ``--child-snapshot`` child, and the resumed server all
    reconstruct bit-identical state from the same flags."""
    g = web_graph(scale=args.scale, seed=args.seed)
    cfg = SessionConfig(clugp=CLUGPConfig(k=args.k), backend=args.backend,
                        exchange=args.exchange, iters=args.iters)
    sess = GraphSession(cfg).partition(g.src, g.dst, g.num_vertices)
    sess.layout()
    return GraphServer(sess, max_batch=args.max_batch, window=args.window,
                       rf_watermark=args.watermark,
                       restream_passes=args.restream_passes, ft=ft)


def drive_queries(srv: GraphServer, args, check: bool) -> dict:
    """Submit a batched query mix, serve it, optionally verify replies
    against the session run directly on the same layout."""
    rng = np.random.default_rng(args.seed + 1)
    n = srv.sess.num_vertices
    tickets = []
    for i in range(args.queries):
        prog = SCORE_PROGRAMS[i % len(SCORE_PROGRAMS)]
        verts = rng.integers(0, n, 4)
        tickets.append((srv.submit("score", program=prog, vertices=verts),
                        "score", prog, verts))
    for v in rng.integers(0, n, 4):
        tickets.append((srv.submit("owner", vertices=[v]), "owner", None,
                        [v]))
        tickets.append((srv.submit("neighbors", vertices=[v]),
                        "neighbors", None, [v]))
    t0 = time.perf_counter()
    served = srv.serve_pending()
    dt = time.perf_counter() - t0
    replies = {t: srv.result(t) for t, *_ in tickets}
    assert all(r is not None and r.error is None
               for r in replies.values()), "serve loop dropped a request"
    if check:
        # every score reply must bit-match a direct run_many with the
        # SAME (combine, dtype) wire-cell grouping the server fuses —
        # the server only batches/caches, it never changes the compute
        from repro.session import resolve_program
        cells: dict = {}
        for p in SCORE_PROGRAMS:
            prog = resolve_program(p, n)
            cells.setdefault((prog.combine, np.dtype(prog.dtype).name),
                             []).append(p)
        direct = {}
        for progs in cells.values():
            outs = srv.sess.run_many(progs, iters=args.iters,
                                     exchange=args.exchange)
            direct.update(zip(progs, outs))
        for t, kind, prog, verts in tickets:
            if kind == "score":
                want = direct[prog][np.asarray(verts)]
                got = replies[t].value
                assert np.array_equal(got, want), (prog, got, want)
        print(f"[serve] {args.queries} score replies bit-match direct "
              f"run_many ({args.exchange} wire)")
    return {"served": served, "query_ms": dt * 1e3 / max(served, 1),
            "microbatches": srv.stats["microbatches"]}


def drive_ingest(srv: GraphServer, args) -> dict:
    """Stream random edge arrivals until ``--ingest-windows`` windows
    have flushed; return the RF drift/repair summary."""
    rng = np.random.default_rng(args.seed + 2)
    n = srv.sess.num_vertices
    target = srv.stats["windows"] + args.ingest_windows
    while srv.stats["windows"] < target:
        chunk = max(1, args.window // 4)
        srv.ingest(rng.integers(0, n, chunk), rng.integers(0, n, chunk))
    drifted = [v for e, v in srv.rf_trace if e == "window"]
    repaired = [v for e, v in srv.rf_trace if e == "restream"]
    return {"rf_base": srv.rf_trace[0][1],
            "rf_drifted": max(drifted) if drifted else srv.rf_base,
            "rf_post_restream": repaired[-1] if repaired else None,
            "restreams": srv.stats["restreams"],
            "ingested_edges": srv.stats["ingested_edges"]}


def child_snapshot(args) -> None:
    """The preemption victim: build the deterministic server, serve one
    microbatch, checkpoint, then SIGKILL this very process — nothing
    after the kill runs, so only the atomic snapshot survives."""
    ft = ServiceFT(args.ckpt_dir)
    srv = build_server(args, ft=ft)
    srv.submit("score", program="pagerank", vertices=[0, 1])
    srv.step()
    srv.checkpoint()
    ft.wait()
    print("[serve-child] snapshot written, dying", flush=True)
    os.kill(os.getpid(), signal.SIGKILL)


def kill_resume_check(args) -> None:
    """Spawn the child, verify it died by SIGKILL, resume from its
    snapshot, and assert the partition state is identical to the
    deterministic reference."""
    cmd = [sys.executable, "-m", "repro.launch.serve_graph",
           "--child-snapshot", "--ckpt-dir", args.ckpt_dir,
           "--scale", str(args.scale), "--k", str(args.k),
           "--exchange", args.exchange, "--backend", args.backend,
           "--iters", str(args.iters), "--seed", str(args.seed),
           "--window", str(args.window)]
    proc = subprocess.run(cmd, capture_output=True, text=True)
    assert proc.returncode == -signal.SIGKILL, (
        f"child expected to die by SIGKILL, got {proc.returncode}:\n"
        f"{proc.stdout}{proc.stderr}")
    ref = build_server(args)
    srv = GraphServer.resume(ServiceFT(args.ckpt_dir))
    assert srv.sess.to_json() == ref.sess.to_json(), "config blob drifted"
    assert np.array_equal(srv.sess.assign, ref.sess.assign), \
        "resumed assignment differs from the pre-kill partition"
    ta = srv.submit("score", program="pagerank", vertices=[0, 1])
    srv.step()
    tb = ref.submit("score", program="pagerank", vertices=[0, 1])
    ref.step()
    assert np.array_equal(srv.result(ta).value, ref.result(tb).value)
    print("[serve] SIGKILL'd child resumed from snapshot: identical "
          "config, assignment, and replies")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--exchange", default="halo")
    ap.add_argument("--backend", default="np")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--window", type=int, default=2048)
    ap.add_argument("--ingest-windows", type=int, default=3)
    ap.add_argument("--watermark", type=float, default=1.02)
    ap.add_argument("--restream-passes", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="assert correctness gates (CI mode)")
    ap.add_argument("--child-snapshot", action="store_true",
                    help=argparse.SUPPRESS)   # internal: preemption victim
    ap.add_argument("--out", default=None,
                    help="override results/BENCH_serve.json")
    args = ap.parse_args()

    if args.child_snapshot:
        child_snapshot(args)
        return 0                    # unreachable — SIGKILL above

    srv = build_server(args)
    q = drive_queries(srv, args, check=args.smoke)
    ing = drive_ingest(srv, args)
    if args.smoke:
        assert ing["restreams"] >= 1, (
            f"RF watermark never tripped: trace {srv.rf_trace}")
        assert ing["rf_post_restream"] <= ing["rf_drifted"] + 1e-9, ing
        # the grown graph still serves
        t = srv.submit("score", program="pagerank", vertices=[0])
        srv.step()
        assert srv.result(t).error is None
        print(f"[serve] drift {ing['rf_drifted']:.3f} repaired to "
              f"{ing['rf_post_restream']:.3f} over {ing['restreams']} "
              f"restream(s)")
    if args.ckpt_dir and args.smoke:
        kill_resume_check(args)

    row = {"bench": "serve", "scale": args.scale, "k": args.k,
           "exchange": args.exchange, "window": args.window,
           "queries": q["served"], "microbatches": q["microbatches"],
           "query_ms": round(q["query_ms"], 3),
           "rf_base": round(ing["rf_base"], 4),
           "rf_drifted": round(ing["rf_drifted"], 4),
           "rf_post_restream": round(ing["rf_post_restream"], 4)
           if ing["rf_post_restream"] is not None else None,
           "restreams": ing["restreams"],
           "ingested_edges": ing["ingested_edges"]}
    out = (Path(args.out) if args.out else
           Path(__file__).resolve().parents[3] / "results"
           / "BENCH_serve.json")
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps([row], indent=1))
    print(",".join(f"{k}={v}" for k, v in row.items()))
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
